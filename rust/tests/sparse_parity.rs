//! Golden-parity suite for the sparse CSR projector backend (ISSUE 10).
//!
//! The tentpole claims pinned here, all at coordinator level (through
//! splitting, staging, merge schedules and the residency machinery):
//!
//! * sparse forward projection is **bit-identical** to the ray-driven
//!   Siddon kernel for every device count × split × merge strategy —
//!   the SpMV replays the traversal's f32 ops in the same order, and
//!   the merge fold order is a function of the plan, not the backend;
//! * sparse backprojection is the **matched adjoint** (⟨Ax, y⟩ = ⟨x,
//!   Aᵀy⟩ through the whole multi-device path) and deterministic;
//! * CSR shards are built once and **reused from the cache** on every
//!   later iteration of a reconstruction session (zero rebuilds);
//! * the simulated timeline charges the one-time build only on the
//!   first (cold) operator call per plan — warm calls are cheaper.

use tigre::algorithms::{self, ReconOpts};
use tigre::coordinator::{ExecMode, MergeStrategy, MultiGpu, ProjectorChoice, ReconSession};
use tigre::geometry::Geometry;
use tigre::kernels::scratch;
use tigre::metrics;
use tigre::phantom;
use tigre::volume::{TrackedProjections, TrackedVolume, Volume};

/// Device memory small enough that the volume must image-split.
fn tiny_mem(n: usize, n_angles: usize) -> u64 {
    let g = Geometry::cone_beam(n, n_angles);
    let plane = (n * n * 4) as u64;
    8 * plane + 3 * 32.min(n_angles) as u64 * g.single_proj_bytes()
}

#[test]
fn sparse_fp_bitwise_matches_siddon_across_gpus_splits_and_merges() {
    let n = 18;
    let n_angles = 12;
    let g = Geometry::cone_beam(n, n_angles);
    let v = phantom::shepp_logan(n);
    let mem = tiny_mem(n, n_angles);
    for gpus in [1usize, 2, 3] {
        for image_split in [false, true] {
            for tree in [false, true] {
                let mut base = MultiGpu::gtx1080ti(gpus);
                if image_split {
                    base = base.with_device_mem(mem);
                }
                if tree {
                    base = base.with_merge_strategy(MergeStrategy::Tree);
                }
                let ray = base
                    .forward(&g, Some(&v), ExecMode::Full)
                    .unwrap()
                    .0
                    .unwrap();
                let sparse = base
                    .clone()
                    .with_sparse_backend()
                    .forward(&g, Some(&v), ExecMode::Full)
                    .unwrap()
                    .0
                    .unwrap();
                assert_eq!(
                    sparse.data, ray.data,
                    "sparse FP must be bit-identical to Siddon \
                     (gpus={gpus} image_split={image_split} tree={tree})"
                );
            }
        }
    }
}

#[test]
fn sparse_fp_close_to_joseph() {
    // Joseph interpolates instead of intersecting, so parity with it is
    // numerical, not bitwise: both discretize the same line integrals.
    let n = 16;
    let g = Geometry::cone_beam(n, 10);
    let v = phantom::shepp_logan(n);
    let sparse = MultiGpu::gtx1080ti(2)
        .with_sparse_backend()
        .forward(&g, Some(&v), ExecMode::Full)
        .unwrap()
        .0
        .unwrap();
    let joseph = MultiGpu::gtx1080ti(2)
        .with_projector(ProjectorChoice::Joseph)
        .forward(&g, Some(&v), ExecMode::Full)
        .unwrap()
        .0
        .unwrap();
    let num: f64 = sparse
        .data
        .iter()
        .zip(&joseph.data)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum();
    let den: f64 = joseph.data.iter().map(|x| (*x as f64).powi(2)).sum();
    let rel = (num / den.max(1e-30)).sqrt();
    assert!(rel < 0.5, "sparse vs joseph relative L2 {rel}");
}

#[test]
fn sparse_bp_deterministic_and_consistent_across_device_counts() {
    let n = 16;
    let n_angles = 12;
    let g = Geometry::cone_beam(n, n_angles);
    let truth = phantom::shepp_logan(n);
    let p = MultiGpu::gtx1080ti(1)
        .forward(&g, Some(&truth), ExecMode::Full)
        .unwrap()
        .0
        .unwrap();
    let run = |gpus: usize| -> Volume {
        MultiGpu::gtx1080ti(gpus)
            .with_device_mem(tiny_mem(n, n_angles))
            .with_sparse_backend()
            .backward(&g, Some(&p), ExecMode::Full)
            .unwrap()
            .0
            .unwrap()
    };
    // same configuration twice: bitwise deterministic
    assert_eq!(run(2).data, run(2).data);
    // across device counts the chunk fold grouping may differ, so the
    // comparison is numerical — same tolerance as the ray-driven suite
    let r1 = run(1);
    let r3 = run(3);
    let rel = metrics::rel_l2(&r1, &r3);
    assert!(rel < 2e-3, "sparse BP deviates across device counts: {rel}");
}

#[test]
fn sparse_bp_is_matched_adjoint_through_the_coordinator() {
    // ⟨Ax, y⟩ == ⟨x, Aᵀy⟩ (up to f32 rounding) through the full
    // multi-device split/merge path — the property CGLS-class solvers
    // need, exact for SpMV/SpMVᵀ where the ray-driven pair is only
    // pseudo-matched.
    let n = 16;
    let n_angles = 10;
    let g = Geometry::cone_beam(n, n_angles);
    let x = phantom::shepp_logan(n);
    let ctx = MultiGpu::gtx1080ti(2)
        .with_device_mem(tiny_mem(n, n_angles))
        .with_sparse_backend();
    let ax = ctx.forward(&g, Some(&x), ExecMode::Full).unwrap().0.unwrap();
    let mut y = ax.clone();
    for (i, v) in y.data.iter_mut().enumerate() {
        *v = ((i % 23) as f32 - 11.0) / 23.0;
    }
    let aty = ctx.backward(&g, Some(&y), ExecMode::Full).unwrap().0.unwrap();
    let lhs: f64 = ax.data.iter().zip(&y.data).map(|(a, b)| *a as f64 * *b as f64).sum();
    let rhs: f64 = aty.data.iter().zip(&x.data).map(|(a, b)| *a as f64 * *b as f64).sum();
    let denom = lhs.abs().max(rhs.abs()).max(1e-12);
    assert!(
        ((lhs - rhs) / denom).abs() < 1e-4,
        "adjoint identity violated through the coordinator: {lhs} vs {rhs}"
    );
}

#[test]
fn sparse_shards_built_once_and_reused_across_iterations() {
    // The residency acceptance gate: on iteration 2+ of a session loop
    // the shard cache serves every unit from memory — `builds` must not
    // move, and hits must accumulate.
    let n = 16;
    let n_angles = 12;
    let g = Geometry::cone_beam(n, n_angles);
    let truth = phantom::cube(n, 0.5, 1.0);
    let ctx = MultiGpu::gtx1080ti(2)
        .with_device_mem(tiny_mem(n, n_angles))
        .with_sparse_backend();
    let proj = ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap().0.unwrap();

    let mut sess = ReconSession::new(&ctx, &g).unwrap();
    let b = TrackedProjections::new(proj);
    let mut x = TrackedVolume::new(Volume::zeros_like(&g));
    let mut builds_after_first = 0u64;
    let mut hits_after_first = 0u64;
    for it in 0..3 {
        let ax = sess.forward(&x).unwrap();
        let (upd, _) = sess.backward_residual(&b, &ax).unwrap();
        sess.recycle_projections(ax);
        x.write().add_scaled(&upd, 1e-3);
        scratch::recycle_volume(upd);
        let stats = ctx.sparse_shard_stats().expect("sparse backend has shard stats");
        if it == 0 {
            assert!(stats.builds > 0, "first iteration must build shards");
            builds_after_first = stats.builds;
            hits_after_first = stats.hits;
        } else {
            assert_eq!(
                stats.builds, builds_after_first,
                "iteration {it} rebuilt a shard the cache should have served"
            );
            assert!(
                stats.hits > hits_after_first,
                "iteration {it} did not hit the shard cache"
            );
            hits_after_first = stats.hits;
        }
    }
    sess.recycle_projections(b);
}

#[test]
fn sparse_simonly_warm_call_cheaper_than_cold() {
    // The simulated timeline charges `sparse_setup_s` only on the first
    // (cold) call per (operator, plan); later calls replay the warm SpMV
    // and must cost strictly less — the basis of the SimOnly crossover
    // report (`tigre project --sim-only --projector sparse`).
    let g = Geometry::cone_beam(32, 16);
    let ctx = MultiGpu::gtx1080ti(2).with_sparse_backend();
    let cold_fp = ctx.forward(&g, None, ExecMode::SimOnly).unwrap().1.makespan_s;
    let warm_fp = ctx.forward(&g, None, ExecMode::SimOnly).unwrap().1.makespan_s;
    assert!(warm_fp < cold_fp, "warm FP {warm_fp} must beat cold {cold_fp}");
    let cold_bp = ctx.backward(&g, None, ExecMode::SimOnly).unwrap().1.makespan_s;
    let warm_bp = ctx.backward(&g, None, ExecMode::SimOnly).unwrap().1.makespan_s;
    assert!(warm_bp < cold_bp, "warm BP {warm_bp} must beat cold {cold_bp}");
    // a warm sparse sweep never loses to the ray-driven kernel: the SpMV
    // replays stored entries at a strictly higher modeled throughput
    let ray_fp = MultiGpu::gtx1080ti(2)
        .forward(&g, None, ExecMode::SimOnly)
        .unwrap()
        .1
        .makespan_s;
    assert!(warm_fp <= ray_fp, "warm sparse FP {warm_fp} vs ray {ray_fp}");
}

#[test]
fn cgls_with_sparse_projector_opt_converges() {
    // The `ReconOpts::projector` plumb-through: CGLS (which requires a
    // matched pair, sparse's home turf) selected via options rather than
    // a pre-configured context.
    let n = 16;
    let g = Geometry::cone_beam(n, 20);
    let truth = phantom::shepp_logan(n);
    let ctx = MultiGpu::gtx1080ti(2);
    let p = ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap().0.unwrap();
    let opts = ReconOpts {
        iterations: 8,
        nonneg: false,
        projector: Some(ProjectorChoice::Sparse),
        ..Default::default()
    };
    let r = algorithms::cgls(&ctx, &g, &p, &opts).unwrap();
    let corr = metrics::correlation(&truth, &r.volume);
    assert!(corr > 0.8, "sparse CGLS correlation {corr}");
    let first = r.residuals[0];
    let last = *r.residuals.last().unwrap();
    assert!(last < first * 0.5, "sparse CGLS residuals {first} → {last}");
}
