//! PJRT runtime: load AOT-compiled JAX/Pallas artifacts (HLO text) and
//! execute them from the rust hot path.
//!
//! Python runs only at build time (`make artifacts`): `python/compile/aot.py`
//! lowers the L2 jax operators (which call the L1 Pallas kernels) to HLO
//! *text* — not serialized protos; the image's xla_extension 0.5.1 rejects
//! jax ≥ 0.5's 64-bit instruction ids, while the text parser reassigns ids
//! (see /opt/xla-example/README.md) — plus a `manifest.json` describing
//! the shapes. This module loads the manifest, compiles the modules on the
//! PJRT CPU client once (cached per thread) and executes them.
//!
//! Artifacts exist for the manifest's shape set; any other shape falls
//! back to the native rust kernels, so the coordinator works for
//! arbitrary sizes either way (the paper's kernel-agnosticism, §2).

pub mod manifest;

#[cfg(feature = "pjrt")]
pub mod pjrt;

/// Stub backend for default (featureless) builds: reports "no artifact"
/// for every shape so the callers below fall back to the native
/// kernels. With `--features pjrt` the real module above compiles
/// instead — against the GPU image's `xla` crate when present, or the
/// vendored API shim (`vendor/xla`) offline, which type-checks the
/// backend in CI and fails at runtime into the same native fallback.
#[cfg(not(feature = "pjrt"))]
pub mod pjrt {
    use crate::geometry::Geometry;
    use crate::volume::{ProjectionSet, Volume};
    use std::path::Path;

    /// Always `Ok(None)` ("no artifact") in featureless builds.
    pub fn try_forward(
        _dir: &Path,
        _g: &Geometry,
        _vol: &Volume,
    ) -> anyhow::Result<Option<ProjectionSet>> {
        Ok(None)
    }

    /// Always `Ok(None)` ("no artifact") in featureless builds.
    pub fn try_backward(
        _dir: &Path,
        _g: &Geometry,
        _proj: &ProjectionSet,
        _weight: crate::kernels::BackprojWeight,
    ) -> anyhow::Result<Option<Volume>> {
        Ok(None)
    }
}

pub use manifest::{Manifest, ManifestEntry};

use crate::geometry::Geometry;
use crate::volume::{ProjectionSet, Volume};
use std::path::Path;

/// Forward projection via a PJRT artifact when the manifest has the
/// shape, native Siddon otherwise.
pub fn forward_or_native(dir: &Path, g: &Geometry, vol: &Volume, threads: usize) -> ProjectionSet {
    match pjrt::try_forward(dir, g, vol) {
        Ok(Some(p)) => p,
        Ok(None) => crate::kernels::forward(g, vol, crate::kernels::Projector::Siddon, threads),
        Err(e) => {
            crate::log_warn!("pjrt forward failed ({e:#}); falling back to native");
            crate::kernels::forward(g, vol, crate::kernels::Projector::Siddon, threads)
        }
    }
}

/// Backprojection via a PJRT artifact when available, native otherwise.
/// `weight` selects between the FDK-weighted and pseudo-matched artifacts
/// (the gradient algorithms require the matched pair).
pub fn backward_or_native(
    dir: &Path,
    g: &Geometry,
    proj: &ProjectionSet,
    weight: crate::kernels::BackprojWeight,
    threads: usize,
) -> Volume {
    match pjrt::try_backward(dir, g, proj, weight) {
        Ok(Some(v)) => v,
        Ok(None) => crate::kernels::backward(g, proj, weight, threads),
        Err(e) => {
            crate::log_warn!("pjrt backward failed ({e:#}); falling back to native");
            crate::kernels::backward(g, proj, weight, threads)
        }
    }
}
