//! Host (CPU) memory state tracking: pageable vs page-locked (pinned).
//!
//! The paper's strategy depends on *when* host buffers are pinned:
//! pinned memory transfers ~3× faster over PCIe-Gen3 (≈12 vs ≈4 GB/s) and
//! enables asynchronous copies, but the pin operation itself is expensive
//! and forces physical allocation. This registry records allocation and
//! pin/unpin events so the cost model can charge them and Fig. 9 can bin
//! them ("memory page-locking and unlocking").

use std::collections::BTreeMap;

/// Pageable vs pinned state of a host allocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemState {
    /// OS-managed memory: synchronous transfers at pageable bandwidth.
    Pageable,
    /// Page-locked memory: async transfers at pinned bandwidth.
    Pinned,
}

/// A pin or unpin event, for cost accounting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PinEvent {
    /// Size of the allocation whose state changed.
    pub bytes: u64,
    /// True for a pin, false for an unpin.
    pub pin: bool,
}

/// Typed misuse errors for the pin/unpin state machine. Double-pinning
/// (or unpinning pageable memory) indicates a scheduling bug — in CUDA a
/// second `cudaHostRegister` of the same range fails — so the registry
/// reports it instead of silently absorbing it, and pin events are
/// charged only on an actual state change.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum HostMemError {
    /// No allocation registered under this name.
    UnknownAlloc(String),
    /// `pin` on an allocation that is already pinned.
    AlreadyPinned(String),
    /// `unpin` on an allocation that is pageable.
    NotPinned(String),
}

impl std::fmt::Display for HostMemError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HostMemError::UnknownAlloc(n) => write!(f, "no host allocation named '{n}'"),
            HostMemError::AlreadyPinned(n) => write!(f, "allocation '{n}' is already pinned"),
            HostMemError::NotPinned(n) => write!(f, "allocation '{n}' is not pinned"),
        }
    }
}

impl std::error::Error for HostMemError {}

/// Registry of named host allocations and their pin states.
#[derive(Debug, Default)]
pub struct HostMemRegistry {
    allocs: BTreeMap<String, (u64, MemState)>,
    events: Vec<PinEvent>,
}

impl HostMemRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an allocation (host buffers start pageable, as in
    /// MATLAB/Python-managed memory — paper §2).
    pub fn alloc(&mut self, name: &str, bytes: u64) {
        self.allocs.insert(name.to_string(), (bytes, MemState::Pageable));
    }

    /// Drop an allocation (unknown names are a no-op).
    pub fn free(&mut self, name: &str) {
        self.allocs.remove(name);
    }

    /// Current pin state of the named allocation, if registered.
    pub fn state(&self, name: &str) -> Option<MemState> {
        self.allocs.get(name).map(|(_, s)| *s)
    }

    /// Size of the named allocation, if registered.
    pub fn bytes(&self, name: &str) -> Option<u64> {
        self.allocs.get(name).map(|(b, _)| *b)
    }

    /// Page-lock an allocation, returning the bytes pinned. Pinning an
    /// already-pinned allocation (or an unknown name) is a typed
    /// [`HostMemError`]; a pin event is charged only on the actual
    /// pageable→pinned transition.
    pub fn pin(&mut self, name: &str) -> Result<u64, HostMemError> {
        match self.allocs.get_mut(name) {
            None => Err(HostMemError::UnknownAlloc(name.to_string())),
            Some((_, MemState::Pinned)) => Err(HostMemError::AlreadyPinned(name.to_string())),
            Some((bytes, state)) => {
                *state = MemState::Pinned;
                let b = *bytes;
                self.events.push(PinEvent { bytes: b, pin: true });
                Ok(b)
            }
        }
    }

    /// Unpin an allocation, returning the bytes unpinned. Unpinning
    /// pageable memory (or an unknown name) is a typed [`HostMemError`];
    /// an unpin event is charged only on the pinned→pageable transition.
    pub fn unpin(&mut self, name: &str) -> Result<u64, HostMemError> {
        match self.allocs.get_mut(name) {
            None => Err(HostMemError::UnknownAlloc(name.to_string())),
            Some((_, MemState::Pageable)) => Err(HostMemError::NotPinned(name.to_string())),
            Some((bytes, state)) => {
                *state = MemState::Pageable;
                let b = *bytes;
                self.events.push(PinEvent { bytes: b, pin: false });
                Ok(b)
            }
        }
    }

    /// Total currently-pinned bytes.
    pub fn pinned_bytes(&self) -> u64 {
        self.allocs
            .values()
            .filter(|(_, s)| *s == MemState::Pinned)
            .map(|(b, _)| *b)
            .sum()
    }

    /// Total registered bytes.
    pub fn total_bytes(&self) -> u64 {
        self.allocs.values().map(|(b, _)| *b).sum()
    }

    /// All pin/unpin events since construction.
    pub fn events(&self) -> &[PinEvent] {
        &self.events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_starts_pageable() {
        let mut r = HostMemRegistry::new();
        r.alloc("image", 1024);
        assert_eq!(r.state("image"), Some(MemState::Pageable));
        assert_eq!(r.bytes("image"), Some(1024));
    }

    #[test]
    fn pin_unpin_events_charged_only_on_state_change() {
        let mut r = HostMemRegistry::new();
        r.alloc("image", 100);
        assert_eq!(r.pin("image"), Ok(100));
        // re-pinning is a typed error, and must not add a second event
        assert_eq!(r.pin("image"), Err(HostMemError::AlreadyPinned("image".into())));
        assert_eq!(r.pinned_bytes(), 100);
        assert_eq!(r.unpin("image"), Ok(100));
        assert_eq!(r.unpin("image"), Err(HostMemError::NotPinned("image".into())));
        assert_eq!(r.events().len(), 2, "exactly one pin + one unpin event");
        assert!(r.events()[0].pin && !r.events()[1].pin);
        // the error type is displayable and a std error
        let e: Box<dyn std::error::Error> =
            Box::new(r.unpin("image").unwrap_err());
        assert!(e.to_string().contains("not pinned"), "{e}");
    }

    #[test]
    fn unknown_names_are_typed_errors() {
        let mut r = HostMemRegistry::new();
        assert_eq!(r.pin("nope"), Err(HostMemError::UnknownAlloc("nope".into())));
        assert_eq!(r.unpin("nope"), Err(HostMemError::UnknownAlloc("nope".into())));
        assert_eq!(r.state("nope"), None);
        assert!(r.events().is_empty(), "failed transitions charge no events");
    }

    #[test]
    fn totals() {
        let mut r = HostMemRegistry::new();
        r.alloc("a", 10);
        r.alloc("b", 20);
        r.pin("b").unwrap();
        assert_eq!(r.total_bytes(), 30);
        assert_eq!(r.pinned_bytes(), 20);
        r.free("b");
        assert_eq!(r.total_bytes(), 10);
        assert_eq!(r.pinned_bytes(), 0);
    }
}
