//! Interpolated (Joseph-style) forward projector.
//!
//! Samples the volume at fixed parametric steps along each ray with
//! trilinear interpolation — the CPU analogue of TIGRE's texture-memory
//! interpolated projector (hardware trilinear fetch on the GPU, explicit
//! lerp here and in the Pallas kernel). Slower than Siddon but smoother;
//! the paper notes it "gave virtually the same results" and is kept for
//! completeness.
//!
//! Hot-path structure (EXPERIMENTS.md §Perf): the ray is clipped and its
//! sampling schedule fixed in f64, then the sample walk runs in f32 over
//! *voxel-space* coordinates (the world→voxel transform is folded into the
//! per-ray affine setup). Interior samples take a stride-based trilinear
//! fast path with no clamping and unchecked 2×2×2 loads; only samples
//! whose neighborhood touches a face fall back to the clamped path.

use crate::geometry::{DetFrame, Geometry};
use crate::util::threadpool::{parallel_for, SendPtr};
use crate::volume::{ProjectionSet, Volume, VolumeSlabView};

/// Sampling step as a fraction of the smallest voxel pitch.
pub const STEP_FRACTION: f64 = 0.5;

/// Forward-project all angles of `g` by sampled trilinear interpolation.
pub fn project(g: &Geometry, vol: &Volume, threads: usize) -> ProjectionSet {
    let nu = g.n_det[0];
    let nv = g.n_det[1];
    let mut out = crate::kernels::scratch::take_projections(nu, nv, g.n_angles());
    project_into(g, &vol.as_view(), &mut out.data, threads);
    out
}

/// Forward-project a borrowed (slab) volume view straight into `out`
/// (every element overwritten) — the zero-copy entry point used by the
/// pipelined executor; see `siddon::project_into` for the contract.
pub fn project_into(g: &Geometry, vol: &VolumeSlabView<'_>, out: &mut [f32], threads: usize) {
    assert_eq!(
        [vol.nx, vol.ny, vol.nz],
        [g.n_vox[0], g.n_vox[1], g.n_vox[2]],
        "volume shape does not match geometry"
    );
    let nu = g.n_det[0];
    let nv = g.n_det[1];
    let n_angles = g.n_angles();
    assert_eq!(out.len(), nu * nv * n_angles, "output length mismatch");

    let frames: Vec<DetFrame> = (0..n_angles).map(|a| g.det_frame(a)).collect();
    let (lo, hi) = g.volume_bbox();
    let step = STEP_FRACTION * g.d_vox.iter().cloned().fold(f64::INFINITY, f64::min);
    let sampler = VolSampler::new(vol);

    let rows = n_angles * nv;
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for(rows, threads, 8, |r0, r1| {
        let ptr = ptr;
        for row in r0..r1 {
            let a = row / nv;
            let iv = row % nv;
            let frame = &frames[a];
            let row0 = frame.row_origin(iv);
            let us = frame.u_step;
            for iu in 0..nu {
                let fu = iu as f64;
                let pix = [
                    row0[0] + fu * us[0],
                    row0[1] + fu * us[1],
                    row0[2] + fu * us[2],
                ];
                let val = sample_ray(&frame.src, &pix, &lo, &hi, g, &sampler, step);
                // SAFETY: parallel_for hands each task a disjoint range of
                // detector rows, so index (a*nv+iv)*nu+iu is written by
                // exactly one task; out.len() == n_angles*nv*nu (asserted
                // above) bounds it.
                unsafe {
                    *ptr.0.add((a * nv + iv) * nu + iu) = val;
                }
            }
        }
    });
}

/// Volume view with the strides and bounds the trilinear fast path needs.
struct VolSampler<'a> {
    data: &'a [f32],
    nx: usize,
    ny: usize,
    nz: usize,
    /// y/z strides in elements (x stride is 1).
    sy: usize,
    sz: usize,
}

impl<'a> VolSampler<'a> {
    fn new(vol: &VolumeSlabView<'a>) -> Self {
        Self {
            data: vol.data,
            nx: vol.nx,
            ny: vol.ny,
            nz: vol.nz,
            sy: vol.nx,
            sz: vol.nx * vol.ny,
        }
    }

    /// Trilinear sample at voxel-space coordinates (`q = (p-lo)/dvox - ½`,
    /// i.e. sample coordinates where integers are voxel centres).
    #[inline(always)]
    fn trilinear_q(&self, qx: f32, qy: f32, qz: f32) -> f32 {
        let x0 = qx.floor();
        let y0 = qy.floor();
        let z0 = qz.floor();
        let wx = qx - x0;
        let wy = qy - y0;
        let wz = qz - z0;
        let xi = x0 as isize;
        let yi = y0 as isize;
        let zi = z0 as isize;
        // Interior fast path: the whole 2×2×2 neighborhood is in-bounds,
        // so the eight taps are unchecked loads at fixed stride offsets.
        if xi >= 0
            && yi >= 0
            && zi >= 0
            && (xi as usize) + 1 < self.nx
            && (yi as usize) + 1 < self.ny
            && (zi as usize) + 1 < self.nz
        {
            let base = zi as usize * self.sz + yi as usize * self.sy + xi as usize;
            // SAFETY: base + sz + sy + 1 < data.len() by the bounds above.
            unsafe {
                let v000 = *self.data.get_unchecked(base);
                let v100 = *self.data.get_unchecked(base + 1);
                let v010 = *self.data.get_unchecked(base + self.sy);
                let v110 = *self.data.get_unchecked(base + self.sy + 1);
                let v001 = *self.data.get_unchecked(base + self.sz);
                let v101 = *self.data.get_unchecked(base + self.sz + 1);
                let v011 = *self.data.get_unchecked(base + self.sz + self.sy);
                let v111 = *self.data.get_unchecked(base + self.sz + self.sy + 1);
                let c00 = v000 + (v100 - v000) * wx;
                let c10 = v010 + (v110 - v010) * wx;
                let c01 = v001 + (v101 - v001) * wx;
                let c11 = v011 + (v111 - v011) * wx;
                let c0 = c00 + (c10 - c00) * wy;
                let c1 = c01 + (c11 - c01) * wy;
                return c0 + (c1 - c0) * wz;
            }
        }
        self.trilinear_q_edge(xi, yi, zi, wx, wy, wz)
    }

    /// Clamped slow path for samples whose neighborhood touches a face
    /// (CUDA texture clamp addressing).
    #[inline(never)]
    fn trilinear_q_edge(&self, xi: isize, yi: isize, zi: isize, wx: f32, wy: f32, wz: f32) -> f32 {
        let cl = |i: isize, n: usize| (i.max(0) as usize).min(n - 1);
        let (x0i, x1i) = (cl(xi, self.nx), cl(xi + 1, self.nx));
        let (y0i, y1i) = (cl(yi, self.ny), cl(yi + 1, self.ny));
        let (z0i, z1i) = (cl(zi, self.nz), cl(zi + 1, self.nz));
        let at = |x: usize, y: usize, z: usize| self.data[z * self.sz + y * self.sy + x];
        let v000 = at(x0i, y0i, z0i);
        let v100 = at(x1i, y0i, z0i);
        let v010 = at(x0i, y1i, z0i);
        let v110 = at(x1i, y1i, z0i);
        let v001 = at(x0i, y0i, z1i);
        let v101 = at(x1i, y0i, z1i);
        let v011 = at(x0i, y1i, z1i);
        let v111 = at(x1i, y1i, z1i);
        let c00 = v000 + (v100 - v000) * wx;
        let c10 = v010 + (v110 - v010) * wx;
        let c01 = v001 + (v101 - v001) * wx;
        let c11 = v011 + (v111 - v011) * wx;
        let c0 = c00 + (c10 - c00) * wy;
        let c1 = c01 + (c11 - c01) * wy;
        c0 + (c1 - c0) * wz
    }
}

/// Integrate by sampling `src→dst` every `step` mm with trilinear lookups.
fn sample_ray(
    src: &[f64; 3],
    dst: &[f64; 3],
    lo: &[f64; 3],
    hi: &[f64; 3],
    g: &Geometry,
    sampler: &VolSampler<'_>,
    step: f64,
) -> f32 {
    let dir = [dst[0] - src[0], dst[1] - src[1], dst[2] - src[2]];
    let len = (dir[0] * dir[0] + dir[1] * dir[1] + dir[2] * dir[2]).sqrt();
    if len == 0.0 {
        return 0.0;
    }
    // Clip to the volume box (f64 setup).
    let mut tmin = 0.0f64;
    let mut tmax = 1.0f64;
    for k in 0..3 {
        if dir[k].abs() < 1e-12 {
            if src[k] < lo[k] || src[k] > hi[k] {
                return 0.0;
            }
        } else {
            let inv = 1.0 / dir[k];
            let t0 = (lo[k] - src[k]) * inv;
            let t1 = (hi[k] - src[k]) * inv;
            let (t0, t1) = if t0 <= t1 { (t0, t1) } else { (t1, t0) };
            tmin = tmin.max(t0);
            tmax = tmax.min(t1);
        }
    }
    if tmin >= tmax {
        return 0.0;
    }

    let dt = step / len;
    let n_steps = (((tmax - tmin) / dt).ceil() as usize).max(1);
    let dt = (tmax - tmin) / n_steps as f64; // equalize last step
    let seg = dt * len;

    // Voxel-space affine sampling schedule (f64 setup → f32 walk): sample
    // k sits at q0 + k·qs, where integers are voxel centres. Multiplying
    // by k instead of incrementally adding avoids f32 drift along the ray.
    let t0 = tmin + 0.5 * dt;
    let mut q0 = [0.0f32; 3];
    let mut qs = [0.0f32; 3];
    for k in 0..3 {
        let p0 = src[k] + t0 * dir[k];
        q0[k] = ((p0 - lo[k]) / g.d_vox[k] - 0.5) as f32;
        qs[k] = (dt * dir[k] / g.d_vox[k]) as f32;
    }

    // Midpoint rule: sample at the centre of each step, accumulate in f32
    // and scale by the segment length once.
    let mut acc = 0.0f32;
    for k in 0..n_steps {
        let fk = k as f32;
        let qx = q0[0] + fk * qs[0];
        let qy = q0[1] + fk * qs[1];
        let qz = q0[2] + fk * qs[2];
        acc += sampler.trilinear_q(qx, qy, qz);
    }
    acc * seg as f32
}

/// Trilinear interpolation at world point `p`; samples are at voxel
/// centres, clamped at the faces (matching CUDA texture clamp addressing).
///
/// Public reference entry point (tests, external callers); the kernel
/// itself uses the precomputed-stride [`VolSampler`] fast path, which this
/// delegates to.
#[inline]
pub fn trilinear(g: &Geometry, vol: &Volume, lo: &[f64; 3], p: &[f64; 3]) -> f32 {
    let fx = ((p[0] - lo[0]) / g.d_vox[0] - 0.5) as f32;
    let fy = ((p[1] - lo[1]) / g.d_vox[1] - 0.5) as f32;
    let fz = ((p[2] - lo[2]) / g.d_vox[2] - 0.5) as f32;
    VolSampler::new(&vol.as_view()).trilinear_q(fx, fy, fz)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phantom;

    /// Pre-refactor scalar trilinear (f64 world-space weights, closure
    /// clamps on every tap) — the golden oracle for the fast path.
    fn trilinear_ref(g: &Geometry, vol: &Volume, lo: &[f64; 3], p: &[f64; 3]) -> f32 {
        let fx = (p[0] - lo[0]) / g.d_vox[0] - 0.5;
        let fy = (p[1] - lo[1]) / g.d_vox[1] - 0.5;
        let fz = (p[2] - lo[2]) / g.d_vox[2] - 0.5;
        let x0 = fx.floor();
        let y0 = fy.floor();
        let z0 = fz.floor();
        let wx = (fx - x0) as f32;
        let wy = (fy - y0) as f32;
        let wz = (fz - z0) as f32;
        let cx = |i: f64| (i.max(0.0) as usize).min(vol.nx - 1);
        let cy = |i: f64| (i.max(0.0) as usize).min(vol.ny - 1);
        let cz = |i: f64| (i.max(0.0) as usize).min(vol.nz - 1);
        let (x0i, x1i) = (cx(x0), cx(x0 + 1.0));
        let (y0i, y1i) = (cy(y0), cy(y0 + 1.0));
        let (z0i, z1i) = (cz(z0), cz(z0 + 1.0));
        let v000 = vol.at(x0i, y0i, z0i);
        let v100 = vol.at(x1i, y0i, z0i);
        let v010 = vol.at(x0i, y1i, z0i);
        let v110 = vol.at(x1i, y1i, z0i);
        let v001 = vol.at(x0i, y0i, z1i);
        let v101 = vol.at(x1i, y0i, z1i);
        let v011 = vol.at(x0i, y1i, z1i);
        let v111 = vol.at(x1i, y1i, z1i);
        let c00 = v000 + (v100 - v000) * wx;
        let c10 = v010 + (v110 - v010) * wx;
        let c01 = v001 + (v101 - v001) * wx;
        let c11 = v011 + (v111 - v011) * wx;
        let c0 = c00 + (c10 - c00) * wy;
        let c1 = c01 + (c11 - c01) * wy;
        c0 + (c1 - c0) * wz
    }

    /// Pre-refactor sampling projector: per-pixel `det_pixel` addressing,
    /// f64 midpoint walk, per-sample f64 `seg` multiply — the golden
    /// oracle for the optimized `project`.
    fn project_ref(g: &Geometry, vol: &Volume) -> ProjectionSet {
        let nu = g.n_det[0];
        let nv = g.n_det[1];
        let mut out = ProjectionSet::zeros(nu, nv, g.n_angles());
        let (lo, hi) = g.volume_bbox();
        let step = STEP_FRACTION * g.d_vox.iter().cloned().fold(f64::INFINITY, f64::min);
        for a in 0..g.n_angles() {
            let frame = g.frame(a);
            for iv in 0..nv {
                for iu in 0..nu {
                    let pix = g.det_pixel(&frame, iu, iv);
                    *out.at_mut(iu, iv, a) =
                        sample_ray_ref(&frame.src, &pix, &lo, &hi, g, vol, step);
                }
            }
        }
        out
    }

    fn sample_ray_ref(
        src: &[f64; 3],
        dst: &[f64; 3],
        lo: &[f64; 3],
        hi: &[f64; 3],
        g: &Geometry,
        vol: &Volume,
        step: f64,
    ) -> f32 {
        let dir = [dst[0] - src[0], dst[1] - src[1], dst[2] - src[2]];
        let len = (dir[0] * dir[0] + dir[1] * dir[1] + dir[2] * dir[2]).sqrt();
        if len == 0.0 {
            return 0.0;
        }
        let mut tmin = 0.0f64;
        let mut tmax = 1.0f64;
        for k in 0..3 {
            if dir[k].abs() < 1e-12 {
                if src[k] < lo[k] || src[k] > hi[k] {
                    return 0.0;
                }
            } else {
                let inv = 1.0 / dir[k];
                let t0 = (lo[k] - src[k]) * inv;
                let t1 = (hi[k] - src[k]) * inv;
                let (t0, t1) = if t0 <= t1 { (t0, t1) } else { (t1, t0) };
                tmin = tmin.max(t0);
                tmax = tmax.min(t1);
            }
        }
        if tmin >= tmax {
            return 0.0;
        }
        let dt = step / len;
        let n_steps = (((tmax - tmin) / dt).ceil() as usize).max(1);
        let dt = (tmax - tmin) / n_steps as f64;
        let seg = dt * len;
        let mut acc = 0.0f64;
        let mut t = tmin + 0.5 * dt;
        for _ in 0..n_steps {
            let p = [src[0] + t * dir[0], src[1] + t * dir[1], src[2] + t * dir[2]];
            acc += trilinear_ref(g, vol, lo, &p) as f64 * seg;
            t += dt;
        }
        acc as f32
    }

    #[test]
    fn golden_parity_vs_reference() {
        let n = 20;
        let g = Geometry::cone_beam(n, 6);
        let v = phantom::shepp_logan(n);
        let opt = project(&g, &v, 2);
        let oracle = project_ref(&g, &v);
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (i, (a, b)) in oracle.data.iter().zip(&opt.data).enumerate() {
            assert!(
                (a - b).abs() <= 2e-4 * (1.0 + a.abs()),
                "pixel {i}: oracle {a} vs optimized {b}"
            );
            num += ((a - b) as f64).powi(2);
            den += (*a as f64).powi(2);
        }
        let rel = (num / den.max(1e-12)).sqrt();
        assert!(rel < 1e-4, "relative L2 deviation from oracle: {rel:.3e}");
    }

    #[test]
    fn trilinear_fast_path_matches_reference() {
        let g = Geometry::cone_beam(8, 1);
        let v = phantom::random(8, 8, 8, 11);
        let (lo, hi) = g.volume_bbox();
        // deterministic scatter of sample points covering interior + faces
        let mut rng = crate::util::pcg::Pcg32::new(3);
        for _ in 0..500 {
            let p = [
                lo[0] + (hi[0] - lo[0]) * rng.next_f32() as f64,
                lo[1] + (hi[1] - lo[1]) * rng.next_f32() as f64,
                lo[2] + (hi[2] - lo[2]) * rng.next_f32() as f64,
            ];
            let fast = trilinear(&g, &v, &lo, &p);
            let slow = trilinear_ref(&g, &v, &lo, &p);
            assert!(
                (fast - slow).abs() < 1e-5,
                "at {p:?}: fast {fast} vs ref {slow}"
            );
        }
    }

    #[test]
    fn agrees_with_siddon_on_smooth_phantom() {
        // A multi-voxel-scale sphere (no sub-voxel structure, where
        // interpolated and exact integrals legitimately diverge).
        let n = 20;
        let c = (n as f64 - 1.0) / 2.0;
        let v = crate::volume::Volume::from_fn(n, n, n, |x, y, z| {
            let d = ((x as f64 - c).powi(2) + (y as f64 - c).powi(2) + (z as f64 - c).powi(2))
                .sqrt();
            if d < 6.0 {
                1.0
            } else {
                0.0
            }
        });
        let g = Geometry::cone_beam(n, 4);
        let pj = project(&g, &v, 2);
        let ps = crate::kernels::siddon::project(&g, &v, 2);
        let r = pj.norm2() / ps.norm2();
        assert!((0.9..1.1).contains(&r), "energy ratio {r}");
        let cj = pj.at(g.n_det[0] / 2, g.n_det[1] / 2, 0);
        let cs = ps.at(g.n_det[0] / 2, g.n_det[1] / 2, 0);
        assert!((cj - cs).abs() / cs.max(1e-6) < 0.12, "centre {cj} vs {cs}");
    }

    #[test]
    fn trilinear_exact_at_voxel_centres() {
        let g = Geometry::cone_beam(8, 1);
        let v = phantom::random(8, 8, 8, 5);
        let (lo, _) = g.volume_bbox();
        for (x, y, z) in [(0usize, 0usize, 0usize), (3, 4, 5), (7, 7, 7)] {
            let p = [
                lo[0] + (x as f64 + 0.5) * g.d_vox[0],
                lo[1] + (y as f64 + 0.5) * g.d_vox[1],
                lo[2] + (z as f64 + 0.5) * g.d_vox[2],
            ];
            let got = trilinear(&g, &v, &lo, &p);
            assert!((got - v.at(x, y, z)).abs() < 1e-5);
        }
    }

    #[test]
    fn trilinear_linear_in_between() {
        // A volume linear in x is reproduced exactly by trilinear interp.
        let g = Geometry::cone_beam(8, 1);
        let v = crate::volume::Volume::from_fn(8, 8, 8, |x, _, _| x as f32);
        let (lo, _) = g.volume_bbox();
        let p = [lo[0] + 3.25 * g.d_vox[0], lo[1] + 4.5 * g.d_vox[1], lo[2] + 4.5 * g.d_vox[2]];
        let got = trilinear(&g, &v, &lo, &p);
        assert!((got - 2.75).abs() < 1e-5, "got {got}");
    }

    #[test]
    fn slab_projections_sum_to_full_projection() {
        let n = 16;
        let g = Geometry::cone_beam(n, 4);
        let v = phantom::shepp_logan(n);
        let full = project(&g, &v, 2);
        let mut acc = ProjectionSet::zeros_like(&g);
        for (z0, z1) in [(0, 5), (5, 11), (11, 16)] {
            let part = project(&g.slab_geometry(z0, z1), &v.extract_slab(z0, z1), 2);
            acc.accumulate(&part);
        }
        // Interpolation near slab faces clamps instead of reading the
        // neighbour slab, so allow a slightly looser tolerance than Siddon.
        let rel = {
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for (a, b) in full.data.iter().zip(&acc.data) {
                num += ((a - b) as f64).powi(2);
                den += (*a as f64).powi(2);
            }
            (num / den.max(1e-12)).sqrt()
        };
        assert!(rel < 0.05, "slab-sum relative error {rel}");
    }

    #[test]
    fn threaded_equals_single_threaded() {
        let g = Geometry::cone_beam(12, 3);
        let v = phantom::shepp_logan(12);
        assert_eq!(project(&g, &v, 1).data, project(&g, &v, 4).data);
    }

    #[test]
    fn view_projection_bit_identical_to_owned_slab() {
        let n = 14;
        let g = Geometry::cone_beam(n, 4);
        let v = phantom::shepp_logan(n);
        let (z0, z1) = (3, 10);
        let gs = g.slab_geometry(z0, z1);
        let owned = project(&gs, &v.extract_slab(z0, z1), 2);
        let mut via_view = vec![0.0f32; owned.data.len()];
        project_into(&gs, &v.slab_view(z0, z1), &mut via_view, 2);
        assert_eq!(owned.data, via_view);
    }
}
