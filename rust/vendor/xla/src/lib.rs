//! Offline API shim for the subset of the `xla` crate the PJRT backend
//! (`src/runtime/pjrt.rs`) uses.
//!
//! The real `xla` crate ships with the GPU image only (it links native
//! XLA libraries), so offline builds cannot resolve it — but the
//! feature-gated backend must still *compile* or it silently rots. This
//! shim mirrors the exact API surface the backend calls, with every
//! entry point failing at **runtime** with [`XlaError::Unavailable`]:
//! `cargo check --features pjrt` (the CI compile-check lane) then
//! type-checks the real backend code, and a build that accidentally
//! runs it falls back to the native kernels through the backend's
//! existing error path. Deploying on the GPU image = swapping this path
//! dependency for the real crate; no source changes.

use std::fmt;

/// The shim's only error: the native XLA runtime is not linked.
#[derive(Clone, Debug)]
pub enum XlaError {
    Unavailable(&'static str),
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            XlaError::Unavailable(what) => {
                write!(f, "xla shim: {what} requires the GPU image's native xla crate")
            }
        }
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>(what: &'static str) -> Result<T, XlaError> {
    Err(XlaError::Unavailable(what))
}

/// PJRT client handle (CPU platform in the backend).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (the AOT artifacts are HLO text).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// A computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with host inputs; the real crate returns per-device,
    /// per-output buffers (`result[device][output]`).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device-resident result buffer.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A host literal (dense array value).
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple1(&self) -> Result<Literal, XlaError> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_tuple1().is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("native xla crate"), "{e}");
    }
}
