//! # tigre-rs
//!
//! A rust + JAX + Pallas reproduction of *"Arbitrarily large iterative
//! tomographic reconstruction on multiple GPUs using the TIGRE toolbox"*
//! (Biguri et al., 2019).
//!
//! The crate implements:
//! * cone-beam CT geometry, volumes/projections and phantoms,
//! * native forward/back-projection kernels (Siddon, Joseph, voxel-driven)
//!   plus AOT-compiled Pallas/JAX kernels loaded through PJRT,
//! * a discrete-event simulated multi-GPU node (`simgpu`) with a cost model
//!   calibrated to the paper's GTX 1080 Ti testbed,
//! * the paper's contribution: partitioned, double-buffered, transfer-
//!   overlapped forward/backprojection schedules and halo-buffered
//!   regularization (`coordinator`),
//! * the TIGRE algorithm suite (FDK, SIRT, SART, OS-SART, CGLS, FISTA,
//!   ASD-POCS) on top of the coordinator,
//! * benchmark harnesses that regenerate every figure of the paper's
//!   evaluation section.
//!
//! See `DESIGN.md` for the architecture and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

#![warn(unreachable_pub, unused_qualifications)]
#![warn(missing_docs)]

pub mod util;

pub mod analysis;

pub mod geometry;
pub mod volume;
pub mod phantom;
pub mod kernels;
pub mod metrics;
pub mod io;
pub mod simgpu;
pub mod coordinator;
pub mod algorithms;
pub mod runtime;
pub mod config;
pub mod bench;

/// CLI entrypoint: dispatches `tigre <subcommand> ...` to the coordinator,
/// algorithm suite and bench runners (see `config::cli_main`).
pub fn run_cli() -> anyhow::Result<()> {
    config::cli_main()
}
