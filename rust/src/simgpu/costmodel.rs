//! Cost model for the simulated GPU node.
//!
//! # Calibration (DESIGN.md §6)
//!
//! The constants below are calibrated against the paper's own testbed so
//! the *ratios* of Figs. 7–9 are meaningful:
//!
//! * **PCIe-Gen3 x16 transfers** — the paper states pageable ≈ 4 GB/s and
//!   pinned ≈ 12 GB/s (§2.1 "from approximately 4GB/s to 12GB/s on a
//!   PCI-e Gen3").
//! * **Projection kernel throughput** — from the paper's end-to-end
//!   anchor: 512³ CGLS×15 runs in 61 s on one GTX 1080 Ti (§4). A CGLS
//!   iteration is one FP + one BP plus small vector ops; with the
//!   projection measured slower than backprojection (Fig. 7) we apportion
//!   ≈2.4 s FP and ≈1.4 s BP per 512-iteration. FP work is
//!   `rays × chord ≈ 512²·512 × 0.7·1024 ≈ 9.6e10` ray-voxel steps →
//!   `4e10 steps/s`. BP work `512³·512 = 6.9e10` voxel-angle updates →
//!   `5e10 updates/s`.
//! * **Page-lock rate** — cudaHostRegister runs ≈ 3 GB/s on this
//!   platform class when memory is already resident, and ≈ 1.5 GB/s when
//!   pinning forces first-touch allocation (the backprojection output
//!   case the paper highlights in Fig. 9's discussion). Unpinning is
//!   ≈ 3× faster.
//! * **Fixed per-call overheads** — property checks + context touch of a
//!   few ms per call dominate at N=128 where the paper reports total
//!   times under 20 ms.

/// All tunables of the simulated node, in SI units (seconds, bytes).
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Pageable host↔device bandwidth (bytes/s).
    pub pcie_pageable_bps: f64,
    /// Pinned host↔device bandwidth (bytes/s).
    pub pcie_pinned_bps: f64,
    /// Fixed latency per copy (driver + DMA setup).
    pub copy_latency_s: f64,
    /// Page-lock rate for already-resident memory (bytes/s).
    pub pin_resident_bps: f64,
    /// Page-lock rate when pinning forces allocation (first touch).
    pub pin_alloc_bps: f64,
    /// Unpin rate (bytes/s).
    pub unpin_bps: f64,
    /// Forward-projection kernel throughput (ray-voxel steps / s).
    pub fp_steps_per_s: f64,
    /// Backprojection kernel throughput (voxel-angle updates / s).
    pub bp_updates_per_s: f64,
    /// TV/regularizer kernel throughput (voxel-iterations / s).
    pub tv_updates_per_s: f64,
    /// Projection-accumulation throughput (bytes/s) — the paper measures
    /// accumulation at ≈0.01% of a projection kernel launch.
    pub accum_bps: f64,
    /// Kernel launch overhead.
    pub kernel_launch_s: f64,
    /// cudaMalloc latency per call.
    pub alloc_latency_s: f64,
    /// cudaFree latency per call.
    pub free_latency_s: f64,
    /// Per-device property check (cudaGetDeviceProperties etc.), charged
    /// once per operator call.
    pub property_check_s: f64,
    /// Sequential read bandwidth of the out-of-core backing store
    /// (bytes/s) — NVMe-class local storage.
    pub disk_read_bps: f64,
    /// Sequential write bandwidth of the backing store (bytes/s).
    pub disk_write_bps: f64,
    /// Fixed latency per store request (syscall + queue).
    pub disk_latency_s: f64,
    /// Peer-to-peer device→device bandwidth (bytes/s) over the PCIe
    /// switch — the links the reduction-tree merge folds partials over.
    /// Slightly below the pinned H2D rate: a P2P copy crosses the switch
    /// without staging through host RAM, but pays both endpoints' DMA.
    pub p2p_bps: f64,
    /// Fixed latency per peer copy (both endpoints' DMA setup).
    pub p2p_latency_s: f64,
    /// Host-side `+=` fold throughput over two f32 streams (bytes of
    /// partial folded / s) — the linear merge's per-pair cost. Memory-
    /// bound: read src + read/write dst on one host core.
    pub host_fold_bps: f64,
    /// Base backoff before the first retry of a transiently-failed
    /// launch / alloc / disk request; doubles per consecutive retry
    /// (bounded by `fault::MAX_LAUNCH_RETRIES`).
    pub fault_retry_backoff_s: f64,
    /// Host time to replan a lost device's remaining units across the
    /// survivors (`splitter::replan_excluding`), charged once per loss.
    pub fault_replan_s: f64,
    /// Sparse system-matrix build throughput (stored non-zeros / s):
    /// the one-time Siddon traversal **plus** CSR push and CSC
    /// transpose assembly per entry — several times slower per
    /// intersection than the pure ray-driven kernel, which is exactly
    /// the setup cost the SpMV iterations amortize (ISSUE 10,
    /// Marchesini et al. 2020).
    pub sparse_build_nnz_per_s: f64,
    /// CSR SpMV throughput (non-zeros / s) for the sparse forward
    /// projection. Streaming and memory-bound — no per-ray f64 setup,
    /// no traversal branching — so substantially faster per
    /// intersection than `fp_steps_per_s`.
    pub spmv_nnz_per_s: f64,
    /// CSC SpMVᵀ throughput (non-zeros / s) for the sparse matched
    /// backprojection; slightly below the SpMV rate (the transpose
    /// gathers along the less cache-friendly axis).
    pub spmvt_nnz_per_s: f64,
    /// Hung-unit watchdog deadline as a multiple of the predicted unit
    /// time: a launch that has not completed after
    /// `predicted × watchdog_factor` seconds is declared hung, cancelled
    /// and retried (escalating to device loss past
    /// `fault::MAX_LAUNCH_RETRIES`). Each simulated hang therefore
    /// charges the full deadline — the device sat on the stuck kernel
    /// until the watchdog fired (ISSUE 8).
    pub watchdog_factor: f64,
}

impl CostModel {
    /// GTX 1080 Ti on PCIe Gen3 x16 — the paper's testbed.
    pub fn gtx1080ti_pcie3() -> Self {
        Self {
            pcie_pageable_bps: 4.0e9,
            pcie_pinned_bps: 12.0e9,
            copy_latency_s: 10e-6,
            pin_resident_bps: 3.0e9,
            pin_alloc_bps: 1.5e9,
            unpin_bps: 9.0e9,
            fp_steps_per_s: 4.0e10,
            bp_updates_per_s: 5.0e10,
            tv_updates_per_s: 2.0e10,
            accum_bps: 400e9, // on-device, memory-bound
            kernel_launch_s: 10e-6,
            alloc_latency_s: 100e-6,
            free_latency_s: 50e-6,
            property_check_s: 1.5e-3,
            // workstation NVMe: ~2.5 GB/s sequential read, ~1.2 GB/s
            // sustained write, ~100 µs per request
            disk_read_bps: 2.5e9,
            disk_write_bps: 1.2e9,
            disk_latency_s: 100e-6,
            // PCIe Gen3 x16 peer copy through the switch; host fold is a
            // single-core memcpy-class loop over two streams
            p2p_bps: 11.0e9,
            p2p_latency_s: 15e-6,
            host_fold_bps: 6.0e9,
            // recovery: ~1 ms first backoff (driver error + re-issue),
            // ~5 ms to rebuild the unit queues after a device drops out
            fault_retry_backoff_s: 1.0e-3,
            fault_replan_s: 5.0e-3,
            // sparse backend (ISSUE 10): the build walks the same rays
            // as the FP kernel but pays vector pushes + a counting-sort
            // transpose per entry (~5× the traversal's per-step cost);
            // the SpMV replays entries at streaming rates — ~3× the
            // ray-driven per-intersection throughput for CSR, a bit
            // less for the transpose gather. These give a crossover of
            // ≈7–8 iterations (`sparse_crossover_iters`), comfortably
            // inside a 15-iteration CGLS run.
            sparse_build_nnz_per_s: 8.0e9,
            spmv_nnz_per_s: 1.2e11,
            spmvt_nnz_per_s: 1.0e11,
            // generous 8× deadline: slab kernels vary ~1.3× with cone
            // overreach, so 8× never false-positives on a healthy unit
            // while still bounding a stuck launch to one order of
            // magnitude of its predicted time
            watchdog_factor: 8.0,
        }
    }

    /// Watchdog deadline for a unit predicted to take `predicted_s`.
    pub fn watchdog_deadline_s(&self, predicted_s: f64) -> f64 {
        predicted_s * self.watchdog_factor
    }

    /// Host seconds one rung of the memory-pressure ladder costs: the
    /// exhausted bounded allocation retries (the failed attempt's sim is
    /// discarded, so its backoff time is re-charged on the successful
    /// retry) plus one replan. Keeps the degraded makespan honest
    /// without double-running the failed schedule.
    pub fn pressure_rung_penalty_s(&self) -> f64 {
        let backoffs: f64 = (0..crate::simgpu::fault::MAX_LAUNCH_RETRIES)
            .map(|i| self.alloc_latency_s + self.fault_retry_backoff_s * (1u64 << i) as f64)
            .sum();
        self.fault_replan_s + backoffs
    }

    /// Time to move `bytes` of partial projections device→device over a
    /// peer link (reduction-tree merge rounds).
    pub fn p2p_time_s(&self, bytes: u64) -> f64 {
        bytes as f64 / self.p2p_bps + self.p2p_latency_s
    }

    /// Host time for one linear-merge fold pass (`dst += src`) over
    /// `bytes` of partial projections.
    pub fn host_fold_time_s(&self, bytes: u64) -> f64 {
        bytes as f64 / self.host_fold_bps
    }

    /// Time to read `bytes` from the out-of-core backing store.
    pub fn disk_read_time_s(&self, bytes: u64) -> f64 {
        bytes as f64 / self.disk_read_bps + self.disk_latency_s
    }

    /// Time to write `bytes` back to the backing store.
    pub fn disk_write_time_s(&self, bytes: u64) -> f64 {
        bytes as f64 / self.disk_write_bps + self.disk_latency_s
    }

    /// Whether streaming a `bytes`-sized unit from disk hides behind a
    /// kernel of `kernel_s` seconds (the loader lane prefetches unit
    /// `k+1` while unit `k` computes, so OOC streaming is free exactly
    /// when the disk read fits inside the kernel).
    pub fn ooc_read_hidden(&self, bytes: u64, kernel_s: f64) -> bool {
        self.disk_read_time_s(bytes) <= kernel_s
    }

    /// Host↔device transfer time for `bytes` over the pageable or pinned
    /// path (bandwidth + fixed DMA-setup latency). This is the single
    /// model both for copies the schedule *performs* (`SimNode::h2d`/`d2h`)
    /// and for copies the residency cache *skips* — the coordinator uses
    /// it to convert a cache hit's `bytes_saved` into the
    /// `transfer_saved_s` reported in `OpStats`.
    pub fn copy_time_s(&self, bytes: u64, pinned: bool) -> f64 {
        let bw = if pinned { self.pcie_pinned_bps } else { self.pcie_pageable_bps };
        bytes as f64 / bw + self.copy_latency_s
    }

    /// Time to page-lock `bytes` of host memory.
    pub fn pin_time_s(&self, bytes: u64, already_allocated: bool) -> f64 {
        let bw = if already_allocated { self.pin_resident_bps } else { self.pin_alloc_bps };
        bytes as f64 / bw + 1e-4
    }

    /// Time to unpin `bytes`.
    pub fn unpin_time_s(&self, bytes: u64) -> f64 {
        bytes as f64 / self.unpin_bps + 5e-5
    }

    /// Forward-projection kernel time for `rays` rays with an average
    /// traversal of `chord` voxel steps.
    pub fn fp_kernel_s(&self, rays: u64, chord: f64) -> f64 {
        rays as f64 * chord / self.fp_steps_per_s
    }

    /// Estimate of the FP kernel time for one launch over a z-slab:
    /// `nu×nv×angles` rays; rays that miss the slab cost ~nothing, so the
    /// effective ray count scales with the slab fraction (plus cone-beam
    /// overreach), and the chord is the in-plane crossing length.
    pub fn fp_slab_kernel_s(
        &self,
        nu: usize,
        nv: usize,
        angles: usize,
        nx: usize,
        ny: usize,
        nz_slab: usize,
        nz_full: usize,
    ) -> f64 {
        let frac = ((nz_slab as f64 / nz_full as f64) * 1.3).min(1.0);
        let rays = (nu * nv * angles) as f64 * frac;
        let chord = 0.7 * (nx + ny) as f64;
        rays * chord / self.fp_steps_per_s
    }

    /// Backprojection kernel time for one launch updating `nx×ny×nz_slab`
    /// voxels from `angles` projections.
    pub fn bp_kernel_s(&self, nx: usize, ny: usize, nz_slab: usize, angles: usize) -> f64 {
        (nx * ny * nz_slab) as f64 * angles as f64 / self.bp_updates_per_s
    }

    /// Estimated stored non-zeros of one slab×chunk unit's sparse
    /// shard: the same effective ray count × chord arithmetic as
    /// [`CostModel::fp_slab_kernel_s`] (each ray-voxel step of the
    /// traversal stores exactly one matrix entry).
    #[allow(clippy::too_many_arguments)]
    pub fn sparse_nnz_estimate(
        &self,
        nu: usize,
        nv: usize,
        angles: usize,
        nx: usize,
        ny: usize,
        nz_slab: usize,
        nz_full: usize,
    ) -> f64 {
        let frac = ((nz_slab as f64 / nz_full as f64) * 1.3).min(1.0);
        let rays = (nu * nv * angles) as f64 * frac;
        rays * 0.7 * (nx + ny) as f64
    }

    /// One-time build (traversal + CSR/CSC assembly) time for a shard
    /// of `nnz` stored entries.
    pub fn sparse_setup_s(&self, nnz: f64) -> f64 {
        nnz / self.sparse_build_nnz_per_s
    }

    /// SpMV forward-projection kernel time for a shard of `nnz` entries.
    pub fn spmv_s(&self, nnz: f64) -> f64 {
        nnz / self.spmv_nnz_per_s
    }

    /// SpMVᵀ matched-backprojection kernel time for a shard of `nnz`
    /// entries.
    pub fn spmvt_s(&self, nnz: f64) -> f64 {
        nnz / self.spmvt_nnz_per_s
    }

    /// Iteration count past which the sparse backend's one-time
    /// `setup_s` has amortized against its per-iteration saving:
    /// `setup / (ray_iter − sparse_iter)`. `None` when the sparse
    /// iteration is not cheaper (the matrix never pays off). SimOnly
    /// surfaces this so users can pick a projector per workload
    /// (`tigre project --sim-only --projector sparse`).
    ///
    /// # Examples
    ///
    /// ```
    /// use tigre::simgpu::CostModel;
    ///
    /// let cost = CostModel::gtx1080ti_pcie3();
    /// // A 3 s build that saves 0.5 s per iteration pays off after
    /// // 6 iterations; a slower-than-ray SpMV never does.
    /// assert_eq!(cost.sparse_crossover_iters(1.0, 0.5, 3.0), Some(6.0));
    /// assert_eq!(cost.sparse_crossover_iters(1.0, 1.5, 3.0), None);
    /// ```
    pub fn sparse_crossover_iters(
        &self,
        ray_iter_s: f64,
        sparse_iter_s: f64,
        setup_s: f64,
    ) -> Option<f64> {
        if sparse_iter_s >= ray_iter_s {
            return None;
        }
        Some(setup_s / (ray_iter_s - sparse_iter_s))
    }

    /// Accumulation kernel time for `bytes` of partial projections.
    pub fn accum_kernel_s(&self, bytes: u64) -> f64 {
        bytes as f64 / self.accum_bps
    }

    /// TV-regularizer kernel time for `voxels` over `iters` inner
    /// iterations.
    pub fn tv_kernel_s(&self, voxels: u64, iters: usize) -> f64 {
        voxels as f64 * iters as f64 / self.tv_updates_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchor_fp_512_within_band() {
        // FP of the full 512 problem ≈ 2–3 s (calibration anchor).
        let c = CostModel::gtx1080ti_pcie3();
        let t = c.fp_slab_kernel_s(512, 512, 512, 512, 512, 512, 512);
        assert!((1.5..4.0).contains(&t), "FP(512) = {t}");
    }

    #[test]
    fn anchor_bp_512_within_band() {
        let c = CostModel::gtx1080ti_pcie3();
        let t = c.bp_kernel_s(512, 512, 512, 512);
        assert!((0.8..2.5).contains(&t), "BP(512) = {t}");
        // backprojection is faster than projection (paper §3.1)
        let fp = c.fp_slab_kernel_s(512, 512, 512, 512, 512, 512, 512);
        assert!(t < fp);
    }

    #[test]
    fn pinned_transfers_3x_faster() {
        let c = CostModel::gtx1080ti_pcie3();
        assert!((c.pcie_pinned_bps / c.pcie_pageable_bps - 3.0).abs() < 0.01);
    }

    #[test]
    fn copy_time_matches_bandwidth_plus_latency() {
        let c = CostModel::gtx1080ti_pcie3();
        let gib = 1u64 << 30;
        let pageable = c.copy_time_s(gib, false);
        let pinned = c.copy_time_s(gib, true);
        assert!((pageable - (gib as f64 / 4.0e9 + 10e-6)).abs() < 1e-9);
        assert!((pinned - (gib as f64 / 12.0e9 + 10e-6)).abs() < 1e-9);
        assert!(pageable > pinned);
        // zero bytes still pay the DMA setup latency
        assert!((c.copy_time_s(0, true) - 10e-6).abs() < 1e-12);
    }

    #[test]
    fn pin_with_allocation_slower() {
        let c = CostModel::gtx1080ti_pcie3();
        assert!(c.pin_time_s(1 << 30, false) > c.pin_time_s(1 << 30, true) * 1.5);
    }

    #[test]
    fn accumulation_negligible_vs_kernel() {
        // paper: accumulation ≈ 0.01% of a projection kernel launch.
        let c = CostModel::gtx1080ti_pcie3();
        let fp = c.fp_slab_kernel_s(1024, 1024, 9, 1024, 1024, 1024, 1024);
        let acc = c.accum_kernel_s(1024 * 1024 * 9 * 4);
        assert!(acc < fp * 0.01, "accum {acc} vs fp {fp}");
    }

    #[test]
    fn disk_slower_than_pcie_and_hidden_behind_big_kernels() {
        let c = CostModel::gtx1080ti_pcie3();
        let slab = 512u64 * 512 * 64 * 4; // a 64-slice slab of the 512 problem
        assert!(c.disk_read_time_s(slab) > c.copy_time_s(slab, true), "disk slower than pinned");
        assert!(c.disk_write_time_s(slab) > c.disk_read_time_s(slab), "writes slower than reads");
        // the FP kernel over that slab takes seconds — the prefetch hides
        let kernel = c.fp_slab_kernel_s(512, 512, 512, 512, 512, 64, 512);
        let read = c.disk_read_time_s(slab);
        assert!(c.ooc_read_hidden(slab, kernel), "read {read} vs kernel {kernel}");
        // a microsecond kernel cannot hide a gigabyte read
        assert!(!c.ooc_read_hidden(1 << 30, 1e-6));
    }

    #[test]
    fn p2p_between_pageable_and_pinned_and_folds_are_host_bound() {
        let c = CostModel::gtx1080ti_pcie3();
        // a peer copy skips the host bounce: faster than pageable, but it
        // cannot beat a single pinned DMA
        assert!(c.p2p_bps > c.pcie_pageable_bps);
        assert!(c.p2p_bps < c.pcie_pinned_bps);
        let mb = 32u64 << 20;
        assert!((c.p2p_time_s(mb) - (mb as f64 / 11.0e9 + 15e-6)).abs() < 1e-9);
        // zero bytes still pay the link latency; a host fold does not
        assert!((c.p2p_time_s(0) - 15e-6).abs() < 1e-12);
        assert_eq!(c.host_fold_time_s(0), 0.0);
        // the tree's win: one p2p hop beats one host fold pass at
        // detector-partial sizes
        assert!(c.p2p_time_s(mb) < c.host_fold_time_s(mb));
    }

    #[test]
    fn watchdog_deadline_scales_predicted_time() {
        let c = CostModel::gtx1080ti_pcie3();
        let t = c.fp_slab_kernel_s(256, 256, 9, 256, 256, 64, 256);
        assert!((c.watchdog_deadline_s(t) - t * c.watchdog_factor).abs() < 1e-12);
        // the deadline must clear the slab-fraction overreach band (1.3×)
        assert!(c.watchdog_factor > 2.0);
    }

    #[test]
    fn sparse_crossover_in_single_digit_iterations() {
        // ISSUE 10 calibration: SpMV beats the ray-driven kernel per
        // iteration, the build costs a handful of FPs, and the
        // crossover lands inside a typical 15-iteration CGLS run.
        let c = CostModel::gtx1080ti_pcie3();
        let nnz = c.sparse_nnz_estimate(512, 512, 512, 512, 512, 512, 512);
        let ray = c.fp_slab_kernel_s(512, 512, 512, 512, 512, 512, 512);
        let spmv = c.spmv_s(nnz);
        let setup = c.sparse_setup_s(nnz);
        assert!(spmv < ray, "SpMV {spmv} must beat ray-driven {ray}");
        assert!(setup > ray, "the build must cost more than one FP");
        let k = c.sparse_crossover_iters(ray, spmv, setup).unwrap();
        assert!((3.0..12.0).contains(&k), "crossover {k} iterations");
        // a sparse iteration that is *slower* never pays off
        assert!(c.sparse_crossover_iters(1.0, 1.0, 5.0).is_none());
        assert!(c.sparse_crossover_iters(1.0, 2.0, 5.0).is_none());
    }

    #[test]
    fn sparse_nnz_tracks_fp_work_estimate() {
        // One stored entry per ray-voxel step: nnz / fp throughput must
        // reproduce the ray-driven kernel-time estimate exactly.
        let c = CostModel::gtx1080ti_pcie3();
        let nnz = c.sparse_nnz_estimate(256, 256, 9, 256, 256, 64, 256);
        let fp = c.fp_slab_kernel_s(256, 256, 9, 256, 256, 64, 256);
        assert!((nnz / c.fp_steps_per_s - fp).abs() < 1e-12);
        assert!(c.spmvt_s(nnz) > c.spmv_s(nnz), "transpose gather is slower");
    }

    #[test]
    fn slab_fraction_reduces_fp_cost() {
        let c = CostModel::gtx1080ti_pcie3();
        let full = c.fp_slab_kernel_s(256, 256, 9, 256, 256, 256, 256);
        let slab = c.fp_slab_kernel_s(256, 256, 9, 256, 256, 64, 256);
        assert!(slab < full * 0.5, "slab {slab} vs full {full}");
    }
}
