//! Cone-beam CT geometry, following TIGRE's conventions.
//!
//! The object rotates (equivalently, source+detector rotate around the
//! object) about the +z axis. At angle `theta`:
//!   * the source sits at `(DSO·cosθ, DSO·sinθ, 0)`,
//!   * the detector plane is perpendicular to the source–origin axis at
//!     distance `DSD` from the source, spanned by `u` (in-plane) and `v`
//!     (along z) axes.
//!
//! Volumes are `nx × ny × nz` voxel grids centred on the origin (plus an
//! optional offset); detectors are `nu × nv` pixel grids centred on the
//! ray through the origin (plus an optional offset, which models the
//! panel-shifted scans used in the paper's §3.2 datasets).

pub mod split;

pub use split::{AngleChunk, ZSlab};

use crate::util::units::F32_BYTES;

/// Full scan geometry: volume grid + detector + trajectory.
#[derive(Clone, Debug, PartialEq)]
pub struct Geometry {
    /// Distance source → detector (mm).
    pub dsd: f64,
    /// Distance source → rotation axis / origin (mm).
    pub dso: f64,
    /// Voxel counts (nx, ny, nz).
    pub n_vox: [usize; 3],
    /// Voxel pitch in mm (sx, sy, sz).
    pub d_vox: [f64; 3],
    /// Offset of the volume centre from the origin, mm.
    pub offset_origin: [f64; 3],
    /// Detector pixel counts (nu, nv).
    pub n_det: [usize; 2],
    /// Detector pixel pitch in mm (du, dv).
    pub d_det: [f64; 2],
    /// Detector offset from the principal ray, mm (panel shift).
    pub offset_det: [f64; 2],
    /// Projection angles in radians.
    pub angles: Vec<f64>,
}

/// Cached per-angle frame: source position and detector basis.
#[derive(Clone, Copy, Debug)]
pub struct AngleFrame {
    /// Source position.
    pub src: [f64; 3],
    /// Centre of the detector panel.
    pub det_center: [f64; 3],
    /// Unit vector along detector `u` (in the rotation plane).
    pub u_dir: [f64; 3],
    /// Unit vector along detector `v` (parallel to +z).
    pub v_dir: [f64; 3],
}

/// Affine per-angle detector addressing, precomputed once per angle:
///
/// `pix(iu, iv) = origin + iu·u_step + iv·v_step`
///
/// where `origin` is the world centre of pixel `(0, 0)` and the step
/// vectors already include the pixel pitch. The projector inner loops use
/// this instead of [`Geometry::det_pixel`], which re-derives the panel
/// placement (9 multiplies + 12 adds) for every single ray; with the
/// affine frame a pixel address is 6 fused multiply-adds, and a detector
/// row walk is pure increments. This mirrors what the CUDA kernels get by
/// stashing `deltaU`/`deltaV`/`uvOrigin` in constant memory per angle.
#[derive(Clone, Copy, Debug)]
pub struct DetFrame {
    /// Source position.
    pub src: [f64; 3],
    /// World centre of detector pixel (0, 0).
    pub origin: [f64; 3],
    /// World step for +1 pixel along `u` (includes the `du` pitch).
    pub u_step: [f64; 3],
    /// World step for +1 pixel along `v` (includes the `dv` pitch).
    pub v_step: [f64; 3],
}

impl DetFrame {
    /// World centre of pixel `(iu, iv)`.
    #[inline(always)]
    pub fn pix(&self, iu: usize, iv: usize) -> [f64; 3] {
        let fu = iu as f64;
        let fv = iv as f64;
        [
            self.origin[0] + fu * self.u_step[0] + fv * self.v_step[0],
            self.origin[1] + fu * self.u_step[1] + fv * self.v_step[1],
            self.origin[2] + fu * self.u_step[2] + fv * self.v_step[2],
        ]
    }

    /// World centre of pixel `(0, iv)` — the start of detector row `iv`;
    /// the row is then spanned by multiples of `u_step`.
    #[inline(always)]
    pub fn row_origin(&self, iv: usize) -> [f64; 3] {
        let fv = iv as f64;
        [
            self.origin[0] + fv * self.v_step[0],
            self.origin[1] + fv * self.v_step[1],
            self.origin[2] + fv * self.v_step[2],
        ]
    }
}

impl Geometry {
    /// A standard circular cone-beam geometry for an `n³` volume with an
    /// `n×n` detector and `n_angles` uniformly spaced angles over 2π.
    /// This is exactly the workload of the paper's Fig. 7–9 sweeps
    /// (`N³` voxels, `N²` detector pixels, `N` angles).
    pub fn cone_beam(n: usize, n_angles: usize) -> Geometry {
        Self::cone_beam_anisotropic([n, n, n], [n, n], n_angles)
    }

    /// Circular cone-beam geometry with independent volume/detector sizes.
    /// Scales so the volume fits the field of view: voxel pitch 1 mm,
    /// detector sized to cover the magnified volume footprint.
    pub fn cone_beam_anisotropic(
        n_vox: [usize; 3],
        n_det: [usize; 2],
        n_angles: usize,
    ) -> Geometry {
        let nmax = n_vox.iter().copied().max().unwrap_or(1) as f64;
        let dso = 3.0 * nmax;
        let dsd = 4.5 * nmax;
        let mag = dsd / dso;
        // Detector must cover the volume diagonal × magnification.
        let fov = nmax * 1.0 * mag * 1.6;
        let du = fov / n_det[0] as f64;
        let dv = fov / n_det[1] as f64;
        let angles = uniform_angles(n_angles, 2.0 * std::f64::consts::PI);
        Geometry {
            dsd,
            dso,
            n_vox,
            d_vox: [1.0, 1.0, 1.0],
            offset_origin: [0.0; 3],
            n_det,
            d_det: [du, dv],
            offset_det: [0.0, 0.0],
            angles,
        }
    }

    /// Validate invariants; returns a description of the first violation.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.dsd > 0.0 && self.dso > 0.0) {
            return Err("DSD and DSO must be positive".into());
        }
        if self.dso >= self.dsd {
            return Err(format!("DSO ({}) must be < DSD ({})", self.dso, self.dsd));
        }
        if self.n_vox.iter().any(|&n| n == 0) || self.n_det.iter().any(|&n| n == 0) {
            return Err("zero-sized volume or detector".into());
        }
        if self.d_vox.iter().any(|&d| d <= 0.0) || self.d_det.iter().any(|&d| d <= 0.0) {
            return Err("non-positive voxel/pixel pitch".into());
        }
        if self.angles.is_empty() {
            return Err("no projection angles".into());
        }
        // The source must be outside the volume (otherwise rays start inside).
        let half = [
            self.n_vox[0] as f64 * self.d_vox[0] / 2.0,
            self.n_vox[1] as f64 * self.d_vox[1] / 2.0,
        ];
        let r = (half[0] * half[0] + half[1] * half[1]).sqrt();
        if self.dso <= r {
            return Err(format!(
                "source orbit radius {} inside volume bounding cylinder {r}",
                self.dso
            ));
        }
        Ok(())
    }

    /// Number of projection angles.
    pub fn n_angles(&self) -> usize {
        self.angles.len()
    }

    /// Geometric magnification DSD/DSO.
    pub fn magnification(&self) -> f64 {
        self.dsd / self.dso
    }

    /// Total voxel count.
    pub fn total_voxels(&self) -> u64 {
        self.n_vox.iter().map(|&n| n as u64).product()
    }

    /// Total detector pixels over all angles.
    pub fn total_proj_pixels(&self) -> u64 {
        self.n_det[0] as u64 * self.n_det[1] as u64 * self.angles.len() as u64
    }

    /// Bytes of the full image volume (f32).
    pub fn volume_bytes(&self) -> u64 {
        self.total_voxels() * F32_BYTES
    }

    /// Bytes of the full projection set (f32).
    pub fn proj_bytes(&self) -> u64 {
        self.total_proj_pixels() * F32_BYTES
    }

    /// Bytes of one projection (all detector pixels at one angle).
    pub fn single_proj_bytes(&self) -> u64 {
        self.n_det[0] as u64 * self.n_det[1] as u64 * F32_BYTES
    }

    /// Bytes of a z-slab of `nz_slab` slices of the volume.
    pub fn slab_bytes(&self, nz_slab: usize) -> u64 {
        self.n_vox[0] as u64 * self.n_vox[1] as u64 * nz_slab as u64 * F32_BYTES
    }

    /// Per-angle source/detector frame.
    pub fn frame(&self, angle_idx: usize) -> AngleFrame {
        let theta = self.angles[angle_idx];
        let (s, c) = theta.sin_cos();
        let src = [self.dso * c, self.dso * s, 0.0];
        // Detector centre is DSD from the source along -r̂, plus panel offset.
        let back = self.dsd - self.dso; // distance origin → detector
        let u_dir = [-s, c, 0.0];
        let v_dir = [0.0, 0.0, 1.0];
        let det_center = [
            -back * c + self.offset_det[0] * u_dir[0],
            -back * s + self.offset_det[0] * u_dir[1],
            self.offset_det[1],
        ];
        AngleFrame { src, det_center, u_dir, v_dir }
    }

    /// Affine detector frame for `angle_idx` (see [`DetFrame`]). The
    /// projector kernels compute this once per angle; per-pixel addressing
    /// is then affine in `(iu, iv)`.
    pub fn det_frame(&self, angle_idx: usize) -> DetFrame {
        let f = self.frame(angle_idx);
        let u0 = (0.5 - self.n_det[0] as f64 / 2.0) * self.d_det[0];
        let v0 = (0.5 - self.n_det[1] as f64 / 2.0) * self.d_det[1];
        DetFrame {
            src: f.src,
            origin: [
                f.det_center[0] + u0 * f.u_dir[0] + v0 * f.v_dir[0],
                f.det_center[1] + u0 * f.u_dir[1] + v0 * f.v_dir[1],
                f.det_center[2] + u0 * f.u_dir[2] + v0 * f.v_dir[2],
            ],
            u_step: [
                self.d_det[0] * f.u_dir[0],
                self.d_det[0] * f.u_dir[1],
                self.d_det[0] * f.u_dir[2],
            ],
            v_step: [
                self.d_det[1] * f.v_dir[0],
                self.d_det[1] * f.v_dir[1],
                self.d_det[1] * f.v_dir[2],
            ],
        }
    }

    /// World position of detector pixel centre `(iu, iv)` at `angle_idx`.
    pub fn det_pixel(&self, frame: &AngleFrame, iu: usize, iv: usize) -> [f64; 3] {
        let u = (iu as f64 + 0.5 - self.n_det[0] as f64 / 2.0) * self.d_det[0];
        let v = (iv as f64 + 0.5 - self.n_det[1] as f64 / 2.0) * self.d_det[1];
        [
            frame.det_center[0] + u * frame.u_dir[0] + v * frame.v_dir[0],
            frame.det_center[1] + u * frame.u_dir[1] + v * frame.v_dir[1],
            frame.det_center[2] + u * frame.u_dir[2] + v * frame.v_dir[2],
        ]
    }

    /// Axis-aligned bounding box of the volume, (min, max) corners in mm.
    pub fn volume_bbox(&self) -> ([f64; 3], [f64; 3]) {
        let mut lo = [0.0; 3];
        let mut hi = [0.0; 3];
        for k in 0..3 {
            let half = self.n_vox[k] as f64 * self.d_vox[k] / 2.0;
            lo[k] = self.offset_origin[k] - half;
            hi[k] = self.offset_origin[k] + half;
        }
        (lo, hi)
    }

    /// Bounding box of a z-slab `[z0, z1)` in voxel indices.
    pub fn slab_bbox(&self, z0: usize, z1: usize) -> ([f64; 3], [f64; 3]) {
        let (mut lo, mut hi) = self.volume_bbox();
        let zmin = lo[2];
        lo[2] = zmin + z0 as f64 * self.d_vox[2];
        hi[2] = zmin + z1 as f64 * self.d_vox[2];
        (lo, hi)
    }

    /// A copy restricted to a z-slab `[z0, z1)`: the sub-volume is recentred
    /// via `offset_origin` so kernels can run on the slab unmodified.
    pub fn slab_geometry(&self, z0: usize, z1: usize) -> Geometry {
        assert!(z0 < z1 && z1 <= self.n_vox[2], "bad slab [{z0},{z1})");
        let mut g = self.clone();
        g.n_vox[2] = z1 - z0;
        let full_half = self.n_vox[2] as f64 * self.d_vox[2] / 2.0;
        let slab_center =
            (z0 as f64 + (z1 - z0) as f64 / 2.0) * self.d_vox[2] - full_half;
        g.offset_origin[2] = self.offset_origin[2] + slab_center;
        g
    }

    /// A copy restricted to a contiguous angle chunk `[a0, a1)`.
    pub fn angle_chunk_geometry(&self, a0: usize, a1: usize) -> Geometry {
        assert!(a0 < a1 && a1 <= self.angles.len(), "bad angle chunk [{a0},{a1})");
        let mut g = self.clone();
        g.angles = self.angles[a0..a1].to_vec();
        g
    }

    /// A copy with the given angle subset (for OS-SART style subsets).
    pub fn angle_subset_geometry(&self, idxs: &[usize]) -> Geometry {
        let mut g = self.clone();
        g.angles = idxs.iter().map(|&i| self.angles[i]).collect();
        g
    }
}

/// `n` uniformly spaced angles in `[0, span)`.
pub fn uniform_angles(n: usize, span: f64) -> Vec<f64> {
    (0..n).map(|i| span * i as f64 / n as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_geometry_validates() {
        let g = Geometry::cone_beam(64, 64);
        g.validate().unwrap();
        assert_eq!(g.n_angles(), 64);
        assert_eq!(g.total_voxels(), 64 * 64 * 64);
        assert!(g.magnification() > 1.0);
    }

    #[test]
    fn validation_catches_errors() {
        let mut g = Geometry::cone_beam(8, 4);
        g.dso = g.dsd + 1.0;
        assert!(g.validate().is_err());

        let mut g = Geometry::cone_beam(8, 4);
        g.angles.clear();
        assert!(g.validate().is_err());

        let mut g = Geometry::cone_beam(8, 4);
        g.n_vox[1] = 0;
        assert!(g.validate().is_err());

        let mut g = Geometry::cone_beam(8, 4);
        g.dso = 1.0; // inside the volume
        assert!(g.validate().is_err());
    }

    #[test]
    fn source_on_orbit() {
        let g = Geometry::cone_beam(32, 8);
        for a in 0..g.n_angles() {
            let f = g.frame(a);
            let r = (f.src[0] * f.src[0] + f.src[1] * f.src[1]).sqrt();
            assert!((r - g.dso).abs() < 1e-9);
            assert_eq!(f.src[2], 0.0);
        }
    }

    #[test]
    fn source_to_detector_distance_is_dsd() {
        let g = Geometry::cone_beam(32, 8);
        for a in [0, 3, 7] {
            let f = g.frame(a);
            let d = [
                f.det_center[0] - f.src[0],
                f.det_center[1] - f.src[1],
                f.det_center[2] - f.src[2],
            ];
            let dist = (d[0] * d[0] + d[1] * d[1] + d[2] * d[2]).sqrt();
            assert!((dist - g.dsd).abs() < 1e-9, "angle {a}: {dist} vs {}", g.dsd);
        }
    }

    #[test]
    fn detector_axes_orthonormal() {
        let g = Geometry::cone_beam(32, 8);
        for a in 0..8 {
            let f = g.frame(a);
            let dot: f64 = (0..3).map(|k| f.u_dir[k] * f.v_dir[k]).sum();
            assert!(dot.abs() < 1e-12);
            let nu: f64 = f.u_dir.iter().map(|x| x * x).sum::<f64>().sqrt();
            let nv: f64 = f.v_dir.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((nu - 1.0).abs() < 1e-12 && (nv - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn central_pixel_on_principal_ray() {
        // With no detector offset and even pixel counts, the mid-detector
        // point equals det_center.
        let g = Geometry::cone_beam(32, 4);
        let f = g.frame(0);
        let p = g.det_pixel(&f, g.n_det[0] / 2, g.n_det[1] / 2);
        // pixel centres are offset half a pitch from the exact centre
        let du = g.d_det[0] / 2.0;
        let dist = ((p[0] - f.det_center[0]).powi(2)
            + (p[1] - f.det_center[1]).powi(2)
            + (p[2] - f.det_center[2]).powi(2))
        .sqrt();
        assert!(dist <= (du * du * 2.0).sqrt() + 1e-9);
    }

    #[test]
    fn det_frame_matches_det_pixel() {
        // the affine frame must address exactly the same pixel centres as
        // the per-pixel derivation, including with a panel offset
        let mut g = Geometry::cone_beam(32, 8);
        g.offset_det = [3.5, -1.25];
        for a in 0..g.n_angles() {
            let f = g.frame(a);
            let df = g.det_frame(a);
            assert_eq!(df.src, f.src);
            for &(iu, iv) in &[(0usize, 0usize), (31, 0), (0, 31), (17, 23)] {
                let want = g.det_pixel(&f, iu, iv);
                let got = df.pix(iu, iv);
                for k in 0..3 {
                    assert!(
                        (want[k] - got[k]).abs() < 1e-9,
                        "angle {a} pixel ({iu},{iv}) axis {k}: {} vs {}",
                        want[k],
                        got[k]
                    );
                }
                // row_origin + iu·u_step is the same address
                let r = df.row_origin(iv);
                for k in 0..3 {
                    let via_row = r[k] + iu as f64 * df.u_step[k];
                    assert!((want[k] - via_row).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn slab_geometry_recenters() {
        let g = Geometry::cone_beam(64, 8);
        let s = g.slab_geometry(0, 16);
        assert_eq!(s.n_vox[2], 16);
        // slab [0,16) of 64 slices: centre at (8-32) = -24 voxels
        assert!((s.offset_origin[2] - (-24.0)).abs() < 1e-9);
        // slabs tile the whole volume bbox
        let s2 = g.slab_geometry(16, 64);
        let (lo1, hi1) = s.volume_bbox();
        let (lo2, hi2) = s2.volume_bbox();
        let (lo, hi) = g.volume_bbox();
        assert!((lo1[2] - lo[2]).abs() < 1e-9);
        assert!((hi1[2] - lo2[2]).abs() < 1e-9);
        assert!((hi2[2] - hi[2]).abs() < 1e-9);
    }

    #[test]
    fn angle_chunk_geometry_subsets() {
        let g = Geometry::cone_beam(16, 10);
        let c = g.angle_chunk_geometry(2, 5);
        assert_eq!(c.angles.len(), 3);
        assert_eq!(c.angles[0], g.angles[2]);
        let s = g.angle_subset_geometry(&[0, 9]);
        assert_eq!(s.angles, vec![g.angles[0], g.angles[9]]);
    }

    #[test]
    fn byte_accounting() {
        let g = Geometry::cone_beam(128, 128);
        assert_eq!(g.volume_bytes(), 128u64.pow(3) * 4);
        assert_eq!(g.proj_bytes(), 128u64.pow(3) * 4);
        assert_eq!(g.single_proj_bytes(), 128 * 128 * 4);
        assert_eq!(g.slab_bytes(16), 128 * 128 * 16 * 4);
    }

    #[test]
    fn uniform_angles_spacing() {
        let a = uniform_angles(4, 2.0 * std::f64::consts::PI);
        assert_eq!(a.len(), 4);
        assert!((a[1] - std::f64::consts::FRAC_PI_2).abs() < 1e-12);
    }
}
