// Seeded violation for the `blessed-accumulation` lint: checked under
// the pretend path rust/src/coordinator/fixture.rs (and NOT allowlisted
// as a merge site). Never compiled.

pub fn rogue_fold(dst: &mut [f32], src: &[f32]) {
    for (o, s) in dst.iter_mut().zip(src) {
        *o += *s;
    }
}

pub fn rogue_indexed(dst: &mut [f32], src: &[f32]) {
    for i in 0..dst.len() {
        dst[i] += src[i];
    }
}

pub fn scalar_counters_are_fine(events: &[u32]) -> (u64, u64) {
    let mut total = 0u64;
    let mut weighted = 0u64;
    for &e in events {
        // scalar accumulation: must NOT be reported
        total += 1;
        weighted += e as u64;
    }
    (total, weighted)
}
