//! `tigre` CLI — leader entrypoint.

fn main() {
    if let Err(e) = tigre::run_cli() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
