//! Naive baseline strategies, for the ablation benches.
//!
//! This is the "common approach" the paper describes and improves upon:
//! allocate projection memory alongside the image, run kernels, then
//! gather — with no double buffering, no pinning, no transfer/compute
//! overlap (every copy is synchronous and the host waits for each kernel
//! *before* issuing the next copy). Comparing these schedules against
//! Algorithms 1 & 2 quantifies the contribution of the queueing strategy
//! itself.

use crate::geometry::Geometry;
use crate::simgpu::{Ev, SimNode};

use super::error::ReconError;
use super::executor::{MultiGpu, OpStats};
use super::splitter::{plan_backward, plan_forward, Plan};

/// Naive forward projection: same partitioning as Algorithm 1 (the
/// splits are forced by memory), but fully serialized — kernel, then
/// copy-out, then host-side accumulation, each step waiting for the last.
pub fn naive_forward(ctx: &MultiGpu, g: &Geometry) -> anyhow::Result<OpStats> {
    let mut plan = plan_forward(g, ctx.n_gpus, ctx.spec.mem_bytes, &ctx.split)
        .map_err(|e| ReconError::Plan(format!("naive forward plan: {e}")))?;
    plan.pin_image = false; // the naive strategy never pins
    let mut sim = ctx.fresh_sim();
    simulate_forward(g, &plan, &mut sim, &ctx.cost)?;
    Ok(OpStats::from_sim(&sim, &plan))
}

/// Naive backprojection: serialized chunk copies and kernels, no overlap.
pub fn naive_backward(ctx: &MultiGpu, g: &Geometry) -> anyhow::Result<OpStats> {
    let mut plan = plan_backward(g, ctx.n_gpus, ctx.spec.mem_bytes, &ctx.split)
        .map_err(|e| ReconError::Plan(format!("naive backward plan: {e}")))?;
    plan.pin_image = false;
    let mut sim = ctx.fresh_sim();
    simulate_backward(g, &plan, &mut sim, &ctx.cost)?;
    Ok(OpStats::from_sim(&sim, &plan))
}

fn simulate_forward(
    g: &Geometry,
    plan: &Plan,
    sim: &mut SimNode,
    cost: &crate::simgpu::CostModel,
) -> Result<(), crate::simgpu::SimOom> {
    sim.property_check();
    let n_dev = sim.n_devices();
    for d in 0..n_dev {
        sim.alloc(d, "projbuf", plan.proj_buffer_bytes)?;
    }
    // host-side accumulation rate for the gather step
    let host_add_bps = 5.0e9;

    if !plan.image_split {
        let shares = crate::geometry::split::split_even(plan.angle_chunks.len(), n_dev);
        let img = g.volume_bytes();
        for d in 0..n_dev {
            sim.alloc(d, "slab", img)?;
            // pageable, synchronous; devices get the image one at a time
            let e = sim.h2d(d, img, false, Ev::ZERO);
            sim.host_sync(e);
        }
        let max_share = shares.iter().map(|(a, b)| b - a).max().unwrap_or(0);
        for j in 0..max_share {
            for d in 0..n_dev {
                let (c0, c1) = shares[d];
                if c0 + j >= c1 {
                    continue;
                }
                let c = c0 + j;
                let ch = plan.angle_chunks[c];
                let t = cost.fp_slab_kernel_s(
                    g.n_det[0],
                    g.n_det[1],
                    ch.len(),
                    g.n_vox[0],
                    g.n_vox[1],
                    g.n_vox[2],
                    g.n_vox[2],
                );
                // serialized: kernel → wait → copy-out → wait
                let k = sim.kernel(d, t, Ev::ZERO, &format!("naive fp d{d} c{c}"));
                sim.host_sync(k);
                let bytes = ch.len() as u64 * g.single_proj_bytes();
                let e = sim.d2h(d, bytes, false, k);
                sim.host_sync(e);
            }
        }
    } else {
        let max_slabs = plan.splits_per_device();
        for s in 0..max_slabs {
            for d in 0..n_dev {
                let Some(slab) = plan.per_device[d].slabs.get(s) else { continue };
                sim.free(d, "slab");
                sim.alloc(d, "slab", g.slab_bytes(slab.len()))?;
                let e = sim.h2d(d, g.slab_bytes(slab.len()), false, Ev::ZERO);
                sim.host_sync(e);
                for (c, ch) in plan.angle_chunks.iter().enumerate() {
                    let t = cost.fp_slab_kernel_s(
                        g.n_det[0],
                        g.n_det[1],
                        ch.len(),
                        g.n_vox[0],
                        g.n_vox[1],
                        slab.len(),
                        g.n_vox[2],
                    );
                    let k = sim.kernel(d, t, Ev::ZERO, &format!("naive fp d{d} s{s} c{c}"));
                    sim.host_sync(k);
                    let bytes = ch.len() as u64 * g.single_proj_bytes();
                    let e = sim.d2h(d, bytes, false, k);
                    sim.host_sync(e);
                    // gather on host: accumulate the partials
                    sim.host_busy(
                        bytes as f64 / host_add_bps,
                        crate::simgpu::Category::OtherMem,
                        "host gather",
                    );
                }
            }
        }
    }
    for d in 0..n_dev {
        sim.free(d, "projbuf");
        sim.free(d, "slab");
    }
    sim.sync_all();
    Ok(())
}

fn simulate_backward(
    g: &Geometry,
    plan: &Plan,
    sim: &mut SimNode,
    cost: &crate::simgpu::CostModel,
) -> Result<(), crate::simgpu::SimOom> {
    sim.property_check();
    let n_dev = sim.n_devices();
    for d in 0..n_dev {
        sim.alloc(d, "projbuf", plan.proj_buffer_bytes)?;
    }
    let max_slabs = plan.splits_per_device();
    for s in 0..max_slabs {
        for d in 0..n_dev {
            let Some(slab) = plan.per_device[d].slabs.get(s) else { continue };
            sim.free(d, "slab");
            sim.alloc(d, "slab", g.slab_bytes(slab.len()))?;
            for (c, ch) in plan.angle_chunks.iter().enumerate() {
                // serialized: copy chunk → wait → kernel → wait
                let bytes = ch.len() as u64 * g.single_proj_bytes();
                let e = sim.h2d(d, bytes, false, Ev::ZERO);
                sim.host_sync(e);
                let t = cost.bp_kernel_s(g.n_vox[0], g.n_vox[1], slab.len(), ch.len());
                let k = sim.kernel(d, t, e, &format!("naive bp d{d} s{s} c{c}"));
                sim.host_sync(k);
            }
            let e = sim.d2h(d, g.slab_bytes(slab.len()), false, Ev::ZERO);
            sim.host_sync(e);
        }
    }
    for d in 0..n_dev {
        sim.free(d, "projbuf");
        sim.free(d, "slab");
    }
    sim.sync_all();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::{ExecMode, MultiGpu};

    #[test]
    fn proposed_beats_naive_forward() {
        let g = Geometry::cone_beam(1024, 128);
        let ctx = MultiGpu::gtx1080ti(2);
        let naive = naive_forward(&ctx, &g).unwrap();
        let (_, proposed) = ctx.forward(&g, None, ExecMode::SimOnly).unwrap();
        assert!(
            proposed.makespan_s < naive.makespan_s,
            "proposed {} vs naive {}",
            proposed.makespan_s,
            naive.makespan_s
        );
    }

    #[test]
    fn proposed_beats_naive_backward() {
        let g = Geometry::cone_beam(1024, 256);
        let ctx = MultiGpu::gtx1080ti(2);
        let naive = naive_backward(&ctx, &g).unwrap();
        let (_, proposed) = ctx.backward(&g, None, ExecMode::SimOnly).unwrap();
        assert!(
            proposed.makespan_s < naive.makespan_s,
            "proposed {} vs naive {}",
            proposed.makespan_s,
            naive.makespan_s
        );
    }

    #[test]
    fn naive_respects_memory_too() {
        let g = Geometry::cone_beam(512, 64);
        let ctx = MultiGpu::gtx1080ti(1).with_device_mem(256 << 20);
        let stats = naive_backward(&ctx, &g).unwrap();
        assert!(stats.peak_device_bytes <= 256 << 20);
        assert!(stats.splits_per_device > 1);
    }
}
