//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Model: `tigre <subcommand> [--flag] [--key value]...`. Options are
//! declared up front so `--help` output and unknown-option errors are
//! automatic.

use std::collections::BTreeMap;

/// Declared option (always `--name <value>` unless `is_flag`).
#[derive(Clone, Debug)]
pub struct OptSpec {
    /// Option name without the leading `--`.
    pub name: &'static str,
    /// One-line description shown in `--help` output.
    pub help: &'static str,
    /// Value used when the option is not given (valued options only).
    pub default: Option<String>,
    /// True for boolean `--flag` options that take no value.
    pub is_flag: bool,
}

/// Parsed arguments for one subcommand.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Positional arguments after options.
    pub positional: Vec<String>,
}

impl Args {
    /// Raw string value of `--name` (default applied), if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Value of `--name` parsed as an integer; `Err` on a malformed value.
    pub fn get_usize(&self, name: &str) -> anyhow::Result<Option<usize>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse().map_err(|_| {
                anyhow::anyhow!("option --{name} expects an integer, got '{v}'")
            })?)),
        }
    }

    /// Value of `--name` parsed as a float; `Err` on a malformed value.
    pub fn get_f64(&self, name: &str) -> anyhow::Result<Option<f64>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse().map_err(|_| {
                anyhow::anyhow!("option --{name} expects a number, got '{v}'")
            })?)),
        }
    }

    /// Comma-separated list of integers, e.g. `--gpus 1,2,4`.
    pub fn get_usize_list(&self, name: &str) -> anyhow::Result<Option<Vec<usize>>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|tok| {
                    tok.trim().parse().map_err(|_| {
                        anyhow::anyhow!("option --{name}: bad integer '{tok}'")
                    })
                })
                .collect::<anyhow::Result<Vec<usize>>>()
                .map(Some),
        }
    }

    /// True when the boolean `--name` flag was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// A subcommand with declared options.
pub struct Command {
    /// Subcommand name as typed on the command line.
    pub name: &'static str,
    /// One-line description shown in usage output.
    pub about: &'static str,
    /// Declared options, in declaration order.
    pub opts: Vec<OptSpec>,
}

impl Command {
    /// New subcommand with no options declared yet.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, opts: Vec::new() }
    }

    /// Declare a valued option `--name <v>` (builder style).
    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&str>) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: default.map(|s| s.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a boolean flag `--name` (builder style).
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    /// Parse raw arguments (excluding the subcommand itself).
    pub fn parse(&self, raw: &[String]) -> anyhow::Result<Args> {
        let mut args = Args::default();
        for spec in &self.opts {
            if let Some(d) = &spec.default {
                args.values.insert(spec.name.to_string(), d.clone());
            }
        }
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if let Some(name) = tok.strip_prefix("--") {
                let spec = self
                    .opts
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{name}\n{}", self.usage()))?;
                if spec.is_flag {
                    args.flags.push(name.to_string());
                } else {
                    i += 1;
                    let val = raw.get(i).ok_or_else(|| {
                        anyhow::anyhow!("option --{name} requires a value")
                    })?;
                    args.values.insert(name.to_string(), val.clone());
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Render the usage/help text for this subcommand.
    pub fn usage(&self) -> String {
        let mut s = format!("usage: tigre {} [options]\n  {}\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let default = o
                .default
                .as_ref()
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            if o.is_flag {
                s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, default));
            } else {
                s.push_str(&format!("  --{:<18} {}{}\n", format!("{} <v>", o.name), o.help, default));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("bench", "run benchmark")
            .opt("size", "image size", Some("128"))
            .opt("gpus", "gpu list", Some("1,2"))
            .flag("verbose", "chatty")
    }

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&[]).unwrap();
        assert_eq!(a.get_usize("size").unwrap(), Some(128));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn overrides_and_flags() {
        let a = cmd().parse(&s(&["--size", "256", "--verbose", "pos1"])).unwrap();
        assert_eq!(a.get_usize("size").unwrap(), Some(256));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn usize_list() {
        let a = cmd().parse(&s(&["--gpus", "1,2,4"])).unwrap();
        assert_eq!(a.get_usize_list("gpus").unwrap(), Some(vec![1, 2, 4]));
    }

    #[test]
    fn unknown_option_is_error() {
        assert!(cmd().parse(&s(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(cmd().parse(&s(&["--size"])).is_err());
    }

    #[test]
    fn bad_integer_is_error() {
        let a = cmd().parse(&s(&["--size", "abc"])).unwrap();
        assert!(a.get_usize("size").is_err());
    }
}
