//! `tigre-lint`: dependency-free static analysis for the repo's own
//! invariants.
//!
//! The coordinator's correctness story (bit-identical folds across device
//! counts, typed error taxonomy, deterministic DES planning) rests on
//! conventions no compiler checks. This module is the checker: a
//! hand-rolled lexer ([`scan`]), a tiny waiver-file parser
//! ([`allowlist`]), and eight lint passes ([`lints`]) that walk
//! `rust/src/**` without executing or compiling anything — essential
//! while the build container lacks a toolchain (ROADMAP "toolchain
//! debt").
//!
//! Entry points: [`check_source`] for one in-memory file (what the golden
//! fixtures use) and [`check_tree`] for a directory walk (what the
//! `tigre-lint` binary and CI use). Diagnostics are rendered as
//! `path:line:col` text or machine-readable JSON.

pub mod allowlist;
pub mod lints;
pub mod scan;

pub use allowlist::Allowlist;
pub use lints::{lint_info, LintInfo, LINTS};

use crate::util::json::Json;
use scan::FileModel;

/// One lint finding, post-allowlist.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Lint id from the catalog (`lints::LINTS`).
    pub lint: &'static str,
    /// Fails the run even without `--deny-all`.
    pub deny: bool,
    /// Normalized (forward-slash) path as scanned.
    pub path: String,
    /// 1-based.
    pub line: usize,
    /// 1-based.
    pub col: usize,
    /// Human-readable description of the finding.
    pub message: String,
    /// Trimmed source line the finding sits on.
    pub snippet: String,
    /// Nearest enclosing named `fn`, if any (drives `fn` waivers).
    pub enclosing_fn: Option<String>,
}

/// Lint one file's source text under `pretend_path` (paths select lint
/// scopes, so fixtures pass coordinator-shaped paths for files that live
/// elsewhere). Returns diagnostics surviving the allowlist, in source
/// order.
pub fn check_source(pretend_path: &str, src: &str, allow: &Allowlist) -> Vec<Diagnostic> {
    let model = FileModel::build(pretend_path, src);
    let mut raw = Vec::new();
    lints::run_all(&model, &mut raw);
    raw.sort_by_key(|d| (d.line, d.col));
    raw.retain(|d| {
        !allow.allows(d.lint, &d.path, d.snippet.as_str(), d.enclosing_fn.as_deref())
    });
    raw
}

/// Recursively collect `.rs` files under `root` in deterministic
/// (sorted-path) order. Fixture trees are excluded so the checker never
/// trips over its own seeded violations.
pub fn collect_rs_files(root: &std::path::Path) -> std::io::Result<Vec<std::path::PathBuf>> {
    let mut files = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<_> = std::fs::read_dir(&dir)?
            .collect::<std::io::Result<Vec<_>>>()?
            .into_iter()
            .map(|e| e.path())
            .collect();
        entries.sort();
        for path in entries {
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if path.is_dir() {
                if name == "lint_fixtures" || name == "target" || name.starts_with('.') {
                    continue;
                }
                stack.push(path);
            } else if name.ends_with(".rs") {
                files.push(path);
            }
        }
    }
    files.sort();
    Ok(files)
}

/// Lint every `.rs` file under `root`. IO errors abort (exit 2 in the
/// binary): an unreadable tree must not pass as clean.
pub fn check_tree(root: &std::path::Path, allow: &Allowlist) -> std::io::Result<Vec<Diagnostic>> {
    let mut out = Vec::new();
    for path in collect_rs_files(root)? {
        let src = std::fs::read_to_string(&path)?;
        let shown = path.to_string_lossy().replace('\\', "/");
        out.extend(check_source(&shown, &src, allow));
    }
    Ok(out)
}

/// `path:line:col: [severity/lint] message` lines plus a summary tail.
pub fn render_text(diags: &[Diagnostic], deny_all: bool) -> String {
    let mut s = String::new();
    for d in diags {
        let sev = if d.deny || deny_all { "deny" } else { "warn" };
        s.push_str(&format!(
            "{}:{}:{}: [{sev}/{}] {}\n    {}\n",
            d.path, d.line, d.col, d.lint, d.message, d.snippet
        ));
    }
    let fatal = diags.iter().filter(|d| d.deny || deny_all).count();
    s.push_str(&format!(
        "tigre-lint: {} diagnostic(s), {} fatal\n",
        diags.len(),
        fatal
    ));
    s
}

/// Machine-readable report: `{"diagnostics": [...], "fatal": n}`.
pub fn render_json(diags: &[Diagnostic], deny_all: bool) -> String {
    let items = diags
        .iter()
        .map(|d| {
            Json::obj(vec![
                ("lint", Json::str(d.lint)),
                ("severity", Json::str(if d.deny || deny_all { "deny" } else { "warn" })),
                ("path", Json::str(d.path.as_str())),
                ("line", Json::num(d.line as f64)),
                ("col", Json::num(d.col as f64)),
                ("message", Json::str(d.message.as_str())),
                ("snippet", Json::str(d.snippet.as_str())),
                (
                    "enclosing_fn",
                    match &d.enclosing_fn {
                        Some(f) => Json::str(f.as_str()),
                        None => Json::Null,
                    },
                ),
            ])
        })
        .collect();
    let fatal = diags.iter().filter(|d| d.deny || deny_all).count();
    Json::obj(vec![
        ("diagnostics", Json::arr(items)),
        ("total", Json::num(diags.len() as f64)),
        ("fatal", Json::num(fatal as f64)),
    ])
    .pretty()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lint_check_source_orders_and_filters_by_allowlist() {
        let src = r#"
fn merge(dst: &mut [f32], src: &[f32]) {
    for (o, s) in dst.iter_mut().zip(src) {
        *o += *s;
    }
}
fn grab(v: Option<u32>) -> u32 {
    v.unwrap()
}
"#;
        let path = "rust/src/coordinator/fake.rs";
        let none = Allowlist::empty();
        let diags = check_source(path, src, &none);
        assert_eq!(diags.len(), 2);
        assert_eq!(diags[0].lint, "blessed-accumulation");
        assert_eq!(diags[0].enclosing_fn.as_deref(), Some("merge"));
        assert_eq!(diags[1].lint, "no-panic-paths");

        let allow = Allowlist::parse(
            "[blessed-accumulation]\nallow = \"coordinator/fake.rs | fn merge\"\n",
        )
        .unwrap();
        let diags = check_source(path, src, &allow);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].lint, "no-panic-paths");
    }

    #[test]
    fn lint_render_json_is_parseable_and_counts_fatal() {
        let src = "fn f() { println!(\"hi\"); }\n";
        let diags = check_source("rust/src/metrics/fake.rs", src, &Allowlist::empty());
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].lint, "no-bare-print");
        assert!(!diags[0].deny, "no-bare-print warns by default");

        let report = Json::parse(&render_json(&diags, false)).unwrap();
        assert_eq!(report.get("total").unwrap().as_u64(), Some(1));
        assert_eq!(report.get("fatal").unwrap().as_u64(), Some(0));
        let report = Json::parse(&render_json(&diags, true)).unwrap();
        assert_eq!(report.get("fatal").unwrap().as_u64(), Some(1));
    }
}
