//! Deterministic fault injection for the executor stack.
//!
//! `FaultPlan` is the general facility grown out of PR 6's `cfg(test)`
//! `Backend::PanicInject`: instead of panicking a whole worker, it
//! injects *recoverable* faults — transient launch failures, permanent
//! device loss, allocation failures and OOC disk-I/O errors — at chosen
//! (device, unit, iteration) coordinates. The same plan drives both the
//! simulated timeline (recovery time shows up in the DES makespan via
//! `CostModel::fault_retry_backoff_s` / `fault_replan_s`) and the real
//! pipelined executor (bounded retry + replanning onto survivors), so a
//! fault scenario can be modeled and executed from one description.
//!
//! Coordinates: a **unit** is the per-device launch ordinal within one
//! operator call (slab×chunk launches in image split, chunk launches in
//! angle split), counted independently per scope — the simulated
//! timeline and the real executor enumerate launches differently, so
//! each [`FaultScope`] keeps its own ordinal counters and fired flags.
//! Device loss is sticky: once a device is lost in a scope it stays
//! lost for every later operator call until the plan is dropped, which
//! is what lets a mid-iteration loss degrade the remainder of a
//! multi-iteration reconstruction.
//!
//! Every site fires at most once per scope; transient sites carry a
//! `times` budget (consecutive failures before the retried launch
//! succeeds). A transient budget above [`MAX_LAUNCH_RETRIES`] escalates
//! to device loss in the callers — bounded backoff, not infinite retry.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Retry budget for a single launch/IO unit before the fault escalates
/// from transient to permanent (device loss for launches, a typed
/// `OocIoError` for disk reads). Shared by the simulated and real paths.
pub const MAX_LAUNCH_RETRIES: usize = 4;

/// What kind of fault a site injects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Launch fails `times` times, then the retried launch succeeds.
    TransientLaunch,
    /// The device drops out permanently at this unit; remaining units
    /// are replanned onto survivors (`splitter::replan_excluding`).
    DeviceLoss,
    /// Device allocation fails `times` times before succeeding
    /// (the recoverable sibling of the typed `SimOom`). A budget above
    /// [`MAX_LAUNCH_RETRIES`] is a *hard* allocation failure: the
    /// simulated node surfaces `SimOom` and the operator entry runs the
    /// memory-pressure ladder (evict → refine → spill, ISSUE 8).
    AllocFail,
    /// An OOC disk read/write fails `times` consecutive attempts.
    DiskIo,
    /// The launch hangs: the unit misses its watchdog deadline
    /// (predicted kernel time × `CostModel::watchdog_factor`) `times`
    /// consecutive attempts before the retried launch completes. Past
    /// [`MAX_LAUNCH_RETRIES`] the watchdog escalates the hang to device
    /// loss, exactly like a transient burst (ISSUE 8).
    Hang,
}

/// One injection site. `unit` is a per-device launch ordinal for
/// launch/alloc faults and a global disk-op ordinal for `DiskIo`,
/// counted from the operator entry (`begin_op`).
#[derive(Clone, Debug)]
pub struct FaultSite {
    /// What kind of failure to inject.
    pub kind: FaultKind,
    /// Device index the site targets.
    pub device: usize,
    /// Launch/alloc/disk ordinal at which the site fires.
    pub unit: usize,
    /// Restrict the site to one algorithm iteration (`set_iteration`);
    /// `None` arms it from the start.
    pub iteration: Option<usize>,
    /// Consecutive failures injected when the site fires (min 1).
    pub times: usize,
}

/// Which execution path is consuming the plan. `ExecMode::Full` runs
/// the simulated timeline *and* the real executor over one plan; the
/// scopes keep independent counters so a site fires once in each.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultScope {
    /// The discrete-event simulated timeline.
    Sim,
    /// The real host executor.
    Real,
}

/// Outcome of the pre-launch fault gate for one unit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaunchFault {
    /// No fault: launch proceeds.
    Ok,
    /// Launch fails `n` times; retry with doubling backoff, then it
    /// succeeds (callers escalate to loss when `n > MAX_LAUNCH_RETRIES`).
    Transient(usize),
    /// Launch hangs `n` times: each attempt runs until the watchdog
    /// deadline fires, is cancelled and retried (callers escalate to
    /// loss when `n > MAX_LAUNCH_RETRIES`).
    Hung(usize),
    /// The device is (or just became) permanently lost.
    Lost,
}

#[derive(Debug, Default)]
struct ScopeState {
    /// Per-device launch ordinal within the current operator call.
    unit_ord: Vec<usize>,
    /// Per-device alloc ordinal within the current operator call.
    alloc_ord: Vec<usize>,
    /// Disk-op ordinal within the current operator call.
    disk_ord: usize,
    /// Per-site consumed flags (sites fire at most once per scope).
    fired: Vec<bool>,
    /// Sticky per-device loss flags — persist across operator calls.
    lost: Vec<bool>,
}

impl ScopeState {
    fn ensure(&mut self, dev: usize, n_sites: usize) {
        if self.unit_ord.len() <= dev {
            self.unit_ord.resize(dev + 1, 0);
            self.alloc_ord.resize(dev + 1, 0);
            self.lost.resize(dev + 1, false);
        }
        if self.fired.len() < n_sites {
            self.fired.resize(n_sites, false);
        }
    }
}

/// A deterministic, seedable fault schedule shared by the simulated
/// timeline and the real executor. Cheap to clone via `Arc`; all state
/// is interior-mutable and thread-safe (worker threads consult the
/// plan concurrently, one device per worker).
#[derive(Debug)]
pub struct FaultPlan {
    sites: Vec<FaultSite>,
    sim: Mutex<ScopeState>,
    real: Mutex<ScopeState>,
    iteration: AtomicUsize,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self::new()
    }
}

impl FaultPlan {
    /// Empty schedule (no faults).
    pub fn new() -> Self {
        Self {
            sites: Vec::new(),
            sim: Mutex::new(ScopeState::default()),
            real: Mutex::new(ScopeState::default()),
            iteration: AtomicUsize::new(0),
        }
    }

    /// Add an explicit site.
    pub fn with_site(mut self, site: FaultSite) -> Self {
        self.sites.push(site);
        self
    }

    /// One transient launch failure at (device, unit).
    pub fn transient_launch(self, device: usize, unit: usize) -> Self {
        self.with_site(FaultSite {
            kind: FaultKind::TransientLaunch,
            device,
            unit,
            iteration: None,
            times: 1,
        })
    }

    /// `times` consecutive launch failures at (device, unit, iteration).
    pub fn transient_launch_at(
        self,
        device: usize,
        unit: usize,
        iteration: usize,
        times: usize,
    ) -> Self {
        self.with_site(FaultSite {
            kind: FaultKind::TransientLaunch,
            device,
            unit,
            iteration: Some(iteration),
            times,
        })
    }

    /// Permanent device loss at (device, unit).
    pub fn device_loss(self, device: usize, unit: usize) -> Self {
        self.with_site(FaultSite {
            kind: FaultKind::DeviceLoss,
            device,
            unit,
            iteration: None,
            times: 1,
        })
    }

    /// Permanent device loss at (device, unit, iteration).
    pub fn device_loss_at(self, device: usize, unit: usize, iteration: usize) -> Self {
        self.with_site(FaultSite {
            kind: FaultKind::DeviceLoss,
            device,
            unit,
            iteration: Some(iteration),
            times: 1,
        })
    }

    /// `times` allocation failures at the device's alloc ordinal `unit`.
    pub fn alloc_fail(self, device: usize, unit: usize, times: usize) -> Self {
        self.with_site(FaultSite {
            kind: FaultKind::AllocFail,
            device,
            unit,
            iteration: None,
            times,
        })
    }

    /// `times` consecutive hangs (watchdog-deadline misses) at the
    /// launch ordinal `unit` of `device`.
    pub fn hang(self, device: usize, unit: usize, times: usize) -> Self {
        self.with_site(FaultSite {
            kind: FaultKind::Hang,
            device,
            unit,
            iteration: None,
            times,
        })
    }

    /// `times` consecutive disk-I/O failures at disk-op ordinal `unit`.
    pub fn disk_io(self, unit: usize, times: usize) -> Self {
        self.with_site(FaultSite {
            kind: FaultKind::DiskIo,
            device: 0,
            unit,
            iteration: None,
            times,
        })
    }

    /// Seeded scatter of `count` single-failure transient launch sites
    /// over `n_devices` devices × `n_units` units (xorshift64 — the
    /// same seed always produces the same schedule).
    pub fn scatter_transients(
        mut self,
        seed: u64,
        count: usize,
        n_devices: usize,
        n_units: usize,
    ) -> Self {
        let mut s = seed.max(1);
        let mut next = || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            s
        };
        for _ in 0..count {
            let device = (next() % n_devices.max(1) as u64) as usize;
            let unit = (next() % n_units.max(1) as u64) as usize;
            self.sites.push(FaultSite {
                kind: FaultKind::TransientLaunch,
                device,
                unit,
                iteration: None,
                times: 1,
            });
        }
        self
    }

    /// All scheduled injection sites.
    pub fn sites(&self) -> &[FaultSite] {
        &self.sites
    }

    /// Does the plan schedule any permanent device loss? The real-path
    /// tree merge degrades to the host-serial fold of the same canonical
    /// schedule when this is set (a lost worker cannot feed its tree
    /// channel), which keeps output bit-identical by construction.
    pub fn plans_loss(&self) -> bool {
        self.sites.iter().any(|s| {
            s.kind == FaultKind::DeviceLoss
                || (s.kind == FaultKind::TransientLaunch && s.times > MAX_LAUNCH_RETRIES)
                || (s.kind == FaultKind::Hang && s.times > MAX_LAUNCH_RETRIES)
        })
    }

    fn state(&self, scope: FaultScope) -> &Mutex<ScopeState> {
        match scope {
            FaultScope::Sim => &self.sim,
            FaultScope::Real => &self.real,
        }
    }

    /// Reset the per-operator ordinals for one scope. Called at every
    /// operator entry (`fresh_sim` for Sim, the pipelined executor
    /// entry for Real). Fired flags and loss flags persist.
    pub fn begin_op(&self, scope: FaultScope) {
        let mut st = self.state(scope).lock().unwrap();
        st.unit_ord.iter_mut().for_each(|o| *o = 0);
        st.alloc_ord.iter_mut().for_each(|o| *o = 0);
        st.disk_ord = 0;
    }

    /// Advance the iteration gate for `iteration: Some(i)` sites.
    pub fn set_iteration(&self, it: usize) {
        self.iteration.store(it, Ordering::SeqCst);
    }

    fn iteration_matches(&self, site: &FaultSite) -> bool {
        match site.iteration {
            None => true,
            Some(i) => i == self.iteration.load(Ordering::SeqCst),
        }
    }

    /// Fault gate consulted before each launch unit on `dev`. Advances
    /// the device's unit ordinal and reports what the launch hits.
    pub fn launch_fault(&self, scope: FaultScope, dev: usize) -> LaunchFault {
        let mut st = self.state(scope).lock().unwrap();
        st.ensure(dev, self.sites.len());
        let ord = st.unit_ord[dev];
        st.unit_ord[dev] += 1;
        if st.lost[dev] {
            return LaunchFault::Lost;
        }
        for (i, site) in self.sites.iter().enumerate() {
            if st.fired[i]
                || site.device != dev
                || site.unit != ord
                || !self.iteration_matches(site)
            {
                continue;
            }
            match site.kind {
                FaultKind::TransientLaunch => {
                    st.fired[i] = true;
                    return LaunchFault::Transient(site.times.max(1));
                }
                FaultKind::DeviceLoss => {
                    st.fired[i] = true;
                    st.lost[dev] = true;
                    return LaunchFault::Lost;
                }
                FaultKind::Hang => {
                    st.fired[i] = true;
                    return LaunchFault::Hung(site.times.max(1));
                }
                FaultKind::AllocFail | FaultKind::DiskIo => {}
            }
        }
        LaunchFault::Ok
    }

    /// Number of injected failures for the next allocation on `dev`.
    pub fn alloc_fault(&self, scope: FaultScope, dev: usize) -> usize {
        let mut st = self.state(scope).lock().unwrap();
        st.ensure(dev, self.sites.len());
        let ord = st.alloc_ord[dev];
        st.alloc_ord[dev] += 1;
        for (i, site) in self.sites.iter().enumerate() {
            if st.fired[i]
                || site.kind != FaultKind::AllocFail
                || site.device != dev
                || site.unit != ord
                || !self.iteration_matches(site)
            {
                continue;
            }
            st.fired[i] = true;
            return site.times.max(1);
        }
        0
    }

    /// Number of injected failures for the next disk operation.
    pub fn disk_fault(&self, scope: FaultScope) -> usize {
        let mut st = self.state(scope).lock().unwrap();
        st.ensure(0, self.sites.len());
        let ord = st.disk_ord;
        st.disk_ord += 1;
        for (i, site) in self.sites.iter().enumerate() {
            if st.fired[i]
                || site.kind != FaultKind::DiskIo
                || site.unit != ord
                || !self.iteration_matches(site)
            {
                continue;
            }
            st.fired[i] = true;
            return site.times.max(1);
        }
        0
    }

    /// Is `dev` permanently lost in `scope`?
    pub fn is_lost(&self, scope: FaultScope, dev: usize) -> bool {
        let st = self.state(scope).lock().unwrap();
        st.lost.get(dev).copied().unwrap_or(false)
    }

    /// Mark `dev` lost (transient budget exhausted → escalation).
    pub fn mark_lost(&self, scope: FaultScope, dev: usize) {
        let mut st = self.state(scope).lock().unwrap();
        st.ensure(dev, self.sites.len());
        st.lost[dev] = true;
    }

    /// Snapshot of the per-device loss flags, sized to `n` devices.
    pub fn lost_devices(&self, scope: FaultScope, n: usize) -> Vec<bool> {
        let st = self.state(scope).lock().unwrap();
        (0..n).map(|d| st.lost.get(d).copied().unwrap_or(false)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transient_fires_once_at_its_ordinal() {
        let p = FaultPlan::new().transient_launch(1, 2);
        p.begin_op(FaultScope::Real);
        assert_eq!(p.launch_fault(FaultScope::Real, 1), LaunchFault::Ok); // unit 0
        assert_eq!(p.launch_fault(FaultScope::Real, 0), LaunchFault::Ok); // other dev
        assert_eq!(p.launch_fault(FaultScope::Real, 1), LaunchFault::Ok); // unit 1
        assert_eq!(p.launch_fault(FaultScope::Real, 1), LaunchFault::Transient(1));
        // consumed: re-running the op does not re-fire
        p.begin_op(FaultScope::Real);
        for _ in 0..4 {
            assert_eq!(p.launch_fault(FaultScope::Real, 1), LaunchFault::Ok);
        }
    }

    #[test]
    fn scopes_are_independent() {
        let p = FaultPlan::new().transient_launch(0, 0);
        p.begin_op(FaultScope::Sim);
        p.begin_op(FaultScope::Real);
        assert_eq!(p.launch_fault(FaultScope::Sim, 0), LaunchFault::Transient(1));
        // the real scope still sees its own copy of the site
        assert_eq!(p.launch_fault(FaultScope::Real, 0), LaunchFault::Transient(1));
    }

    #[test]
    fn device_loss_is_sticky_across_ops() {
        let p = FaultPlan::new().device_loss(1, 1);
        assert!(p.plans_loss());
        p.begin_op(FaultScope::Real);
        assert_eq!(p.launch_fault(FaultScope::Real, 1), LaunchFault::Ok);
        assert_eq!(p.launch_fault(FaultScope::Real, 1), LaunchFault::Lost);
        assert!(p.is_lost(FaultScope::Real, 1));
        // next op: lost from unit 0
        p.begin_op(FaultScope::Real);
        assert_eq!(p.launch_fault(FaultScope::Real, 1), LaunchFault::Lost);
        assert_eq!(p.lost_devices(FaultScope::Real, 4), vec![false, true, false, false]);
        // but not in the sim scope
        assert!(!p.is_lost(FaultScope::Sim, 1));
    }

    #[test]
    fn iteration_gate_arms_only_its_iteration() {
        let p = FaultPlan::new().transient_launch_at(0, 0, 2, 3);
        p.set_iteration(0);
        p.begin_op(FaultScope::Real);
        assert_eq!(p.launch_fault(FaultScope::Real, 0), LaunchFault::Ok);
        p.set_iteration(2);
        p.begin_op(FaultScope::Real);
        assert_eq!(p.launch_fault(FaultScope::Real, 0), LaunchFault::Transient(3));
    }

    #[test]
    fn alloc_and_disk_faults_use_their_own_ordinals() {
        let p = FaultPlan::new().alloc_fail(0, 1, 2).disk_io(0, 3);
        p.begin_op(FaultScope::Sim);
        // launch ordinal does not consume alloc sites
        assert_eq!(p.launch_fault(FaultScope::Sim, 0), LaunchFault::Ok);
        assert_eq!(p.alloc_fault(FaultScope::Sim, 0), 0); // alloc ordinal 0
        assert_eq!(p.alloc_fault(FaultScope::Sim, 0), 2); // alloc ordinal 1
        assert_eq!(p.alloc_fault(FaultScope::Sim, 0), 0);
        assert_eq!(p.disk_fault(FaultScope::Sim), 3);
        assert_eq!(p.disk_fault(FaultScope::Sim), 0);
    }

    #[test]
    fn hang_fires_once_at_its_launch_ordinal() {
        let p = FaultPlan::new().hang(0, 1, 2);
        assert!(!p.plans_loss(), "a recoverable hang plans no loss");
        p.begin_op(FaultScope::Real);
        assert_eq!(p.launch_fault(FaultScope::Real, 0), LaunchFault::Ok); // unit 0
        assert_eq!(p.launch_fault(FaultScope::Real, 0), LaunchFault::Hung(2));
        // consumed: the retried launch (a fresh ordinal next op) is clean
        p.begin_op(FaultScope::Real);
        for _ in 0..3 {
            assert_eq!(p.launch_fault(FaultScope::Real, 0), LaunchFault::Ok);
        }
    }

    #[test]
    fn hang_past_retry_budget_plans_a_loss() {
        // the tree merge keys off plans_loss() to degrade safely — an
        // escalating hang must advertise itself the same way a
        // transient burst does
        let p = FaultPlan::new().hang(1, 0, MAX_LAUNCH_RETRIES + 1);
        assert!(p.plans_loss());
        p.begin_op(FaultScope::Real);
        assert_eq!(
            p.launch_fault(FaultScope::Real, 1),
            LaunchFault::Hung(MAX_LAUNCH_RETRIES + 1)
        );
    }

    #[test]
    fn scatter_is_deterministic_per_seed() {
        let a = FaultPlan::new().scatter_transients(7, 5, 4, 10);
        let b = FaultPlan::new().scatter_transients(7, 5, 4, 10);
        let coords = |p: &FaultPlan| {
            p.sites().iter().map(|s| (s.device, s.unit)).collect::<Vec<_>>()
        };
        assert_eq!(coords(&a), coords(&b));
        assert_eq!(a.sites().len(), 5);
        assert!(a.sites().iter().all(|s| s.device < 4 && s.unit < 10));
    }
}
