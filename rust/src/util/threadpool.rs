//! Scoped thread-pool / parallel-for substrate (rayon is unavailable).
//!
//! Two entry points:
//!  * [`parallel_for`] — split an index range into chunks and run a closure
//!    over each chunk on worker threads (used by the native kernels).
//!  * [`ThreadPool`] — a persistent pool with a job queue (used by the
//!    coordinator to model one host thread per simulated GPU).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Number of worker threads to use by default: the host parallelism.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `body(start, end)` over disjoint chunks of `0..n` on up to
/// `threads` scoped threads. Chunks are balanced via an atomic cursor so
/// irregular per-index cost (e.g. rays missing the volume) self-balances.
pub fn parallel_for<F>(n: usize, threads: usize, chunk: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n <= chunk {
        body(0, n);
        return;
    }
    let cursor = AtomicUsize::new(0);
    let body = &body;
    let cursor = &cursor;
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(move || loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                body(start, end);
            });
        }
    });
}

type Job = Box<dyn FnOnce() + Send + 'static>;

type Pending = (Mutex<usize>, std::sync::Condvar);

/// Decrements the pending-job count on drop, so a panicking job can
/// never leave `wait_idle` blocked forever: the decrement happens during
/// unwinding as well as on the normal path.
struct PendingGuard<'a>(&'a Pending);

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        let (lock, cvar) = self.0;
        // the count mutex is only ever held for the increment/decrement
        // itself, so it cannot be poisoned by a job panic
        let mut p = lock.lock().unwrap_or_else(|e| e.into_inner());
        *p -= 1;
        if *p == 0 {
            cvar.notify_all();
        }
    }
}

/// A persistent thread pool with graceful shutdown on drop. Jobs that
/// panic are contained: the panic is caught on the worker, the pending
/// count still drops (drop guard), and the worker keeps serving jobs.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
    pending: Arc<Pending>,
}

impl ThreadPool {
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending: Arc<Pending> = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            handles.push(thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => {
                        let _guard = PendingGuard(&pending);
                        // contain job panics so the worker survives and
                        // the guard's decrement runs exactly once
                        let result =
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                        if let Err(payload) = result {
                            let msg = payload
                                .downcast_ref::<&str>()
                                .map(|s| s.to_string())
                                .or_else(|| payload.downcast_ref::<String>().cloned())
                                .unwrap_or_else(|| "<non-string panic>".into());
                            crate::log_warn!("threadpool job panicked: {msg}");
                        }
                    }
                    Err(_) => break,
                }
            }));
        }
        Self { tx: Some(tx), handles, pending }
    }

    /// Submit a job; returns immediately.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker hung up");
    }

    /// Block until every submitted job has completed (including jobs
    /// that panicked — see [`PendingGuard`]).
    pub fn wait_idle(&self) {
        let (lock, cvar) = &*self.pending;
        let mut p = lock.lock().unwrap_or_else(|e| e.into_inner());
        while *p > 0 {
            p = cvar.wait(p).unwrap_or_else(|e| e.into_inner());
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit on recv error
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_indices_once() {
        let n = 10_000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(n, 4, 128, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_handles_zero() {
        let touched = AtomicUsize::new(0);
        parallel_for(0, 4, 16, |s, e| {
            touched.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(touched.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn parallel_for_single_thread_path() {
        let sum = AtomicU64::new(0);
        parallel_for(100, 1, 16, |s, e| {
            for i in s..e {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            }
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn pool_runs_jobs_and_waits() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn pool_survives_panicking_jobs() {
        // A panicking job must still decrement the pending count (drop
        // guard) — before the fix this deadlocked wait_idle — and must
        // not kill the worker thread.
        let pool = ThreadPool::new(2);
        for _ in 0..4 {
            pool.submit(|| panic!("job panic (expected in this test)"));
        }
        pool.wait_idle(); // would hang forever without the guard

        // the pool still processes subsequent jobs on all workers
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..16 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn pool_mixed_panicking_and_normal_jobs() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for i in 0..30 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                if i % 3 == 0 {
                    panic!("boom {i}");
                }
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 20);
    }

    #[test]
    fn pool_drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::Relaxed), 8);
    }
}
