//! Ray-driven intersection forward projector (Siddon, 1985, with the
//! Amanatides–Woo incremental traversal).
//!
//! For every detector pixel at every angle a ray is cast from the source
//! to the pixel centre; the projection value is the exact line integral
//! `Σ length(ray ∩ voxel) · value(voxel)`. This is TIGRE's default `Ax`
//! operator and the one timed in the paper's Fig. 7–9.
//!
//! The kernel works on *any* `Geometry`, including slab geometries produced
//! by `Geometry::slab_geometry` — that is what makes the coordinator's
//! image-partitioning transparent to the kernel, mirroring how the CUDA
//! kernels in the paper are reused unchanged on image pieces.
//!
//! Hot-path structure (EXPERIMENTS.md §Perf): detector pixels are addressed
//! through the precomputed affine [`DetFrame`] (one per angle) instead of
//! re-deriving the panel placement per ray; the per-ray *setup* (box clip,
//! entry voxel, per-axis `t` increments) stays in f64 for robustness, while
//! the traversal accumulates in f32 with a precomputed linear index walked
//! by stride increments — one add and one unchecked load per voxel crossed.

use crate::geometry::{DetFrame, Geometry};
use crate::util::threadpool::{parallel_for, SendPtr};
use crate::volume::{ProjectionSet, Volume, VolumeSlabView};

/// Forward-project all angles of `g`. `vol` must match `g.n_vox`.
pub fn project(g: &Geometry, vol: &Volume, threads: usize) -> ProjectionSet {
    let nu = g.n_det[0];
    let nv = g.n_det[1];
    let mut out = crate::kernels::scratch::take_projections(nu, nv, g.n_angles());
    project_into(g, &vol.as_view(), &mut out.data, threads);
    out
}

/// Forward-project a borrowed (slab) volume view straight into `out`
/// (layout `(a·nv + iv)·nu + iu`, every element overwritten). This is the
/// zero-copy entry point the pipelined executor uses: the view borrows the
/// caller's resident volume and `out` is the caller's staging buffer or a
/// disjoint window of the shared output, so neither input nor output is
/// copied around the kernel.
pub fn project_into(g: &Geometry, vol: &VolumeSlabView<'_>, out: &mut [f32], threads: usize) {
    assert_eq!(
        [vol.nx, vol.ny, vol.nz],
        [g.n_vox[0], g.n_vox[1], g.n_vox[2]],
        "volume shape does not match geometry"
    );
    let nu = g.n_det[0];
    let nv = g.n_det[1];
    let n_angles = g.n_angles();
    assert_eq!(out.len(), nu * nv * n_angles, "output length mismatch");

    // Precompute per-angle affine detector frames once (the CUDA code
    // keeps these in constant memory).
    let frames: Vec<DetFrame> = (0..n_angles).map(|a| g.det_frame(a)).collect();
    let (lo, hi) = g.volume_bbox();
    let dv = g.d_vox;
    let n = [vol.nx, vol.ny, vol.nz];
    let data = vol.data;

    let rows = n_angles * nv;
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for(rows, threads, 8, |r0, r1| {
        let ptr = ptr; // copy the Send wrapper into the closure
        for row in r0..r1 {
            let a = row / nv;
            let iv = row % nv;
            let frame = &frames[a];
            // Detector row iv: pixel centres are affine in iu.
            let row0 = frame.row_origin(iv);
            let us = frame.u_step;
            for iu in 0..nu {
                let fu = iu as f64;
                let pix = [
                    row0[0] + fu * us[0],
                    row0[1] + fu * us[1],
                    row0[2] + fu * us[2],
                ];
                let val = raytrace(&frame.src, &pix, &lo, &hi, &dv, &n, data);
                // SAFETY: parallel_for hands each task a disjoint range of
                // detector rows, so index (a*nv+iv)*nu+iu is written by
                // exactly one task; out.len() == n_angles*nv*nu bounds it.
                unsafe {
                    *ptr.0.add((a * nv + iv) * nu + iu) = val;
                }
            }
        }
    });
}

/// Exact line integral of the volume along segment src→dst using
/// Amanatides–Woo voxel traversal. `lo`/`hi` bound the volume in mm,
/// `dvox` is voxel pitch, `n` the voxel counts.
///
/// f64 per-ray setup, f32 traversal: the parametric segment lengths are
/// accumulated against the voxel values in f32 and scaled by the (f64)
/// ray length once at the end, which keeps the result within ~1e-6
/// relative of the all-f64 reference (`tests::golden_parity_vs_reference`)
/// while letting the inner loop run entirely in 32-bit registers.
#[allow(clippy::too_many_arguments)]
pub fn raytrace(
    src: &[f64; 3],
    dst: &[f64; 3],
    lo: &[f64; 3],
    hi: &[f64; 3],
    dvox: &[f64; 3],
    n: &[usize; 3],
    data: &[f32],
) -> f32 {
    debug_assert_eq!(data.len(), n[0] * n[1] * n[2]);
    let dir = [dst[0] - src[0], dst[1] - src[1], dst[2] - src[2]];
    let len = (dir[0] * dir[0] + dir[1] * dir[1] + dir[2] * dir[2]).sqrt();
    if len == 0.0 {
        return 0.0;
    }

    // Clip the parametric ray p(t) = src + t·dir, t ∈ [0,1], to the box.
    let mut tmin = 0.0f64;
    let mut tmax = 1.0f64;
    for k in 0..3 {
        if dir[k].abs() < 1e-12 {
            if src[k] < lo[k] || src[k] > hi[k] {
                return 0.0;
            }
        } else {
            let inv = 1.0 / dir[k];
            let t0 = (lo[k] - src[k]) * inv;
            let t1 = (hi[k] - src[k]) * inv;
            let (t0, t1) = if t0 <= t1 { (t0, t1) } else { (t1, t0) };
            tmin = tmin.max(t0);
            tmax = tmax.min(t1);
        }
    }
    if tmin >= tmax {
        return 0.0;
    }

    // Entry point and starting voxel.
    let eps = 1e-9;
    let entry = [
        src[0] + (tmin + eps) * dir[0],
        src[1] + (tmin + eps) * dir[1],
        src[2] + (tmin + eps) * dir[2],
    ];
    let mut ix = [0isize; 3];
    for k in 0..3 {
        let f = ((entry[k] - lo[k]) / dvox[k]).floor();
        ix[k] = (f as isize).clamp(0, n[k] as isize - 1);
    }

    // Per-axis traversal increments in t.
    let mut t_next = [f64::INFINITY; 3];
    let mut dt = [f64::INFINITY; 3];
    let mut step = [0isize; 3];
    for k in 0..3 {
        if dir[k] > 1e-12 {
            step[k] = 1;
            let boundary = lo[k] + (ix[k] + 1) as f64 * dvox[k];
            t_next[k] = (boundary - src[k]) / dir[k];
            dt[k] = dvox[k] / dir[k];
        } else if dir[k] < -1e-12 {
            step[k] = -1;
            let boundary = lo[k] + ix[k] as f64 * dvox[k];
            t_next[k] = (boundary - src[k]) / dir[k];
            dt[k] = -dvox[k] / dir[k];
        }
    }

    let nx = n[0] as isize;
    let ny = n[1] as isize;
    let bound = [nx, ny, n[2] as isize];
    // Linear index of the current voxel, walked by per-axis strides so the
    // loop never re-multiplies indices.
    let stride = [1isize, nx, nx * ny];
    let istep = [
        step[0] * stride[0],
        step[1] * stride[1],
        step[2] * stride[2],
    ];
    let mut idx = (ix[2] * ny + ix[1]) * nx + ix[0];

    let mut t = tmin;
    let mut acc = 0.0f32;
    loop {
        // Next crossing among the three axes.
        let (axis, tn) = {
            let mut axis = 0;
            let mut tn = t_next[0];
            if t_next[1] < tn {
                axis = 1;
                tn = t_next[1];
            }
            if t_next[2] < tn {
                axis = 2;
                tn = t_next[2];
            }
            (axis, tn)
        };
        let t_end = tn.min(tmax);
        if t_end > t {
            // SAFETY: ix starts clamped in-bounds and the walk below
            // breaks before idx leaves the grid, so idx indexes `data`.
            acc += (t_end - t) as f32 * unsafe { *data.get_unchecked(idx as usize) };
            t = t_end;
        }
        if tn >= tmax {
            break;
        }
        ix[axis] += step[axis];
        if ix[axis] < 0 || ix[axis] >= bound[axis] {
            break;
        }
        idx += istep[axis];
        t_next[axis] += dt[axis];
    }
    acc * len as f32
}

/// Pre-refactor scalar reference (all-f64 accumulation, per-pixel world
/// addressing) — kept verbatim as the golden oracle for the optimized
/// traversal above.
#[cfg(test)]
pub(crate) mod reference {
    use super::*;

    #[allow(clippy::too_many_arguments)]
    pub fn raytrace_ref(
        src: &[f64; 3],
        dst: &[f64; 3],
        lo: &[f64; 3],
        hi: &[f64; 3],
        dvox: &[f64; 3],
        n: &[usize; 3],
        data: &[f32],
    ) -> f32 {
        let dir = [dst[0] - src[0], dst[1] - src[1], dst[2] - src[2]];
        let len = (dir[0] * dir[0] + dir[1] * dir[1] + dir[2] * dir[2]).sqrt();
        if len == 0.0 {
            return 0.0;
        }
        let mut tmin = 0.0f64;
        let mut tmax = 1.0f64;
        for k in 0..3 {
            if dir[k].abs() < 1e-12 {
                if src[k] < lo[k] || src[k] > hi[k] {
                    return 0.0;
                }
            } else {
                let inv = 1.0 / dir[k];
                let t0 = (lo[k] - src[k]) * inv;
                let t1 = (hi[k] - src[k]) * inv;
                let (t0, t1) = if t0 <= t1 { (t0, t1) } else { (t1, t0) };
                tmin = tmin.max(t0);
                tmax = tmax.min(t1);
            }
        }
        if tmin >= tmax {
            return 0.0;
        }
        let eps = 1e-9;
        let entry = [
            src[0] + (tmin + eps) * dir[0],
            src[1] + (tmin + eps) * dir[1],
            src[2] + (tmin + eps) * dir[2],
        ];
        let mut ix = [0isize; 3];
        for k in 0..3 {
            let f = ((entry[k] - lo[k]) / dvox[k]).floor();
            ix[k] = (f as isize).clamp(0, n[k] as isize - 1);
        }
        let mut t_next = [f64::INFINITY; 3];
        let mut dt = [f64::INFINITY; 3];
        let mut step = [0isize; 3];
        for k in 0..3 {
            if dir[k] > 1e-12 {
                step[k] = 1;
                let boundary = lo[k] + (ix[k] + 1) as f64 * dvox[k];
                t_next[k] = (boundary - src[k]) / dir[k];
                dt[k] = dvox[k] / dir[k];
            } else if dir[k] < -1e-12 {
                step[k] = -1;
                let boundary = lo[k] + ix[k] as f64 * dvox[k];
                t_next[k] = (boundary - src[k]) / dir[k];
                dt[k] = -dvox[k] / dir[k];
            }
        }
        let nx = n[0] as isize;
        let ny = n[1] as isize;
        let nz = n[2] as isize;
        let mut t = tmin;
        let mut acc = 0.0f64;
        loop {
            let (axis, tn) = {
                let mut axis = 0;
                let mut tn = t_next[0];
                if t_next[1] < tn {
                    axis = 1;
                    tn = t_next[1];
                }
                if t_next[2] < tn {
                    axis = 2;
                    tn = t_next[2];
                }
                (axis, tn)
            };
            let t_end = tn.min(tmax);
            if t_end > t {
                let idx = ((ix[2] * ny + ix[1]) * nx + ix[0]) as usize;
                acc += (t_end - t) * len * data[idx] as f64;
                t = t_end;
            }
            if tn >= tmax {
                break;
            }
            ix[axis] += step[axis];
            if ix[axis] < 0 || ix[axis] >= [nx, ny, nz][axis] {
                break;
            }
            t_next[axis] += dt[axis];
        }
        acc as f32
    }

    /// Full reference projector: per-pixel `det_pixel` addressing over the
    /// f64 tracer, single-threaded.
    pub fn project_ref(g: &Geometry, vol: &Volume) -> ProjectionSet {
        let nu = g.n_det[0];
        let nv = g.n_det[1];
        let mut out = ProjectionSet::zeros(nu, nv, g.n_angles());
        let (lo, hi) = g.volume_bbox();
        let n = [vol.nx, vol.ny, vol.nz];
        for a in 0..g.n_angles() {
            let frame = g.frame(a);
            for iv in 0..nv {
                for iu in 0..nu {
                    let pix = g.det_pixel(&frame, iu, iv);
                    *out.at_mut(iu, iv, a) =
                        raytrace_ref(&frame.src, &pix, &lo, &hi, &g.d_vox, &n, &vol.data);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phantom;

    #[test]
    fn ray_through_uniform_cube_axis_aligned() {
        // 8³ volume of ones, 1mm voxels: a straight axis-aligned ray
        // through the middle integrates to exactly 8.
        let n = [8usize, 8, 8];
        let lo = [-4.0, -4.0, -4.0];
        let hi = [4.0, 4.0, 4.0];
        let dv = [1.0, 1.0, 1.0];
        let data = vec![1.0f32; 512];
        let v = raytrace(&[-100.0, 0.5, 0.5], &[100.0, 0.5, 0.5], &lo, &hi, &dv, &n, &data);
        assert!((v - 8.0).abs() < 1e-4, "got {v}");
    }

    #[test]
    fn ray_diagonal_through_cube() {
        // Corner-to-corner diagonal of a unit-density 8³ cube has length
        // 8·√3.
        let n = [8usize, 8, 8];
        let lo = [-4.0, -4.0, -4.0];
        let hi = [4.0, 4.0, 4.0];
        let dv = [1.0, 1.0, 1.0];
        let data = vec![1.0f32; 512];
        let v = raytrace(&[-40.0, -40.0, -40.0], &[40.0, 40.0, 40.0], &lo, &hi, &dv, &n, &data);
        let expect = 8.0 * (3.0f64).sqrt();
        assert!((v as f64 - expect).abs() < 1e-3, "got {v}, want {expect}");
    }

    #[test]
    fn ray_missing_volume_is_zero() {
        let n = [8usize, 8, 8];
        let lo = [-4.0, -4.0, -4.0];
        let hi = [4.0, 4.0, 4.0];
        let dv = [1.0, 1.0, 1.0];
        let data = vec![1.0f32; 512];
        let v = raytrace(&[-100.0, 50.0, 0.0], &[100.0, 50.0, 0.0], &lo, &hi, &dv, &n, &data);
        assert_eq!(v, 0.0);
    }

    #[test]
    fn golden_parity_vs_reference() {
        // The optimized traversal (affine addressing, f32 accumulation,
        // stride-walked index) against the pre-refactor f64 oracle.
        let n = 24;
        let g = Geometry::cone_beam(n, 8);
        let v = phantom::shepp_logan(n);
        let opt = project(&g, &v, 2);
        let oracle = reference::project_ref(&g, &v);
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (i, (a, b)) in oracle.data.iter().zip(&opt.data).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * (1.0 + a.abs()),
                "pixel {i}: oracle {a} vs optimized {b}"
            );
            num += ((a - b) as f64).powi(2);
            den += (*a as f64).powi(2);
        }
        let rel = (num / den.max(1e-12)).sqrt();
        assert!(rel < 1e-5, "relative L2 deviation from oracle: {rel:.3e}");
    }

    #[test]
    fn golden_parity_with_detector_offset() {
        // Panel-shifted scans exercise the affine origin path.
        let n = 16;
        let mut g = Geometry::cone_beam(n, 6);
        g.offset_det = [2.5, -1.5];
        let v = phantom::shepp_logan(n);
        let opt = project(&g, &v, 2);
        let oracle = reference::project_ref(&g, &v);
        for (i, (a, b)) in oracle.data.iter().zip(&opt.data).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * (1.0 + a.abs()),
                "pixel {i}: oracle {a} vs optimized {b}"
            );
        }
    }

    #[test]
    fn projection_of_centered_cube_hits_detector_center() {
        let g = Geometry::cone_beam(16, 4);
        let v = phantom::cube(16, 0.4, 1.0);
        let p = project(&g, &v, 1);
        // central detector pixel must see the cube at every angle
        for a in 0..4 {
            let c = p.at(g.n_det[0] / 2, g.n_det[1] / 2, a);
            assert!(c > 3.0, "angle {a}: centre value {c}");
            // corner pixel sees air
            assert_eq!(p.at(0, 0, a), 0.0, "angle {a} corner");
        }
    }

    #[test]
    fn rotation_invariance_of_symmetric_phantom() {
        // A rotationally symmetric phantom projects identically at all
        // angles (up to discretization noise).
        let n = 24;
        let c = (n as f64 - 1.0) / 2.0;
        let v = crate::volume::Volume::from_fn(n, n, n, |x, y, z| {
            let dx = x as f64 - c;
            let dy = y as f64 - c;
            let dz = z as f64 - c;
            if (dx * dx + dy * dy + dz * dz).sqrt() < 8.0 {
                1.0
            } else {
                0.0
            }
        });
        let g = Geometry::cone_beam(n, 8);
        let p = project(&g, &v, 2);
        let e0: f64 = (0..g.n_det[0] * g.n_det[1])
            .map(|i| p.data[i] as f64 * p.data[i] as f64)
            .sum::<f64>()
            .sqrt();
        for a in 1..8 {
            let ea: f64 = p
                .chunk(a, a + 1)
                .iter()
                .map(|x| *x as f64 * *x as f64)
                .sum::<f64>()
                .sqrt();
            assert!(
                ((ea - e0) / e0).abs() < 0.02,
                "angle {a}: energy {ea} vs {e0}"
            );
        }
    }

    #[test]
    fn slab_projections_sum_to_full_projection() {
        // THE core property the paper relies on: forward projections of
        // z-slabs, accumulated, equal the projection of the whole volume.
        let n = 20;
        let g = Geometry::cone_beam(n, 6);
        let v = phantom::shepp_logan(n);
        let full = project(&g, &v, 2);

        let mut acc = ProjectionSet::zeros_like(&g);
        for (z0, z1) in [(0, 7), (7, 14), (14, 20)] {
            let slab_geo = g.slab_geometry(z0, z1);
            let slab = v.extract_slab(z0, z1);
            let part = project(&slab_geo, &slab, 2);
            acc.accumulate(&part);
        }
        for (i, (a, b)) in full.data.iter().zip(&acc.data).enumerate() {
            assert!(
                (a - b).abs() <= 1e-3 * (1.0 + a.abs()),
                "pixel {i}: full {a} vs acc {b}"
            );
        }
    }

    #[test]
    fn threaded_equals_single_threaded() {
        let g = Geometry::cone_beam(16, 5);
        let v = phantom::shepp_logan(16);
        let p1 = project(&g, &v, 1);
        let p4 = project(&g, &v, 4);
        assert_eq!(p1.data, p4.data);
    }

    #[test]
    fn view_projection_bit_identical_to_owned_slab() {
        // The zero-copy staging path: projecting a borrowed slab view must
        // equal projecting the extracted (copied) slab, bit for bit.
        let n = 16;
        let g = Geometry::cone_beam(n, 5);
        let v = phantom::shepp_logan(n);
        let (z0, z1) = (4, 11);
        let gs = g.slab_geometry(z0, z1);
        let owned = project(&gs, &v.extract_slab(z0, z1), 2);
        let mut via_view = vec![0.0f32; owned.data.len()];
        project_into(&gs, &v.slab_view(z0, z1), &mut via_view, 2);
        assert_eq!(owned.data, via_view);
    }
}
