//! CGLS — conjugate gradient on the normal equations `AᵀA x = Aᵀb`
//! (the paper's Fig. 10 algorithm; also the §4 timing anchor:
//! 512³ × 15 iterations in 61 s on one GTX 1080 Ti).
//!
//! CGLS "fundamentally requires a matched backprojection" (paper §3.1),
//! so the context is forced to pseudo-matched weights.

use crate::coordinator::checkpoint::{self, CheckpointState};
use crate::coordinator::{MultiGpu, ReconSession};
use crate::geometry::Geometry;
use crate::kernels::scratch;
use crate::volume::{ProjectionSet, TrackedProjections, TrackedVolume, Volume};

use super::common::{projector_ctx, DivergenceGuard, ReconOpts, ReconResult};
use super::ossart::matched_ctx;
use crate::coordinator::DegradeEvent;

/// CGLS reconstruction from zero initial guess.
///
/// CGLS updates its residual incrementally (`r ← r − αq`), so unlike the
/// Landweber family there is no constant projection input to keep
/// device-resident — the session still skips nothing stale (epochs bump
/// on every in-place update) and still reuses each forward output's
/// device-resident chunks when `Aᵀ` consumes them unmodified.
pub fn cgls(
    ctx: &MultiGpu,
    g: &Geometry,
    proj: &ProjectionSet,
    opts: &ReconOpts,
) -> anyhow::Result<ReconResult> {
    let ctx = matched_ctx(&projector_ctx(ctx, opts));
    let mut sess = ReconSession::new(&ctx, g)?;

    let (mut ck, resumed) = checkpoint::setup(&opts.checkpoint, "cgls")?;
    let mut residuals = Vec::with_capacity(opts.iterations);
    let mut start = 0;
    let (mut x, mut r, mut s, mut p, mut gamma);
    if let Some(mut st) = resumed {
        // restore the whole CG recurrence: iterate x, direction p,
        // running residual r and γ = ‖Aᵀr‖². `s` is overwritten before
        // its first read, so a zero buffer of the right shape serves.
        start = st.iteration.min(opts.iterations);
        residuals = st.residuals.clone();
        x = st.volume("x")?;
        r = TrackedProjections::new(st.projections("r")?);
        p = TrackedVolume::new(st.volume("p")?);
        gamma = st.scalar("gamma")?;
        s = Volume::zeros_like(g);
    } else {
        x = Volume::zeros_like(g);
        // r = b − Ax = b;  p = s = Aᵀr
        r = TrackedProjections::new(proj.clone());
        s = sess.backward(&r)?;
        p = TrackedVolume::new(s.clone());
        gamma = s.dot(&s);
    }
    let mut guard = DivergenceGuard::new("cgls", opts);
    guard.seed(&residuals);
    for it in start..opts.iterations {
        ctx.set_fault_iteration(it);
        if gamma <= 0.0 {
            break;
        }
        // q = Ap
        let q = sess.forward(&p)?;
        let qq = q.get().dot(q.get());
        if qq <= 0.0 {
            sess.recycle_projections(q);
            break;
        }
        let alpha = (gamma / qq) as f32;
        x.add_scaled(p.get(), alpha);
        r.write().add_scaled(q.get(), -alpha);
        sess.recycle_projections(q);
        residuals.push(r.get().norm2());
        // CG has no step size to shrink: residual growth (a broken
        // recurrence, e.g. accumulated rounding) restarts the direction
        // (β = 0, i.e. p = steepest descent) instead
        let restart = guard.check(it, *residuals.last().unwrap())?.is_some();
        if restart {
            ctx.degrade.record(DegradeEvent::StepBackoff { algorithm: "cgls", iteration: it });
        }
        if opts.verbose {
            crate::log_info!("cgls iter {it}: residual {:.4e}", r.get().norm2());
        }
        // s = Aᵀr (previous direction buffer goes back to the arena)
        scratch::recycle_volume(std::mem::replace(&mut s, sess.backward(&r)?));
        let gamma_new = s.dot(&s);
        let beta = if restart { 0.0 } else { (gamma_new / gamma) as f32 };
        gamma = gamma_new;
        // p = s + β p
        for (pv, sv) in p.write().data.iter_mut().zip(&s.data) {
            *pv = sv + beta * *pv;
        }
        if let Some(ck) = ck.as_mut() {
            if ck.due(it + 1) {
                ck.save(&CheckpointState {
                    iteration: it + 1,
                    residuals: residuals.clone(),
                    scalars: vec![("gamma".into(), gamma)],
                    volumes: vec![("x".into(), x.clone()), ("p".into(), p.get().clone())],
                    projections: vec![("r".into(), r.get().clone())],
                })?;
            }
        }
    }
    if opts.nonneg {
        x.clamp_min(0.0);
    }
    sess.recycle_projections(r);
    scratch::recycle_volume(s);
    scratch::recycle_volume(p.into_inner());

    Ok(ReconResult {
        volume: x,
        residuals,
        sim_time_s: sess.sim_time_s,
        peak_device_bytes: sess.peak_device_bytes,
        backoffs: guard.backoffs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ExecMode;
    use crate::metrics;
    use crate::phantom;

    #[test]
    fn cgls_residual_is_monotone_nonincreasing() {
        let n = 16;
        let g = Geometry::cone_beam(n, 24);
        let truth = phantom::shepp_logan(n);
        let ctx = MultiGpu::gtx1080ti(1);
        let (p, _) = ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap();
        let opts = ReconOpts { iterations: 8, nonneg: false, ..Default::default() };
        let r = cgls(&ctx, &g, &p.unwrap(), &opts).unwrap();
        for w in r.residuals.windows(2) {
            assert!(w[1] <= w[0] * 1.001, "CGLS residual must not increase: {:?}", r.residuals);
        }
    }

    #[test]
    fn cgls_outperforms_few_iteration_sirt() {
        // CGLS converges much faster per iteration than SIRT.
        let n = 16;
        let g = Geometry::cone_beam(n, 24);
        let truth = phantom::shepp_logan(n);
        let ctx = MultiGpu::gtx1080ti(1);
        let (p, _) = ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap();
        let p = p.unwrap();
        let opts = ReconOpts { iterations: 6, nonneg: true, ..Default::default() };
        let r_cgls = cgls(&ctx, &g, &p, &opts).unwrap();
        let r_sirt = super::super::ossart::sirt(&ctx, &g, &p, &opts).unwrap();
        let e_cgls = metrics::rmse(&truth, &r_cgls.volume);
        let e_sirt = metrics::rmse(&truth, &r_sirt.volume);
        assert!(e_cgls < e_sirt, "cgls {e_cgls} vs sirt {e_sirt}");
    }

    #[test]
    fn cgls_robust_to_angular_undersampling_vs_fdk() {
        // The Fig. 10 comparison: with ⅓ of the angles, CGLS beats FDK.
        let n = 20;
        let g = Geometry::cone_beam(n, 20);
        let truth = phantom::shepp_logan(n);
        let ctx = MultiGpu::gtx1080ti(1);
        let (p, _) = ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap();
        let p = p.unwrap();
        let r_cgls = cgls(
            &ctx,
            &g,
            &p,
            &ReconOpts { iterations: 10, ..Default::default() },
        )
        .unwrap();
        let r_fdk =
            super::super::fdk::fdk(&ctx, &g, &p, crate::kernels::filtering::Window::RamLak)
                .unwrap();
        let e_cgls = metrics::rmse(&truth, &r_cgls.volume);
        let e_fdk = metrics::rmse(&truth, &r_fdk.volume);
        assert!(e_cgls < e_fdk, "cgls {e_cgls} vs fdk {e_fdk}");
    }

    #[test]
    fn fault_cgls_resumes_from_checkpoint_bit_identically() {
        // CGLS carries the richest recurrence (x, p, r, γ): the resumed
        // run must replay it exactly to stay bit-identical.
        use crate::coordinator::CheckpointConfig;
        let n = 14;
        let g = Geometry::cone_beam(n, 12);
        let truth = phantom::shepp_logan(n);
        let ctx = MultiGpu::gtx1080ti(2);
        let (p, _) = ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap();
        let p = p.unwrap();
        let dir = std::env::temp_dir()
            .join("tigre_algo_ckpt")
            .join(format!("cgls_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let clean =
            cgls(&ctx, &g, &p, &ReconOpts { iterations: 3, ..Default::default() }).unwrap();
        let ck = Some(CheckpointConfig::new(&dir, 1));
        let _partial = cgls(
            &ctx,
            &g,
            &p,
            &ReconOpts { iterations: 2, checkpoint: ck.clone(), ..Default::default() },
        )
        .unwrap();
        let resumed = cgls(
            &ctx,
            &g,
            &p,
            &ReconOpts { iterations: 3, checkpoint: ck, ..Default::default() },
        )
        .unwrap();
        assert_eq!(resumed.volume.data, clean.volume.data);
        assert_eq!(resumed.residuals, clean.residuals);
    }

    #[test]
    fn cgls_works_with_split_devices() {
        // Same reconstruction quality when devices are tiny and the
        // volume must split — the paper's end-to-end claim.
        let n = 16;
        let g = Geometry::cone_beam(n, 16);
        let truth = phantom::shepp_logan(n);
        let big = MultiGpu::gtx1080ti(1);
        let (p, _) = big.forward(&g, Some(&truth), ExecMode::Full).unwrap();
        let p = p.unwrap();
        let opts = ReconOpts { iterations: 5, nonneg: false, ..Default::default() };
        let r_big = cgls(&big, &g, &p, &opts).unwrap();
        let plane = (n * n * 4) as u64;
        let tiny = MultiGpu::gtx1080ti(2).with_device_mem(6 * plane + 3 * 16 * g.single_proj_bytes());
        let r_tiny = cgls(&tiny, &g, &p, &opts).unwrap();
        let rel = metrics::rel_l2(&r_big.volume, &r_tiny.volume);
        assert!(rel < 1e-3, "split CGLS deviates: {rel}");
    }
}
