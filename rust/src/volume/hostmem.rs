//! Host (CPU) memory state tracking: pageable vs page-locked (pinned).
//!
//! The paper's strategy depends on *when* host buffers are pinned:
//! pinned memory transfers ~3× faster over PCIe-Gen3 (≈12 vs ≈4 GB/s) and
//! enables asynchronous copies, but the pin operation itself is expensive
//! and forces physical allocation. This registry records allocation and
//! pin/unpin events so the cost model can charge them and Fig. 9 can bin
//! them ("memory page-locking and unlocking").

use std::collections::BTreeMap;

/// Pageable vs pinned state of a host allocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemState {
    /// OS-managed memory: synchronous transfers at pageable bandwidth.
    Pageable,
    /// Page-locked memory: async transfers at pinned bandwidth.
    Pinned,
}

/// A pin or unpin event, for cost accounting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PinEvent {
    pub bytes: u64,
    pub pin: bool, // true = pin, false = unpin
}

/// Registry of named host allocations and their pin states.
#[derive(Debug, Default)]
pub struct HostMemRegistry {
    allocs: BTreeMap<String, (u64, MemState)>,
    events: Vec<PinEvent>,
}

impl HostMemRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an allocation (host buffers start pageable, as in
    /// MATLAB/Python-managed memory — paper §2).
    pub fn alloc(&mut self, name: &str, bytes: u64) {
        self.allocs.insert(name.to_string(), (bytes, MemState::Pageable));
    }

    pub fn free(&mut self, name: &str) {
        self.allocs.remove(name);
    }

    pub fn state(&self, name: &str) -> Option<MemState> {
        self.allocs.get(name).map(|(_, s)| *s)
    }

    pub fn bytes(&self, name: &str) -> Option<u64> {
        self.allocs.get(name).map(|(b, _)| *b)
    }

    /// Page-lock an allocation. Idempotent; returns the bytes newly pinned
    /// (0 if it was already pinned).
    pub fn pin(&mut self, name: &str) -> u64 {
        match self.allocs.get_mut(name) {
            Some((bytes, state)) if *state == MemState::Pageable => {
                *state = MemState::Pinned;
                let b = *bytes;
                self.events.push(PinEvent { bytes: b, pin: true });
                b
            }
            _ => 0,
        }
    }

    /// Unpin an allocation. Idempotent; returns bytes newly unpinned.
    pub fn unpin(&mut self, name: &str) -> u64 {
        match self.allocs.get_mut(name) {
            Some((bytes, state)) if *state == MemState::Pinned => {
                *state = MemState::Pageable;
                let b = *bytes;
                self.events.push(PinEvent { bytes: b, pin: false });
                b
            }
            _ => 0,
        }
    }

    /// Total currently-pinned bytes.
    pub fn pinned_bytes(&self) -> u64 {
        self.allocs
            .values()
            .filter(|(_, s)| *s == MemState::Pinned)
            .map(|(b, _)| *b)
            .sum()
    }

    /// Total registered bytes.
    pub fn total_bytes(&self) -> u64 {
        self.allocs.values().map(|(b, _)| *b).sum()
    }

    /// All pin/unpin events since construction.
    pub fn events(&self) -> &[PinEvent] {
        &self.events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_starts_pageable() {
        let mut r = HostMemRegistry::new();
        r.alloc("image", 1024);
        assert_eq!(r.state("image"), Some(MemState::Pageable));
        assert_eq!(r.bytes("image"), Some(1024));
    }

    #[test]
    fn pin_unpin_events_and_idempotence() {
        let mut r = HostMemRegistry::new();
        r.alloc("image", 100);
        assert_eq!(r.pin("image"), 100);
        assert_eq!(r.pin("image"), 0); // idempotent
        assert_eq!(r.pinned_bytes(), 100);
        assert_eq!(r.unpin("image"), 100);
        assert_eq!(r.unpin("image"), 0);
        assert_eq!(r.events().len(), 2);
        assert!(r.events()[0].pin && !r.events()[1].pin);
    }

    #[test]
    fn unknown_names_are_noops() {
        let mut r = HostMemRegistry::new();
        assert_eq!(r.pin("nope"), 0);
        assert_eq!(r.state("nope"), None);
    }

    #[test]
    fn totals() {
        let mut r = HostMemRegistry::new();
        r.alloc("a", 10);
        r.alloc("b", 20);
        r.pin("b");
        assert_eq!(r.total_bytes(), 30);
        assert_eq!(r.pinned_bytes(), 20);
        r.free("b");
        assert_eq!(r.total_bytes(), 10);
        assert_eq!(r.pinned_bytes(), 0);
    }
}
