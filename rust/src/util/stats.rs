//! Benchmark statistics + micro-bench harness (criterion is unavailable).
//!
//! [`Samples`] accumulates raw observations and reports robust summary
//! statistics; [`bench`] runs a closure with warmup and a time budget and
//! returns the samples. All benches under `rust/benches/` use this.

// The whole point of this module is measuring wall-clock time; nothing
// here feeds the DES or the planner (see rust/clippy.toml).
#![allow(clippy::disallowed_methods)]

use std::time::{Duration, Instant};

/// A set of numeric observations (seconds, bytes, ratios, ...).
#[derive(Clone, Debug, Default)]
pub struct Samples {
    values: Vec<f64>,
}

impl Samples {
    /// Empty sample set.
    pub fn new() -> Self {
        Self { values: Vec::new() }
    }

    /// Wrap an existing vector of observations.
    pub fn from(values: Vec<f64>) -> Self {
        Self { values }
    }

    /// Record one observation.
    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    /// Number of observations recorded so far.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True when no observations have been recorded.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Arithmetic mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    /// Sample standard deviation (Bessel-corrected; 0 for fewer than 2 samples).
    pub fn stddev(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// Smallest observation (+∞ when empty).
    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Largest observation (−∞ when empty).
    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, p in [0,100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut v = self.values.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (v.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            v[lo]
        } else {
            v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
        }
    }

    /// Median (50th percentile).
    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Result of a [`bench`] run.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Bench name as passed to [`bench`].
    pub name: String,
    /// Per-iteration wall-clock durations in seconds.
    pub samples: Samples,
}

impl BenchResult {
    /// One-line criterion-style summary, durations in adaptive units.
    pub fn summary(&self) -> String {
        format!(
            "{:<40} time: [{} {} {}] ±{} ({} samples)",
            self.name,
            fmt_duration(self.samples.min()),
            fmt_duration(self.samples.median()),
            fmt_duration(self.samples.max()),
            fmt_duration(self.samples.stddev()),
            self.samples.len(),
        )
    }
}

/// Format seconds with adaptive unit (ns/µs/ms/s).
pub fn fmt_duration(secs: f64) -> String {
    if !secs.is_finite() {
        return "n/a".to_string();
    }
    let abs = secs.abs();
    if abs >= 1.0 {
        format!("{secs:.3}s")
    } else if abs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if abs >= 1e-6 {
        format!("{:.3}µs", secs * 1e6)
    } else {
        format!("{:.1}ns", secs * 1e9)
    }
}

/// Run `f` repeatedly: `warmup` unmeasured iterations, then measured
/// iterations until both `min_iters` and `budget` are satisfied (at least
/// one measured iteration always runs).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_iters: usize, budget: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Samples::new();
    let start = Instant::now();
    loop {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() >= min_iters && start.elapsed() >= budget {
            break;
        }
        // hard cap to keep bench suites bounded
        if samples.len() >= 10_000 {
            break;
        }
    }
    BenchResult { name: name.to_string(), samples }
}

/// Fixed-width table printer for bench output (aligned, markdown-ish).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append one row; panics if the cell count differs from the header count.
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render the table as right-aligned markdown-style text.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:>w$} |", w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Samples::from(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.stddev() - 1.5811388).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let s = Samples::from(vec![0.0, 10.0]);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 10.0);
    }

    #[test]
    fn bench_runs_minimum_iterations() {
        let mut count = 0;
        let r = bench("t", 1, 5, Duration::from_millis(0), || count += 1);
        assert!(r.samples.len() >= 5);
        assert_eq!(count, r.samples.len() + 1); // +1 warmup
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(2.0), "2.000s");
        assert_eq!(fmt_duration(0.002), "2.000ms");
        assert_eq!(fmt_duration(2e-6), "2.000µs");
        assert_eq!(fmt_duration(2e-9), "2.0ns");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["N", "time"]);
        t.row(vec!["128".into(), "1.2ms".into()]);
        t.row(vec!["2048".into(), "900ms".into()]);
        let out = t.render();
        assert!(out.contains("| 2048 |"));
        assert!(out.lines().count() == 4);
    }
}
