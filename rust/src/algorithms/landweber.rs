//! Landweber iteration and MLEM — two further members of TIGRE's
//! algorithm family, rounding out the suite on the same multi-GPU
//! operator substrate.
//!
//! * Landweber: `x ← x + λ·Aᵀ(b − Ax)` — plain gradient descent on the
//!   least-squares objective, step bounded by 1/‖AᵀA‖.
//! * MLEM: `x ← x ∘ Aᵀ(b ⊘ Ax) ⊘ Aᵀ1` — the multiplicative EM update for
//!   Poisson data (requires non-negative projections).

use crate::coordinator::checkpoint::{self, CheckpointState};
use crate::coordinator::{MultiGpu, ReconSession};
use crate::geometry::Geometry;
use crate::kernels::scratch;
use crate::volume::{ProjectionSet, TrackedProjections, TrackedVolume, Volume};

use super::common::{projector_ctx, DivergenceGuard, ReconOpts, ReconResult};
use super::ossart::matched_ctx;
use crate::coordinator::DegradeEvent;

/// Estimate `‖AᵀA‖` by power iteration through a session (shared by
/// Landweber and FISTA). Temporaries go back to the `kernels::scratch`
/// arena; the session's residency cache sees each round's fresh epochs.
pub(crate) fn power_iteration_norm(
    sess: &mut ReconSession,
    g: &Geometry,
    seed: u64,
) -> anyhow::Result<f64> {
    let mut v =
        TrackedVolume::new(crate::phantom::random(g.n_vox[0], g.n_vox[1], g.n_vox[2], seed));
    let mut lmax = 1.0f64;
    for _ in 0..4 {
        let av = sess.forward(&v)?;
        let atav = sess.backward(&av)?;
        sess.recycle_projections(av);
        lmax = atav.norm2() / v.get().norm2().max(1e-30);
        let n = atav.norm2().max(1e-30) as f32;
        scratch::recycle_volume(v.replace(atav));
        v.write().scale(1.0 / n);
    }
    scratch::recycle_volume(v.into_inner());
    Ok(lmax)
}

/// Landweber iteration; `opts.lambda` scales the power-iteration step.
pub fn landweber(
    ctx: &MultiGpu,
    g: &Geometry,
    proj: &ProjectionSet,
    opts: &ReconOpts,
) -> anyhow::Result<ReconResult> {
    let ctx = matched_ctx(&projector_ctx(ctx, opts));
    let mut sess = ReconSession::new(&ctx, g)?;

    // step = λ / ‖AᵀA‖ (power iteration)
    let lmax = power_iteration_norm(&mut sess, g, 17)?;
    let mut step = opts.lambda / lmax.max(1e-30) as f32;

    // the measured projections are constant across iterations — exactly
    // what the session keeps device-resident from the first iteration on
    let b = TrackedProjections::new(proj.clone());
    let mut x = TrackedVolume::new(Volume::zeros_like(g));
    let mut residuals = Vec::with_capacity(opts.iterations);
    let (mut ck, resumed) = checkpoint::setup(&opts.checkpoint, "landweber")?;
    let mut start = 0;
    if let Some(mut st) = resumed {
        start = st.iteration.min(opts.iterations);
        residuals = st.residuals.clone();
        scratch::recycle_volume(x.replace(st.volume("x")?));
    }
    let mut guard = DivergenceGuard::new("landweber", opts);
    guard.seed(&residuals);
    for it in start..opts.iterations {
        ctx.set_fault_iteration(it);
        let ax = sess.forward(&x)?;
        // upd = Aᵀ(b − Ax), with the residual formed on-device against
        // the resident b (see ReconSession::backward_residual)
        let (upd, res_norm) = sess.backward_residual(&b, &ax)?;
        sess.recycle_projections(ax);
        residuals.push(res_norm);
        // residual growth → shrink the step before applying this update
        if let Some(f) = guard.check(it, res_norm)? {
            step *= f;
            ctx.degrade
                .record(DegradeEvent::StepBackoff { algorithm: "landweber", iteration: it });
        }
        x.write().add_scaled(&upd, step);
        scratch::recycle_volume(upd);
        if opts.nonneg {
            x.write().clamp_min(0.0);
        }
        if opts.verbose {
            crate::log_info!("landweber iter {it}: residual {:.4e}", residuals.last().unwrap());
        }
        if let Some(ck) = ck.as_mut() {
            if ck.due(it + 1) {
                ck.save(&CheckpointState {
                    iteration: it + 1,
                    residuals: residuals.clone(),
                    volumes: vec![("x".into(), x.get().clone())],
                    ..Default::default()
                })?;
            }
        }
    }
    sess.recycle_projections(b);
    Ok(ReconResult {
        volume: x.into_inner(),
        residuals,
        sim_time_s: sess.sim_time_s,
        peak_device_bytes: sess.peak_device_bytes,
        backoffs: guard.backoffs,
    })
}

/// MLEM for non-negative (count-derived) projections.
pub fn mlem(
    ctx: &MultiGpu,
    g: &Geometry,
    proj: &ProjectionSet,
    opts: &ReconOpts,
) -> anyhow::Result<ReconResult> {
    anyhow::ensure!(
        proj.data.iter().all(|&v| v >= 0.0),
        "MLEM requires non-negative projections"
    );
    let ctx = matched_ctx(&projector_ctx(ctx, opts));
    let mut sess = ReconSession::new(&ctx, g)?;

    // sensitivity image Aᵀ1
    let ones = TrackedProjections::new({
        let mut p = ProjectionSet::zeros_like(g);
        for v in &mut p.data {
            *v = 1.0;
        }
        p
    });
    let sens = sess.backward(&ones)?;
    sess.recycle_projections(ones);

    // start from a uniform positive image
    let mut x = TrackedVolume::new({
        let mut v = Volume::zeros_like(g);
        for xv in &mut v.data {
            *xv = 1.0;
        }
        v
    });
    let mut residuals = Vec::with_capacity(opts.iterations);
    let (mut ck, resumed) = checkpoint::setup(&opts.checkpoint, "mlem")?;
    let mut start = 0;
    if let Some(mut st) = resumed {
        start = st.iteration.min(opts.iterations);
        residuals = st.residuals.clone();
        scratch::recycle_volume(x.replace(st.volume("x")?));
    }
    let mut guard = DivergenceGuard::new("mlem", opts);
    guard.seed(&residuals);
    // divergence backoff for the multiplicative update: blend the EM
    // correction toward the identity (damp = 1 is the exact EM step)
    let mut damp: f32 = 1.0;
    for it in start..opts.iterations {
        ctx.set_fault_iteration(it);
        // reuse Ax in place as the ratio buffer b ⊘ Ax (the in-place
        // write bumps the epoch, so the session restages it — correctly)
        let mut ratio = sess.forward(&x)?;
        let mut res2 = 0.0f64;
        for (av, bv) in ratio.write().data.iter_mut().zip(&proj.data) {
            let d = (bv - *av) as f64;
            res2 += d * d;
            *av = if *av > 1e-8 { bv / *av } else { 0.0 };
        }
        residuals.push(res2.sqrt());
        if let Some(f) = guard.check(it, res2.sqrt())? {
            damp *= f;
            ctx.degrade.record(DegradeEvent::StepBackoff { algorithm: "mlem", iteration: it });
        }
        let corr = sess.backward(&ratio)?;
        sess.recycle_projections(ratio);
        if damp < 1.0 {
            for ((xv, cv), sv) in x.write().data.iter_mut().zip(&corr.data).zip(&sens.data) {
                let em = if *sv > 1e-8 { cv / sv } else { 0.0 };
                *xv *= (1.0 - damp) + damp * em;
            }
        } else {
            for ((xv, cv), sv) in x.write().data.iter_mut().zip(&corr.data).zip(&sens.data) {
                *xv = if *sv > 1e-8 { *xv * cv / sv } else { 0.0 };
            }
        }
        scratch::recycle_volume(corr);
        if opts.verbose {
            crate::log_info!("mlem iter {it}: residual {:.4e}", residuals.last().unwrap());
        }
        if let Some(ck) = ck.as_mut() {
            if ck.due(it + 1) {
                ck.save(&CheckpointState {
                    iteration: it + 1,
                    residuals: residuals.clone(),
                    volumes: vec![("x".into(), x.get().clone())],
                    ..Default::default()
                })?;
            }
        }
    }
    scratch::recycle_volume(sens);
    Ok(ReconResult {
        volume: x.into_inner(),
        residuals,
        sim_time_s: sess.sim_time_s,
        peak_device_bytes: sess.peak_device_bytes,
        backoffs: guard.backoffs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ExecMode;
    use crate::metrics;
    use crate::phantom;

    fn setup(n: usize, a: usize) -> (Geometry, Volume, ProjectionSet, MultiGpu) {
        let g = Geometry::cone_beam(n, a);
        let truth = phantom::cube(n, 0.5, 1.0);
        let ctx = MultiGpu::gtx1080ti(1);
        let (p, _) = ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap();
        (g, truth, p.unwrap(), ctx)
    }

    #[test]
    fn landweber_residual_decreases() {
        let (g, truth, p, ctx) = setup(14, 12);
        let opts = ReconOpts { iterations: 15, lambda: 1.0, ..Default::default() };
        let r = landweber(&ctx, &g, &p, &opts).unwrap();
        assert!(r.residuals.last().unwrap() < &(r.residuals[0] * 0.7), "{:?}", r.residuals);
        assert!(metrics::correlation(&truth, &r.volume) > 0.8);
    }

    #[test]
    fn mlem_converges_and_stays_nonnegative() {
        let (g, truth, p, ctx) = setup(14, 12);
        let opts = ReconOpts { iterations: 12, ..Default::default() };
        let r = mlem(&ctx, &g, &p, &opts).unwrap();
        assert!(r.volume.data.iter().all(|&v| v >= 0.0));
        assert!(metrics::correlation(&truth, &r.volume) > 0.8);
        assert!(r.residuals.last().unwrap() < &(r.residuals[0] * 0.7));
    }

    #[test]
    fn mlem_rejects_negative_projections() {
        let (g, _, mut p, ctx) = setup(10, 6);
        p.data[0] = -1.0;
        assert!(mlem(&ctx, &g, &p, &ReconOpts::default()).is_err());
    }

    // -- fault tolerance & checkpoint/resume (ISSUE 7) --------------------

    fn ckpt_dir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join("tigre_algo_ckpt")
            .join(format!("{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn fault_landweber_is_bit_identical_under_mid_run_faults() {
        use crate::coordinator::splitter::{image_split_mem, SplitConfig};
        use crate::simgpu::fault::{FaultPlan, FaultScope};
        // image-split regime so every device owns launch units
        let (g, _, p, _) = setup(14, 12);
        let mem = image_split_mem(&g, &SplitConfig::default());
        let opts = ReconOpts { iterations: 3, nonneg: false, ..Default::default() };
        let clean =
            landweber(&MultiGpu::gtx1080ti(2).with_device_mem(mem), &g, &p, &opts).unwrap();
        // a retried transient burst on device 0 plus a permanent loss of
        // device 1 at iteration 1: the remaining iterations run degraded
        // on the survivor, and every iterate must stay bit-identical
        let faulted_ctx = MultiGpu::gtx1080ti(2).with_device_mem(mem).with_fault_plan(
            FaultPlan::new().transient_launch_at(0, 0, 0, 2).device_loss_at(1, 0, 1),
        );
        let faulted = landweber(&faulted_ctx, &g, &p, &opts).unwrap();
        assert!(
            faulted_ctx.fault.as_ref().unwrap().is_lost(FaultScope::Real, 1),
            "the loss site must actually have fired"
        );
        assert_eq!(faulted.volume.data, clean.volume.data);
        assert_eq!(faulted.residuals, clean.residuals);
    }

    #[test]
    fn fault_landweber_resumes_from_checkpoint_bit_identically() {
        use crate::coordinator::CheckpointConfig;
        let (g, _, p, ctx) = setup(14, 10);
        let dir = ckpt_dir("landweber");
        let clean = landweber(
            &ctx,
            &g,
            &p,
            &ReconOpts { iterations: 3, ..Default::default() },
        )
        .unwrap();
        // the "killed" run: two iterations, checkpointed every iteration
        let ck = Some(CheckpointConfig::new(&dir, 1));
        let partial = landweber(
            &ctx,
            &g,
            &p,
            &ReconOpts { iterations: 2, checkpoint: ck.clone(), ..Default::default() },
        )
        .unwrap();
        assert_eq!(partial.residuals.len(), 2);
        // the resumed run restarts from the durable iterate and finishes
        let resumed = landweber(
            &ctx,
            &g,
            &p,
            &ReconOpts { iterations: 3, checkpoint: ck, ..Default::default() },
        )
        .unwrap();
        assert_eq!(resumed.volume.data, clean.volume.data);
        assert_eq!(resumed.residuals, clean.residuals);
    }

    #[test]
    fn fault_mlem_resumes_from_checkpoint_bit_identically() {
        use crate::coordinator::CheckpointConfig;
        let (g, _, p, ctx) = setup(14, 10);
        let dir = ckpt_dir("mlem");
        let clean =
            mlem(&ctx, &g, &p, &ReconOpts { iterations: 3, ..Default::default() }).unwrap();
        let ck = Some(CheckpointConfig::new(&dir, 1));
        let _partial = mlem(
            &ctx,
            &g,
            &p,
            &ReconOpts { iterations: 2, checkpoint: ck.clone(), ..Default::default() },
        )
        .unwrap();
        let resumed = mlem(
            &ctx,
            &g,
            &p,
            &ReconOpts { iterations: 3, checkpoint: ck, ..Default::default() },
        )
        .unwrap();
        assert_eq!(resumed.volume.data, clean.volume.data);
        assert_eq!(resumed.residuals, clean.residuals);
    }

    // -- numerical-health guards (ISSUE 8) --------------------------------

    #[test]
    fn degrade_landweber_backs_off_a_divergent_step_and_recovers() {
        // λ = 3.5 puts the step past the 2/‖AᵀA‖ stability bound: the
        // dominant mode amplifies ~2.5× per sweep, the divergence guard
        // fires, and one halving (λ → 1.75) lands back inside the bound
        let (g, _, p, ctx) = setup(14, 12);
        let opts = ReconOpts { iterations: 10, lambda: 3.5, nonneg: false, ..Default::default() };
        let r = landweber(&ctx, &g, &p, &opts).unwrap();
        assert!(r.backoffs >= 1, "guard must fire on a divergent step: {:?}", r.residuals);
        let peak = r.residuals.iter().cloned().fold(f64::MIN, f64::max);
        let last = *r.residuals.last().unwrap();
        assert!(
            last < peak,
            "after backoff the residual must come back down: {:?}",
            r.residuals
        );
        assert!(r.volume.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn degrade_landweber_exhausted_backoff_budget_is_a_typed_divergence_error() {
        let (g, _, p, ctx) = setup(14, 12);
        // no backoff budget: the first detected growth is terminal
        let opts = ReconOpts {
            iterations: 10,
            lambda: 3.5,
            nonneg: false,
            max_step_backoffs: 0,
            ..Default::default()
        };
        let err = landweber(&ctx, &g, &p, &opts).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("landweber diverged"), "{msg}");
        assert!(msg.contains("step-size backoffs"), "{msg}");
    }

    #[test]
    fn degrade_guarded_clean_run_is_bit_identical_to_seed_behaviour() {
        // the guard only reacts: on a converging run it never fires and
        // the iterates are exactly those of a guard-free configuration
        // (tolerance effectively disabled)
        let (g, _, p, ctx) = setup(14, 10);
        let base = ReconOpts { iterations: 4, lambda: 1.0, ..Default::default() };
        let loose = ReconOpts { divergence_tolerance: 1e12, ..base.clone() };
        let a = landweber(&ctx, &g, &p, &base).unwrap();
        let b = landweber(&ctx, &g, &p, &loose).unwrap();
        assert_eq!(a.backoffs, 0);
        assert_eq!(a.volume.data, b.volume.data);
        assert_eq!(a.residuals, b.residuals);
    }

    #[test]
    fn landweber_split_devices_match() {
        let (g, _, p, big) = setup(14, 10);
        let opts = ReconOpts { iterations: 4, nonneg: false, ..Default::default() };
        let r_big = landweber(&big, &g, &p, &opts).unwrap();
        let plane = (14 * 14 * 4) as u64;
        let tiny = MultiGpu::gtx1080ti(2)
            .with_device_mem(6 * plane + 3 * 10 * g.single_proj_bytes());
        let r_tiny = landweber(&tiny, &g, &p, &opts).unwrap();
        let rel = metrics::rel_l2(&r_big.volume, &r_tiny.volume);
        assert!(rel < 2e-3, "split landweber deviates {rel}");
    }
}
