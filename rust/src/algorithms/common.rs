//! Shared algorithm plumbing: options, convergence traces and result
//! reporting. The operator wrapper the algorithms drive their loops
//! through is `coordinator::residency::ReconSession` (PR 4): it carries
//! the cumulative simulated time and peak memory the old `TrackedOps`
//! tracked, plus the cross-iteration device residency cache.

use crate::coordinator::checkpoint::CheckpointConfig;
use crate::volume::Volume;

/// Options common to the iterative algorithms.
#[derive(Clone, Debug)]
pub struct ReconOpts {
    pub iterations: usize,
    /// Relaxation / step parameter (λ for SART-family, unused by CGLS).
    pub lambda: f32,
    /// Enforce non-negativity after each update.
    pub nonneg: bool,
    /// Verbose per-iteration logging.
    pub verbose: bool,
    /// Durable iteration checkpointing (ISSUE 7): when set, the
    /// algorithm snapshots its recurrence state every
    /// `checkpoint.every` iterations and *resumes from* any checkpoint
    /// already present in the directory — the resumed run's final
    /// iterate is bit-identical to an uninterrupted one.
    pub checkpoint: Option<CheckpointConfig>,
}

impl Default for ReconOpts {
    fn default() -> Self {
        Self { iterations: 10, lambda: 1.0, nonneg: true, verbose: false, checkpoint: None }
    }
}

/// Result of a reconstruction: the volume, the convergence trace and the
/// simulated wall-clock the multi-GPU node would have spent.
#[derive(Clone, Debug)]
pub struct ReconResult {
    pub volume: Volume,
    /// ‖b − Ax‖₂ after each iteration (when the algorithm computes it).
    pub residuals: Vec<f64>,
    /// Total simulated time across all operator calls, seconds.
    pub sim_time_s: f64,
    /// Peak simulated device memory over all calls.
    pub peak_device_bytes: u64,
}

/// `max(x, eps)` reciprocal used for SART weight volumes.
pub fn safe_recip(data: &mut [f32]) {
    for v in data.iter_mut() {
        *v = if v.abs() > 1e-6 { 1.0 / *v } else { 0.0 };
    }
}

/// Build the ordered-subset angle index lists: `n_subsets` interleaved
/// subsets (TIGRE's default angular ordering for OS-SART).
pub fn ordered_subsets(n_angles: usize, subset_size: usize) -> Vec<Vec<usize>> {
    let subset_size = subset_size.clamp(1, n_angles);
    let n_subsets = n_angles.div_ceil(subset_size);
    let mut subsets: Vec<Vec<usize>> = vec![Vec::new(); n_subsets];
    // interleave angles so each subset spans the angular range
    for a in 0..n_angles {
        subsets[a % n_subsets].push(a);
    }
    subsets
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_subsets_partition_angles() {
        let subsets = ordered_subsets(10, 3);
        assert_eq!(subsets.len(), 4);
        let mut all: Vec<usize> = subsets.concat();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
        // each subset spans the angular range (interleaved)
        assert!(subsets[0].contains(&0));
        assert!(subsets[0].iter().any(|&a| a >= 5));
    }

    #[test]
    fn subset_size_one_gives_singletons() {
        let subsets = ordered_subsets(4, 1);
        assert_eq!(subsets.len(), 4);
        assert!(subsets.iter().all(|s| s.len() == 1));
    }

    #[test]
    fn subset_size_all_gives_one() {
        let subsets = ordered_subsets(6, 6);
        assert_eq!(subsets.len(), 1);
        assert_eq!(subsets[0].len(), 6);
    }

    #[test]
    fn safe_recip_handles_zero() {
        let mut v = vec![2.0, 0.0, -4.0];
        safe_recip(&mut v);
        assert_eq!(v, vec![0.5, 0.0, -0.25]);
    }
}
