//! `simgpu` — a discrete-event simulated multi-GPU node.
//!
//! The paper's testbed is 1–4 NVIDIA GTX 1080 Ti GPUs on PCIe Gen3. That
//! hardware is substituted by a faithful *device model* (DESIGN.md §2):
//! each simulated GPU has
//!  * a memory ledger with a hard capacity (allocation beyond device RAM
//!    is a programming error, caught loudly),
//!  * three engines with CUDA stream semantics — a compute engine and two
//!    DMA engines (H2D and D2H) that can run concurrently with compute,
//!  * a connection to the host with *pageable* vs *pinned* bandwidth, and
//!    the CUDA rule that pageable copies are synchronous (they block the
//!    host thread) while pinned copies are asynchronous.
//!
//! The host itself is a resource: synchronous operations serialize on it,
//! which is exactly the effect the paper's queueing order fights (§2.1
//! "memory copies will halt the CPU code until completion").
//!
//! Every operation is logged as a [`TimelineEvent`] tagged with the same
//! three categories Fig. 9 bins: `Compute`, `PinUnpin`, `OtherMem`.

pub mod costmodel;
pub mod device;
pub mod fault;
pub mod timeline;

pub use costmodel::CostModel;
pub use device::{DeviceMem, GpuSpec};
pub use fault::{FaultKind, FaultPlan, FaultScope, FaultSite, LaunchFault, MAX_LAUNCH_RETRIES};
pub use timeline::{Category, TimelineEvent};

use std::collections::BTreeMap;
use std::sync::Arc;

/// Typed out-of-memory error for the simulated device ledger.
///
/// OOM is a *recoverable planning signal* in a toolbox whose premise is
/// arbitrarily small GPU memories: it propagates through the executor's
/// `Result` path (and converts into `anyhow::Error` via `?`) instead of
/// crashing the process, so callers can re-plan with smaller slabs or
/// report the infeasible configuration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SimOom {
    /// Device index whose ledger rejected the allocation.
    pub device: usize,
    /// Allocation label (e.g. `slab`, `projbuf0`).
    pub label: String,
    /// Ledger detail: requested vs free vs capacity.
    pub detail: String,
}

impl std::fmt::Display for SimOom {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "device {} OOM allocating '{}': {}", self.device, self.label, self.detail)
    }
}

impl std::error::Error for SimOom {}

/// Identifies a completed (virtual-time) operation for dependencies.
#[derive(Clone, Copy, Debug, PartialEq, PartialOrd)]
pub struct Ev(pub f64);

impl Ev {
    /// The start of virtual time — "no dependency".
    pub const ZERO: Ev = Ev(0.0);

    /// Later of the two events (join of dependencies).
    pub fn max(self, other: Ev) -> Ev {
        Ev(self.0.max(other.0))
    }
}

/// Which engine of a device an operation occupies.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Engine {
    /// Kernel execution engine (one compute queue per device).
    Compute,
    /// Host→device DMA engine.
    H2D,
    /// Device→host DMA engine.
    D2H,
}

/// The simulated node: host + `n` devices + virtual clocks.
#[derive(Debug)]
pub struct SimNode {
    /// Calibrated latency/bandwidth constants driving all charges.
    pub cost: CostModel,
    devices: Vec<DeviceState>,
    /// Host thread availability time.
    host_free: f64,
    /// Out-of-core backing store availability time: one disk, shared by
    /// every loader lane, serializing its requests — but asynchronous to
    /// the host and the device engines (reads run on loader threads), so
    /// streaming hides behind kernels exactly when
    /// `CostModel::ooc_read_hidden` says so.
    disk_free: f64,
    events: Vec<TimelineEvent>,
    /// Optional fault schedule (ISSUE 7): transient launch failures add
    /// retry backoff to the faulted kernel; a permanent device loss
    /// charges one replan and redirects the device's remaining kernels
    /// onto the cyclic-next survivor's compute engine.
    fault: Option<Arc<FaultPlan>>,
    /// Devices whose loss has already been charged `fault_replan_s`.
    fault_replanned: Vec<bool>,
}

#[derive(Debug)]
struct DeviceState {
    mem: DeviceMem,
    engine_free: BTreeMap<Engine, f64>,
}

impl SimNode {
    /// A node with `n` identical devices.
    pub fn new(n: usize, spec: GpuSpec, cost: CostModel) -> Self {
        let devices = (0..n)
            .map(|_| DeviceState {
                mem: DeviceMem::new(spec.clone()),
                engine_free: BTreeMap::from([
                    (Engine::Compute, 0.0),
                    (Engine::H2D, 0.0),
                    (Engine::D2H, 0.0),
                ]),
            })
            .collect();
        let n = devices.len();
        Self {
            cost,
            devices,
            host_free: 0.0,
            disk_free: 0.0,
            events: Vec::new(),
            fault: None,
            fault_replanned: vec![false; n],
        }
    }

    /// Attach a fault schedule; its `Sim` scope drives this node. The
    /// caller is expected to `begin_op(FaultScope::Sim)` per operator
    /// (done by `MultiGpu::fresh_sim`).
    pub fn set_fault_plan(&mut self, plan: Arc<FaultPlan>) {
        self.fault = Some(plan);
    }

    /// Number of simulated devices in the node.
    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Memory ledger of device `dev`.
    pub fn device_mem(&self, dev: usize) -> &DeviceMem {
        &self.devices[dev].mem
    }

    /// Current virtual time of the host thread.
    pub fn host_time(&self) -> Ev {
        Ev(self.host_free)
    }

    /// Makespan: the latest completion over host, disk and all engines.
    pub fn makespan(&self) -> f64 {
        let dev_max = self
            .devices
            .iter()
            .flat_map(|d| d.engine_free.values())
            .cloned()
            .fold(0.0f64, f64::max);
        dev_max.max(self.host_free).max(self.disk_free)
    }

    /// All logged events (chronological by start).
    pub fn events(&self) -> &[TimelineEvent] {
        &self.events
    }

    /// Advance the host clock to at least `ev` (host-side synchronize on
    /// a device event — `cudaStreamSynchronize`).
    pub fn host_sync(&mut self, ev: Ev) {
        self.host_free = self.host_free.max(ev.0);
    }

    /// Synchronize host with *everything* queued so far (`cudaDeviceSynchronize`
    /// over all devices).
    pub fn sync_all(&mut self) {
        let m = self.makespan();
        self.host_free = self.host_free.max(m);
    }

    // ---- memory ledger operations --------------------------------------

    /// Allocate `bytes` on device `dev` under `label`. Charges the small
    /// `alloc` latency to the host (cudaMalloc is synchronous). Exceeding
    /// the device capacity is a typed, recoverable [`SimOom`] error — not
    /// a panic — so planners and executors can treat it as a signal.
    pub fn alloc(&mut self, dev: usize, label: &str, bytes: u64) -> Result<Ev, SimOom> {
        self.devices[dev].mem.alloc(label, bytes).map_err(|detail| SimOom {
            device: dev,
            label: label.to_string(),
            detail,
        })?;
        let mut dur = self.cost.alloc_latency_s;
        if let Some(plan) = &self.fault {
            let k = plan.alloc_fault(FaultScope::Sim, dev);
            if k > MAX_LAUNCH_RETRIES {
                // Hard injected allocation failure: every bounded retry
                // failed too. Undo the ledger charge (nothing was ever
                // allocated), charge the host for the exhausted retries,
                // and surface the typed OOM — the operator entry answers
                // with the memory-pressure ladder (evict → refine →
                // spill, ISSUE 8). The site is consumed, so the ladder's
                // retried op allocates cleanly.
                self.devices[dev].mem.free(label);
                let t0 = self.host_free;
                let mut t1 = t0;
                for i in 0..MAX_LAUNCH_RETRIES {
                    t1 += self.cost.alloc_latency_s
                        + self.cost.fault_retry_backoff_s * (1u64 << i) as f64;
                }
                self.host_free = t1;
                self.log(dev, Category::OtherMem, t0, t1, format!("alloc fail {label}"));
                return Err(SimOom {
                    device: dev,
                    label: label.to_string(),
                    detail: format!(
                        "injected allocation failure ({k} attempts > retry budget {MAX_LAUNCH_RETRIES})"
                    ),
                });
            }
            for i in 0..k {
                dur += self.cost.alloc_latency_s + self.cost.fault_retry_backoff_s * (1u64 << i) as f64;
            }
        }
        let t0 = self.host_free;
        let t1 = t0 + dur;
        self.host_free = t1;
        self.log(dev, Category::OtherMem, t0, t1, format!("alloc {label}"));
        Ok(Ev(t1))
    }

    /// Charge `bytes` that are *already resident* on device `dev` from a
    /// previous operator call (the residency cache's carried-over staging
    /// buffers). Ledger-only: no host time and no timeline event, because
    /// nothing happens at call time — the memory simply never went away.
    pub fn reserve(&mut self, dev: usize, label: &str, bytes: u64) -> Result<(), SimOom> {
        if bytes == 0 {
            return Ok(());
        }
        self.devices[dev].mem.alloc(label, bytes).map_err(|detail| SimOom {
            device: dev,
            label: label.to_string(),
            detail,
        })
    }

    /// Free a device allocation (host-synchronous, negligible time).
    pub fn free(&mut self, dev: usize, label: &str) {
        self.devices[dev].mem.free(label);
        let t0 = self.host_free;
        let t1 = t0 + self.cost.free_latency_s;
        self.host_free = t1;
        self.log(dev, Category::OtherMem, t0, t1, format!("free {label}"));
    }

    // ---- host pin/unpin --------------------------------------------------

    /// Page-lock `bytes` of host memory. Fully host-synchronous.
    pub fn pin_host(&mut self, bytes: u64, already_allocated: bool) -> Ev {
        let dur = self.cost.pin_time_s(bytes, already_allocated);
        let t0 = self.host_free;
        let t1 = t0 + dur;
        self.host_free = t1;
        self.log_host(Category::PinUnpin, t0, t1, format!("pin {bytes}B"));
        Ev(t1)
    }

    /// Unpin host memory. Host-synchronous.
    pub fn unpin_host(&mut self, bytes: u64) -> Ev {
        let dur = self.cost.unpin_time_s(bytes);
        let t0 = self.host_free;
        let t1 = t0 + dur;
        self.host_free = t1;
        self.log_host(Category::PinUnpin, t0, t1, format!("unpin {bytes}B"));
        Ev(t1)
    }

    /// Generic host-side busy time (e.g. a CPU gather/accumulate pass in
    /// the naive baseline). Host-synchronous.
    pub fn host_busy(&mut self, dur_s: f64, cat: Category, label: &str) -> Ev {
        let t0 = self.host_free;
        let t1 = t0 + dur_s;
        self.host_free = t1;
        self.log_host(cat, t0, t1, label.to_string());
        Ev(t1)
    }

    /// Per-call fixed overhead: GPU property checks, context touch
    /// (paper: dominates at small sizes). Host-synchronous.
    pub fn property_check(&mut self) -> Ev {
        let t0 = self.host_free;
        let t1 = t0 + self.cost.property_check_s * self.devices.len() as f64;
        self.host_free = t1;
        self.log_host(Category::OtherMem, t0, t1, "property check".into());
        Ev(t1)
    }

    // ---- transfers -------------------------------------------------------

    /// Host→device copy of `bytes`. If `pinned`, runs asynchronously on
    /// the device's H2D engine after `after`; otherwise it is synchronous:
    /// it also blocks the host until completion (paper §2).
    pub fn h2d(&mut self, dev: usize, bytes: u64, pinned: bool, after: Ev) -> Ev {
        self.copy(dev, Engine::H2D, bytes, pinned, after, "h2d")
    }

    /// Device→host copy (same semantics as [`SimNode::h2d`]).
    pub fn d2h(&mut self, dev: usize, bytes: u64, pinned: bool, after: Ev) -> Ev {
        self.copy(dev, Engine::D2H, bytes, pinned, after, "d2h")
    }

    fn copy(
        &mut self,
        dev: usize,
        engine: Engine,
        bytes: u64,
        pinned: bool,
        after: Ev,
        what: &str,
    ) -> Ev {
        let dur = self.cost.copy_time_s(bytes, pinned);
        let eng_free = self.devices[dev].engine_free[&engine];
        // A copy can start once: the engine is free, dependencies are met,
        // and the host has issued it (queueing takes no time, but a
        // synchronous copy cannot be issued before the host reaches it).
        let t0 = eng_free.max(after.0).max(self.host_free);
        let t1 = t0 + dur;
        self.devices[dev].engine_free.insert(engine, t1);
        if !pinned {
            // pageable copies block the host until done
            self.host_free = t1;
        }
        self.log(
            dev,
            Category::OtherMem,
            t0,
            t1,
            format!("{what} {bytes}B {}", if pinned { "pinned" } else { "pageable" }),
        );
        Ev(t1)
    }

    /// Peer-to-peer device→device copy of `bytes` from `src` to `dst`
    /// over the PCIe switch — one hop of the reduction-tree merge. The
    /// copy occupies `src`'s D2H engine and `dst`'s H2D engine for its
    /// duration (both endpoints DMA) and is asynchronous to the host
    /// (cudaMemcpyPeerAsync semantics): pairs on disjoint devices run
    /// concurrently, which is exactly what makes a merge round log-depth.
    pub fn p2p(&mut self, src: usize, dst: usize, bytes: u64, after: Ev) -> Ev {
        debug_assert_ne!(src, dst, "p2p endpoints must differ");
        let dur = self.cost.p2p_time_s(bytes);
        let t0 = self.devices[src].engine_free[&Engine::D2H]
            .max(self.devices[dst].engine_free[&Engine::H2D])
            .max(after.0);
        let t1 = t0 + dur;
        self.devices[src].engine_free.insert(Engine::D2H, t1);
        self.devices[dst].engine_free.insert(Engine::H2D, t1);
        self.log(dst, Category::OtherMem, t0, t1, format!("p2p d{src}->d{dst} {bytes}B"));
        Ev(t1)
    }

    // ---- out-of-core backing store ---------------------------------------

    /// Retry time injected into the next disk operation by the fault
    /// plan (bounded, doubling backoff — the Sim mirror of the real
    /// loader-lane retry in `volume::outofcore`).
    fn disk_fault_extra(&mut self) -> f64 {
        let Some(plan) = &self.fault else { return 0.0 };
        let k = plan.disk_fault(FaultScope::Sim);
        let mut extra = 0.0;
        for i in 0..k.min(MAX_LAUNCH_RETRIES) {
            extra += self.cost.disk_latency_s + self.cost.fault_retry_backoff_s * (1u64 << i) as f64;
        }
        extra
    }

    /// Read `bytes` from the backing store after `after`: serializes on
    /// the single disk, does **not** advance the host clock (loader
    /// threads issue these). Returns the completion event the dependent
    /// H2D copy must wait on.
    pub fn disk_read(&mut self, bytes: u64, after: Ev) -> Ev {
        let dur = self.cost.disk_read_time_s(bytes) + self.disk_fault_extra();
        let t0 = self.disk_free.max(after.0);
        let t1 = t0 + dur;
        self.disk_free = t1;
        self.log_host(Category::OtherMem, t0, t1, format!("disk read {bytes}B"));
        Ev(t1)
    }

    /// Write `bytes` back to the backing store after `after` (dirty-slab
    /// writeback / result spill). Same engine semantics as
    /// [`SimNode::disk_read`].
    pub fn disk_write(&mut self, bytes: u64, after: Ev) -> Ev {
        let dur = self.cost.disk_write_time_s(bytes) + self.disk_fault_extra();
        let t0 = self.disk_free.max(after.0);
        let t1 = t0 + dur;
        self.disk_free = t1;
        self.log_host(Category::OtherMem, t0, t1, format!("disk write {bytes}B"));
        Ev(t1)
    }

    // ---- kernels ----------------------------------------------------------

    /// Queue a kernel of `dur_s` seconds on the device's compute engine
    /// after `after`. Asynchronous: does not advance the host clock.
    ///
    /// With a fault plan attached, a transient launch failure stretches
    /// the kernel by its retry backoffs, and a permanently lost device's
    /// kernels run on the cyclic-next survivor's compute engine instead
    /// (one `fault_replan_s` host charge at the moment of loss). The
    /// survivor redirect models recovery *time* only — the memory
    /// ledger keeps the original placement.
    pub fn kernel(&mut self, dev: usize, dur_s: f64, after: Ev, label: &str) -> Ev {
        let (run_dev, extra) = self.fault_route(dev, dur_s);
        let t0 = self.devices[run_dev].engine_free[&Engine::Compute]
            .max(after.0)
            .max(self.host_free); // issue order: host must have reached it
        let t1 = t0 + dur_s + self.cost.kernel_launch_s + extra;
        self.devices[run_dev].engine_free.insert(Engine::Compute, t1);
        self.log(run_dev, Category::Compute, t0, t1, label.to_string());
        Ev(t1)
    }

    /// Consult the fault plan for the next launch unit on `dev`: returns
    /// the device the kernel actually runs on and the extra retry time.
    /// `dur_s` is the unit's predicted kernel time — a hung launch
    /// occupies the engine until the watchdog deadline
    /// (`predicted × watchdog_factor`) before it is killed and retried.
    fn fault_route(&mut self, dev: usize, dur_s: f64) -> (usize, f64) {
        let Some(plan) = self.fault.clone() else { return (dev, 0.0) };
        match plan.launch_fault(FaultScope::Sim, dev) {
            LaunchFault::Ok => return (dev, 0.0),
            LaunchFault::Transient(k) if k <= MAX_LAUNCH_RETRIES => {
                let mut extra = 0.0;
                for i in 0..k {
                    extra +=
                        self.cost.kernel_launch_s + self.cost.fault_retry_backoff_s * (1u64 << i) as f64;
                }
                return (dev, extra);
            }
            LaunchFault::Hung(k) if k <= MAX_LAUNCH_RETRIES => {
                // Each hang wastes a full watchdog deadline of engine
                // time before the unit is killed and relaunched.
                let mut extra = 0.0;
                for i in 0..k {
                    extra += self.cost.watchdog_deadline_s(dur_s)
                        + self.cost.kernel_launch_s
                        + self.cost.fault_retry_backoff_s * (1u64 << i) as f64;
                }
                return (dev, extra);
            }
            // retry budget exhausted: escalate to permanent loss
            LaunchFault::Transient(_) | LaunchFault::Hung(_) => {
                plan.mark_lost(FaultScope::Sim, dev)
            }
            LaunchFault::Lost => {}
        }
        if !self.fault_replanned[dev] {
            self.fault_replanned[dev] = true;
            let replan = self.cost.fault_replan_s;
            self.host_busy(replan, Category::OtherMem, &format!("fault replan d{dev}"));
        }
        // cyclic-next survivor — mirrors `splitter::replan_excluding`
        let lost = plan.lost_devices(FaultScope::Sim, self.devices.len());
        let survivor = (1..self.devices.len())
            .map(|k| (dev + k) % self.devices.len())
            .find(|&s| !lost[s])
            .unwrap_or(dev); // no survivor: degenerate, keep the engine
        (survivor, 0.0)
    }

    /// Completion time of a device's engine.
    pub fn engine_time(&self, dev: usize, engine: Engine) -> Ev {
        Ev(self.devices[dev].engine_free[&engine])
    }

    fn log(&mut self, dev: usize, cat: Category, t0: f64, t1: f64, label: String) {
        self.events.push(TimelineEvent { device: Some(dev), category: cat, t_start: t0, t_end: t1, label });
    }

    fn log_host(&mut self, cat: Category, t0: f64, t1: f64, label: String) {
        self.events.push(TimelineEvent { device: None, category: cat, t_start: t0, t_end: t1, label });
    }

    /// Per-category total busy time (the Fig. 9 breakdown). Overlapped
    /// intervals within one category on different engines both count,
    /// matching how the paper attributes concurrent copies to "computing"
    /// when they overlap kernels: callers should use
    /// [`timeline::breakdown`] for the overlap-aware binning.
    pub fn busy_by_category(&self) -> BTreeMap<Category, f64> {
        let mut m = BTreeMap::new();
        for e in &self.events {
            *m.entry(e.category).or_insert(0.0) += e.t_end - e.t_start;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_node(n: usize) -> SimNode {
        SimNode::new(n, GpuSpec::gtx1080ti(), CostModel::gtx1080ti_pcie3())
    }

    #[test]
    fn pageable_copy_blocks_host_pinned_does_not() {
        let mut sim = small_node(1);
        let bytes = 1 << 30; // 1 GiB
        sim.h2d(0, bytes, false, Ev::ZERO);
        let host_after_pageable = sim.host_time().0;
        assert!(host_after_pageable > 0.2, "pageable 1GiB at 4GB/s ≈ 0.25s");

        let mut sim2 = small_node(1);
        sim2.h2d(0, bytes, true, Ev::ZERO);
        assert!(sim2.host_time().0 < 1e-3, "pinned copy is async for the host");
        assert!(sim2.engine_time(0, Engine::H2D).0 > 0.05, "engine busy ≈ 1/12 s");
    }

    #[test]
    fn kernel_overlaps_with_pinned_copy() {
        let mut sim = small_node(1);
        let k = sim.kernel(0, 1.0, Ev::ZERO, "fp");
        let c = sim.h2d(0, 12 << 30, true, Ev::ZERO); // ≈1 s at 12GB/s
        // both finish around t=1: overlap, not serialization
        assert!((k.0 - 1.0).abs() < 0.01);
        assert!((c.0 - 1.0).abs() < 0.1);
        assert!(sim.makespan() < 1.5, "makespan {}", sim.makespan());
    }

    #[test]
    fn dependencies_serialize() {
        let mut sim = small_node(1);
        let c = sim.h2d(0, 12 << 30, true, Ev::ZERO);
        let k = sim.kernel(0, 1.0, c, "fp after copy");
        assert!(k.0 > 1.9, "kernel must wait for the copy: {}", k.0);
    }

    #[test]
    fn compute_engine_serializes_kernels() {
        let mut sim = small_node(1);
        let k1 = sim.kernel(0, 1.0, Ev::ZERO, "a");
        let k2 = sim.kernel(0, 1.0, Ev::ZERO, "b");
        assert!(k2.0 >= k1.0 + 1.0 - 1e-9);
    }

    #[test]
    fn devices_run_concurrently() {
        let mut sim = small_node(4);
        for d in 0..4 {
            sim.kernel(d, 1.0, Ev::ZERO, "fp");
        }
        assert!(sim.makespan() < 1.1, "4 devices in parallel: {}", sim.makespan());
    }

    #[test]
    fn device_oom_is_a_typed_recoverable_error() {
        let mut sim = small_node(1);
        let err = sim.alloc(0, "huge", 12 << 30).unwrap_err(); // > 11 GiB
        assert_eq!(err.device, 0);
        assert_eq!(err.label, "huge");
        assert!(err.to_string().contains("OOM"), "{err}");
        // the failed allocation left no trace: the node remains usable
        assert_eq!(sim.device_mem(0).used(), 0);
        sim.alloc(0, "ok", 1 << 30).unwrap();
        assert_eq!(sim.device_mem(0).used(), 1 << 30);
        // and it converts into anyhow::Error through `?`
        let as_anyhow: anyhow::Error = err.into();
        assert!(format!("{as_anyhow:#}").contains("OOM"));
    }

    #[test]
    fn reserve_charges_ledger_without_host_time_or_events() {
        let mut sim = small_node(1);
        let n_events = sim.events().len();
        sim.reserve(0, "resident", 2 << 30).unwrap();
        assert_eq!(sim.device_mem(0).used(), 2 << 30);
        assert_eq!(sim.host_time().0, 0.0, "reserve must not advance the host clock");
        assert_eq!(sim.events().len(), n_events, "reserve must not log events");
        // over-reserving is the same typed error as alloc
        assert!(sim.reserve(0, "more", 10 << 30).is_err());
    }

    #[test]
    fn disk_engine_serializes_reads_but_overlaps_compute() {
        let mut sim = small_node(1);
        // two loader-lane reads serialize on the one disk...
        let r1 = sim.disk_read(5 << 30, Ev::ZERO); // 5 GiB ≈ 2.1 s
        let r2 = sim.disk_read(5 << 30, Ev::ZERO);
        assert!(r2.0 > r1.0 + 1.0, "disk requests must serialize: {} vs {}", r2.0, r1.0);
        // ...without blocking the host or the compute engine
        assert_eq!(sim.host_time().0, 0.0, "disk reads run on loader threads");
        let k = sim.kernel(0, 1.0, Ev::ZERO, "fp");
        assert!((k.0 - 1.0).abs() < 0.01, "kernel overlaps the reads");
        // a copy depending on a read waits for it
        let c = sim.h2d(0, 1024, true, r1);
        assert!(c.0 >= r1.0);
        // writes occupy the same engine and count toward the makespan
        let w = sim.disk_write(1 << 30, Ev::ZERO);
        assert!(w.0 >= r2.0);
        assert!(sim.makespan() >= w.0);
    }

    #[test]
    fn p2p_occupies_both_endpoints_but_not_the_host() {
        let mut sim = small_node(4);
        let bytes = 11u64 << 30; // ≈1 s at 11 GB/s
        // disjoint pairs overlap — a reduction-tree round is one hop deep
        let a = sim.p2p(1, 0, bytes, Ev::ZERO);
        let b = sim.p2p(3, 2, bytes, Ev::ZERO);
        assert!((a.0 - b.0).abs() < 1e-9, "disjoint pairs run concurrently");
        assert!(sim.makespan() < 1.5, "round of 2 hops ≈ 1 hop: {}", sim.makespan());
        // asynchronous to the host
        assert_eq!(sim.host_time().0, 0.0, "p2p must not block the host");
        // both endpoints' DMA engines are busy for the copy
        assert!(sim.engine_time(1, Engine::D2H).0 >= a.0 - 1e-9);
        assert!(sim.engine_time(0, Engine::H2D).0 >= a.0 - 1e-9);
        // a second hop into the same destination serializes on its engine
        let c = sim.p2p(2, 0, bytes, Ev::ZERO);
        assert!(c.0 > a.0 + 0.9, "shared H2D engine serializes: {} vs {}", c.0, a.0);
        // and dependencies are honored
        let d = sim.p2p(3, 1, bytes, c);
        assert!(d.0 >= c.0 + 0.9);
    }

    #[test]
    fn alloc_free_ledger() {
        let mut sim = small_node(1);
        sim.alloc(0, "img", 4 << 30).unwrap();
        assert_eq!(sim.device_mem(0).used(), 4 << 30);
        sim.free(0, "img");
        assert_eq!(sim.device_mem(0).used(), 0);
    }

    #[test]
    fn pin_is_host_synchronous_and_expensive() {
        let mut sim = small_node(1);
        let before = sim.host_time().0;
        sim.pin_host(8 << 30, true);
        let after = sim.host_time().0;
        assert!(after - before > 0.5, "pinning 8GiB should cost ≈1s+: {}", after - before);
    }

    #[test]
    fn sync_all_advances_host_to_makespan() {
        let mut sim = small_node(2);
        sim.kernel(1, 2.0, Ev::ZERO, "slow");
        assert!(sim.host_time().0 < 0.1);
        sim.sync_all();
        assert!(sim.host_time().0 >= 2.0);
    }

    #[test]
    fn fault_transient_launch_stretches_the_kernel() {
        let mut clean = small_node(1);
        clean.kernel(0, 0.1, Ev::ZERO, "fp");
        let mut faulted = small_node(1);
        let plan = Arc::new(FaultPlan::new().transient_launch(0, 0));
        plan.begin_op(FaultScope::Sim);
        faulted.set_fault_plan(plan);
        faulted.kernel(0, 0.1, Ev::ZERO, "fp");
        let dt = faulted.makespan() - clean.makespan();
        assert!(
            dt >= faulted.cost.fault_retry_backoff_s - 1e-12,
            "retry backoff must appear in the makespan: Δ={dt}"
        );
    }

    #[test]
    fn fault_device_loss_redirects_kernels_and_charges_replan() {
        let mut clean = small_node(2);
        for d in 0..2 {
            clean.kernel(d, 1.0, Ev::ZERO, "fp");
        }
        let clean_mk = clean.makespan(); // two devices in parallel ≈ 1 s

        let mut faulted = small_node(2);
        let plan = Arc::new(FaultPlan::new().device_loss(1, 0));
        plan.begin_op(FaultScope::Sim);
        faulted.set_fault_plan(plan.clone());
        faulted.kernel(0, 1.0, Ev::ZERO, "fp");
        faulted.kernel(1, 1.0, Ev::ZERO, "fp"); // lost → runs on device 0
        assert!(plan.is_lost(FaultScope::Sim, 1));
        let mk = faulted.makespan();
        assert!(
            mk > clean_mk + 0.9,
            "lost device's kernel must serialize on the survivor: {mk} vs {clean_mk}"
        );
        // the one-time replan charge landed on the host
        assert!(faulted.events().iter().any(|e| e.label.contains("fault replan d1")));
    }

    #[test]
    fn fault_hang_stretches_kernel_by_watchdog_deadline() {
        let mut clean = small_node(1);
        clean.kernel(0, 0.1, Ev::ZERO, "fp");
        let mut faulted = small_node(1);
        let plan = Arc::new(FaultPlan::new().hang(0, 0, 1));
        plan.begin_op(FaultScope::Sim);
        faulted.set_fault_plan(plan);
        faulted.kernel(0, 0.1, Ev::ZERO, "fp");
        let dt = faulted.makespan() - clean.makespan();
        let deadline = faulted.cost.watchdog_deadline_s(0.1);
        assert!(
            dt >= deadline - 1e-12,
            "a hang must waste a full watchdog deadline: Δ={dt} < {deadline}"
        );
    }

    #[test]
    fn fault_escalated_hang_redirects_to_a_survivor() {
        let mut faulted = small_node(2);
        let plan = Arc::new(FaultPlan::new().hang(1, 0, MAX_LAUNCH_RETRIES + 1));
        plan.begin_op(FaultScope::Sim);
        faulted.set_fault_plan(plan.clone());
        faulted.kernel(0, 1.0, Ev::ZERO, "fp");
        faulted.kernel(1, 1.0, Ev::ZERO, "fp"); // hangs past budget → lost
        assert!(plan.is_lost(FaultScope::Sim, 1));
        assert!(faulted.events().iter().any(|e| e.label.contains("fault replan d1")));
    }

    #[test]
    fn fault_injected_alloc_failure_past_budget_is_a_typed_oom() {
        let mut sim = small_node(1);
        let plan = Arc::new(FaultPlan::new().alloc_fail(0, 0, MAX_LAUNCH_RETRIES + 1));
        plan.begin_op(FaultScope::Sim);
        sim.set_fault_plan(plan);
        let err = sim.alloc(0, "img", 1 << 20).unwrap_err();
        assert_eq!(err.device, 0);
        assert!(err.detail.contains("injected"), "{err}");
        // the ledger was rolled back and the exhausted retries cost time
        assert_eq!(sim.device_mem(0).used(), 0);
        assert!(sim.host_time().0 > 0.0);
        // the site is consumed: a ladder retry allocates cleanly
        sim.alloc(0, "img", 1 << 20).unwrap();
        assert_eq!(sim.device_mem(0).used(), 1 << 20);
    }

    #[test]
    fn fault_disk_retry_time_appears_on_the_disk_engine() {
        let mut clean = small_node(1);
        clean.disk_read(1 << 20, Ev::ZERO);
        let mut faulted = small_node(1);
        let plan = Arc::new(FaultPlan::new().disk_io(0, 2));
        plan.begin_op(FaultScope::Sim);
        faulted.set_fault_plan(plan);
        faulted.disk_read(1 << 20, Ev::ZERO);
        assert!(faulted.makespan() > clean.makespan());
    }

    #[test]
    fn events_are_logged_with_categories() {
        let mut sim = small_node(1);
        sim.alloc(0, "x", 1024).unwrap();
        sim.pin_host(1024, true);
        sim.kernel(0, 0.1, Ev::ZERO, "k");
        let cats: Vec<Category> = sim.events().iter().map(|e| e.category).collect();
        assert!(cats.contains(&Category::OtherMem));
        assert!(cats.contains(&Category::PinUnpin));
        assert!(cats.contains(&Category::Compute));
    }
}
