//! ASD-POCS (adaptive steepest-descent projection-onto-convex-sets,
//! Sidky & Pan 2008) — TIGRE's flagship TV-constrained algorithm:
//! alternate an OS-SART data-fidelity sweep with steepest-descent TV
//! minimization, adapting the TV step to the data-update magnitude.
//! The TV inner loop runs on the multi-GPU halo-split regularizer (§2.3).

use crate::coordinator::checkpoint::{self, CheckpointState};
use crate::coordinator::regularizer::tv_gradient_descent_split;
use crate::coordinator::{MultiGpu, ReconSession};
use crate::geometry::Geometry;
use crate::kernels::scratch;
use crate::volume::{ProjectionSet, TrackedVolume, Volume};

use super::common::{projector_ctx, DivergenceGuard, ReconOpts, ReconResult};
use super::ossart::os_sart;
use crate::coordinator::DegradeEvent;

/// ASD-POCS options.
#[derive(Clone, Debug)]
pub struct AsdPocsOpts {
    /// Options shared by every iterative algorithm.
    pub common: ReconOpts,
    /// OS-SART subset size for the data sweep.
    pub subset_size: usize,
    /// TV gradient-descent iterations per outer iteration.
    pub tv_iters: usize,
    /// Initial TV step as a fraction of the data-update magnitude.
    pub alpha: f32,
    /// Halo depth for the split TV minimization (paper N_in = 60).
    pub n_in: usize,
}

impl Default for AsdPocsOpts {
    fn default() -> Self {
        Self {
            common: ReconOpts::default(),
            subset_size: 4,
            tv_iters: 10,
            alpha: 0.002,
            n_in: crate::coordinator::regularizer::DEFAULT_N_IN,
        }
    }
}

/// ASD-POCS reconstruction.
pub fn asd_pocs(
    ctx: &MultiGpu,
    g: &Geometry,
    proj: &ProjectionSet,
    opts: &AsdPocsOpts,
) -> anyhow::Result<ReconResult> {
    // one session carries the outer residual forwards across iterations
    // (the projector override also reaches the inner OS-SART sweep,
    // which clones `opts.common` — including `projector` — below)
    let ctx = projector_ctx(ctx, &opts.common);
    let mut sess = ReconSession::new(&ctx, g)?;
    let mut x = TrackedVolume::new(Volume::zeros_like(g));
    let mut residuals = Vec::with_capacity(opts.common.iterations);
    let mut sim_time = 0.0;
    let mut peak = 0;

    // the inner data sweep must not checkpoint: only the outer loop owns
    // the durable state (x), snapshotted at outer-iteration granularity
    let mut one_iter = ReconOpts { iterations: 1, checkpoint: None, ..opts.common.clone() };
    let (mut ck, resumed) = checkpoint::setup(&opts.common.checkpoint, "asd-pocs")?;
    let mut start = 0;
    if let Some(mut st) = resumed {
        start = st.iteration.min(opts.common.iterations);
        residuals = st.residuals.clone();
        scratch::recycle_volume(x.replace(st.volume("x")?));
    }
    let mut guard = DivergenceGuard::new("asd-pocs", &opts.common);
    guard.seed(&residuals);
    let mut alpha_scale: f32 = 1.0;
    for it in start..opts.common.iterations {
        ctx.set_fault_iteration(it);
        // --- data fidelity sweep (OS-SART), warm-started from x ---
        // os_sart starts from zero, so apply it to the residual problem:
        // Δb = b − A x, then x ← x + recon(Δb).
        let ax = sess.forward(&x)?;
        let mut db = proj.clone();
        db.add_scaled(ax.get(), -1.0);
        sess.recycle_projections(ax);
        residuals.push(db.norm2());
        // residual growth → relax both the data sweep (λ) and the TV
        // step (α) before this iteration's updates
        if let Some(f) = guard.check(it, *residuals.last().unwrap())? {
            one_iter.lambda *= f;
            alpha_scale *= f;
            ctx.degrade
                .record(DegradeEvent::StepBackoff { algorithm: "asd-pocs", iteration: it });
        }

        let r = os_sart(&ctx, g, &db, opts.subset_size, &one_iter)?;
        sim_time += r.sim_time_s;
        peak = peak.max(r.peak_device_bytes);
        let dx_norm = r.volume.norm2();
        x.write().add_scaled(&r.volume, 1.0);
        if opts.common.nonneg {
            x.write().clamp_min(0.0);
        }

        // --- TV minimization, step adapted to the data update ---
        let base_alpha = if dx_norm > 0.0 { opts.alpha } else { opts.alpha * 0.5 };
        let alpha = alpha_scale * base_alpha;
        let (x_tv, stats) =
            tv_gradient_descent_split(&ctx, x.get(), opts.tv_iters, alpha, opts.n_in)?;
        sim_time += stats.makespan_s;
        scratch::recycle_volume(x.replace(x_tv));

        if opts.common.verbose {
            crate::log_info!("asd-pocs iter {it}: residual {:.4e}", residuals.last().unwrap());
        }
        if let Some(ck) = ck.as_mut() {
            if ck.due(it + 1) {
                ck.save(&CheckpointState {
                    iteration: it + 1,
                    residuals: residuals.clone(),
                    volumes: vec![("x".into(), x.get().clone())],
                    ..Default::default()
                })?;
            }
        }
    }
    sim_time += sess.sim_time_s;
    peak = peak.max(sess.peak_device_bytes);

    Ok(ReconResult {
        volume: x.into_inner(),
        residuals,
        sim_time_s: sim_time,
        peak_device_bytes: peak,
        backoffs: guard.backoffs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ExecMode;
    use crate::metrics;
    use crate::phantom;

    #[test]
    fn asd_pocs_reconstructs_piecewise_flat_phantom() {
        let n = 16;
        let g = Geometry::cone_beam(n, 12); // few angles: TV's home turf
        let truth = phantom::cube(n, 0.5, 1.0);
        let ctx = MultiGpu::gtx1080ti(2);
        let (p, _) = ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap();
        let opts = AsdPocsOpts {
            common: ReconOpts { iterations: 6, lambda: 0.9, ..Default::default() },
            subset_size: 3,
            tv_iters: 5,
            alpha: 0.002,
            n_in: 5,
        };
        let r = asd_pocs(&ctx, &g, &p.unwrap(), &opts).unwrap();
        let corr = metrics::correlation(&truth, &r.volume);
        assert!(corr > 0.8, "correlation {corr}");
        // residual decreased
        assert!(r.residuals.last().unwrap() < &(r.residuals[0] * 0.8));
    }

    #[test]
    fn fault_asd_pocs_resumes_from_checkpoint_bit_identically() {
        // only the outer loop checkpoints; the inner OS-SART sweep and the
        // TV descent replay deterministically from the restored x
        use crate::coordinator::CheckpointConfig;
        let n = 14;
        let g = Geometry::cone_beam(n, 12);
        let truth = phantom::cube(n, 0.5, 1.0);
        let ctx = MultiGpu::gtx1080ti(2);
        let (p, _) = ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap();
        let p = p.unwrap();
        let dir = std::env::temp_dir()
            .join("tigre_algo_ckpt")
            .join(format!("asdpocs_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mk = |iterations, checkpoint| AsdPocsOpts {
            common: ReconOpts { iterations, checkpoint, ..Default::default() },
            subset_size: 3,
            tv_iters: 4,
            alpha: 0.002,
            n_in: 5,
        };
        let clean = asd_pocs(&ctx, &g, &p, &mk(3, None)).unwrap();
        let ck = Some(CheckpointConfig::new(&dir, 1));
        let _partial = asd_pocs(&ctx, &g, &p, &mk(2, ck.clone())).unwrap();
        let resumed = asd_pocs(&ctx, &g, &p, &mk(3, ck)).unwrap();
        assert_eq!(resumed.volume.data, clean.volume.data);
        assert_eq!(resumed.residuals, clean.residuals);
    }

    #[test]
    fn asd_pocs_smoother_than_plain_ossart_under_noise() {
        let n = 16;
        let g = Geometry::cone_beam(n, 12);
        let truth = phantom::cube(n, 0.5, 1.0);
        let ctx = MultiGpu::gtx1080ti(1);
        let (p, _) = ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap();
        let mut noisy = p.unwrap();
        let mut rng = crate::util::pcg::Pcg32::new(13);
        let scale = 0.05 * noisy.data.iter().cloned().fold(f32::MIN, f32::max);
        for v in &mut noisy.data {
            *v += scale * rng.normal() as f32;
        }
        let common = ReconOpts { iterations: 5, lambda: 0.9, ..Default::default() };
        let r_tv = asd_pocs(
            &ctx,
            &g,
            &noisy,
            &AsdPocsOpts {
                common: common.clone(),
                subset_size: 3,
                tv_iters: 8,
                alpha: 0.004,
                n_in: 8,
            },
        )
        .unwrap();
        let r_os = os_sart(&ctx, &g, &noisy, 3, &common).unwrap();
        let tv_tv = crate::kernels::tv::tv_value(&r_tv.volume);
        let tv_os = crate::kernels::tv::tv_value(&r_os.volume);
        assert!(tv_tv < tv_os, "asd-pocs TV {tv_tv} vs os-sart TV {tv_os}");
    }
}
