//! Property tests over the coordinator schedules: for random geometries,
//! device counts and device memories, the simulated schedules must
//! (a) never exceed device memory, (b) beat the naive baseline at scale,
//! (c) produce breakdown fractions that sum to 1, and (d) keep split
//! numerics equal to unsplit numerics.

use tigre::coordinator::{baseline, ExecMode, MultiGpu};
use tigre::geometry::Geometry;
use tigre::phantom;
use tigre::util::prop::{check, prop_assert};
use tigre::util::units::MIB;

#[test]
fn prop_fp_schedule_memory_and_breakdown() {
    check("fp schedule invariants", 40, |g| {
        let n = g.usize(64, 512);
        let n_angles = g.usize(8, 128);
        let n_gpus = g.usize(1, 4);
        let mem = (g.usize(48, 2048) as u64) * MIB;
        let geo = Geometry::cone_beam(n, n_angles);
        let ctx = MultiGpu::gtx1080ti(n_gpus).with_device_mem(mem);
        let Ok((_, stats)) = ctx.forward(&geo, None, ExecMode::SimOnly) else {
            // undersized device for even one slice + buffers: legal reject
            return Ok(());
        };
        prop_assert(stats.peak_device_bytes <= mem, "device memory exceeded")?;
        let (c, p, m, i) = stats.breakdown.fractions();
        prop_assert((c + p + m + i - 1.0).abs() < 1e-9, "fractions must sum to 1")?;
        prop_assert(stats.makespan_s > 0.0, "makespan positive")
    });
}

#[test]
fn prop_bp_schedule_memory_and_breakdown() {
    check("bp schedule invariants", 40, |g| {
        let n = g.usize(64, 512);
        let n_angles = g.usize(8, 128);
        let n_gpus = g.usize(1, 4);
        let mem = (g.usize(48, 2048) as u64) * MIB;
        let geo = Geometry::cone_beam(n, n_angles);
        let ctx = MultiGpu::gtx1080ti(n_gpus).with_device_mem(mem);
        let Ok((_, stats)) = ctx.backward(&geo, None, ExecMode::SimOnly) else {
            return Ok(());
        };
        prop_assert(stats.peak_device_bytes <= mem, "device memory exceeded")?;
        let (c, p, m, i) = stats.breakdown.fractions();
        prop_assert((c + p + m + i - 1.0).abs() < 1e-9, "fractions must sum to 1")
    });
}

#[test]
fn prop_proposed_never_slower_than_naive_at_scale() {
    check("proposed ≤ naive for compute-heavy problems", 12, |g| {
        let n = *g.choose(&[768usize, 1024, 1536]);
        let geo = Geometry::cone_beam(n, n);
        let n_gpus = g.usize(1, 4);
        let ctx = MultiGpu::gtx1080ti(n_gpus);
        let (_, fp) = ctx.forward(&geo, None, ExecMode::SimOnly).map_err(|e| e.to_string())?;
        let nfp = baseline::naive_forward(&ctx, &geo).map_err(|e| e.to_string())?;
        prop_assert(
            fp.makespan_s <= nfp.makespan_s * 1.02,
            format!("fp {} vs naive {}", fp.makespan_s, nfp.makespan_s),
        )?;
        let (_, bp) = ctx.backward(&geo, None, ExecMode::SimOnly).map_err(|e| e.to_string())?;
        let nbp = baseline::naive_backward(&ctx, &geo).map_err(|e| e.to_string())?;
        prop_assert(
            bp.makespan_s <= nbp.makespan_s * 1.02,
            format!("bp {} vs naive {}", bp.makespan_s, nbp.makespan_s),
        )
    });
}

#[test]
fn prop_split_fp_numerics_invariant_to_device_memory() {
    check("fp numerics invariant to split granularity", 8, |g| {
        let n = 16;
        let n_angles = g.usize(4, 12);
        let geo = Geometry::cone_beam(n, n_angles);
        let truth = phantom::shepp_logan(n);
        let reference = tigre::kernels::forward(
            &geo,
            &truth,
            tigre::kernels::Projector::Siddon,
            2,
        );
        let plane = (n * n * 4) as u64;
        let slices = g.usize(3, 10) as u64;
        let mem = slices * plane + 3 * n_angles as u64 * geo.single_proj_bytes();
        let n_gpus = g.usize(1, 3);
        let ctx = MultiGpu::gtx1080ti(n_gpus).with_device_mem(mem);
        let Ok((proj, _)) = ctx.forward(&geo, Some(&truth), ExecMode::Full) else {
            return Ok(());
        };
        let proj = proj.unwrap();
        for (a, b) in reference.data.iter().zip(&proj.data) {
            prop_assert(
                (a - b).abs() <= 2e-3 * (1.0 + a.abs()),
                format!("split numerics deviate: {a} vs {b}"),
            )?;
        }
        Ok(())
    });
}
