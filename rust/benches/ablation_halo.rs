//! Ablation — the regularizer halo depth N_in (paper §2.3): deeper halos
//! mean fewer host synchronizations but more redundant compute. The
//! paper lands on N_in = 60; this bench sweeps the trade-off on the
//! simulated node and reports where the optimum falls.

use tigre::coordinator::regularizer::rof_denoise_split;
use tigre::coordinator::MultiGpu;
use tigre::phantom;
use tigre::util::stats::Table;

fn main() {
    // A tall volume split over 4 devices, 120 total ROF iterations.
    let vol = phantom::random(24, 24, 96, 3);
    let total_iters = 120;
    let ctx = MultiGpu::gtx1080ti(4);

    let mut t = Table::new(&["N_in", "rounds", "sim time [s]", "redundant slices/device"]);
    let mut best = (0usize, f64::INFINITY);
    for &n_in in &[1usize, 5, 15, 30, 60, 120] {
        let (_, stats) =
            rof_denoise_split(&ctx, &vol, 0.2, total_iters, n_in).expect("halo schedule fits");
        let rounds = total_iters.div_ceil(n_in);
        let redundant = 2 * n_in.min(96); // halo slices recomputed per round
        if stats.makespan_s < best.1 {
            best = (n_in, stats.makespan_s);
        }
        t.row(vec![
            n_in.to_string(),
            rounds.to_string(),
            format!("{:.4}", stats.makespan_s),
            redundant.to_string(),
        ]);
    }
    println!("=== halo-depth (N_in) ablation (paper §2.3, N_in = 60) ===");
    println!("{}", t.render());
    println!(
        "optimum on this node: N_in = {} ({:.4}s) — paper picked 60 on its hardware",
        best.0, best.1
    );
}
