//! Hot-path micro-benchmarks of the real (native rust) kernels — the
//! substrate for the §Perf optimization pass. Not a paper figure; this
//! is the profile-and-iterate harness of EXPERIMENTS.md §Perf L3.

use std::time::Duration;

use tigre::geometry::Geometry;
use tigre::kernels::{self, BackprojWeight, Projector};
use tigre::phantom;
use tigre::util::stats::bench;
use tigre::volume::ProjectionSet;

fn main() {
    let threads = kernels::kernel_threads();
    println!("=== native kernel hot paths ({threads} host threads) ===");

    for &n in &[32usize, 48, 64] {
        let g = Geometry::cone_beam(n, 16);
        let v = phantom::shepp_logan(n);
        let r = bench(
            &format!("fp_siddon n={n} a=16"),
            1,
            3,
            Duration::from_millis(600),
            || {
                std::hint::black_box(kernels::forward(&g, &v, Projector::Siddon, threads));
            },
        );
        println!("{}", r.summary());
    }

    for &n in &[32usize, 48] {
        let g = Geometry::cone_beam(n, 16);
        let v = phantom::shepp_logan(n);
        let r = bench(
            &format!("fp_joseph n={n} a=16"),
            1,
            3,
            Duration::from_millis(600),
            || {
                std::hint::black_box(kernels::forward(&g, &v, Projector::Joseph, threads));
            },
        );
        println!("{}", r.summary());
    }

    for &n in &[32usize, 48, 64] {
        let g = Geometry::cone_beam(n, 16);
        let v = phantom::shepp_logan(n);
        let p = kernels::forward(&g, &v, Projector::Siddon, threads);
        let r = bench(
            &format!("bp_fdk n={n} a=16"),
            1,
            3,
            Duration::from_millis(600),
            || {
                std::hint::black_box(kernels::backward(&g, &p, BackprojWeight::Fdk, threads));
            },
        );
        println!("{}", r.summary());
    }

    // FDK filtering (FFT hot path)
    for &n in &[64usize, 128] {
        let g = Geometry::cone_beam(n, 32);
        let mut p = ProjectionSet::zeros_like(&g);
        let mut rng = tigre::util::pcg::Pcg32::new(1);
        for v in &mut p.data {
            *v = rng.next_f32();
        }
        let r = bench(
            &format!("fdk_filter n={n} a=32"),
            1,
            3,
            Duration::from_millis(500),
            || {
                let mut q = p.clone();
                tigre::kernels::filtering::fdk_filter(
                    &g,
                    &mut q,
                    tigre::kernels::filtering::Window::Hann,
                    threads,
                );
                std::hint::black_box(q);
            },
        );
        println!("{}", r.summary());
    }

    // TV / ROF regularizers
    let v = phantom::random(32, 32, 32, 5);
    let r = bench("rof_denoise 32³ x10", 1, 3, Duration::from_millis(500), || {
        std::hint::black_box(tigre::kernels::tv::rof_denoise(&v, 0.2, 10));
    });
    println!("{}", r.summary());
    let r = bench("tv_gradient 32³", 1, 3, Duration::from_millis(500), || {
        std::hint::black_box(tigre::kernels::tv::tv_gradient(&v));
    });
    println!("{}", r.summary());

    // DES scheduler itself (must be negligible vs what it models)
    let g = Geometry::cone_beam(2048, 2048);
    let ctx = tigre::coordinator::MultiGpu::gtx1080ti(4);
    let r = bench("des_schedule fp N=2048 4gpu", 1, 3, Duration::from_millis(500), || {
        std::hint::black_box(
            ctx.forward(&g, None, tigre::coordinator::ExecMode::SimOnly).unwrap(),
        );
    });
    println!("{}", r.summary());
}
