"""L1 Pallas kernel: voxel-driven FDK-weighted cone-beam backprojector.

Grid: one step per axial (z) slice. Each step keeps the full projection
chunk in VMEM (the paper streams 32-projection chunks; the BlockSpec is
that chunk's residency), computes the perspective footprint of every
voxel of the slice for every angle with vectorized bilinear gathers, and
accumulates the FDK-weighted samples.

The paper's N_x x N_y x N_angles thread blocks with N_z=8 voxel updates
per thread map here to: z-slice grid steps (coarse axis) x fully
vectorized (ny, nx, A) arithmetic inside the step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import geometry as geo
from .ref import bilinear


def _bp_kernel(proj_ref, params_ref, angles_ref, out_ref, *, nx, ny, nz, matched):
    proj = proj_ref[...]  # (A, nv, nu)
    params = params_ref[...]
    angles = angles_ref[...]
    a_count, nv, nu = proj.shape

    z = pl.program_id(0)
    lo, _ = geo.volume_bbox(params, nx, ny, nz)
    xs = lo[0] + (jnp.arange(nx) + 0.5) * params[geo.DX]
    ys = lo[1] + (jnp.arange(ny) + 0.5) * params[geo.DY]
    pz = lo[2] + (z + 0.5) * params[geo.DZ]
    px = xs[None, :]  # (1, nx)
    py = ys[:, None]  # (ny, 1)

    dsd = params[geo.DSD]
    dso = params[geo.DSO]
    # pseudo-matched weight scale (mirrors voxel_backproj.rs):
    # l*(dvox*M)^2/(du*dv), hoisted constant part
    dvox = jnp.minimum(jnp.minimum(params[geo.DX], params[geo.DY]), params[geo.DZ])
    matched_scale = dvox * dvox * dvox * dsd * dsd / (params[geo.DU] * params[geo.DV])

    def body(a, acc):
        theta = angles[a]
        s, c = jnp.sin(theta), jnp.cos(theta)
        rx = px * c + py * s  # (ny, nx)
        ry = -px * s + py * c
        depth = dso - rx
        t = dsd / jnp.maximum(depth, 1e-9)
        u = t * ry - params[geo.OFF_U]
        v = t * pz - params[geo.OFF_V]
        fu = u / params[geo.DU] + nu / 2.0 - 0.5
        fv = v / params[geo.DV] + nv / 2.0 - 0.5
        sample = bilinear(proj[a], fu, fv)
        if matched:
            w = matched_scale / jnp.maximum(depth, 1e-9) ** 2
        else:
            w = (dso / jnp.maximum(depth, 1e-9)) ** 2
        return acc + jnp.where(depth > 1e-9, w * sample, 0.0).astype(acc.dtype)

    acc = jax.lax.fori_loop(0, a_count, body, jnp.zeros((ny, nx), proj.dtype))
    out_ref[0, :, :] = acc


@functools.partial(jax.jit, static_argnames=("nx", "ny", "nz", "matched"))
def backward(proj, params, angles, nx, ny, nz, matched=False):
    """Pallas backprojection: proj (A,nv,nu) -> vol (nz,ny,nx)."""
    a, nv, nu = proj.shape
    kernel = functools.partial(_bp_kernel, nx=nx, ny=ny, nz=nz, matched=matched)
    return pl.pallas_call(
        kernel,
        grid=(nz,),
        in_specs=[
            pl.BlockSpec((a, nv, nu), lambda i: (0, 0, 0)),
            pl.BlockSpec((12,), lambda i: (0,)),
            pl.BlockSpec((a,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, ny, nx), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nz, ny, nx), proj.dtype),
        interpret=True,
    )(proj, params, angles)
