//! End-to-end integration: full reconstructions through the multi-GPU
//! coordinator, including the paper's headline claim — a volume much
//! larger than any single (simulated) device reconstructs identically to
//! the unconstrained run.

use tigre::algorithms::{self, ReconOpts};
use tigre::coordinator::{ExecMode, MultiGpu};
use tigre::geometry::Geometry;
use tigre::kernels::filtering::Window;
use tigre::metrics;
use tigre::phantom;

/// Devices shrunk so the 24³ image needs several slabs per device.
fn tiny_ctx(n: usize, n_angles: usize, n_gpus: usize) -> MultiGpu {
    let g = Geometry::cone_beam(n, n_angles);
    let plane = (n * n * 4) as u64;
    let mem = 8 * plane + 3 * 32.min(n_angles) as u64 * g.single_proj_bytes();
    MultiGpu::gtx1080ti(n_gpus).with_device_mem(mem)
}

#[test]
fn cgls_identical_on_big_and_tiny_devices() {
    let n = 20;
    let g = Geometry::cone_beam(n, 16);
    let truth = phantom::shepp_logan(n);
    let big = MultiGpu::gtx1080ti(1);
    let (p, _) = big.forward(&g, Some(&truth), ExecMode::Full).unwrap();
    let p = p.unwrap();
    let opts = ReconOpts { iterations: 6, nonneg: false, ..Default::default() };

    let r_big = algorithms::cgls(&big, &g, &p, &opts).unwrap();
    let tiny = tiny_ctx(n, 16, 2);
    let r_tiny = algorithms::cgls(&tiny, &g, &p, &opts).unwrap();

    let rel = metrics::rel_l2(&r_big.volume, &r_tiny.volume);
    assert!(rel < 2e-3, "device size must not change the numerics: {rel}");
    // and the tiny run must actually have split the image
    assert!(r_tiny.peak_device_bytes <= tiny.spec.mem_bytes);
}

#[test]
fn ossart_identical_on_big_and_tiny_devices() {
    let n = 16;
    let g = Geometry::cone_beam(n, 12);
    let truth = phantom::cube(n, 0.5, 1.0);
    let big = MultiGpu::gtx1080ti(1);
    let (p, _) = big.forward(&g, Some(&truth), ExecMode::Full).unwrap();
    let p = p.unwrap();
    let opts = ReconOpts { iterations: 3, lambda: 0.8, ..Default::default() };

    let r_big = algorithms::os_sart(&big, &g, &p, 4, &opts).unwrap();
    let r_tiny = algorithms::os_sart(&tiny_ctx(n, 12, 3), &g, &p, 4, &opts).unwrap();
    let rel = metrics::rel_l2(&r_big.volume, &r_tiny.volume);
    assert!(rel < 2e-3, "os-sart split deviation {rel}");
}

#[test]
fn fdk_identical_on_big_and_tiny_devices() {
    // FDK through split devices must equal FDK on unconstrained devices.
    let n = 24;
    let g = Geometry::cone_beam(n, 48);
    let truth = phantom::shepp_logan(n);
    let big = MultiGpu::gtx1080ti(1);
    let (p, _) = big.forward(&g, Some(&truth), ExecMode::Full).unwrap();
    let p = p.unwrap();
    let r_big = algorithms::fdk(&big, &g, &p, Window::Hann).unwrap();
    let r_tiny = algorithms::fdk(&tiny_ctx(n, 48, 2), &g, &p, Window::Hann).unwrap();
    let rel = metrics::rel_l2(&r_big.volume, &r_tiny.volume);
    assert!(rel < 1e-4, "split FDK deviates: {rel}");
    // and it does reconstruct the object (structure present)
    let corr = metrics::correlation(&truth, &r_big.volume);
    assert!(corr > 0.55, "FDK correlation {corr}");
}

#[test]
fn fig10_shape_cgls_beats_fdk_at_third_of_angles() {
    // The paper's coffee-bean comparison, at miniature scale: with ~1/3
    // of the angles, CGLS-style iterative recon beats FDK on RMSE.
    let n = 20;
    let truth = phantom::bean(n, n, n);
    let g = Geometry::cone_beam(n, 20); // sparse angles
    let ctx = MultiGpu::gtx1080ti(2);
    let (p, _) = ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap();
    let p = p.unwrap();
    let fdk = algorithms::fdk(&ctx, &g, &p, Window::RamLak).unwrap();
    let cgls = algorithms::cgls(
        &ctx,
        &g,
        &p,
        &ReconOpts { iterations: 10, ..Default::default() },
    )
    .unwrap();
    let e_fdk = metrics::rmse(&truth, &fdk.volume);
    let e_cgls = metrics::rmse(&truth, &cgls.volume);
    assert!(e_cgls < e_fdk, "cgls {e_cgls} vs fdk {e_fdk}");
}

#[test]
fn fig11_shape_ossart_on_asymmetric_fossil() {
    // The paper's Ichthyosaur reconstruction shape: strongly anisotropic
    // volume, OS-SART with subsets.
    let (nx, ny, nz) = (24, 8, 14);
    let truth = phantom::fossil(nx, ny, nz, 7);
    let g = Geometry::cone_beam_anisotropic([nx, ny, nz], [28, 28], 18);
    let ctx = MultiGpu::gtx1080ti(2);
    let (p, _) = ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap();
    let r = algorithms::os_sart(
        &ctx,
        &g,
        &p.unwrap(),
        6,
        &ReconOpts { iterations: 6, lambda: 0.9, ..Default::default() },
    )
    .unwrap();
    let corr = metrics::correlation(&truth, &r.volume);
    assert!(corr > 0.7, "fossil OS-SART correlation {corr}");
}

#[test]
fn algorithm_sim_time_accumulates_per_iteration() {
    // The simulated algorithm time (behind the paper's "512³ CGLS in
    // 61 s" anchor) accumulates with iteration count. (Multi-GPU op-level
    // scaling is covered by the coordinator tests at realistic sizes;
    // tiny problems are overhead-dominated — the paper observes the same
    // effect at N=128.)
    let n = 16;
    let g = Geometry::cone_beam(n, 16);
    let truth = phantom::cube(n, 0.4, 1.0);
    let ctx = MultiGpu::gtx1080ti(1);
    let (p, _) = ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap();
    let p = p.unwrap();
    let t2 = algorithms::cgls(
        &ctx,
        &g,
        &p,
        &ReconOpts { iterations: 2, nonneg: false, ..Default::default() },
    )
    .unwrap()
    .sim_time_s;
    let t6 = algorithms::cgls(
        &ctx,
        &g,
        &p,
        &ReconOpts { iterations: 6, nonneg: false, ..Default::default() },
    )
    .unwrap()
    .sim_time_s;
    assert!(t6 > t2 * 2.0, "6 iters {t6} vs 2 iters {t2}");
}
