//! PCG32 pseudo-random number generator (O'Neill, 2014).
//!
//! Deterministic, seedable, fast; used by phantoms, synthetic noise, the
//! property-testing framework and workload generators. No external `rand`
//! crate is available offline, so this is the project-wide PRNG.

/// A PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create a generator from a seed and the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream selector (`inc`).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut g = Self { state: 0, inc: (stream << 1) | 1 };
        g.next_u32();
        g.state = g.state.wrapping_add(seed);
        g.next_u32();
        g
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output (two 32-bit draws).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [lo, hi] (inclusive). Debiased via rejection.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "range_u64: lo > hi");
        if lo == 0 && hi == u64::MAX {
            return self.next_u64();
        }
        let span = hi - lo + 1;
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + v % span;
            }
        }
    }

    /// Uniform usize in [lo, hi] (inclusive).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal deviate (Box–Muller; one value per call, simple).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > f64::EPSILON {
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i);
            xs.swap(i, j);
        }
    }

    /// Poisson deviate via Knuth's algorithm for small lambda, normal
    /// approximation above 64 (adequate for detector noise simulation).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let v = lambda + lambda.sqrt() * self.normal();
            return v.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.next_f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut g = Pcg32::new(7);
        for _ in 0..1000 {
            let v = g.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_inclusive_bounds_hit() {
        let mut g = Pcg32::new(3);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            match g.range_u64(0, 3) {
                0 => lo_seen = true,
                3 => hi_seen = true,
                v => assert!(v <= 3),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_mean_and_var_reasonable() {
        let mut g = Pcg32::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| g.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn poisson_mean_tracks_lambda() {
        let mut g = Pcg32::new(5);
        for &lam in &[0.5, 4.0, 30.0, 200.0] {
            let n = 5000;
            let mean = (0..n).map(|_| g.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((mean - lam).abs() < lam.max(1.0) * 0.1, "lam {lam} mean {mean}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = Pcg32::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        g.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
