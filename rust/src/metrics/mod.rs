//! Image-quality and convergence metrics for the reconstruction
//! experiments (Fig. 10/11 analogues report these against ground truth).

use crate::volume::Volume;

/// Root-mean-square error between two equal-shaped volumes.
pub fn rmse(a: &Volume, b: &Volume) -> f64 {
    assert_eq!(a.data.len(), b.data.len(), "rmse shape mismatch");
    if a.data.is_empty() {
        return 0.0;
    }
    let sum: f64 = a
        .data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum();
    (sum / a.data.len() as f64).sqrt()
}

/// Mean absolute error.
pub fn mae(a: &Volume, b: &Volume) -> f64 {
    assert_eq!(a.data.len(), b.data.len());
    if a.data.is_empty() {
        return 0.0;
    }
    a.data.iter().zip(&b.data).map(|(x, y)| ((*x - *y) as f64).abs()).sum::<f64>()
        / a.data.len() as f64
}

/// Peak signal-to-noise ratio in dB; the peak is the reference's dynamic
/// range (max − min), matching the convention of image-recon papers.
pub fn psnr(reference: &Volume, test: &Volume) -> f64 {
    let e = rmse(reference, test);
    let max = reference.data.iter().cloned().fold(f32::MIN, f32::max) as f64;
    let min = reference.data.iter().cloned().fold(f32::MAX, f32::min) as f64;
    let peak = (max - min).max(f64::MIN_POSITIVE);
    if e == 0.0 {
        return f64::INFINITY;
    }
    20.0 * (peak / e).log10()
}

/// Pearson correlation coefficient between two volumes.
pub fn correlation(a: &Volume, b: &Volume) -> f64 {
    assert_eq!(a.data.len(), b.data.len());
    let n = a.data.len() as f64;
    if n == 0.0 {
        return 1.0;
    }
    let ma = a.data.iter().map(|v| *v as f64).sum::<f64>() / n;
    let mb = b.data.iter().map(|v| *v as f64).sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (x, y) in a.data.iter().zip(&b.data) {
        let dx = *x as f64 - ma;
        let dy = *y as f64 - mb;
        cov += dx * dy;
        va += dx * dx;
        vb += dy * dy;
    }
    if va == 0.0 || vb == 0.0 {
        return if va == vb { 1.0 } else { 0.0 };
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// Global SSIM with the standard constants, computed from whole-volume
/// mean/variance/covariance (a single-window SSIM; adequate for tracking
/// relative reconstruction quality across algorithms).
pub fn ssim_global(a: &Volume, b: &Volume) -> f64 {
    assert_eq!(a.data.len(), b.data.len());
    let n = a.data.len() as f64;
    if n == 0.0 {
        return 1.0;
    }
    let max = a.data.iter().cloned().fold(f32::MIN, f32::max) as f64;
    let min = a.data.iter().cloned().fold(f32::MAX, f32::min) as f64;
    let l = (max - min).max(f64::MIN_POSITIVE);
    let c1 = (0.01 * l) * (0.01 * l);
    let c2 = (0.03 * l) * (0.03 * l);
    let ma = a.data.iter().map(|v| *v as f64).sum::<f64>() / n;
    let mb = b.data.iter().map(|v| *v as f64).sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (x, y) in a.data.iter().zip(&b.data) {
        let dx = *x as f64 - ma;
        let dy = *y as f64 - mb;
        cov += dx * dy;
        va += dx * dx;
        vb += dy * dy;
    }
    cov /= n;
    va /= n;
    vb /= n;
    ((2.0 * ma * mb + c1) * (2.0 * cov + c2)) / ((ma * ma + mb * mb + c1) * (va + vb + c2))
}

/// Relative residual `‖a − b‖₂ / ‖a‖₂` (convergence tracking).
pub fn rel_l2(a: &Volume, b: &Volume) -> f64 {
    assert_eq!(a.data.len(), b.data.len());
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in a.data.iter().zip(&b.data) {
        let d = (*x - *y) as f64;
        num += d * d;
        den += (*x as f64) * (*x as f64);
    }
    if den == 0.0 {
        return if num == 0.0 { 0.0 } else { f64::INFINITY };
    }
    (num / den).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phantom;

    #[test]
    fn identical_volumes_are_perfect() {
        let v = phantom::shepp_logan(16);
        assert_eq!(rmse(&v, &v), 0.0);
        assert_eq!(mae(&v, &v), 0.0);
        assert!(psnr(&v, &v).is_infinite());
        assert!((correlation(&v, &v) - 1.0).abs() < 1e-12);
        assert!((ssim_global(&v, &v) - 1.0).abs() < 1e-9);
        assert_eq!(rel_l2(&v, &v), 0.0);
    }

    #[test]
    fn rmse_known_value() {
        let a = Volume { nx: 2, ny: 1, nz: 1, data: vec![0.0, 0.0] };
        let b = Volume { nx: 2, ny: 1, nz: 1, data: vec![3.0, 4.0] };
        assert!((rmse(&a, &b) - (12.5f64).sqrt()).abs() < 1e-12);
        assert!((mae(&a, &b) - 3.5).abs() < 1e-12);
    }

    #[test]
    fn noisier_is_worse() {
        let v = phantom::shepp_logan(16);
        let mut n1 = v.clone();
        let mut n2 = v.clone();
        let mut rng = crate::util::pcg::Pcg32::new(1);
        for (a, b) in n1.data.iter_mut().zip(n2.data.iter_mut()) {
            let e = rng.normal() as f32;
            *a += 0.01 * e;
            *b += 0.1 * e;
        }
        assert!(psnr(&v, &n1) > psnr(&v, &n2));
        assert!(rmse(&v, &n1) < rmse(&v, &n2));
        assert!(ssim_global(&v, &n1) > ssim_global(&v, &n2));
    }

    #[test]
    fn correlation_sign() {
        let a = Volume { nx: 3, ny: 1, nz: 1, data: vec![1.0, 2.0, 3.0] };
        let b = Volume { nx: 3, ny: 1, nz: 1, data: vec![-1.0, -2.0, -3.0] };
        assert!((correlation(&a, &b) + 1.0).abs() < 1e-12);
    }
}
