//! Total-variation regularization kernels (paper §2.3).
//!
//! Two minimizers, as in TIGRE:
//!  * [`tv_gradient_descent`] — steepest-descent TV minimization (the
//!    inner loop of ASD-POCS / POCS-TV algorithms).
//!  * [`rof_denoise`] — Rudin–Osher–Fatemi model via Chambolle's dual
//!    projection algorithm.
//!
//! Both are *coupled* neighbourhood operators: one iteration reads the
//! 6-neighbourhood of every voxel. That single-voxel coupling is exactly
//! why the coordinator can run `N_in` independent iterations on a slab
//! with an `N_in`-deep halo before re-synchronizing (paper Fig. 6) — the
//! property is proven by the halo tests in `coordinator::regularizer`.

use crate::volume::Volume;

const EPS: f32 = 1e-8;

/// Total variation (isotropic, forward differences, reflecting boundary).
pub fn tv_value(v: &Volume) -> f64 {
    let (nx, ny, nz) = (v.nx, v.ny, v.nz);
    let mut tv = 0.0f64;
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let c = v.at(x, y, z);
                let dx = if x + 1 < nx { v.at(x + 1, y, z) - c } else { 0.0 };
                let dy = if y + 1 < ny { v.at(x, y + 1, z) - c } else { 0.0 };
                let dz = if z + 1 < nz { v.at(x, y, z + 1) - c } else { 0.0 };
                tv += ((dx * dx + dy * dy + dz * dz) as f64).sqrt();
            }
        }
    }
    tv
}

/// Gradient of the (smoothed) isotropic TV functional.
pub fn tv_gradient(v: &Volume) -> Volume {
    let (nx, ny, nz) = (v.nx, v.ny, v.nz);
    let mut g = Volume::zeros(nx, ny, nz);
    let at = |x: isize, y: isize, z: isize| -> f32 {
        // reflecting boundary
        let cx = x.clamp(0, nx as isize - 1) as usize;
        let cy = y.clamp(0, ny as isize - 1) as usize;
        let cz = z.clamp(0, nz as isize - 1) as usize;
        v.at(cx, cy, cz)
    };
    // |∇v| at (x,y,z) with forward differences
    let mag = |x: isize, y: isize, z: isize| -> f32 {
        let c = at(x, y, z);
        let dx = at(x + 1, y, z) - c;
        let dy = at(x, y + 1, z) - c;
        let dz = at(x, y, z + 1) - c;
        (dx * dx + dy * dy + dz * dz + EPS).sqrt()
    };
    for z in 0..nz as isize {
        for y in 0..ny as isize {
            for x in 0..nx as isize {
                let c = at(x, y, z);
                // d/dc of sqrt terms containing c: the term at (x,y,z)
                // and the three backward terms.
                let m0 = mag(x, y, z);
                let t0 = -((at(x + 1, y, z) - c) + (at(x, y + 1, z) - c) + (at(x, y, z + 1) - c))
                    / m0;
                let tx = (c - at(x - 1, y, z)) / mag(x - 1, y, z);
                let ty = (c - at(x, y - 1, z)) / mag(x, y - 1, z);
                let tz = (c - at(x, y, z - 1)) / mag(x, y, z - 1);
                *g.at_mut(x as usize, y as usize, z as usize) = t0 + tx + ty + tz;
            }
        }
    }
    g
}

/// `iters` steps of normalized steepest descent on TV:
/// `x ← x − α·‖x‖·ĝ` with ĝ the unit TV gradient (TIGRE's `minimizeTV`).
pub fn tv_gradient_descent(v: &mut Volume, iters: usize, alpha: f32) {
    for _ in 0..iters {
        let g = tv_gradient(v);
        let gn = g.norm2() as f32;
        if gn <= EPS {
            return;
        }
        // step size relative to the image magnitude, as in TIGRE's
        // minimizeTV (dtvg = alpha * im3Dnorm(x))
        let scale = alpha * v.norm2() as f32 / gn;
        for (x, gv) in v.data.iter_mut().zip(&g.data) {
            *x -= scale * gv;
        }
    }
}

/// ROF denoising `min_x ‖x − f‖²/2 + λ·TV(x)` via Chambolle's dual
/// projection (2004), 3-D variant with step τ = 1/12.
pub fn rof_denoise(f: &Volume, lambda: f32, iters: usize) -> Volume {
    let (nx, ny, nz) = (f.nx, f.ny, f.nz);
    let n = f.data.len();
    // dual field p : 3 components
    let mut px = vec![0.0f32; n];
    let mut py = vec![0.0f32; n];
    let mut pz = vec![0.0f32; n];
    let mut div = vec![0.0f32; n];
    let tau = 1.0 / 12.0;

    let idx = |x: usize, y: usize, z: usize| (z * ny + y) * nx + x;

    for _ in 0..iters {
        // div p (backward differences, homogeneous boundary)
        for z in 0..nz {
            for y in 0..ny {
                for x in 0..nx {
                    let i = idx(x, y, z);
                    let mut d = px[i] + py[i] + pz[i];
                    if x > 0 {
                        d -= px[idx(x - 1, y, z)];
                    }
                    if y > 0 {
                        d -= py[idx(x, y - 1, z)];
                    }
                    if z > 0 {
                        d -= pz[idx(x, y, z - 1)];
                    }
                    div[i] = d;
                }
            }
        }
        // p ← (p + τ∇(div p − f/λ)) / (1 + τ|∇(div p − f/λ)|)
        for z in 0..nz {
            for y in 0..ny {
                for x in 0..nx {
                    let i = idx(x, y, z);
                    let w = div[i] - f.data[i] / lambda;
                    let wx1 = if x + 1 < nx {
                        div[idx(x + 1, y, z)] - f.data[idx(x + 1, y, z)] / lambda
                    } else {
                        w
                    };
                    let wy1 = if y + 1 < ny {
                        div[idx(x, y + 1, z)] - f.data[idx(x, y + 1, z)] / lambda
                    } else {
                        w
                    };
                    let wz1 = if z + 1 < nz {
                        div[idx(x, y, z + 1)] - f.data[idx(x, y, z + 1)] / lambda
                    } else {
                        w
                    };
                    let gx = wx1 - w;
                    let gy = wy1 - w;
                    let gz = wz1 - w;
                    let mag = (gx * gx + gy * gy + gz * gz).sqrt();
                    let denom = 1.0 + tau * mag;
                    px[i] = (px[i] + tau * gx) / denom;
                    py[i] = (py[i] + tau * gy) / denom;
                    pz[i] = (pz[i] + tau * gz) / denom;
                }
            }
        }
    }
    // x = f − λ·div p  (recompute div with final p)
    let mut out = Volume::zeros(nx, ny, nz);
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let i = idx(x, y, z);
                let mut d = px[i] + py[i] + pz[i];
                if x > 0 {
                    d -= px[idx(x - 1, y, z)];
                }
                if y > 0 {
                    d -= py[idx(x, y - 1, z)];
                }
                if z > 0 {
                    d -= pz[idx(x, y, z - 1)];
                }
                out.data[i] = f.data[i] - lambda * d;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phantom;

    #[test]
    fn tv_of_constant_is_zero() {
        let mut v = Volume::zeros(8, 8, 8);
        for x in &mut v.data {
            *x = 3.0;
        }
        assert_eq!(tv_value(&v), 0.0);
    }

    #[test]
    fn tv_of_step_edge_is_area() {
        // A half-space step of height 1 across x: TV = number of edge
        // faces = ny·nz.
        let v = Volume::from_fn(8, 8, 8, |x, _, _| if x < 4 { 0.0 } else { 1.0 });
        assert!((tv_value(&v) - 64.0).abs() < 1e-6);
    }

    #[test]
    fn gradient_descent_reduces_tv() {
        let mut v = phantom::random(12, 12, 12, 1);
        let before = tv_value(&v);
        tv_gradient_descent(&mut v, 20, 0.002);
        let after = tv_value(&v);
        assert!(after < before * 0.95, "TV {before} → {after}");
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let v = phantom::random(6, 6, 6, 2);
        let g = tv_gradient(&v);
        let h = 1e-3f32;
        for &(x, y, z) in &[(2usize, 3usize, 2usize), (0, 0, 0), (5, 5, 5), (1, 4, 3)] {
            let mut vp = v.clone();
            *vp.at_mut(x, y, z) += h;
            let mut vm = v.clone();
            *vm.at_mut(x, y, z) -= h;
            let fd = (tv_value(&vp) - tv_value(&vm)) as f32 / (2.0 * h);
            let an = g.at(x, y, z);
            assert!(
                (fd - an).abs() < 0.05 * (1.0 + fd.abs()),
                "voxel ({x},{y},{z}): fd {fd} vs analytic {an}"
            );
        }
    }

    #[test]
    fn rof_smooths_noise_but_keeps_structure() {
        let clean = phantom::cube(16, 0.5, 1.0);
        let mut noisy = clean.clone();
        let mut rng = crate::util::pcg::Pcg32::new(4);
        for v in &mut noisy.data {
            *v += 0.2 * rng.normal() as f32;
        }
        let den = rof_denoise(&noisy, 0.15, 40);
        let e_noisy = crate::metrics::rmse(&clean, &noisy);
        let e_den = crate::metrics::rmse(&clean, &den);
        assert!(e_den < e_noisy * 0.8, "rmse {e_noisy} → {e_den}");
    }

    #[test]
    fn rof_of_constant_is_identity() {
        let mut v = Volume::zeros(6, 6, 6);
        for x in &mut v.data {
            *x = 2.0;
        }
        let d = rof_denoise(&v, 0.2, 10);
        for (a, b) in v.data.iter().zip(&d.data) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn rof_lambda_zero_is_identity() {
        let v = phantom::random(6, 6, 6, 9);
        let d = rof_denoise(&v, 1e-9, 5);
        for (a, b) in v.data.iter().zip(&d.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
