//! FDK (Feldkamp–Davis–Kress) analytic reconstruction: cosine-weight +
//! ramp-filter the projections, then one FDK-weighted backprojection.

use crate::coordinator::{ExecMode, MultiGpu};
use crate::geometry::Geometry;
use crate::kernels::filtering::{fdk_filter, Window};
use crate::volume::{ProjectionSet, Volume};

use super::common::ReconResult;

/// FDK reconstruction. `window` defaults to Hann in the examples (as the
/// paper's reconstructions do for measured data).
pub fn fdk(
    ctx: &MultiGpu,
    g: &Geometry,
    proj: &ProjectionSet,
    window: Window,
) -> anyhow::Result<ReconResult> {
    let threads = crate::kernels::kernel_threads();
    let mut filtered = proj.clone();
    fdk_filter(g, &mut filtered, window, threads);

    let (vol, stats) = ctx.backward(g, Some(&filtered), ExecMode::Full)?;
    let mut volume = vol.expect("Full mode returns data");

    // FDK normalization beyond the Δθ/2 folded into the filter: the ramp
    // filter was applied at the *physical* detector pitch (du = mag·du_iso),
    // which under-weights by one magnification factor relative to the
    // virtual iso-centre detector of the textbook formula.
    let mag = (g.dsd / g.dso) as f32;
    volume.scale(mag);

    Ok(ReconResult {
        volume,
        residuals: vec![],
        sim_time_s: stats.makespan_s,
        peak_device_bytes: stats.peak_device_bytes,
        backoffs: 0,
    })
}

/// Convenience: forward-project a phantom and reconstruct it (used by
/// tests and benches).
pub fn project_and_fdk(
    ctx: &MultiGpu,
    g: &Geometry,
    phantom: &Volume,
    window: Window,
) -> anyhow::Result<ReconResult> {
    let (p, _) = ctx.forward(g, Some(phantom), ExecMode::Full)?;
    fdk(ctx, g, &p.unwrap(), window)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics;
    use crate::phantom;

    /// FDK with plenty of angles must reconstruct values close to the
    /// phantom (scale included): checks both structure and amplitude.
    #[test]
    fn fdk_reconstructs_sphere_amplitude() {
        let n = 32;
        let c = (n as f64 - 1.0) / 2.0;
        let truth = crate::volume::Volume::from_fn(n, n, n, |x, y, z| {
            let d = ((x as f64 - c).powi(2) + (y as f64 - c).powi(2) + (z as f64 - c).powi(2))
                .sqrt();
            if d < 9.0 {
                1.0
            } else {
                0.0
            }
        });
        let g = Geometry::cone_beam(n, 96);
        let ctx = MultiGpu::gtx1080ti(2);
        let r = project_and_fdk(&ctx, &g, &truth, Window::RamLak).unwrap();
        // centre of the sphere should be near 1.0 (within discretization)
        let centre = r.volume.at(n / 2, n / 2, n / 2);
        assert!(
            (0.6..1.4).contains(&centre),
            "FDK amplitude at sphere centre: {centre}"
        );
        // air stays near 0
        let air = r.volume.at(1, n / 2, n / 2);
        assert!(air.abs() < 0.25, "air value {air}");
        // overall correlation with the truth is high
        let corr = metrics::correlation(&truth, &r.volume);
        assert!(corr > 0.85, "correlation {corr}");
    }

    #[test]
    fn fdk_angular_undersampling_degrades_quality() {
        // The Fig. 10 effect: FDK with ⅓ of the angles shows artefacts.
        let n = 24;
        let truth = phantom::shepp_logan(n);
        let ctx = MultiGpu::gtx1080ti(1);
        let g_full = Geometry::cone_beam(n, 72);
        let g_sub = Geometry::cone_beam(n, 24);
        let full = project_and_fdk(&ctx, &g_full, &truth, Window::RamLak).unwrap();
        let sub = project_and_fdk(&ctx, &g_sub, &truth, Window::RamLak).unwrap();
        let e_full = metrics::rmse(&truth, &full.volume);
        let e_sub = metrics::rmse(&truth, &sub.volume);
        assert!(e_sub > e_full, "undersampled {e_sub} vs full {e_full}");
    }

    #[test]
    fn hann_window_smooths() {
        let n = 24;
        let truth = phantom::shepp_logan(n);
        let ctx = MultiGpu::gtx1080ti(1);
        let g = Geometry::cone_beam(n, 48);
        let ram = project_and_fdk(&ctx, &g, &truth, Window::RamLak).unwrap();
        let han = project_and_fdk(&ctx, &g, &truth, Window::Hann).unwrap();
        // Hann suppresses high frequencies → smoother volume (smaller TV)
        let tv_ram = crate::kernels::tv::tv_value(&ram.volume);
        let tv_han = crate::kernels::tv::tv_value(&han.volume);
        assert!(tv_han < tv_ram, "hann TV {tv_han} vs ramlak TV {tv_ram}");
    }
}
