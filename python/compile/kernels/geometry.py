"""Shared cone-beam geometry math for the L1 kernels and the L2 model.

The conventions mirror `rust/src/geometry/mod.rs` exactly (the rust side
loads the AOT artifacts and feeds them the same `params` vector):

    params = [dsd, dso, dx, dy, dz, du, dv, off_u, off_v, ox, oy, oz]

* volume: nx*ny*nz voxels of pitch (dx,dy,dz), centred at (ox,oy,oz)
* source at angle t: (dso*cos t, dso*sin t, 0)
* detector centre: -(dsd-dso)*(cos t, sin t, 0) + off_u*u_hat + off_v*v_hat
* u_hat = (-sin t, cos t, 0), v_hat = (0, 0, 1)
* pixel (iu, iv) at u=(iu+.5-nu/2)*du, v=(iv+.5-nv/2)*dv
"""

import jax.numpy as jnp

# params vector layout indices
DSD, DSO, DX, DY, DZ, DU, DV, OFF_U, OFF_V, OX, OY, OZ = range(12)


def volume_bbox(params, nx, ny, nz):
    """(lo, hi) corners of the volume in mm, each a length-3 array."""
    half = jnp.array(
        [
            nx * params[DX] / 2.0,
            ny * params[DY] / 2.0,
            nz * params[DZ] / 2.0,
        ]
    )
    center = jnp.array([params[OX], params[OY], params[OZ]])
    return center - half, center + half


def source_pos(params, theta):
    """Source position at angle theta (scalar or array)."""
    return jnp.stack(
        [params[DSO] * jnp.cos(theta), params[DSO] * jnp.sin(theta), jnp.zeros_like(theta)],
        axis=-1,
    )


def detector_pixels(params, theta, nu, nv):
    """World positions of all detector pixel centres at angle `theta`.

    Returns an array of shape (nv, nu, 3). Built componentwise (no
    constant basis vectors: Pallas kernels may not capture constant
    arrays).
    """
    s, c = jnp.sin(theta), jnp.cos(theta)
    back = params[DSD] - params[DSO]
    # u_hat = (-s, c, 0); v_hat = (0, 0, 1)
    iu = jnp.arange(nu)
    iv = jnp.arange(nv)
    u = (iu + 0.5 - nu / 2.0) * params[DU] + params[OFF_U]  # (nu,) in-plane
    v = (iv + 0.5 - nv / 2.0) * params[DV] + params[OFF_V]  # (nv,) along z
    px = -back * c + u * (-s)  # (nu,)
    py = -back * s + u * c  # (nu,)
    pz = v  # (nv,)
    zero_nv = jnp.zeros((nv,), dtype=px.dtype)
    x = px[None, :] + zero_nv[:, None]  # (nv, nu)
    y = py[None, :] + zero_nv[:, None]
    z = pz[:, None] + jnp.zeros((nu,), dtype=px.dtype)[None, :]
    return jnp.stack([x, y, z], axis=-1)


def clip_ray_to_box(src, dst, lo, hi):
    """Slab-method clip of rays src->dst against the box [lo, hi].

    src: (3,), dst: (..., 3). Returns (tmin, tmax) with shape dst.shape[:-1];
    rays that miss have tmin >= tmax.
    """
    d = dst - src  # (..., 3)
    eps = 1e-12
    safe = jnp.where(jnp.abs(d) < eps, jnp.where(d >= 0, eps, -eps), d)
    t0 = (lo - src) / safe
    t1 = (hi - src) / safe
    tsmall = jnp.minimum(t0, t1)
    tbig = jnp.maximum(t0, t1)
    # degenerate axes: ray parallel and outside -> miss
    inside = (src >= lo) & (src <= hi)
    parallel = jnp.abs(d) < eps
    tsmall = jnp.where(parallel & ~inside, jnp.inf, tsmall)
    tbig = jnp.where(parallel & ~inside, -jnp.inf, tbig)
    tmin = jnp.maximum(jnp.max(tsmall, axis=-1), 0.0)
    tmax = jnp.minimum(jnp.min(tbig, axis=-1), 1.0)
    return tmin, tmax


def trilinear(vol, params, lo, pts):
    """Trilinear interpolation of `vol` (nz, ny, nx) at world points
    `pts` (..., 3), with clamp addressing (CUDA-texture-like), sampling at
    voxel centres."""
    nz, ny, nx = vol.shape
    fx = (pts[..., 0] - lo[0]) / params[DX] - 0.5
    fy = (pts[..., 1] - lo[1]) / params[DY] - 0.5
    fz = (pts[..., 2] - lo[2]) / params[DZ] - 0.5
    x0 = jnp.floor(fx)
    y0 = jnp.floor(fy)
    z0 = jnp.floor(fz)
    wx = (fx - x0).astype(vol.dtype)
    wy = (fy - y0).astype(vol.dtype)
    wz = (fz - z0).astype(vol.dtype)

    def cl(i, n):
        return jnp.clip(i, 0, n - 1).astype(jnp.int32)

    x0i, x1i = cl(x0, nx), cl(x0 + 1, nx)
    y0i, y1i = cl(y0, ny), cl(y0 + 1, ny)
    z0i, z1i = cl(z0, nz), cl(z0 + 1, nz)

    flat = vol.reshape(-1)

    def at(zi, yi, xi):
        return flat[(zi * ny + yi) * nx + xi]

    v000 = at(z0i, y0i, x0i)
    v100 = at(z0i, y0i, x1i)
    v010 = at(z0i, y1i, x0i)
    v110 = at(z0i, y1i, x1i)
    v001 = at(z1i, y0i, x0i)
    v101 = at(z1i, y0i, x1i)
    v011 = at(z1i, y1i, x0i)
    v111 = at(z1i, y1i, x1i)

    c00 = v000 + (v100 - v000) * wx
    c10 = v010 + (v110 - v010) * wx
    c01 = v001 + (v101 - v001) * wx
    c11 = v011 + (v111 - v011) * wx
    c0 = c00 + (c10 - c00) * wy
    c1 = c01 + (c11 - c01) * wy
    return c0 + (c1 - c0) * wz


def fp_n_steps(nx, ny, nz, step_frac=0.5):
    """Static sample count for the interpolated projector: enough steps to
    cover the volume diagonal at `step_frac` of the voxel pitch."""
    diag = (nx**2 + ny**2 + nz**2) ** 0.5
    return max(1, int(diag / step_frac + 1))
