//! Ablation — the page-lock policy (paper §2.1–2.2): pin vs never-pin
//! vs always-pin across sizes and GPU counts, for both operators.
//!
//! Paper claims reproduced here:
//!  * 1–2 GPUs: pinning pays off iff the image must be split;
//!  * >2 GPUs: pinning always pays off (simultaneous copies);
//!  * BP pinning is costlier than FP pinning (forces allocation).

use tigre::coordinator::{backward, forward, splitter, ExecMode, MultiGpu};
use tigre::geometry::Geometry;
use tigre::simgpu::SimNode;
use tigre::util::stats::Table;

fn run_with_pin(n: usize, gpus: usize, fwd: bool, pin: Option<bool>) -> (f64, bool) {
    let g = Geometry::cone_beam(n, n);
    let ctx = MultiGpu::gtx1080ti(gpus);
    let plan_fn = if fwd { splitter::plan_forward } else { splitter::plan_backward };
    let mut plan = plan_fn(&g, gpus, ctx.spec.mem_bytes, &ctx.split).unwrap();
    if let Some(p) = pin {
        plan.pin_image = p;
    }
    let mut sim = SimNode::new(gpus, ctx.spec.clone(), ctx.cost.clone());
    if fwd {
        forward::simulate(&g, &plan, &mut sim).expect("schedule fits device memory");
    } else {
        backward::simulate(&g, &plan, &mut sim).expect("schedule fits device memory");
    }
    (sim.makespan(), plan.image_split)
}

fn main() {
    let mut t = Table::new(&["op", "N", "GPUs", "policy [s]", "no-pin [s]", "force-pin [s]", "policy wins"]);
    for &fwd in &[true, false] {
        for &n in &[512usize, 1024, 2048] {
            for &gpus in &[1usize, 2, 4] {
                let (policy, _split) = run_with_pin(n, gpus, fwd, None);
                let (no_pin, _) = run_with_pin(n, gpus, fwd, Some(false));
                let (force, _) = run_with_pin(n, gpus, fwd, Some(true));
                let best = policy <= no_pin.min(force) * 1.001;
                t.row(vec![
                    if fwd { "FP" } else { "BP" }.into(),
                    n.to_string(),
                    gpus.to_string(),
                    format!("{policy:.2}"),
                    format!("{no_pin:.2}"),
                    format!("{force:.2}"),
                    if best { "yes" } else { "NO" }.into(),
                ]);
            }
        }
    }
    println!("=== pinning-policy ablation (paper §2.1–2.2) ===");
    println!("{}", t.render());

    // sanity check of the sim-vs-policy story at the headline point
    let (_, stats4) = MultiGpu::gtx1080ti(4)
        .forward(&Geometry::cone_beam(1024, 1024), None, ExecMode::SimOnly)
        .unwrap();
    println!(">2 GPUs pins by policy: {}", stats4.pinned);
}
