//! Typed error taxonomy for the reconstruction stack (ISSUE 8).
//!
//! Replaces the stringly `anyhow!(...)` paths in the coordinator with
//! variants callers can match on: planning failures, exhausted device
//! recovery, memory pressure that survived the full degradation ladder
//! (evict → refine → spill), and numerical-health violations (non-finite
//! values at merge boundaries, diverging iterations). Every variant
//! implements `std::error::Error`, so existing `anyhow::Result` call
//! sites keep working through `?` — and the structured payload is
//! matchable wherever the typed error has not yet been erased.

use std::fmt;

/// What the coordinator was doing when a non-finite value was caught.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NonFiniteStage {
    /// A device's partial projection, scanned before the host fold.
    MergePartial,
    /// The folded/merged output, scanned after accumulation.
    MergedOutput,
    /// A backprojected volume slab, scanned before it is published.
    VolumeSlab,
    /// An iterative algorithm's residual norm.
    Residual,
}

impl fmt::Display for NonFiniteStage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            NonFiniteStage::MergePartial => "merge partial",
            NonFiniteStage::MergedOutput => "merged output",
            NonFiniteStage::VolumeSlab => "volume slab",
            NonFiniteStage::Residual => "residual",
        };
        f.write_str(s)
    }
}

/// Unified reconstruction error taxonomy.
#[derive(Clone, Debug, PartialEq)]
pub enum ReconError {
    /// The splitter could not produce a feasible plan (infeasible
    /// geometry/budget combination). Carries the splitter's detail.
    Plan(String),
    /// Fault recovery ran out of devices: every device was lost.
    AllDevicesLost(String),
    /// Memory pressure persisted through the whole degradation ladder
    /// (evict → refine → spill) on `device`.
    MemoryPressure {
        /// Device whose allocations kept failing.
        device: usize,
        /// Ladder rungs attempted before giving up.
        attempts: usize,
        /// Last OOM detail from the ledger.
        detail: String,
    },
    /// A NaN/Inf was caught by a numerical-health scan.
    NonFinite {
        /// Where in the pipeline the scan fired.
        stage: NonFiniteStage,
        /// Element index of the first non-finite value (0 for scalars).
        index: usize,
        /// Context label (unit/device/iteration description).
        detail: String,
    },
    /// A checkpoint on disk is unusable: missing manifest fields, a
    /// truncated buffer, or a manifest written by a different algorithm.
    Checkpoint(String),
    /// A caller handed the coordinator unusable input (e.g. a plan mode
    /// that requires resident data received a streamed store).
    Input(String),
    /// An iterative algorithm kept diverging after exhausting its
    /// step-size backoff budget.
    Diverged {
        /// Algorithm name (e.g. `landweber`).
        algorithm: &'static str,
        /// Iteration at which the guard gave up.
        iteration: usize,
        /// Residual norm at that iteration.
        residual: f64,
        /// Backoffs applied before giving up.
        backoffs: usize,
    },
}

impl fmt::Display for ReconError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReconError::Plan(d) => write!(f, "planning failed: {d}"),
            ReconError::AllDevicesLost(d) => {
                write!(f, "fault recovery exhausted all devices: {d}")
            }
            ReconError::MemoryPressure { device, attempts, detail } => write!(
                f,
                "memory pressure on device {device} survived {attempts} degradation \
                 rungs (evict → refine → spill): {detail}"
            ),
            ReconError::NonFinite { stage, index, detail } => write!(
                f,
                "non-finite value in {stage} at element {index} ({detail})"
            ),
            ReconError::Checkpoint(d) => write!(f, "checkpoint invalid: {d}"),
            ReconError::Input(d) => write!(f, "invalid input: {d}"),
            ReconError::Diverged { algorithm, iteration, residual, backoffs } => write!(
                f,
                "{algorithm} diverged at iteration {iteration} (residual {residual:.3e}) \
                 after {backoffs} step-size backoffs"
            ),
        }
    }
}

impl std::error::Error for ReconError {}

impl From<crate::simgpu::SimOom> for ReconError {
    fn from(oom: crate::simgpu::SimOom) -> Self {
        ReconError::MemoryPressure { device: oom.device, attempts: 0, detail: oom.detail }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_carry_the_structured_payload() {
        let e = ReconError::MemoryPressure { device: 2, attempts: 3, detail: "want 4GiB".into() };
        let s = e.to_string();
        assert!(s.contains("device 2") && s.contains("3 degradation") && s.contains("4GiB"), "{s}");

        let e = ReconError::NonFinite {
            stage: NonFiniteStage::MergePartial,
            index: 17,
            detail: "fp unit 3 dev 1".into(),
        };
        assert!(e.to_string().contains("merge partial"), "{e}");
        assert!(e.to_string().contains("element 17"), "{e}");

        let e = ReconError::Diverged {
            algorithm: "cgls",
            iteration: 5,
            residual: 1.0e9,
            backoffs: 4,
        };
        assert!(e.to_string().contains("cgls diverged at iteration 5"), "{e}");

        let e = ReconError::Checkpoint("manifest missing 'epoch'".into());
        assert!(e.to_string().contains("checkpoint invalid"), "{e}");
        assert!(e.to_string().contains("missing 'epoch'"), "{e}");

        let e = ReconError::Input("Full mode requires the volume data".into());
        assert!(e.to_string().contains("invalid input"), "{e}");
    }

    #[test]
    fn converts_into_anyhow_through_question_mark() {
        fn surface() -> anyhow::Result<()> {
            Err(ReconError::AllDevicesLost("0 of 2 devices remain".into()))?;
            Ok(())
        }
        let as_anyhow = surface().unwrap_err();
        assert!(format!("{as_anyhow:#}").contains("exhausted all devices"));
        assert!(format!("{as_anyhow:#}").contains("0 of 2 devices remain"));
    }

    #[test]
    fn sim_oom_maps_to_memory_pressure() {
        let oom = crate::simgpu::SimOom {
            device: 1,
            label: "slab".into(),
            detail: "want 8 GiB, free 1 GiB".into(),
        };
        match ReconError::from(oom) {
            ReconError::MemoryPressure { device: 1, .. } => {}
            other => panic!("wrong variant: {other:?}"),
        }
    }
}
