// Seeded violation for the `deterministic-maps` lint: checked under the
// pretend path rust/src/geometry/split.rs. Never compiled.

use std::collections::HashMap;
use std::collections::HashSet;

pub fn plan(units: &[usize]) -> HashMap<usize, usize> {
    let mut seen = HashSet::new();
    let mut out = HashMap::new();
    for (i, &u) in units.iter().enumerate() {
        if seen.insert(u) {
            out.insert(u, i);
        }
    }
    out
}
