//! End-to-end out-of-core reconstruction (PR 5 acceptance): an iterative
//! loop whose iterate and measured projections live in disk-backed
//! stores with a host budget **smaller than the volume+projection
//! footprint** reconstructs bit-identically to the in-RAM pipelined
//! path on the same host-budgeted plans, across 1–3 simulated GPUs in
//! both the angle-split and the (host-budget-forced) image-split
//! regimes.

use tigre::coordinator::{plan_forward_ooc, ExecMode, MultiGpu, ReconSession};
use tigre::geometry::Geometry;
use tigre::phantom;
use tigre::volume::{
    OocProjections, OocVolume, ProjectionSet, TrackedProjections, TrackedVolume, Volume,
};

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir()
        .join("tigre_ooc_e2e")
        .join(format!("{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn ooc_reconstruction_bit_identical_to_in_ram_pipelined_path() {
    let n = 16;
    let n_angles = 12;
    let g = Geometry::cone_beam(n, n_angles);
    let truth = phantom::shepp_logan(n);
    let footprint = g.volume_bytes() + g.proj_bytes();
    let dir = tmpdir("parity");

    for n_gpus in [1usize, 2, 3] {
        let ctx = MultiGpu::gtx1080ti(n_gpus);
        let proj: ProjectionSet =
            ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap().0.unwrap();

        // image-split axis driven by the HOST budget, not device RAM:
        //  * streaming regime — budget below the volume forces slab
        //    streaming even on 11 GiB devices;
        //  * angle-split regime — budget holds the volume (one
        //    materialization) but still not the whole footprint.
        for (label, host_budget) in [
            ("image-split", g.volume_bytes() / 2),
            ("angle-split", g.volume_bytes() + g.proj_bytes() / 2),
        ] {
            assert!(
                host_budget < footprint,
                "{label}: the budget must be smaller than the {footprint} B footprint"
            );
            let fp_plan =
                plan_forward_ooc(&g, n_gpus, ctx.spec.mem_bytes, &ctx.split, host_budget)
                    .unwrap();
            assert_eq!(
                fp_plan.image_split,
                label == "image-split",
                "gpus={n_gpus} {label}: unexpected regime"
            );

            // two sessions on identical host-budgeted plans: one drives
            // OOC-backed inputs, the other the in-RAM parity baseline
            let mut sess_ooc = ReconSession::new_ooc(&ctx, &g, host_budget).unwrap();
            let mut sess_ram = ReconSession::new_ooc(&ctx, &g, host_budget).unwrap();

            let tag = format!("g{n_gpus}_{label}");
            let mut x_ooc = TrackedVolume::new_ooc(
                OocVolume::create(&dir.join(format!("x_{tag}.raw")), n, n, n, 3, host_budget)
                    .unwrap(),
            );
            let mut x_ram = TrackedVolume::new(Volume::zeros_like(&g));
            let b_ooc = TrackedProjections::new_ooc(
                OocProjections::from_projections(
                    &dir.join(format!("b_{tag}.raw")),
                    &proj,
                    2,
                    host_budget,
                )
                .unwrap(),
            );
            let b_ram = TrackedProjections::new(proj.clone());

            // streamed BP of the measured projections (chunks from disk)
            let atb_ooc = sess_ooc.backward(&b_ooc).unwrap();
            let atb_ram = sess_ram.backward(&b_ram).unwrap();
            assert_eq!(
                atb_ooc.data, atb_ram.data,
                "gpus={n_gpus} {label}: streamed Aᵀb must be bit-identical"
            );

            // Landweber-style loop: x streams from its store every
            // forward; the update streams back through add_scaled_volume
            for it in 0..3 {
                let ax_ooc = sess_ooc.forward(&x_ooc).unwrap();
                let ax_ram = sess_ram.forward(&x_ram).unwrap();
                assert_eq!(
                    ax_ooc.get().data,
                    ax_ram.get().data,
                    "gpus={n_gpus} {label} iter={it}: streamed FP must be bit-identical"
                );
                let mut r = proj.clone();
                r.add_scaled(ax_ooc.get(), -1.0);
                let upd_ooc =
                    sess_ooc.backward(&TrackedProjections::new(r.clone())).unwrap();
                let upd_ram = sess_ram.backward(&TrackedProjections::new(r)).unwrap();
                assert_eq!(upd_ooc.data, upd_ram.data, "gpus={n_gpus} {label} iter={it}");
                x_ooc.write_ooc().unwrap().add_scaled_volume(&upd_ooc, 1e-3).unwrap();
                x_ram.write().add_scaled(&upd_ram, 1e-3);
                assert_eq!(
                    x_ooc.ooc().unwrap().to_volume().unwrap().data,
                    x_ram.get().data,
                    "gpus={n_gpus} {label} iter={it}: OOC iterate must track the RAM one"
                );
            }

            // the stores actually streamed (not silently materialized)
            let vstats = x_ooc.ooc().unwrap().stats();
            assert!(vstats.bytes_read > 0, "gpus={n_gpus} {label}: volume store never read");
            if label == "image-split" {
                assert!(
                    x_ooc.ooc().unwrap().bytes() > host_budget,
                    "streaming regime must have a volume bigger than its budget"
                );
            }
            let bstats = b_ooc.ooc().unwrap().stats();
            assert!(bstats.bytes_read > 0, "gpus={n_gpus} {label}: proj store never read");
        }
    }
}

#[test]
fn ooc_operator_calls_match_in_ram_reference_through_public_api() {
    // MultiGpu::forward_ooc / backward_ooc (plans derived from the
    // store's own budget) agree with the unsplit reference numerics to
    // splitting tolerance, and their simulated schedules charge the
    // disk engine (makespan strictly above the plain plan's).
    let n = 20;
    let n_angles = 12;
    let g = Geometry::cone_beam(n, n_angles);
    let v = phantom::shepp_logan(n);
    let dir = tmpdir("public_api");
    let budget = g.volume_bytes() / 2;
    let ctx = MultiGpu::gtx1080ti(2);

    let store = OocVolume::from_volume(&dir.join("v.raw"), &v, 4, budget).unwrap();
    let (p_ooc, fp_stats) = ctx.forward_ooc(&g, &store, ExecMode::Full).unwrap();
    let p_ooc = p_ooc.unwrap();
    let reference = ctx.forward(&g, Some(&v), ExecMode::Full).unwrap().0.unwrap();
    for (i, (a, b)) in reference.data.iter().zip(&p_ooc.data).enumerate() {
        assert!(
            (a - b).abs() <= 2e-3 * (1.0 + a.abs()),
            "pixel {i}: reference {a} vs ooc {b}"
        );
    }
    assert!(fp_stats.makespan_s > 0.0);

    let pstore =
        OocProjections::from_projections(&dir.join("p.raw"), &p_ooc, 2, g.proj_bytes() / 2)
            .unwrap();
    let (v_ooc, bp_stats) = ctx.backward_ooc(&g, &pstore, ExecMode::Full).unwrap();
    let v_ooc = v_ooc.unwrap();
    let v_ref = ctx.backward(&g, Some(&p_ooc), ExecMode::Full).unwrap().0.unwrap();
    for (i, (a, b)) in v_ref.data.iter().zip(&v_ooc.data).enumerate() {
        assert!(
            (a - b).abs() <= 2e-3 * (1.0 + a.abs()),
            "voxel {i}: reference {a} vs ooc {b}"
        );
    }
    assert!(bp_stats.peak_device_bytes <= ctx.spec.mem_bytes);

    // SimOnly works without touching data and models the disk tier
    let (none, sim_stats) = ctx.forward_ooc(&g, &store, ExecMode::SimOnly).unwrap();
    assert!(none.is_none());
    assert!(sim_stats.makespan_s > 0.0);
}
