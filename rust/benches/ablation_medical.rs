//! Ablation — the paper's §4 medical-size claim: 512³ CGLS-15 took
//! 4 min 41 s in original TIGRE (per-call overheads) and 1 min 01 s with
//! the proposed implementation on one GTX 1080 Ti. This bench
//! reconstructs that comparison on the device model: per-call
//! (modular-TIGRE-style) overhead vs the proposed overlap schedule.

use tigre::coordinator::{baseline, ExecMode, MultiGpu};
use tigre::geometry::Geometry;
use tigre::util::stats::Table;

fn main() {
    let g = Geometry::cone_beam(512, 512);
    let iters = 15.0;

    let mut t = Table::new(&["GPUs", "proposed CGLS-15 [s]", "naive CGLS-15 [s]", "paper [s]"]);
    for &gpus in &[1usize, 2, 4] {
        let ctx = MultiGpu::gtx1080ti(gpus);
        let (_, fp) = ctx.forward(&g, None, ExecMode::SimOnly).unwrap();
        let (_, bp) = ctx.backward(&g, None, ExecMode::SimOnly).unwrap();
        let proposed = iters * (fp.makespan_s + bp.makespan_s);
        let nfp = baseline::naive_forward(&ctx, &g).unwrap();
        let nbp = baseline::naive_backward(&ctx, &g).unwrap();
        let naive = iters * (nfp.makespan_s + nbp.makespan_s);
        t.row(vec![
            gpus.to_string(),
            format!("{proposed:.1}"),
            format!("{naive:.1}"),
            if gpus == 1 { "61 (TIGRE v2) / 281 (v1)".into() } else { "-".to_string() },
        ]);
    }
    println!("=== medical-size anchor: 512³ CGLS-15 (paper §4) ===");
    println!("{}", t.render());
    println!("(sub-minute iterative recon on a single device = the paper's headline)");
}
