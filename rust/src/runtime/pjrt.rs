//! PJRT execution of the AOT artifacts.
//!
//! Calling convention (fixed jointly with `python/compile/aot.py`):
//!  * forward:  `(vol f32[nz,ny,nx], params f32[12], angles f32[A])`
//!              → 1-tuple of `proj f32[A,nv,nu]`
//!  * backward: `(proj f32[A,nv,nu], params f32[12], angles f32[A])`
//!              → 1-tuple of `vol f32[nz,ny,nx]`
//!
//! `params = [dsd, dso, dx, dy, dz, du, dv, off_u, off_v, ox, oy, oz]`
//! (voxel/detector pitches, detector offset, volume-origin offset), so a
//! single artifact serves every geometry of its shape — including the
//! recentred slab geometries the coordinator produces.
//!
//! Executables are compiled once and cached per thread (the xla crate's
//! handles are not Sync).

// Per-thread executable cache keyed by artifact path, lookup-only —
// iteration order never observed (see rust/clippy.toml).
#![allow(clippy::disallowed_types)]

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::geometry::Geometry;
use crate::volume::{ProjectionSet, Volume};

use super::manifest::{ArtifactOp, Manifest};

thread_local! {
    static ENGINE: RefCell<Option<Engine>> = const { RefCell::new(None) };
}

struct Engine {
    client: xla::PjRtClient,
    manifest_dir: PathBuf,
    manifest: Manifest,
    compiled: HashMap<PathBuf, xla::PjRtLoadedExecutable>,
}

impl Engine {
    fn new(dir: &Path) -> anyhow::Result<Engine> {
        Ok(Engine {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
            manifest_dir: dir.to_path_buf(),
            manifest: Manifest::load(dir)?,
            compiled: HashMap::new(),
        })
    }

    fn executable(&mut self, file: &Path) -> anyhow::Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(file) {
            let proto = xla::HloModuleProto::from_text_file(
                file.to_str().context("non-utf8 artifact path")?,
            )
            .map_err(|e| anyhow::anyhow!("loading HLO text {file:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {file:?}: {e:?}"))?;
            self.compiled.insert(file.to_path_buf(), exe);
        }
        Ok(self.compiled.get(file).unwrap())
    }
}

fn with_engine<R>(
    dir: &Path,
    f: impl FnOnce(&mut Engine) -> anyhow::Result<R>,
) -> anyhow::Result<R> {
    ENGINE.with(|cell| {
        let mut slot = cell.borrow_mut();
        let rebuild = match slot.as_ref() {
            Some(e) => e.manifest_dir != dir,
            None => true,
        };
        if rebuild {
            *slot = Some(Engine::new(dir)?);
        }
        f(slot.as_mut().unwrap())
    })
}

/// Geometry scalars in the artifact's `params` layout.
fn params_vec(g: &Geometry) -> Vec<f32> {
    vec![
        g.dsd as f32,
        g.dso as f32,
        g.d_vox[0] as f32,
        g.d_vox[1] as f32,
        g.d_vox[2] as f32,
        g.d_det[0] as f32,
        g.d_det[1] as f32,
        g.offset_det[0] as f32,
        g.offset_det[1] as f32,
        g.offset_origin[0] as f32,
        g.offset_origin[1] as f32,
        g.offset_origin[2] as f32,
    ]
}

fn angles_vec(g: &Geometry) -> Vec<f32> {
    g.angles.iter().map(|&a| a as f32).collect()
}

fn run3(
    engine: &mut Engine,
    file: &Path,
    main_in: (&[f32], &[i64]),
    g: &Geometry,
    out_len: usize,
) -> anyhow::Result<Vec<f32>> {
    let exe = engine.executable(file)?;
    let x = xla::Literal::vec1(main_in.0)
        .reshape(main_in.1)
        .map_err(|e| anyhow::anyhow!("reshape input: {e:?}"))?;
    let p = xla::Literal::vec1(&params_vec(g));
    let a = xla::Literal::vec1(&angles_vec(g));
    let result = exe
        .execute::<xla::Literal>(&[x, p, a])
        .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
    let out = result
        .to_tuple1()
        .map_err(|e| anyhow::anyhow!("unwrap tuple: {e:?}"))?;
    let v = out
        .to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
    anyhow::ensure!(v.len() == out_len, "artifact output length {} != {out_len}", v.len());
    Ok(v)
}

/// Try the forward projection via an artifact. `Ok(None)` = no artifact
/// for this shape (caller falls back to native).
pub fn try_forward(dir: &Path, g: &Geometry, vol: &Volume) -> anyhow::Result<Option<ProjectionSet>> {
    with_engine(dir, |engine| {
        let Some(entry) = engine
            .manifest
            .find(ArtifactOp::Forward, g.n_vox, g.n_det, g.n_angles())
            .cloned()
        else {
            return Ok(None);
        };
        let dims = [vol.nz as i64, vol.ny as i64, vol.nx as i64];
        let out_len = g.n_det[0] * g.n_det[1] * g.n_angles();
        let data = run3(engine, &entry.file, (&vol.data, &dims), g, out_len)?;
        Ok(Some(ProjectionSet {
            nu: g.n_det[0],
            nv: g.n_det[1],
            n_angles: g.n_angles(),
            data,
        }))
    })
}

/// Try the backprojection via an artifact (FDK or matched weights).
pub fn try_backward(
    dir: &Path,
    g: &Geometry,
    proj: &ProjectionSet,
    weight: crate::kernels::BackprojWeight,
) -> anyhow::Result<Option<Volume>> {
    let op = match weight {
        crate::kernels::BackprojWeight::Fdk => ArtifactOp::Backward,
        crate::kernels::BackprojWeight::Matched => ArtifactOp::BackwardMatched,
    };
    with_engine(dir, |engine| {
        let Some(entry) = engine
            .manifest
            .find(op, g.n_vox, g.n_det, g.n_angles())
            .cloned()
        else {
            return Ok(None);
        };
        let dims = [proj.n_angles as i64, proj.nv as i64, proj.nu as i64];
        let out_len = g.n_vox[0] * g.n_vox[1] * g.n_vox[2];
        let data = run3(engine, &entry.file, (&proj.data, &dims), g, out_len)?;
        Ok(Some(Volume { nx: g.n_vox[0], ny: g.n_vox[1], nz: g.n_vox[2], data }))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifacts_dir_falls_back() {
        let g = Geometry::cone_beam(8, 2);
        let v = crate::phantom::cube(8, 0.5, 1.0);
        let r = try_forward(Path::new("/nonexistent-artifacts"), &g, &v).unwrap();
        assert!(r.is_none());
    }

    #[test]
    fn params_layout_is_twelve_floats() {
        let g = Geometry::cone_beam(8, 2);
        assert_eq!(params_vec(&g).len(), 12);
        assert_eq!(angles_vec(&g).len(), 2);
    }
}
