//! Fig. 9 — percentage of total execution time per operation category
//! (computing / pin+unpin / other memory) vs image size for 1, 2 and 4
//! GPUs, for both operators.
//!
//! Binning matches the paper: "Computing contains the time for kernel
//! launches, which includes simultaneous memory copies as they happen
//! concurrently" — i.e. only *exposed* memory time counts as memory
//! (see simgpu::timeline::breakdown).

use tigre::bench::{fig7_sweep, fig9_table, FIG9_SIZES};

fn main() {
    let cells = fig7_sweep(FIG9_SIZES, &[1, 2, 4]);

    println!("=== Fig. 9 (a): forward projection time breakdown ===");
    println!("{}", fig9_table(&cells, true));
    println!("=== Fig. 9 (b): backprojection time breakdown ===");
    println!("{}", fig9_table(&cells, false));

    // Paper observations, printed as checkpoints on every run:
    // (1) FP compute dominates even at small-ish sizes;
    let fp512 = cells.iter().find(|c| c.n == 512 && c.gpus == 1).unwrap();
    let (c, ..) = fp512.fp_breakdown.fractions();
    println!("FP N=512 1-GPU compute fraction: {c:.2} (paper: dominates)");
    // (2) BP at 512 with >1 GPU: computation takes less than half.
    let bp512 = cells.iter().find(|c| c.n == 512 && c.gpus == 2).unwrap();
    let (c2, ..) = bp512.bp_breakdown.fractions();
    println!("BP N=512 2-GPU compute fraction: {c2:.2} (paper: < 0.5 with >1 GPU)");
    // (3) pinning absent where the policy skips it.
    let small = cells.iter().find(|c| c.n == 256 && c.gpus == 1).unwrap();
    println!(
        "N=256 1-GPU pinned: FP {} BP {} (paper: some sizes skip pinning)",
        small.fp_pinned, small.bp_pinned
    );
}
