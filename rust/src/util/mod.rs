//! Substrate utilities.
//!
//! The build environment is fully offline, so everything that a networked
//! project would pull from crates.io (arg parsing, JSON, PRNG, thread pool,
//! property testing, bench statistics) is implemented here from scratch.

pub mod cli;
pub mod json;
pub mod log;
pub mod pcg;
pub mod prop;
pub mod stats;
pub mod threadpool;
pub mod units;
