//! The paper's system contribution: multi-GPU splitting, double-buffered
//! queueing and transfer/compute overlap for the forward projection
//! (Algorithm 1), backprojection (Algorithm 2) and halo-buffered
//! regularization (§2.3).
//!
//! Every operator runs in two coupled forms (DESIGN.md §6):
//!  * **real execution** — the plan's slab/chunk loops drive actual
//!    kernels (native rust or PJRT artifacts) so the split-and-accumulate
//!    numerics are verified against unsplit reference execution;
//!  * **simulated timeline** — the identical schedule replayed against the
//!    discrete-event device model, producing the makespan and the Fig.-9
//!    breakdown at sizes no CPU could compute.
//!
//! ## Multi-GPU distribution (documented deviation-free reading of §2)
//!
//! *Forward projection*: when the image fits on each device, angles are
//! split across devices (each projects the whole image for its share; no
//! accumulation). When the image must be split, z-slabs are distributed
//! across devices and every device projects **all** angles of its slabs;
//! per-chunk partial projections accumulate through the devices in a
//! staggered chunk order so at most one device touches a chunk at a time
//! and every copy hides behind compute (paper Fig. 3). This reproduces the
//! paper's §3.1 split counts (N=3072: FP 10→5 partitions from 1→2 GPUs).
//!
//! *Backprojection*: z-slabs are distributed across devices; each device
//! streams **all** projections through a 2-chunk double buffer while its
//! voxel-update kernels run (paper Fig. 5).
//!
//! Since PR 3 the **real** path executes that schedule for real too:
//! [`pipeline`] runs one concurrent worker per device assignment with
//! zero-copy slab/chunk staging views and a double-buffered merge lane
//! per worker, deterministically merged — bit-identical output for every
//! worker count. The pre-PR3 host-sequential loops survive behind
//! [`ExecutorConfig::pipelined`]` = false` as the benchmark baseline.
//!
//! Since PR 4 the iterative algorithms drive their loops through a
//! [`residency::ReconSession`]: a cross-iteration device residency cache
//! keeps constant inputs (the measured projections, an unchanged volume,
//! each device's own forward-output chunks) staged across operator calls,
//! with write-epochs making stale reuse impossible. Only the simulated
//! schedule changes; the real executors stay stateless and bit-identical.
//!
//! Since PR 5 the same splitting strategy extends one tier up the memory
//! hierarchy (disk → host → device): volumes and projection sets can live
//! **out of core** (`volume::outofcore`), plans carry a host-memory
//! budget ([`splitter::plan_forward_ooc`]/[`splitter::plan_backward_ooc`]
//! /[`splitter::plan_ooc_pair`]), and the pipelined executor streams
//! slabs/chunks from the backing store on prefetching loader lanes —
//! bit-identical to the in-RAM path on the same plan, with the simulated
//! timeline's disk engine predicting when the streaming hides behind
//! kernel time. [`ReconSession::new_ooc`](residency::ReconSession::new_ooc)
//! builds a session in that regime.
//!
//! Since PR 6 the image-split forward's cross-device merge is a
//! [`splitter::MergeStrategy`]: the linear host fold, or a log-depth
//! pairwise **reduction tree** whose rounds overlap in-flight workers
//! (real path) / peer-to-peer device links (simulated path). Both
//! execute the same canonical schedule ([`splitter::merge_schedule`]),
//! so output stays bit-identical — only the merge critical path changes.
//!
//! Since PR 7 execution is fault-tolerant: a deterministic
//! [`crate::simgpu::fault::FaultPlan`] injects device loss, transient
//! launch failures, allocation failures and disk-I/O errors at chosen
//! (device, unit, iteration) coordinates into both the simulated timeline
//! (recovery time appears in the makespan) and the real pipelined
//! executor, which retries transient faults with bounded backoff and
//! replans a lost device's remaining units onto the survivors
//! ([`splitter::replan_excluding`]) — FP/BP output stays bit-identical to
//! the fault-free run because recovery re-executes the *same* unit
//! partition in the canonical merge order. [`checkpoint`] adds
//! iteration-granular durable snapshots so a killed reconstruction
//! resumes from its last checkpoint with a bit-identical final iterate.
//!
//! Since PR 10 a precomputed **sparse CSR system matrix** is a third
//! kernel backend ([`executor::Backend::Sparse`]): each slab×chunk
//! unit's Siddon traversal runs once and is cached as a CSR shard
//! ([`residency::SparseShardCache`]), after which forward projection is
//! SpMV (bit-identical to the ray-driven Siddon kernel) and
//! backprojection the matched adjoint SpMVᵀ — repeated-iteration
//! workloads amortize the one-time build, with
//! [`crate::simgpu::CostModel::sparse_crossover_iters`] predicting the
//! break-even iteration count on the simulated timeline.

pub mod backward;
pub mod baseline;
pub mod checkpoint;
pub mod degrade;
pub mod error;
pub mod executor;
pub mod forward;
pub mod pipeline;
pub mod regularizer;
pub mod residency;
pub mod splitter;

pub use checkpoint::{CheckpointConfig, CheckpointState, Checkpointer};
pub use degrade::{DegradeEvent, DegradeLog, DegradeStats};
pub use error::{NonFiniteStage, ReconError};
pub use executor::{Backend, ExecMode, ExecutorConfig, MultiGpu, OpStats, ProjectorChoice};
pub use residency::{
    ReconSession, ResidencyCache, ResidencyStats, SparseShardCache, SparseShardStats,
};
pub use splitter::{
    merge_schedule, ooc_bp_chunk, plan_backward_ooc, plan_forward_ooc, plan_ooc_pair,
    MergeStrategy, Plan, PlanProjector, SplitConfig,
};
