//! Voxel-driven backprojector with FDK or pseudo-matched weights.
//!
//! For every voxel and every angle, the voxel centre is perspectively
//! projected onto the detector; the projection value is fetched with
//! bilinear interpolation and accumulated with a distance weight. This is
//! TIGRE's backprojection structure (each CUDA thread updates a column of
//! `N_z` voxels across `N_angles` projections; here each task owns a run
//! of z-slices, which keeps writes disjoint without atomics).
//!
//! Like the projectors, the kernel accepts slab geometries, which is how
//! the coordinator backprojects image pieces independently (paper Alg. 2).
//!
//! Hot-path structure (DESIGN.md §Perf, EXPERIMENTS.md §Perf): the angle
//! loop is **blocked** — a block of [`ANGLE_BLOCK`] projections is swept
//! over a tile of [`SLICE_TILE`] z-slices before the next block is
//! touched, so the block stays resident in L2 instead of streaming every
//! projection past every slice (the CUDA code gets the equivalent locality
//! from the 3-D texture cache; Petascale-XCT-style loop blocking is the
//! CPU analogue). Within a detector row the x-inner loop is split into
//! two passes over a small tile: a pure-FMA f32 pass that computes pixel
//! coordinates and weights (auto-vectorizable, one divide per voxel), then
//! a gather pass doing the bilinear fetch and accumulate. Accumulation
//! order over angles is identical to the naive loop, so results do not
//! depend on the thread count or the blocking factors.

use crate::geometry::Geometry;
use crate::kernels::BackprojWeight;
use crate::util::threadpool::{parallel_for, SendPtr};
use crate::volume::{ProjChunkView, ProjectionSet, Volume};

/// Projections swept together over a slice tile (~16 × a 64² f32 panel
/// ≈ 256 KiB — sized for a shared L2).
const ANGLE_BLOCK: usize = 16;
/// z-slices per task chunk; the unit of write disjointness and of reuse
/// of a resident angle block.
const SLICE_TILE: usize = 4;
/// x-tile for the two-pass inner loop (coordinate/weight buffers live on
/// the stack).
const X_TILE: usize = 128;

/// Backproject all angles of `g` into a volume of `g.n_vox`.
pub fn backproject(
    g: &Geometry,
    proj: &ProjectionSet,
    weight: BackprojWeight,
    threads: usize,
) -> Volume {
    let [nx, ny, nz] = g.n_vox;
    let mut out = crate::kernels::scratch::take_volume(nx, ny, nz);
    backproject_into(g, &proj.as_view(), &mut out.data, weight, threads);
    out
}

/// Backproject a borrowed angle-chunk view, **accumulating** (`+=`) into
/// `out` (layout `(z·ny + y)·nx + x`, length `nx·ny·nz`; zero it first for
/// a plain backprojection). This is the zero-copy entry point the
/// pipelined executor uses: the view borrows the resident projection set
/// and `out` is a per-launch staging buffer or a disjoint slab of the
/// shared output. The accumulation order over angles is the view's angle
/// order, independent of `threads` (tasks own disjoint z-slices).
pub fn backproject_into(
    g: &Geometry,
    proj: &ProjChunkView<'_>,
    out: &mut [f32],
    weight: BackprojWeight,
    threads: usize,
) {
    assert_eq!(proj.nu, g.n_det[0], "projection nu mismatch");
    assert_eq!(proj.nv, g.n_det[1], "projection nv mismatch");
    assert_eq!(proj.n_angles, g.n_angles(), "projection angle count mismatch");

    let [nx, ny, nz] = g.n_vox;
    assert_eq!(out.len(), nx * ny * nz, "output length mismatch");
    let (lo, _) = g.volume_bbox();

    // Per-angle trig, hoisted out of the voxel loop.
    let trig: Vec<(f64, f64)> = g.angles.iter().map(|&t| t.sin_cos()).collect();

    let dso = g.dso;
    let dsd = g.dsd;
    let nu = g.n_det[0];
    let nvd = g.n_det[1];
    let per_proj = nu * nvd;
    let n_angles = g.n_angles();

    // f32 inner-loop constants (f64 setup).
    let inv_du = (1.0 / g.d_det[0]) as f32;
    let inv_dv = (1.0 / g.d_det[1]) as f32;
    let off_u = g.offset_det[0] as f32;
    let off_v = g.offset_det[1] as f32;
    let half_u = (nu as f64 / 2.0 - 0.5) as f32;
    let half_v = (nvd as f64 / 2.0 - 0.5) as f32;
    let dso_f = dso as f32;
    let dsd_f = dsd as f32;
    let fdk = matches!(weight, BackprojWeight::Fdk);

    // Matched-weight scale: approximates Σ_rays ℓ over the voxel footprint
    // (see DESIGN.md §Perf / kernels): ℓ̄·(dvox·M)²/(du·dv) with
    // M = DSD/(DSO − r·ŝ). The constant part is hoisted here.
    let dvox = g.d_vox[0].min(g.d_vox[1]).min(g.d_vox[2]);
    let matched_scale =
        (dvox * dvox * dvox * dsd * dsd * (1.0 / g.d_det[0]) * (1.0 / g.d_det[1])) as f32;

    let dvx = g.d_vox[0];
    let px0 = lo[0] + 0.5 * dvx; // centre of voxel column x = 0

    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for(nz, threads, SLICE_TILE, |z0, z1| {
        let ptr = ptr;
        let mut fu_buf = [0.0f32; X_TILE];
        let mut fv_buf = [0.0f32; X_TILE];
        let mut w_buf = [0.0f32; X_TILE];
        // Angle-blocked sweep: each block of projections is reused across
        // every slice of this task's tile before the next block streams in.
        for a0 in (0..n_angles).step_by(ANGLE_BLOCK) {
            let a1 = (a0 + ANGLE_BLOCK).min(n_angles);
            for z in z0..z1 {
                let pz = (lo[2] + (z as f64 + 0.5) * g.d_vox[2]) as f32;
                // SAFETY: tasks own disjoint z ranges, so this mutable
                // slice aliases nothing in other tasks.
                let slice = unsafe {
                    std::slice::from_raw_parts_mut(ptr.0.add(z * ny * nx), ny * nx)
                };
                for a in a0..a1 {
                    let (s, c) = trig[a];
                    let pslice = &proj.data[a * per_proj..(a + 1) * per_proj];
                    for y in 0..ny {
                        let py = lo[1] + (y as f64 + 0.5) * g.d_vox[1];
                        // Rotated coordinates are affine in the voxel
                        // column index x (f64 bases, f32 walk):
                        //   rx =  px·c + py·s = rx0 + x·drx
                        //   ry = −px·s + py·c = ry0 + x·dry
                        let rx0 = (px0 * c + py * s) as f32;
                        let drx = (dvx * c) as f32;
                        let ry0 = (-px0 * s + py * c) as f32;
                        let dry = (-dvx * s) as f32;
                        let row = &mut slice[y * nx..(y + 1) * nx];
                        let mut x0 = 0usize;
                        while x0 < nx {
                            let tile = (nx - x0).min(X_TILE);
                            // Pass 1 — pure arithmetic, auto-vectorizable:
                            // one divide per voxel, everything else FMA.
                            for i in 0..tile {
                                let fx = (x0 + i) as f32;
                                let rx = rx0 + fx * drx;
                                let ry = ry0 + fx * dry;
                                let depth = dso_f - rx; // distance along the axis
                                let inv_depth = 1.0 / depth;
                                let t = dsd_f * inv_depth;
                                fu_buf[i] = (t * ry - off_u) * inv_du + half_u;
                                fv_buf[i] = (t * pz - off_v) * inv_dv + half_v;
                                let w = if fdk {
                                    let r = dso_f * inv_depth;
                                    r * r
                                } else {
                                    matched_scale * inv_depth * inv_depth
                                };
                                // behind the source → no contribution
                                w_buf[i] = if depth > 1e-9 { w } else { 0.0 };
                            }
                            // Pass 2 — gather + accumulate.
                            for i in 0..tile {
                                let w = w_buf[i];
                                if w == 0.0 {
                                    continue;
                                }
                                let sample = bilinear(pslice, nu, nvd, fu_buf[i], fv_buf[i]);
                                if sample == 0.0 {
                                    continue;
                                }
                                row[x0 + i] += w * sample;
                            }
                            x0 += tile;
                        }
                    }
                }
            }
        }
    });
}

/// Bilinear fetch from one projection panel at fractional pixel `(fu, fv)`.
/// Points more than half a pixel outside the panel contribute zero
/// (matching TIGRE's boundary handling).
#[inline(always)]
fn bilinear(panel: &[f32], nu: usize, nv: usize, fu: f32, fv: f32) -> f32 {
    // fast path: strictly interior — no clamping, contiguous 2×2 fetch
    if fu >= 0.0 && fv >= 0.0 && fu < (nu - 1) as f32 && fv < (nv - 1) as f32 {
        let u0 = fu as usize;
        let v0 = fv as usize;
        let wu = fu - u0 as f32;
        let wv = fv - v0 as f32;
        let base = v0 * nu + u0;
        // SAFETY: u0+1 < nu and v0+1 < nv by the branch condition.
        unsafe {
            let p00 = *panel.get_unchecked(base);
            let p10 = *panel.get_unchecked(base + 1);
            let p01 = *panel.get_unchecked(base + nu);
            let p11 = *panel.get_unchecked(base + nu + 1);
            let c0 = p00 + (p10 - p00) * wu;
            let c1 = p01 + (p11 - p01) * wu;
            c0 + (c1 - c0) * wv
        }
    } else {
        bilinear_edge(panel, nu, nv, fu, fv)
    }
}

/// Slow path: the half-pixel border (clamped taps) and outside (zero).
#[inline(never)]
fn bilinear_edge(panel: &[f32], nu: usize, nv: usize, fu: f32, fv: f32) -> f32 {
    let nui = nu as isize;
    let nvi = nv as isize;
    if !(fu > -0.5 && fv > -0.5 && fu < nu as f32 - 0.5 && fv < nv as f32 - 0.5) {
        return 0.0; // outside the panel (also catches NaN coordinates)
    }
    let u0 = fu.floor();
    let v0 = fv.floor();
    let wu = fu - u0;
    let wv = fv - v0;
    let cl = |i: f32, n: isize| (i.max(0.0) as isize).min(n - 1) as usize;
    let (u0i, u1i) = (cl(u0, nui), cl(u0 + 1.0, nui));
    let (v0i, v1i) = (cl(v0, nvi), cl(v0 + 1.0, nvi));
    let p00 = panel[v0i * nu + u0i];
    let p10 = panel[v0i * nu + u1i];
    let p01 = panel[v1i * nu + u0i];
    let p11 = panel[v1i * nu + u1i];
    let c0 = p00 + (p10 - p00) * wu;
    let c1 = p01 + (p11 - p01) * wu;
    c0 + (c1 - c0) * wv
}

/// Pre-refactor scalar backprojector (f64 per-voxel arithmetic, angle
/// streaming per z-slice) — kept verbatim as the golden oracle.
#[cfg(test)]
pub(crate) mod reference {
    use super::*;

    pub fn backproject_ref(g: &Geometry, proj: &ProjectionSet, weight: BackprojWeight) -> Volume {
        let [nx, ny, nz] = g.n_vox;
        let mut out = Volume::zeros(nx, ny, nz);
        let (lo, _) = g.volume_bbox();
        let trig: Vec<(f64, f64)> = g.angles.iter().map(|&t| t.sin_cos()).collect();
        let dso = g.dso;
        let dsd = g.dsd;
        let inv_du = 1.0 / g.d_det[0];
        let inv_dv = 1.0 / g.d_det[1];
        let nu = g.n_det[0];
        let nvd = g.n_det[1];
        let off_u = g.offset_det[0];
        let off_v = g.offset_det[1];
        let half_u = nu as f64 / 2.0 - 0.5;
        let half_v = nvd as f64 / 2.0 - 0.5;
        let dvox = g.d_vox[0].min(g.d_vox[1]).min(g.d_vox[2]);
        let matched_scale = dvox * dvox * dvox * dsd * dsd * inv_du * inv_dv;
        for z in 0..nz {
            let pz = lo[2] + (z as f64 + 0.5) * g.d_vox[2];
            for (a, &(s, c)) in trig.iter().enumerate() {
                for y in 0..ny {
                    let py = lo[1] + (y as f64 + 0.5) * g.d_vox[1];
                    let py_s = py * s;
                    let py_c = py * c;
                    for x in 0..nx {
                        let px = lo[0] + (x as f64 + 0.5) * g.d_vox[0];
                        let rx = px * c + py_s;
                        let depth = dso - rx;
                        if depth <= 1e-9 {
                            continue;
                        }
                        let ry = -px * s + py_c;
                        let inv_depth = 1.0 / depth;
                        let t = dsd * inv_depth;
                        let fu = (t * ry - off_u) * inv_du + half_u;
                        let fv = (t * pz - off_v) * inv_dv + half_v;
                        let sample = bilinear_f64(proj, a, fu, fv);
                        if sample == 0.0 {
                            continue;
                        }
                        let w = match weight {
                            BackprojWeight::Fdk => {
                                let r = dso * inv_depth;
                                r * r
                            }
                            BackprojWeight::Matched => matched_scale * inv_depth * inv_depth,
                        };
                        out.data[(z * ny + y) * nx + x] += (w * sample as f64) as f32;
                    }
                }
            }
        }
        out
    }

    fn bilinear_f64(proj: &ProjectionSet, a: usize, fu: f64, fv: f64) -> f32 {
        let nu = proj.nu as isize;
        let nv = proj.nv as isize;
        if fu <= -0.5 || fv <= -0.5 || fu >= nu as f64 - 0.5 || fv >= nv as f64 - 0.5 {
            return 0.0;
        }
        let u0 = fu.floor();
        let v0 = fv.floor();
        let wu = (fu - u0) as f32;
        let wv = (fv - v0) as f32;
        let cl = |i: f64, n: isize| (i.max(0.0) as isize).min(n - 1) as usize;
        let (u0i, u1i) = (cl(u0, nu), cl(u0 + 1.0, nu));
        let (v0i, v1i) = (cl(v0, nv), cl(v0 + 1.0, nv));
        let p00 = proj.at(u0i, v0i, a);
        let p10 = proj.at(u1i, v0i, a);
        let p01 = proj.at(u0i, v1i, a);
        let p11 = proj.at(u1i, v1i, a);
        let c0 = p00 + (p10 - p00) * wu;
        let c1 = p01 + (p11 - p01) * wu;
        c0 + (c1 - c0) * wv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{forward, Projector};
    use crate::phantom;

    #[test]
    fn golden_parity_vs_reference() {
        // Optimized (angle-blocked, two-pass f32) against the pre-refactor
        // f64 oracle, for both weightings and with enough angles to cross
        // an ANGLE_BLOCK boundary.
        let n = 20;
        let g = Geometry::cone_beam(n, 2 * ANGLE_BLOCK + 3);
        let v = phantom::shepp_logan(n);
        let p = forward(&g, &v, Projector::Siddon, 2);
        for weight in [BackprojWeight::Fdk, BackprojWeight::Matched] {
            let opt = backproject(&g, &p, weight, 3);
            let oracle = reference::backproject_ref(&g, &p, weight);
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for (i, (a, b)) in oracle.data.iter().zip(&opt.data).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-4 * (1.0 + a.abs()),
                    "{weight:?} voxel {i}: oracle {a} vs optimized {b}"
                );
                num += ((a - b) as f64).powi(2);
                den += (*a as f64).powi(2);
            }
            let rel = (num / den.max(1e-12)).sqrt();
            assert!(rel < 1e-5, "{weight:?} relative L2 deviation: {rel:.3e}");
        }
    }

    #[test]
    fn golden_parity_with_detector_offset() {
        let n = 16;
        let mut g = Geometry::cone_beam(n, 7);
        g.offset_det = [1.75, -2.5];
        let v = phantom::shepp_logan(n);
        let p = forward(&g, &v, Projector::Siddon, 2);
        let opt = backproject(&g, &p, BackprojWeight::Fdk, 2);
        let oracle = reference::backproject_ref(&g, &p, BackprojWeight::Fdk);
        for (i, (a, b)) in oracle.data.iter().zip(&opt.data).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * (1.0 + a.abs()),
                "voxel {i}: oracle {a} vs optimized {b}"
            );
        }
    }

    #[test]
    fn backprojection_is_linear() {
        let g = Geometry::cone_beam(12, 6);
        let mut p1 = ProjectionSet::zeros_like(&g);
        let mut rng = crate::util::pcg::Pcg32::new(2);
        for v in &mut p1.data {
            *v = rng.next_f32();
        }
        let mut p2 = p1.clone();
        for v in &mut p2.data {
            *v *= 3.0;
        }
        let b1 = backproject(&g, &p1, BackprojWeight::Fdk, 2);
        let b2 = backproject(&g, &p2, BackprojWeight::Fdk, 2);
        for (a, b) in b1.data.iter().zip(&b2.data) {
            assert!((3.0 * a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} {b}");
        }
    }

    #[test]
    fn central_disc_projections_light_up_centre_only() {
        // Projections that are 1 on a small central detector disc and 0
        // elsewhere backproject onto the rotation axis: centre voxel gets
        // every angle, corner voxels (outside every disc cone) get none.
        let g = Geometry::cone_beam(16, 12);
        let mut p = ProjectionSet::zeros_like(&g);
        let (cu, cv) = (g.n_det[0] as f64 / 2.0 - 0.5, g.n_det[1] as f64 / 2.0 - 0.5);
        for a in 0..12 {
            for iv in 0..g.n_det[1] {
                for iu in 0..g.n_det[0] {
                    let d = ((iu as f64 - cu).powi(2) + (iv as f64 - cv).powi(2)).sqrt();
                    if d < 2.5 {
                        *p.at_mut(iu, iv, a) = 1.0;
                    }
                }
            }
        }
        let b = backproject(&g, &p, BackprojWeight::Fdk, 2);
        let c = b.at(8, 8, 8);
        let corner = b.at(0, 0, 0);
        assert!(c > 11.0, "centre should see every angle, got {c}");
        assert!(corner < 0.5, "corner should be dark, got {corner}");
    }

    #[test]
    fn backprojection_of_forward_projection_peaks_at_object() {
        // A*Aᵀ-like smoke test: backprojecting the projections of a small
        // centred cube must produce a volume whose maximum is at/near the
        // cube, not in air.
        let n = 16;
        let g = Geometry::cone_beam(n, 8);
        let v = phantom::cube(n, 0.25, 1.0);
        let p = forward(&g, &v, Projector::Siddon, 2);
        let b = backproject(&g, &p, BackprojWeight::Matched, 2);
        let centre = b.at(n / 2, n / 2, n / 2);
        let edge = b.at(0, n / 2, n / 2);
        assert!(centre > edge * 2.0, "centre {centre} vs edge {edge}");
    }

    #[test]
    fn slab_backprojections_tile_full_volume() {
        // Alg. 2's core property: backprojecting into independent z-slabs
        // and stacking equals backprojecting the whole volume.
        let n = 16;
        let g = Geometry::cone_beam(n, 6);
        let v = phantom::shepp_logan(n);
        let p = forward(&g, &v, Projector::Siddon, 2);
        let full = backproject(&g, &p, BackprojWeight::Fdk, 2);

        let mut tiled = Volume::zeros(n, n, n);
        for (z0, z1) in [(0, 6), (6, 11), (11, 16)] {
            let part = backproject(&g.slab_geometry(z0, z1), &p, BackprojWeight::Fdk, 2);
            tiled.insert_slab(z0, &part);
        }
        for (i, (a, b)) in full.data.iter().zip(&tiled.data).enumerate() {
            assert!((a - b).abs() <= 1e-4 * (1.0 + a.abs()), "voxel {i}: {a} vs {b}");
        }
    }

    #[test]
    fn angle_chunks_sum_to_full_backprojection() {
        // Backprojection is a sum over angles, so chunked accumulation
        // must match (this is what lets Alg. 2 stream projection chunks).
        let n = 12;
        let g = Geometry::cone_beam(n, 9);
        let v = phantom::shepp_logan(n);
        let p = forward(&g, &v, Projector::Siddon, 2);
        let full = backproject(&g, &p, BackprojWeight::Fdk, 2);

        let mut acc = Volume::zeros(n, n, n);
        for (a0, a1) in [(0, 4), (4, 8), (8, 9)] {
            let gc = g.angle_chunk_geometry(a0, a1);
            let pc = p.extract_chunk(a0, a1);
            let part = backproject(&gc, &pc, BackprojWeight::Fdk, 2);
            acc.add_scaled(&part, 1.0);
        }
        for (a, b) in full.data.iter().zip(&acc.data) {
            assert!((a - b).abs() <= 1e-4 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn threaded_equals_single_threaded() {
        let g = Geometry::cone_beam(12, 5);
        let v = phantom::shepp_logan(12);
        let p = forward(&g, &v, Projector::Siddon, 1);
        let b1 = backproject(&g, &p, BackprojWeight::Fdk, 1);
        let b4 = backproject(&g, &p, BackprojWeight::Fdk, 4);
        assert_eq!(b1.data, b4.data);
    }

    #[test]
    fn view_backprojection_accumulates_and_matches_owned_chunk() {
        // backproject_into on a borrowed chunk view (a) accumulates into a
        // non-zero output and (b) is bit-identical to the owned-chunk path.
        let n = 12;
        let g = Geometry::cone_beam(n, 9);
        let v = phantom::shepp_logan(n);
        let p = forward(&g, &v, Projector::Siddon, 2);
        let (a0, a1) = (3, 8);
        let gc = g.angle_chunk_geometry(a0, a1);
        let owned = backproject(&gc, &p.extract_chunk(a0, a1), BackprojWeight::Fdk, 2);

        let mut via_view = vec![0.0f32; owned.data.len()];
        backproject_into(&gc, &p.chunk_view(a0, a1), &mut via_view, BackprojWeight::Fdk, 2);
        assert_eq!(owned.data, via_view);

        // accumulate semantics: a second pass adds the same contribution
        // (up to reassociation of the running f32 sum)
        backproject_into(&gc, &p.chunk_view(a0, a1), &mut via_view, BackprojWeight::Fdk, 2);
        for (once, twice) in owned.data.iter().zip(&via_view) {
            assert!(
                (twice - 2.0 * once).abs() <= 1e-5 * (1.0 + once.abs()),
                "second pass must accumulate: {once} then {twice}"
            );
        }
    }

    #[test]
    fn matched_weight_magnitude_sane() {
        // matched backprojection should produce values comparable to the
        // Siddon row sums (adjoint consistency at the scale level).
        let g = Geometry::cone_beam(16, 8);
        let v = phantom::cube(16, 0.5, 1.0);
        let p = forward(&g, &v, Projector::Siddon, 2);
        let b = backproject(&g, &p, BackprojWeight::Matched, 2);
        let lhs = p.dot(&p);
        let rhs = v.dot(&b);
        let ratio = lhs / rhs;
        assert!((0.4..2.5).contains(&ratio), "⟨Ax,Ax⟩/⟨x,AᵀAx⟩ = {ratio}");
    }
}
