//! Halo-buffered multi-GPU regularization (paper §2.3, Fig. 6).
//!
//! TV-type regularizers are coupled neighbourhood operators: each
//! iteration reads a 1-voxel neighbourhood. The paper's split: give every
//! device its z-slab plus an `N_in`-deep halo of the neighbouring slabs;
//! the device can then run `N_in` *independent* inner iterations before
//! the halos must be re-synchronized. Deeper halos mean fewer exchanges
//! but more redundant compute (the trade-off swept by
//! `benches/ablation_halo.rs`; the paper lands on `N_in = 60`).
//!
//! Global reductions (the norms used by TV gradient descent) are
//! approximated per-device assuming uniform distribution across the image
//! (paper: "negligible effect in the convergence and result").

use crate::geometry::split::split_even;
use crate::kernels::tv;
use crate::simgpu::timeline::breakdown;
use crate::simgpu::Ev;
use crate::volume::Volume;

use super::executor::{MultiGpu, OpStats};

/// Paper's default halo depth.
pub const DEFAULT_N_IN: usize = 60;

/// One device's slab with halos: core `[z0, z1)`, extended
/// `[z0 − lo_halo, z1 + hi_halo)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HaloSlab {
    /// First owned (core) slice, inclusive.
    pub core_z0: usize,
    /// One past the last owned slice, exclusive.
    pub core_z1: usize,
    /// First slice including the low-side halo, inclusive.
    pub ext_z0: usize,
    /// One past the last slice including the high-side halo, exclusive.
    pub ext_z1: usize,
}

/// Partition `nz` slices over `n_dev` devices with `halo`-deep overlaps.
pub fn halo_slabs(nz: usize, n_dev: usize, halo: usize) -> Vec<HaloSlab> {
    split_even(nz, n_dev)
        .into_iter()
        .filter(|(a, b)| b > a)
        .map(|(z0, z1)| HaloSlab {
            core_z0: z0,
            core_z1: z1,
            ext_z0: z0.saturating_sub(halo),
            ext_z1: (z1 + halo).min(nz),
        })
        .collect()
}

/// Multi-device TV gradient descent: `total_iters` iterations in rounds
/// of `n_in`, with per-round halo exchange. Returns the denoised volume
/// and the simulated-schedule stats.
pub fn tv_gradient_descent_split(
    ctx: &MultiGpu,
    vol: &Volume,
    total_iters: usize,
    alpha: f32,
    n_in: usize,
) -> anyhow::Result<(Volume, OpStats)> {
    run_split(ctx, vol, total_iters, n_in, |slab, iters, info| {
        tv_gd_approx_norm(slab, iters, alpha, info);
    })
}

/// Multi-device ROF denoising. Chambolle's dual state is local, so a
/// single round with `halo ≥ iters` reproduces the monolithic result
/// *exactly* in every core voxel; if `iters > n_in` the minimization is
/// chained in rounds (a documented approximation).
pub fn rof_denoise_split(
    ctx: &MultiGpu,
    vol: &Volume,
    lambda: f32,
    iters: usize,
    n_in: usize,
) -> anyhow::Result<(Volume, OpStats)> {
    run_split(ctx, vol, iters, n_in, |slab, round_iters, _| {
        *slab = tv::rof_denoise(slab, lambda, round_iters);
    })
}

/// Info handed to the per-slab kernel for global-norm approximation.
#[derive(Clone, Copy, Debug)]
pub struct GlobalInfo {
    /// Voxel count of the full (unsplit) volume.
    pub total_voxels: u64,
}

fn run_split<F>(
    ctx: &MultiGpu,
    vol: &Volume,
    total_iters: usize,
    n_in: usize,
    kernel: F,
) -> anyhow::Result<(Volume, OpStats)>
where
    F: Fn(&mut Volume, usize, GlobalInfo),
{
    let n_in = n_in.max(1);
    let nz = vol.nz;
    let slabs = halo_slabs(nz, ctx.n_gpus, n_in);
    let info = GlobalInfo { total_voxels: vol.data.len() as u64 };

    let mut current = vol.clone();
    let mut sim = ctx.fresh_sim();
    sim.property_check();
    // Host buffers for the exchange are allocated pinned (paper §2.3:
    // "the memory is allocated and pinned in the CPU RAM").
    sim.pin_host(vol.bytes(), true);

    let mut done = 0;
    while done < total_iters {
        let round = n_in.min(total_iters - done);
        // real execution: independent per-slab minimization on the
        // extended slabs, then core write-back (the halo exchange).
        let mut next = current.clone();
        for hs in &slabs {
            let mut ext = current.extract_slab(hs.ext_z0, hs.ext_z1);
            kernel(&mut ext, round, info);
            let core_in_ext =
                ext.extract_slab(hs.core_z0 - hs.ext_z0, hs.core_z1 - hs.ext_z0);
            next.insert_slab(hs.core_z0, &core_in_ext);
        }
        current = next;

        // simulated timeline for the round
        let plane = (vol.nx * vol.ny) as u64 * 4;
        let mut kernel_evs: Vec<Ev> = Vec::new();
        for (d, hs) in slabs.iter().enumerate() {
            let ext_bytes = (hs.ext_z1 - hs.ext_z0) as u64 * plane;
            let dev = d % ctx.n_gpus.max(1);
            sim.alloc(dev, &format!("tv_slab_r{done}"), ext_bytes)?;
            let h = sim.h2d(dev, ext_bytes, true, Ev::ZERO);
            let voxels = (hs.ext_z1 - hs.ext_z0) as u64 * (vol.nx * vol.ny) as u64;
            let t = sim.cost.tv_kernel_s(voxels, round);
            let k = sim.kernel(dev, t, h, &format!("tv d{dev} r{done}"));
            let core_bytes = (hs.core_z1 - hs.core_z0) as u64 * plane;
            let out = sim.d2h(dev, core_bytes, true, k);
            kernel_evs.push(out);
            sim.free(dev, &format!("tv_slab_r{done}"));
        }
        for e in kernel_evs {
            sim.host_sync(e);
        }
        done += round;
    }
    sim.unpin_host(vol.bytes());
    sim.sync_all();

    let stats = OpStats {
        makespan_s: sim.makespan(),
        breakdown: breakdown(sim.events()),
        splits_per_device: slabs.len().div_ceil(ctx.n_gpus.max(1)),
        pinned: true,
        peak_device_bytes: (0..sim.n_devices()).map(|d| sim.device_mem(d).peak()).max().unwrap_or(0),
        residency: Default::default(),
        degradation: Default::default(),
    };
    Ok((current, stats))
}

/// TV gradient descent with the paper's approximated global norms: each
/// slab estimates `‖x‖` and `‖g‖` from its own voxels scaled by
/// `√(N_total / N_local)` (uniform-distribution assumption).
fn tv_gd_approx_norm(slab: &mut Volume, iters: usize, alpha: f32, info: GlobalInfo) {
    let scale_up = (info.total_voxels as f64 / slab.data.len() as f64).sqrt();
    for _ in 0..iters {
        let g = tv::tv_gradient(slab);
        let gn_est = (g.norm2() * scale_up) as f32;
        if gn_est <= 1e-8 {
            return;
        }
        let xn_est = (slab.norm2() * scale_up) as f32;
        let step = alpha * xn_est / gn_est;
        for (x, gv) in slab.data.iter_mut().zip(&g.data) {
            *x -= step * gv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::MultiGpu;
    use crate::phantom;

    #[test]
    fn halo_slabs_cover_and_extend() {
        let slabs = halo_slabs(100, 3, 10);
        assert_eq!(slabs.len(), 3);
        assert_eq!(slabs[0].core_z0, 0);
        assert_eq!(slabs[2].core_z1, 100);
        // cores tile exactly
        for w in slabs.windows(2) {
            assert_eq!(w[0].core_z1, w[1].core_z0);
        }
        // halos clamp at the volume boundary
        assert_eq!(slabs[0].ext_z0, 0);
        assert_eq!(slabs[2].ext_z1, 100);
        assert_eq!(slabs[1].ext_z0, slabs[1].core_z0 - 10);
        assert_eq!(slabs[1].ext_z1, slabs[1].core_z1 + 10);
    }

    #[test]
    fn rof_split_exact_when_halo_covers_iters() {
        // Chambolle's update has a 1-voxel dependency radius per
        // iteration, so halo = iters reproduces the monolithic result
        // exactly in every core voxel.
        let v = phantom::random(12, 12, 24, 5);
        let iters = 6;
        let full = crate::kernels::tv::rof_denoise(&v, 0.2, iters);
        let ctx = MultiGpu::gtx1080ti(3);
        let (split, _) = rof_denoise_split(&ctx, &v, 0.2, iters, iters).unwrap();
        for (i, (a, b)) in full.data.iter().zip(&split.data).enumerate() {
            assert!((a - b).abs() < 1e-6, "voxel {i}: {a} vs {b}");
        }
    }

    #[test]
    fn rof_split_shallow_halo_differs() {
        // Negative control: halo shallower than the iteration count must
        // show boundary artefacts (otherwise the invariant above is
        // vacuous).
        let v = phantom::random(10, 10, 30, 7);
        let iters = 8;
        let full = crate::kernels::tv::rof_denoise(&v, 0.25, iters);
        let ctx = MultiGpu::gtx1080ti(3);
        let (exact, _) = rof_denoise_split(&ctx, &v, 0.25, iters, iters).unwrap();
        let (shallow, _) = rof_denoise_split(&ctx, &v, 0.25, iters, 1).unwrap();
        let err_exact = crate::metrics::rmse(&full, &exact);
        let err_shallow = crate::metrics::rmse(&full, &shallow);
        assert!(err_exact < 1e-6);
        assert!(err_shallow > err_exact * 10.0, "shallow {err_shallow} vs exact {err_exact}");
    }

    #[test]
    fn tv_gd_split_close_to_monolithic() {
        let v = phantom::random(12, 12, 24, 9);
        let mut full = v.clone();
        crate::kernels::tv::tv_gradient_descent(&mut full, 10, 0.01);
        let ctx = MultiGpu::gtx1080ti(2);
        let (split, _) = tv_gradient_descent_split(&ctx, &v, 10, 0.01, 10).unwrap();
        // approximate-norm splitting: within 2% relative error
        let rel = crate::metrics::rel_l2(&full, &split);
        assert!(rel < 0.02, "split TV-GD relative error {rel}");
    }

    #[test]
    fn tv_gd_split_reduces_tv() {
        let v = phantom::random(10, 10, 20, 11);
        let before = crate::kernels::tv::tv_value(&v);
        let ctx = MultiGpu::gtx1080ti(2);
        let (after_vol, stats) = tv_gradient_descent_split(&ctx, &v, 20, 0.01, 5).unwrap();
        let after = crate::kernels::tv::tv_value(&after_vol);
        assert!(after < before * 0.9, "TV {before} → {after}");
        assert!(stats.makespan_s > 0.0);
        assert!(stats.pinned);
    }

    #[test]
    fn deeper_halo_fewer_rounds_more_compute() {
        // The trade-off the paper tunes with N_in = 60: deeper halos
        // reduce exchanges (host syncs) but add redundant compute.
        let v = phantom::random(16, 16, 64, 3);
        let ctx = MultiGpu::gtx1080ti(4);
        let (_, shallow) = rof_denoise_split(&ctx, &v, 0.2, 12, 2).unwrap();
        let (_, deep) = rof_denoise_split(&ctx, &v, 0.2, 12, 12).unwrap();
        // deep halo: one round; shallow: six rounds of exchange overhead.
        // At this tiny size the per-round fixed costs dominate, so the
        // deep variant must win.
        assert!(
            deep.makespan_s < shallow.makespan_s,
            "deep {} vs shallow {}",
            deep.makespan_s,
            shallow.makespan_s
        );
    }
}
