//! Byte-size units and formatting helpers.

/// Bytes per KiB.
pub const KIB: u64 = 1024;
/// Bytes per MiB.
pub const MIB: u64 = 1024 * KIB;
/// Bytes per GiB.
pub const GIB: u64 = 1024 * MIB;

/// Size of one f32 element.
pub const F32_BYTES: u64 = 4;

/// Format a byte count with adaptive binary units.
pub fn fmt_bytes(bytes: u64) -> String {
    if bytes >= GIB {
        format!("{:.2} GiB", bytes as f64 / GIB as f64)
    } else if bytes >= MIB {
        format!("{:.2} MiB", bytes as f64 / MIB as f64)
    } else if bytes >= KIB {
        format!("{:.2} KiB", bytes as f64 / KIB as f64)
    } else {
        format!("{bytes} B")
    }
}

/// Parse strings like "11GiB", "256MiB", "1.5GiB", "4096" (bytes).
pub fn parse_bytes(s: &str) -> anyhow::Result<u64> {
    let s = s.trim();
    let (num, mult) = if let Some(p) = s.strip_suffix("GiB") {
        (p, GIB as f64)
    } else if let Some(p) = s.strip_suffix("MiB") {
        (p, MIB as f64)
    } else if let Some(p) = s.strip_suffix("KiB") {
        (p, KIB as f64)
    } else if let Some(p) = s.strip_suffix('B') {
        (p, 1.0)
    } else {
        (s, 1.0)
    };
    let v: f64 = num
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("cannot parse byte size '{s}'"))?;
    if v < 0.0 {
        anyhow::bail!("negative byte size '{s}'");
    }
    Ok((v * mult) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_adaptive() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(11 * GIB), "11.00 GiB");
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(parse_bytes("11GiB").unwrap(), 11 * GIB);
        assert_eq!(parse_bytes("256MiB").unwrap(), 256 * MIB);
        assert_eq!(parse_bytes("1.5GiB").unwrap(), (1.5 * GIB as f64) as u64);
        assert_eq!(parse_bytes("4096").unwrap(), 4096);
        assert_eq!(parse_bytes(" 64 KiB ").unwrap(), 64 * KIB);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_bytes("abc").is_err());
        assert!(parse_bytes("-5GiB").is_err());
    }
}
