// Seeded violation for the `no-panic-paths` lint: checked under the
// pretend path rust/src/coordinator/fixture.rs. Never compiled.

pub fn grab(v: Option<u32>) -> u32 {
    v.unwrap()
}

pub fn tagged(v: Option<u32>) -> u32 {
    v.expect("fixture message")
}

pub fn boom() {
    panic!("fixture panic");
}

pub fn later() {
    todo!()
}

#[cfg(test)]
mod tests {
    // test code is exempt: this unwrap must NOT be reported
    pub fn fine(v: Option<u32>) -> u32 {
        v.unwrap()
    }
}
