//! Coordinator-level end-to-end benchmark: the pipelined real executor
//! against the host-sequential baseline, on the operator calls users
//! actually make (`MultiGpu::forward`/`backward` in `Full` mode). This is
//! the substrate of the tracked `BENCH_coordinator.json` perf trajectory
//! (EXPERIMENTS.md §Executor-pipeline); `benches/coordinator.rs` is the
//! runner.
//!
//! Every entry measures the *same plan* twice — `ExecutorConfig::pipelined`
//! on and off — with the same total **kernel**-thread budget (the
//! pipelined executor divides the backend's threads across its device
//! workers and never runs more concurrent workers than that budget; its
//! per-worker merge lanes are the baseline's inline `+=` folds moved off
//! the critical path, not additional work), so the speedup isolates what
//! the pipeline changes: concurrent device workers, zero-copy staging
//! views, and the merge-fold overlapping kernels, instead of
//! host-serialized launches with owned-copy staging.
//!
//! `Full` mode always replays the discrete-event simulation before the
//! real execution; that fixed cost is identical on both sides and would
//! compress every ratio toward 1, so each workload also times
//! `ExecMode::SimOnly` and reports **sim-subtracted** medians (the raw
//! sim median is recorded per entry as `sim_median_s`).
//!
//! The acceptance workload is the multi-device **image-split** plan
//! (devices shrunk until slabs + chunk streaming are forced), which is
//! where the sequential path serializes the most work; the angle-split
//! plan rides along as the lighter comparison point.

use std::path::Path;
use std::time::Duration;

use crate::coordinator::splitter::{plan_backward_ooc, plan_forward_ooc};
use crate::coordinator::{backward, forward};
use crate::coordinator::{ExecMode, MergeStrategy, MultiGpu, ReconSession, SplitConfig};
use crate::geometry::Geometry;
use crate::kernels::scratch;
use crate::phantom;
use crate::simgpu::fault::{FaultPlan, MAX_LAUNCH_RETRIES};
use crate::util::json::Json;
use crate::util::stats::bench;
use crate::volume::{
    OocProjections, OocVolume, ProjInput, ProjectionSet, TrackedProjections, TrackedVolume,
    Volume, VolumeInput,
};

/// Schema tag of `BENCH_coordinator.json`; bump on breaking layout changes.
pub const SCHEMA: &str = "tigre-bench-coordinator/v1";

/// The "tiny device" threshold for the acceptance workload (re-exported
/// from the splitter, which owns the buffer arithmetic it must track).
pub use crate::coordinator::splitter::image_split_mem;

/// One benchmarked operator workload: sequential vs pipelined. The
/// executor medians are **sim-subtracted** (see module docs): the planning
/// + discrete-event replay time — identical for both executors — is
/// measured separately (`sim_median_s`) and removed, so the speedup
/// compares real execution against real execution.
#[derive(Clone, Debug)]
pub struct CoordBenchEntry {
    /// Workload id, e.g. `fp image-split n=48 a=24 gpus=2`.
    pub name: String,
    /// Sim-subtracted median of the sequential baseline executor, seconds.
    pub sequential_median_s: f64,
    /// Sim-subtracted median of the pipelined executor, seconds.
    pub pipelined_median_s: f64,
    /// Median of the `SimOnly` call for this workload (already removed
    /// from the two executor medians above).
    pub sim_median_s: f64,
    /// Measured samples per executor (the smaller of the two sides).
    pub samples: usize,
}

impl CoordBenchEntry {
    /// Sequential time over pipelined time (>1 means the pipeline wins).
    pub fn speedup(&self) -> f64 {
        if self.pipelined_median_s > 0.0 {
            self.sequential_median_s / self.pipelined_median_s
        } else {
            f64::INFINITY
        }
    }
}

/// Run the executor suite. `smoke` shrinks sizes and budgets to a
/// sub-second CI sanity run; the entry set (names modulo `n=` values)
/// stays the same so JSON consumers need no special cases.
pub fn run_suite(smoke: bool, threads: usize) -> Vec<CoordBenchEntry> {
    let mut out = Vec::new();
    // (n, n_angles, gpus) per workload row
    let cases: &[(usize, usize, usize)] =
        if smoke { &[(20, 12, 2)] } else { &[(48, 24, 2), (64, 32, 3)] };
    let budget = if smoke { Duration::from_millis(40) } else { Duration::from_millis(900) };
    let (warmup, min_iters) = if smoke { (0, 1) } else { (1, 3) };

    for &(n, n_angles, gpus) in cases {
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);

        // the acceptance workload: multi-device image-split plan
        let mem = image_split_mem(&g, &SplitConfig::default());
        let split_ctx = MultiGpu::gtx1080ti(gpus).with_device_mem(mem).with_threads(threads);
        out.extend(bench_pair(
            &format!("image-split n={n} a={n_angles} gpus={gpus}"),
            &split_ctx,
            &g,
            &v,
            warmup,
            min_iters,
            budget,
        ));

        // angle-split comparison point (full image resident per device)
        let full_ctx = MultiGpu::gtx1080ti(gpus).with_threads(threads);
        out.extend(bench_pair(
            &format!("angle-split n={n} a={n_angles} gpus={gpus}"),
            &full_ctx,
            &g,
            &v,
            warmup,
            min_iters,
            budget,
        ));

        // cross-iteration residency: cached vs uncached session on a
        // 1-GPU iterative loop (the regime where 2nd+ iterations stage
        // no projections at all — see coordinator::residency)
        out.push(bench_residency(
            &format!("residency landweber-3it n={n} a={n_angles} gpus=1"),
            &MultiGpu::gtx1080ti(1).with_threads(threads),
            &g,
            &v,
        ));

        // out-of-core streaming (PR 5): disk-backed inputs through the
        // loader lanes vs in-RAM inputs on the SAME host-budgeted plan
        out.extend(bench_ooc(
            &format!("n={n} a={n_angles} gpus={gpus}"),
            &full_ctx,
            &g,
            &v,
            warmup,
            min_iters,
            budget,
        ));
    }

    // merge-strategy ablation (PR 6): linear host fold vs reduction tree
    // per device count, on deterministic DES makespans
    out.extend(bench_merge(threads));
    // fault-tolerance ablation (ISSUE 7): recovery overhead of one
    // injected transient launch failure, on deterministic DES makespans
    out.extend(bench_fault(threads));
    // graceful-degradation ablation (ISSUE 8): replanning overhead of one
    // injected allocation failure, on deterministic DES makespans
    out.extend(bench_degrade(threads));
    // sparse-projector ablation (ISSUE 10): ray-driven vs precomputed CSR
    // over an iterative sweep, on deterministic DES makespans
    out.extend(bench_sparse(threads));
    out
}

/// Sparse-projector ablation (ISSUE 10): a K-iteration forward sweep with
/// the ray-driven kernel vs the precomputed CSR SpMV backend, per device
/// count, on deterministic DES makespans. The sparse side's FIRST call
/// charges the one-time matrix build (`CostModel::sparse_setup_s` folded
/// into each unit's kernel time) and every later call replays the warm
/// SpMV — `SparseShardCache::sim_op_warm` keys warmth on the (operator,
/// plan) pair, exactly like the real backend's shard reuse — so the entry
/// captures the amortization the backend exists for:
/// `sequential_median_s` = K ray-driven sweeps, `pipelined_median_s` =
/// one cold + K−1 warm sparse sweeps, and `speedup > 1` means the build
/// paid for itself within K iterations. The model's kernel-time crossover
/// is ≈7–8 iterations ([`crate::simgpu::CostModel::sparse_crossover_iters`]),
/// so K=20 clears it ~2.5× even where transfers eat part of the per-
/// iteration saving. Makespans are deterministic, so each side is
/// simulated once (cold + warm for sparse) and scaled — not looped. The
/// geometry is fixed large (as in [`bench_merge`]) so kernels, not fixed
/// launch/copy latencies, dominate the critical path; `SimOnly` keeps it
/// sub-second.
fn bench_sparse(threads: usize) -> Vec<CoordBenchEntry> {
    const N: usize = 512;
    const A: usize = 256;
    const ITERS: usize = 20;
    let g = Geometry::cone_beam(N, A);
    let mem = image_split_mem(&g, &SplitConfig::default());
    [1usize, 2, 4]
        .into_iter()
        .map(|gpus| {
            let makespan = |ctx: &MultiGpu| -> f64 {
                ctx.forward(&g, None, ExecMode::SimOnly)
                    .expect("bench sparse sim")
                    .1
                    .makespan_s
            };
            let ray = MultiGpu::gtx1080ti(gpus).with_device_mem(mem).with_threads(threads);
            // `with_sparse_backend` resets the thread budget, so apply it
            // before `with_threads`
            let sparse = MultiGpu::gtx1080ti(gpus)
                .with_device_mem(mem)
                .with_sparse_backend()
                .with_threads(threads);
            let cold = makespan(&sparse); // charges every shard build once
            let warm = makespan(&sparse); // pure SpMV replay
            CoordBenchEntry {
                name: format!("sparse fp image-split n={N} a={A} gpus={gpus} iters={ITERS}"),
                sequential_median_s: ITERS as f64 * makespan(&ray),
                pipelined_median_s: cold + (ITERS - 1) as f64 * warm,
                sim_median_s: 0.0,
                samples: 1,
            }
        })
        .collect()
}

/// Graceful-degradation ablation (ISSUE 8): simulated image-split forward
/// makespan with ONE injected allocation failure at (device 0, unit 0)
/// that exhausts the bounded allocation retries — forcing the
/// memory-pressure ladder to refine the plan and replay — vs the
/// pressure-free run, per device count. The real numeric path is
/// bit-identical under pressure replanning (a tested invariant: FP
/// refinement only re-chunks the angles), so — as with [`bench_fault`] —
/// each entry reports the deterministic DES makespans:
/// `sequential_median_s` = degraded, `pipelined_median_s` = clean, and
/// `speedup` is the **degradation-overhead factor** (≥1; the tracked gate
/// is <2×, i.e. a survived OOM must never double the makespan). A fresh
/// context — hence a fresh fault plan — is built per measurement because
/// injected sites fire once and then stay consumed.
fn bench_degrade(threads: usize) -> Vec<CoordBenchEntry> {
    const N: usize = 256;
    const A: usize = 128;
    let g = Geometry::cone_beam(N, A);
    let mem = image_split_mem(&g, &SplitConfig::default());
    [1usize, 2, 4]
        .into_iter()
        .map(|gpus| {
            let makespan = |degraded: bool| -> f64 {
                let ctx =
                    MultiGpu::gtx1080ti(gpus).with_device_mem(mem).with_threads(threads);
                let ctx = if degraded {
                    ctx.with_fault_plan(
                        FaultPlan::new().alloc_fail(0, 0, MAX_LAUNCH_RETRIES + 1),
                    )
                } else {
                    ctx
                };
                ctx.forward(&g, None, ExecMode::SimOnly)
                    .expect("bench degrade sim")
                    .1
                    .makespan_s
            };
            CoordBenchEntry {
                name: format!("degrade fp image-split n={N} a={A} gpus={gpus}"),
                sequential_median_s: makespan(true),
                pipelined_median_s: makespan(false),
                sim_median_s: 0.0,
                samples: 1,
            }
        })
        .collect()
}

/// Fault-tolerance ablation (ISSUE 7): simulated image-split forward
/// makespan with ONE injected transient launch failure at (device 0,
/// unit 0) vs the fault-free run, per device count. The real numeric
/// path is bit-identical under faults (a tested invariant), so — as with
/// [`bench_merge`] — each entry reports the deterministic DES makespans:
/// `sequential_median_s` = faulted, `pipelined_median_s` = clean, and
/// `speedup` is the **recovery-overhead factor** (≥1; the tracked gate is
/// <2×, i.e. a single retried launch must never double the makespan).
/// A fresh context — hence a fresh fault plan — is built per measurement
/// because injected sites fire once and then stay consumed.
fn bench_fault(threads: usize) -> Vec<CoordBenchEntry> {
    const N: usize = 256;
    const A: usize = 128;
    let g = Geometry::cone_beam(N, A);
    let mem = image_split_mem(&g, &SplitConfig::default());
    [1usize, 2, 4]
        .into_iter()
        .map(|gpus| {
            let makespan = |faulted: bool| -> f64 {
                let ctx =
                    MultiGpu::gtx1080ti(gpus).with_device_mem(mem).with_threads(threads);
                let ctx = if faulted {
                    ctx.with_fault_plan(FaultPlan::new().transient_launch(0, 0))
                } else {
                    ctx
                };
                ctx.forward(&g, None, ExecMode::SimOnly)
                    .expect("bench fault sim")
                    .1
                    .makespan_s
            };
            CoordBenchEntry {
                name: format!("fault fp image-split n={N} a={A} gpus={gpus}"),
                sequential_median_s: makespan(true),
                pipelined_median_s: makespan(false),
                sim_median_s: 0.0,
                samples: 1,
            }
        })
        .collect()
}

/// Merge-strategy ablation (PR 6): simulated image-split forward makespan
/// with the linear host fold vs the pairwise reduction tree, per device
/// count. The real numeric path is bit-identical on both sides (a tested
/// invariant), so — as with [`bench_residency`] — each entry reports the
/// deterministic DES makespans: `sequential_median_s` = linear merge,
/// `pipelined_median_s` = tree merge, `speedup` = the merge
/// critical-path win. The geometry is fixed rather than smoke-scaled:
/// it must be large enough that per-fold bandwidth, not fixed launch and
/// link latency, dominates, or the log-vs-linear scaling the entries
/// exist to track would be invisible. `SimOnly` keeps even the fixed
/// size sub-second.
fn bench_merge(threads: usize) -> Vec<CoordBenchEntry> {
    const N: usize = 256;
    const A: usize = 128;
    let g = Geometry::cone_beam(N, A);
    let mem = image_split_mem(&g, &SplitConfig::default());
    [1usize, 2, 4, 8, 16]
        .into_iter()
        .map(|gpus| {
            let makespan = |tree: bool| -> f64 {
                let ctx =
                    MultiGpu::gtx1080ti(gpus).with_device_mem(mem).with_threads(threads);
                let ctx =
                    if tree { ctx.with_merge_strategy(MergeStrategy::Tree) } else { ctx };
                ctx.forward(&g, None, ExecMode::SimOnly)
                    .expect("bench merge sim")
                    .1
                    .makespan_s
            };
            CoordBenchEntry {
                name: format!("merge image-split n={N} a={A} gpus={gpus}"),
                sequential_median_s: makespan(false),
                pipelined_median_s: makespan(true),
                sim_median_s: 0.0,
                samples: 1,
            }
        })
        .collect()
}

/// Streamed-vs-in-RAM throughput of the pipelined executor on identical
/// host-budgeted OOC plans (bit-identical outputs — only the staging
/// tier differs). Field mapping for these entries:
/// `sequential_median_s` = **streamed from disk**, `pipelined_median_s`
/// = **in-RAM**, so `speedup` is the streaming overhead factor (≈1 when
/// the loader lanes hide the reads behind kernels, >1 when exposed).
fn bench_ooc(
    tag: &str,
    ctx: &MultiGpu,
    g: &Geometry,
    v: &Volume,
    warmup: usize,
    min_iters: usize,
    budget: Duration,
) -> Vec<CoordBenchEntry> {
    // host budget smaller than the volume+projection footprint: the
    // defining constraint of the out-of-core workload class
    let host_budget = (g.volume_bytes() + g.proj_bytes()) / 2;
    let fp_plan =
        plan_forward_ooc(g, ctx.n_gpus, ctx.spec.mem_bytes, &ctx.split, host_budget)
            .expect("bench ooc fp plan");
    let bp_plan =
        plan_backward_ooc(g, ctx.n_gpus, ctx.spec.mem_bytes, &ctx.split, host_budget)
            .expect("bench ooc bp plan");

    let dir = std::env::temp_dir()
        .join("tigre_bench_ooc")
        .join(format!("{}_{}", std::process::id(), tag.replace(' ', "_")));
    std::fs::create_dir_all(&dir).expect("bench ooc tmpdir");
    let slab_nz = fp_plan
        .per_device
        .iter()
        .flat_map(|d| &d.slabs)
        .map(|s| s.len())
        .max()
        .unwrap_or(1)
        .max(1);
    // Store cache budgets are deliberately MINIMAL (two staging units,
    // not `host_budget`): with a roomy cache the whole input would be
    // RAM-resident after warmup and the "streamed" side would measure
    // memcpys, not disk streaming. Two units keep the double-buffered
    // loads honest while every pass re-reads the file.
    let plane_bytes = (g.n_vox[0] * g.n_vox[1]) as u64 * 4;
    let vstore = OocVolume::from_volume(
        &dir.join("vol.raw"),
        v,
        slab_nz,
        2 * slab_nz as u64 * plane_bytes,
    )
    .expect("vol spill");
    let p: ProjectionSet =
        ctx.forward(g, Some(v), ExecMode::Full).expect("bench forward").0.unwrap();
    let bp_chunk = bp_plan.angle_chunks.iter().map(|c| c.len()).max().unwrap_or(1);
    let pstore = OocProjections::from_projections(
        &dir.join("proj.raw"),
        &p,
        bp_chunk.max(1),
        2 * bp_chunk.max(1) as u64 * g.single_proj_bytes(),
    )
    .expect("proj spill");

    // the DES replay is plan-driven (identical on the RAM and OOC input
    // sides of each pair) — measure it once per plan and subtract
    let fp_sim = bench(&format!("ooc fp {tag} sim"), warmup, min_iters, budget, || {
        std::hint::black_box(
            forward::run_with(ctx, g, None, ExecMode::SimOnly, &fp_plan, None).expect("fp sim"),
        );
    });
    let bp_sim = bench(&format!("ooc bp {tag} sim"), warmup, min_iters, budget, || {
        std::hint::black_box(
            backward::run_with(ctx, g, None, ExecMode::SimOnly, &bp_plan, None).expect("bp sim"),
        );
    });
    let fp_ram = bench(&format!("ooc fp {tag} ram"), warmup, min_iters, budget, || {
        std::hint::black_box(
            forward::run_with(ctx, g, Some(VolumeInput::Ram(v)), ExecMode::Full, &fp_plan, None)
                .expect("fp ram"),
        );
    });
    let fp_ooc = bench(&format!("ooc fp {tag} stream"), warmup, min_iters, budget, || {
        std::hint::black_box(
            forward::run_with(
                ctx,
                g,
                Some(VolumeInput::Ooc(&vstore)),
                ExecMode::Full,
                &fp_plan,
                None,
            )
            .expect("fp stream"),
        );
    });
    let bp_ram = bench(&format!("ooc bp {tag} ram"), warmup, min_iters, budget, || {
        std::hint::black_box(
            backward::run_with(ctx, g, Some(ProjInput::Ram(&p)), ExecMode::Full, &bp_plan, None)
                .expect("bp ram"),
        );
    });
    let bp_ooc = bench(&format!("ooc bp {tag} stream"), warmup, min_iters, budget, || {
        std::hint::black_box(
            backward::run_with(
                ctx,
                g,
                Some(ProjInput::Ooc(&pstore)),
                ExecMode::Full,
                &bp_plan,
                None,
            )
            .expect("bp stream"),
        );
    });

    let minus_sim = |full: f64, sim: f64| (full - sim).max(1e-9);
    let fp_sim_s = fp_sim.samples.median();
    let bp_sim_s = bp_sim.samples.median();
    drop(vstore);
    drop(pstore);
    let out = vec![
        CoordBenchEntry {
            name: format!("ooc fp stream {tag}"),
            sequential_median_s: minus_sim(fp_ooc.samples.median(), fp_sim_s),
            pipelined_median_s: minus_sim(fp_ram.samples.median(), fp_sim_s),
            sim_median_s: fp_sim_s,
            samples: fp_ooc.samples.len().min(fp_ram.samples.len()),
        },
        CoordBenchEntry {
            name: format!("ooc bp stream {tag}"),
            sequential_median_s: minus_sim(bp_ooc.samples.median(), bp_sim_s),
            pipelined_median_s: minus_sim(bp_ram.samples.median(), bp_sim_s),
            sim_median_s: bp_sim_s,
            samples: bp_ooc.samples.len().min(bp_ram.samples.len()),
        },
    ];
    let _ = std::fs::remove_dir_all(&dir);
    out
}

/// Simulated-makespan comparison of a 3-iteration Landweber-style loop
/// with the residency cache on vs off. The real numeric path is identical
/// on both sides (bit-parity is a tested invariant), so the entry reports
/// the deterministic DES makespans: `sequential_median_s` = uncached,
/// `pipelined_median_s` = cached, `speedup` = the residency win.
fn bench_residency(tag: &str, ctx: &MultiGpu, g: &Geometry, v: &Volume) -> CoordBenchEntry {
    const ITERS: usize = 3;
    let proj: ProjectionSet =
        ctx.forward(g, Some(v), ExecMode::Full).expect("bench forward").0.unwrap();
    let run = |cached: bool| -> f64 {
        let mut sess = ReconSession::new(ctx, g).expect("bench session");
        if !cached {
            sess = sess.without_residency();
        }
        let b = TrackedProjections::new(proj.clone());
        let mut x = TrackedVolume::new(Volume::zeros_like(g));
        for _ in 0..ITERS {
            let ax = sess.forward(&x).expect("bench fp");
            let (upd, _) = sess.backward_residual(&b, &ax).expect("bench bp");
            sess.recycle_projections(ax);
            x.write().add_scaled(&upd, 1e-3);
            scratch::recycle_volume(upd);
        }
        sess.recycle_projections(b);
        sess.sim_time_s
    };
    CoordBenchEntry {
        name: tag.to_string(),
        sequential_median_s: run(false),
        pipelined_median_s: run(true),
        sim_median_s: 0.0,
        samples: ITERS,
    }
}

/// Measure FP and BP for one context, sequential vs pipelined.
fn bench_pair(
    tag: &str,
    ctx: &MultiGpu,
    g: &Geometry,
    v: &Volume,
    warmup: usize,
    min_iters: usize,
    budget: Duration,
) -> Vec<CoordBenchEntry> {
    let pipe = ctx.clone();
    let seq = ctx.clone().with_sequential_executor();

    // projections for the BP side (content does not affect timing shape)
    let p: ProjectionSet =
        pipe.forward(g, Some(v), ExecMode::Full).expect("bench forward").0.unwrap();

    // The Full-mode calls below each replay the DES schedule before real
    // execution; time that fixed cost alone so it can be subtracted.
    let fp_sim = bench(&format!("fp {tag} sim"), warmup, min_iters, budget, || {
        std::hint::black_box(pipe.forward(g, None, ExecMode::SimOnly).expect("fp sim"));
    });
    let bp_sim = bench(&format!("bp {tag} sim"), warmup, min_iters, budget, || {
        std::hint::black_box(pipe.backward(g, None, ExecMode::SimOnly).expect("bp sim"));
    });

    let fp_seq = bench(&format!("fp {tag} sequential"), warmup, min_iters, budget, || {
        std::hint::black_box(seq.forward(g, Some(v), ExecMode::Full).expect("fp seq"));
    });
    let fp_pipe = bench(&format!("fp {tag} pipelined"), warmup, min_iters, budget, || {
        std::hint::black_box(pipe.forward(g, Some(v), ExecMode::Full).expect("fp pipe"));
    });
    let bp_seq = bench(&format!("bp {tag} sequential"), warmup, min_iters, budget, || {
        std::hint::black_box(seq.backward(g, Some(&p), ExecMode::Full).expect("bp seq"));
    });
    let bp_pipe = bench(&format!("bp {tag} pipelined"), warmup, min_iters, budget, || {
        std::hint::black_box(pipe.backward(g, Some(&p), ExecMode::Full).expect("bp pipe"));
    });

    // sim-subtracted real-execution time, floored against timer noise
    let minus_sim = |full: f64, sim: f64| (full - sim).max(1e-9);
    let fp_sim_s = fp_sim.samples.median();
    let bp_sim_s = bp_sim.samples.median();
    vec![
        CoordBenchEntry {
            name: format!("fp {tag}"),
            sequential_median_s: minus_sim(fp_seq.samples.median(), fp_sim_s),
            pipelined_median_s: minus_sim(fp_pipe.samples.median(), fp_sim_s),
            sim_median_s: fp_sim_s,
            samples: fp_seq.samples.len().min(fp_pipe.samples.len()),
        },
        CoordBenchEntry {
            name: format!("bp {tag}"),
            sequential_median_s: minus_sim(bp_seq.samples.median(), bp_sim_s),
            pipelined_median_s: minus_sim(bp_pipe.samples.median(), bp_sim_s),
            sim_median_s: bp_sim_s,
            samples: bp_seq.samples.len().min(bp_pipe.samples.len()),
        },
    ]
}

/// Encode one run (label + entries) as a JSON object.
pub fn run_to_json(label: &str, threads: usize, smoke: bool, entries: &[CoordBenchEntry]) -> Json {
    Json::obj(vec![
        ("label", Json::str(label)),
        ("threads", Json::num(threads as f64)),
        ("smoke", Json::Bool(smoke)),
        (
            "entries",
            Json::arr(
                entries
                    .iter()
                    .map(|e| {
                        Json::obj(vec![
                            ("name", Json::str(e.name.clone())),
                            ("sequential_median_s", Json::num(e.sequential_median_s)),
                            ("pipelined_median_s", Json::num(e.pipelined_median_s)),
                            ("sim_median_s", Json::num(e.sim_median_s)),
                            ("samples", Json::num(e.samples as f64)),
                            ("speedup", Json::num(e.speedup())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Append a run to the `BENCH_coordinator.json`-format trajectory at
/// `path` (created if absent; `notes` and other top-level fields are
/// preserved — see [`super::append_trajectory_run`]).
pub fn append_run_to_file(
    path: &Path,
    label: &str,
    threads: usize,
    smoke: bool,
    entries: &[CoordBenchEntry],
) -> anyhow::Result<()> {
    super::append_trajectory_run(path, SCHEMA, run_to_json(label, threads, smoke, entries))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_entries() -> Vec<CoordBenchEntry> {
        vec![CoordBenchEntry {
            name: "fp image-split n=48 a=24 gpus=2".into(),
            sequential_median_s: 0.6,
            pipelined_median_s: 0.3,
            sim_median_s: 0.001,
            samples: 3,
        }]
    }

    #[test]
    fn speedup_is_seq_over_pipe() {
        assert!((fake_entries()[0].speedup() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn run_json_has_schema_fields() {
        let j = run_to_json("probe", 4, true, &fake_entries());
        assert_eq!(j.get("label").and_then(Json::as_str), Some("probe"));
        let es = j.get("entries").and_then(Json::as_arr).unwrap();
        assert_eq!(es.len(), 1);
        assert!(es[0].get("sequential_median_s").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(es[0].get("pipelined_median_s").and_then(Json::as_f64).unwrap() > 0.0);
        assert!((es[0].get("speedup").and_then(Json::as_f64).unwrap() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn append_creates_then_appends() {
        let dir = std::env::temp_dir().join(format!("tigre_bench_coord_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_coordinator.json");
        let _ = std::fs::remove_file(&path);
        append_run_to_file(&path, "r1", 4, true, &fake_entries()).unwrap();
        append_run_to_file(&path, "r2", 4, true, &fake_entries()).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some(SCHEMA));
        assert_eq!(doc.get("runs").and_then(Json::as_arr).unwrap().len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn image_split_mem_actually_splits_both_operators() {
        let g = Geometry::cone_beam(48, 24);
        let cfg = SplitConfig::default();
        let mem = image_split_mem(&g, &cfg);
        for gpus in [2usize, 3] {
            let fp = crate::coordinator::splitter::plan_forward(&g, gpus, mem, &cfg).unwrap();
            assert!(fp.image_split, "gpus={gpus}: FP plan must image-split");
            let bp = crate::coordinator::splitter::plan_backward(&g, gpus, mem, &cfg).unwrap();
            assert!(bp.image_split, "gpus={gpus}: BP plan must image-split");
            assert!(bp.splits_per_device() > 1, "gpus={gpus}: BP slab queue expected");
        }
    }

    #[test]
    fn smoke_suite_runs_and_covers_both_operators_and_plans() {
        let entries = run_suite(true, 2);
        assert_eq!(
            entries.len(),
            21,
            "fp/bp × image-split/angle-split + residency + ooc fp/bp + 5 merge counts + 3 fault counts + 3 degrade counts + 3 sparse counts"
        );
        for e in &entries {
            assert!(
                e.sequential_median_s > 0.0 && e.pipelined_median_s > 0.0 && e.samples >= 1,
                "{}: empty measurement",
                e.name
            );
            assert!(e.speedup() > 0.0);
        }
        assert!(entries.iter().any(|e| e.name.starts_with("fp image-split")));
        assert!(entries.iter().any(|e| e.name.starts_with("bp angle-split")));
        // the residency entry compares deterministic DES makespans: at
        // 1 GPU the cached loop must beat the uncached one
        let res = entries.iter().find(|e| e.name.starts_with("residency")).unwrap();
        assert!(res.speedup() > 1.0, "residency speedup {} ≤ 1", res.speedup());
        // ooc entries compare streamed vs in-RAM staging on one plan
        assert!(entries.iter().any(|e| e.name.starts_with("ooc fp stream")));
        assert!(entries.iter().any(|e| e.name.starts_with("ooc bp stream")));
        // merge entries compare deterministic DES makespans of the linear
        // host fold vs the pairwise tree: the tree must win once the fold
        // chain is deep (≥8 devices) and the win must widen with scale
        let m = |gpus: usize| {
            entries
                .iter()
                .find(|e| {
                    e.name.starts_with("merge") && e.name.ends_with(&format!("gpus={gpus}"))
                })
                .unwrap_or_else(|| panic!("missing merge entry for gpus={gpus}"))
        };
        assert_eq!(m(1).speedup(), 1.0, "one device has nothing to merge");
        assert!(m(8).speedup() > 1.0, "tree loses at 8 devices: {}", m(8).speedup());
        assert!(
            m(16).speedup() > m(8).speedup(),
            "log-vs-linear gap must widen: {} vs {}",
            m(16).speedup(),
            m(8).speedup()
        );
        // fault entries compare a faulted vs clean DES makespan: one
        // retried transient must cost something but never double the run
        for gpus in [1usize, 2, 4] {
            let f = entries
                .iter()
                .find(|e| {
                    e.name.starts_with("fault") && e.name.ends_with(&format!("gpus={gpus}"))
                })
                .unwrap_or_else(|| panic!("missing fault entry for gpus={gpus}"));
            let overhead = f.speedup();
            assert!(
                overhead > 1.0 && overhead < 2.0,
                "fault gpus={gpus}: recovery overhead {overhead} outside (1, 2)"
            );
        }
        // degrade entries compare a pressure-replanned vs clean DES
        // makespan: surviving one exhausted allocation must cost the
        // ladder penalty + the refined plan but never double the run
        for gpus in [1usize, 2, 4] {
            let d = entries
                .iter()
                .find(|e| {
                    e.name.starts_with("degrade") && e.name.ends_with(&format!("gpus={gpus}"))
                })
                .unwrap_or_else(|| panic!("missing degrade entry for gpus={gpus}"));
            let overhead = d.speedup();
            assert!(
                overhead > 1.0 && overhead < 2.0,
                "degrade gpus={gpus}: replanning overhead {overhead} outside (1, 2)"
            );
        }
        // sparse entries compare K ray-driven sweeps vs one cold + K−1
        // warm sparse sweeps: past the model's ≈7–8-iteration crossover
        // the CSR build must have amortized at every device count
        for gpus in [1usize, 2, 4] {
            let s = entries
                .iter()
                .find(|e| {
                    e.name.starts_with("sparse") && e.name.contains(&format!("gpus={gpus} "))
                })
                .unwrap_or_else(|| panic!("missing sparse entry for gpus={gpus}"));
            assert!(
                s.speedup() > 1.0,
                "sparse gpus={gpus}: build not amortized over the sweep, speedup {}",
                s.speedup()
            );
        }
    }
}
