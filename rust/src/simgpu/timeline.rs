//! Timeline events and the Fig.-9 style breakdown.

/// The three bins of the paper's Fig. 9.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Category {
    /// Kernel launches (including copies fully hidden behind them).
    Compute,
    /// Host memory page-locking and unlocking.
    PinUnpin,
    /// Non-overlapped memory work: allocation, freeing, exposed copies.
    /// Peer-to-peer merge transfers (`SimNode::p2p`) and host-side merge
    /// folds also bin here — they are memory movement, not kernel time,
    /// even when a later accumulate kernel depends on them.
    OtherMem,
}

/// One simulated operation on the timeline.
#[derive(Clone, Debug)]
pub struct TimelineEvent {
    /// Device index, or `None` for host-only operations (pin/unpin …).
    pub device: Option<usize>,
    /// Which Fig.-9 bin the operation belongs to.
    pub category: Category,
    /// Start time in simulated seconds.
    pub t_start: f64,
    /// End time in simulated seconds.
    pub t_end: f64,
    /// Human-readable label (kernel/copy name) for traces.
    pub label: String,
}

impl TimelineEvent {
    /// Event length in simulated seconds.
    pub fn duration(&self) -> f64 {
        self.t_end - self.t_start
    }
}

/// Fig.-9 binning of a timeline: the makespan `[0, T]` is split into
/// * `compute` — instants where at least one compute engine is busy,
/// * `pin`     — remaining instants covered by pin/unpin work,
/// * `othermem`— remaining instants covered by memory operations,
/// * `idle`    — nothing happening (host logic between queue submissions).
///
/// This matches the paper's accounting: "Computing contains the time for
/// kernel launches, which includes simultaneous memory copies as they
/// happen concurrently"; only *exposed* memory time counts as memory.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Breakdown {
    /// Seconds with at least one compute engine busy.
    pub compute: f64,
    /// Exposed (non-overlapped) pin/unpin seconds.
    pub pin: f64,
    /// Exposed memory-operation seconds.
    pub othermem: f64,
    /// Seconds with nothing happening.
    pub idle: f64,
}

impl Breakdown {
    /// Sum of all four bins — the makespan.
    pub fn total(&self) -> f64 {
        self.compute + self.pin + self.othermem + self.idle
    }

    /// `(compute, pin, othermem, idle)` as fractions of the makespan.
    pub fn fractions(&self) -> (f64, f64, f64, f64) {
        let t = self.total().max(1e-300);
        (self.compute / t, self.pin / t, self.othermem / t, self.idle / t)
    }
}

/// Compute the exposed-time breakdown over the events' makespan with an
/// O(E log E) boundary sweep (the Fig. 7–9 sweeps produce tens of
/// thousands of events at N = 3072, so the naive per-interval scan is
/// far too slow).
pub fn breakdown(events: &[TimelineEvent]) -> Breakdown {
    if events.is_empty() {
        return Breakdown::default();
    }
    // boundary list: (time, category index, delta)
    let mut bounds: Vec<(f64, usize, i64)> = Vec::with_capacity(events.len() * 2 + 1);
    for e in events {
        if e.t_end <= e.t_start {
            continue;
        }
        let c = match e.category {
            Category::Compute => 0,
            Category::PinUnpin => 1,
            Category::OtherMem => 2,
        };
        bounds.push((e.t_start, c, 1));
        bounds.push((e.t_end, c, -1));
    }
    bounds.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    let mut out = Breakdown::default();
    let mut active = [0i64; 3];
    let mut t_prev = 0.0f64;
    let mut i = 0;
    while i < bounds.len() {
        let t = bounds[i].0;
        if t > t_prev {
            let d = t - t_prev;
            if active[0] > 0 {
                out.compute += d;
            } else if active[1] > 0 {
                out.pin += d;
            } else if active[2] > 0 {
                out.othermem += d;
            } else {
                out.idle += d;
            }
        }
        // apply all deltas at this timestamp
        while i < bounds.len() && bounds[i].0 == t {
            active[bounds[i].1] += bounds[i].2;
            i += 1;
        }
        t_prev = t_prev.max(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(cat: Category, t0: f64, t1: f64) -> TimelineEvent {
        TimelineEvent { device: Some(0), category: cat, t_start: t0, t_end: t1, label: String::new() }
    }

    #[test]
    fn empty_timeline() {
        assert_eq!(breakdown(&[]).total(), 0.0);
    }

    #[test]
    fn copy_hidden_behind_compute_counts_as_compute() {
        let events = vec![
            ev(Category::Compute, 0.0, 2.0),
            ev(Category::OtherMem, 0.5, 1.5), // fully overlapped copy
        ];
        let b = breakdown(&events);
        assert!((b.compute - 2.0).abs() < 1e-12);
        assert_eq!(b.othermem, 0.0);
    }

    #[test]
    fn exposed_copy_counts_as_memory() {
        let events = vec![
            ev(Category::Compute, 0.0, 1.0),
            ev(Category::OtherMem, 1.0, 1.6), // after the kernel: exposed
        ];
        let b = breakdown(&events);
        assert!((b.compute - 1.0).abs() < 1e-12);
        assert!((b.othermem - 0.6).abs() < 1e-12);
    }

    #[test]
    fn pin_beats_memory_in_precedence() {
        let events = vec![
            ev(Category::PinUnpin, 0.0, 1.0),
            ev(Category::OtherMem, 0.5, 1.5),
        ];
        let b = breakdown(&events);
        assert!((b.pin - 1.0).abs() < 1e-12);
        assert!((b.othermem - 0.5).abs() < 1e-12);
    }

    #[test]
    fn idle_gap_counted() {
        let events = vec![ev(Category::Compute, 1.0, 2.0)];
        let b = breakdown(&events);
        assert!((b.idle - 1.0).abs() < 1e-12, "gap [0,1) is idle");
        assert!((b.total() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fractions_sum_to_one() {
        let events = vec![
            ev(Category::Compute, 0.0, 1.0),
            ev(Category::PinUnpin, 1.0, 1.5),
            ev(Category::OtherMem, 1.5, 2.0),
        ];
        let (c, p, m, i) = breakdown(&events).fractions();
        assert!((c + p + m + i - 1.0).abs() < 1e-12);
        assert!((c - 0.5).abs() < 1e-12);
    }
}
