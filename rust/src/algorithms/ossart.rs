//! The SART family: SIRT, SART and OS-SART (the paper's Fig. 11
//! algorithm), all as ordered-subset Kaczmarz-type updates
//!
//! `x ← x + λ · V_s ∘ Aᵀ_s( W_s ∘ (b_s − A_s x) )`
//!
//! where `s` is the angle subset, `W_s = 1 / A_s·1` (ray lengths through
//! the volume) and `V_s = 1 / Aᵀ_s·1` (backprojection weights). Subset
//! size 1 gives SART, the full angle set gives SIRT.

use crate::coordinator::checkpoint::{self, CheckpointState};
use crate::coordinator::{MultiGpu, ReconSession};
use crate::geometry::Geometry;
use crate::kernels::{scratch, BackprojWeight};
use crate::volume::{ProjectionSet, TrackedProjections, TrackedVolume, Volume};

use super::common::{
    ordered_subsets, projector_ctx, safe_recip, DivergenceGuard, ReconOpts, ReconResult,
};
use crate::coordinator::DegradeEvent;

/// OS-SART with the given subset size.
///
/// Each angle subset is its own operator geometry, so each gets its own
/// [`ReconSession`] (plans computed once per subset, reused across every
/// iteration; each session is an independent residency domain — see the
/// `coordinator::residency` docs).
pub fn os_sart(
    ctx: &MultiGpu,
    g: &Geometry,
    proj: &ProjectionSet,
    subset_size: usize,
    opts: &ReconOpts,
) -> anyhow::Result<ReconResult> {
    // SART-family updates need the pseudo-matched backprojector: FDK
    // distance weights would bias the row/column normalization. The
    // opts-level projector override (if any) is applied first.
    let ctx = matched_ctx(&projector_ctx(ctx, opts));
    let subsets = ordered_subsets(g.n_angles(), subset_size);

    // Per-subset geometries and weights.
    let ones_vol = TrackedVolume::new({
        let mut v = Volume::zeros_like(g);
        for x in &mut v.data {
            *x = 1.0;
        }
        v
    });

    let mut x = TrackedVolume::new(Volume::zeros_like(g));
    let mut residuals = Vec::with_capacity(opts.iterations);

    // Precompute per-subset structures (session + W + V).
    struct Subset {
        sess: ReconSession,
        idxs: Vec<usize>,
        w: ProjectionSet,
        v: Volume,
    }
    let mut subs = Vec::with_capacity(subsets.len());
    for idxs in &subsets {
        let geo = g.angle_subset_geometry(idxs);
        let mut sess = ReconSession::new(&ctx, &geo)?;
        // W = 1 / (A_s 1): ray lengths through a ones-volume
        let mut w = sess.forward(&ones_vol)?.into_inner();
        safe_recip(&mut w.data);
        // V = 1 / (Aᵀ_s 1): backprojection of ones
        let ones_proj = TrackedProjections::new({
            let mut p = ProjectionSet::zeros_like(&geo);
            for v in &mut p.data {
                *v = 1.0;
            }
            p
        });
        let mut v = sess.backward(&ones_proj)?;
        sess.recycle_projections(ones_proj);
        safe_recip(&mut v.data);
        subs.push(Subset { sess, idxs: idxs.clone(), w, v });
    }

    // checkpoints snapshot at outer-sweep granularity; the subset weights
    // above are recomputed deterministically on resume
    let (mut ck, resumed) = checkpoint::setup(&opts.checkpoint, "os-sart")?;
    let mut start = 0;
    if let Some(mut st) = resumed {
        start = st.iteration.min(opts.iterations);
        residuals = st.residuals.clone();
        scratch::recycle_volume(x.replace(st.volume("x")?));
    }
    let mut guard = DivergenceGuard::new("os-sart", opts);
    guard.seed(&residuals);
    let mut lambda = opts.lambda;
    for it in start..opts.iterations {
        ctx.set_fault_iteration(it);
        let mut res2 = 0.0f64;
        for sub in &mut subs {
            let b_s = proj.extract_subset(&sub.idxs);
            // residual r = W ∘ (b_s − A_s x)
            let mut r = sub.sess.forward(&x)?;
            for ((rv, bv), wv) in r.write().data.iter_mut().zip(&b_s.data).zip(&sub.w.data) {
                let raw = bv - *rv;
                res2 += (raw as f64) * (raw as f64);
                *rv = raw * wv;
            }
            // x += λ · V ∘ Aᵀ_s r
            let upd = sub.sess.backward(&r)?;
            sub.sess.recycle_projections(r);
            scratch::recycle_projections(b_s);
            for ((xv, uv), vv) in x.write().data.iter_mut().zip(&upd.data).zip(&sub.v.data) {
                *xv += lambda * uv * vv;
            }
            scratch::recycle_volume(upd);
            if opts.nonneg {
                x.write().clamp_min(0.0);
            }
        }
        let res = res2.sqrt();
        residuals.push(res);
        // residual growth → relax λ for the following sweeps
        if let Some(f) = guard.check(it, res)? {
            lambda *= f;
            ctx.degrade
                .record(DegradeEvent::StepBackoff { algorithm: "os-sart", iteration: it });
        }
        if opts.verbose {
            crate::log_info!("os-sart iter {it}: residual {res:.4e}");
        }
        if let Some(ck) = ck.as_mut() {
            if ck.due(it + 1) {
                ck.save(&CheckpointState {
                    iteration: it + 1,
                    residuals: residuals.clone(),
                    volumes: vec![("x".into(), x.get().clone())],
                    ..Default::default()
                })?;
            }
        }
    }

    let (sim_time_s, peak_device_bytes) = subs
        .iter()
        .fold((0.0, 0), |(t, p), s| (t + s.sess.sim_time_s, p.max(s.sess.peak_device_bytes)));
    scratch::recycle_volume(ones_vol.into_inner());
    Ok(ReconResult {
        volume: x.into_inner(),
        residuals,
        sim_time_s,
        peak_device_bytes,
        backoffs: guard.backoffs,
    })
}

/// SART: ordered subsets of size 1.
pub fn sart(
    ctx: &MultiGpu,
    g: &Geometry,
    proj: &ProjectionSet,
    opts: &ReconOpts,
) -> anyhow::Result<ReconResult> {
    os_sart(ctx, g, proj, 1, opts)
}

/// SIRT: a single subset containing every angle.
pub fn sirt(
    ctx: &MultiGpu,
    g: &Geometry,
    proj: &ProjectionSet,
    opts: &ReconOpts,
) -> anyhow::Result<ReconResult> {
    os_sart(ctx, g, proj, g.n_angles(), opts)
}

/// Clone of the context with the backprojector forced to matched weights.
pub(crate) fn matched_ctx(ctx: &MultiGpu) -> MultiGpu {
    let mut c = ctx.clone();
    match &mut c.backend {
        crate::coordinator::Backend::Native { weight, .. } => *weight = BackprojWeight::Matched,
        crate::coordinator::Backend::Pjrt { weight, .. } => *weight = BackprojWeight::Matched,
        // the sparse backprojector is SpMVᵀ — already the matched adjoint
        crate::coordinator::Backend::Sparse { .. } => {}
        #[cfg(test)]
        crate::coordinator::Backend::PanicInject { .. }
        | crate::coordinator::Backend::NanInject { .. } => {}
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics;
    use crate::phantom;

    fn setup(n: usize, n_angles: usize) -> (Geometry, Volume, ProjectionSet, MultiGpu) {
        let g = Geometry::cone_beam(n, n_angles);
        let truth = phantom::shepp_logan(n);
        let ctx = MultiGpu::gtx1080ti(2);
        let (p, _) = ctx
            .forward(&g, Some(&truth), crate::coordinator::ExecMode::Full)
            .unwrap();
        (g, truth, p.unwrap(), ctx)
    }

    #[test]
    fn sirt_converges_monotonically() {
        // residual decrease on Shepp-Logan
        let (g, _, proj, ctx) = setup(16, 24);
        let opts = ReconOpts { iterations: 15, lambda: 0.9, ..Default::default() };
        let r = sirt(&ctx, &g, &proj, &opts).unwrap();
        assert!(
            r.residuals.last().unwrap() < &(r.residuals[0] * 0.6),
            "residuals {:?}",
            r.residuals
        );
        // image quality on a piecewise-constant phantom (SIRT resolves
        // Shepp-Logan's sub-voxel features only after many iterations)
        let g2 = Geometry::cone_beam(16, 24);
        let truth = phantom::cube(16, 0.5, 1.0);
        let (p2, _) = ctx
            .forward(&g2, Some(&truth), crate::coordinator::ExecMode::Full)
            .unwrap();
        let r2 = sirt(&ctx, &g2, &p2.unwrap(), &opts).unwrap();
        let corr = metrics::correlation(&truth, &r2.volume);
        assert!(corr > 0.85, "correlation {corr}");
    }

    #[test]
    fn ossart_beats_sirt_per_iteration() {
        // Ordered subsets converge faster per full sweep.
        let (g, truth, proj, ctx) = setup(16, 24);
        let opts = ReconOpts { iterations: 4, lambda: 0.8, ..Default::default() };
        let r_sirt = sirt(&ctx, &g, &proj, &opts).unwrap();
        let r_os = os_sart(&ctx, &g, &proj, 6, &opts).unwrap();
        let e_sirt = metrics::rmse(&truth, &r_sirt.volume);
        let e_os = metrics::rmse(&truth, &r_os.volume);
        assert!(e_os < e_sirt, "os-sart {e_os} vs sirt {e_sirt}");
    }

    #[test]
    fn sart_is_subset_size_one() {
        let (g, _, proj, ctx) = setup(12, 8);
        let opts = ReconOpts { iterations: 1, lambda: 0.5, ..Default::default() };
        let a = sart(&ctx, &g, &proj, &opts).unwrap();
        let b = os_sart(&ctx, &g, &proj, 1, &opts).unwrap();
        assert_eq!(a.volume.data, b.volume.data);
    }

    #[test]
    fn nonneg_constraint_respected() {
        let (g, _, proj, ctx) = setup(12, 10);
        let opts = ReconOpts { iterations: 3, lambda: 1.2, nonneg: true, ..Default::default() };
        let r = os_sart(&ctx, &g, &proj, 5, &opts).unwrap();
        assert!(r.volume.data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn fault_os_sart_resumes_from_checkpoint_bit_identically() {
        // the subset weights W/V are recomputed on resume; only x and the
        // residual history travel through the checkpoint
        use crate::coordinator::CheckpointConfig;
        let (g, _, proj, ctx) = setup(14, 12);
        let dir = std::env::temp_dir()
            .join("tigre_algo_ckpt")
            .join(format!("ossart_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let clean =
            os_sart(&ctx, &g, &proj, 3, &ReconOpts { iterations: 3, ..Default::default() })
                .unwrap();
        let ck = Some(CheckpointConfig::new(&dir, 1));
        let _partial = os_sart(
            &ctx,
            &g,
            &proj,
            3,
            &ReconOpts { iterations: 2, checkpoint: ck.clone(), ..Default::default() },
        )
        .unwrap();
        let resumed = os_sart(
            &ctx,
            &g,
            &proj,
            3,
            &ReconOpts { iterations: 3, checkpoint: ck, ..Default::default() },
        )
        .unwrap();
        assert_eq!(resumed.volume.data, clean.volume.data);
        assert_eq!(resumed.residuals, clean.residuals);
    }

    #[test]
    fn sim_time_accumulates() {
        let (g, _, proj, ctx) = setup(12, 8);
        let opts = ReconOpts { iterations: 2, ..Default::default() };
        let r = sirt(&ctx, &g, &proj, &opts).unwrap();
        assert!(r.sim_time_s > 0.0);
        assert!(r.peak_device_bytes > 0);
    }
}
