//! Shared benchmark workloads and sweep runners, used by every target in
//! `rust/benches/` (each bench regenerates one table/figure of the
//! paper's evaluation; see DESIGN.md §5 for the experiment index).
//! [`kernels`] owns the machine-readable kernel hot-path suite behind
//! the `BENCH_kernels.json` trajectory; [`coordinator`] owns the
//! pipelined-vs-sequential executor suite behind `BENCH_coordinator.json`
//! (both share [`append_trajectory_run`] for the JSON file format).

pub mod coordinator;
pub mod kernels;

use crate::coordinator::{baseline, ExecMode, MultiGpu};
use crate::geometry::Geometry;
use crate::simgpu::timeline::Breakdown;
use crate::util::json::Json;
use crate::util::stats::Table;

/// Append one run object to a JSON perf-trajectory file: created if
/// absent, schema-checked if present, `runs` extended by `run`, and every
/// other top-level field (e.g. a checked-in `notes` block) preserved
/// verbatim. Shared by the `BENCH_kernels.json` and
/// `BENCH_coordinator.json` trajectories so both files keep one format.
pub fn append_trajectory_run(
    path: &std::path::Path,
    schema: &str,
    run: Json,
) -> anyhow::Result<()> {
    let mut top: std::collections::BTreeMap<String, Json> = std::collections::BTreeMap::new();
    let mut runs: Vec<Json> = Vec::new();
    if path.exists() {
        let text = std::fs::read_to_string(path)?;
        let doc = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        anyhow::ensure!(
            doc.get("schema").and_then(Json::as_str) == Some(schema),
            "{}: unexpected schema (want {schema})",
            path.display()
        );
        if let Some(obj) = doc.as_obj() {
            top = obj.clone();
        }
        if let Some(existing) = doc.get("runs").and_then(Json::as_arr) {
            runs = existing.to_vec();
        }
    }
    runs.push(run);
    top.insert("schema".into(), Json::str(schema));
    top.insert("runs".into(), Json::arr(runs));
    std::fs::write(path, Json::Obj(top).pretty() + "\n")?;
    Ok(())
}

/// Common CLI flags of the JSON-trajectory bench runners
/// (`kernel_hotpath`, `coordinator`): `--smoke`, `--json <path>`,
/// `--label <name>`; libtest-style `--bench`/`--test` are ignored.
pub struct BenchArgs {
    /// Reduced sizes/iterations for CI (`--smoke`).
    pub smoke: bool,
    /// Where to append the JSON trajectory (`--json <path>`).
    pub json_path: Option<std::path::PathBuf>,
    /// Run label recorded in the trajectory (`--label <name>`).
    pub label: String,
}

/// Parse the process arguments for a trajectory bench runner; prints a
/// usage error and exits on unknown flags.
pub fn parse_bench_args() -> BenchArgs {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut parsed =
        BenchArgs { smoke: false, json_path: None, label: String::from("run") };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--smoke" => parsed.smoke = true,
            "--json" => {
                i += 1;
                parsed.json_path =
                    Some(std::path::PathBuf::from(args.get(i).map(String::as_str).unwrap_or_else(
                        || {
                            eprintln!("--json requires a path");
                            std::process::exit(2);
                        },
                    )));
            }
            "--label" => {
                i += 1;
                parsed.label = args.get(i).cloned().unwrap_or_else(|| {
                    eprintln!("--label requires a value");
                    std::process::exit(2);
                });
            }
            "--bench" | "--test" => {} // ignore libtest-style flags
            other => {
                eprintln!("unknown flag '{other}' (known: --smoke --json <path> --label <name>)");
                std::process::exit(2);
            }
        }
        i += 1;
    }
    parsed
}

/// The paper's Fig. 7–9 size grid (`N³` voxels, `N²` detector pixels,
/// `N` angles). 3072 included: SimOnly needs no host data.
pub const FIG7_SIZES: &[usize] = &[128, 256, 512, 1024, 1536, 2048, 2560, 3072];
/// The Fig. 9 (time-breakdown) size grid.
pub const FIG9_SIZES: &[usize] = &[256, 512, 1024, 2048, 3072];
/// Device counts swept by the figures (the paper's 4-GPU workstation).
pub const GPU_COUNTS: &[usize] = &[1, 2, 3, 4];

/// One cell of the Fig. 7 sweep.
#[derive(Clone, Debug)]
pub struct SweepCell {
    /// Cubic problem size `N`.
    pub n: usize,
    /// Device count.
    pub gpus: usize,
    /// Simulated forward-projection makespan, seconds.
    pub fp_s: f64,
    /// Simulated backprojection makespan, seconds.
    pub bp_s: f64,
    /// FP time binned by category (Fig. 9 stacking).
    pub fp_breakdown: Breakdown,
    /// BP time binned by category (Fig. 9 stacking).
    pub bp_breakdown: Breakdown,
    /// Image partitions per device the FP plan chose.
    pub fp_splits: usize,
    /// Image partitions per device the BP plan chose.
    pub bp_splits: usize,
    /// Whether the FP plan page-locked host image memory.
    pub fp_pinned: bool,
    /// Whether the BP plan page-locked host image memory.
    pub bp_pinned: bool,
}

/// Run the FP+BP simulated sweep for one (N, gpus) cell.
pub fn sweep_cell(n: usize, gpus: usize) -> anyhow::Result<SweepCell> {
    let g = Geometry::cone_beam(n, n);
    let ctx = MultiGpu::gtx1080ti(gpus);
    let (_, fp) = ctx.forward(&g, None, ExecMode::SimOnly)?;
    let (_, bp) = ctx.backward(&g, None, ExecMode::SimOnly)?;
    Ok(SweepCell {
        n,
        gpus,
        fp_s: fp.makespan_s,
        bp_s: bp.makespan_s,
        fp_breakdown: fp.breakdown,
        bp_breakdown: bp.breakdown,
        fp_splits: fp.splits_per_device,
        bp_splits: bp.splits_per_device,
        fp_pinned: fp.pinned,
        bp_pinned: bp.pinned,
    })
}

/// The full Fig. 7 sweep (returns row-major over sizes × gpu counts).
pub fn fig7_sweep(sizes: &[usize], gpu_counts: &[usize]) -> Vec<SweepCell> {
    let mut out = Vec::new();
    for &n in sizes {
        for &gpus in gpu_counts {
            match sweep_cell(n, gpus) {
                Ok(c) => out.push(c),
                Err(e) => {
                    // The paper's 4-GPU machine also skips points (RAM):
                    // record the reason and move on.
                    crate::log_warn!("sweep N={n} gpus={gpus} skipped: {e:#}");
                }
            }
        }
    }
    out
}

/// Render the Fig. 7 absolute-time table for one operator.
pub fn fig7_table(cells: &[SweepCell], forward: bool) -> String {
    let mut t = Table::new(&["N", "1 GPU [s]", "2 GPU [s]", "3 GPU [s]", "4 GPU [s]", "splits(1GPU)"]);
    let sizes: Vec<usize> = dedup_sizes(cells);
    for n in sizes {
        let mut row = vec![n.to_string()];
        for gpus in GPU_COUNTS {
            let cell = cells.iter().find(|c| c.n == n && c.gpus == *gpus);
            row.push(match cell {
                Some(c) => format!("{:.3}", if forward { c.fp_s } else { c.bp_s }),
                None => "-".into(),
            });
        }
        let splits = cells
            .iter()
            .find(|c| c.n == n && c.gpus == 1)
            .map(|c| if forward { c.fp_splits } else { c.bp_splits })
            .unwrap_or(0);
        row.push(splits.to_string());
        t.row(row);
    }
    t.render()
}

/// Render the Fig. 8 percent-of-1-GPU table for one operator.
pub fn fig8_table(cells: &[SweepCell], forward: bool) -> String {
    let mut t = Table::new(&["N", "2 GPU [%]", "3 GPU [%]", "4 GPU [%]", "theory [%]"]);
    let sizes: Vec<usize> = dedup_sizes(cells);
    for n in sizes {
        let base = cells
            .iter()
            .find(|c| c.n == n && c.gpus == 1)
            .map(|c| if forward { c.fp_s } else { c.bp_s });
        let Some(base) = base else { continue };
        let mut row = vec![n.to_string()];
        for gpus in &[2usize, 3, 4] {
            let cell = cells.iter().find(|c| c.n == n && c.gpus == *gpus);
            row.push(match cell {
                Some(c) => {
                    let v = if forward { c.fp_s } else { c.bp_s };
                    format!("{:.1}", 100.0 * v / base)
                }
                None => "-".into(),
            });
        }
        row.push("50.0/33.3/25.0".into());
        t.row(row);
    }
    t.render()
}

/// Render the Fig. 9 breakdown table for one operator.
pub fn fig9_table(cells: &[SweepCell], forward: bool) -> String {
    let mut t = Table::new(&["N", "GPUs", "compute %", "pin/unpin %", "other mem %", "idle %"]);
    for c in cells {
        let b = if forward { &c.fp_breakdown } else { &c.bp_breakdown };
        let (comp, pin, mem, idle) = b.fractions();
        t.row(vec![
            c.n.to_string(),
            c.gpus.to_string(),
            format!("{:.1}", comp * 100.0),
            format!("{:.1}", pin * 100.0),
            format!("{:.1}", mem * 100.0),
            format!("{:.1}", idle * 100.0),
        ]);
    }
    t.render()
}

fn dedup_sizes(cells: &[SweepCell]) -> Vec<usize> {
    let mut sizes: Vec<usize> = cells.iter().map(|c| c.n).collect();
    sizes.sort_unstable();
    sizes.dedup();
    sizes
}

/// Proposed-vs-naive comparison for one (N, gpus) cell.
pub fn buffering_ablation(n: usize, gpus: usize) -> anyhow::Result<(f64, f64, f64, f64)> {
    let g = Geometry::cone_beam(n, n);
    let ctx = MultiGpu::gtx1080ti(gpus);
    let (_, fp) = ctx.forward(&g, None, ExecMode::SimOnly)?;
    let (_, bp) = ctx.backward(&g, None, ExecMode::SimOnly)?;
    let nfp = baseline::naive_forward(&ctx, &g)?;
    let nbp = baseline::naive_backward(&ctx, &g)?;
    Ok((fp.makespan_s, nfp.makespan_s, bp.makespan_s, nbp.makespan_s))
}

/// Save a sweep to CSV under `results/` for plotting.
pub fn save_sweep_csv(path: &std::path::Path, cells: &[SweepCell]) -> anyhow::Result<()> {
    let cols: Vec<Vec<f64>> = vec![
        cells.iter().map(|c| c.n as f64).collect(),
        cells.iter().map(|c| c.gpus as f64).collect(),
        cells.iter().map(|c| c.fp_s).collect(),
        cells.iter().map(|c| c.bp_s).collect(),
        cells.iter().map(|c| c.fp_splits as f64).collect(),
        cells.iter().map(|c| c.bp_splits as f64).collect(),
    ];
    crate::io::save_csv(path, &["n", "gpus", "fp_s", "bp_s", "fp_splits", "bp_splits"], &cols)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_cell_produces_sane_numbers() {
        let c = sweep_cell(512, 2).unwrap();
        assert!(c.fp_s > 0.0 && c.bp_s > 0.0);
        assert!(c.bp_s < c.fp_s, "BP faster than FP (paper §3.1)");
        assert_eq!(c.n, 512);
    }

    #[test]
    fn tables_render_for_small_sweep() {
        let cells = fig7_sweep(&[128, 256], &[1, 2]);
        assert_eq!(cells.len(), 4);
        let t7 = fig7_table(&cells, true);
        assert!(t7.contains("128") && t7.contains("256"));
        let t8 = fig8_table(&cells, false);
        assert!(t8.contains("50.0/33.3/25.0"));
        let t9 = fig9_table(&cells, true);
        assert!(t9.lines().count() >= 6);
    }

    #[test]
    fn buffering_ablation_proposed_wins() {
        let (fp, nfp, bp, nbp) = buffering_ablation(1024, 2).unwrap();
        assert!(fp <= nfp);
        assert!(bp <= nbp);
    }
}
