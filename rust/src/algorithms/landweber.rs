//! Landweber iteration and MLEM — two further members of TIGRE's
//! algorithm family, rounding out the suite on the same multi-GPU
//! operator substrate.
//!
//! * Landweber: `x ← x + λ·Aᵀ(b − Ax)` — plain gradient descent on the
//!   least-squares objective, step bounded by 1/‖AᵀA‖.
//! * MLEM: `x ← x ∘ Aᵀ(b ⊘ Ax) ⊘ Aᵀ1` — the multiplicative EM update for
//!   Poisson data (requires non-negative projections).

use crate::coordinator::MultiGpu;
use crate::geometry::Geometry;
use crate::kernels::scratch;
use crate::volume::{ProjectionSet, Volume};

use super::common::{ReconOpts, ReconResult, TrackedOps};
use super::ossart::matched_ctx;

/// Landweber iteration; `opts.lambda` scales the power-iteration step.
pub fn landweber(
    ctx: &MultiGpu,
    g: &Geometry,
    proj: &ProjectionSet,
    opts: &ReconOpts,
) -> anyhow::Result<ReconResult> {
    let ctx = matched_ctx(ctx);
    let mut ops = TrackedOps::new(&ctx, g);

    // step = λ / ‖AᵀA‖ (power iteration); per-round temporaries go back
    // to the kernels::scratch arena so each operator call reuses buffers
    let mut v = crate::phantom::random(g.n_vox[0], g.n_vox[1], g.n_vox[2], 17);
    let mut lmax = 1.0f64;
    for _ in 0..4 {
        let av = ops.forward(g, &v)?;
        let atav = ops.backward(g, &av)?;
        scratch::recycle_projections(av);
        lmax = atav.norm2() / v.norm2().max(1e-30);
        let n = atav.norm2().max(1e-30) as f32;
        scratch::recycle_volume(std::mem::replace(&mut v, atav));
        v.scale(1.0 / n);
    }
    let step = opts.lambda / lmax.max(1e-30) as f32;

    let mut x = Volume::zeros_like(g);
    let mut residuals = Vec::with_capacity(opts.iterations);
    for it in 0..opts.iterations {
        let mut r = ops.forward(g, &x)?;
        // r = b − Ax
        for (rv, bv) in r.data.iter_mut().zip(&proj.data) {
            *rv = bv - *rv;
        }
        residuals.push(r.norm2());
        let upd = ops.backward(g, &r)?;
        scratch::recycle_projections(r);
        x.add_scaled(&upd, step);
        scratch::recycle_volume(upd);
        if opts.nonneg {
            x.clamp_min(0.0);
        }
        if opts.verbose {
            crate::log_info!("landweber iter {it}: residual {:.4e}", residuals.last().unwrap());
        }
    }
    Ok(ReconResult {
        volume: x,
        residuals,
        sim_time_s: ops.sim_time_s,
        peak_device_bytes: ops.peak_device_bytes,
    })
}

/// MLEM for non-negative (count-derived) projections.
pub fn mlem(
    ctx: &MultiGpu,
    g: &Geometry,
    proj: &ProjectionSet,
    opts: &ReconOpts,
) -> anyhow::Result<ReconResult> {
    anyhow::ensure!(
        proj.data.iter().all(|&v| v >= 0.0),
        "MLEM requires non-negative projections"
    );
    let ctx = matched_ctx(ctx);
    let mut ops = TrackedOps::new(&ctx, g);

    // sensitivity image Aᵀ1
    let ones = {
        let mut p = ProjectionSet::zeros_like(g);
        for v in &mut p.data {
            *v = 1.0;
        }
        p
    };
    let sens = ops.backward(g, &ones)?;

    // start from a uniform positive image
    let mut x = Volume::zeros_like(g);
    for v in &mut x.data {
        *v = 1.0;
    }
    let mut residuals = Vec::with_capacity(opts.iterations);
    for it in 0..opts.iterations {
        // reuse Ax in place as the ratio buffer b ⊘ Ax
        let mut ratio = ops.forward(g, &x)?;
        let mut res2 = 0.0f64;
        for (av, bv) in ratio.data.iter_mut().zip(&proj.data) {
            let d = (bv - *av) as f64;
            res2 += d * d;
            *av = if *av > 1e-8 { bv / *av } else { 0.0 };
        }
        residuals.push(res2.sqrt());
        let corr = ops.backward(g, &ratio)?;
        scratch::recycle_projections(ratio);
        for ((xv, cv), sv) in x.data.iter_mut().zip(&corr.data).zip(&sens.data) {
            *xv = if *sv > 1e-8 { *xv * cv / sv } else { 0.0 };
        }
        scratch::recycle_volume(corr);
        if opts.verbose {
            crate::log_info!("mlem iter {it}: residual {:.4e}", residuals.last().unwrap());
        }
    }
    Ok(ReconResult {
        volume: x,
        residuals,
        sim_time_s: ops.sim_time_s,
        peak_device_bytes: ops.peak_device_bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ExecMode;
    use crate::metrics;
    use crate::phantom;

    fn setup(n: usize, a: usize) -> (Geometry, Volume, ProjectionSet, MultiGpu) {
        let g = Geometry::cone_beam(n, a);
        let truth = phantom::cube(n, 0.5, 1.0);
        let ctx = MultiGpu::gtx1080ti(1);
        let (p, _) = ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap();
        (g, truth, p.unwrap(), ctx)
    }

    #[test]
    fn landweber_residual_decreases() {
        let (g, truth, p, ctx) = setup(14, 12);
        let opts = ReconOpts { iterations: 15, lambda: 1.0, ..Default::default() };
        let r = landweber(&ctx, &g, &p, &opts).unwrap();
        assert!(r.residuals.last().unwrap() < &(r.residuals[0] * 0.7), "{:?}", r.residuals);
        assert!(metrics::correlation(&truth, &r.volume) > 0.8);
    }

    #[test]
    fn mlem_converges_and_stays_nonnegative() {
        let (g, truth, p, ctx) = setup(14, 12);
        let opts = ReconOpts { iterations: 12, ..Default::default() };
        let r = mlem(&ctx, &g, &p, &opts).unwrap();
        assert!(r.volume.data.iter().all(|&v| v >= 0.0));
        assert!(metrics::correlation(&truth, &r.volume) > 0.8);
        assert!(r.residuals.last().unwrap() < &(r.residuals[0] * 0.7));
    }

    #[test]
    fn mlem_rejects_negative_projections() {
        let (g, _, mut p, ctx) = setup(10, 6);
        p.data[0] = -1.0;
        assert!(mlem(&ctx, &g, &p, &ReconOpts::default()).is_err());
    }

    #[test]
    fn landweber_split_devices_match() {
        let (g, _, p, big) = setup(14, 10);
        let opts = ReconOpts { iterations: 4, nonneg: false, ..Default::default() };
        let r_big = landweber(&big, &g, &p, &opts).unwrap();
        let plane = (14 * 14 * 4) as u64;
        let tiny = MultiGpu::gtx1080ti(2)
            .with_device_mem(6 * plane + 3 * 10 * g.single_proj_bytes());
        let r_tiny = landweber(&tiny, &g, &p, &opts).unwrap();
        let rel = metrics::rel_l2(&r_big.volume, &r_tiny.volume);
        assert!(rel < 2e-3, "split landweber deviates {rel}");
    }
}
