//! Algorithm 1 — the forward-projection kernel launch procedure.
//!
//! Per-iteration queue order (paper Alg. 1, line numbers in comments):
//! the FP kernel is queued asynchronously *first*; the synchronous copies
//! that follow then overlap it on the DMA engines, and the host only
//! blocks on the compute engine at the end of the iteration. That
//! ordering — kernel before copies — is the paper's core trick for hiding
//! transfer time without pinned output buffers.

use crate::geometry::Geometry;
use crate::simgpu::{Category, Ev, SimNode, SimOom};
use crate::volume::{ProjectionSet, Volume, VolumeInput};

use super::degrade::DegradeEvent;
use super::error::ReconError;
use super::executor::{Backend, ExecMode, MultiGpu, OpStats};
use super::residency::{FpResidency, OpKind};
use super::splitter::{plan_forward, refine_for_budget, MergeStrategy, Plan, PlanProjector};

/// Bounded refinement retries on rung 2 of the pressure ladder (each
/// halves the unit size, so 4 rungs shrink it 16×).
pub(crate) const MAX_PRESSURE_REFINES: usize = 4;

/// Key identifying the *set* of CSR shards one operator plan touches —
/// the geometry fingerprint folded with every slab boundary and angle-
/// chunk boundary the plan emits. The
/// [`SparseShardCache`](super::residency::SparseShardCache) uses it to
/// decide, per (op, plan), whether the simulated timeline should charge
/// shard build time (first iteration) or skip it (2nd+ — the shards are
/// host-resident). Individual shards are keyed on their own sub-geometry
/// fingerprint; this key is deliberately coarser, covering the whole
/// plan in one tag.
pub(crate) fn sparse_plan_key(g: &Geometry, plan: &Plan) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = crate::kernels::sparse::geometry_fingerprint(g);
    let mut mix = |h: &mut u64, v: u64| {
        *h ^= v;
        *h = h.wrapping_mul(PRIME);
    };
    for d in &plan.per_device {
        for s in &d.slabs {
            mix(&mut h, s.z0 as u64);
            mix(&mut h, s.z1 as u64);
        }
    }
    for c in &plan.angle_chunks {
        mix(&mut h, c.a0 as u64);
        mix(&mut h, c.a1 as u64);
    }
    h
}

/// Stamp the plan's projector family from the active backend, mirroring
/// the `plan.merge` stamp: a [`Backend::Sparse`] context marks the plan
/// `Sparse`, with `warm` resolved against the backend's shard cache for
/// this (op, plan) pair — the first simulated call charges CSR build
/// time, subsequent ones do not (the residency claim of ISSUE 10).
pub(crate) fn stamp_projector(ctx: &MultiGpu, g: &Geometry, plan: &mut Plan, op: OpKind) {
    if let Backend::Sparse { cache, .. } = &ctx.backend {
        plan.projector =
            PlanProjector::Sparse { warm: cache.sim_op_warm(op, sparse_plan_key(g, plan)) };
    }
}

/// Per-unit FP kernel time under the plan's projector family: ray-driven
/// units cost `fp_slab_kernel_s`; sparse units cost an SpMV over the
/// shard's estimated nnz, plus the one-time CSR build when the shard
/// cache is cold. Each (slab, chunk) unit appears exactly once in an
/// operator schedule, so charging the build on the unit's own kernel
/// launch charges each shard exactly once.
fn fp_unit_kernel_s(
    sim: &SimNode,
    g: &Geometry,
    plan: &Plan,
    chunk_len: usize,
    nz_slab: usize,
) -> f64 {
    match plan.projector {
        PlanProjector::Ray => sim.cost.fp_slab_kernel_s(
            g.n_det[0],
            g.n_det[1],
            chunk_len,
            g.n_vox[0],
            g.n_vox[1],
            nz_slab,
            g.n_vox[2],
        ),
        PlanProjector::Sparse { warm } => {
            let nnz = sim.cost.sparse_nnz_estimate(
                g.n_det[0],
                g.n_det[1],
                chunk_len,
                g.n_vox[0],
                g.n_vox[1],
                nz_slab,
                g.n_vox[2],
            );
            let setup = if warm { 0.0 } else { sim.cost.sparse_setup_s(nnz) };
            setup + sim.cost.spmv_s(nnz)
        }
    }
}

/// Run the forward projection: returns real projections (in `Full` mode)
/// and the simulated-schedule statistics.
pub fn run(
    ctx: &MultiGpu,
    g: &Geometry,
    vol: Option<&Volume>,
    mode: ExecMode,
) -> anyhow::Result<(Option<ProjectionSet>, OpStats)> {
    let plan = plan_forward(g, ctx.n_gpus, ctx.spec.mem_bytes, &ctx.split)
        .map_err(|e| ReconError::Plan(format!("forward plan: {e}")))?;
    run_with(ctx, g, vol.map(VolumeInput::Ram), mode, &plan, None)
}

/// Like [`run`] but against a pre-computed plan, a RAM-or-OOC input and
/// optional residency decisions — the entry point
/// `coordinator::residency::ReconSession` and `MultiGpu::forward_ooc`
/// drive their calls through (plans are computed once per session, not
/// once per call).
pub(crate) fn run_with(
    ctx: &MultiGpu,
    g: &Geometry,
    vol: Option<VolumeInput<'_>>,
    mode: ExecMode,
    plan: &Plan,
    res: Option<&FpResidency>,
) -> anyhow::Result<(Option<ProjectionSet>, OpStats)> {
    // Single source of truth for the merge strategy: every executor entry
    // point (plain, OOC, ReconSession) stamps the plan from the config,
    // so the simulated timeline always models the strategy the real path
    // will run. Direct `simulate` callers keep their plan's own setting.
    let mut plan = {
        let mut p = plan.clone();
        p.merge = ctx.exec.merge;
        stamp_projector(ctx, g, &mut p, OpKind::Fp);
        p
    };

    // Memory-pressure ladder (ISSUE 8): an allocation failure does not
    // surface — the schedule is retried down the degradation rungs
    // (evict residency → refine the plan → spill to OOC staging) until
    // it fits. Bit-identity is structural: FP refinement only re-chunks
    // the angles (every angle is computed independently), and an
    // injected `AllocFail` site is consumed by the failed attempt, so
    // the retry replays a clean schedule. The clean path takes the first
    // iteration with zero extra cost.
    let mut res = res;
    let mut rungs = 0usize;
    let mut refines = 0usize;
    let mut penalty_s = 0.0;
    let (sim, plan) = loop {
        let mut sim = ctx.fresh_sim();
        if penalty_s > 0.0 {
            // the discarded failed attempts' retry backoffs + replans
            sim.host_busy(penalty_s, Category::OtherMem, "pressure replan");
        }
        let attempt = (|| -> Result<(), SimOom> {
            if let Some(r) = res {
                // buffers still resident from previous calls occupy
                // device RAM before this call does anything
                // (ledger-only, no time)
                for (d, &bytes) in r.reserve.iter().enumerate() {
                    sim.reserve(d, "resident", bytes)?;
                }
            }
            simulate_with(g, &plan, &mut sim, res)
        })();
        let oom = match attempt {
            Ok(()) => break (sim, plan),
            Err(oom) => oom,
        };
        rungs += 1;
        penalty_s += ctx.cost.pressure_rung_penalty_s();
        // rung 1: sacrifice resident buffers (restaged next call)
        if let Some(r) = res.take() {
            ctx.degrade.record(DegradeEvent::Evicted {
                device: oom.device,
                entries: r.reserve.iter().filter(|&&b| b > 0).count(),
            });
            continue;
        }
        // rung 2: refine the plan to smaller units (bounded)
        if refines < MAX_PRESSURE_REFINES {
            if let Ok((refined, detail)) = refine_for_budget(&plan, g, true, oom.device) {
                ctx.degrade.record(DegradeEvent::Refined { device: oom.device, detail });
                plan = refined;
                refines += 1;
                continue;
            }
        }
        // rung 3: spill the staging tier to disk (once)
        if !plan.ooc_volume {
            ctx.degrade.record(DegradeEvent::Spilled {
                device: oom.device,
                detail: format!("fp staging -> disk after '{}'", oom.label),
            });
            plan.ooc_volume = true;
            continue;
        }
        return Err(ReconError::MemoryPressure {
            device: oom.device,
            attempts: rungs,
            detail: oom.detail,
        }
        .into());
    };
    let plan = &plan;
    let mut stats = OpStats::from_sim(&sim, plan);

    let proj = match mode {
        ExecMode::SimOnly => None,
        ExecMode::Full => {
            let vol = vol
                .ok_or_else(|| ReconError::Input("Full mode requires the volume data".into()))?;
            Some(execute_real(ctx, g, vol, plan)?)
        }
    };
    stats.degradation = ctx.degrade.drain();
    Ok((proj, stats))
}

/// Replay Algorithm 1 on the discrete-event node.
pub fn simulate(g: &Geometry, plan: &Plan, sim: &mut SimNode) -> Result<(), SimOom> {
    simulate_with(g, plan, sim, None)
}

/// [`simulate`] with residency decisions: uploads of units the cache
/// holds fresh are skipped, and a cached image allocation survives the
/// operator's resource-free epilogue.
pub(crate) fn simulate_with(
    g: &Geometry,
    plan: &Plan,
    sim: &mut SimNode,
    res: Option<&FpResidency>,
) -> Result<(), SimOom> {
    let chunks = &plan.angle_chunks;
    let n_chunks = chunks.len();
    let n_dev = sim.n_devices();
    let chunk_bytes = |c: usize| chunks[c].len() as u64 * g.single_proj_bytes();

    // 1: Check GPU memory and properties
    sim.property_check();

    // 3–5: page-lock image memory if the plan says so (the image volume
    // already exists in host RAM → "resident" pin rate).
    if plan.pin_image {
        sim.pin_host(g.volume_bytes(), true);
    }

    // 6: initialize buffers (2 kernel-output buffers; +1 partial-
    // accumulation buffer when the image is split).
    for d in 0..n_dev {
        for b in 0..plan.n_proj_buffers {
            sim.alloc(d, &format!("projbuf{b}"), plan.proj_buffer_bytes)?;
        }
    }

    if !plan.image_split {
        simulate_angle_split(g, plan, sim, res)?;
    } else {
        simulate_image_split(g, plan, sim, n_chunks, &chunk_bytes)?;
    }

    // 25: free GPU resources. A cached image stays resident for the next
    // call (skipping its free is exactly the point of the cache); an
    // image that was never allocated here (residency hit) has nothing to
    // free either.
    for d in 0..n_dev {
        for b in 0..plan.n_proj_buffers {
            sim.free(d, &format!("projbuf{b}"));
        }
        let keep = res.is_some_and(|r| {
            r.keep_image.get(d).copied().unwrap_or(false)
                || r.skip_image_h2d.get(d).copied().unwrap_or(false)
        });
        if !keep {
            sim.free(d, "slab");
        }
    }
    if plan.pin_image {
        sim.unpin_host(g.volume_bytes());
    }
    sim.sync_all();
    Ok(())
}

/// Image fits on every device: each device projects the whole image for
/// its share of the angles. No accumulation.
fn simulate_angle_split(
    g: &Geometry,
    plan: &Plan,
    sim: &mut SimNode,
    res: Option<&FpResidency>,
) -> Result<(), SimOom> {
    let n_dev = sim.n_devices();
    let chunks = &plan.angle_chunks;
    // contiguous chunk shares per device (same mapping as the real
    // executors — see Plan::chunk_shares)
    let shares = plan.chunk_shares(n_dev);

    // 8: copy the (whole) image to every device — unless the device still
    // holds an epoch-fresh copy from a previous call (residency hit).
    // An out-of-core volume is first read from the backing store once
    // (materialized within the host budget); every upload depends on it.
    let img_bytes = g.volume_bytes();
    let any_upload = (0..n_dev)
        .any(|d| !res.is_some_and(|r| r.skip_image_h2d.get(d).copied().unwrap_or(false)));
    let img_on_host = if plan.ooc_volume && any_upload {
        sim.disk_read(img_bytes, Ev::ZERO)
    } else {
        Ev::ZERO
    };
    let mut img_ready = vec![Ev::ZERO; n_dev];
    for d in 0..n_dev {
        let skip = res.is_some_and(|r| r.skip_image_h2d.get(d).copied().unwrap_or(false));
        if skip {
            img_ready[d] = Ev::ZERO; // already on-device, no upload
        } else {
            sim.alloc(d, "slab", img_bytes)?;
            img_ready[d] = sim.h2d(d, img_bytes, plan.pin_image, img_on_host);
        }
    }
    // 9: Synchronize()
    for &e in &img_ready {
        sim.host_sync(e);
    }

    // 10–21: chunk loop, lockstep across devices
    let max_share = shares.iter().map(|(a, b)| b - a).max().unwrap_or(0);
    let mut prev_kernel: Vec<Option<(Ev, usize)>> = vec![None; n_dev]; // (event, chunk)
    for j in 0..max_share {
        // 11: queue kernels on all devices first (async)
        let mut this_kernel: Vec<Option<(Ev, usize)>> = vec![None; n_dev];
        for d in 0..n_dev {
            let (c0, c1) = shares[d];
            if c0 + j >= c1 {
                continue;
            }
            let c = c0 + j;
            let t = fp_unit_kernel_s(sim, g, plan, chunks[c].len(), g.n_vox[2]);
            let ev = sim.kernel(d, t, img_ready[d], &format!("fp d{d} c{c}"));
            this_kernel[d] = Some((ev, c));
        }
        // 17–19: copy previous kernel's projections out (synchronous,
        // pageable output array) — overlaps the kernel queued above.
        for d in 0..n_dev {
            if let Some((ev, c)) = prev_kernel[d] {
                let bytes = chunks[c].len() as u64 * g.single_proj_bytes();
                sim.d2h(d, bytes, false, ev);
            }
        }
        // 20: Synchronize(Compute)
        for d in 0..n_dev {
            if let Some((ev, _)) = this_kernel[d] {
                sim.host_sync(ev);
            }
        }
        prev_kernel = this_kernel;
    }
    // 22: copy last kernel projections out
    for d in 0..n_dev {
        if let Some((ev, c)) = prev_kernel[d] {
            let bytes = chunks[c].len() as u64 * g.single_proj_bytes();
            sim.d2h(d, bytes, false, ev);
        }
    }
    Ok(())
}

/// Image larger than the devices: z-slabs are distributed across devices;
/// every device projects all angle chunks of each of its slabs in a
/// staggered order, accumulating per-chunk partial projections on-device
/// (third buffer) against its *own* previous slab's partial — the
/// per-worker private partials of the pipelined executor (PR 3). Slabs
/// cycle through one staging allocation, so there is nothing for the
/// residency cache to keep here (see `coordinator::residency`).
///
/// A merge epilogue then folds the per-device partials by the canonical
/// pairwise schedule, per `plan.merge` (DESIGN.md §Reduction-tree):
/// `Linear` charges one serial host `+=` pass per fold; `Tree` charges a
/// peer-to-peer device copy plus an on-device accumulation kernel per
/// fold, with a round's disjoint pairs overlapping on their own engines
/// — which is what makes the tree's merge critical path log-depth.
fn simulate_image_split(
    g: &Geometry,
    plan: &Plan,
    sim: &mut SimNode,
    n_chunks: usize,
    chunk_bytes: &dyn Fn(usize) -> u64,
) -> Result<(), SimOom> {
    let n_dev = sim.n_devices();
    let chunks = &plan.angle_chunks;
    let stagger = n_chunks.div_ceil(n_dev.max(1));
    // per-device host-side partial state per chunk: version event
    let mut host_partial: Vec<Vec<Option<Ev>>> = vec![vec![None; n_chunks]; n_dev];

    let max_slabs = plan.splits_per_device();
    let mut slab_alloced = vec![false; n_dev];
    for s in 0..max_slabs {
        // 8: copy current image split to each device (contiguous z-slab).
        // OOC volumes stream the slab from the backing store first (the
        // loader lane's prefetch — the disk engine serializes, the host
        // does not block).
        let mut slab_ready = vec![Ev::ZERO; n_dev];
        let mut active = vec![false; n_dev];
        for d in 0..n_dev {
            let Some(slab) = plan.per_device[d].slabs.get(s) else { continue };
            active[d] = true;
            let bytes = g.slab_bytes(slab.len());
            if slab_alloced[d] {
                sim.free(d, "slab");
            }
            sim.alloc(d, "slab", bytes)?;
            slab_alloced[d] = true;
            let staged = if plan.ooc_volume {
                sim.disk_read(bytes, Ev::ZERO)
            } else {
                Ev::ZERO
            };
            slab_ready[d] = sim.h2d(d, bytes, plan.pin_image, staged);
        }
        // 9: Synchronize()
        for (d, &e) in slab_ready.iter().enumerate() {
            if active[d] {
                sim.host_sync(e);
            }
        }

        // 10–21: chunk loop (staggered chunk index per device)
        let mut prev_out: Vec<Option<(Ev, usize)>> = vec![None; n_dev];
        for j in 0..n_chunks {
            // 11: queue FP kernels on all devices (async)
            let mut this_out: Vec<Option<(Ev, usize)>> = vec![None; n_dev];
            for d in 0..n_dev {
                if !active[d] {
                    continue;
                }
                let c = (j + d * stagger) % n_chunks;
                let slab = plan.per_device[d].slabs[s];
                let t = fp_unit_kernel_s(sim, g, plan, chunks[c].len(), slab.len());
                let kev = sim.kernel(d, t, slab_ready[d], &format!("fp d{d} s{s} c{c}"));
                this_out[d] = Some((kev, c));
            }
            // 12–16: if a partial already exists for this chunk, stream it
            // in (synchronous copy — overlaps the queued kernel) and queue
            // the accumulation kernel.
            for d in 0..n_dev {
                if !active[d] {
                    continue;
                }
                let Some((kev, c)) = this_out[d] else { continue };
                if let Some(host_ev) = host_partial[d][c] {
                    // 13: copy already-computed partials CPU→GPU
                    let h2d_ev = sim.h2d(d, chunk_bytes(c), plan.pin_image, host_ev);
                    // 15: accumulate (async, after kernel + partials)
                    let acc_t = sim.cost.accum_kernel_s(chunk_bytes(c));
                    let aev =
                        sim.kernel(d, acc_t, kev.max(h2d_ev), &format!("accum d{d} c{c}"));
                    this_out[d] = Some((aev, c));
                }
            }
            // 17–19: copy previous chunk's result out (synchronous) —
            // this publishes the device's new host partial for that chunk.
            for d in 0..n_dev {
                if let Some((ev, c)) = prev_out[d] {
                    let out = sim.d2h(d, chunk_bytes(c), false, ev);
                    host_partial[d][c] = Some(out);
                }
            }
            // 20: Synchronize(Compute)
            for d in 0..n_dev {
                if let Some((ev, _)) = this_out[d] {
                    sim.host_sync(ev);
                }
            }
            prev_out = this_out;
        }
        // 22: flush the final chunk of this slab
        for d in 0..n_dev {
            if let Some((ev, c)) = prev_out[d] {
                let out = sim.d2h(d, chunk_bytes(c), false, ev);
                host_partial[d][c] = Some(out);
            }
        }
    }

    // Merge epilogue: fold the per-device partials into the final
    // projection set by the canonical pairwise schedule. Schedule
    // indices are positions in the compacted active-device list, exactly
    // as in the real executor (`pipeline::tree_roles_for`).
    let active_devs: Vec<usize> =
        (0..n_dev).filter(|&d| !plan.per_device[d].slabs.is_empty()).collect();
    let mut done: Vec<Ev> = active_devs
        .iter()
        .map(|&d| host_partial[d].iter().flatten().fold(Ev::ZERO, |acc, &e| acc.max(e)))
        .collect();
    let proj_bytes: u64 = (0..n_chunks).map(chunk_bytes).sum();
    match plan.merge {
        MergeStrategy::Linear => {
            // n_active − 1 serial host-side `+=` passes over a full
            // partial each — the host-bound linear critical path
            let fold_s = sim.cost.host_fold_time_s(proj_bytes);
            for round in plan.merge_rounds() {
                for (dst, src) in round {
                    sim.host_sync(done[dst].max(done[src]));
                    let ev = sim.host_busy(
                        fold_s,
                        Category::OtherMem,
                        &format!("merge fold {src}->{dst}"),
                    );
                    done[dst] = ev;
                }
            }
        }
        MergeStrategy::Tree => {
            // log-depth pairwise device→device folds: each pair streams
            // the source partial over the peer link and accumulates on
            // the destination; a round's disjoint pairs overlap on their
            // own DMA/compute engines. Modeling shortcut (DESIGN.md
            // §Reduction-tree): the fold streams chunk-wise through the
            // plan's existing projection buffers, so no additional
            // device memory is charged.
            let acc_s = sim.cost.accum_kernel_s(proj_bytes);
            for round in plan.merge_rounds() {
                for (dst, src) in round {
                    let (d_dst, d_src) = (active_devs[dst], active_devs[src]);
                    let ready = done[dst].max(done[src]);
                    let moved = sim.p2p(d_src, d_dst, proj_bytes, ready);
                    done[dst] =
                        sim.kernel(d_dst, acc_s, moved, &format!("merge accum d{d_dst}"));
                }
            }
            // the host collects the merged result from the root
            if let Some(&root) = done.first() {
                sim.host_sync(root);
            }
        }
    }
    Ok(())
}

/// Real numerics with the identical partitioning: the pipelined executor
/// (concurrent device workers, zero-copy staging views, OOC loader
/// lanes, double-buffered merge lanes — see `coordinator::pipeline`) by
/// default, or the host-sequential baseline when `ctx.exec.pipelined`
/// is off.
fn execute_real(
    ctx: &MultiGpu,
    g: &Geometry,
    vol: VolumeInput<'_>,
    plan: &Plan,
) -> anyhow::Result<ProjectionSet> {
    if ctx.exec.pipelined {
        super::pipeline::forward_pipelined(ctx, g, vol, plan)
    } else {
        super::pipeline::forward_sequential(ctx, g, vol, plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::{ExecMode, MultiGpu};
    use crate::phantom;
    use crate::util::units::{GIB, MIB};

    #[test]
    fn split_execution_matches_unsplit_reference() {
        // THE correctness claim: splitting across devices and slabs gives
        // bit-comparable results to the monolithic kernel.
        let n = 20;
        let g = Geometry::cone_beam(n, 12);
        let v = phantom::shepp_logan(n);
        let reference = crate::kernels::forward(
            &g,
            &v,
            crate::kernels::Projector::Siddon,
            2,
        );

        for n_gpus in [1, 2, 3] {
            // tiny devices force an image split (a slab is a few slices)
            let mem = crate::coordinator::splitter::image_split_mem(
                &g,
                &crate::coordinator::SplitConfig::default(),
            );
            // both executors must match the unsplit reference: the
            // pipelined default and the sequential baseline
            for sequential in [false, true] {
                let ctx = MultiGpu::gtx1080ti(n_gpus).with_device_mem(mem);
                let ctx = if sequential { ctx.with_sequential_executor() } else { ctx };
                let (proj, stats) = ctx.forward(&g, Some(&v), ExecMode::Full).unwrap();
                let proj = proj.unwrap();
                assert!(stats.splits_per_device > 1, "device memory must force a split");
                for (i, (a, b)) in reference.data.iter().zip(&proj.data).enumerate() {
                    assert!(
                        (a - b).abs() <= 2e-3 * (1.0 + a.abs()),
                        "gpus={n_gpus} seq={sequential} pixel {i}: ref {a} vs split {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn angle_split_path_matches_reference() {
        let n = 16;
        let g = Geometry::cone_beam(n, 10);
        let v = phantom::shepp_logan(n);
        let reference =
            crate::kernels::forward(&g, &v, crate::kernels::Projector::Siddon, 2);
        let ctx = MultiGpu::gtx1080ti(2); // plenty of memory: angle split
        let (proj, stats) = ctx.forward(&g, Some(&v), ExecMode::Full).unwrap();
        assert_eq!(stats.splits_per_device, 1);
        assert!(!stats.pinned);
        assert_eq!(reference.data, proj.unwrap().data);
    }

    #[test]
    fn sim_only_runs_huge_problems_without_data() {
        // N = 2048 (32 GiB volume) — cannot be allocated here, but the
        // schedule can be timed.
        let g = Geometry::cone_beam(2048, 64);
        let ctx = MultiGpu::gtx1080ti(2);
        let (proj, stats) = ctx.forward(&g, None, ExecMode::SimOnly).unwrap();
        assert!(proj.is_none());
        assert!(stats.makespan_s > 0.0);
        assert!(stats.peak_device_bytes <= ctx.spec.mem_bytes);
    }

    #[test]
    fn multi_gpu_speeds_up_large_problems() {
        // the paper's workload: N³ voxels, N² detector, N angles
        let g = Geometry::cone_beam(1024, 1024);
        let t1 = MultiGpu::gtx1080ti(1)
            .forward(&g, None, ExecMode::SimOnly)
            .unwrap()
            .1
            .makespan_s;
        let t2 = MultiGpu::gtx1080ti(2)
            .forward(&g, None, ExecMode::SimOnly)
            .unwrap()
            .1
            .makespan_s;
        let t4 = MultiGpu::gtx1080ti(4)
            .forward(&g, None, ExecMode::SimOnly)
            .unwrap()
            .1
            .makespan_s;
        assert!(t2 < t1 * 0.65, "2 GPUs: {t2} vs {t1}");
        assert!(t4 < t2 * 0.7, "4 GPUs: {t4} vs {t2}");
    }

    #[test]
    fn device_memory_never_exceeded() {
        for (n, mem) in [(64usize, 64 * MIB), (96, 128 * MIB), (128, 1 * GIB)] {
            let g = Geometry::cone_beam(n, 32);
            let ctx = MultiGpu::gtx1080ti(2).with_device_mem(mem);
            let (_, stats) = ctx.forward(&g, None, ExecMode::SimOnly).unwrap();
            assert!(
                stats.peak_device_bytes <= mem,
                "N={n}: peak {} > {}",
                stats.peak_device_bytes,
                mem
            );
        }
    }

    #[test]
    fn compute_dominates_at_large_sizes() {
        let g = Geometry::cone_beam(2048, 256);
        let ctx = MultiGpu::gtx1080ti(1);
        let (_, stats) = ctx.forward(&g, None, ExecMode::SimOnly).unwrap();
        let (c, _, _, _) = stats.breakdown.fractions();
        assert!(c > 0.8, "compute fraction at N=2048: {c}");
    }

    /// The PR-6 performance claim, on the simulated timeline: at ≥ 8
    /// devices the reduction tree's log-depth merge beats the linear host
    /// fold, and the win grows with device count (`n−1` serial folds vs.
    /// `⌈log₂ n⌉` overlapped rounds).
    #[test]
    fn tree_merge_shortens_simulated_image_split_makespan_at_scale() {
        let g = Geometry::cone_beam(256, 128);
        let mem = crate::coordinator::splitter::image_split_mem(
            &g,
            &crate::coordinator::SplitConfig::default(),
        );
        let makespan = |gpus: usize, tree: bool| {
            let ctx = MultiGpu::gtx1080ti(gpus).with_device_mem(mem);
            let ctx = if tree { ctx.with_tree_merge() } else { ctx };
            ctx.forward(&g, None, ExecMode::SimOnly).unwrap().1.makespan_s
        };
        // a single device has nothing to merge: strategies coincide
        assert_eq!(makespan(1, false), makespan(1, true));
        let speedup8 = makespan(8, false) / makespan(8, true);
        let speedup16 = makespan(16, false) / makespan(16, true);
        assert!(speedup8 > 1.0, "tree must win at 8 devices: {speedup8}");
        assert!(
            speedup16 > speedup8,
            "log vs linear scaling must widen the win: {speedup16} vs {speedup8}"
        );
    }

    /// The merge strategy must not perturb the angle-split timeline —
    /// there are no cross-device partials to fold there.
    #[test]
    fn merge_strategy_does_not_affect_angle_split_sim() {
        let g = Geometry::cone_beam(128, 64);
        let linear =
            MultiGpu::gtx1080ti(2).forward(&g, None, ExecMode::SimOnly).unwrap().1.makespan_s;
        let tree = MultiGpu::gtx1080ti(2)
            .with_tree_merge()
            .forward(&g, None, ExecMode::SimOnly)
            .unwrap()
            .1
            .makespan_s;
        assert_eq!(linear, tree);
    }
}
