//! Leveled stderr logger with a global verbosity switch.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log levels, ordered by verbosity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable problems only.
    Error = 0,
    /// Degraded-but-continuing conditions.
    Warn = 1,
    /// High-level progress (the default).
    Info = 2,
    /// Per-operator-call detail.
    Debug = 3,
    /// Per-chunk detail.
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global log level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current global log level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// True if `l` would currently be emitted.
pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit a message at a level (used by the macros below).
pub fn emit(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {args}");
    }
}

/// Log at [`util::log::Level::Info`](crate::util::log::Level::Info) with `format!` syntax.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Info, format_args!($($arg)*)) };
}

/// Log at [`util::log::Level::Warn`](crate::util::log::Level::Warn) with `format!` syntax.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Warn, format_args!($($arg)*)) };
}

/// Log at [`util::log::Level::Error`](crate::util::log::Level::Error) with `format!` syntax.
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Error, format_args!($($arg)*)) };
}

/// Log at [`util::log::Level::Debug`](crate::util::log::Level::Debug) with `format!` syntax.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Debug, format_args!($($arg)*)) };
}

/// Log at [`util::log::Level::Trace`](crate::util::log::Level::Trace) with `format!` syntax.
#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Trace, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        let prev = level();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(prev);
    }

    #[test]
    fn ordering_of_levels() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info < Level::Debug);
    }
}
