//! Iteration-granular checkpointing for the iterative algorithms
//! (ISSUE 7): periodically persist the iterate and each algorithm's
//! recurrence state through the crate's raw+sidecar file format, with an
//! epoch-stamped manifest committed atomically — so a killed
//! reconstruction restarts from its last durable checkpoint and finishes
//! with the *bit-identical* final iterate of an uninterrupted run.
//!
//! ## Durability protocol
//!
//! A checkpoint is a set of epoch-suffixed data files
//! (`<name>.e<epoch>.raw` + `.json` shape sidecars, the exact
//! [`crate::io::save_volume`] format — every checkpoint is also
//! numpy-loadable) plus one `manifest.json`. A save:
//!
//! 1. writes every data file of the **new** epoch and fsyncs it,
//! 2. commits by atomically replacing the manifest
//!    (temp-file + fsync + rename, same as the OOC sidecars), and only
//!    then
//! 3. best-effort deletes the previous epoch's files.
//!
//! A crash at any point leaves the manifest referencing one fully
//! durable epoch: before step 2 the old manifest still points at the old
//! (intact) files; after it, the new files were already synced. Torn
//! states are impossible by construction, which the truncation test in
//! `volume::outofcore` and the resume tests in `algorithms::*` pin.
//!
//! ## What gets saved
//!
//! [`CheckpointState`] is deliberately algorithm-agnostic: named volumes,
//! named projection sets, named f64 scalars, the residual trace and the
//! number of completed iterations. Each algorithm decides what its
//! recurrence needs (Landweber/MLEM/OS-SART/ASD-POCS: the iterate `x`;
//! CGLS: `x`, direction `p`, residual `r` and `gamma`; FISTA: `x`, `y`
//! and the momentum scalar `t`) and restores it in
//! [`CheckpointState::volume`]/[`CheckpointState::projections`]/
//! [`CheckpointState::scalar`]. f32 arrays round-trip bit-exactly through
//! the raw files; f64 scalars and residuals round-trip exactly through
//! JSON because Rust's float formatting is shortest-roundtrip.

use std::fs;
use std::path::{Path, PathBuf};

use super::error::ReconError;
use crate::util::json::Json;
use crate::volume::outofcore::write_json_atomic;
use crate::volume::{ProjectionSet, Volume};

/// Where and how often to checkpoint. Carried in
/// [`crate::algorithms::ReconOpts::checkpoint`].
#[derive(Clone, Debug)]
pub struct CheckpointConfig {
    /// Directory the checkpoint files live in (created on first save).
    pub dir: PathBuf,
    /// Save after every `every` completed iterations (clamped to ≥ 1).
    pub every: usize,
}

impl CheckpointConfig {
    /// Checkpoint into `dir` every `every` iterations (`every` clamped to ≥ 1).
    pub fn new(dir: impl Into<PathBuf>, every: usize) -> Self {
        Self { dir: dir.into(), every: every.max(1) }
    }
}

/// One durable snapshot of an iterative run; see the module docs.
#[derive(Clone, Debug, Default)]
pub struct CheckpointState {
    /// Iterations completed when the snapshot was taken; a resumed run
    /// restarts its loop at this index.
    pub iteration: usize,
    /// Residual trace up to (and including) `iteration`.
    pub residuals: Vec<f64>,
    /// Named recurrence scalars (CGLS `gamma`, FISTA `t`, …).
    pub scalars: Vec<(String, f64)>,
    /// Named volumes (the iterate, CGLS's direction, FISTA's `y`, …).
    pub volumes: Vec<(String, Volume)>,
    /// Named projection sets (CGLS's running residual).
    pub projections: Vec<(String, ProjectionSet)>,
}

impl CheckpointState {
    /// Take the named volume out of a restored state (each name is
    /// consumed once — the algorithms move the arrays, not copy them).
    pub fn volume(&mut self, name: &str) -> anyhow::Result<Volume> {
        let i = self
            .volumes
            .iter()
            .position(|(n, _)| n == name)
            .ok_or_else(|| ReconError::Checkpoint(format!("missing volume '{name}'")))?;
        Ok(self.volumes.swap_remove(i).1)
    }

    /// Take the named projection set out of a restored state.
    pub fn projections(&mut self, name: &str) -> anyhow::Result<ProjectionSet> {
        let i = self
            .projections
            .iter()
            .position(|(n, _)| n == name)
            .ok_or_else(|| ReconError::Checkpoint(format!("missing projections '{name}'")))?;
        Ok(self.projections.swap_remove(i).1)
    }

    /// Look up a named scalar.
    pub fn scalar(&self, name: &str) -> anyhow::Result<f64> {
        self.scalars
            .iter()
            .find(|(n, _)| n == name)
            .map(|&(_, v)| v)
            .ok_or_else(|| ReconError::Checkpoint(format!("missing scalar '{name}'")).into())
    }
}

/// Writes checkpoints for one algorithm run. Epochs increase monotonically
/// across process restarts (a resumed run continues from the manifest's
/// epoch), so a resumed run's saves never collide with the files it
/// resumed from.
pub struct Checkpointer {
    cfg: CheckpointConfig,
    algorithm: String,
    epoch: u64,
}

fn manifest_path(dir: &Path) -> PathBuf {
    dir.join("manifest.json")
}

fn data_path(dir: &Path, name: &str, epoch: u64) -> PathBuf {
    dir.join(format!("{name}.e{epoch}.raw"))
}

fn sync_file(p: &Path) -> anyhow::Result<()> {
    fs::OpenOptions::new().read(true).open(p)?.sync_all()?;
    Ok(())
}

impl Checkpointer {
    /// A writer for `algorithm` under `cfg.dir`, picking up after any
    /// manifest already there.
    pub fn new(cfg: &CheckpointConfig, algorithm: &str) -> anyhow::Result<Checkpointer> {
        let epoch = match read_manifest(&cfg.dir) {
            Ok(Some(m)) => m.get("epoch").and_then(Json::as_u64).unwrap_or(0),
            _ => 0,
        };
        Ok(Checkpointer { cfg: cfg.clone(), algorithm: algorithm.to_string(), epoch })
    }

    /// Should a snapshot be taken after `completed` iterations?
    pub fn due(&self, completed: usize) -> bool {
        completed > 0 && completed % self.cfg.every == 0
    }

    /// Epochs committed so far (tests assert on cleanup behaviour).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Persist one snapshot per the durability protocol in the module
    /// docs: data files first (fsynced), manifest rename as the commit
    /// point, previous epoch deleted last (best-effort).
    pub fn save(&mut self, state: &CheckpointState) -> anyhow::Result<()> {
        fs::create_dir_all(&self.cfg.dir)?;
        let prev = self.epoch;
        let epoch = self.epoch + 1;
        for (name, v) in &state.volumes {
            let p = data_path(&self.cfg.dir, name, epoch);
            crate::io::save_volume(&p, v)?;
            sync_file(&p)?;
        }
        for (name, ps) in &state.projections {
            let p = data_path(&self.cfg.dir, name, epoch);
            crate::io::save_projections(&p, ps)?;
            sync_file(&p)?;
        }
        let manifest = Json::obj(vec![
            ("algorithm", Json::str(self.algorithm.as_str())),
            ("epoch", Json::num(epoch as f64)),
            ("iteration", Json::num(state.iteration as f64)),
            (
                "residuals",
                Json::arr(state.residuals.iter().map(|&r| Json::num(r)).collect()),
            ),
            (
                "scalars",
                Json::Obj(
                    state
                        .scalars
                        .iter()
                        .map(|(n, v)| (n.clone(), Json::num(*v)))
                        .collect(),
                ),
            ),
            (
                "volumes",
                Json::arr(state.volumes.iter().map(|(n, _)| Json::str(n.as_str())).collect()),
            ),
            (
                "projections",
                Json::arr(
                    state.projections.iter().map(|(n, _)| Json::str(n.as_str())).collect(),
                ),
            ),
        ]);
        write_json_atomic(&manifest_path(&self.cfg.dir), &manifest.pretty())?;
        self.epoch = epoch;
        if prev > 0 {
            for (name, _) in &state.volumes {
                let p = data_path(&self.cfg.dir, name, prev);
                let _ = fs::remove_file(p.with_extension("json"));
                let _ = fs::remove_file(p);
            }
            for (name, _) in &state.projections {
                let p = data_path(&self.cfg.dir, name, prev);
                let _ = fs::remove_file(p.with_extension("json"));
                let _ = fs::remove_file(p);
            }
        }
        Ok(())
    }
}

fn read_manifest(dir: &Path) -> anyhow::Result<Option<Json>> {
    let text = match fs::read_to_string(manifest_path(dir)) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    Ok(Some(Json::parse(&text)?))
}

/// Load the last durable checkpoint for `algorithm` from `cfg.dir`, or
/// `None` when no manifest exists (a fresh run). A manifest written by a
/// *different* algorithm is a hard error — two reconstructions pointed at
/// the same directory would otherwise silently resume from each other's
/// state.
pub fn resume(cfg: &CheckpointConfig, algorithm: &str) -> anyhow::Result<Option<CheckpointState>> {
    let Some(m) = read_manifest(&cfg.dir)? else { return Ok(None) };
    let found = m.get("algorithm").and_then(Json::as_str).unwrap_or("");
    if found != algorithm {
        return Err(ReconError::Checkpoint(format!(
            "{}: checkpoint belongs to '{found}', not '{algorithm}'",
            cfg.dir.display()
        ))
        .into());
    }
    let epoch = m
        .get("epoch")
        .and_then(Json::as_u64)
        .ok_or_else(|| ReconError::Checkpoint("manifest missing 'epoch'".into()))?;
    let iteration = m
        .get("iteration")
        .and_then(Json::as_usize)
        .ok_or_else(|| ReconError::Checkpoint("manifest missing 'iteration'".into()))?;
    let residuals = m
        .get("residuals")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_f64).collect())
        .unwrap_or_default();
    let scalars = m
        .get("scalars")
        .and_then(Json::as_obj)
        .map(|o| o.iter().filter_map(|(k, v)| v.as_f64().map(|f| (k.clone(), f))).collect())
        .unwrap_or_default();
    let mut volumes = Vec::new();
    if let Some(names) = m.get("volumes").and_then(Json::as_arr) {
        for n in names.iter().filter_map(Json::as_str) {
            volumes.push((n.to_string(), crate::io::load_volume(&data_path(&cfg.dir, n, epoch))?));
        }
    }
    let mut projections = Vec::new();
    if let Some(names) = m.get("projections").and_then(Json::as_arr) {
        for n in names.iter().filter_map(Json::as_str) {
            projections.push((
                n.to_string(),
                crate::io::load_projections(&data_path(&cfg.dir, n, epoch))?,
            ));
        }
    }
    Ok(Some(CheckpointState { iteration, residuals, scalars, volumes, projections }))
}

/// One-call setup for the algorithms: a writer when checkpointing is
/// configured, plus the restored state when a prior run left a durable
/// checkpoint behind.
pub fn setup(
    cfg: &Option<CheckpointConfig>,
    algorithm: &str,
) -> anyhow::Result<(Option<Checkpointer>, Option<CheckpointState>)> {
    let Some(cfg) = cfg else { return Ok((None, None)) };
    let state = resume(cfg, algorithm)?;
    Ok((Some(Checkpointer::new(cfg, algorithm)?), state))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join("tigre_ckpt_tests")
            .join(format!("{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn state(it: usize, seed: f32) -> CheckpointState {
        let mut v = Volume::zeros(4, 4, 4);
        for (i, x) in v.data.iter_mut().enumerate() {
            *x = seed + i as f32;
        }
        let mut p = ProjectionSet::zeros(3, 2, 5);
        for (i, x) in p.data.iter_mut().enumerate() {
            *x = seed - i as f32 * 0.5;
        }
        CheckpointState {
            iteration: it,
            residuals: (0..it).map(|k| 1.0 / (k + 1) as f64).collect(),
            scalars: vec![("gamma".into(), 0.125 + seed as f64)],
            volumes: vec![("x".into(), v)],
            projections: vec![("r".into(), p)],
        }
    }

    #[test]
    fn fault_checkpoint_roundtrips_bit_exactly() {
        let d = tmpdir("roundtrip");
        let cfg = CheckpointConfig::new(&d, 1);
        let mut ck = Checkpointer::new(&cfg, "cgls").unwrap();
        let st = state(3, 7.0);
        ck.save(&st).unwrap();
        let mut got = resume(&cfg, "cgls").unwrap().expect("manifest written");
        assert_eq!(got.iteration, 3);
        assert_eq!(got.residuals, st.residuals);
        assert_eq!(got.scalar("gamma").unwrap(), 0.125 + 7.0);
        assert_eq!(got.volume("x").unwrap(), st.volumes[0].1);
        assert_eq!(got.projections("r").unwrap(), st.projections[0].1);
        // wrong algorithm must refuse, not resume
        let err = resume(&cfg, "landweber").unwrap_err();
        assert!(format!("{err:#}").contains("belongs to"), "{err:#}");
        // absent directory is a fresh run, not an error
        assert!(resume(&CheckpointConfig::new(d.join("nowhere"), 1), "cgls")
            .unwrap()
            .is_none());
    }

    #[test]
    fn fault_checkpoint_epochs_advance_and_old_files_are_cleaned() {
        let d = tmpdir("epochs");
        let cfg = CheckpointConfig::new(&d, 2);
        let mut ck = Checkpointer::new(&cfg, "landweber").unwrap();
        assert!(!ck.due(0) && !ck.due(1) && ck.due(2) && !ck.due(3) && ck.due(4));
        ck.save(&state(2, 1.0)).unwrap();
        ck.save(&state(4, 2.0)).unwrap();
        assert_eq!(ck.epoch(), 2);
        assert!(data_path(&d, "x", 2).exists());
        assert!(!data_path(&d, "x", 1).exists(), "previous epoch must be cleaned up");
        assert!(!manifest_path(&d).with_extension("json.tmp").exists());
        let got = resume(&cfg, "landweber").unwrap().unwrap();
        assert_eq!(got.iteration, 4);
        // a new writer on the same dir continues the epoch sequence
        let ck2 = Checkpointer::new(&cfg, "landweber").unwrap();
        assert_eq!(ck2.epoch(), 2);
    }

    #[test]
    fn fault_torn_manifest_never_exists_but_missing_data_is_typed() {
        // delete a data file behind the manifest's back: resume must be a
        // hard error (the epoch was durable, so this means real damage)
        let d = tmpdir("damage");
        let cfg = CheckpointConfig::new(&d, 1);
        let mut ck = Checkpointer::new(&cfg, "mlem").unwrap();
        ck.save(&state(1, 3.0)).unwrap();
        fs::remove_file(data_path(&d, "x", 1)).unwrap();
        assert!(resume(&cfg, "mlem").is_err());
    }
}
