//! Offline shim for the subset of the `anyhow` API this workspace uses.
//!
//! The build environment has no crates.io access, so the real `anyhow`
//! cannot be fetched; this crate re-implements the pieces the code base
//! relies on with identical spelling and semantics:
//!
//!  * [`Error`] — an opaque error value carrying a context chain.
//!    `Display` prints the outermost message; alternate display (`{:#}`)
//!    prints the whole chain joined by `": "`, matching anyhow.
//!  * [`Result`] — `Result<T, Error>` with a defaulted error type.
//!  * [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros.
//!  * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!    and `Option`.
//!  * `From<E: std::error::Error>` so `?` converts foreign errors.

use std::fmt;

/// An error with a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost message of the chain.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // anyhow's Debug prints the message plus a "Caused by" list; a
        // single joined line carries the same information for test
        // failures and `main` error returns.
        f.write_str(&self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` to `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(e.root_cause(), "inner");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("17").unwrap(), 17);
        assert!(parse("x").is_err());
    }

    #[test]
    fn context_on_option_and_result() {
        let n: Option<i32> = None;
        let e = n.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");

        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("formatting").unwrap_err();
        assert!(format!("{e:#}").starts_with("formatting: "));
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert!(f(5).is_ok());
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too big");
        let from_string: Error = anyhow!(String::from("owned message"));
        assert_eq!(format!("{from_string}"), "owned message");
    }
}
