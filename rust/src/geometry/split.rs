//! Partition descriptors: z-slabs of a volume and angle chunks of a
//! projection set. These are the units the coordinator schedules.

/// A contiguous stack of axial (z) slices `[z0, z1)` of a volume.
///
/// Because volumes are stored z-slowest, a z-slab is a contiguous memory
/// range — the paper partitions images into "volumetric axial slice stacks"
/// for exactly this reason (single contiguous H2D/D2H copies).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ZSlab {
    /// First slice (inclusive).
    pub z0: usize,
    /// One past the last slice (exclusive).
    pub z1: usize,
}

impl ZSlab {
    /// Number of slices in the slab.
    pub fn len(&self) -> usize {
        self.z1 - self.z0
    }

    /// True when the slab covers no slices.
    pub fn is_empty(&self) -> bool {
        self.z0 >= self.z1
    }
}

/// A contiguous run of projection angles `[a0, a1)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AngleChunk {
    /// First angle index (inclusive).
    pub a0: usize,
    /// One past the last angle index (exclusive).
    pub a1: usize,
}

impl AngleChunk {
    /// Number of angles in the chunk.
    pub fn len(&self) -> usize {
        self.a1 - self.a0
    }

    /// True when the chunk covers no angles.
    pub fn is_empty(&self) -> bool {
        self.a0 >= self.a1
    }
}

/// Split `n` items into `parts` nearly-equal contiguous ranges
/// (first `n % parts` ranges get one extra item). Returns `(start, end)`.
pub fn split_even(n: usize, parts: usize) -> Vec<(usize, usize)> {
    assert!(parts > 0, "parts must be > 0");
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Split `n` items into chunks of at most `chunk` items.
pub fn split_chunks(n: usize, chunk: usize) -> Vec<(usize, usize)> {
    assert!(chunk > 0, "chunk must be > 0");
    let mut out = Vec::with_capacity(n.div_ceil(chunk));
    let mut start = 0;
    while start < n {
        let end = (start + chunk).min(n);
        out.push((start, end));
        start = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, prop_assert};

    #[test]
    fn split_even_exact() {
        assert_eq!(split_even(10, 2), vec![(0, 5), (5, 10)]);
        assert_eq!(split_even(10, 3), vec![(0, 4), (4, 7), (7, 10)]);
        assert_eq!(split_even(2, 4), vec![(0, 1), (1, 2), (2, 2), (2, 2)]);
    }

    #[test]
    fn split_chunks_exact() {
        assert_eq!(split_chunks(10, 4), vec![(0, 4), (4, 8), (8, 10)]);
        assert_eq!(split_chunks(8, 8), vec![(0, 8)]);
        assert_eq!(split_chunks(0, 4), Vec::<(usize, usize)>::new());
    }

    #[test]
    fn prop_split_even_partitions() {
        check("split_even partitions 0..n", 300, |g| {
            let n = g.usize(0, 10_000);
            let parts = g.usize(1, 64);
            let s = split_even(n, parts);
            prop_assert(s.len() == parts, "wrong number of parts")?;
            prop_assert(s[0].0 == 0, "must start at 0")?;
            prop_assert(s[parts - 1].1 == n, "must end at n")?;
            for w in s.windows(2) {
                prop_assert(w[0].1 == w[1].0, "ranges must be contiguous")?;
            }
            let max = s.iter().map(|(a, b)| b - a).max().unwrap();
            let min = s.iter().map(|(a, b)| b - a).min().unwrap();
            prop_assert(max - min <= 1, "ranges must be balanced")
        });
    }

    #[test]
    fn prop_split_chunks_partitions() {
        check("split_chunks partitions 0..n", 300, |g| {
            let n = g.usize(0, 10_000);
            let chunk = g.usize(1, 512);
            let s = split_chunks(n, chunk);
            let total: usize = s.iter().map(|(a, b)| b - a).sum();
            prop_assert(total == n, "total length mismatch")?;
            for (a, b) in &s {
                prop_assert(b > a && b - a <= chunk, "chunk size bound")?;
            }
            for w in s.windows(2) {
                prop_assert(w[0].1 == w[1].0, "contiguous")?;
            }
            Ok(())
        });
    }
}
