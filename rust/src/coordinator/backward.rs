//! Algorithm 2 — the backprojection kernel launch procedure.
//!
//! The image is split into equal z-slab stacks allocated among GPUs; if
//! the total (plus the two projection-chunk buffers) exceeds aggregate
//! device RAM, each GPU works through a queue of slabs. Every GPU consumes
//! **all** projections, streamed through the double buffer while the voxel
//! update kernels run (paper Fig. 5): the chunk copy for launch `k+1`
//! overlaps the kernel for launch `k` because the kernel is queued first.

use crate::geometry::Geometry;
use crate::simgpu::{Category, Ev, SimNode, SimOom};
use crate::volume::{ProjInput, ProjectionSet, Volume};

use super::degrade::DegradeEvent;
use super::error::ReconError;
use super::executor::{ExecMode, MultiGpu, OpStats};
use super::forward::{stamp_projector, MAX_PRESSURE_REFINES};
use super::residency::{BpResidency, OpKind};
use super::splitter::{plan_backward, refine_for_budget, Plan, PlanProjector};

/// Run the backprojection: returns the real volume (in `Full` mode) and
/// the simulated-schedule statistics.
pub fn run(
    ctx: &MultiGpu,
    g: &Geometry,
    proj: Option<&ProjectionSet>,
    mode: ExecMode,
) -> anyhow::Result<(Option<Volume>, OpStats)> {
    let plan = plan_backward(g, ctx.n_gpus, ctx.spec.mem_bytes, &ctx.split)
        .map_err(|e| ReconError::Plan(format!("backward plan: {e}")))?;
    run_with(ctx, g, proj.map(ProjInput::Ram), mode, &plan, None)
}

/// Like [`run`] but against a pre-computed plan, a RAM-or-OOC input and
/// optional residency decisions (`coordinator::residency::ReconSession`
/// and `MultiGpu::backward_ooc` enter here).
pub(crate) fn run_with(
    ctx: &MultiGpu,
    g: &Geometry,
    proj: Option<ProjInput<'_>>,
    mode: ExecMode,
    plan: &Plan,
    res: Option<&BpResidency>,
) -> anyhow::Result<(Option<Volume>, OpStats)> {
    // Memory-pressure ladder (ISSUE 8) — see `forward::run_with` for the
    // protocol. BP refinement doubles the pressured device's slab count:
    // slabs write disjoint z-ranges and every slab still consumes all
    // chunks in the same order, so output stays bit-identical. Residency
    // decisions are indexed by the original plan's slabs, so rung 1
    // (dropping them) always precedes any refinement.
    // Stamp the projector family from the backend (see
    // `forward::stamp_projector`) so the simulated timeline costs
    // SpMVᵀ + cold-shard builds when the sparse backend is active.
    let mut plan = plan.clone();
    stamp_projector(ctx, g, &mut plan, OpKind::Bp);
    let mut res = res;
    let mut rungs = 0usize;
    let mut refines = 0usize;
    let mut penalty_s = 0.0;
    let (sim, plan) = loop {
        let mut sim = ctx.fresh_sim();
        if penalty_s > 0.0 {
            sim.host_busy(penalty_s, Category::OtherMem, "pressure replan");
        }
        let attempt = (|| -> Result<(), SimOom> {
            if let Some(r) = res {
                for (d, &bytes) in r.reserve.iter().enumerate() {
                    sim.reserve(d, "resident", bytes)?;
                }
            }
            simulate_with(g, &plan, &mut sim, res)
        })();
        let oom = match attempt {
            Ok(()) => break (sim, plan),
            Err(oom) => oom,
        };
        rungs += 1;
        penalty_s += ctx.cost.pressure_rung_penalty_s();
        if let Some(r) = res.take() {
            ctx.degrade.record(DegradeEvent::Evicted {
                device: oom.device,
                entries: r.reserve.iter().filter(|&&b| b > 0).count(),
            });
            continue;
        }
        if refines < MAX_PRESSURE_REFINES {
            if let Ok((refined, detail)) = refine_for_budget(&plan, g, false, oom.device) {
                ctx.degrade.record(DegradeEvent::Refined { device: oom.device, detail });
                plan = refined;
                refines += 1;
                continue;
            }
        }
        if !plan.ooc_volume {
            ctx.degrade.record(DegradeEvent::Spilled {
                device: oom.device,
                detail: format!("bp output slabs -> disk after '{}'", oom.label),
            });
            plan.ooc_volume = true;
            continue;
        }
        return Err(ReconError::MemoryPressure {
            device: oom.device,
            attempts: rungs,
            detail: oom.detail,
        }
        .into());
    };
    let plan = &plan;
    let mut stats = OpStats::from_sim(&sim, plan);

    let vol = match mode {
        ExecMode::SimOnly => None,
        ExecMode::Full => {
            let proj = proj
                .ok_or_else(|| ReconError::Input("Full mode requires projection data".into()))?;
            Some(execute_real(ctx, g, proj, plan)?)
        }
    };
    stats.degradation = ctx.degrade.drain();
    Ok((vol, stats))
}

/// Per-unit BP kernel time under the plan's projector family: ray-driven
/// units cost `bp_kernel_s`; sparse units cost an SpMVᵀ over the shard's
/// estimated nnz plus the one-time CSR build when the shard cache is
/// cold (each (slab, chunk) unit runs exactly once per operator call, so
/// each shard's build is charged exactly once).
fn bp_unit_kernel_s(
    sim: &SimNode,
    g: &Geometry,
    plan: &Plan,
    chunk_len: usize,
    nz_slab: usize,
) -> f64 {
    match plan.projector {
        PlanProjector::Ray => {
            sim.cost.bp_kernel_s(g.n_vox[0], g.n_vox[1], nz_slab, chunk_len)
        }
        PlanProjector::Sparse { warm } => {
            let nnz = sim.cost.sparse_nnz_estimate(
                g.n_det[0],
                g.n_det[1],
                chunk_len,
                g.n_vox[0],
                g.n_vox[1],
                nz_slab,
                g.n_vox[2],
            );
            let setup = if warm { 0.0 } else { sim.cost.sparse_setup_s(nnz) };
            setup + sim.cost.spmvt_s(nnz)
        }
    }
}

/// Replay Algorithm 2 on the discrete-event node.
pub fn simulate(g: &Geometry, plan: &Plan, sim: &mut SimNode) -> Result<(), SimOom> {
    simulate_with(g, plan, sim, None)
}

/// [`simulate`] with residency decisions: chunk uploads shrink to the
/// bytes the cache does not already hold (possibly zero — the copy is
/// skipped entirely), and residual mode charges the on-device `b − Ax`
/// subtraction before the first kernel that consumes each chunk.
pub(crate) fn simulate_with(
    g: &Geometry,
    plan: &Plan,
    sim: &mut SimNode,
    res: Option<&BpResidency>,
) -> Result<(), SimOom> {
    let n_dev = sim.n_devices();
    let chunks = &plan.angle_chunks;

    // 1: check GPU memory and properties
    sim.property_check();

    // 3–5: page-lock the image memory. The output volume does not exist
    // yet, so pinning forces physical allocation — the slower pin rate
    // (this is why Fig. 9 shows a larger pin share for backprojection).
    if plan.pin_image {
        sim.pin_host(g.volume_bytes(), false);
    }

    // 6: projection double buffers
    for d in 0..n_dev {
        for b in 0..plan.n_proj_buffers {
            sim.alloc(d, &format!("projbuf{b}"), plan.proj_buffer_bytes)?;
        }
    }

    // 7: slab loop (lockstep across devices; each device has its own queue)
    let max_slabs = plan.splits_per_device();
    let mut slab_alloced = vec![false; n_dev];
    for s in 0..max_slabs {
        let mut active = vec![false; n_dev];
        for d in 0..n_dev {
            let Some(slab) = plan.per_device[d].slabs.get(s) else { continue };
            active[d] = true;
            if slab_alloced[d] {
                sim.free(d, "slab");
            }
            sim.alloc(d, "slab", g.slab_bytes(slab.len()))?;
            slab_alloced[d] = true;
            // the output slab starts as zeros on-device: no H2D needed
        }

        // 8–12: stream all projection chunks through the double buffer
        let mut prev_kernel: Vec<Option<Ev>> = vec![None; n_dev];
        let mut prev_prev_copy: Vec<Option<Ev>> = vec![None; n_dev];
        let mut prev_copy: Vec<Option<Ev>> = vec![None; n_dev];
        for (c, ch) in chunks.iter().enumerate() {
            let bytes = ch.len() as u64 * g.single_proj_bytes();
            // 9: copy projection chunk to all devices (synchronous,
            // pageable input array). Buffer reuse: chunk c lands in
            // buffer c%2, so it must wait for kernel c-2... which has
            // long finished from the host's point of view because the
            // host synchronizes each kernel (line 10/Synchronize). The
            // copy still overlaps kernel c-1 on the compute engine.
            // With residency decisions the transferred bytes shrink to
            // what is not already resident; zero bytes = no copy at all.
            let mut copy_ev: Vec<Option<Ev>> = vec![None; n_dev];
            for d in 0..n_dev {
                if !active[d] {
                    continue;
                }
                let h2d_bytes = match res {
                    Some(r) => r.stage[d][s][c].h2d_bytes,
                    None => bytes,
                };
                if h2d_bytes > 0 {
                    let mut dep = prev_prev_copy[d].unwrap_or(Ev::ZERO);
                    if plan.ooc_proj {
                        // chunk streams from the backing store first
                        // (loader-lane prefetch on the serialized disk)
                        dep = dep.max(sim.disk_read(h2d_bytes, Ev::ZERO));
                    }
                    copy_ev[d] = Some(sim.h2d(d, h2d_bytes, plan.pin_image, dep));
                }
            }
            // 10: Synchronize() — wait for the copies
            for d in 0..n_dev {
                if let Some(e) = copy_ev[d] {
                    sim.host_sync(e);
                }
            }
            // 11: queue the backprojection kernel (async). In residual
            // mode the on-device `b − Ax` subtraction is fused into the
            // consuming launch (memory-bound accumulation time, no extra
            // launch overhead — the paper measures accumulation at
            // ~0.01% of a projection kernel).
            for d in 0..n_dev {
                if !active[d] {
                    continue;
                }
                let slab = plan.per_device[d].slabs[s];
                let sub = res.map_or(0.0, |r| r.stage[d][s][c].subtract_s);
                let t = bp_unit_kernel_s(sim, g, plan, ch.len(), slab.len()) + sub;
                let dep =
                    copy_ev[d].unwrap_or(Ev::ZERO).max(prev_kernel[d].unwrap_or(Ev::ZERO));
                let ev = sim.kernel(d, t, dep, &format!("bp d{d} s{s} c{c}"));
                prev_kernel[d] = Some(ev);
            }
            prev_prev_copy = prev_copy;
            prev_copy = copy_ev;
        }

        // 13: copy the finished image piece back to the host — and, for
        // an out-of-core output volume, spill it on to the backing store
        // (the write overlaps the next slab's compute on the disk engine)
        for d in 0..n_dev {
            if !active[d] {
                continue;
            }
            let slab = plan.per_device[d].slabs[s];
            let ev = sim.d2h(
                d,
                g.slab_bytes(slab.len()),
                plan.pin_image,
                prev_kernel[d].unwrap_or(Ev::ZERO),
            );
            sim.host_sync(ev);
            if plan.ooc_volume {
                sim.disk_write(g.slab_bytes(slab.len()), ev);
            }
        }
    }

    // 15: free GPU resources
    for d in 0..n_dev {
        for b in 0..plan.n_proj_buffers {
            sim.free(d, &format!("projbuf{b}"));
        }
        if slab_alloced[d] {
            sim.free(d, "slab");
        }
    }
    if plan.pin_image {
        sim.unpin_host(g.volume_bytes());
    }
    sim.sync_all();
    Ok(())
}

/// Real numerics with the identical partitioning: the pipelined executor
/// by default (see `coordinator::pipeline`), or the host-sequential
/// baseline when `ctx.exec.pipelined` is off.
fn execute_real(
    ctx: &MultiGpu,
    g: &Geometry,
    proj: ProjInput<'_>,
    plan: &Plan,
) -> anyhow::Result<Volume> {
    if ctx.exec.pipelined {
        super::pipeline::backward_pipelined(ctx, g, proj, plan)
    } else {
        super::pipeline::backward_sequential(ctx, g, proj, plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::{ExecMode, MultiGpu};
    use crate::kernels::{BackprojWeight, Projector};
    use crate::phantom;
    use crate::util::units::MIB;

    #[test]
    fn split_backprojection_matches_unsplit_reference() {
        let n = 20;
        let g = Geometry::cone_beam(n, 12);
        let v = phantom::shepp_logan(n);
        let p = crate::kernels::forward(&g, &v, Projector::Siddon, 2);
        let reference = crate::kernels::backward(&g, &p, BackprojWeight::Fdk, 2);

        for n_gpus in [1, 2, 3] {
            // tiny devices force slab queues (splitter owns the threshold)
            let mem = crate::coordinator::splitter::image_split_mem(
                &g,
                &crate::coordinator::SplitConfig::default(),
            );
            // both executors must match the unsplit reference: the
            // pipelined default and the sequential baseline
            for sequential in [false, true] {
                let ctx = MultiGpu::gtx1080ti(n_gpus).with_device_mem(mem);
                let ctx = if sequential { ctx.with_sequential_executor() } else { ctx };
                let (vol, stats) = ctx.backward(&g, Some(&p), ExecMode::Full).unwrap();
                let vol = vol.unwrap();
                assert!(stats.peak_device_bytes <= mem);
                for (i, (a, b)) in reference.data.iter().zip(&vol.data).enumerate() {
                    assert!(
                        (a - b).abs() <= 2e-3 * (1.0 + a.abs()),
                        "gpus={n_gpus} seq={sequential} voxel {i}: ref {a} vs split {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn bp_sim_scales_with_devices() {
        // the paper's workload: N³ voxels, N² detector, N angles. At
        // N=1024 BP scaling is pin-overhead-limited (paper §3.1); the
        // near-linear regime the paper reports is at large N.
        let g = Geometry::cone_beam(2048, 2048);
        let times: Vec<f64> = [1usize, 2, 4]
            .iter()
            .map(|&n| {
                MultiGpu::gtx1080ti(n)
                    .backward(&g, None, ExecMode::SimOnly)
                    .unwrap()
                    .1
                    .makespan_s
            })
            .collect();
        assert!(times[1] < times[0] * 0.65, "2 GPU {} vs 1 GPU {}", times[1], times[0]);
        assert!(times[2] < times[1] * 0.7, "4 GPU {} vs 2 GPU {}", times[2], times[1]);
    }

    #[test]
    fn bp_pin_share_larger_than_fp() {
        // Paper Fig. 9: pinning is a bigger fraction of BP than FP
        // (pinning the not-yet-allocated output volume is slower).
        let g = Geometry::cone_beam(1536, 1536);
        let ctx = MultiGpu::gtx1080ti(2);
        let (_, fp) = ctx.forward(&g, None, ExecMode::SimOnly).unwrap();
        let (_, bp) = ctx.backward(&g, None, ExecMode::SimOnly).unwrap();
        if fp.pinned && bp.pinned {
            let fp_frac = fp.breakdown.pin / fp.makespan_s;
            let bp_frac = bp.breakdown.pin / bp.makespan_s;
            assert!(bp_frac > fp_frac, "bp pin {bp_frac} vs fp pin {fp_frac}");
        }
    }

    #[test]
    fn bp_memory_bounded_with_tiny_devices() {
        let g = Geometry::cone_beam(96, 48);
        let ctx = MultiGpu::gtx1080ti(2).with_device_mem(3 * MIB);
        let (_, stats) = ctx.backward(&g, None, ExecMode::SimOnly).unwrap();
        assert!(stats.peak_device_bytes <= 3 * MIB);
        assert!(stats.splits_per_device > 1);
    }

    #[test]
    fn ooc_plans_charge_the_disk_engine_in_simonly() {
        // streamed chunks wait on disk reads, and an out-of-core output
        // volume (with_ooc_volume_spill — the add_scaled_volume /
        // store_slab writeback the caller performs) charges disk writes
        // after each slab's D2H: both must extend the plain makespan
        use crate::coordinator::splitter::plan_backward_ooc;
        let g = Geometry::cone_beam(96, 48);
        let ctx = MultiGpu::gtx1080ti(1);
        let cfg = crate::coordinator::SplitConfig::default();
        let budget = g.proj_bytes() / 2;
        let ooc_in = plan_backward_ooc(&g, 1, ctx.spec.mem_bytes, &cfg, budget).unwrap();
        // identical plan with the streaming flags stripped: the only
        // schedule difference left is the disk engine
        let mut ram_same = ooc_in.clone();
        ram_same.ooc_proj = false;
        ram_same.host_budget_bytes = None;
        let ooc_in_out = ooc_in.clone().with_ooc_volume_spill();
        let t = |plan: &crate::coordinator::Plan| {
            run_with(&ctx, &g, None, ExecMode::SimOnly, plan, None).unwrap().1.makespan_s
        };
        let t_ram = t(&ram_same);
        let t_in = t(&ooc_in);
        let t_in_out = t(&ooc_in_out);
        assert!(t_in > t_ram, "chunk disk reads must cost time: {t_in} vs {t_ram}");
        assert!(t_in_out > t_in, "output spill must cost time: {t_in_out} vs {t_in}");
    }

    #[test]
    fn backprojection_faster_than_projection_at_scale() {
        // Paper §3.1: "the backprojection ... is faster".
        let g = Geometry::cone_beam(1024, 512);
        let ctx = MultiGpu::gtx1080ti(1);
        let fp = ctx.forward(&g, None, ExecMode::SimOnly).unwrap().1.makespan_s;
        let bp = ctx.backward(&g, None, ExecMode::SimOnly).unwrap().1.makespan_s;
        assert!(bp < fp, "bp {bp} vs fp {fp}");
    }
}
