//! Ablation — the double-buffered, overlap-ordered queueing of
//! Algorithms 1 & 2 vs the naive serialized strategy (the "common
//! approach" of the literature the paper improves on).

use tigre::bench::buffering_ablation;
use tigre::util::stats::Table;

fn main() {
    let mut t = Table::new(&[
        "N", "GPUs", "FP prop [s]", "FP naive [s]", "FP gain", "BP prop [s]", "BP naive [s]", "BP gain",
    ]);
    for &n in &[256usize, 512, 1024, 2048] {
        for &gpus in &[1usize, 2, 4] {
            let (fp, nfp, bp, nbp) = buffering_ablation(n, gpus).unwrap();
            t.row(vec![
                n.to_string(),
                gpus.to_string(),
                format!("{fp:.2}"),
                format!("{nfp:.2}"),
                format!("{:.2}x", nfp / fp),
                format!("{bp:.2}"),
                format!("{nbp:.2}"),
                format!("{:.2}x", nbp / bp),
            ]);
        }
    }
    println!("=== buffering/overlap ablation: proposed (Alg. 1/2) vs naive ===");
    println!("{}", t.render());
    println!("(gain = naive / proposed; >1 means the paper's queueing wins)");
}
