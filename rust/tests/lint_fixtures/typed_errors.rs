// Seeded violation for the `typed-errors` lint: checked under the
// pretend path rust/src/coordinator/fixture.rs. Never compiled.

pub fn stringly() -> anyhow::Result<()> {
    Err(anyhow::anyhow!("fixture stringly error"))
}

pub fn bailing(x: u32) -> anyhow::Result<u32> {
    anyhow::ensure!(x > 0, "fixture ensure");
    if x > 10 {
        anyhow::bail!("fixture bail");
    }
    Ok(x)
}

pub fn wrapped(v: Option<u32>) -> anyhow::Result<u32> {
    use anyhow::Context;
    v.context("fixture context")
}
