//! Volume and projection containers + host memory accounting.
//!
//! Layout conventions (chosen so the paper's partitions are contiguous):
//!  * [`Volume`]: `data[(z*ny + y)*nx + x]` — z slowest, so an axial z-slab
//!    is one contiguous memory range (single H2D copy).
//!  * [`ProjectionSet`]: `data[(a*nv + v)*nu + u]` — angle slowest, so an
//!    angle chunk is one contiguous range.

mod hostmem;
pub mod outofcore;

pub use hostmem::{HostMemError, HostMemRegistry, MemState, PinEvent};
pub use outofcore::{OocProjections, OocVolume, StoreStats};

use std::sync::atomic::{AtomicU64, Ordering};

use crate::geometry::Geometry;

/// Process-unique identity for epoch-tracked host buffers (see
/// [`TrackedVolume`] / [`TrackedProjections`]). Monotonic and never
/// reused, so a residency cache entry keyed by `(id, epoch)` can never
/// alias a different buffer that later occupies the same address.
static NEXT_TRACKED_ID: AtomicU64 = AtomicU64::new(1);

fn next_tracked_id() -> u64 {
    NEXT_TRACKED_ID.fetch_add(1, Ordering::Relaxed)
}

/// A 3-D image volume of f32 attenuation values.
#[derive(Clone, Debug, PartialEq)]
pub struct Volume {
    /// Voxels along x (fastest-varying index).
    pub nx: usize,
    /// Voxels along y.
    pub ny: usize,
    /// Voxels along z (slowest-varying index).
    pub nz: usize,
    /// Voxel values, layout `data[(z*ny + y)*nx + x]`.
    pub data: Vec<f32>,
}

impl Volume {
    /// All-zero volume of the given shape.
    pub fn zeros(nx: usize, ny: usize, nz: usize) -> Self {
        Self { nx, ny, nz, data: vec![0.0; nx * ny * nz] }
    }

    /// All-zero volume shaped to a geometry's voxel grid.
    pub fn zeros_like(g: &Geometry) -> Self {
        Self::zeros(g.n_vox[0], g.n_vox[1], g.n_vox[2])
    }

    /// Volume filled by evaluating `f(x, y, z)` at every voxel.
    pub fn from_fn(nx: usize, ny: usize, nz: usize, f: impl Fn(usize, usize, usize) -> f32) -> Self {
        let mut v = Self::zeros(nx, ny, nz);
        for z in 0..nz {
            for y in 0..ny {
                for x in 0..nx {
                    v.data[(z * ny + y) * nx + x] = f(x, y, z);
                }
            }
        }
        v
    }

    /// Linear index of voxel `(x, y, z)`.
    #[inline(always)]
    pub fn idx(&self, x: usize, y: usize, z: usize) -> usize {
        (z * self.ny + y) * self.nx + x
    }

    /// Value at voxel `(x, y, z)`.
    #[inline(always)]
    pub fn at(&self, x: usize, y: usize, z: usize) -> f32 {
        self.data[self.idx(x, y, z)]
    }

    /// Mutable reference to voxel `(x, y, z)`.
    #[inline(always)]
    pub fn at_mut(&mut self, x: usize, y: usize, z: usize) -> &mut f32 {
        let i = self.idx(x, y, z);
        &mut self.data[i]
    }

    /// Total voxel count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True for a zero-voxel volume.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Storage size in bytes (f32 voxels).
    pub fn bytes(&self) -> u64 {
        self.data.len() as u64 * 4
    }

    /// Borrow the contiguous z-slab `[z0, z1)`.
    pub fn slab(&self, z0: usize, z1: usize) -> &[f32] {
        let plane = self.nx * self.ny;
        &self.data[z0 * plane..z1 * plane]
    }

    /// Mutably borrow the contiguous z-slab `[z0, z1)`.
    pub fn slab_mut(&mut self, z0: usize, z1: usize) -> &mut [f32] {
        let plane = self.nx * self.ny;
        &mut self.data[z0 * plane..z1 * plane]
    }

    /// Copy a z-slab out into an owned sub-volume.
    pub fn extract_slab(&self, z0: usize, z1: usize) -> Volume {
        Volume { nx: self.nx, ny: self.ny, nz: z1 - z0, data: self.slab(z0, z1).to_vec() }
    }

    /// Borrow a z-slab as a zero-copy kernel input (see
    /// [`VolumeSlabView`]); the pipelined executor stages slabs this way
    /// instead of through [`Volume::extract_slab`] memcpys.
    pub fn slab_view(&self, z0: usize, z1: usize) -> VolumeSlabView<'_> {
        VolumeSlabView { nx: self.nx, ny: self.ny, nz: z1 - z0, data: self.slab(z0, z1) }
    }

    /// Borrow the whole volume as a kernel-input view.
    pub fn as_view(&self) -> VolumeSlabView<'_> {
        VolumeSlabView { nx: self.nx, ny: self.ny, nz: self.nz, data: &self.data }
    }

    /// Write a sub-volume back into the z-slab `[z0, z0+sub.nz)`.
    pub fn insert_slab(&mut self, z0: usize, sub: &Volume) {
        assert_eq!(sub.nx, self.nx);
        assert_eq!(sub.ny, self.ny);
        let dst = self.slab_mut(z0, z0 + sub.nz);
        dst.copy_from_slice(&sub.data);
    }

    // -- elementwise math used by the algorithms -------------------------

    /// Multiply every voxel by `s`.
    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// `self += s * other` (AXPY), elementwise.
    pub fn add_scaled(&mut self, other: &Volume, s: f32) {
        assert_eq!(self.data.len(), other.data.len());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    /// Clamp every voxel to at least `lo` (nonnegativity projection).
    pub fn clamp_min(&mut self, lo: f32) {
        for v in &mut self.data {
            if *v < lo {
                *v = lo;
            }
        }
    }

    /// Inner product in f64 accumulation.
    pub fn dot(&self, other: &Volume) -> f64 {
        assert_eq!(self.data.len(), other.data.len());
        self.data.iter().zip(&other.data).map(|(a, b)| *a as f64 * *b as f64).sum()
    }

    /// Euclidean norm in f64 accumulation.
    pub fn norm2(&self) -> f64 {
        self.data.iter().map(|v| *v as f64 * *v as f64).sum::<f64>().sqrt()
    }

    /// Central axial slice (handy for figure export).
    pub fn mid_slice(&self) -> Vec<f32> {
        let z = self.nz / 2;
        self.slab(z, z + 1).to_vec()
    }
}

/// Borrowed z-slab of a [`Volume`]: the zero-copy staging unit of the
/// pipelined executor. Because volumes are stored z-slowest, a slab is one
/// contiguous range and the view is just `(shape, &[f32])`; kernels walk
/// it with the same `(x + nx·(y + ny·z))` strides as an owned volume, so
/// no kernel code changes between owned and borrowed inputs.
#[derive(Clone, Copy, Debug)]
pub struct VolumeSlabView<'a> {
    /// Voxels along x.
    pub nx: usize,
    /// Voxels along y.
    pub ny: usize,
    /// Slices in the slab (not the parent volume's full z).
    pub nz: usize,
    /// Borrowed contiguous slab storage.
    pub data: &'a [f32],
}

impl VolumeSlabView<'_> {
    /// Voxel count of the slab.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the slab covers no voxels.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Materialize an owned copy (only needed by backends that require
    /// owned host buffers, e.g. PJRT artifact execution).
    pub fn to_volume(&self) -> Volume {
        Volume { nx: self.nx, ny: self.ny, nz: self.nz, data: self.data.to_vec() }
    }
}

/// Borrowed angle chunk of a [`ProjectionSet`]: the zero-copy staging unit
/// for backprojection inputs (angle-slowest layout ⇒ one contiguous range).
#[derive(Clone, Copy, Debug)]
pub struct ProjChunkView<'a> {
    /// Detector columns.
    pub nu: usize,
    /// Detector rows.
    pub nv: usize,
    /// Angles in the chunk (not the parent set's full count).
    pub n_angles: usize,
    /// Borrowed contiguous chunk storage.
    pub data: &'a [f32],
}

impl ProjChunkView<'_> {
    /// Element count of the chunk.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the chunk covers no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Materialize an owned copy (PJRT backend only — see
    /// [`VolumeSlabView::to_volume`]).
    pub fn to_projections(&self) -> ProjectionSet {
        ProjectionSet {
            nu: self.nu,
            nv: self.nv,
            n_angles: self.n_angles,
            data: self.data.to_vec(),
        }
    }
}

/// A kernel-input volume for the executors: either a host-resident
/// [`Volume`] (staged through zero-copy [`VolumeSlabView`]s) or an
/// out-of-core [`OocVolume`] (slabs streamed from disk by the pipelined
/// executor's loader lanes). `Copy`-cheap: both arms borrow.
#[derive(Clone, Copy, Debug)]
pub enum VolumeInput<'a> {
    /// Host-resident volume, staged through zero-copy slab views.
    Ram(&'a Volume),
    /// Out-of-core volume, slabs streamed from disk.
    Ooc(&'a OocVolume),
}

impl VolumeInput<'_> {
    /// `(nx, ny, nz)` of the backing volume.
    pub fn dims(&self) -> (usize, usize, usize) {
        match self {
            VolumeInput::Ram(v) => (v.nx, v.ny, v.nz),
            VolumeInput::Ooc(o) => o.dims(),
        }
    }

    /// Logical size in bytes of the backing volume.
    pub fn bytes(&self) -> u64 {
        match self {
            VolumeInput::Ram(v) => v.bytes(),
            VolumeInput::Ooc(o) => o.bytes(),
        }
    }

    /// True for the out-of-core arm.
    pub fn is_ooc(&self) -> bool {
        matches!(self, VolumeInput::Ooc(_))
    }
}

/// A kernel-input projection set: host-resident (zero-copy
/// [`ProjChunkView`] staging) or out-of-core (angle chunks streamed from
/// disk). See [`VolumeInput`].
#[derive(Clone, Copy, Debug)]
pub enum ProjInput<'a> {
    /// Host-resident projection set, staged through zero-copy chunk views.
    Ram(&'a ProjectionSet),
    /// Out-of-core projection set, angle chunks streamed from disk.
    Ooc(&'a OocProjections),
}

impl ProjInput<'_> {
    /// `(nu, nv, n_angles)` of the backing set.
    pub fn dims(&self) -> (usize, usize, usize) {
        match self {
            ProjInput::Ram(p) => (p.nu, p.nv, p.n_angles),
            ProjInput::Ooc(o) => (o.nu, o.nv, o.n_angles),
        }
    }

    /// Logical size in bytes of the backing set.
    pub fn bytes(&self) -> u64 {
        match self {
            ProjInput::Ram(p) => p.bytes(),
            ProjInput::Ooc(o) => o.bytes(),
        }
    }

    /// True for the out-of-core arm.
    pub fn is_ooc(&self) -> bool {
        matches!(self, ProjInput::Ooc(_))
    }
}

// OOC stores are boxed: they are cold fat handles (paths, mutexed cache
// bookkeeping) next to the hot Ram variant.
#[derive(Debug)]
enum VolumeBacking {
    Ram(Volume),
    Ooc(Box<OocVolume>),
}

#[derive(Debug)]
enum ProjBacking {
    Ram(ProjectionSet),
    Ooc(Box<OocProjections>),
}

/// A [`Volume`] with an identity and a write-epoch, for the coordinator's
/// cross-iteration device residency cache (`coordinator::residency`).
///
/// Every mutable access goes through [`TrackedVolume::write`] (or
/// [`TrackedVolume::replace`] / [`TrackedVolume::write_ooc`]), which
/// bumps the epoch; a staged device copy is keyed by `(id, epoch)`, so
/// after any host-side write the stale device copy can never be reused —
/// it simply stops matching.
///
/// Since PR 5 the wrapper holds either an in-RAM [`Volume`] or an
/// out-of-core [`OocVolume`] behind one enum, so `ReconSession` and the
/// algorithms drive both through the same API. The RAM-only accessors
/// ([`TrackedVolume::get`]/[`write`](TrackedVolume::write)/
/// [`replace`](TrackedVolume::replace)/[`into_inner`](TrackedVolume::into_inner))
/// panic on an OOC backing — use [`TrackedVolume::as_input`] /
/// [`TrackedVolume::ooc`] there.
#[derive(Debug)]
pub struct TrackedVolume {
    backing: VolumeBacking,
    id: u64,
    epoch: u64,
}

impl TrackedVolume {
    /// Track a host-resident volume (fresh identity, epoch 0).
    pub fn new(vol: Volume) -> Self {
        Self { backing: VolumeBacking::Ram(vol), id: next_tracked_id(), epoch: 0 }
    }

    /// Track an out-of-core volume (streamed by the executors).
    pub fn new_ooc(vol: OocVolume) -> Self {
        Self { backing: VolumeBacking::Ooc(Box::new(vol)), id: next_tracked_id(), epoch: 0 }
    }

    /// True when the backing is an out-of-core store.
    pub fn is_ooc(&self) -> bool {
        matches!(self.backing, VolumeBacking::Ooc(_))
    }

    /// The executor-input view of whichever backing this wrapper holds.
    pub fn as_input(&self) -> VolumeInput<'_> {
        match &self.backing {
            VolumeBacking::Ram(v) => VolumeInput::Ram(v),
            VolumeBacking::Ooc(o) => VolumeInput::Ooc(o),
        }
    }

    /// Read access; does not change the epoch. Panics on an OOC backing.
    pub fn get(&self) -> &Volume {
        match &self.backing {
            VolumeBacking::Ram(v) => v,
            VolumeBacking::Ooc(_) => {
                panic!("TrackedVolume::get on an out-of-core volume; use as_input()/ooc()")
            }
        }
    }

    /// The OOC backing, if any. Read-only **by contract**: the store's
    /// mutators take `&self` (interior mutex), so writing through this
    /// handle compiles but bypasses the epoch — a `ReconSession` could
    /// then reuse a device copy it wrongly believes fresh. Mutate
    /// through [`TrackedVolume::write_ooc`] so the epoch records the
    /// write.
    pub fn ooc(&self) -> Option<&OocVolume> {
        match &self.backing {
            VolumeBacking::Ooc(o) => Some(o),
            VolumeBacking::Ram(_) => None,
        }
    }

    /// Mutable access; bumps the epoch (conservatively — even if the
    /// caller ends up not writing). Panics on an OOC backing.
    pub fn write(&mut self) -> &mut Volume {
        match &mut self.backing {
            VolumeBacking::Ram(v) => {
                self.epoch += 1;
                v
            }
            VolumeBacking::Ooc(_) => {
                panic!("TrackedVolume::write on an out-of-core volume; use write_ooc()")
            }
        }
    }

    /// Mutable access to an OOC backing, bumping the epoch; `None` on a
    /// RAM backing (the epoch is then untouched).
    pub fn write_ooc(&mut self) -> Option<&mut OocVolume> {
        match &mut self.backing {
            VolumeBacking::Ooc(o) => {
                self.epoch += 1;
                Some(o)
            }
            VolumeBacking::Ram(_) => None,
        }
    }

    /// Swap the wrapped volume for `vol`, returning the old one. Bumps
    /// the epoch (the identity stays: same logical buffer, new content).
    /// Panics on an OOC backing.
    pub fn replace(&mut self, vol: Volume) -> Volume {
        match &mut self.backing {
            VolumeBacking::Ram(v) => {
                self.epoch += 1;
                std::mem::replace(v, vol)
            }
            VolumeBacking::Ooc(_) => {
                panic!("TrackedVolume::replace on an out-of-core volume")
            }
        }
    }

    /// Unwrap the RAM backing. Panics on an OOC backing.
    pub fn into_inner(self) -> Volume {
        match self.backing {
            VolumeBacking::Ram(v) => v,
            VolumeBacking::Ooc(_) => {
                panic!("TrackedVolume::into_inner on an out-of-core volume")
            }
        }
    }

    /// Process-unique buffer identity (never reused).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Write counter; bumped by every mutable-access path.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

/// A [`ProjectionSet`] with an identity and a write-epoch; see
/// [`TrackedVolume`] (including the RAM-vs-OOC backing contract).
/// `ReconSession::forward` returns its output wrapped in one of these so
/// the backprojection can recognize chunks that are still
/// device-resident from the producing forward call.
#[derive(Debug)]
pub struct TrackedProjections {
    backing: ProjBacking,
    id: u64,
    epoch: u64,
}

impl TrackedProjections {
    /// Track a host-resident projection set (fresh identity, epoch 0).
    pub fn new(proj: ProjectionSet) -> Self {
        Self { backing: ProjBacking::Ram(proj), id: next_tracked_id(), epoch: 0 }
    }

    /// Track an out-of-core projection set (streamed by the executors).
    pub fn new_ooc(proj: OocProjections) -> Self {
        Self { backing: ProjBacking::Ooc(Box::new(proj)), id: next_tracked_id(), epoch: 0 }
    }

    /// True when the backing is an out-of-core store.
    pub fn is_ooc(&self) -> bool {
        matches!(self.backing, ProjBacking::Ooc(_))
    }

    /// The executor-input view of whichever backing this wrapper holds.
    pub fn as_input(&self) -> ProjInput<'_> {
        match &self.backing {
            ProjBacking::Ram(p) => ProjInput::Ram(p),
            ProjBacking::Ooc(o) => ProjInput::Ooc(o),
        }
    }

    /// Read access; does not change the epoch. Panics on an OOC backing.
    pub fn get(&self) -> &ProjectionSet {
        match &self.backing {
            ProjBacking::Ram(p) => p,
            ProjBacking::Ooc(_) => {
                panic!("TrackedProjections::get on an out-of-core set; use as_input()/ooc()")
            }
        }
    }

    /// The OOC backing, if any (read-only by contract; see
    /// [`TrackedVolume::ooc`]).
    pub fn ooc(&self) -> Option<&OocProjections> {
        match &self.backing {
            ProjBacking::Ooc(o) => Some(o),
            ProjBacking::Ram(_) => None,
        }
    }

    /// Mutable access; bumps the epoch. Panics on an OOC backing.
    pub fn write(&mut self) -> &mut ProjectionSet {
        match &mut self.backing {
            ProjBacking::Ram(p) => {
                self.epoch += 1;
                p
            }
            ProjBacking::Ooc(_) => {
                panic!("TrackedProjections::write on an out-of-core set; use write_ooc()")
            }
        }
    }

    /// Mutable access to an OOC backing, bumping the epoch; `None` on a
    /// RAM backing.
    pub fn write_ooc(&mut self) -> Option<&mut OocProjections> {
        match &mut self.backing {
            ProjBacking::Ooc(o) => {
                self.epoch += 1;
                Some(o)
            }
            ProjBacking::Ram(_) => None,
        }
    }

    /// Swap the wrapped set, returning the old one; bumps the epoch.
    /// Panics on an OOC backing.
    pub fn replace(&mut self, proj: ProjectionSet) -> ProjectionSet {
        match &mut self.backing {
            ProjBacking::Ram(p) => {
                self.epoch += 1;
                std::mem::replace(p, proj)
            }
            ProjBacking::Ooc(_) => {
                panic!("TrackedProjections::replace on an out-of-core set")
            }
        }
    }

    /// Unwrap the RAM backing. Panics on an OOC backing.
    pub fn into_inner(self) -> ProjectionSet {
        match self.backing {
            ProjBacking::Ram(p) => p,
            ProjBacking::Ooc(_) => {
                panic!("TrackedProjections::into_inner on an out-of-core set")
            }
        }
    }

    /// Process-unique buffer identity (never reused).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Write counter; bumped by every mutable-access path.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

/// A stack of 2-D projections (detector readings), one per angle.
#[derive(Clone, Debug, PartialEq)]
pub struct ProjectionSet {
    /// Detector columns (fastest-varying index).
    pub nu: usize,
    /// Detector rows.
    pub nv: usize,
    /// Number of angles (slowest-varying index).
    pub n_angles: usize,
    /// Detector readings, layout `data[(a*nv + v)*nu + u]`.
    pub data: Vec<f32>,
}

impl ProjectionSet {
    /// All-zero projection set of the given shape.
    pub fn zeros(nu: usize, nv: usize, n_angles: usize) -> Self {
        Self { nu, nv, n_angles, data: vec![0.0; nu * nv * n_angles] }
    }

    /// All-zero set shaped to a geometry's detector and angle list.
    pub fn zeros_like(g: &Geometry) -> Self {
        Self::zeros(g.n_det[0], g.n_det[1], g.n_angles())
    }

    /// Linear index of detector pixel `(iu, iv)` at angle `a`.
    #[inline(always)]
    pub fn idx(&self, iu: usize, iv: usize, a: usize) -> usize {
        (a * self.nv + iv) * self.nu + iu
    }

    /// Value at detector pixel `(iu, iv)`, angle `a`.
    #[inline(always)]
    pub fn at(&self, iu: usize, iv: usize, a: usize) -> f32 {
        self.data[self.idx(iu, iv, a)]
    }

    /// Mutable reference to detector pixel `(iu, iv)`, angle `a`.
    #[inline(always)]
    pub fn at_mut(&mut self, iu: usize, iv: usize, a: usize) -> &mut f32 {
        let i = self.idx(iu, iv, a);
        &mut self.data[i]
    }

    /// Storage size in bytes (f32 elements).
    pub fn bytes(&self) -> u64 {
        self.data.len() as u64 * 4
    }

    /// Borrow the contiguous angle chunk `[a0, a1)`.
    pub fn chunk(&self, a0: usize, a1: usize) -> &[f32] {
        let per = self.nu * self.nv;
        &self.data[a0 * per..a1 * per]
    }

    /// Mutably borrow the contiguous angle chunk `[a0, a1)`.
    pub fn chunk_mut(&mut self, a0: usize, a1: usize) -> &mut [f32] {
        let per = self.nu * self.nv;
        &mut self.data[a0 * per..a1 * per]
    }

    /// Borrow an angle chunk as a zero-copy kernel input (see
    /// [`ProjChunkView`]); replaces [`ProjectionSet::extract_chunk`] copies
    /// on the pipelined executor's staging path.
    pub fn chunk_view(&self, a0: usize, a1: usize) -> ProjChunkView<'_> {
        ProjChunkView { nu: self.nu, nv: self.nv, n_angles: a1 - a0, data: self.chunk(a0, a1) }
    }

    /// Borrow the whole set as a kernel-input view.
    pub fn as_view(&self) -> ProjChunkView<'_> {
        ProjChunkView { nu: self.nu, nv: self.nv, n_angles: self.n_angles, data: &self.data }
    }

    /// Copy an angle chunk into an owned projection set.
    pub fn extract_chunk(&self, a0: usize, a1: usize) -> ProjectionSet {
        ProjectionSet {
            nu: self.nu,
            nv: self.nv,
            n_angles: a1 - a0,
            data: self.chunk(a0, a1).to_vec(),
        }
    }

    /// Write an owned chunk back at angle offset `a0`.
    pub fn insert_chunk(&mut self, a0: usize, sub: &ProjectionSet) {
        assert_eq!(sub.nu, self.nu);
        assert_eq!(sub.nv, self.nv);
        self.chunk_mut(a0, a0 + sub.n_angles).copy_from_slice(&sub.data);
    }

    /// Extract a non-contiguous angle subset (OS-SART ordered subsets).
    pub fn extract_subset(&self, idxs: &[usize]) -> ProjectionSet {
        let per = self.nu * self.nv;
        let mut out = ProjectionSet::zeros(self.nu, self.nv, idxs.len());
        for (k, &a) in idxs.iter().enumerate() {
            out.data[k * per..(k + 1) * per].copy_from_slice(&self.data[a * per..(a + 1) * per]);
        }
        out
    }

    /// Accumulate (`+=`) another projection set of identical shape. This is
    /// the paper's "ultra-fast" accumulation step that merges per-slab
    /// partial projections.
    pub fn accumulate(&mut self, other: &ProjectionSet) {
        assert_eq!(self.data.len(), other.data.len(), "accumulate shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// `self += s * other` (AXPY), elementwise.
    pub fn add_scaled(&mut self, other: &ProjectionSet, s: f32) {
        assert_eq!(self.data.len(), other.data.len());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    /// Inner product in f64 accumulation.
    pub fn dot(&self, other: &ProjectionSet) -> f64 {
        assert_eq!(self.data.len(), other.data.len());
        self.data.iter().zip(&other.data).map(|(a, b)| *a as f64 * *b as f64).sum()
    }

    /// Euclidean norm in f64 accumulation.
    pub fn norm2(&self) -> f64 {
        self.data.iter().map(|v| *v as f64 * *v as f64).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn volume_indexing_is_z_slowest() {
        let v = Volume::from_fn(3, 4, 5, |x, y, z| (x + 10 * y + 100 * z) as f32);
        assert_eq!(v.at(2, 3, 4), 432.0);
        // slab of z=4 is the last contiguous plane
        let slab = v.slab(4, 5);
        assert_eq!(slab.len(), 12);
        assert_eq!(slab[0], 400.0);
        assert_eq!(slab[11], 432.0);
    }

    #[test]
    fn slab_roundtrip() {
        let v = Volume::from_fn(4, 4, 8, |x, y, z| (x * y * z) as f32);
        let slab = v.extract_slab(2, 5);
        assert_eq!(slab.nz, 3);
        let mut w = Volume::zeros(4, 4, 8);
        w.insert_slab(2, &slab);
        assert_eq!(w.at(3, 3, 4), v.at(3, 3, 4));
        assert_eq!(w.at(3, 3, 0), 0.0);
    }

    #[test]
    fn projection_chunk_roundtrip() {
        let mut p = ProjectionSet::zeros(5, 3, 7);
        for a in 0..7 {
            for iv in 0..3 {
                for iu in 0..5 {
                    *p.at_mut(iu, iv, a) = (a * 100 + iv * 10 + iu) as f32;
                }
            }
        }
        let c = p.extract_chunk(2, 4);
        assert_eq!(c.n_angles, 2);
        assert_eq!(c.at(4, 2, 0), 224.0);
        let mut q = ProjectionSet::zeros(5, 3, 7);
        q.insert_chunk(2, &c);
        assert_eq!(q.at(4, 2, 3), p.at(4, 2, 3));
        assert_eq!(q.at(4, 2, 5), 0.0);
    }

    #[test]
    fn slab_view_is_zero_copy_and_matches_extract() {
        let v = Volume::from_fn(4, 3, 8, |x, y, z| (x + 10 * y + 100 * z) as f32);
        let view = v.slab_view(2, 5);
        assert_eq!((view.nx, view.ny, view.nz), (4, 3, 3));
        // the view borrows the volume's own storage — no copy
        assert_eq!(view.data.as_ptr(), v.slab(2, 5).as_ptr());
        assert_eq!(view.data, &v.extract_slab(2, 5).data[..]);
        assert_eq!(view.to_volume(), v.extract_slab(2, 5));
        let full = v.as_view();
        assert_eq!(full.data.as_ptr(), v.data.as_ptr());
        assert_eq!(full.len(), v.len());
    }

    #[test]
    fn chunk_view_is_zero_copy_and_matches_extract() {
        let mut p = ProjectionSet::zeros(5, 3, 7);
        for (i, v) in p.data.iter_mut().enumerate() {
            *v = i as f32;
        }
        let view = p.chunk_view(2, 4);
        assert_eq!((view.nu, view.nv, view.n_angles), (5, 3, 2));
        assert_eq!(view.data.as_ptr(), p.chunk(2, 4).as_ptr());
        assert_eq!(view.data, &p.extract_chunk(2, 4).data[..]);
        assert_eq!(view.to_projections(), p.extract_chunk(2, 4));
        assert_eq!(p.as_view().len(), p.data.len());
    }

    #[test]
    fn tracked_wrappers_bump_epoch_on_every_write_path() {
        let mut tv = TrackedVolume::new(Volume::zeros(2, 2, 2));
        let id = tv.id();
        assert_eq!(tv.epoch(), 0);
        tv.write().data[0] = 1.0;
        assert_eq!(tv.epoch(), 1);
        let old = tv.replace(Volume::zeros(2, 2, 2));
        assert_eq!(old.data[0], 1.0);
        assert_eq!(tv.epoch(), 2);
        assert_eq!(tv.id(), id, "identity survives writes");
        assert_eq!(tv.into_inner().data.len(), 8);

        let mut tp = TrackedProjections::new(ProjectionSet::zeros(2, 2, 3));
        assert_eq!(tp.epoch(), 0);
        *tp.write().at_mut(0, 0, 0) = 2.0;
        assert_eq!(tp.epoch(), 1);
        assert_eq!(tp.get().at(0, 0, 0), 2.0);
    }

    #[test]
    fn tracked_ooc_backing_bumps_epoch_through_write_ooc() {
        let d = std::env::temp_dir()
            .join("tigre_tracked_ooc")
            .join(format!("{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        let ooc =
            OocVolume::from_volume(&d.join("x.raw"), &Volume::zeros(4, 4, 4), 2, 1 << 20).unwrap();
        let mut tv = TrackedVolume::new_ooc(ooc);
        assert!(tv.is_ooc());
        assert!(matches!(tv.as_input(), VolumeInput::Ooc(_)));
        assert_eq!(tv.epoch(), 0);
        tv.write_ooc().unwrap().store_slab(0, &[1.0; 16]).unwrap();
        assert_eq!(tv.epoch(), 1, "write_ooc must bump the epoch");
        assert_eq!(tv.ooc().unwrap().to_volume().unwrap().at(0, 0, 0), 1.0);

        let mut ram = TrackedVolume::new(Volume::zeros(2, 2, 2));
        assert!(ram.write_ooc().is_none());
        assert_eq!(ram.epoch(), 0, "write_ooc on RAM backing must not bump");
        assert!(matches!(ram.as_input(), VolumeInput::Ram(_)));
    }

    #[test]
    fn tracked_ids_are_unique() {
        let a = TrackedVolume::new(Volume::zeros(1, 1, 1));
        let b = TrackedVolume::new(Volume::zeros(1, 1, 1));
        let c = TrackedProjections::new(ProjectionSet::zeros(1, 1, 1));
        assert_ne!(a.id(), b.id());
        assert_ne!(b.id(), c.id());
    }

    #[test]
    fn subset_extraction() {
        let mut p = ProjectionSet::zeros(2, 2, 5);
        for a in 0..5 {
            *p.at_mut(0, 0, a) = a as f32;
        }
        let s = p.extract_subset(&[4, 1]);
        assert_eq!(s.n_angles, 2);
        assert_eq!(s.at(0, 0, 0), 4.0);
        assert_eq!(s.at(0, 0, 1), 1.0);
    }

    #[test]
    fn accumulate_adds() {
        let mut a = ProjectionSet::zeros(2, 2, 1);
        let mut b = ProjectionSet::zeros(2, 2, 1);
        *a.at_mut(0, 0, 0) = 1.0;
        *b.at_mut(0, 0, 0) = 2.5;
        a.accumulate(&b);
        assert_eq!(a.at(0, 0, 0), 3.5);
    }

    #[test]
    fn math_helpers() {
        let mut v = Volume::zeros(2, 1, 1);
        v.data = vec![3.0, 4.0];
        assert_eq!(v.norm2(), 5.0);
        let w = Volume { nx: 2, ny: 1, nz: 1, data: vec![1.0, 2.0] };
        assert_eq!(v.dot(&w), 11.0);
        v.add_scaled(&w, 2.0);
        assert_eq!(v.data, vec![5.0, 8.0]);
        v.clamp_min(6.0);
        assert_eq!(v.data, vec![6.0, 8.0]);
        v.scale(0.5);
        assert_eq!(v.data, vec![3.0, 4.0]);
    }
}
