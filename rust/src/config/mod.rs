//! CLI: subcommand dispatch for the `tigre` binary (the L3 leader
//! entrypoint), plus the run-configuration plumbing.

// The CLI reports host wall-clock alongside simulated time by design;
// nothing here feeds the DES or the planner (see rust/clippy.toml).
#![allow(clippy::disallowed_methods)]

use std::path::{Path, PathBuf};

use crate::algorithms::{self, ReconOpts};
use crate::coordinator::{Backend, ExecMode, MultiGpu, ProjectorChoice};
use crate::geometry::Geometry;
use crate::kernels::filtering::Window;
use crate::phantom;
use crate::util::cli::Command;
use crate::util::units::{fmt_bytes, parse_bytes};
use crate::volume::Volume;

/// Build the execution context from common CLI options.
fn ctx_from(args: &crate::util::cli::Args) -> anyhow::Result<MultiGpu> {
    let gpus = args.get_usize("gpus")?.unwrap_or(1);
    let mut ctx = MultiGpu::gtx1080ti(gpus);
    if let Some(mem) = args.get("device-mem") {
        ctx = ctx.with_device_mem(parse_bytes(mem)?);
    }
    if let Some(dir) = args.get("artifacts") {
        ctx = ctx.with_backend(Backend::Pjrt {
            artifacts_dir: PathBuf::from(dir),
            weight: crate::kernels::BackprojWeight::Fdk,
            threads: crate::kernels::kernel_threads(),
        });
    }
    // --projector overrides whatever backend the flags above selected
    // (siddon/joseph force the native ray-driven kernels; sparse swaps in
    // the precomputed CSR system-matrix backend)
    if let Some(p) = args.get("projector") {
        ctx = ctx.with_projector(ProjectorChoice::parse(p)?);
    }
    Ok(ctx)
}

fn make_phantom(kind: &str, nx: usize, ny: usize, nz: usize) -> anyhow::Result<Volume> {
    Ok(match kind {
        "shepp-logan" => phantom::rasterize(&phantom::shepp_logan_ellipsoids(), nx, ny, nz),
        "bean" => phantom::bean(nx, ny, nz),
        "fossil" => phantom::fossil(nx, ny, nz, 7),
        "cube" => {
            anyhow::ensure!(nx == ny && ny == nz, "cube phantom needs a cubic volume");
            phantom::cube(nx, 0.5, 1.0)
        }
        other => anyhow::bail!("unknown phantom '{other}' (shepp-logan|bean|fossil|cube)"),
    })
}

/// CLI entrypoint; dispatches `tigre <subcommand> ...`.
pub fn cli_main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let sub = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    match sub {
        "info" => info(rest),
        "reconstruct" => reconstruct(rest),
        "project" => project(rest),
        "sweep" => sweep(rest),
        "selftest" => selftest(rest),
        "help" | "--help" | "-h" => {
            println!("{}", help_text());
            Ok(())
        }
        other => {
            anyhow::bail!("unknown subcommand '{other}'\n{}", help_text());
        }
    }
}

fn help_text() -> String {
    "tigre — multi-GPU (simulated) iterative tomographic reconstruction\n\
     subcommands:\n\
     \x20 info         show node, device and artifact information\n\
     \x20 reconstruct  phantom → projections → reconstruction\n\
     \x20 project      forward/backproject a phantom, report timings\n\
     \x20 sweep        Fig.7-style FP/BP timing sweep over N × GPUs\n\
     \x20 selftest     verify split == unsplit numerics on this install\n\
     run `tigre <subcommand> --help-cmd` for options"
        .to_string()
}

fn info(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("info", "show node, device and artifact info")
        .opt("gpus", "number of simulated GPUs", Some("2"))
        .opt("device-mem", "per-device memory (e.g. 11GiB)", None)
        .opt("artifacts", "artifacts directory", Some("artifacts"))
        .flag("help-cmd", "show options");
    let args = cmd.parse(rest)?;
    if args.flag("help-cmd") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let ctx = ctx_from(&args)?;
    println!("node: {} × {}", ctx.n_gpus, ctx.spec.name);
    println!("device memory: {}", fmt_bytes(ctx.spec.mem_bytes));
    println!(
        "PCIe: pageable {:.1} GB/s, pinned {:.1} GB/s",
        ctx.cost.pcie_pageable_bps / 1e9,
        ctx.cost.pcie_pinned_bps / 1e9
    );
    let dir = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    match crate::runtime::Manifest::load(&dir) {
        Ok(m) if !m.entries.is_empty() => {
            println!("artifacts ({}):", dir.display());
            for e in &m.entries {
                println!(
                    "  {} [{}³ vox, {}² det, {} angles]",
                    e.name, e.nx, e.nu, e.angles
                );
            }
        }
        _ => println!("artifacts: none (run `make artifacts`)"),
    }
    // paper §4 size limits for this device
    println!(
        "max N (paper §4 formulas): FP {}, BP {}, relaxed {}",
        crate::coordinator::splitter::max_n_forward(ctx.spec.mem_bytes),
        crate::coordinator::splitter::max_n_backward(ctx.spec.mem_bytes),
        crate::coordinator::splitter::max_n_relaxed(ctx.spec.mem_bytes),
    );
    Ok(())
}

fn reconstruct(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("reconstruct", "phantom → projections → reconstruction")
        .opt("algo", "fdk|sirt|sart|ossart|cgls|fista|asdpocs|landweber|mlem", Some("cgls"))
        .opt("phantom", "shepp-logan|bean|fossil|cube", Some("shepp-logan"))
        .opt("n", "volume size (n³)", Some("32"))
        .opt("angles", "number of projection angles", Some("32"))
        .opt("iters", "iterations", Some("10"))
        .opt("subset", "OS-SART subset size", Some("8"))
        .opt("gpus", "number of simulated GPUs", Some("2"))
        .opt("device-mem", "per-device memory (e.g. 256MiB)", None)
        .opt("artifacts", "use PJRT artifacts from this dir", None)
        .opt("projector", "siddon|joseph|sparse", None)
        .opt("out", "save volume to this .raw path", None)
        .opt("slice", "save central slice PGM to this path", None)
        .opt("checkpoint", "checkpoint/resume directory (iterative algorithms)", None)
        .opt("checkpoint-every", "iterations between checkpoints", Some("1"))
        .opt("div-tolerance", "residual growth factor counted as divergence", Some("1.25"))
        .opt("max-backoffs", "step backoffs before a run fails as diverged", Some("4"))
        .flag("verbose", "per-iteration logging")
        .flag("help-cmd", "show options");
    let args = cmd.parse(rest)?;
    if args.flag("help-cmd") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let n = args.get_usize("n")?.unwrap();
    let n_angles = args.get_usize("angles")?.unwrap();
    let iters = args.get_usize("iters")?.unwrap();
    let ctx = ctx_from(&args)?;
    let g = Geometry::cone_beam(n, n_angles);
    let truth = make_phantom(args.get("phantom").unwrap(), n, n, n)?;

    crate::log_info!("forward-projecting {n}³ phantom over {n_angles} angles");
    let (p, fp_stats) = ctx.forward(&g, Some(&truth), ExecMode::Full)?;
    let p = p.unwrap();
    crate::log_info!(
        "projection done: sim {:.3}s, splits/device {}",
        fp_stats.makespan_s,
        fp_stats.splits_per_device
    );

    // a populated checkpoint dir makes this run resume where it stopped
    let checkpoint = match args.get("checkpoint") {
        Some(dir) => {
            let every = args.get_usize("checkpoint-every")?.unwrap();
            Some(crate::coordinator::CheckpointConfig::new(dir, every))
        }
        None => None,
    };
    let opts = ReconOpts {
        iterations: iters,
        verbose: args.flag("verbose"),
        checkpoint,
        divergence_tolerance: args.get_f64("div-tolerance")?.unwrap(),
        max_step_backoffs: args.get_usize("max-backoffs")?.unwrap(),
        projector: args.get("projector").map(ProjectorChoice::parse).transpose()?,
        ..Default::default()
    };
    let algo = args.get("algo").unwrap();
    let t0 = std::time::Instant::now();
    let result = match algo {
        "fdk" => algorithms::fdk(&ctx, &g, &p, Window::Hann)?,
        "sirt" => algorithms::sirt(&ctx, &g, &p, &opts)?,
        "sart" => algorithms::sart(&ctx, &g, &p, &opts)?,
        "ossart" => {
            let subset = args.get_usize("subset")?.unwrap();
            algorithms::os_sart(&ctx, &g, &p, subset, &opts)?
        }
        "cgls" => algorithms::cgls(&ctx, &g, &p, &opts)?,
        "landweber" => algorithms::landweber(&ctx, &g, &p, &opts)?,
        "mlem" => algorithms::mlem(&ctx, &g, &p, &opts)?,
        "fista" => algorithms::fista(
            &ctx,
            &g,
            &p,
            &algorithms::fista::FistaOpts { common: opts, ..Default::default() },
        )?,
        "asdpocs" => algorithms::asd_pocs(
            &ctx,
            &g,
            &p,
            &algorithms::asd_pocs::AsdPocsOpts { common: opts, ..Default::default() },
        )?,
        other => anyhow::bail!("unknown algorithm '{other}'"),
    };
    let wall = t0.elapsed().as_secs_f64();

    println!("algorithm:        {algo}");
    println!("problem:          {n}³ voxels, {n_angles} angles, {} GPUs", ctx.n_gpus);
    println!("host wall-clock:  {wall:.2}s (CPU kernels)");
    println!("simulated time:   {:.3}s (paper-testbed estimate)", result.sim_time_s);
    println!("peak device mem:  {}", fmt_bytes(result.peak_device_bytes));
    println!("RMSE vs phantom:  {:.5}", crate::metrics::rmse(&truth, &result.volume));
    println!("PSNR vs phantom:  {:.2} dB", crate::metrics::psnr(&truth, &result.volume));
    if let Some(res) = result.residuals.last() {
        println!("final residual:   {res:.4e}");
    }
    if result.backoffs > 0 {
        println!("step backoffs:    {} (divergence guard fired)", result.backoffs);
    }
    if let Some(out) = args.get("out") {
        crate::io::save_volume(Path::new(out), &result.volume)?;
        println!("volume saved to {out}");
    }
    if let Some(slice) = args.get("slice") {
        crate::io::save_slice_pgm(Path::new(slice), &result.volume, n / 2, None)?;
        println!("central slice saved to {slice}");
    }
    Ok(())
}

fn project(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("project", "forward+backproject a phantom, report timings")
        .opt("n", "volume size (n³)", Some("64"))
        .opt("angles", "number of angles", Some("64"))
        .opt("gpus", "number of simulated GPUs", Some("2"))
        .opt("device-mem", "per-device memory", None)
        .opt("artifacts", "use PJRT artifacts from this dir", None)
        .opt("projector", "siddon|joseph|sparse", None)
        .flag("sim-only", "skip real compute (arbitrary N)")
        .flag("help-cmd", "show options");
    let args = cmd.parse(rest)?;
    if args.flag("help-cmd") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let n = args.get_usize("n")?.unwrap();
    let n_angles = args.get_usize("angles")?.unwrap();
    let ctx = ctx_from(&args)?;
    let g = Geometry::cone_beam(n, n_angles);

    if args.flag("sim-only") {
        let (_, fp) = ctx.forward(&g, None, ExecMode::SimOnly)?;
        let (_, bp) = ctx.backward(&g, None, ExecMode::SimOnly)?;
        print_op("forward", &fp);
        print_op("backward", &bp);
        if matches!(ctx.backend, Backend::Sparse { .. }) {
            // Crossover prediction (ISSUE 10): the first SimOnly pass
            // above charged the CSR builds (cold shards); a second pass
            // is warm, and a ray-driven clone gives the baseline.
            let (_, fp_warm) = ctx.forward(&g, None, ExecMode::SimOnly)?;
            let (_, bp_warm) = ctx.backward(&g, None, ExecMode::SimOnly)?;
            let ray_ctx = ctx.clone().with_projector(ProjectorChoice::Siddon);
            let (_, ray_fp) = ray_ctx.forward(&g, None, ExecMode::SimOnly)?;
            let (_, ray_bp) = ray_ctx.backward(&g, None, ExecMode::SimOnly)?;
            let ray = ray_fp.makespan_s + ray_bp.makespan_s;
            let warm = fp_warm.makespan_s + bp_warm.makespan_s;
            let setup = (fp.makespan_s + bp.makespan_s - warm).max(0.0);
            match ctx.cost.sparse_crossover_iters(ray, warm, setup) {
                Some(k) => println!(
                    "sparse crossover:  ~{k:.1} iterations \
                     (ray {ray:.4}s/iter vs sparse {warm:.4}s/iter + {setup:.4}s setup)"
                ),
                None => println!(
                    "sparse crossover:  never (sparse iteration {warm:.4}s \
                     not faster than ray-driven {ray:.4}s)"
                ),
            }
        }
    } else {
        let truth = phantom::shepp_logan(n);
        let t0 = std::time::Instant::now();
        let (p, fp) = ctx.forward(&g, Some(&truth), ExecMode::Full)?;
        let fp_wall = t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        let (_, bp) = ctx.backward(&g, Some(&p.unwrap()), ExecMode::Full)?;
        let bp_wall = t0.elapsed().as_secs_f64();
        print_op("forward", &fp);
        println!("  host wall-clock: {fp_wall:.3}s");
        print_op("backward", &bp);
        println!("  host wall-clock: {bp_wall:.3}s");
    }
    Ok(())
}

fn print_op(name: &str, stats: &crate::coordinator::OpStats) {
    let (c, p, m, i) = stats.breakdown.fractions();
    println!("{name}:");
    println!("  simulated time:  {:.4}s", stats.makespan_s);
    println!("  splits/device:   {}", stats.splits_per_device);
    println!("  pinned:          {}", stats.pinned);
    println!("  peak device mem: {}", fmt_bytes(stats.peak_device_bytes));
    println!(
        "  breakdown:       {:.0}% compute, {:.0}% pin, {:.0}% mem, {:.0}% idle",
        c * 100.0,
        p * 100.0,
        m * 100.0,
        i * 100.0
    );
    let r = &stats.residency;
    if r.hits + r.misses > 0 {
        println!(
            "  residency:       {} hits / {} misses, {} B saved ({:.2}ms transfer)",
            r.hits,
            r.misses,
            r.bytes_saved,
            r.transfer_saved_s * 1e3
        );
    }
    let d = &stats.degradation;
    if !d.is_clean() {
        println!(
            "  degradation:     {} evict, {} refine, {} spill, {} hang-retry, \
             {} watchdog-lost, {} slow",
            d.evictions,
            d.refinements,
            d.spills,
            d.hang_retries,
            d.watchdog_escalations,
            d.slow_units
        );
        for ev in &d.events {
            println!("    - {ev}");
        }
    }
}

fn sweep(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("sweep", "Fig.7-style FP/BP timing sweep")
        .opt("sizes", "comma-separated N list", Some("128,256,512,1024"))
        .opt("gpus", "comma-separated GPU counts", Some("1,2,3,4"))
        .opt("csv", "save results CSV here", None)
        .flag("help-cmd", "show options");
    let args = cmd.parse(rest)?;
    if args.flag("help-cmd") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let sizes = args.get_usize_list("sizes")?.unwrap();
    let gpus = args.get_usize_list("gpus")?.unwrap();
    let cells = crate::bench::fig7_sweep(&sizes, &gpus);
    println!("== forward projection (Fig. 7 analogue) ==");
    println!("{}", crate::bench::fig7_table(&cells, true));
    println!("== backprojection (Fig. 7 analogue) ==");
    println!("{}", crate::bench::fig7_table(&cells, false));
    println!("== % of 1-GPU time (Fig. 8 analogue) — forward ==");
    println!("{}", crate::bench::fig8_table(&cells, true));
    if let Some(csv) = args.get("csv") {
        crate::bench::save_sweep_csv(Path::new(csv), &cells)?;
        println!("csv saved to {csv}");
    }
    Ok(())
}

fn selftest(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("selftest", "verify split == unsplit numerics")
        .flag("help-cmd", "show options");
    let args = cmd.parse(rest)?;
    if args.flag("help-cmd") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let n = 20;
    let g = Geometry::cone_beam(n, 12);
    let truth = phantom::shepp_logan(n);
    let reference = crate::kernels::forward(&g, &truth, crate::kernels::Projector::Siddon, 2);
    let plane = (n * n * 4) as u64;
    let mem = 7 * plane + 3 * 12 * g.single_proj_bytes();
    for gpus in [1, 2, 3] {
        let ctx = MultiGpu::gtx1080ti(gpus).with_device_mem(mem);
        let (p, stats) = ctx.forward(&g, Some(&truth), ExecMode::Full)?;
        let p = p.unwrap();
        let max_err = reference
            .data
            .iter()
            .zip(&p.data)
            .map(|(a, b)| (a - b).abs() / (1.0 + a.abs()))
            .fold(0.0f32, f32::max);
        anyhow::ensure!(max_err < 2e-3, "split mismatch on {gpus} GPUs: {max_err}");
        println!(
            "gpus={gpus}: split FP matches reference (max rel err {max_err:.2e}, \
             {} splits/device) OK",
            stats.splits_per_device
        );
    }
    println!("selftest OK");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phantom_factory_kinds() {
        assert!(make_phantom("shepp-logan", 8, 8, 8).is_ok());
        assert!(make_phantom("bean", 8, 8, 8).is_ok());
        assert!(make_phantom("fossil", 8, 8, 8).is_ok());
        assert!(make_phantom("cube", 8, 8, 8).is_ok());
        assert!(make_phantom("cube", 8, 8, 9).is_err());
        assert!(make_phantom("nope", 8, 8, 8).is_err());
    }

    #[test]
    fn help_mentions_all_subcommands() {
        let h = help_text();
        for s in ["info", "reconstruct", "project", "sweep", "selftest"] {
            assert!(h.contains(s), "help missing {s}");
        }
    }
}
