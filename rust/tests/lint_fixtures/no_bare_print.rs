// Seeded violation for the `no-bare-print` lint: checked under the
// pretend path rust/src/metrics/fixture.rs. Never compiled.

pub fn chatty(x: f32) {
    println!("progress: {x}");
    eprintln!("warning: {x}");
}
