//! End-to-end driver (DESIGN.md "End-to-end validation"): runs the full
//! system on a real small workload — phantom → projections with noise →
//! multi-GPU iterative reconstruction (real kernels through the full
//! coordinator, PJRT artifacts when available) — then sweeps the paper's
//! headline scaling experiment on the device model and reports every
//! headline metric.
//!
//! Run with: `cargo run --release --example scaling`

use tigre::algorithms::{self, ReconOpts};
use tigre::bench;
use tigre::coordinator::{Backend, ExecMode, MultiGpu};
use tigre::geometry::Geometry;
use tigre::metrics;
use tigre::phantom;

fn main() -> anyhow::Result<()> {
    // ---------- part 1: real end-to-end workload ----------
    // 32³ volume, 32 angles; devices shrunk so every operator splits.
    let n = 32;
    let n_angles = 32;
    let g = Geometry::cone_beam(n, n_angles);
    let truth = phantom::shepp_logan(n);
    let plane = (n * n * 4) as u64;
    // scale kernel chunk sizes down with the miniature problem so the
    // devices really do split the image (see coffee_bean.rs)
    let fp_chunk = 3u64;
    let bp_chunk = 4u64;
    let mem = 12 * plane + (3 * fp_chunk).max(2 * bp_chunk) * g.single_proj_bytes();

    // PJRT artifacts if built (make artifacts), native kernels otherwise.
    let artifacts = std::path::PathBuf::from("artifacts");
    let has_artifacts = tigre::runtime::Manifest::load(&artifacts)
        .map(|m| !m.entries.is_empty())
        .unwrap_or(false);
    let mut node = MultiGpu::gtx1080ti(2).with_device_mem(mem);
    node.split.fp_chunk = fp_chunk as usize;
    node.split.bp_chunk = bp_chunk as usize;
    if has_artifacts {
        node = node.with_backend(Backend::Pjrt {
            artifacts_dir: artifacts,
            weight: tigre::kernels::BackprojWeight::Fdk,
            threads: 2,
        });
        println!(
            "kernel backend: PJRT artifacts (AOT-compiled Pallas/JAX; \
             native fallback for slab shapes outside the manifest)"
        );
    } else {
        println!("kernel backend: native rust (run `make artifacts` for PJRT)");
    }

    let t0 = std::time::Instant::now();
    let (proj, fp) = node.forward(&g, Some(&truth), ExecMode::Full)?;
    let mut proj = proj.unwrap();
    let mut rng = tigre::util::pcg::Pcg32::new(4);
    let peak = proj.data.iter().cloned().fold(f32::MIN, f32::max);
    for v in &mut proj.data {
        *v += 0.01 * peak * rng.normal() as f32;
    }
    let recon = algorithms::cgls(
        &node,
        &g,
        &proj,
        &ReconOpts { iterations: 12, ..Default::default() },
    )?;
    let wall = t0.elapsed().as_secs_f64();

    println!("== end-to-end run ==");
    println!(
        "volume {n}³ on 2 devices of {} ({} splits/device): device RAM bound held: {}",
        tigre::util::units::fmt_bytes(mem),
        fp.splits_per_device,
        recon.peak_device_bytes <= mem
    );
    println!("CGLS-12: RMSE {:.5}, PSNR {:.2} dB, host wall {wall:.1}s, sim {:.2}s",
        metrics::rmse(&truth, &recon.volume),
        metrics::psnr(&truth, &recon.volume),
        recon.sim_time_s,
    );
    let mut residual_cols: Vec<Vec<f64>> = vec![
        (1..=recon.residuals.len()).map(|i| i as f64).collect(),
        recon.residuals.clone(),
    ];
    residual_cols[1].iter_mut().for_each(|v| *v = *v);
    tigre::io::save_csv(
        std::path::Path::new("results/scaling_convergence.csv"),
        &["iteration", "residual"],
        &residual_cols,
    )?;
    println!("convergence trace: results/scaling_convergence.csv");

    // ---------- part 2: the headline scaling sweep (device model) ----------
    println!("\n== scaling sweep (Fig. 7/8 shape, simulated 1080 Ti node) ==");
    let cells = bench::fig7_sweep(&[256, 512, 1024, 2048], &[1, 2, 3, 4]);
    println!("{}", bench::fig7_table(&cells, true));
    println!("{}", bench::fig8_table(&cells, true));

    // headline metrics
    let b1 = cells.iter().find(|c| c.n == 2048 && c.gpus == 1).unwrap();
    let b4 = cells.iter().find(|c| c.n == 2048 && c.gpus == 4).unwrap();
    println!(
        "headline: N=2048 FP speedup ×{:.2} on 4 GPUs (theory ×4); \
         device memory never exceeded: yes (asserted per run)",
        b1.fp_s / b4.fp_s
    );
    println!(
        "arbitrarily-large support: N=3072 volume is {} vs 11 GiB devices — plans with {} splits",
        tigre::util::units::fmt_bytes(Geometry::cone_beam(3072, 8).volume_bytes()),
        bench::sweep_cell(3072, 2)?.bp_splits
    );
    Ok(())
}
