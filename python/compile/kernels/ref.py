"""Pure-jnp oracles for the Pallas kernels.

These are the correctness reference: pytest asserts the Pallas kernels
(`projector.py`, `backprojector.py`) match these to float tolerance, and
the rust integration tests compare the AOT artifacts against the native
rust kernels. The math mirrors `rust/src/kernels/{joseph,voxel_backproj}.rs`.
"""

import jax
import jax.numpy as jnp

from . import geometry as geo


def forward_ref(vol, params, angles, nu, nv, step_frac=0.5):
    """Interpolated (Joseph-style) cone-beam forward projection.

    vol: (nz, ny, nx) f32; returns (A, nv, nu) f32.
    """
    nz, ny, nx = vol.shape
    lo, hi = geo.volume_bbox(params, nx, ny, nz)
    n_steps = geo.fp_n_steps(nx, ny, nz, step_frac)

    def one_angle(theta):
        src = geo.source_pos(params, theta)  # (3,)
        pix = geo.detector_pixels(params, theta, nu, nv)  # (nv, nu, 3)
        tmin, tmax = geo.clip_ray_to_box(src, pix, lo, hi)  # (nv, nu)
        hit = tmax > tmin
        span = jnp.where(hit, tmax - tmin, 0.0)
        d = pix - src  # (nv, nu, 3)
        length = jnp.sqrt(jnp.sum(d * d, axis=-1))  # (nv, nu)
        dt = span / n_steps
        seg = (dt * length).astype(vol.dtype)  # (nv, nu)
        # midpoint-rule samples: t = tmin + (i + 0.5) dt
        idx = jnp.arange(n_steps, dtype=vol.dtype) + 0.5  # (S,)
        t = tmin[..., None] + idx * dt[..., None]  # (nv, nu, S)
        pts = src + t[..., None] * d[..., None, :]  # (nv, nu, S, 3)
        samples = geo.trilinear(vol, params, lo, pts)  # (nv, nu, S)
        return jnp.sum(samples, axis=-1) * seg

    return jnp.stack([one_angle(t) for t in angles], axis=0)


def backward_ref(proj, params, angles, nx, ny, nz):
    """Voxel-driven FDK-weighted cone-beam backprojection.

    proj: (A, nv, nu) f32; returns (nz, ny, nx) f32.
    """
    a_count, nv, nu = proj.shape
    lo, _ = geo.volume_bbox(params, nx, ny, nz)
    # voxel centre world coordinates
    xs = lo[0] + (jnp.arange(nx) + 0.5) * params[geo.DX]
    ys = lo[1] + (jnp.arange(ny) + 0.5) * params[geo.DY]
    zs = lo[2] + (jnp.arange(nz) + 0.5) * params[geo.DZ]
    px = xs[None, None, :]
    py = ys[None, :, None]
    pz = zs[:, None, None]

    dsd = params[geo.DSD]
    dso = params[geo.DSO]

    def one_angle(carry, inputs):
        theta, pslice = inputs
        s, c = jnp.sin(theta), jnp.cos(theta)
        rx = px * c + py * s  # broadcast -> (1, ny, nx)
        ry = -px * s + py * c
        depth = dso - rx  # (1, ny, nx)
        t = dsd / jnp.maximum(depth, 1e-9)
        u = t * ry - params[geo.OFF_U]
        v = t * pz - params[geo.OFF_V]  # (nz, ny, nx)
        fu = u / params[geo.DU] + nu / 2.0 - 0.5
        fv = v / params[geo.DV] + nv / 2.0 - 0.5
        fu_b = jnp.broadcast_to(fu, (nz, ny, nx))
        fv_b = jnp.broadcast_to(fv, (nz, ny, nx))
        sample = bilinear(pslice, fu_b, fv_b)
        w = (dso / jnp.maximum(depth, 1e-9)) ** 2
        contrib = jnp.where(depth > 1e-9, w * sample, 0.0)
        return carry + contrib.astype(carry.dtype), None

    init = jnp.zeros((nz, ny, nx), dtype=proj.dtype)
    out, _ = jax.lax.scan(one_angle, init, (angles, proj))
    return out


def bilinear(img, fu, fv):
    """Bilinear fetch from img (nv, nu) at fractional pixels (fu, fv);
    zero outside the half-pixel border (TIGRE boundary handling)."""
    nv, nu = img.shape
    inside = (fu > -0.5) & (fv > -0.5) & (fu < nu - 0.5) & (fv < nv - 0.5)
    u0 = jnp.floor(fu)
    v0 = jnp.floor(fv)
    wu = (fu - u0).astype(img.dtype)
    wv = (fv - v0).astype(img.dtype)

    def cl(i, n):
        return jnp.clip(i, 0, n - 1).astype(jnp.int32)

    u0i, u1i = cl(u0, nu), cl(u0 + 1, nu)
    v0i, v1i = cl(v0, nv), cl(v0 + 1, nv)
    flat = img.reshape(-1)

    def at(vi, ui):
        return flat[vi * nu + ui]

    p00 = at(v0i, u0i)
    p10 = at(v0i, u1i)
    p01 = at(v1i, u0i)
    p11 = at(v1i, u1i)
    c0 = p00 + (p10 - p00) * wu
    c1 = p01 + (p11 - p01) * wu
    out = c0 + (c1 - c0) * wv
    return jnp.where(inside, out, 0.0)


def default_params(n, nu=None, nv=None):
    """The `Geometry::cone_beam(n, ...)` scaling as a params vector:
    dso = 3n, dsd = 4.5n, voxel pitch 1, detector covers 1.6x the
    magnified footprint. Mirrors rust/src/geometry/mod.rs."""
    nu = nu or n
    nv = nv or n
    dso = 3.0 * n
    dsd = 4.5 * n
    mag = dsd / dso
    fov = n * mag * 1.6
    du = fov / nu
    dv = fov / nv
    return jnp.array(
        [dsd, dso, 1.0, 1.0, 1.0, du, dv, 0.0, 0.0, 0.0, 0.0, 0.0],
        dtype=jnp.float32,
    )
