//! Degradation bookkeeping for the graceful-degradation layer (ISSUE 8).
//!
//! Every rung of the memory-pressure ladder (evict → refine → spill),
//! every watchdog event (hang retry, escalation, slow real unit) and
//! every numerical-health intervention records itself in a shared
//! [`DegradeLog`]. The executor drains the log into
//! [`OpStats::degradation`](super::OpStats) after each operator call, so
//! tests and the CLI can pin *which* degradation path a run took — the
//! acceptance criterion for bit-identical completion under pressure.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// One recorded degradation step.
#[derive(Clone, Debug, PartialEq)]
pub enum DegradeEvent {
    /// Residency-cache entries were evicted to relieve pressure
    /// (rung 1 of the ladder).
    Evicted {
        /// Device whose allocation failed.
        device: usize,
        /// Cache entries dropped.
        entries: usize,
    },
    /// The plan was refined to smaller units (rung 2).
    Refined {
        /// Device whose allocation failed.
        device: usize,
        /// Human-readable before → after description from the splitter.
        detail: String,
    },
    /// The op fell back to an OOC-spill style replan (rung 3).
    Spilled {
        /// Device whose allocation failed.
        device: usize,
        /// Host budget / slab description.
        detail: String,
    },
    /// A hung unit was killed at its watchdog deadline and retried.
    HangRetry {
        /// Device the unit ran on.
        device: usize,
        /// Consecutive hangs observed for this unit.
        times: usize,
    },
    /// Hang retries were exhausted; the device was escalated to lost
    /// and its units replanned onto survivors (PR-7 machinery).
    WatchdogEscalated {
        /// Device marked lost.
        device: usize,
    },
    /// A real unit overran its watchdog deadline but completed (real
    /// kernels are synchronous and cannot be cancelled — record only).
    SlowUnit {
        /// Device the unit ran on.
        device: usize,
        /// Wall-clock seconds the unit actually took.
        elapsed_s: f64,
        /// The deadline it overran.
        deadline_s: f64,
    },
    /// An iterative algorithm backed its step size off after detecting
    /// residual growth.
    StepBackoff {
        /// Algorithm name.
        algorithm: &'static str,
        /// Iteration at which the guard fired.
        iteration: usize,
    },
}

impl std::fmt::Display for DegradeEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DegradeEvent::Evicted { device, entries } => {
                write!(f, "evict d{device} ({entries} entries)")
            }
            DegradeEvent::Refined { device, detail } => write!(f, "refine d{device}: {detail}"),
            DegradeEvent::Spilled { device, detail } => write!(f, "spill d{device}: {detail}"),
            DegradeEvent::HangRetry { device, times } => {
                write!(f, "hang retry d{device} (x{times})")
            }
            DegradeEvent::WatchdogEscalated { device } => write!(f, "watchdog lost d{device}"),
            DegradeEvent::SlowUnit { device, elapsed_s, deadline_s } => {
                write!(f, "slow unit d{device} ({elapsed_s:.3}s > {deadline_s:.3}s)")
            }
            DegradeEvent::StepBackoff { algorithm, iteration } => {
                write!(f, "{algorithm} step backoff @ it {iteration}")
            }
        }
    }
}

/// Drained per-op summary of degradation activity, carried on
/// [`OpStats`](super::OpStats).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DegradeStats {
    /// Residency evictions forced by memory pressure.
    pub evictions: usize,
    /// Plan refinements (rung 2 replans).
    pub refinements: usize,
    /// OOC-spill fallbacks (rung 3).
    pub spills: usize,
    /// Hung-unit retries.
    pub hang_retries: usize,
    /// Watchdog escalations to device loss.
    pub watchdog_escalations: usize,
    /// Record-only slow real units.
    pub slow_units: usize,
    /// Ordered human-readable event trail.
    pub events: Vec<String>,
}

impl DegradeStats {
    /// True when no degradation path was taken.
    pub fn is_clean(&self) -> bool {
        self.events.is_empty()
    }
}

/// Shared, thread-safe degradation recorder. Cloned handles (via `Arc`)
/// are held by the executor, the pipeline workers and the algorithms;
/// [`DegradeLog::drain`] moves everything recorded since the last drain
/// into a [`DegradeStats`].
#[derive(Debug, Default)]
pub struct DegradeLog {
    evictions: AtomicUsize,
    refinements: AtomicUsize,
    spills: AtomicUsize,
    hang_retries: AtomicUsize,
    watchdog_escalations: AtomicUsize,
    slow_units: AtomicUsize,
    events: Mutex<Vec<DegradeEvent>>,
}

impl DegradeLog {
    /// Fresh, empty log.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one degradation event (thread-safe).
    pub fn record(&self, ev: DegradeEvent) {
        let ctr = match &ev {
            DegradeEvent::Evicted { .. } => &self.evictions,
            DegradeEvent::Refined { .. } => &self.refinements,
            DegradeEvent::Spilled { .. } => &self.spills,
            DegradeEvent::HangRetry { .. } => &self.hang_retries,
            DegradeEvent::WatchdogEscalated { .. } => &self.watchdog_escalations,
            DegradeEvent::SlowUnit { .. } => &self.slow_units,
            DegradeEvent::StepBackoff { .. } => {
                // a poisoned lock only means another worker panicked while
                // logging; the event list itself is always consistent
                self.events.lock().unwrap_or_else(|e| e.into_inner()).push(ev);
                return;
            }
        };
        ctr.fetch_add(1, Ordering::Relaxed);
        self.events.lock().unwrap_or_else(|e| e.into_inner()).push(ev);
    }

    /// Move everything recorded since the last drain into a summary.
    pub fn drain(&self) -> DegradeStats {
        let events: Vec<DegradeEvent> =
            std::mem::take(&mut *self.events.lock().unwrap_or_else(|e| e.into_inner()));
        DegradeStats {
            evictions: self.evictions.swap(0, Ordering::Relaxed),
            refinements: self.refinements.swap(0, Ordering::Relaxed),
            spills: self.spills.swap(0, Ordering::Relaxed),
            hang_retries: self.hang_retries.swap(0, Ordering::Relaxed),
            watchdog_escalations: self.watchdog_escalations.swap(0, Ordering::Relaxed),
            slow_units: self.slow_units.swap(0, Ordering::Relaxed),
            events: events.iter().map(|e| e.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_count_and_drain_resets() {
        let log = DegradeLog::new();
        log.record(DegradeEvent::Evicted { device: 0, entries: 3 });
        log.record(DegradeEvent::Refined { device: 0, detail: "fp chunk 9 -> 4".into() });
        log.record(DegradeEvent::HangRetry { device: 1, times: 2 });
        let stats = log.drain();
        assert_eq!(stats.evictions, 1);
        assert_eq!(stats.refinements, 1);
        assert_eq!(stats.hang_retries, 1);
        assert_eq!(stats.events.len(), 3);
        assert!(stats.events[1].contains("refine d0"), "{:?}", stats.events);
        assert!(!stats.is_clean());
        // drained: the next op starts clean
        let again = log.drain();
        assert!(again.is_clean());
        assert_eq!(again, DegradeStats::default());
    }

    #[test]
    fn is_shareable_across_threads() {
        let log = std::sync::Arc::new(DegradeLog::new());
        let handles: Vec<_> = (0..4)
            .map(|d| {
                let log = log.clone();
                std::thread::spawn(move || {
                    log.record(DegradeEvent::SlowUnit {
                        device: d,
                        elapsed_s: 1.0,
                        deadline_s: 0.5,
                    })
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let stats = log.drain();
        assert_eq!(stats.slow_units, 4);
        assert_eq!(stats.events.len(), 4);
    }
}
