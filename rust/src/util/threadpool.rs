//! Scoped thread-pool / parallel-for substrate (rayon is unavailable).
//!
//! Three entry points:
//!  * [`parallel_for`] — split an index range into chunks and run a closure
//!    over each chunk on worker threads (used by the native kernels).
//!  * [`ThreadPool`] — a persistent pool with a job queue (used by the
//!    coordinator to model one host thread per simulated GPU).
//!  * [`ThreadPool::scope`] — submit jobs that borrow from the caller's
//!    stack and get a [`ScopedHandle`] per job; the coordinator's
//!    pipelined executor runs one device worker per [`Scope::spawn`].

use std::marker::PhantomData;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// Number of worker threads to use by default: the host parallelism.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `body(start, end)` over disjoint chunks of `0..n` on up to
/// `threads` scoped threads. Chunks are balanced via an atomic cursor so
/// irregular per-index cost (e.g. rays missing the volume) self-balances.
pub fn parallel_for<F>(n: usize, threads: usize, chunk: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n <= chunk {
        body(0, n);
        return;
    }
    let cursor = AtomicUsize::new(0);
    let body = &body;
    let cursor = &cursor;
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(move || loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                body(start, end);
            });
        }
    });
}

/// Raw mutable `f32` pointer wrapper asserting `Send + Sync`. Every use
/// site guarantees that concurrent tasks write **disjoint** regions of the
/// pointee (see the SAFETY comments at each dereference); the wrapper
/// exists so kernels and the pipelined executor can hand one output
/// pointer to scoped tasks. Shared here instead of per-module copies so
/// the safety contract lives in one place.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr(pub *mut f32);
// SAFETY: the pointee is a caller-owned buffer that outlives the scoped
// tasks the pointer is handed to, and every dereference site writes a
// region disjoint from all concurrently running tasks (asserted by the
// SAFETY comment at each `unsafe` dereference).
unsafe impl Send for SendPtr {}
// SAFETY: shared references to the wrapper only copy the raw pointer;
// all writes through it go through the disjoint-region contract above.
unsafe impl Sync for SendPtr {}

type Job = Box<dyn FnOnce() + Send + 'static>;

type Pending = (Mutex<usize>, std::sync::Condvar);

/// Decrements the pending-job count on drop, so a panicking job can
/// never leave `wait_idle` blocked forever: the decrement happens during
/// unwinding as well as on the normal path.
struct PendingGuard<'a>(&'a Pending);

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        let (lock, cvar) = self.0;
        // the count mutex is only ever held for the increment/decrement
        // itself, so it cannot be poisoned by a job panic
        let mut p = lock.lock().unwrap_or_else(|e| e.into_inner());
        *p -= 1;
        if *p == 0 {
            cvar.notify_all();
        }
    }
}

/// A persistent thread pool with graceful shutdown on drop. Jobs that
/// panic are contained: the panic is caught on the worker, the pending
/// count still drops (drop guard), and the worker keeps serving jobs.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
    pending: Arc<Pending>,
}

impl ThreadPool {
    /// Spawn a pool with `workers` threads (at least one).
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending: Arc<Pending> = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            handles.push(thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => {
                        let _guard = PendingGuard(&pending);
                        // contain job panics so the worker survives and
                        // the guard's decrement runs exactly once
                        let result =
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                        if let Err(payload) = result {
                            let msg = payload
                                .downcast_ref::<&str>()
                                .map(|s| s.to_string())
                                .or_else(|| payload.downcast_ref::<String>().cloned())
                                .unwrap_or_else(|| "<non-string panic>".into());
                            crate::log_warn!("threadpool job panicked: {msg}");
                        }
                    }
                    Err(_) => break,
                }
            }));
        }
        Self { tx: Some(tx), handles, pending }
    }

    /// Submit a job; returns immediately.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.submit_boxed(Box::new(f));
    }

    fn submit_boxed(&self, job: Job) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx.as_ref().expect("pool shut down").send(job).expect("worker hung up");
    }

    /// Block until every submitted job has completed (including jobs
    /// that panicked — see [`PendingGuard`]).
    pub fn wait_idle(&self) {
        let (lock, cvar) = &*self.pending;
        let mut p = lock.lock().unwrap_or_else(|e| e.into_inner());
        while *p > 0 {
            p = cvar.wait(p).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Run `f` with a [`Scope`] through which jobs that **borrow from the
    /// caller's environment** can be submitted to this pool. `scope` does
    /// not return until every job spawned inside it has finished (even if
    /// `f` or a job panics), which is what makes the borrows sound.
    ///
    /// Unlike [`std::thread::scope`] this does not spawn a thread per job:
    /// jobs run on the pool's persistent workers, so a caller can bound
    /// concurrency by the pool size. Jobs must not block on *other* jobs
    /// of the same pool (the workers they would need may be occupied).
    pub fn scope<'env, F, R>(&self, f: F) -> R
    where
        F: for<'scope> FnOnce(&'scope Scope<'scope, 'env>) -> R,
    {
        let scope = Scope {
            pool: self,
            pending: Arc::new((Mutex::new(0), Condvar::new())),
            _scope: PhantomData,
            _env: PhantomData,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&scope)));
        // Jobs may still borrow the environment: block until all are done
        // before returning/unwinding, on the success and the panic path.
        scope.wait_all();
        match result {
            Ok(r) => r,
            Err(payload) => std::panic::resume_unwind(payload),
        }
    }
}

/// Job-submission scope over a [`ThreadPool`]; see [`ThreadPool::scope`].
pub struct Scope<'scope, 'env: 'scope> {
    pool: &'scope ThreadPool,
    /// Jobs spawned in this scope that have not finished yet.
    pending: Arc<(Mutex<usize>, Condvar)>,
    _scope: PhantomData<&'scope mut &'scope ()>,
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'scope, 'env> Scope<'scope, 'env> {
    /// Submit a job that may borrow from the environment of the enclosing
    /// [`ThreadPool::scope`] call. Returns a [`ScopedHandle`] carrying the
    /// job's return value (or its panic payload).
    pub fn spawn<T, F>(&'scope self, f: F) -> ScopedHandle<'scope, T>
    where
        T: Send + 'env,
        F: FnOnce() -> T + Send + 'env,
    {
        let state: HandleState<T> = Arc::new((Mutex::new(None), Condvar::new()));
        let job_state = Arc::clone(&state);
        let scope_pending = Arc::clone(&self.pending);
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap_or_else(|e| e.into_inner()) += 1;
        }
        let job = move || {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
            {
                let (lock, cvar) = &*job_state;
                *lock.lock().unwrap_or_else(|e| e.into_inner()) = Some(result);
                cvar.notify_all();
            }
            // Release this job's handle-state reference BEFORE the
            // decrement: if the caller dropped the handle unjoined, this
            // is the last Arc and the stored `T` (which may borrow 'env)
            // drops here — while wait_all still holds the environment
            // alive. Decrementing first would let `scope` return and free
            // 'env before a borrowed T's Drop ran on this worker.
            drop(job_state);
            // decrement strictly after the result is published (and the
            // worker's state reference released) so wait_all implies every
            // handle is ready and every unclaimed result is already dropped
            let (lock, cvar) = &*scope_pending;
            let mut p = lock.lock().unwrap_or_else(|e| e.into_inner());
            *p -= 1;
            if *p == 0 {
                cvar.notify_all();
            }
        };
        let job: Box<dyn FnOnce() + Send + 'env> = Box::new(job);
        // SAFETY: `ThreadPool::scope` blocks (wait_all) until this scope's
        // pending count reaches zero before returning or unwinding, so the
        // job — and everything it borrows with lifetime 'env — is done
        // executing before any borrowed data can be dropped. Extending the
        // closure's lifetime to 'static is therefore sound, exactly as in
        // std::thread::scope.
        let job: Box<dyn FnOnce() + Send + 'static> = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Box<dyn FnOnce() + Send>>(job)
        };
        self.pool.submit_boxed(job);
        ScopedHandle { state, _scope: PhantomData }
    }

    fn wait_all(&self) {
        let (lock, cvar) = &*self.pending;
        let mut p = lock.lock().unwrap_or_else(|e| e.into_inner());
        while *p > 0 {
            p = cvar.wait(p).unwrap_or_else(|e| e.into_inner());
        }
    }
}

type HandleState<T> = Arc<(Mutex<Option<thread::Result<T>>>, Condvar)>;

/// Handle to one scoped job: blocks until the job finishes and yields its
/// return value, or `Err(payload)` if the job panicked (mirroring
/// [`std::thread::JoinHandle::join`]). Dropping the handle detaches the
/// job's *result* only — the job itself still completes within the scope.
pub struct ScopedHandle<'scope, T> {
    state: HandleState<T>,
    _scope: PhantomData<&'scope ()>,
}

impl<T> ScopedHandle<'_, T> {
    /// Block until the job finishes; `Err(payload)` if it panicked.
    pub fn join(self) -> thread::Result<T> {
        let (lock, cvar) = &*self.state;
        let mut slot = lock.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            match slot.take() {
                Some(result) => return result,
                None => slot = cvar.wait(slot).unwrap_or_else(|e| e.into_inner()),
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit on recv error
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_indices_once() {
        let n = 10_000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(n, 4, 128, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_handles_zero() {
        let touched = AtomicUsize::new(0);
        parallel_for(0, 4, 16, |s, e| {
            touched.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(touched.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn parallel_for_single_thread_path() {
        let sum = AtomicU64::new(0);
        parallel_for(100, 1, 16, |s, e| {
            for i in s..e {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            }
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn pool_runs_jobs_and_waits() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn pool_survives_panicking_jobs() {
        // A panicking job must still decrement the pending count (drop
        // guard) — before the fix this deadlocked wait_idle — and must
        // not kill the worker thread.
        let pool = ThreadPool::new(2);
        for _ in 0..4 {
            pool.submit(|| panic!("job panic (expected in this test)"));
        }
        pool.wait_idle(); // would hang forever without the guard

        // the pool still processes subsequent jobs on all workers
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..16 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn pool_mixed_panicking_and_normal_jobs() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for i in 0..30 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                if i % 3 == 0 {
                    panic!("boom {i}");
                }
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 20);
    }

    #[test]
    fn scope_jobs_borrow_environment() {
        let pool = ThreadPool::new(3);
        let data: Vec<u64> = (0..1000).collect();
        let (lo_half, hi_half) = data.split_at(data.len() / 2);
        let total: u64 = pool.scope(|s| {
            let lo = s.spawn(move || lo_half.iter().sum::<u64>());
            let hi = s.spawn(move || hi_half.iter().sum::<u64>());
            lo.join().unwrap() + hi.join().unwrap()
        });
        assert_eq!(total, 499_500);
        // the pool is reusable after a scope
        pool.wait_idle();
    }

    #[test]
    fn scope_handle_reports_job_panic() {
        let pool = ThreadPool::new(2);
        let (ok, bad) = pool.scope(|s| {
            let ok = s.spawn(|| 7usize);
            let bad = s.spawn(|| -> usize { panic!("scoped job panic (expected)") });
            (ok.join(), bad.join())
        });
        assert_eq!(ok.unwrap(), 7);
        assert!(bad.is_err(), "panic must surface through the handle");
    }

    #[test]
    fn scope_waits_for_unjoined_jobs() {
        // A job whose handle is dropped must still complete before scope
        // returns — otherwise its borrow of `hits` would dangle.
        let pool = ThreadPool::new(2);
        let hits = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..16 {
                let _unjoined = s.spawn(|| {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    hits.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn single_worker_pool_serializes_scope_jobs() {
        // With one worker the jobs run strictly one at a time, in
        // submission order — the "single-worker path" the executor's
        // determinism tests compare against.
        let pool = ThreadPool::new(1);
        let order = Mutex::new(Vec::new());
        let order_ref = &order;
        pool.scope(|s| {
            for i in 0..8 {
                s.spawn(move || order_ref.lock().unwrap().push(i));
            }
        });
        assert_eq!(*order.lock().unwrap(), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn pool_drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::Relaxed), 8);
    }
}
