//! Fig. 11 — the Ichthyosaur-fossil case study: OS-SART (subset 200,
//! 50 iterations) on a strongly anisotropic volume.
//!
//! Paper setup (scaled): 3360×900×2000 volume, 2000 angles of a
//! 2000×2000 panel-shifted detector, 2× GTX 1080 Ti, 6 h 40 min.

use tigre::algorithms::{self, ReconOpts};
use tigre::coordinator::{ExecMode, MultiGpu};
use tigre::geometry::Geometry;
use tigre::metrics;
use tigre::phantom;

fn main() {
    // ---- real numerics at miniature scale (aspect ratio preserved) ----
    let (nx, ny, nz) = (33, 9, 20); // 3360:900:2000 ÷ ~100
    let n_angles = 40;
    let subset = 4; // paper: 200/2000 angles → 1/10 of the set
    let truth = phantom::fossil(nx, ny, nz, 7);
    let g = Geometry::cone_beam_anisotropic([nx, ny, nz], [40, 40], n_angles);
    let ctx = MultiGpu::gtx1080ti(2);

    let (p, _) = ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap();
    let p = p.unwrap();
    let t0 = std::time::Instant::now();
    let r = algorithms::os_sart(
        &ctx,
        &g,
        &p,
        subset,
        &ReconOpts { iterations: 12, lambda: 0.9, ..Default::default() },
    )
    .unwrap();
    println!("=== Fig. 11 analogue: OS-SART on the fossil phantom ===");
    println!(
        "volume {nx}×{ny}×{nz}, {n_angles} angles, subset {subset}, 12 iterations \
         (real wall-clock {:.1}s)",
        t0.elapsed().as_secs_f64()
    );
    println!("RMSE  : {:.5}", metrics::rmse(&truth, &r.volume));
    println!("PSNR  : {:.2} dB", metrics::psnr(&truth, &r.volume));
    println!("corr  : {:.4}", metrics::correlation(&truth, &r.volume));
    println!(
        "residual: {:.3e} → {:.3e} over iterations",
        r.residuals[0],
        r.residuals.last().unwrap()
    );
    let _ = tigre::io::save_slice_pgm(
        std::path::Path::new("results/fig11_ossart.pgm"),
        &r.volume,
        nz / 2,
        None,
    );

    // ---- paper-scale timing on the device model ----
    // 3360×900×2000 volume, 2000×2000 detector, 2000 angles; OS-SART with
    // subsets of 200 → per iteration: 10 × (FP + BP over 200 angles).
    let g_paper = Geometry::cone_beam_anisotropic([3360, 900, 2000], [2000, 2000], 200);
    let node = MultiGpu::gtx1080ti(2);
    let (_, fp) = node.forward(&g_paper, None, ExecMode::SimOnly).unwrap();
    let (_, bp) = node.backward(&g_paper, None, ExecMode::SimOnly).unwrap();
    let per_sweep = 10.0 * (fp.makespan_s + bp.makespan_s);
    println!("=== paper-scale timing estimate (2× GTX 1080 Ti model) ===");
    println!(
        "per-subset FP {:.1}s + BP {:.1}s; 50 iterations ≈ {:.2} h (paper: 6.67 h)",
        fp.makespan_s,
        bp.makespan_s,
        50.0 * per_sweep / 3600.0
    );
    println!(
        "image 14.5 GB > device RAM: splits/device FP {} BP {}",
        fp.splits_per_device, bp.splits_per_device
    );
}
