"""L2 — the jax compute graph composing the L1 Pallas kernels.

These are the functions `aot.py` lowers to HLO text for the rust runtime:
  forward(vol, params, angles)  -> (proj,)
  backward(proj, params, angles) -> (vol,)
plus build-time-only compositions used by the python tests (a fused
residual-backprojection step, SART weight volumes) that demonstrate the
L2 layer fusing data-fidelity math around the kernels.

Everything here is shape-polymorphic at trace time and lowered per
manifest shape; python never runs at request time.
"""

import jax.numpy as jnp

from .kernels import backprojector, projector


def forward(vol, params, angles, nu, nv):
    """Cone-beam forward projection via the Pallas projector."""
    return projector.forward(vol, params, angles, nu=nu, nv=nv)


def backward(proj, params, angles, nx, ny, nz, matched=False):
    """Backprojection via the Pallas backprojector (FDK weights by
    default, pseudo-matched weights for the gradient algorithms)."""
    return backprojector.backward(
        proj, params, angles, nx=nx, ny=ny, nz=nz, matched=matched
    )


def residual_backproject(vol, meas, params, angles, nu, nv):
    """One fused data-fidelity step: Aᵀ(A x − b).

    The L2 fusion the gradient algorithms (CGLS/FISTA) are built from —
    lowering this as one module lets XLA fuse the residual subtraction
    into the kernels' dataflow instead of round-tripping through host
    memory.
    """
    nz, ny, nx = vol.shape
    r = forward(vol, params, angles, nu, nv) - meas
    return backward(r, params, angles, nx, ny, nz)


def sart_weights(params, angles, nx, ny, nz, nu, nv):
    """The SART normalization pair (W, V): W = 1/(A·1), V = 1/(Aᵀ·1)."""
    ones_vol = jnp.ones((nz, ny, nx), dtype=jnp.float32)
    w = forward(ones_vol, params, angles, nu, nv)
    w = jnp.where(jnp.abs(w) > 1e-6, 1.0 / w, 0.0)
    a = angles.shape[0]
    ones_proj = jnp.ones((a, nv, nu), dtype=jnp.float32)
    v = backward(ones_proj, params, angles, nx, ny, nz)
    v = jnp.where(jnp.abs(v) > 1e-6, 1.0 / v, 0.0)
    return w, v
