//! Checked-in waiver file for `tigre-lint` (`lint-allow.toml` at the
//! repo root).
//!
//! The format is a deliberately tiny TOML subset — parsed by hand so the
//! checker stays dependency-free:
//!
//! ```text
//! # comment
//! [lint-id]
//! allow = "<path-substring> | <matcher>"
//! ```
//!
//! `<path-substring>` is matched against the normalized (forward-slash)
//! file path. `<matcher>` is one of:
//!
//! * `*` (or an omitted ` | <matcher>` part) — every diagnostic of that
//!   lint in matching files,
//! * `fn <name>` — diagnostics whose enclosing named function is `<name>`
//!   (how merge sites are blessed for the accumulation lint),
//! * anything else — a substring of the offending source line (typically
//!   an `.expect("…")` message, which pins the waiver to the exact
//!   protocol the comment above the entry justifies).
//!
//! Policy (DESIGN.md §Static-analysis): every entry carries a `#` comment
//! explaining *why* the invariant does not apply; the typed-errors lint
//! must keep an **empty** section.

/// How one waiver entry matches a diagnostic within a file.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Matcher {
    /// Every diagnostic of the lint in matching files.
    Any,
    /// Diagnostics inside the named function.
    Fn(String),
    /// Diagnostics whose source line contains the substring.
    Line(String),
}

/// One parsed `allow = "path | matcher"` entry.
#[derive(Clone, Debug)]
pub struct Entry {
    /// Lint id the waiver applies to (the `[section]` header).
    pub lint: String,
    /// Substring matched against the normalized file path.
    pub path_sub: String,
    /// How diagnostics within matching files are selected.
    pub matcher: Matcher,
}

/// The parsed waiver file.
#[derive(Clone, Debug, Default)]
pub struct Allowlist {
    entries: Vec<Entry>,
}

impl Allowlist {
    /// No waivers (what the golden-fixture tests check against).
    pub fn empty() -> Allowlist {
        Allowlist::default()
    }

    /// All parsed waiver entries, in file order.
    pub fn entries(&self) -> &[Entry] {
        &self.entries
    }

    /// Parse the waiver format; errors carry the 1-based line number.
    pub fn parse(text: &str) -> Result<Allowlist, String> {
        let mut entries = Vec::new();
        let mut section: Option<String> = None;
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = Some(name.trim().to_string());
                continue;
            }
            let Some(value) = line
                .strip_prefix("allow")
                .map(str::trim_start)
                .and_then(|l| l.strip_prefix('='))
            else {
                return Err(format!("line {}: expected `[section]` or `allow = \"…\"`", i + 1));
            };
            let value = value.trim();
            let Some(value) = value
                .strip_prefix('"')
                .and_then(|v| v.strip_suffix('"'))
            else {
                return Err(format!("line {}: allow value must be double-quoted", i + 1));
            };
            let Some(lint) = section.clone() else {
                return Err(format!("line {}: `allow` before any [lint] section", i + 1));
            };
            let (path_sub, matcher) = match value.split_once('|') {
                None => (value.trim().to_string(), Matcher::Any),
                Some((p, m)) => {
                    let m = m.trim();
                    let matcher = if m == "*" || m.is_empty() {
                        Matcher::Any
                    } else if let Some(f) = m.strip_prefix("fn ") {
                        Matcher::Fn(f.trim().to_string())
                    } else {
                        Matcher::Line(m.to_string())
                    };
                    (p.trim().to_string(), matcher)
                }
            };
            if path_sub.is_empty() {
                return Err(format!("line {}: empty path pattern", i + 1));
            }
            entries.push(Entry { lint, path_sub, matcher });
        }
        Ok(Allowlist { entries })
    }

    /// Load from disk; a missing file is an empty allowlist, a malformed
    /// one is an error (waivers must never be silently dropped).
    pub fn load(path: &std::path::Path) -> Result<Allowlist, String> {
        match std::fs::read_to_string(path) {
            Ok(text) => Allowlist::parse(&text),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Allowlist::empty()),
            Err(e) => Err(format!("{}: {e}", path.display())),
        }
    }

    /// Is a diagnostic of `lint` at `path`/`line_text` (inside
    /// `enclosing_fn`) waived?
    pub fn allows(
        &self,
        lint: &str,
        path: &str,
        line_text: &str,
        enclosing_fn: Option<&str>,
    ) -> bool {
        self.entries.iter().any(|e| {
            e.lint == lint
                && path.contains(e.path_sub.as_str())
                && match &e.matcher {
                    Matcher::Any => true,
                    Matcher::Fn(name) => enclosing_fn == Some(name.as_str()),
                    Matcher::Line(sub) => line_text.contains(sub.as_str()),
                }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lint_allowlist_parses_sections_and_matchers() {
        let text = r#"
# top comment
[no-panic-paths]
# lane protocol
allow = "coordinator/pipeline.rs | merge lane terminated"
allow = "coordinator/pipeline.rs | fn recover_fp_losses"
[no-bare-print]
allow = "util/log.rs | *"
allow = "config/mod.rs"
"#;
        let a = Allowlist::parse(text).unwrap();
        assert_eq!(a.entries().len(), 4);
        assert!(a.allows(
            "no-panic-paths",
            "rust/src/coordinator/pipeline.rs",
            r#"let b = rx.recv().expect("merge lane terminated");"#,
            Some("worker"),
        ));
        assert!(a.allows(
            "no-panic-paths",
            "rust/src/coordinator/pipeline.rs",
            "*o += *v;",
            Some("recover_fp_losses"),
        ));
        assert!(!a.allows(
            "no-panic-paths",
            "rust/src/coordinator/splitter.rs",
            r#"x.expect("merge lane terminated")"#,
            None,
        ));
        assert!(a.allows("no-bare-print", "rust/src/util/log.rs", "eprintln!(..)", None));
        assert!(a.allows("no-bare-print", "rust/src/config/mod.rs", "println!(..)", None));
        assert!(!a.allows("typed-errors", "rust/src/config/mod.rs", "anyhow!(..)", None));
    }

    #[test]
    fn lint_allowlist_rejects_malformed_lines() {
        assert!(Allowlist::parse("allow = \"x\"").is_err(), "entry before section");
        assert!(Allowlist::parse("[a]\nallow = unquoted").is_err());
        assert!(Allowlist::parse("[a]\nnonsense line").is_err());
        assert!(Allowlist::parse("[a]\nallow = \"\"").is_err(), "empty path");
    }
}
