//! `tigre-lint` — walk `rust/src/**` and enforce the repo's own
//! determinism/safety/error-taxonomy invariants without compiling
//! anything. See DESIGN.md §Static-analysis for the lint catalog and the
//! waiver policy.
//!
//! ```text
//! tigre-lint [--deny-all] [--json] [--allowlist FILE] [ROOT]
//! ```
//!
//! Exit codes: 0 clean, 1 fatal diagnostics, 2 usage/IO error.

use std::path::PathBuf;
use std::process::ExitCode;

use tigre::analysis::{self, Allowlist};

struct Args {
    deny_all: bool,
    json: bool,
    allowlist: Option<PathBuf>,
    root: Option<PathBuf>,
}

const USAGE: &str = "usage: tigre-lint [--deny-all] [--json] [--allowlist FILE] [ROOT]";

fn parse_args() -> Result<Args, String> {
    let mut args = Args { deny_all: false, json: false, allowlist: None, root: None };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--deny-all" => args.deny_all = true,
            "--json" => args.json = true,
            "--allowlist" => {
                let p = it.next().ok_or("--allowlist needs a file argument")?;
                args.allowlist = Some(PathBuf::from(p));
            }
            "--help" | "-h" => return Err(USAGE.to_string()),
            _ if a.starts_with('-') => return Err(format!("unknown flag '{a}'\n{USAGE}")),
            _ => {
                if args.root.is_some() {
                    return Err(format!("more than one ROOT argument\n{USAGE}"));
                }
                args.root = Some(PathBuf::from(a));
            }
        }
    }
    Ok(args)
}

/// First existing default: the crate source tree, from either the repo
/// root or `rust/` as the working directory.
fn default_root() -> Result<PathBuf, String> {
    for cand in ["rust/src", "src"] {
        let p = PathBuf::from(cand);
        if p.is_dir() {
            return Ok(p);
        }
    }
    Err("no ROOT given and neither rust/src nor src exists here".to_string())
}

/// The checked-in waiver file, from either working directory.
fn default_allowlist() -> PathBuf {
    for cand in ["lint-allow.toml", "../lint-allow.toml"] {
        let p = PathBuf::from(cand);
        if p.is_file() {
            return p;
        }
    }
    PathBuf::from("lint-allow.toml")
}

fn run() -> Result<ExitCode, String> {
    let args = parse_args()?;
    let root = match args.root {
        Some(r) => r,
        None => default_root()?,
    };
    let allow_path = args.allowlist.unwrap_or_else(default_allowlist);
    let allow = Allowlist::load(&allow_path)?;

    let diags = analysis::check_tree(&root, &allow)
        .map_err(|e| format!("walking {}: {e}", root.display()))?;

    if args.json {
        println!("{}", analysis::render_json(&diags, args.deny_all));
    } else {
        print!("{}", analysis::render_text(&diags, args.deny_all));
    }

    let fatal = diags.iter().any(|d| d.deny || args.deny_all);
    Ok(if fatal { ExitCode::from(1) } else { ExitCode::SUCCESS })
}

fn main() -> ExitCode {
    match run() {
        Ok(code) => code,
        Err(msg) => {
            eprintln!("tigre-lint: {msg}");
            ExitCode::from(2)
        }
    }
}
