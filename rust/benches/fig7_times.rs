//! Fig. 7 + Fig. 8 — projection and backprojection total time (compute +
//! transfers) vs problem size N for 1–4 GPUs, and the same data as a
//! percentage of the 1-GPU time.
//!
//! Workload (paper §3.1): N³ voxel volume, N² detector pixels, N angles,
//! GTX 1080 Ti-class devices with 11 GiB each. Times come from the
//! discrete-event device model (DESIGN.md §6); the *shape* — near-linear
//! scaling at large N, overhead domination at small N, BP scaling worse
//! than FP — is the reproduction target, not absolute seconds.

use tigre::bench::{fig7_sweep, fig7_table, fig8_table, save_sweep_csv, FIG7_SIZES, GPU_COUNTS};

fn main() {
    let t0 = std::time::Instant::now();
    let cells = fig7_sweep(FIG7_SIZES, GPU_COUNTS);

    println!("=== Fig. 7 (a): forward projection time [simulated s] ===");
    println!("{}", fig7_table(&cells, true));
    println!("=== Fig. 7 (b): backprojection time [simulated s] ===");
    println!("{}", fig7_table(&cells, false));
    println!("=== Fig. 8 (a): forward projection, % of 1-GPU time ===");
    println!("{}", fig8_table(&cells, true));
    println!("=== Fig. 8 (b): backprojection, % of 1-GPU time ===");
    println!("{}", fig8_table(&cells, false));

    // paper §3.1 checkpoints, printed every bench run
    let c3072_1 = cells.iter().find(|c| c.n == 3072 && c.gpus == 1).unwrap();
    let c3072_2 = cells.iter().find(|c| c.n == 3072 && c.gpus == 2).unwrap();
    println!(
        "splits at N=3072 — FP: {} (1 GPU, paper 10) / {} (2 GPU, paper 5); \
         BP: {} (1 GPU, paper 11) / {} (2 GPU, paper 6)",
        c3072_1.fp_splits, c3072_2.fp_splits, c3072_1.bp_splits, c3072_2.bp_splits
    );
    let big = cells.iter().find(|c| c.n == 2048 && c.gpus == 2).unwrap();
    let base = cells.iter().find(|c| c.n == 2048 && c.gpus == 1).unwrap();
    println!(
        "scaling checkpoint N=2048: 2-GPU FP at {:.1}% of 1-GPU (theory 50%)",
        100.0 * big.fp_s / base.fp_s
    );

    let _ = save_sweep_csv(std::path::Path::new("results/fig7_sweep.csv"), &cells);
    println!(
        "(csv: results/fig7_sweep.csv; harness wall-clock {:.1}s)",
        t0.elapsed().as_secs_f64()
    );
}
