//! The Ichthyosaur-fossil scenario (paper §3.2, Fig. 11): OS-SART on a
//! strongly anisotropic volume with subset updates, plus the ASD-POCS
//! TV-regularized variant the toolbox offers for noisy data.
//!
//! Run with: `cargo run --release --example ichthyosaur`

use tigre::algorithms::{self, ReconOpts};
use tigre::coordinator::{ExecMode, MultiGpu};
use tigre::geometry::Geometry;
use tigre::metrics;
use tigre::phantom;
use tigre::util::pcg::Pcg32;

fn main() -> anyhow::Result<()> {
    // 3360×900×2000 at ~1:100 scale
    let (nx, ny, nz) = (33, 9, 20);
    let n_angles = 40;
    let truth = phantom::fossil(nx, ny, nz, 7);
    let g = Geometry::cone_beam_anisotropic([nx, ny, nz], [40, 40], n_angles);
    let node = MultiGpu::gtx1080ti(2);

    let (proj, _) = node.forward(&g, Some(&truth), ExecMode::Full)?;
    let mut proj = proj.unwrap();

    // detector noise (the real scan is at 3.37 µA — photon-starved)
    let mut rng = Pcg32::new(11);
    let peak = proj.data.iter().cloned().fold(f32::MIN, f32::max);
    for v in &mut proj.data {
        *v += 0.02 * peak * rng.normal() as f32;
    }

    // OS-SART, subset 4 of 40 angles (paper: 200 of 2000), 12 iterations
    let ossart = algorithms::os_sart(
        &node,
        &g,
        &proj,
        4,
        &ReconOpts { iterations: 12, lambda: 0.9, ..Default::default() },
    )?;
    // ASD-POCS adds the TV constraint for the noisy projections
    let asd = algorithms::asd_pocs(
        &node,
        &g,
        &proj,
        &algorithms::asd_pocs::AsdPocsOpts {
            common: ReconOpts { iterations: 8, lambda: 0.9, ..Default::default() },
            subset_size: 4,
            tv_iters: 8,
            alpha: 0.004,
            n_in: 8,
        },
    )?;

    println!("fossil {nx}×{ny}×{nz}, {n_angles} noisy projections:");
    let report = |name: &str, r: &algorithms::ReconResult| {
        println!(
            "  {name:<10} RMSE {:.5}  PSNR {:.2} dB  corr {:.4}  (sim {:.2}s)",
            metrics::rmse(&truth, &r.volume),
            metrics::psnr(&truth, &r.volume),
            metrics::correlation(&truth, &r.volume),
            r.sim_time_s
        );
    };
    report("OS-SART", &ossart);
    report("ASD-POCS", &asd);
    println!(
        "TV regularization smooths the noise: TV {:.1} (OS-SART) → {:.1} (ASD-POCS)",
        tigre::kernels::tv::tv_value(&ossart.volume),
        tigre::kernels::tv::tv_value(&asd.volume)
    );

    tigre::io::save_slice_pgm(
        std::path::Path::new("results/fossil_ossart.pgm"),
        &ossart.volume,
        nz / 2,
        None,
    )?;
    tigre::io::save_slice_pgm(
        std::path::Path::new("results/fossil_asdpocs.pgm"),
        &asd.volume,
        nz / 2,
        None,
    )?;
    println!("slices: results/fossil_ossart.pgm, results/fossil_asdpocs.pgm");
    Ok(())
}
