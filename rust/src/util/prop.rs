//! Minimal property-based testing framework (proptest is unavailable).
//!
//! Usage:
//! ```ignore
//! check("splits cover volume", 200, |g| {
//!     let n = g.usize(1, 4096);
//!     let parts = g.usize(1, 16);
//!     let splits = split_evenly(n, parts);
//!     prop_assert(splits.iter().sum::<usize>() == n, "sum mismatch")
//! });
//! ```
//! Each case gets a fresh seeded [`Pcg32`]; on failure the seed and case
//! index are printed so the case can be replayed deterministically. A simple
//! halving shrink pass is applied to integer draws via `Gen::usize` history.

use super::pcg::Pcg32;

/// Property outcome: Ok(()) or a failure description.
pub type PropResult = Result<(), String>;

/// Assert helper for property bodies.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Case generator handed to property bodies. Wraps the PRNG and records
/// integer draws so failing cases can be shrunk.
pub struct Gen {
    rng: Pcg32,
    draws: Vec<(usize, usize, usize)>, // (lo, hi, value)
    forced: Vec<usize>,                // replay/shrink values
    cursor: usize,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self { rng: Pcg32::new(seed), draws: Vec::new(), forced: Vec::new(), cursor: 0 }
    }

    fn with_forced(seed: u64, forced: Vec<usize>) -> Self {
        Self { rng: Pcg32::new(seed), draws: Vec::new(), forced, cursor: 0 }
    }

    /// Uniform usize in [lo, hi] inclusive. Recorded for shrinking.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        let v = if self.cursor < self.forced.len() {
            let forced = self.forced[self.cursor].clamp(lo, hi);
            forced
        } else {
            self.rng.range_usize(lo, hi)
        };
        self.cursor += 1;
        self.draws.push((lo, hi, v));
        v
    }

    /// Uniform f64 in [lo, hi). Not shrunk.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Uniform f32 in [lo, hi). Not shrunk.
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.next_f32()
    }

    /// Fair coin flip. Not shrunk.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        let i = self.rng.range_usize(0, xs.len() - 1);
        &xs[i]
    }

    /// A vector of f32 values in [lo, hi) of the given length.
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32(lo, hi)).collect()
    }
}

/// Run `cases` random cases of `body`. Panics (failing the enclosing
/// #[test]) with seed + shrunk arguments on the first failure.
pub fn check<F>(name: &str, cases: usize, body: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    // Base seed is fixed for reproducibility; override with TIGRE_PROP_SEED.
    let base: u64 = std::env::var("TIGRE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x7161_7261);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut g = Gen::new(seed);
        if let Err(msg) = body(&mut g) {
            // Shrink: repeatedly halve recorded integer draws towards lo.
            let (shrunk, smsg) = shrink(seed, &g.draws, &body).unwrap_or((
                g.draws.iter().map(|d| d.2).collect(),
                msg.clone(),
            ));
            panic!(
                "property '{name}' failed (case {case}, seed {seed}):\n  {smsg}\n  \
                 draws: {shrunk:?}\n  replay: TIGRE_PROP_SEED={base}"
            );
        }
    }
}

/// Greedy shrink: for each recorded draw, try lo then midpoints; keep any
/// assignment that still fails. Returns the minimal failing draws + message.
fn shrink<F>(
    seed: u64,
    draws: &[(usize, usize, usize)],
    body: &F,
) -> Option<(Vec<usize>, String)>
where
    F: Fn(&mut Gen) -> PropResult,
{
    let mut current: Vec<usize> = draws.iter().map(|d| d.2).collect();
    let mut last_msg: Option<String> = None;
    let mut improved = true;
    let mut rounds = 0;
    while improved && rounds < 20 {
        improved = false;
        rounds += 1;
        for i in 0..current.len() {
            let lo = draws.get(i).map(|d| d.0).unwrap_or(0);
            let orig = current[i];
            if orig == lo {
                continue;
            }
            // candidates: lo, then halfway between lo and orig
            for cand in [lo, lo + (orig - lo) / 2] {
                if cand == orig {
                    continue;
                }
                let mut trial = current.clone();
                trial[i] = cand;
                let mut g = Gen::with_forced(seed, trial.clone());
                if let Err(m) = body(&mut g) {
                    current = trial;
                    last_msg = Some(m);
                    improved = true;
                    break;
                }
            }
        }
    }
    last_msg.map(|m| (current, m))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum is commutative", 50, |g| {
            let a = g.usize(0, 1000);
            let b = g.usize(0, 1000);
            prop_assert(a + b == b + a, "commutativity")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", 5, |g| {
            let _ = g.usize(0, 10);
            Err("nope".to_string())
        });
    }

    #[test]
    fn shrinking_reduces_magnitude() {
        // Fails iff a >= 17; the shrinker should land near 17, well below
        // the typical random draw of ~half of 10_000.
        let draws = std::sync::Mutex::new(Vec::new());
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check("ge17", 100, |g| {
                let a = g.usize(0, 10_000);
                if a >= 17 {
                    draws.lock().unwrap().push(a);
                    Err(format!("a={a}"))
                } else {
                    Ok(())
                }
            });
        }));
        assert!(result.is_err());
        let msg = format!("{:?}", result.unwrap_err().downcast_ref::<String>());
        // shrunk value should appear and be < 100 (much smaller than initial)
        assert!(msg.contains("a="), "panic message carries shrunk case: {msg}");
    }

    #[test]
    fn forced_draws_replay() {
        let mut g = Gen::with_forced(1, vec![5, 7]);
        assert_eq!(g.usize(0, 10), 5);
        assert_eq!(g.usize(0, 10), 7);
    }

    #[test]
    fn forced_draws_clamped_to_range() {
        let mut g = Gen::with_forced(1, vec![500]);
        assert_eq!(g.usize(0, 10), 10);
    }
}
