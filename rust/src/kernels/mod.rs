//! Native rust forward/back-projection and regularization kernels.
//!
//! The paper deliberately does not constrain the kernels ("our multi-GPU
//! strategy … is applicable to most, if not all, the algorithms for forward
//! and backprojection in the literature"). This module provides the
//! arbitrary-shape CPU implementations used by the coordinator's real
//! execution path; `runtime::pjrt` provides the AOT-compiled Pallas/JAX
//! versions of the same operators for manifest shapes, and the two are
//! cross-checked by integration tests.
//!
//! Kernels mirror TIGRE's:
//!  * [`siddon`] — ray-driven intersection projector (Siddon/Amanatides-Woo
//!    traversal), TIGRE's default `Ax`.
//!  * [`joseph`] — interpolated (sampled trilinear) projector, TIGRE's
//!    alternative `Ax` ("included for completeness", paper §3.1).
//!  * [`sparse`] — precomputed CSR system matrix per slab×chunk unit:
//!    forward is an SpMV bit-identical to [`siddon`], backward is the
//!    exactly matched adjoint SpMVᵀ (Marchesini et al. 2020 style).
//!  * [`voxel_backproj`] — voxel-driven backprojector with FDK or
//!    pseudo-matched weights, TIGRE's `Aᵀb`.
//!  * [`tv`] — total-variation regularizers (gradient-descent and ROF).
//!  * [`fft`] + [`filtering`] — ramp/Hann filtering for FDK.
//!  * [`scratch`] — per-thread buffer arena the kernels draw their output
//!    buffers from; callers recycle consumed buffers so iterative
//!    algorithms stop paying an allocate-and-fault cycle per operator call.

pub mod fft;
pub mod filtering;
pub mod joseph;
pub mod scratch;
pub mod siddon;
pub mod sparse;
pub mod tv;
pub mod voxel_backproj;

use crate::geometry::Geometry;
use crate::volume::{ProjChunkView, ProjectionSet, Volume, VolumeSlabView};

/// Which forward projector to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Projector {
    /// Ray-voxel intersection (Siddon). TIGRE's default.
    Siddon,
    /// Sampled trilinear interpolation (Joseph-style).
    Joseph,
}

/// Backprojection weighting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackprojWeight {
    /// FDK distance weights `(DSO / (DSO − r·ŝ))²` — default, fastest for
    /// FDK-type reconstruction.
    Fdk,
    /// Pseudo-matched weights approximating the adjoint of the ray-driven
    /// projector (used by CGLS/FISTA which need `Aᵀ`).
    Matched,
}

/// Number of worker threads used by the native kernels: the host
/// parallelism by default, overridable via the `TIGRE_THREADS` env var
/// for reproducible benchmarking (the coordinator overrides this to one
/// thread per simulated device execution lane).
pub fn kernel_threads() -> usize {
    std::env::var("TIGRE_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(crate::util::threadpool::default_threads)
}

/// Forward projection `Ax` with the chosen projector, over all angles of
/// `g`, on `threads` host threads.
pub fn forward(g: &Geometry, vol: &Volume, kind: Projector, threads: usize) -> ProjectionSet {
    match kind {
        Projector::Siddon => siddon::project(g, vol, threads),
        Projector::Joseph => joseph::project(g, vol, threads),
    }
}

/// Backprojection `Aᵀb` with the chosen weighting, over all angles of `g`.
pub fn backward(
    g: &Geometry,
    proj: &ProjectionSet,
    weight: BackprojWeight,
    threads: usize,
) -> Volume {
    voxel_backproj::backproject(g, proj, weight, threads)
}

/// Zero-copy forward projection: project a borrowed (slab) view straight
/// into `out` (every element overwritten). The executor's staging path.
pub fn forward_into(
    g: &Geometry,
    vol: &VolumeSlabView<'_>,
    out: &mut [f32],
    kind: Projector,
    threads: usize,
) {
    match kind {
        Projector::Siddon => siddon::project_into(g, vol, out, threads),
        Projector::Joseph => joseph::project_into(g, vol, out, threads),
    }
}

/// Zero-copy backprojection: accumulate (`+=`) a borrowed angle-chunk view
/// into `out` (zero it first for a plain backprojection).
pub fn backward_into(
    g: &Geometry,
    proj: &ProjChunkView<'_>,
    out: &mut [f32],
    weight: BackprojWeight,
    threads: usize,
) {
    voxel_backproj::backproject_into(g, proj, out, weight, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phantom;

    /// ⟨Ax, y⟩ vs ⟨x, Aᵀy⟩ should agree up to the discretization mismatch
    /// of the unmatched pair — the paper's operators are "pseudo-matched",
    /// so we check the ratio is stable (within a band), not exactly 1.
    #[test]
    fn projector_backprojector_pseudo_adjoint() {
        let g = Geometry::cone_beam(24, 12);
        let x = phantom::random(24, 24, 24, 3);
        let ax = forward(&g, &x, Projector::Siddon, 2);
        let mut y = ProjectionSet::zeros_like(&g);
        let mut rng = crate::util::pcg::Pcg32::new(9);
        for v in &mut y.data {
            *v = rng.next_f32();
        }
        let aty = backward(&g, &y, BackprojWeight::Matched, 2);
        let lhs = ax.dot(&y);
        let rhs = x.dot(&aty);
        assert!(lhs > 0.0 && rhs > 0.0);
        let ratio = lhs / rhs;
        assert!(
            (0.5..2.0).contains(&ratio),
            "adjoint ratio out of band: {ratio} (lhs {lhs}, rhs {rhs})"
        );
    }

    #[test]
    fn forward_dispatches_both_projectors() {
        let g = Geometry::cone_beam(16, 4);
        let v = phantom::cube(16, 0.5, 1.0);
        let ps = forward(&g, &v, Projector::Siddon, 1);
        let pj = forward(&g, &v, Projector::Joseph, 1);
        assert_eq!(ps.data.len(), pj.data.len());
        // both see the cube: non-trivial energy, and similar magnitude
        let ns = ps.norm2();
        let nj = pj.norm2();
        assert!(ns > 0.0 && nj > 0.0);
        let ratio = ns / nj;
        assert!((0.7..1.4).contains(&ratio), "projector energy ratio {ratio}");
    }
}
