//! FDK projection filtering: cosine pre-weighting + ramp filtering of
//! every detector row (Feldkamp–Davis–Kress for flat-panel cone beam).
//!
//! The ramp kernel is applied via FFT along the detector `u` axis, padded
//! to the next power of two ≥ 2·nu to linearize the convolution, exactly
//! as TIGRE's `filtering.m` does.

use crate::geometry::Geometry;
use crate::kernels::fft::{fft, ifft, next_pow2, C64};
use crate::util::threadpool::{parallel_for, SendPtr};
use crate::volume::ProjectionSet;

/// Apodization window applied on top of the ramp.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Window {
    /// Pure ramp (Ram-Lak).
    RamLak,
    /// Ramp × Hann window — suppresses high-frequency noise.
    Hann,
    /// Ramp × Shepp-Logan (sinc) window.
    SheppLogan,
    /// Ramp × cosine window.
    Cosine,
}

/// Spatial-domain Ram-Lak kernel sampled at pixel pitch `du`
/// (Kak & Slaney eq. 61): h[0]=1/(4du²), h[odd n]=−1/(π n du)², h[even]=0.
pub fn ramlak_kernel(half_len: usize, du: f64) -> Vec<f64> {
    let mut h = vec![0.0; 2 * half_len + 1];
    for (i, v) in h.iter_mut().enumerate() {
        let n = i as isize - half_len as isize;
        if n == 0 {
            *v = 1.0 / (4.0 * du * du);
        } else if n % 2 != 0 {
            let pnd = std::f64::consts::PI * n as f64 * du;
            *v = -1.0 / (pnd * pnd);
        }
    }
    h
}

/// Frequency response of the filter over `m` FFT bins: FFT of the padded
/// spatial ramp, then the apodization window in frequency.
fn filter_spectrum(m: usize, du: f64, window: Window) -> Vec<f64> {
    // Build the spatial kernel centred at 0 (wrap negative taps).
    let half = m / 2;
    let h = ramlak_kernel(half, du);
    let mut spec: Vec<C64> = vec![(0.0, 0.0); m];
    for (i, &v) in h.iter().enumerate() {
        let n = i as isize - half as isize;
        let idx = n.rem_euclid(m as isize) as usize;
        spec[idx].0 += v;
    }
    fft(&mut spec);
    // The ramp spectrum is real and non-negative; take the magnitude and
    // apply the window as a function of normalized frequency.
    (0..m)
        .map(|k| {
            let mag = (spec[k].0 * spec[k].0 + spec[k].1 * spec[k].1).sqrt();
            // normalized frequency in [0,1]: 0 at DC, 1 at Nyquist
            let f = if k <= m / 2 { k as f64 } else { (m - k) as f64 } / (m as f64 / 2.0);
            let w = match window {
                Window::RamLak => 1.0,
                Window::Hann => 0.5 * (1.0 + (std::f64::consts::PI * f).cos()),
                Window::SheppLogan => {
                    if f == 0.0 {
                        1.0
                    } else {
                        let x = std::f64::consts::PI * f / 2.0;
                        x.sin() / x
                    }
                }
                Window::Cosine => (std::f64::consts::PI * f / 2.0).cos(),
            };
            mag * w
        })
        .collect()
}

/// Filter a projection set in place for FDK reconstruction:
/// 1. cosine pre-weight `DSD / √(DSD² + u² + v²)` per pixel,
/// 2. ramp-filter every detector row along `u`,
/// 3. scale by the FDK constants (pixel pitch × angular step / 2).
pub fn fdk_filter(g: &Geometry, proj: &mut ProjectionSet, window: Window, threads: usize) {
    let nu = g.n_det[0];
    let nv = g.n_det[1];
    let n_angles = g.n_angles();
    let du = g.d_det[0];
    let dsd = g.dsd;

    let m = next_pow2(2 * nu);
    let spec = filter_spectrum(m, du, window);

    // FDK scale: Δθ/2 for the angular integral plus the `du` from the
    // discrete convolution.
    let dtheta = if n_angles > 1 {
        let span = angular_span(&g.angles);
        span / n_angles as f64
    } else {
        2.0 * std::f64::consts::PI
    };
    let scale = (du * dtheta / 2.0) as f32;

    // cosine pre-weights, shared across angles
    let mut cosw = vec![0.0f32; nu * nv];
    for iv in 0..nv {
        let v = (iv as f64 + 0.5 - nv as f64 / 2.0) * g.d_det[1] + g.offset_det[1];
        for iu in 0..nu {
            let u = (iu as f64 + 0.5 - nu as f64 / 2.0) * du + g.offset_det[0];
            cosw[iv * nu + iu] = (dsd / (dsd * dsd + u * u + v * v).sqrt()) as f32;
        }
    }

    let rows = n_angles * nv;
    let ptr = SendPtr(proj.data.as_mut_ptr());
    parallel_for(rows, threads, 4, |r0, r1| {
        let ptr = ptr;
        let mut line: Vec<C64> = vec![(0.0, 0.0); m];
        for row in r0..r1 {
            let a = row / nv;
            let iv = row % nv;
            let base = (a * nv + iv) * nu;
            // load row with cosine weighting, zero-pad
            for v in line.iter_mut() {
                *v = (0.0, 0.0);
            }
            // SAFETY: parallel_for hands each task a disjoint range of
            // detector rows; base = (a*nv+iv)*nu stays inside
            // proj.data.len() = n_angles*nv*nu, and this task is the only
            // reader/writer of its rows.
            unsafe {
                for iu in 0..nu {
                    let x = *ptr.0.add(base + iu) * cosw[iv * nu + iu];
                    line[iu] = (x as f64, 0.0);
                }
            }
            fft(&mut line);
            for (k, v) in line.iter_mut().enumerate() {
                v.0 *= spec[k];
                v.1 *= spec[k];
            }
            ifft(&mut line);
            // SAFETY: same disjoint-row bounds as the read above — this
            // write-back touches only this task's rows.
            unsafe {
                for iu in 0..nu {
                    *ptr.0.add(base + iu) = line[iu].0 as f32 * scale;
                }
            }
        }
    });
}

/// Angular span covered by an angle list (assumes uniform spacing).
fn angular_span(angles: &[f64]) -> f64 {
    if angles.len() < 2 {
        return 2.0 * std::f64::consts::PI;
    }
    let step = angles[1] - angles[0];
    step.abs() * angles.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ramlak_kernel_structure() {
        let h = ramlak_kernel(4, 1.0);
        assert_eq!(h.len(), 9);
        assert!((h[4] - 0.25).abs() < 1e-12); // centre 1/(4du²)
        assert_eq!(h[4 + 2], 0.0); // even taps zero
        assert!(h[4 + 1] < 0.0); // odd taps negative
        assert!((h[4 - 1] - h[4 + 1]).abs() < 1e-15); // symmetric
    }

    #[test]
    fn spectrum_is_ramp_like() {
        let spec = filter_spectrum(64, 1.0, Window::RamLak);
        // DC ~ 0, rises monotonically to Nyquist
        assert!(spec[0].abs() < 1e-2);
        assert!(spec[1] < spec[8] && spec[8] < spec[31]);
        // symmetric: bin k equals bin m-k
        for k in 1..32 {
            assert!((spec[k] - spec[64 - k]).abs() < 1e-9);
        }
    }

    #[test]
    fn hann_suppresses_high_freq() {
        let ram = filter_spectrum(64, 1.0, Window::RamLak);
        let han = filter_spectrum(64, 1.0, Window::Hann);
        assert!(han[31] < ram[31] * 0.2, "Nyquist suppressed");
        assert!((han[1] - ram[1]).abs() / ram[1] < 0.01, "low freq preserved");
    }

    #[test]
    fn filtering_removes_dc() {
        // A constant projection row has only DC; the ramp kills it.
        let g = Geometry::cone_beam(16, 3);
        let mut p = ProjectionSet::zeros_like(&g);
        for v in &mut p.data {
            *v = 1.0;
        }
        fdk_filter(&g, &mut p, Window::RamLak, 2);
        // Away from edges the filtered row should be close to zero
        // (not exactly: the row is finite so edges ring).
        let mid = p.at(8, 8, 0).abs();
        assert!(mid < 0.05, "dc residue {mid}");
    }

    #[test]
    fn filtering_is_linear() {
        let g = Geometry::cone_beam(16, 2);
        let mut rng = crate::util::pcg::Pcg32::new(8);
        let mut p1 = ProjectionSet::zeros_like(&g);
        for v in &mut p1.data {
            *v = rng.next_f32();
        }
        let mut p2 = p1.clone();
        for v in &mut p2.data {
            *v *= 2.0;
        }
        fdk_filter(&g, &mut p1, Window::Hann, 2);
        fdk_filter(&g, &mut p2, Window::Hann, 2);
        for (a, b) in p1.data.iter().zip(&p2.data) {
            assert!((2.0 * a - b).abs() < 1e-4 + 1e-3 * b.abs());
        }
    }

    #[test]
    fn threaded_matches_single() {
        let g = Geometry::cone_beam(16, 3);
        let mut rng = crate::util::pcg::Pcg32::new(4);
        let mut p1 = ProjectionSet::zeros_like(&g);
        for v in &mut p1.data {
            *v = rng.next_f32();
        }
        let mut p4 = p1.clone();
        fdk_filter(&g, &mut p1, Window::RamLak, 1);
        fdk_filter(&g, &mut p4, Window::RamLak, 4);
        assert_eq!(p1.data, p4.data);
    }
}
