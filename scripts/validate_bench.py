#!/usr/bin/env python3
"""Validate a tigre bench-trajectory JSON file (schema + non-empty runs).

Shared by every CI validation step (replaces the per-step heredocs):

    validate_bench.py PATH SCHEMA [--require-prefixes a,b,c] [--allow-empty-runs]

Checks
  * the document parses and its `schema` field equals SCHEMA;
  * `runs` is a list; unless --allow-empty-runs, it is non-empty and the
    last run has a non-empty `entries` list (the seed gate for tracked
    trajectories);
  * every entry of the last run passes the per-schema numeric checks
    (kernels: median_s/samples/throughput; coordinator:
    sequential_median_s/pipelined_median_s/samples/speedup);
  * coordinator runs only: every `merge ...` ablation entry at >= 8
    devices (name contains `gpus=K`, K >= 8) must report speedup > 1 —
    the reduction tree shortening the merge critical path at scale is a
    tracked acceptance property, not just a data point;
  * coordinator runs only: every `fault ...` ablation entry must report
    1 < speedup < 2 — for these entries `speedup` is the recovery
    overhead factor (faulted makespan / clean makespan), and a single
    retried transient launch must cost something yet never double the
    run (the tracked recovery-overhead acceptance gate);
  * coordinator runs only: every `degrade ...` ablation entry must
    report 1 < speedup < 2 — for these entries `speedup` is the
    degradation overhead factor (pressure-replanned makespan / clean
    makespan), and surviving one exhausted allocation via the pressure
    ladder (evict -> refine -> spill) must cost something yet never
    double the run (the tracked graceful-degradation acceptance gate);
  * coordinator runs only: every `sparse ...` ablation entry must report
    speedup > 1 — for these entries `speedup` compares K ray-driven
    sweeps against one cold (matrix build) + K-1 warm SpMV sweeps, and
    the one-time CSR build amortizing within the sweep is the tracked
    acceptance property of the sparse projector backend;
  * when --require-prefixes is given, each comma-separated prefix matches
    at least one entry name of the last run.

Exit code 0 = valid; 1 = validation failure; 2 = usage error.
"""

import argparse
import json
import sys


def fail(msg: str) -> None:
    sys.exit(f"validate_bench: {msg}")


def check_entry(schema: str, entry: dict) -> None:
    name = entry.get("name", "<unnamed>")
    if schema.startswith("tigre-bench-kernels/"):
        numeric = ("median_s", "throughput")
        counts = ("samples",)
    elif schema.startswith("tigre-bench-coordinator/"):
        numeric = ("sequential_median_s", "pipelined_median_s", "speedup")
        counts = ("samples",)
    else:
        fail(f"unknown schema family '{schema}'")
    for key in numeric:
        value = entry.get(key)
        if not isinstance(value, (int, float)) or value <= 0:
            fail(f"entry '{name}': {key} must be a positive number, got {value!r}")
    for key in counts:
        value = entry.get(key)
        if not isinstance(value, int) or value < 1:
            fail(f"entry '{name}': {key} must be an integer >= 1, got {value!r}")
    if schema.startswith("tigre-bench-coordinator/") and name.startswith("merge"):
        check_merge_entry(name, entry)
    if schema.startswith("tigre-bench-coordinator/") and name.startswith("fault"):
        check_fault_entry(name, entry)
    if schema.startswith("tigre-bench-coordinator/") and name.startswith("degrade"):
        check_degrade_entry(name, entry)
    if schema.startswith("tigre-bench-coordinator/") and name.startswith("sparse"):
        check_sparse_entry(name, entry)


def parse_gpus(name: str) -> int:
    """Extract the device count from a 'gpus=K' token in an entry name."""
    for token in name.split():
        if token.startswith("gpus="):
            try:
                return int(token.removeprefix("gpus="))
            except ValueError:
                fail(f"entry '{name}': unparseable device count {token!r}")
    fail(f"entry '{name}': ablation entries must carry a 'gpus=K' token")


def check_fault_entry(name: str, entry: dict) -> None:
    """Fault-ablation acceptance: recovery overhead in (1, 2) at any scale.

    For `fault ...` entries `speedup` = faulted / clean makespan. One
    injected transient must register (> 1) but its bounded retry backoff
    must never double the run (< 2).
    """
    parse_gpus(name)  # names must stay machine-parsable per device count
    overhead = entry.get("speedup", 0)
    if not 1.0 < overhead < 2.0:
        fail(
            f"entry '{name}': recovery overhead must lie in (1, 2), "
            f"got {overhead!r}"
        )


def check_degrade_entry(name: str, entry: dict) -> None:
    """Degradation-ablation acceptance: replanning overhead in (1, 2).

    For `degrade ...` entries `speedup` = pressure-replanned / clean
    makespan. One exhausted allocation must register (> 1) — the ladder
    charges the discarded attempt's retry backoffs plus a replan — but
    completing on the refined plan must never double the run (< 2).
    """
    parse_gpus(name)  # names must stay machine-parsable per device count
    overhead = entry.get("speedup", 0)
    if not 1.0 < overhead < 2.0:
        fail(
            f"entry '{name}': degradation overhead must lie in (1, 2), "
            f"got {overhead!r}"
        )


def check_sparse_entry(name: str, entry: dict) -> None:
    """Sparse-ablation acceptance: the CSR build must amortize (> 1).

    For `sparse ...` entries `speedup` = (K ray-driven sweeps) / (one
    cold build-and-SpMV sweep + K-1 warm SpMV sweeps). Past the cost
    model's ~7-8 iteration crossover the precomputed matrix must win at
    every device count; speedup <= 1 means the build never paid off.
    """
    parse_gpus(name)  # names must stay machine-parsable per device count
    speedup = entry.get("speedup", 0)
    if speedup <= 1.0:
        fail(
            f"entry '{name}': the CSR build must amortize over the sweep "
            f"(speedup > 1), got {speedup!r}"
        )


def check_merge_entry(name: str, entry: dict) -> None:
    """Merge-ablation acceptance: the tree must win at >= 8 devices."""
    gpus = parse_gpus(name)
    if gpus >= 8 and entry.get("speedup", 0) <= 1.0:
        fail(
            f"entry '{name}': reduction tree must beat the linear fold at "
            f"{gpus} devices, got speedup {entry.get('speedup')!r}"
        )


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("path", help="trajectory JSON file")
    parser.add_argument("schema", help="expected schema tag")
    parser.add_argument(
        "--require-prefixes",
        default="",
        help="comma-separated entry-name prefixes the last run must contain",
    )
    parser.add_argument(
        "--allow-empty-runs",
        action="store_true",
        help="accept runs: [] (schema-only check for not-yet-seeded files)",
    )
    args = parser.parse_args()

    try:
        with open(args.path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{args.path}: {e}")

    if doc.get("schema") != args.schema:
        fail(f"{args.path}: schema {doc.get('schema')!r} != expected {args.schema!r}")
    runs = doc.get("runs")
    if not isinstance(runs, list):
        fail(f"{args.path}: 'runs' must be a list")
    if not runs:
        if args.allow_empty_runs:
            print(f"ok: {args.path} is schema-valid (no measured runs yet)")
            return
        fail(
            f"{args.path}: unseeded trajectory (runs: []) — run the bench commands in "
            "EXPERIMENTS.md and commit the JSON"
        )

    last = runs[-1]
    entries = last.get("entries")
    if not isinstance(entries, list) or not entries:
        fail(f"{args.path}: last run '{last.get('label')}' has no entries")
    for entry in entries:
        check_entry(args.schema, entry)

    names = [e.get("name", "") for e in entries]
    for prefix in filter(None, args.require_prefixes.split(",")):
        if not any(n.startswith(prefix) for n in names):
            fail(f"{args.path}: last run has no entry with prefix '{prefix}'")

    print(
        f"ok: {args.path} run '{last.get('label')}' has {len(entries)} valid entries "
        f"({len(runs)} run(s) total)"
    )


if __name__ == "__main__":
    main()
