//! Partition planner: how many image slabs / projection chunks fit on the
//! devices (the memory arithmetic behind Algorithms 1 & 2).
//!
//! The paper's strategy: keep only **2 projection-chunk buffers** on each
//! device (plus a third to stream in previously-computed partials when the
//! image is split) and give **all remaining device RAM to the image slab**
//! — that minimizes the number of image partitions, which is the dominant
//! cost driver.
//!
//! Kernel-geometry constants follow the paper:
//!  * projection kernel processes `N_angles = 9` whole projections per
//!    launch (thread blocks 9×9×9, footnote 1),
//!  * backprojection processes `N_angles = 32` projections per launch and
//!    updates `N_z = 8` slices per thread (footnote 2).

use crate::geometry::split::{split_even, AngleChunk, ZSlab};
use crate::geometry::Geometry;
use crate::util::units::F32_BYTES;

/// Projections computed per FP kernel launch (paper footnote 1).
pub const FP_CHUNK_ANGLES: usize = 9;
/// Projections consumed per BP kernel launch (paper footnote 2).
pub const BP_CHUNK_ANGLES: usize = 32;
/// Axial slices each BP thread updates (paper footnote 2).
pub const BP_NZ_PER_THREAD: usize = 8;

/// Splitting configuration.
#[derive(Clone, Debug)]
pub struct SplitConfig {
    /// Projections computed per FP kernel launch.
    pub fp_chunk: usize,
    /// Projections consumed per BP kernel launch.
    pub bp_chunk: usize,
    /// Fraction of device RAM usable (contexts, fragmentation).
    pub mem_fraction: f64,
}

impl Default for SplitConfig {
    fn default() -> Self {
        Self { fp_chunk: FP_CHUNK_ANGLES, bp_chunk: BP_CHUNK_ANGLES, mem_fraction: 1.0 }
    }
}

/// How the per-device partial projections of an image-split forward
/// projection are folded into the final projection set (ISSUE 6 /
/// DESIGN.md §Reduction-tree). Angle-split forward and backprojection
/// write disjoint output regions, so the strategy is a no-op there.
///
/// Both strategies execute the **same canonical pairwise schedule**
/// ([`merge_schedule`]) — identical fold pairings, identical operand
/// order — so their outputs are bit-identical; they differ only in
/// *where/when* the folds run (serial host passes vs. overlapped
/// pairwise worker folds, and in the simulated timeline host `+=`
/// passes vs. peer-to-peer device links).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MergeStrategy {
    /// Serial host-side folds: one `+=` pass per schedule pair, executed
    /// on the host thread after the workers join. Host-bound: the merge
    /// critical path grows linearly with the device count.
    #[default]
    Linear,
    /// Log-depth pairwise reduction tree: each round, worker `i` folds
    /// worker `i + stride`'s partial (overlapped with other workers'
    /// in-flight kernel launches); the simulated timeline models the
    /// rounds as peer-to-peer device transfers plus on-device
    /// accumulation kernels.
    Tree,
}

/// The canonical pairwise merge schedule over `n` partials, as rounds of
/// `(dst, src)` folds meaning `partial[dst] += partial[src]` (in that
/// operand order). Stride-doubling pairing: round `r` (stride `2^r`)
/// folds `i + stride` into `i` for every `i` divisible by `2·stride`;
/// indices with no partner get a bye. Index 0 is always the final root.
///
/// Properties (pinned by unit tests below):
/// * every index except 0 appears as `src` exactly once, so `n−1` folds
///   total — the same folds a linear accumulation performs;
/// * pairs within a round are disjoint, so rounds can run in parallel;
/// * `⌈log₂ n⌉` rounds — the tree's critical path.
///
/// **Both** merge strategies execute exactly this schedule (Linear runs
/// it serially, Tree runs each round's pairs concurrently), which is
/// what makes tree-vs-linear output bit-identity structural rather than
/// a floating-point accident.
pub fn merge_schedule(n: usize) -> Vec<Vec<(usize, usize)>> {
    let mut rounds = Vec::new();
    let mut stride = 1;
    while stride < n {
        let round: Vec<(usize, usize)> =
            (0..n).step_by(2 * stride).filter(|i| i + stride < n).map(|i| (i, i + stride)).collect();
        if !round.is_empty() {
            rounds.push(round);
        }
        stride *= 2;
    }
    rounds
}

/// Degraded-mode replanning after permanent device loss (ISSUE 7):
/// given the per-device loss flags, return `owner[d]` — the surviving
/// device that executes device `d`'s remaining units (identity for
/// survivors; for a lost device, the cyclic-next survivor after it in
/// device order). The unit partition itself is **immutable** — only
/// ownership moves — so the canonical [`merge_schedule`] still folds the
/// same per-assignment partials in the same order and recovered output
/// stays bit-identical to the fault-free run. Errors when every device
/// is lost.
pub fn replan_excluding(n: usize, lost: &[bool]) -> Result<Vec<usize>, String> {
    let is_lost = |d: usize| lost.get(d).copied().unwrap_or(false);
    if (0..n).all(is_lost) {
        return Err(format!("replan: all {n} devices lost, no survivors"));
    }
    Ok((0..n)
        .map(|d| {
            if !is_lost(d) {
                d
            } else {
                // the all-lost case returned Err above, so a survivor
                // exists; `d` is unreachable but keeps the scan total
                (1..n)
                    .map(|k| (d + k) % n)
                    .find(|&s| !is_lost(s))
                    .unwrap_or(d)
            }
        })
        .collect())
}

/// Which projector family a plan's simulated timeline should cost:
/// ray-driven kernels (Siddon/Joseph) or the precomputed sparse CSR
/// system matrix (ISSUE 10 / DESIGN.md §Sparse-projector). Stamped by
/// `forward::run_with` / `backward::run_with` from the executor's
/// [`Backend`](crate::coordinator::executor::Backend), mirroring the
/// [`Plan::merge`] stamping pattern, so direct `simulate` callers can
/// also select it by hand.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PlanProjector {
    /// Ray-driven FP/BP kernels; per-unit time comes from
    /// `CostModel::fp_slab_kernel_s` / `bp_kernel_s`.
    #[default]
    Ray,
    /// Precomputed CSR shards; per-unit time is `spmv_s` / `spmvt_s`
    /// over the shard's estimated nnz, plus `sparse_setup_s` when the
    /// shard is cold (not yet in the
    /// [`SparseShardCache`](crate::coordinator::residency::SparseShardCache)).
    Sparse {
        /// True when every shard this plan touches is already resident,
        /// so the timeline charges no build time (2nd+ iterations).
        warm: bool,
    },
}

/// The work assigned to one device.
#[derive(Clone, Debug)]
pub struct DeviceAssignment {
    /// Device index the assignment belongs to.
    pub device: usize,
    /// The z-range of the whole volume owned by this device.
    pub z_range: ZSlab,
    /// That range, split into slabs that fit in device RAM.
    pub slabs: Vec<ZSlab>,
}

/// A complete partition plan for one operator call.
#[derive(Clone, Debug)]
pub struct Plan {
    /// One assignment per participating device.
    pub per_device: Vec<DeviceAssignment>,
    /// Angle chunks processed per kernel launch.
    pub angle_chunks: Vec<AngleChunk>,
    /// Number of on-device projection buffers (2, or 3 when partial
    /// accumulation streams are needed — FP with a split image).
    pub n_proj_buffers: usize,
    /// Bytes of one projection-chunk buffer.
    pub proj_buffer_bytes: u64,
    /// Bytes of the largest slab allocation.
    pub max_slab_bytes: u64,
    /// Whether the host image memory should be page-locked (paper §2.1/2.2
    /// policy; see [`should_pin_image`]).
    pub pin_image: bool,
    /// True if any device processes more than one slab (image larger than
    /// the devices' aggregate capacity).
    pub image_split: bool,
    /// Forward projection without an image split keeps the *entire*
    /// volume resident on every device (angles are split instead).
    pub full_image_per_device: bool,
    /// Host-RAM budget the plan's streaming working set must fit in
    /// (`None` for in-RAM plans, which borrow resident arrays instead of
    /// staging). Set by the `plan_*_ooc` planners; enforced by
    /// [`Plan::validate`] via [`Plan::host_working_set_bytes`].
    pub host_budget_bytes: Option<u64>,
    /// The volume side of this plan streams from/to an `OocVolume`
    /// (forward input; backward output when the caller stores slabs).
    /// Drives the disk-read/-write events of the simulated timeline.
    pub ooc_volume: bool,
    /// The projection input streams from an `OocProjections` store
    /// (backprojection chunks).
    pub ooc_proj: bool,
    /// How image-split forward partials are folded (no-op for every
    /// other operator shape). `forward::run_with` re-stamps this from
    /// `ExecutorConfig::merge`, so it only matters for callers driving
    /// [`crate::coordinator::forward::simulate`] directly.
    pub merge: MergeStrategy,
    /// Projector family the simulated timeline costs (ray-driven vs
    /// sparse CSR). Like [`Plan::merge`], the executor entry points
    /// re-stamp this from the active
    /// [`Backend`](crate::coordinator::executor::Backend); it only
    /// matters for callers driving `simulate` directly.
    pub projector: PlanProjector,
}

impl Plan {
    /// Total image partitions per device (the `N_sp` of Algorithms 1 & 2).
    pub fn splits_per_device(&self) -> usize {
        self.per_device.iter().map(|d| d.slabs.len()).max().unwrap_or(0)
    }

    /// Contiguous angle-chunk shares per device for the angle-split
    /// forward path (image resident on every device, no accumulation):
    /// device `d` computes chunks `[shares[d].0, shares[d].1)`. Shared by
    /// the simulated schedule and both real executors so their device ↔
    /// work mapping can never drift apart.
    pub fn chunk_shares(&self, n_gpus: usize) -> Vec<(usize, usize)> {
        split_even(self.angle_chunks.len(), n_gpus)
    }

    /// Transient working set one operator call needs on a device beyond
    /// anything the residency cache may keep resident: the projection
    /// buffers plus the largest staged unit the schedule cycles through.
    /// For the angle-split forward the "staged unit" is the full image
    /// (counted even though it is the cacheable unit — the conservative
    /// double-count is what guarantees a resident buffer can never push a
    /// later call over device capacity); for slab-cycling plans it is the
    /// largest slab. `coordinator::residency` derives the per-device
    /// cache budget as `usable − max(FP, BP working set)`.
    pub fn working_set_bytes(&self, g: &Geometry) -> u64 {
        let bufs = self.n_proj_buffers as u64 * self.proj_buffer_bytes;
        let staged = if self.full_image_per_device {
            g.volume_bytes()
        } else {
            self.max_slab_bytes
        };
        bufs + staged
    }

    /// Host-RAM bytes the *streaming tier* of this plan adds: the OOC
    /// loader-lane staging buffers (and the one-off materialized volume
    /// of an angle-split OOC forward). In-RAM plans stage through
    /// zero-copy borrows, so their streaming working set is zero.
    ///
    /// Scope — what the host budget deliberately does **not** bound
    /// (all common to the RAM and OOC execution paths, so bounding them
    /// here would make budgets below the projection footprint
    /// unplannable rather than honest): the caller's own arrays
    /// (outputs, iterates — spill those via `OocVolume` when they must
    /// leave RAM), the per-worker merge-lane stage buffers, and the
    /// image-split forward's per-device partial projection sets, which
    /// are full-size at any slab granularity. The budget mirrors the
    /// device-side semantics of `coordinator::residency`: it governs
    /// what the *new tier* adds, not the executor's pre-existing
    /// machinery.
    pub fn host_working_set_bytes(&self, g: &Geometry) -> u64 {
        let n_active = self
            .per_device
            .iter()
            .filter(|d| !d.slabs.is_empty())
            .count()
            .max(1) as u64;
        let mut ws = 0;
        if self.ooc_volume {
            ws += if self.full_image_per_device {
                // angle-split forward: the volume is materialized once
                // from the store and shared by every worker
                g.volume_bytes()
            } else {
                // slab cycling: two loader-lane staging slabs per worker
                n_active * 2 * self.max_slab_bytes
            };
        }
        if self.ooc_proj {
            // chunk streaming: two loader-lane chunk buffers per worker
            ws += n_active * 2 * self.proj_buffer_bytes;
        }
        ws
    }

    /// Rounds of the canonical pairwise merge schedule over this plan's
    /// *active* devices (those that own at least one slab); pair indices
    /// are positions in the compacted active-device list, matching both
    /// the pipelined executor's worker indices and the simulated
    /// timeline's active-device enumeration.
    pub fn merge_rounds(&self) -> Vec<Vec<(usize, usize)>> {
        merge_schedule(self.per_device.iter().filter(|d| !d.slabs.is_empty()).count())
    }

    /// Select the merge strategy (for direct `simulate` callers; the
    /// executor entry points stamp this from `ExecutorConfig` instead).
    pub fn with_merge(mut self, merge: MergeStrategy) -> Self {
        self.merge = merge;
        self
    }

    /// Select the projector family the simulated timeline costs (for
    /// direct `simulate` callers; the executor entry points stamp this
    /// from the active `Backend` instead).
    pub fn with_plan_projector(mut self, projector: PlanProjector) -> Self {
        self.projector = projector;
        self
    }

    /// Mark the plan's volume side as out-of-core for the simulated
    /// timeline: a backward plan then charges a disk write for every
    /// output slab spilled after its D2H (the `OocVolume::store_slab` /
    /// `add_scaled_volume` writeback the caller performs when the
    /// iterate lives out of core). `SimOnly` sweeps use this to predict
    /// the spill cost; the real executors always return RAM volumes.
    pub fn with_ooc_volume_spill(mut self) -> Self {
        self.ooc_volume = true;
        self
    }

    /// Sanity invariants; used by property tests.
    pub fn validate(&self, g: &Geometry, mem_bytes: u64, cfg: &SplitConfig) -> Result<(), String> {
        // slabs of each device tile its z-range, contiguously, non-empty
        for d in &self.per_device {
            if d.slabs.is_empty() {
                if d.z_range.len() > 0 {
                    return Err(format!("device {} has z-range but no slabs", d.device));
                }
                continue;
            }
            match (d.slabs.first(), d.slabs.last()) {
                (Some(first), Some(last))
                    if first.z0 == d.z_range.z0 && last.z1 == d.z_range.z1 => {}
                _ => {
                    return Err(format!("device {} slabs do not tile its range", d.device));
                }
            }
            for w in d.slabs.windows(2) {
                if w[0].z1 != w[1].z0 {
                    return Err("slabs not contiguous".into());
                }
            }
            // memory bound: resident image + buffers must fit
            let plane = (g.n_vox[0] * g.n_vox[1]) as u64 * F32_BYTES;
            let cap = (mem_bytes as f64 * cfg.mem_fraction) as u64;
            if self.full_image_per_device {
                let need =
                    g.volume_bytes() + self.n_proj_buffers as u64 * self.proj_buffer_bytes;
                if need > cap {
                    return Err(format!(
                        "device {}: full image + buffers need {need} B > capacity {cap} B",
                        d.device
                    ));
                }
            }
            for s in &d.slabs {
                let need =
                    s.len() as u64 * plane + self.n_proj_buffers as u64 * self.proj_buffer_bytes;
                if need > cap {
                    return Err(format!(
                        "device {}: slab of {} slices needs {need} B > capacity {cap} B",
                        d.device,
                        s.len()
                    ));
                }
            }
        }
        // device ranges tile the volume
        let mut z = 0;
        for d in &self.per_device {
            if d.z_range.z0 != z {
                return Err("device z-ranges not contiguous".into());
            }
            z = d.z_range.z1;
        }
        if z != g.n_vox[2] {
            return Err("device z-ranges do not cover the volume".into());
        }
        // angle chunks tile the angles
        let mut a = 0;
        for c in &self.angle_chunks {
            if c.a0 != a {
                return Err("angle chunks not contiguous".into());
            }
            a = c.a1;
        }
        if a != g.n_angles() {
            return Err("angle chunks do not cover all angles".into());
        }
        // host-memory budget dimension (out-of-core plans)
        if let Some(h) = self.host_budget_bytes {
            let ws = self.host_working_set_bytes(g);
            if ws > h {
                return Err(format!(
                    "host streaming working set {ws} B exceeds the host budget {h} B"
                ));
            }
        }
        Ok(())
    }
}

/// Page-lock policy (paper §2.1–2.2): pin when the image must be split
/// (1–2 GPUs: pays off despite the cost) and always on >2 GPUs (enables
/// the simultaneous copies).
pub fn should_pin_image(image_split: bool, n_gpus: usize) -> bool {
    image_split || n_gpus > 2
}

/// Device memory that forces the **image-split** regime for `g` under
/// both planners — room for FP's three (or BP's two) chunk buffers plus a
/// 6-slice slab, well below full-volume residency. The single source of
/// the "tiny device" threshold used by the executor/parity tests and the
/// `bench::coordinator` acceptance workload, so it tracks the buffer
/// arithmetic above instead of drifting as hand-copied constants.
pub fn image_split_mem(g: &Geometry, cfg: &SplitConfig) -> u64 {
    let plane = (g.n_vox[0] * g.n_vox[1]) as u64 * F32_BYTES;
    let fp_bufs = 3 * cfg.fp_chunk.min(g.n_angles()).max(1) as u64 * g.single_proj_bytes();
    let bp_bufs = 2 * cfg.bp_chunk.min(g.n_angles()).max(1) as u64 * g.single_proj_bytes();
    let usable_target = fp_bufs.max(bp_bufs) + 6 * plane;
    // The planners derive usable memory as `mem · mem_fraction`; invert
    // that here (+1 byte against float truncation) so the *usable* budget
    // hits the target for any configured fraction, not just the default.
    (usable_target as f64 / cfg.mem_fraction.max(f64::EPSILON)).ceil() as u64 + 1
}

/// Plan the forward projection (Algorithm 1).
///
/// The image is distributed across devices by z (each device projects its
/// own sub-image over **all** angles, producing partial projections that
/// are accumulated), and each device's share is further split into slabs
/// that fit next to the projection buffers.
pub fn plan_forward(
    g: &Geometry,
    n_gpus: usize,
    mem_bytes: u64,
    cfg: &SplitConfig,
) -> Result<Plan, String> {
    plan_operator(g, n_gpus, mem_bytes, cfg, cfg.fp_chunk, true, false)
}

/// Plan the backprojection (Algorithm 2).
///
/// The image is distributed across devices by z; each device consumes
/// **all** projections, streamed in chunks through a double buffer.
pub fn plan_backward(
    g: &Geometry,
    n_gpus: usize,
    mem_bytes: u64,
    cfg: &SplitConfig,
) -> Result<Plan, String> {
    plan_operator(g, n_gpus, mem_bytes, cfg, cfg.bp_chunk, false, false)
}

fn plan_operator(
    g: &Geometry,
    n_gpus: usize,
    mem_bytes: u64,
    cfg: &SplitConfig,
    chunk: usize,
    is_forward: bool,
    force_image_split: bool,
) -> Result<Plan, String> {
    if n_gpus == 0 {
        return Err("need at least one GPU".into());
    }
    g.validate()?;
    let chunk = chunk.min(g.n_angles()).max(1);
    let nz = g.n_vox[2];
    let plane_bytes = (g.n_vox[0] * g.n_vox[1]) as u64 * F32_BYTES;
    let proj_buffer_bytes = chunk as u64 * g.single_proj_bytes();
    let usable = (mem_bytes as f64 * cfg.mem_fraction) as u64;

    // Device z-ranges: even distribution.
    let ranges = split_even(nz, n_gpus);

    // First try the no-split layout: 2 buffers + the resident image. For
    // the forward projection the whole volume stays on every device
    // (angles split across devices); backprojection only holds the
    // device's own z-range.
    let max_range = ranges.iter().map(|(a, b)| b - a).max().unwrap_or(0);
    let resident = if is_forward { nz } else { max_range };
    let two_buf_need = 2 * proj_buffer_bytes + resident as u64 * plane_bytes;
    let (n_buffers, image_split, slabs_per_device): (usize, bool, Vec<Vec<ZSlab>>) =
        if !force_image_split && two_buf_need <= usable {
            (
                2,
                false,
                ranges
                    .iter()
                    .map(|&(z0, z1)| if z1 > z0 { vec![ZSlab { z0, z1 }] } else { vec![] })
                    .collect(),
            )
        } else {
            // Image must split: FP needs a 3rd buffer to stream partial
            // projections for on-device accumulation; BP still needs 2.
            let n_buffers = if is_forward { 3 } else { 2 };
            let buf_bytes = n_buffers as u64 * proj_buffer_bytes;
            if usable <= buf_bytes + plane_bytes {
                return Err(format!(
                    "device RAM {usable} B cannot hold {n_buffers} projection buffers \
                     ({buf_bytes} B) plus one image slice ({plane_bytes} B)"
                ));
            }
            let cap_slices = ((usable - buf_bytes) / plane_bytes) as usize;
            let mut all = Vec::with_capacity(n_gpus);
            for &(z0, z1) in &ranges {
                let span = z1 - z0;
                if span == 0 {
                    all.push(vec![]);
                    continue;
                }
                let n_splits = span.div_ceil(cap_slices);
                // "same size volumetric axial slice stacks, as big as
                // possible": balanced equal split into n_splits pieces.
                let slabs = split_even(span, n_splits)
                    .into_iter()
                    .filter(|(a, b)| b > a)
                    .map(|(a, b)| ZSlab { z0: z0 + a, z1: z0 + b })
                    .collect();
                all.push(slabs);
            }
            (n_buffers, true, all)
        };

    let max_slab_bytes = slabs_per_device
        .iter()
        .flatten()
        .map(|s| s.len() as u64 * plane_bytes)
        .max()
        .unwrap_or(0);

    let angle_chunks = crate::geometry::split::split_chunks(g.n_angles(), chunk)
        .into_iter()
        .map(|(a0, a1)| AngleChunk { a0, a1 })
        .collect();

    let per_device = ranges
        .iter()
        .enumerate()
        .map(|(i, &(z0, z1))| DeviceAssignment {
            device: i,
            z_range: ZSlab { z0, z1 },
            slabs: slabs_per_device[i].clone(),
        })
        .collect();

    Ok(Plan {
        per_device,
        angle_chunks,
        n_proj_buffers: n_buffers,
        proj_buffer_bytes,
        max_slab_bytes,
        pin_image: should_pin_image(image_split, n_gpus),
        image_split,
        full_image_per_device: is_forward && !image_split,
        host_budget_bytes: None,
        ooc_volume: false,
        ooc_proj: false,
        merge: MergeStrategy::Linear,
        projector: PlanProjector::Ray,
    })
}

// ---------------------------------------------------------------------------
// out-of-core planners (PR 5): the host-memory budget dimension
// ---------------------------------------------------------------------------

/// Re-split every device's z-range into `n_splits(d)` balanced slabs and
/// refresh `max_slab_bytes`.
fn resplit_slabs(plan: &mut Plan, g: &Geometry, n_splits: impl Fn(usize) -> usize) {
    let plane_bytes = (g.n_vox[0] * g.n_vox[1]) as u64 * F32_BYTES;
    for d in plan.per_device.iter_mut() {
        let span = d.z_range.len();
        if span == 0 {
            continue;
        }
        let n = n_splits(d.device).max(1).min(span);
        d.slabs = split_even(span, n)
            .into_iter()
            .filter(|(a, b)| b > a)
            .map(|(a, b)| ZSlab { z0: d.z_range.z0 + a, z1: d.z_range.z0 + b })
            .collect();
    }
    plan.max_slab_bytes = plan
        .per_device
        .iter()
        .flat_map(|d| &d.slabs)
        .map(|s| s.len() as u64 * plane_bytes)
        .max()
        .unwrap_or(0);
}

/// Shrink a slab-cycling plan's slabs until the loader-lane staging
/// (two slab buffers per active worker) fits `host_budget`.
fn constrain_slabs_to_host_budget(
    plan: &mut Plan,
    g: &Geometry,
    host_budget: u64,
) -> Result<(), String> {
    let plane_bytes = (g.n_vox[0] * g.n_vox[1]) as u64 * F32_BYTES;
    let n_active = plan.per_device.iter().filter(|d| !d.slabs.is_empty()).count().max(1) as u64;
    let cap_slices = (host_budget / (2 * n_active * plane_bytes)) as usize;
    if cap_slices == 0 {
        return Err(format!(
            "host budget {host_budget} B cannot hold two staging slices per worker \
             ({n_active} workers × {plane_bytes} B/slice)"
        ));
    }
    let per_dev_splits: Vec<usize> = plan
        .per_device
        .iter()
        .map(|d| d.z_range.len().div_ceil(cap_slices).max(d.slabs.len()).max(1))
        .collect();
    resplit_slabs(plan, g, |d| per_dev_splits[d]);
    Ok(())
}

/// Largest BP chunk (angles per launch) whose two-buffer loader-lane
/// staging fits `host_budget` across `n_gpus` workers; used by
/// [`plan_backward_ooc`] and by tests that need an in-RAM reference plan
/// with identical chunking.
pub fn ooc_bp_chunk(g: &Geometry, n_gpus: usize, cfg: &SplitConfig, host_budget: u64) -> usize {
    let per = g.single_proj_bytes().max(1);
    let cap = (host_budget / (2 * n_gpus.max(1) as u64 * per)) as usize;
    cfg.bp_chunk.min(cap)
}

/// Plan the forward projection of a volume streamed from an
/// [`crate::volume::OocVolume`] with `host_budget` bytes of host RAM for
/// staging.
///
/// Two regimes:
/// * the volume fits the host budget → the standard plan, with the
///   volume materialized once from the store (angle-split stays
///   available and the disk read is a one-off);
/// * the volume exceeds the host budget → the **image-split** regime is
///   forced even on devices that could hold the full image, because the
///   host can never materialize it: slabs stream disk → host staging →
///   device, sized so two staging slabs per worker respect the budget.
pub fn plan_forward_ooc(
    g: &Geometry,
    n_gpus: usize,
    mem_bytes: u64,
    cfg: &SplitConfig,
    host_budget: u64,
) -> Result<Plan, String> {
    let force_split = g.volume_bytes() > host_budget;
    let mut plan = plan_operator(g, n_gpus, mem_bytes, cfg, cfg.fp_chunk, true, force_split)?;
    plan.ooc_volume = true;
    plan.host_budget_bytes = Some(host_budget);
    if plan.image_split {
        constrain_slabs_to_host_budget(&mut plan, g, host_budget)?;
    }
    plan.validate(g, mem_bytes, cfg)?;
    Ok(plan)
}

/// Plan the backprojection of projections streamed from an
/// [`crate::volume::OocProjections`] store: chunk sizes shrink until two
/// staging chunks per worker fit `host_budget`. (The output volume is
/// the caller's array — write it through `OocVolume::store_slab` when it
/// too must live out of core.)
pub fn plan_backward_ooc(
    g: &Geometry,
    n_gpus: usize,
    mem_bytes: u64,
    cfg: &SplitConfig,
    host_budget: u64,
) -> Result<Plan, String> {
    let chunk = ooc_bp_chunk(g, n_gpus, cfg, host_budget);
    if chunk == 0 {
        return Err(format!(
            "host budget {host_budget} B cannot hold two staging projections per worker"
        ));
    }
    let mut plan = plan_operator(g, n_gpus, mem_bytes, cfg, chunk, false, false)?;
    plan.ooc_proj = true;
    plan.host_budget_bytes = Some(host_budget);
    plan.validate(g, mem_bytes, cfg)?;
    Ok(plan)
}

/// Plan both operators of an out-of-core session together and **align
/// their slab boundaries**: when both plans slab-cycle, each device's
/// range is re-split to the finer of the two partitions so a store slab
/// staged by one pass is byte-identical reusable by the other (FP reads
/// of the iterate, the slab-streamed update `x += s·upd`, BP slab
/// writebacks). Unaligned plans would stage overlapping-but-unequal
/// ranges and the store cache could never hit across passes.
pub fn plan_ooc_pair(
    g: &Geometry,
    n_gpus: usize,
    mem_bytes: u64,
    cfg: &SplitConfig,
    host_budget: u64,
) -> Result<(Plan, Plan), String> {
    let mut fp = plan_forward_ooc(g, n_gpus, mem_bytes, cfg, host_budget)?;
    let mut bp = plan_backward_ooc(g, n_gpus, mem_bytes, cfg, host_budget)?;
    if fp.image_split {
        let fp_counts: Vec<usize> = fp.per_device.iter().map(|d| d.slabs.len()).collect();
        let bp_counts: Vec<usize> = bp.per_device.iter().map(|d| d.slabs.len()).collect();
        resplit_slabs(&mut fp, g, |d| fp_counts[d].max(bp_counts[d]));
        resplit_slabs(&mut bp, g, |d| fp_counts[d].max(bp_counts[d]));
        fp.validate(g, mem_bytes, cfg)?;
        bp.validate(g, mem_bytes, cfg)?;
    }
    Ok((fp, bp))
}

// ---------------------------------------------------------------------------
// memory-pressure refinement (ISSUE 8): rung 2 of the degradation ladder
// ---------------------------------------------------------------------------

/// Refine a plan to smaller units after an allocation failure on
/// `device` (rung 2 of the pressure ladder, after residency eviction
/// and before OOC spill). Returns the refined plan plus a
/// human-readable before → after description for the degradation log.
///
/// The refinement axis is chosen so the output stays **bit-identical**
/// to the original plan (DESIGN.md §Graceful-degradation):
///
/// * **Forward**: halve the angle-chunk size (shrinks the projection
///   buffers). Every angle is computed independently and lands in its
///   own detector region, so chunk boundaries cannot change any
///   per-angle value — for the angle-split shape this also redistributes
///   chunk shares across devices, which is equally harmless because no
///   accumulation crosses angles. Slab refinement is **not** used for
///   FP: splitting a slab regroups the per-ray z-summation and changes
///   the floating-point result.
/// * **Backward**: double the affected device's slab count (shrinks its
///   largest allocation). Slabs write disjoint z-ranges and every slab
///   still consumes all projection chunks in the same order, so the
///   per-voxel accumulation sequence is untouched. Chunk refinement is
///   **not** used for BP: it would regroup the per-voxel chunk
///   accumulation.
///
/// Errs when the axis is exhausted (chunks of 1 angle / slabs of 1
/// slice) — the ladder then falls through to the spill rung.
pub fn refine_for_budget(
    plan: &Plan,
    g: &Geometry,
    is_forward: bool,
    device: usize,
) -> Result<(Plan, String), String> {
    let mut refined = plan.clone();
    if is_forward {
        let max_chunk = plan.angle_chunks.iter().map(|c| c.len()).max().unwrap_or(0);
        if max_chunk <= 1 {
            return Err(format!(
                "fp plan cannot refine below 1-angle chunks (device {device})"
            ));
        }
        let new_chunk = max_chunk.div_ceil(2);
        refined.angle_chunks = crate::geometry::split::split_chunks(g.n_angles(), new_chunk)
            .into_iter()
            .map(|(a0, a1)| AngleChunk { a0, a1 })
            .collect();
        refined.proj_buffer_bytes = new_chunk as u64 * g.single_proj_bytes();
        Ok((refined, format!("fp chunk {max_chunk} -> {new_chunk} angles")))
    } else {
        let Some(d) = plan.per_device.iter().find(|d| d.device == device) else {
            return Err(format!("bp plan has no device {device}"));
        };
        let span = d.z_range.len();
        let before = d.slabs.len();
        if span == 0 || before >= span {
            return Err(format!(
                "bp plan cannot refine device {device} below 1-slice slabs"
            ));
        }
        let after = (before * 2).min(span);
        let counts: Vec<usize> =
            plan.per_device.iter().map(|a| if a.device == device { after } else { a.slabs.len().max(1) }).collect();
        resplit_slabs(&mut refined, g, |dev| counts[dev]);
        refined.image_split =
            refined.per_device.iter().any(|a| a.slabs.len() > 1) || refined.image_split;
        refined.pin_image =
            should_pin_image(refined.image_split, refined.per_device.len());
        Ok((refined, format!("bp d{device} slabs {before} -> {after}")))
    }
}

/// Paper §4 size-limit formulas for an `N³` volume / `N²` detector / `N`
/// angles problem on a device with `mem` bytes:
///
/// * FP with the fast-kernel constants: 1 image slice + one chunk of
///   `FP_CHUNK_ANGLES` projections → `(1 + 9)·N²·4 ≤ mem`.
/// * BP with the fast-kernel constants: `N_z = 8` slices + one chunk of
///   `BP_CHUNK_ANGLES` projections → `(8 + 32)·N²·4 ≤ mem`.
/// * Relaxed (single slice + single projection, double-buffered):
///   `(2 + 2)·N²·4 ≤ mem`.
pub fn max_n_forward(mem: u64) -> u64 {
    ((mem as f64 / ((1 + FP_CHUNK_ANGLES) as f64 * F32_BYTES as f64)).sqrt()) as u64
}

/// Largest cubic `N` a BP launch fits in `mem` bytes (see above).
pub fn max_n_backward(mem: u64) -> u64 {
    ((mem as f64 / ((BP_NZ_PER_THREAD + BP_CHUNK_ANGLES) as f64 * F32_BYTES as f64)).sqrt()) as u64
}

/// Largest cubic `N` under the relaxed double-buffered bound (see above).
pub fn max_n_relaxed(mem: u64) -> u64 {
    ((mem as f64 / (4.0 * F32_BYTES as f64)).sqrt()) as u64
}

#[cfg(test)]
// test-only HashSet validating fold-schedule properties; never shipped
#[allow(clippy::disallowed_types)]
mod tests {
    use super::*;
    use crate::util::prop::{check, prop_assert};
    use crate::util::units::GIB;

    fn fig7_geometry(n: usize) -> Geometry {
        Geometry::cone_beam(n, n)
    }

    /// §3.1: at N = 3072 on 11 GiB devices, the paper reports
    /// FP: 10 (1 GPU) / 5 (2 GPU) partitions; BP: 11 / 6.
    /// Our exact memory accounting lands within one split of those.
    #[test]
    fn splitter_paper_counts() {
        let g = fig7_geometry(3072);
        let mem = 11 * GIB;
        let cfg = SplitConfig::default();

        let fp1 = plan_forward(&g, 1, mem, &cfg).unwrap();
        let fp2 = plan_forward(&g, 2, mem, &cfg).unwrap();
        let bp1 = plan_backward(&g, 1, mem, &cfg).unwrap();
        let bp2 = plan_backward(&g, 2, mem, &cfg).unwrap();

        let fp1_n = fp1.splits_per_device();
        let fp2_n = fp2.splits_per_device();
        let bp1_n = bp1.splits_per_device();
        let bp2_n = bp2.splits_per_device();

        assert!((10..=12).contains(&fp1_n), "FP 1-GPU splits {fp1_n} (paper: 10)");
        assert!((5..=6).contains(&fp2_n), "FP 2-GPU splits {fp2_n} (paper: 5)");
        assert!((11..=13).contains(&bp1_n), "BP 1-GPU splits {bp1_n} (paper: 11)");
        assert!((6..=7).contains(&bp2_n), "BP 2-GPU splits {bp2_n} (paper: 6)");
        // BP needs at least as many splits as FP (bigger angle chunks)
        assert!(bp1_n >= fp1_n);
        // doubling GPUs roughly halves per-device splits
        assert!(fp2_n <= fp1_n / 2 + 1);
    }

    /// §4: maximum-N formulas reproduce the paper's 17000 / 8500 / 27000.
    #[test]
    fn paper_max_size_limits() {
        let mem = 11 * GIB;
        let fp = max_n_forward(mem);
        let bp = max_n_backward(mem);
        let relaxed = max_n_relaxed(mem);
        assert!((16500..18000).contains(&fp), "FP max N = {fp} (paper ≈17000)");
        assert!((8300..8800).contains(&bp), "BP max N = {bp} (paper ≈8500)");
        assert!((26500..27800).contains(&relaxed), "relaxed max N = {relaxed} (paper ≈27000)");
    }

    #[test]
    fn working_set_counts_buffers_plus_staged_unit() {
        let g = fig7_geometry(128);
        // angle-split FP: staged unit is the full image
        let fp = plan_forward(&g, 2, 11 * GIB, &SplitConfig::default()).unwrap();
        assert!(fp.full_image_per_device);
        assert_eq!(
            fp.working_set_bytes(&g),
            fp.n_proj_buffers as u64 * fp.proj_buffer_bytes + g.volume_bytes()
        );
        // BP: staged unit is the largest slab
        let bp = plan_backward(&g, 2, 11 * GIB, &SplitConfig::default()).unwrap();
        assert!(!bp.full_image_per_device);
        assert_eq!(
            bp.working_set_bytes(&g),
            bp.n_proj_buffers as u64 * bp.proj_buffer_bytes + bp.max_slab_bytes
        );
        // the working set always fits the device (plan feasibility)
        assert!(fp.working_set_bytes(&g) <= 11 * GIB);
        assert!(bp.working_set_bytes(&g) <= 11 * GIB);
    }

    #[test]
    fn small_image_no_split_two_buffers() {
        let g = fig7_geometry(128);
        let p = plan_forward(&g, 2, 11 * GIB, &SplitConfig::default()).unwrap();
        assert!(!p.image_split);
        assert_eq!(p.n_proj_buffers, 2);
        assert_eq!(p.splits_per_device(), 1);
        assert!(!p.pin_image, "no pinning needed when everything fits on ≤2 GPUs");
        p.validate(&g, 11 * GIB, &SplitConfig::default()).unwrap();
    }

    #[test]
    fn three_gpus_always_pin() {
        let g = fig7_geometry(128);
        let p = plan_forward(&g, 3, 11 * GIB, &SplitConfig::default()).unwrap();
        assert!(p.pin_image, ">2 GPUs always page-lock (paper §2.1)");
    }

    #[test]
    fn forward_split_gets_third_buffer() {
        let g = fig7_geometry(2048);
        let mem = 2 * GIB; // force splitting
        let p = plan_forward(&g, 1, mem, &SplitConfig::default()).unwrap();
        assert!(p.image_split);
        assert_eq!(p.n_proj_buffers, 3, "FP accumulation needs the extra buffer");
        assert!(p.pin_image);
        let pb = plan_backward(&g, 1, mem, &SplitConfig::default()).unwrap();
        assert_eq!(pb.n_proj_buffers, 2, "BP streams chunks through 2 buffers");
        p.validate(&g, mem, &SplitConfig::default()).unwrap();
        pb.validate(&g, mem, &SplitConfig::default()).unwrap();
    }

    #[test]
    fn error_when_device_too_small_for_one_slice() {
        let g = fig7_geometry(2048);
        // one slice = 2048²·4 = 16 MiB; buffers are ~150 MiB for FP
        let err = plan_forward(&g, 1, 32 << 20, &SplitConfig::default());
        assert!(err.is_err());
    }

    #[test]
    fn more_gpus_than_slices() {
        let mut g = fig7_geometry(64);
        g.n_vox[2] = 2; // 2 slices, 4 GPUs
        let p = plan_forward(&g, 4, 11 * GIB, &SplitConfig::default()).unwrap();
        let nonempty = p.per_device.iter().filter(|d| !d.slabs.is_empty()).count();
        assert_eq!(nonempty, 2);
        p.validate(&g, 11 * GIB, &SplitConfig::default()).unwrap();
    }

    #[test]
    fn ooc_forward_forces_image_split_when_volume_exceeds_host_budget() {
        let g = fig7_geometry(64);
        let cfg = SplitConfig::default();
        // plenty of device RAM: the RAM planner would angle-split...
        let ram = plan_forward(&g, 2, 11 * GIB, &cfg).unwrap();
        assert!(!ram.image_split && ram.full_image_per_device);
        // ...but a host budget of half the volume forces slab streaming
        let budget = g.volume_bytes() / 2;
        let ooc = plan_forward_ooc(&g, 2, 11 * GIB, &cfg, budget).unwrap();
        assert!(ooc.image_split && !ooc.full_image_per_device);
        assert!(ooc.ooc_volume && !ooc.ooc_proj);
        assert_eq!(ooc.host_budget_bytes, Some(budget));
        assert!(
            ooc.host_working_set_bytes(&g) <= budget,
            "staging {} > budget {budget}",
            ooc.host_working_set_bytes(&g)
        );
        ooc.validate(&g, 11 * GIB, &cfg).unwrap();
        // a volume that fits the budget keeps the angle-split plan
        let roomy = plan_forward_ooc(&g, 2, 11 * GIB, &cfg, 2 * g.volume_bytes()).unwrap();
        assert!(!roomy.image_split && roomy.full_image_per_device && roomy.ooc_volume);
        roomy.validate(&g, 11 * GIB, &cfg).unwrap();
    }

    #[test]
    fn ooc_backward_shrinks_chunks_to_the_host_budget() {
        let g = fig7_geometry(64);
        let cfg = SplitConfig::default();
        // budget fits two staging chunks of 4 angles per worker (2 GPUs)
        let budget = 2 * 2 * 4 * g.single_proj_bytes();
        assert_eq!(ooc_bp_chunk(&g, 2, &cfg, budget), 4);
        let p = plan_backward_ooc(&g, 2, 11 * GIB, &cfg, budget).unwrap();
        assert!(p.ooc_proj && !p.ooc_volume);
        assert!(p.angle_chunks.iter().all(|c| c.len() <= 4));
        assert!(p.host_working_set_bytes(&g) <= budget);
        p.validate(&g, 11 * GIB, &cfg).unwrap();
        // a budget below two single projections per worker is infeasible
        assert!(plan_backward_ooc(&g, 2, 11 * GIB, &cfg, g.single_proj_bytes()).is_err());
    }

    #[test]
    fn ooc_pair_aligns_slab_boundaries_across_operators() {
        let g = fig7_geometry(48);
        let cfg = SplitConfig::default();
        let mem = image_split_mem(&g, &cfg); // tiny devices: both split
        let budget = g.volume_bytes() / 2;
        let (fp, bp) = plan_ooc_pair(&g, 2, mem, &cfg, budget).unwrap();
        assert!(fp.image_split);
        for (df, db) in fp.per_device.iter().zip(&bp.per_device) {
            assert_eq!(df.z_range, db.z_range);
            assert_eq!(
                df.slabs, db.slabs,
                "device {}: FP and BP must share one slab partition",
                df.device
            );
        }
        fp.validate(&g, mem, &cfg).unwrap();
        bp.validate(&g, mem, &cfg).unwrap();
    }

    #[test]
    fn validate_rejects_over_budget_streaming_working_set() {
        let g = fig7_geometry(64);
        let cfg = SplitConfig::default();
        let mut p = plan_forward_ooc(&g, 1, 11 * GIB, &cfg, 2 * g.volume_bytes()).unwrap();
        p.host_budget_bytes = Some(16); // absurdly small after the fact
        let err = p.validate(&g, 11 * GIB, &cfg).unwrap_err();
        assert!(err.contains("host"), "{err}");
    }

    #[test]
    fn prop_plans_valid_across_random_configs() {
        check("operator plans always valid", 120, |gen| {
            let n = gen.usize(8, 160);
            let n_angles = gen.usize(1, 64);
            let n_gpus = gen.usize(1, 4);
            // device memory from "comically small but feasible" upward
            let g = Geometry::cone_beam(n, n_angles);
            let cfg = SplitConfig::default();
            let min_fp = 3 * cfg.fp_chunk as u64 * g.single_proj_bytes()
                + 2 * (g.n_vox[0] * g.n_vox[1]) as u64 * F32_BYTES;
            let min_bp = 2 * cfg.bp_chunk as u64 * g.single_proj_bytes()
                + 2 * (g.n_vox[0] * g.n_vox[1]) as u64 * F32_BYTES;
            let mem = min_fp.max(min_bp) + gen.usize(0, 1 << 30) as u64;

            let fp = plan_forward(&g, n_gpus, mem, &cfg).map_err(|e| format!("fp: {e}"))?;
            fp.validate(&g, mem, &cfg).map_err(|e| format!("fp validate: {e}"))?;
            let bp = plan_backward(&g, n_gpus, mem, &cfg).map_err(|e| format!("bp: {e}"))?;
            bp.validate(&g, mem, &cfg).map_err(|e| format!("bp validate: {e}"))?;

            prop_assert(
                fp.angle_chunks.iter().all(|c| c.len() <= cfg.fp_chunk),
                "fp chunk size bound",
            )?;
            prop_assert(
                bp.angle_chunks.iter().all(|c| c.len() <= cfg.bp_chunk),
                "bp chunk size bound",
            )
        });
    }

    #[test]
    fn prop_max_slab_plus_buffers_fit() {
        check("slab + buffers never exceed device RAM", 100, |gen| {
            let n = gen.usize(16, 256);
            let g = Geometry::cone_beam(n, gen.usize(4, 40));
            let cfg = SplitConfig::default();
            let plane = (g.n_vox[0] * g.n_vox[1]) as u64 * F32_BYTES;
            let min = 3 * cfg.fp_chunk as u64 * g.single_proj_bytes() + 2 * plane;
            let mem = min + gen.usize(0, 1 << 28) as u64;
            let p = plan_forward(&g, gen.usize(1, 4), mem, &cfg)
                .map_err(|e| format!("plan: {e}"))?;
            prop_assert(
                p.max_slab_bytes + p.n_proj_buffers as u64 * p.proj_buffer_bytes <= mem,
                "memory bound violated",
            )
        });
    }

    #[test]
    fn merge_schedule_trivial_counts_have_no_rounds() {
        assert!(merge_schedule(0).is_empty());
        assert!(merge_schedule(1).is_empty());
        assert_eq!(merge_schedule(2), vec![vec![(0, 1)]]);
    }

    #[test]
    fn merge_schedule_five_devices_pins_the_bye_round() {
        // n = 5: index 4 has no partner until the stride-4 round.
        assert_eq!(
            merge_schedule(5),
            vec![vec![(0, 1), (2, 3)], vec![(0, 2)], vec![(0, 4)]]
        );
    }

    #[test]
    fn merge_schedule_properties_hold_for_all_small_counts() {
        for n in 2..=33usize {
            let rounds = merge_schedule(n);
            // log-depth critical path
            let expect_rounds = (usize::BITS - (n - 1).leading_zeros()) as usize;
            assert_eq!(rounds.len(), expect_rounds, "rounds for n={n}");
            // every index except 0 consumed as src exactly once → n−1 folds,
            // the same folds a linear accumulation performs
            let mut src_seen = vec![0usize; n];
            let mut folds = 0;
            for round in &rounds {
                // in-round pairs are disjoint (parallelizable)
                let mut in_round = std::collections::HashSet::new();
                for &(dst, src) in round {
                    assert!(dst < src && src < n, "ordered pair ({dst},{src}) for n={n}");
                    assert!(in_round.insert(dst) && in_round.insert(src));
                    src_seen[src] += 1;
                    folds += 1;
                }
            }
            assert_eq!(folds, n - 1, "fold count for n={n}");
            assert_eq!(src_seen[0], 0, "root never consumed");
            assert!(src_seen[1..].iter().all(|&c| c == 1), "src multiplicity for n={n}");
        }
    }

    #[test]
    fn fault_replan_assigns_cyclic_next_survivor() {
        // survivors map to themselves
        assert_eq!(replan_excluding(4, &[false; 4]).unwrap(), vec![0, 1, 2, 3]);
        // lost device 1 → device 2; wrap-around for the last device
        assert_eq!(replan_excluding(4, &[false, true, false, true]).unwrap(), vec![0, 2, 2, 0]);
        assert_eq!(replan_excluding(3, &[true, true, false]).unwrap(), vec![2, 2, 2]);
        // short flag slices read as "not lost"
        assert_eq!(replan_excluding(3, &[true]).unwrap(), vec![1, 1, 2]);
        // no survivors is a planning error, not a panic
        assert!(replan_excluding(2, &[true, true]).is_err());
    }

    #[test]
    fn degrade_refine_fp_halves_angle_chunks_and_keeps_validity() {
        let g = fig7_geometry(64);
        let cfg = SplitConfig::default();
        let p = plan_forward(&g, 2, 11 * GIB, &cfg).unwrap();
        let before = p.angle_chunks.iter().map(|c| c.len()).max().unwrap();
        let (r, detail) = refine_for_budget(&p, &g, true, 0).unwrap();
        let after = r.angle_chunks.iter().map(|c| c.len()).max().unwrap();
        assert!(after < before, "chunks must shrink: {before} -> {after}");
        assert_eq!(r.proj_buffer_bytes, after as u64 * g.single_proj_bytes());
        assert!(detail.contains("fp chunk"), "{detail}");
        // the slab partition is untouched (FP slab refinement would
        // regroup the per-ray z-sum and break bit-identity)
        for (a, b) in p.per_device.iter().zip(&r.per_device) {
            assert_eq!(a.slabs, b.slabs);
        }
        r.validate(&g, 11 * GIB, &cfg).unwrap();
        // repeated refinement bottoms out at 1-angle chunks with an error
        let mut cur = r;
        for _ in 0..16 {
            match refine_for_budget(&cur, &g, true, 0) {
                Ok((next, _)) => cur = next,
                Err(e) => {
                    assert!(e.contains("cannot refine"), "{e}");
                    assert!(cur.angle_chunks.iter().all(|c| c.len() == 1));
                    return;
                }
            }
        }
        panic!("fp refinement never bottomed out");
    }

    #[test]
    fn degrade_refine_bp_doubles_the_affected_device_slabs_only() {
        let g = fig7_geometry(64);
        let cfg = SplitConfig::default();
        let p = plan_backward(&g, 2, 11 * GIB, &cfg).unwrap();
        let (r, detail) = refine_for_budget(&p, &g, false, 1).unwrap();
        assert!(detail.contains("bp d1"), "{detail}");
        assert_eq!(r.per_device[0].slabs.len(), p.per_device[0].slabs.len());
        assert_eq!(r.per_device[1].slabs.len(), 2 * p.per_device[1].slabs.len());
        // angle chunks untouched (BP chunk refinement would regroup the
        // per-voxel accumulation and break bit-identity)
        assert_eq!(r.angle_chunks.len(), p.angle_chunks.len());
        assert!(r.image_split, "more than one slab per device is the split regime");
        r.validate(&g, 11 * GIB, &cfg).unwrap();
        // bottoms out at single-slice slabs
        let mut cur = r;
        loop {
            match refine_for_budget(&cur, &g, false, 1) {
                Ok((next, _)) => cur = next,
                Err(e) => {
                    assert!(e.contains("cannot refine"), "{e}");
                    assert!(cur.per_device[1].slabs.iter().all(|s| s.len() == 1));
                    break;
                }
            }
        }
    }

    #[test]
    fn plan_defaults_to_linear_merge_and_with_merge_overrides() {
        let g = Geometry::cone_beam(32, 8);
        let p = plan_forward(&g, 2, 1 << 30, &SplitConfig::default()).unwrap();
        assert_eq!(p.merge, MergeStrategy::Linear);
        assert_eq!(MergeStrategy::default(), MergeStrategy::Linear);
        let p = p.with_merge(MergeStrategy::Tree);
        assert_eq!(p.merge, MergeStrategy::Tree);
        // schedule indices cover the active devices of the plan
        let active = p.per_device.iter().filter(|d| !d.slabs.is_empty()).count();
        assert_eq!(p.merge_rounds(), merge_schedule(active));
    }
}
