//! Minimal JSON parser and emitter.
//!
//! Used for the AOT artifact manifest written by `python/compile/aot.py`,
//! for benchmark result dumps, and for config files. Supports the full JSON
//! grammar (objects, arrays, strings with escapes, numbers, booleans, null).
//! `serde` is unavailable offline, hence this hand-rolled implementation.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so emission is
/// deterministic, which keeps golden-file tests stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// The `null` literal.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (integers included — JSON has one numeric type).
    Num(f64),
    /// A string, unescaped.
    Str(String),
    /// An ordered array of values.
    Arr(Vec<Json>),
    /// An object; keys sorted for deterministic emission.
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct JsonError {
    /// Byte offset into the source text where parsing failed.
    pub offset: usize,
    /// Human-readable description of what was expected.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors -------------------------------------------------

    /// Build an object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array value.
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Build a numeric value.
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ---- accessors -----------------------------------------------------

    /// The numeric value, if this is a [`Json::Num`].
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a non-negative integer (rejects fractional numbers).
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    /// [`Self::as_u64`] narrowed to `usize`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    /// The string contents, if this is a [`Json::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean value, if this is a [`Json::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The items, if this is a [`Json::Arr`].
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The key/value map, if this is a [`Json::Obj`].
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // ---- parsing -------------------------------------------------------

    /// Parse a complete JSON document (trailing data is an error).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- emission --------------------------------------------------------

    /// Compact single-line encoding.
    pub fn encode(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed encoding with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    it.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.encode())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are rare in our manifests;
                            // map lone surrogates to the replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 char
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf8 in number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let src = r#"{"a":1,"b":[true,null,"x\n"],"c":{"d":-2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_u64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let re = Json::parse(&v.encode()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::str("quote\" slash\\ nl\n tab\t");
        let enc = v.encode();
        assert_eq!(Json::parse(&enc).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("name", Json::str("fp_siddon")),
            ("shapes", Json::arr(vec![Json::num(64.0), Json::num(32.0)])),
        ]);
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn integer_emission_is_integral() {
        assert_eq!(Json::num(42.0).encode(), "42");
        assert_eq!(Json::num(0.5).encode(), "0.5");
    }
}
