// Control fixture: trips NOTHING even under the strictest pretend path
// (rust/src/coordinator/fixture.rs). Never compiled.

pub fn typed(v: Option<u32>) -> Result<u32, String> {
    v.ok_or_else(|| "missing value".to_string())
}

pub fn safe_view(p: *mut f32, n: usize) -> &'static mut [f32] {
    // SAFETY: fixture-only illustration of a justified block; the caller
    // guarantees the pointer is valid for n elements and exclusively owned.
    unsafe { std::slice::from_raw_parts_mut(p, n) }
}

pub fn count(events: &[u32]) -> u64 {
    let mut n = 0u64;
    for _ in events {
        n += 1;
    }
    n
}
