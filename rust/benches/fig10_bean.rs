//! Fig. 10 — the coffee-bean case study: FDK vs CGLS-30 with ⅓ of the
//! angles, on a volume (plus algorithm auxiliaries) much larger than the
//! simulated devices, forcing the full splitting machinery.
//!
//! Paper setup (scaled): panel-shifted detector, 2134/6401 angles used,
//! 3340×3340×900 volume on a 2× GTX 1080 Ti node, CGLS-30 in 4 h 21 min.
//! Here: a bean phantom at miniature scale for real numerics + the same
//! problem at paper scale timed on the device model.

use tigre::algorithms::{self, ReconOpts};
use tigre::coordinator::{ExecMode, MultiGpu};
use tigre::geometry::Geometry;
use tigre::kernels::filtering::Window;
use tigre::metrics;
use tigre::phantom;

fn main() {
    // ---- real numerics at miniature scale (devices shrunk so the
    // volume splits, as the paper's bean does on 11 GiB cards) ----
    let n = 28;
    let full_angles = 54;
    let third_angles = full_angles / 3;
    let truth = phantom::bean(n, n, n);
    let plane = (n * n * 4) as u64;
    let g_third = {
        let mut g = Geometry::cone_beam(n, third_angles);
        g.offset_det[0] = 0.5; // panel shift, as in the measured scan
        g
    };
    // kernel chunks scaled down with the miniature problem so the image
    // really splits (as the 40 GB bean volume does on 11 GiB devices)
    let mut ctx = MultiGpu::gtx1080ti(2);
    ctx.split.fp_chunk = 3;
    ctx.split.bp_chunk = 4;
    let mem = 9 * plane
        + (3 * ctx.split.fp_chunk as u64).max(2 * ctx.split.bp_chunk as u64)
            * g_third.single_proj_bytes();
    let ctx = ctx.with_device_mem(mem);

    let (p, fp_stats) = ctx.forward(&g_third, Some(&truth), ExecMode::Full).unwrap();
    let p = p.unwrap();
    println!(
        "bean {n}³, {third_angles}/{full_angles} angles, 2 devices of {} B: {} splits/device",
        mem, fp_stats.splits_per_device
    );

    let t0 = std::time::Instant::now();
    let fdk = algorithms::fdk(&ctx, &g_third, &p, Window::Hann).unwrap();
    let cgls = algorithms::cgls(
        &ctx,
        &g_third,
        &p,
        &ReconOpts { iterations: 30, ..Default::default() },
    )
    .unwrap();
    println!("(real compute wall-clock {:.1}s)", t0.elapsed().as_secs_f64());

    let e_fdk = metrics::rmse(&truth, &fdk.volume);
    let e_cgls = metrics::rmse(&truth, &cgls.volume);
    let p_fdk = metrics::psnr(&truth, &fdk.volume);
    let p_cgls = metrics::psnr(&truth, &cgls.volume);
    println!("=== Fig. 10 analogue: quality at 1/3 angular sampling ===");
    println!("FDK   : RMSE {e_fdk:.5}  PSNR {p_fdk:.2} dB");
    println!("CGLS30: RMSE {e_cgls:.5}  PSNR {p_cgls:.2} dB");
    println!(
        "CGLS more robust than FDK under undersampling: {} (paper: yes)",
        e_cgls < e_fdk
    );

    let _ = tigre::io::save_slice_pgm(
        std::path::Path::new("results/fig10_fdk.pgm"),
        &fdk.volume,
        n / 2,
        None,
    );
    let _ = tigre::io::save_slice_pgm(
        std::path::Path::new("results/fig10_cgls.pgm"),
        &cgls.volume,
        n / 2,
        None,
    );

    // ---- paper-scale timing on the device model ----
    // 3340×3340×900 volume, 900×3780 projections × 2134 angles ≈ the
    // paper's cropped dataset (29 GB projections + 40 GB image).
    let g_paper = Geometry::cone_beam_anisotropic([3340, 3340, 900], [3780, 900], 2134);
    let node = MultiGpu::gtx1080ti(2);
    let (_, fp) = node.forward(&g_paper, None, ExecMode::SimOnly).unwrap();
    let (_, bp) = node.backward(&g_paper, None, ExecMode::SimOnly).unwrap();
    let per_iter = fp.makespan_s + bp.makespan_s;
    let cgls30 = 30.0 * per_iter;
    println!("=== paper-scale timing estimate (2× GTX 1080 Ti model) ===");
    println!(
        "FP {:.0}s + BP {:.0}s per iteration; CGLS-30 ≈ {:.2} h (paper: 4.35 h)",
        fp.makespan_s,
        bp.makespan_s,
        cgls30 / 3600.0
    );
    println!(
        "splits/device: FP {} BP {}; peak device mem {} / 11 GiB",
        fp.splits_per_device,
        bp.splits_per_device,
        tigre::util::units::fmt_bytes(fp.peak_device_bytes.max(bp.peak_device_bytes))
    );
}
