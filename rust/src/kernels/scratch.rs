//! Per-thread scratch buffer arena for the kernel hot path.
//!
//! Every `forward`/`backward` call produces a freshly allocated
//! [`ProjectionSet`] or [`Volume`]; the iterative algorithms (Landweber,
//! OS-SART, CGLS, FISTA) make two such calls per iteration and immediately
//! drop the previous iteration's buffers, so the hot loop used to spend a
//! measurable slice of its time in the allocator (and, worse, in the
//! kernel page-faulting freshly mmapped zero pages during the first write
//! pass). This module keeps a small per-thread free list of `Vec<f32>`
//! buffers: recycling a buffer and re-taking it later turns that
//! allocate-and-fault cycle into a `memset`.
//!
//! Determinism: taken buffers are always fully zeroed, so a kernel using a
//! recycled buffer produces bit-identical output to one using a fresh
//! allocation. The arena is thread-local (no locks on the hot path) and
//! capacity-capped, so it cannot grow without bound when geometries of
//! many different sizes are used.
//!
//! Concurrency (audited for the pipelined executor, whose device workers
//! take/recycle from `ThreadPool` worker threads concurrently): every pool
//! is `thread_local!`, so a `take_zeroed` can only ever pop buffers the
//! *same* thread recycled — two threads can never receive aliasing
//! buffers, with no synchronization needed. Buffers may legally migrate:
//! a buffer taken on a pool worker and recycled on the host (or vice
//! versa) simply joins the recycling thread's free list; ownership is by
//! `Vec` move the whole way, so there is no window in which a buffer is
//! simultaneously in a free list and in use
//! (`concurrent_pool_take_recycle_never_aliases_live_buffers` is the
//! regression test for this invariant).

use std::cell::{Cell, RefCell};

use crate::volume::{ProjectionSet, Volume};

/// Max buffers kept per thread. Iterative algorithms cycle at most a
/// handful of distinct shapes (projection set, volume, subset variants).
const MAX_POOLED: usize = 16;

/// Max total bytes retained per thread (f32 elements × 4). Bounds what a
/// long-lived process keeps resident after a large reconstruction; work
/// bigger than this still recycles within an iteration (take→recycle→take
/// round-trips), it just returns memory to the allocator between phases.
/// Call [`clear`] to release everything eagerly.
const MAX_POOLED_BYTES: usize = 256 << 20;

thread_local! {
    static POOL: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
    static HITS: Cell<u64> = const { Cell::new(0) };
    static MISSES: Cell<u64> = const { Cell::new(0) };
}

/// Take a zeroed `f32` buffer of exactly `len` elements, reusing a pooled
/// allocation when one is large enough.
pub fn take_zeroed(len: usize) -> Vec<f32> {
    let reused = POOL.with(|p| {
        let mut pool = p.borrow_mut();
        // best fit: smallest pooled buffer whose capacity suffices
        let mut best: Option<(usize, usize)> = None; // (index, capacity)
        for (i, b) in pool.iter().enumerate() {
            let cap = b.capacity();
            if cap >= len && best.map_or(true, |(_, c)| cap < c) {
                best = Some((i, cap));
            }
        }
        best.map(|(i, _)| pool.swap_remove(i))
    });
    match reused {
        Some(mut v) => {
            HITS.with(|c| c.set(c.get() + 1));
            v.clear();
            v.resize(len, 0.0);
            v
        }
        None => {
            MISSES.with(|c| c.set(c.get() + 1));
            vec![0.0; len]
        }
    }
}

/// Return a buffer to the thread-local pool. Eviction is by recency: the
/// pool keeps the most recently recycled buffers (the live working set)
/// and drops the oldest until both the count and total-byte caps hold, so
/// one burst of huge allocations cannot pin memory for the thread's
/// lifetime.
pub fn recycle(buf: Vec<f32>) {
    if buf.capacity() == 0 || buf.capacity() * 4 > MAX_POOLED_BYTES {
        return;
    }
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        pool.push(buf);
        let total = |pool: &Vec<Vec<f32>>| {
            pool.iter().map(|b| b.capacity() * 4).sum::<usize>()
        };
        while pool.len() > MAX_POOLED || total(&pool) > MAX_POOLED_BYTES {
            pool.remove(0); // oldest first
        }
    });
}

/// Drop every buffer the calling thread's arena holds.
pub fn clear() {
    POOL.with(|p| p.borrow_mut().clear());
}

/// Take a zeroed volume of the given shape from the arena.
pub fn take_volume(nx: usize, ny: usize, nz: usize) -> Volume {
    Volume { nx, ny, nz, data: take_zeroed(nx * ny * nz) }
}

/// Take a zeroed projection set of the given shape from the arena.
pub fn take_projections(nu: usize, nv: usize, n_angles: usize) -> ProjectionSet {
    ProjectionSet { nu, nv, n_angles, data: take_zeroed(nu * nv * n_angles) }
}

/// Recycle a volume's backing buffer.
pub fn recycle_volume(v: Volume) {
    recycle(v.data);
}

/// Recycle a projection set's backing buffer.
pub fn recycle_projections(p: ProjectionSet) {
    recycle(p.data);
}

/// (hits, misses) of the calling thread's arena — used by tests and the
/// bench harness to confirm the iterative hot loop actually recycles.
pub fn thread_stats() -> (u64, u64) {
    (HITS.with(Cell::get), MISSES.with(Cell::get))
}

#[cfg(test)]
// test-only HashSet tracking live buffer pointers; never shipped
#[allow(clippy::disallowed_types)]
mod tests {
    use super::*;

    #[test]
    fn take_recycle_take_reuses_allocation() {
        clear();
        let (h0, _) = thread_stats();
        let v = take_zeroed(4096);
        let ptr = v.as_ptr();
        recycle(v);
        let v2 = take_zeroed(4096);
        assert_eq!(v2.as_ptr(), ptr, "same-size take should reuse the buffer");
        assert_eq!(v2.len(), 4096);
        assert!(v2.iter().all(|&x| x == 0.0), "recycled buffer must be zeroed");
        let (h1, _) = thread_stats();
        assert!(h1 > h0);
        recycle(v2);
    }

    #[test]
    fn recycled_buffers_are_rezeroed() {
        clear();
        let mut v = take_zeroed(128);
        for x in v.iter_mut() {
            *x = 7.5;
        }
        recycle(v);
        let v2 = take_zeroed(64); // smaller take from a larger buffer
        assert_eq!(v2.len(), 64);
        assert!(v2.iter().all(|&x| x == 0.0));
        recycle(v2);
    }

    #[test]
    fn pool_is_count_capped_with_recency_eviction() {
        clear();
        for len in 1..=(2 * MAX_POOLED) {
            recycle(vec![0.0; len]);
        }
        POOL.with(|p| {
            let pool = p.borrow();
            assert!(pool.len() <= MAX_POOLED);
            // oldest (here: smallest) buffers were the ones evicted
            assert!(pool.iter().all(|b| b.capacity() > MAX_POOLED));
        });
        clear();
        POOL.with(|p| assert!(p.borrow().is_empty()));
    }

    #[test]
    fn oversized_buffers_are_never_pooled() {
        clear();
        // reserves virtual address space only; pages are never touched
        let huge: Vec<f32> = Vec::with_capacity(MAX_POOLED_BYTES / 4 + 1);
        recycle(huge);
        POOL.with(|p| assert!(p.borrow().is_empty()));
    }

    #[test]
    fn concurrent_pool_take_recycle_never_aliases_live_buffers() {
        // Regression test for the pipelined executor: device workers on
        // ThreadPool threads take/recycle concurrently (and buffers
        // migrate between threads via channels/returns). A live buffer's
        // address must never be handed out again while it is live, and
        // buffer contents must never be clobbered by another thread.
        use crate::util::threadpool::ThreadPool;
        use std::collections::HashSet;
        use std::sync::{Arc, Mutex};

        let live: Arc<Mutex<HashSet<usize>>> = Arc::new(Mutex::new(HashSet::new()));
        let pool = ThreadPool::new(4);
        for i in 0..400usize {
            let live = Arc::clone(&live);
            pool.submit(move || {
                let len = 512 + (i % 5) * 256;
                let mut a = take_zeroed(len);
                let mut b = take_zeroed(len);
                assert_ne!(a.as_ptr(), b.as_ptr(), "two live takes alias");
                {
                    let mut l = live.lock().unwrap();
                    assert!(
                        l.insert(a.as_ptr() as usize),
                        "take returned a buffer another thread holds live"
                    );
                    assert!(
                        l.insert(b.as_ptr() as usize),
                        "take returned a buffer another thread holds live"
                    );
                }
                // stamp both, do some "kernel work", verify the stamps
                // survived (no cross-thread clobbering)
                let stamp = i as f32 + 1.0;
                a.iter_mut().for_each(|v| *v = stamp);
                b.iter_mut().for_each(|v| *v = -stamp);
                std::thread::yield_now();
                assert!(a.iter().all(|&v| v == stamp), "live buffer clobbered");
                assert!(b.iter().all(|&v| v == -stamp), "live buffer clobbered");
                // un-register strictly before recycling, so a concurrent
                // take of the recycled buffer can never race the registry
                {
                    let mut l = live.lock().unwrap();
                    l.remove(&(a.as_ptr() as usize));
                    l.remove(&(b.as_ptr() as usize));
                }
                recycle(a);
                recycle(b);
            });
        }
        pool.wait_idle();
        assert!(live.lock().unwrap().is_empty());
    }

    #[test]
    fn cross_thread_recycling_is_safe_and_rezeroed() {
        // The executor returns worker-taken buffers to the host thread,
        // which recycles them there: the buffer joins the host arena and
        // the next host take must see zeroed contents.
        clear();
        let buf = std::thread::spawn(|| {
            let mut b = take_zeroed(1024);
            b.iter_mut().for_each(|v| *v = 3.25);
            b
        })
        .join()
        .unwrap();
        recycle(buf);
        let again = take_zeroed(1024);
        assert!(again.iter().all(|&v| v == 0.0), "migrated buffer must re-zero");
        recycle(again);
    }

    #[test]
    fn shaped_helpers_roundtrip() {
        let vol = take_volume(4, 5, 6);
        assert_eq!((vol.nx, vol.ny, vol.nz, vol.data.len()), (4, 5, 6, 120));
        recycle_volume(vol);
        let p = take_projections(3, 4, 5);
        assert_eq!((p.nu, p.nv, p.n_angles, p.data.len()), (3, 4, 5, 60));
        recycle_projections(p);
    }
}
