//! Hot-path micro-benchmarks of the real (native rust) kernels — the
//! substrate for the §Perf optimization pass (EXPERIMENTS.md §Perf) and
//! the producer of the `BENCH_kernels.json` perf trajectory.
//!
//! Usage:
//!   cargo bench --bench kernel_hotpath                       # print table
//!   cargo bench --bench kernel_hotpath -- --smoke            # CI sanity run
//!   cargo bench --bench kernel_hotpath -- \
//!       --json BENCH_kernels.json --label post-PR2           # append a run
//!
//! With `--json` the run is appended to the trajectory file (created if
//! absent); when the file then holds ≥2 runs, a before/after speedup
//! table (first vs. last run, matched by workload name) is printed.
//! Thread count follows `TIGRE_THREADS` when set, so trajectory entries
//! are comparable across machines with pinned parallelism.

use std::time::Duration;

use tigre::bench::{kernels as kb, parse_bench_args};
use tigre::geometry::Geometry;
use tigre::kernels;
use tigre::util::json::Json;
use tigre::util::stats::{bench, fmt_duration};

fn main() {
    // shared trajectory-runner flags (see tigre::bench::parse_bench_args)
    let args = parse_bench_args();
    let smoke = args.smoke;
    let json_path = args.json_path;
    let label = args.label;

    let threads = kernels::kernel_threads();
    println!(
        "=== native kernel hot paths ({threads} host threads{}) ===",
        if smoke { ", smoke mode" } else { "" }
    );

    let entries = kb::run_suite(smoke, threads);
    for e in &entries {
        println!(
            "{:<28} median {:>10}  min {:>10}  {:>14.3e} {} ({} samples)",
            e.name,
            fmt_duration(e.median_s),
            fmt_duration(e.min_s),
            e.throughput(),
            e.unit,
            e.samples,
        );
    }

    // auxiliary (non-trajectory) workloads: TV/ROF + the DES scheduler
    if !smoke {
        let v = tigre::phantom::random(32, 32, 32, 5);
        let r = bench("rof_denoise 32³ x10", 1, 3, Duration::from_millis(500), || {
            std::hint::black_box(tigre::kernels::tv::rof_denoise(&v, 0.2, 10));
        });
        println!("{}", r.summary());
        let r = bench("tv_gradient 32³", 1, 3, Duration::from_millis(500), || {
            std::hint::black_box(tigre::kernels::tv::tv_gradient(&v));
        });
        println!("{}", r.summary());

        // DES scheduler itself (must be negligible vs what it models)
        let g = Geometry::cone_beam(2048, 2048);
        let ctx = tigre::coordinator::MultiGpu::gtx1080ti(4);
        let r = bench("des_schedule fp N=2048 4gpu", 1, 3, Duration::from_millis(500), || {
            std::hint::black_box(
                ctx.forward(&g, None, tigre::coordinator::ExecMode::SimOnly).unwrap(),
            );
        });
        println!("{}", r.summary());
    }

    if let Some(path) = json_path {
        if let Err(e) = kb::append_run_to_file(&path, &label, threads, smoke, &entries) {
            eprintln!("error: writing {}: {e:#}", path.display());
            std::process::exit(1);
        }
        println!("appended run '{label}' to {}", path.display());
        match std::fs::read_to_string(&path).map_err(|e| e.to_string()).and_then(|t| {
            Json::parse(&t).map_err(|e| e.to_string())
        }) {
            Ok(doc) => {
                let rows = kb::speedups(&doc);
                let n_runs = doc.get("runs").and_then(Json::as_arr).map_or(0, |r| r.len());
                if !rows.is_empty() {
                    println!("--- trajectory: first vs last run ---");
                    for (name, before, after, speedup) in rows {
                        println!(
                            "{name:<28} {:>10} -> {:>10}  {speedup:.2}x",
                            fmt_duration(before),
                            fmt_duration(after),
                        );
                    }
                } else if n_runs >= 2 {
                    println!(
                        "(no speedup table: first/last runs differ in threads/smoke \
                         config or share no workload names)"
                    );
                }
            }
            Err(e) => eprintln!("warning: could not re-read trajectory: {e}"),
        }
    }
}
