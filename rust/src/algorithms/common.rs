//! Shared algorithm plumbing: options, convergence traces and result
//! reporting. The operator wrapper the algorithms drive their loops
//! through is `coordinator::residency::ReconSession` (PR 4): it carries
//! the cumulative simulated time and peak memory the old `TrackedOps`
//! tracked, plus the cross-iteration device residency cache.

use crate::coordinator::checkpoint::CheckpointConfig;
use crate::coordinator::{MultiGpu, NonFiniteStage, ProjectorChoice, ReconError};
use crate::volume::Volume;

/// Options common to the iterative algorithms.
#[derive(Clone, Debug)]
pub struct ReconOpts {
    /// Number of outer iterations.
    pub iterations: usize,
    /// Relaxation / step parameter (λ for SART-family, unused by CGLS).
    pub lambda: f32,
    /// Enforce non-negativity after each update.
    pub nonneg: bool,
    /// Verbose per-iteration logging.
    pub verbose: bool,
    /// Durable iteration checkpointing (ISSUE 7): when set, the
    /// algorithm snapshots its recurrence state every
    /// `checkpoint.every` iterations and *resumes from* any checkpoint
    /// already present in the directory — the resumed run's final
    /// iterate is bit-identical to an uninterrupted one.
    pub checkpoint: Option<CheckpointConfig>,
    /// Numerical-health guard (ISSUE 8): an iteration whose residual
    /// exceeds the previous one by more than this factor counts as
    /// divergence and triggers a step-size backoff. Generous enough
    /// that normal non-monotone ripples (FISTA momentum, early MLEM)
    /// never trip it.
    pub divergence_tolerance: f64,
    /// Multiplicative step-size scale applied on each divergence
    /// backoff (each algorithm maps it onto its own step/relaxation
    /// knob — see `DivergenceGuard`).
    pub step_backoff: f32,
    /// Backoff budget: residual growth past this many backoffs fails
    /// the run with [`ReconError::Diverged`] instead of looping.
    pub max_step_backoffs: usize,
    /// Override the context's projector family for this reconstruction
    /// (ISSUE 10): `Some(ProjectorChoice::Sparse)` swaps in the
    /// precomputed CSR system-matrix backend, whose per-unit shards are
    /// built on the first iteration and reused from the shard cache by
    /// every later one. `None` (default) keeps the context's backend.
    pub projector: Option<ProjectorChoice>,
}

impl Default for ReconOpts {
    fn default() -> Self {
        Self {
            iterations: 10,
            lambda: 1.0,
            nonneg: true,
            verbose: false,
            checkpoint: None,
            divergence_tolerance: 1.25,
            step_backoff: 0.5,
            max_step_backoffs: 4,
            projector: None,
        }
    }
}

/// Resolve the context an algorithm should run against: the caller's
/// context as-is, or a clone rebuilt around the projector family
/// `opts.projector` selects. Every iterative algorithm entry point
/// funnels through this, which is what makes
/// `ReconOpts { projector: Some(ProjectorChoice::Sparse), .. }` and the
/// CLI `--projector sparse` flag equivalent.
pub(crate) fn projector_ctx(ctx: &MultiGpu, opts: &ReconOpts) -> MultiGpu {
    match opts.projector {
        Some(p) => ctx.clone().with_projector(p),
        None => ctx.clone(),
    }
}

/// Result of a reconstruction: the volume, the convergence trace and the
/// simulated wall-clock the multi-GPU node would have spent.
#[derive(Clone, Debug)]
pub struct ReconResult {
    /// The reconstructed volume.
    pub volume: Volume,
    /// ‖b − Ax‖₂ after each iteration (when the algorithm computes it).
    pub residuals: Vec<f64>,
    /// Total simulated time across all operator calls, seconds.
    pub sim_time_s: f64,
    /// Peak simulated device memory over all calls.
    pub peak_device_bytes: u64,
    /// Divergence-guard step backoffs taken (ISSUE 8); 0 on a healthy
    /// run.
    pub backoffs: usize,
}

/// Per-iteration numerical-health guard (ISSUE 8), shared by all six
/// iterative algorithms: watches the residual trace for non-finite
/// values (typed error, stage [`NonFiniteStage::Residual`]) and for
/// growth past `opts.divergence_tolerance`. Growth hands the algorithm
/// its configured step scale (`opts.step_backoff`) to apply to its own
/// step/relaxation knob; growth persisting past `opts.max_step_backoffs`
/// fails the run with [`ReconError::Diverged`].
///
/// The guard only *reacts* to the residual trace — on a converging run
/// it never fires and the iterates are untouched, so clean-path outputs
/// are bit-identical to a guard-free build.
pub struct DivergenceGuard {
    algorithm: &'static str,
    tolerance: f64,
    step_backoff: f32,
    max_backoffs: usize,
    prev: Option<f64>,
    /// Backoffs taken so far (reported through [`ReconResult::backoffs`]).
    pub backoffs: usize,
}

impl DivergenceGuard {
    /// Fresh guard configured from `opts`, labelled with the algorithm name.
    pub fn new(algorithm: &'static str, opts: &ReconOpts) -> Self {
        Self {
            algorithm,
            tolerance: opts.divergence_tolerance,
            step_backoff: opts.step_backoff,
            max_backoffs: opts.max_step_backoffs,
            prev: None,
            backoffs: 0,
        }
    }

    /// Seed the previous-residual state from a restored trace. Checkpoint
    /// resume must call this so the guard compares the first resumed
    /// iteration against the same predecessor an uninterrupted run would
    /// have used — otherwise resumed and uninterrupted runs could make
    /// different backoff decisions and lose bit-identity.
    pub fn seed(&mut self, residuals: &[f64]) {
        self.prev = residuals.last().copied();
    }

    /// Judge iteration `iteration`'s residual. `Ok(None)`: healthy.
    /// `Ok(Some(f))`: residual grew past tolerance — scale the step by
    /// `f` before applying this iteration's update. `Err`: non-finite
    /// residual, or growth with the backoff budget exhausted.
    pub fn check(
        &mut self,
        iteration: usize,
        residual: f64,
    ) -> Result<Option<f32>, ReconError> {
        if !residual.is_finite() {
            return Err(ReconError::NonFinite {
                stage: NonFiniteStage::Residual,
                index: iteration,
                detail: format!("{}: residual {residual}", self.algorithm),
            });
        }
        let grew = self.prev.is_some_and(|p| residual > p * self.tolerance);
        self.prev = Some(residual);
        if !grew {
            return Ok(None);
        }
        if self.backoffs >= self.max_backoffs {
            return Err(ReconError::Diverged {
                algorithm: self.algorithm,
                iteration,
                residual,
                backoffs: self.backoffs,
            });
        }
        self.backoffs += 1;
        Ok(Some(self.step_backoff))
    }
}

/// `max(x, eps)` reciprocal used for SART weight volumes.
pub fn safe_recip(data: &mut [f32]) {
    for v in data.iter_mut() {
        *v = if v.abs() > 1e-6 { 1.0 / *v } else { 0.0 };
    }
}

/// Build the ordered-subset angle index lists: `n_subsets` interleaved
/// subsets (TIGRE's default angular ordering for OS-SART).
pub fn ordered_subsets(n_angles: usize, subset_size: usize) -> Vec<Vec<usize>> {
    let subset_size = subset_size.clamp(1, n_angles);
    let n_subsets = n_angles.div_ceil(subset_size);
    let mut subsets: Vec<Vec<usize>> = vec![Vec::new(); n_subsets];
    // interleave angles so each subset spans the angular range
    for a in 0..n_angles {
        subsets[a % n_subsets].push(a);
    }
    subsets
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_subsets_partition_angles() {
        let subsets = ordered_subsets(10, 3);
        assert_eq!(subsets.len(), 4);
        let mut all: Vec<usize> = subsets.concat();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
        // each subset spans the angular range (interleaved)
        assert!(subsets[0].contains(&0));
        assert!(subsets[0].iter().any(|&a| a >= 5));
    }

    #[test]
    fn subset_size_one_gives_singletons() {
        let subsets = ordered_subsets(4, 1);
        assert_eq!(subsets.len(), 4);
        assert!(subsets.iter().all(|s| s.len() == 1));
    }

    #[test]
    fn subset_size_all_gives_one() {
        let subsets = ordered_subsets(6, 6);
        assert_eq!(subsets.len(), 1);
        assert_eq!(subsets[0].len(), 6);
    }

    #[test]
    fn safe_recip_handles_zero() {
        let mut v = vec![2.0, 0.0, -4.0];
        safe_recip(&mut v);
        assert_eq!(v, vec![0.5, 0.0, -0.25]);
    }

    #[test]
    fn degrade_divergence_guard_backs_off_then_fails() {
        let opts = ReconOpts { max_step_backoffs: 2, ..Default::default() };
        let mut g = DivergenceGuard::new("test", &opts);
        // decreasing and mildly-noisy traces never fire
        assert_eq!(g.check(0, 10.0).unwrap(), None);
        assert_eq!(g.check(1, 9.0).unwrap(), None);
        assert_eq!(g.check(2, 9.0 * 1.2).unwrap(), None); // within tolerance
        // two growth events spend the backoff budget...
        assert_eq!(g.check(3, 100.0).unwrap(), Some(opts.step_backoff));
        assert_eq!(g.check(4, 1000.0).unwrap(), Some(opts.step_backoff));
        assert_eq!(g.backoffs, 2);
        // ...the third is a typed divergence error
        let err = g.check(5, 10_000.0).unwrap_err();
        assert!(matches!(
            err,
            crate::coordinator::ReconError::Diverged { algorithm: "test", backoffs: 2, .. }
        ));
    }

    #[test]
    fn degrade_divergence_guard_rejects_non_finite_residuals() {
        let mut g = DivergenceGuard::new("test", &ReconOpts::default());
        let err = g.check(0, f64::NAN).unwrap_err();
        assert!(matches!(
            err,
            crate::coordinator::ReconError::NonFinite {
                stage: crate::coordinator::NonFiniteStage::Residual,
                ..
            }
        ));
    }

    #[test]
    fn degrade_divergence_guard_seed_matches_uninterrupted_trace() {
        // resume parity: seeding from a restored trace must reproduce the
        // uninterrupted guard's decision on the next residual
        let opts = ReconOpts::default();
        let mut full = DivergenceGuard::new("test", &opts);
        full.check(0, 10.0).unwrap();
        full.check(1, 8.0).unwrap();
        let full_next = full.check(2, 20.0).unwrap();
        let mut resumed = DivergenceGuard::new("test", &opts);
        resumed.seed(&[10.0, 8.0]);
        assert_eq!(resumed.check(2, 20.0).unwrap(), full_next);
    }
}
