//! The coffee-bean scenario (paper §3.2, Fig. 10): a panel-shifted scan
//! reconstructed with FDK and CGLS at ⅓ angular sampling, on devices too
//! small to hold the volume — demonstrating that the splitting machinery
//! is invisible to the algorithms and that iterative recon is more
//! robust to undersampling.
//!
//! Run with: `cargo run --release --example coffee_bean`

use tigre::algorithms::{self, ReconOpts};
use tigre::coordinator::{ExecMode, MultiGpu};
use tigre::geometry::Geometry;
use tigre::kernels::filtering::Window;
use tigre::metrics;
use tigre::phantom;

fn main() -> anyhow::Result<()> {
    let n = 32;
    let full_angles = 96;
    let third = full_angles / 3;

    // bean phantom + panel-shifted detector (the Zeiss scan stitches two
    // shifted panels; here the offset exercises the same geometry path)
    let truth = phantom::bean(n, n, n);
    let mut g_full = Geometry::cone_beam(n, full_angles);
    g_full.offset_det[0] = 0.8;
    let mut g_third = Geometry::cone_beam(n, third);
    g_third.offset_det[0] = 0.8;

    // Devices shrunk so the image needs multiple slabs per device, as the
    // paper's 40 GB bean volume does on 11 GiB cards. At miniature scale
    // the projection buffers would dominate an 11 GiB-proportioned card,
    // so the kernel chunk sizes are scaled down with the problem.
    let plane = (n * n * 4) as u64;
    let mut node = MultiGpu::gtx1080ti(2);
    node.split.fp_chunk = 3;
    node.split.bp_chunk = 4;
    let mem = 10 * plane
        + (3 * node.split.fp_chunk as u64).max(2 * node.split.bp_chunk as u64)
            * g_third.single_proj_bytes();
    node = node.with_device_mem(mem);

    let (p_full, s) = node.forward(&g_full, Some(&truth), ExecMode::Full)?;
    println!(
        "full sampling: {} angles, {} splits/device (devices hold only {} of the image)",
        full_angles,
        s.splits_per_device,
        tigre::util::units::fmt_bytes(mem)
    );
    let (p_third, _) = node.forward(&g_third, Some(&truth), ExecMode::Full)?;
    let p_full = p_full.unwrap();
    let p_third = p_third.unwrap();

    // FDK at full vs third sampling; CGLS-30 at third sampling (Fig. 10)
    let fdk_full = algorithms::fdk(&node, &g_full, &p_full, Window::Hann)?;
    let fdk_third = algorithms::fdk(&node, &g_third, &p_third, Window::Hann)?;
    let cgls_third = algorithms::cgls(
        &node,
        &g_third,
        &p_third,
        &ReconOpts { iterations: 30, ..Default::default() },
    )?;

    println!("quality vs ground truth (RMSE / PSNR):");
    let report = |name: &str, v: &tigre::volume::Volume| {
        println!(
            "  {name:<22} {:.5} / {:.2} dB",
            metrics::rmse(&truth, v),
            metrics::psnr(&truth, v)
        );
    };
    report("FDK, full angles", &fdk_full.volume);
    report("FDK, 1/3 angles", &fdk_third.volume);
    report("CGLS-30, 1/3 angles", &cgls_third.volume);
    println!(
        "CGLS at 1/3 sampling beats FDK at 1/3 sampling: {} (paper Fig. 10: yes)",
        metrics::rmse(&truth, &cgls_third.volume) < metrics::rmse(&truth, &fdk_third.volume)
    );
    println!(
        "CGLS-30 simulated time on the 2-GPU node: {:.2}s (paper, at full scale: 4 h 21 min)",
        cgls_third.sim_time_s
    );

    tigre::io::save_slice_pgm(
        std::path::Path::new("results/bean_fdk_third.pgm"),
        &fdk_third.volume,
        n / 2,
        None,
    )?;
    tigre::io::save_slice_pgm(
        std::path::Path::new("results/bean_cgls_third.pgm"),
        &cgls_third.volume,
        n / 2,
        None,
    )?;
    println!("slices: results/bean_fdk_third.pgm, results/bean_cgls_third.pgm");
    Ok(())
}
