//! Machine-readable kernel hot-path benchmark — the substrate of the
//! `BENCH_kernels.json` perf trajectory (EXPERIMENTS.md §Perf).
//!
//! `benches/kernel_hotpath.rs` is the runner; this module owns the
//! workload definitions, the throughput accounting (rays/s for the
//! projectors, voxel-updates/s for the backprojector) and the JSON
//! record so that every PR's before/after numbers land in one tracked
//! file with a stable schema. Appending rather than overwriting keeps
//! the trajectory: each run is one element of `runs`, labelled by the
//! caller (e.g. `pre-PR2-seed`, `post-PR2`).

use std::path::Path;
use std::time::Duration;

use crate::geometry::Geometry;
use crate::kernels::{self, BackprojWeight, Projector};
use crate::phantom;
use crate::util::json::Json;
use crate::util::stats::{bench, BenchResult};
use crate::volume::ProjectionSet;

/// Schema tag of `BENCH_kernels.json`; bump on breaking layout changes.
pub const SCHEMA: &str = "tigre-bench-kernels/v1";

/// One benchmarked kernel workload.
#[derive(Clone, Debug)]
pub struct KernelBenchEntry {
    /// Workload id, e.g. `fp_siddon n=64 a=16`.
    pub name: String,
    /// Median wall-clock per call, seconds.
    pub median_s: f64,
    /// Fastest observed call, seconds.
    pub min_s: f64,
    /// Number of timed calls behind the medians.
    pub samples: usize,
    /// Units of work per call (rays, voxel-updates, pixels).
    pub work_per_call: f64,
    /// Throughput unit, e.g. `rays/s`.
    pub unit: &'static str,
}

impl KernelBenchEntry {
    /// Work units per second at the median (infinite for a 0 s median).
    pub fn throughput(&self) -> f64 {
        if self.median_s > 0.0 {
            self.work_per_call / self.median_s
        } else {
            f64::INFINITY
        }
    }

    fn from_result(r: &BenchResult, work_per_call: f64, unit: &'static str) -> Self {
        Self {
            name: r.name.clone(),
            median_s: r.samples.median(),
            min_s: r.samples.min(),
            samples: r.samples.len(),
            work_per_call,
            unit,
        }
    }
}

/// Run the kernel hot-path suite. `smoke` shrinks sizes and budgets to a
/// sub-second CI sanity run; the entry set (names modulo `n=` values)
/// stays the same so JSON consumers need no special cases.
pub fn run_suite(smoke: bool, threads: usize) -> Vec<KernelBenchEntry> {
    let mut out = Vec::new();
    let (fp_sizes, bp_sizes, joseph_sizes): (&[usize], &[usize], &[usize]) = if smoke {
        (&[16, 32], &[16, 32], &[16])
    } else {
        (&[32, 48, 64], &[32, 48, 64], &[32, 48])
    };
    let budget = if smoke { Duration::from_millis(40) } else { Duration::from_millis(600) };
    let (warmup, min_iters) = if smoke { (0, 1) } else { (1, 3) };
    let n_angles = 16usize;

    for &n in fp_sizes {
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        let r = bench(&format!("fp_siddon n={n} a={n_angles}"), warmup, min_iters, budget, || {
            std::hint::black_box(kernels::forward(&g, &v, Projector::Siddon, threads));
        });
        let rays = (n * n * n_angles) as f64;
        out.push(KernelBenchEntry::from_result(&r, rays, "rays/s"));
    }

    for &n in joseph_sizes {
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        let r = bench(&format!("fp_joseph n={n} a={n_angles}"), warmup, min_iters, budget, || {
            std::hint::black_box(kernels::forward(&g, &v, Projector::Joseph, threads));
        });
        let rays = (n * n * n_angles) as f64;
        out.push(KernelBenchEntry::from_result(&r, rays, "rays/s"));
    }

    for &n in bp_sizes {
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        let p = kernels::forward(&g, &v, Projector::Siddon, threads);
        let r = bench(&format!("bp_fdk n={n} a={n_angles}"), warmup, min_iters, budget, || {
            std::hint::black_box(kernels::backward(&g, &p, BackprojWeight::Fdk, threads));
        });
        let updates = (n * n * n * n_angles) as f64;
        out.push(KernelBenchEntry::from_result(&r, updates, "voxel_updates/s"));
    }

    // FDK filtering (FFT hot path)
    {
        let n = if smoke { 32 } else { 64 };
        let g = Geometry::cone_beam(n, 32);
        let mut p = ProjectionSet::zeros_like(&g);
        let mut rng = crate::util::pcg::Pcg32::new(1);
        for v in &mut p.data {
            *v = rng.next_f32();
        }
        let r = bench(&format!("fdk_filter n={n} a=32"), warmup, min_iters, budget, || {
            let mut q = p.clone();
            kernels::filtering::fdk_filter(&g, &mut q, kernels::filtering::Window::Hann, threads);
            std::hint::black_box(q);
        });
        let pixels = (n * n * 32) as f64;
        out.push(KernelBenchEntry::from_result(&r, pixels, "pixels/s"));
    }

    out
}

/// Encode one run (label + entries) as a JSON object.
pub fn run_to_json(label: &str, threads: usize, smoke: bool, entries: &[KernelBenchEntry]) -> Json {
    Json::obj(vec![
        ("label", Json::str(label)),
        ("threads", Json::num(threads as f64)),
        ("smoke", Json::Bool(smoke)),
        (
            "entries",
            Json::arr(
                entries
                    .iter()
                    .map(|e| {
                        Json::obj(vec![
                            ("name", Json::str(e.name.clone())),
                            ("median_s", Json::num(e.median_s)),
                            ("min_s", Json::num(e.min_s)),
                            ("samples", Json::num(e.samples as f64)),
                            ("work_per_call", Json::num(e.work_per_call)),
                            ("unit", Json::str(e.unit)),
                            ("throughput", Json::num(e.throughput())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Append a run to `path` (created if absent, schema-checked if present)
/// and write the file back pretty-printed. Top-level fields other than
/// `runs` (e.g. the checked-in `notes` block) are preserved verbatim.
pub fn append_run_to_file(
    path: &Path,
    label: &str,
    threads: usize,
    smoke: bool,
    entries: &[KernelBenchEntry],
) -> anyhow::Result<()> {
    super::append_trajectory_run(path, SCHEMA, run_to_json(label, threads, smoke, entries))
}

/// Speedup table between the first and last runs of a trajectory file
/// (matched by entry name): `(name, before_s, after_s, speedup)` rows.
/// Runs recorded with different configurations (`threads`, `smoke`) are
/// not comparable — an empty table is returned rather than attributing
/// configuration differences to kernel changes.
pub fn speedups(doc: &Json) -> Vec<(String, f64, f64, f64)> {
    let Some(runs) = doc.get("runs").and_then(Json::as_arr) else { return Vec::new() };
    let (Some(first), Some(last)) = (runs.first(), runs.last()) else { return Vec::new() };
    if runs.len() < 2 {
        return Vec::new();
    }
    let config = |run: &Json| {
        (
            run.get("threads").and_then(Json::as_usize),
            run.get("smoke").and_then(Json::as_bool),
        )
    };
    if config(first) != config(last) {
        return Vec::new();
    }
    let entries = |run: &Json| -> Vec<(String, f64)> {
        run.get("entries")
            .and_then(Json::as_arr)
            .map(|es| {
                es.iter()
                    .filter_map(|e| {
                        Some((
                            e.get("name")?.as_str()?.to_string(),
                            e.get("median_s")?.as_f64()?,
                        ))
                    })
                    .collect()
            })
            .unwrap_or_default()
    };
    let before = entries(first);
    let after = entries(last);
    let mut rows = Vec::new();
    for (name, b) in &before {
        if let Some((_, a)) = after.iter().find(|(n, _)| n == name) {
            rows.push((name.clone(), *b, *a, if *a > 0.0 { *b / *a } else { f64::INFINITY }));
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_entries() -> Vec<KernelBenchEntry> {
        vec![KernelBenchEntry {
            name: "fp_siddon n=64 a=16".into(),
            median_s: 0.5,
            min_s: 0.4,
            samples: 3,
            work_per_call: 65536.0,
            unit: "rays/s",
        }]
    }

    #[test]
    fn run_json_has_schema_fields() {
        let j = run_to_json("test", 4, true, &fake_entries());
        assert_eq!(j.get("label").and_then(Json::as_str), Some("test"));
        assert_eq!(j.get("threads").and_then(Json::as_usize), Some(4));
        let es = j.get("entries").and_then(Json::as_arr).unwrap();
        assert_eq!(es.len(), 1);
        assert_eq!(es[0].get("unit").and_then(Json::as_str), Some("rays/s"));
        assert!(es[0].get("throughput").and_then(Json::as_f64).unwrap() > 0.0);
    }

    #[test]
    fn append_creates_then_appends_and_speedups_match() {
        let dir = std::env::temp_dir().join(format!("tigre_bench_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_kernels.json");
        let _ = std::fs::remove_file(&path);

        let mut before = fake_entries();
        append_run_to_file(&path, "before", 4, true, &before).unwrap();
        before[0].median_s = 0.25; // 2× faster "after"
        append_run_to_file(&path, "after", 4, true, &before).unwrap();

        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some(SCHEMA));
        assert_eq!(doc.get("runs").and_then(Json::as_arr).unwrap().len(), 2);
        let rows = speedups(&doc);
        assert_eq!(rows.len(), 1);
        let row = &rows[0];
        assert_eq!(row.0.as_str(), "fp_siddon n=64 a=16");
        assert!((row.1 / row.2 - 2.0).abs() < 1e-12);
        assert!((row.3 - 2.0).abs() < 1e-12);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn append_preserves_unknown_top_level_fields() {
        let dir = std::env::temp_dir().join(format!("tigre_bench_notes_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_kernels.json");
        std::fs::write(
            &path,
            format!(r#"{{"schema": "{SCHEMA}", "notes": ["keep me"], "runs": []}}"#),
        )
        .unwrap();
        append_run_to_file(&path, "r1", 2, true, &fake_entries()).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let notes = doc.get("notes").and_then(Json::as_arr).expect("notes survive append");
        assert_eq!(notes[0].as_str(), Some("keep me"));
        assert_eq!(doc.get("runs").and_then(Json::as_arr).unwrap().len(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn speedups_refuse_mismatched_configs() {
        let mk = |threads: usize, smoke: bool| run_to_json("r", threads, smoke, &fake_entries());
        let doc = Json::obj(vec![
            ("schema", Json::str(SCHEMA)),
            ("runs", Json::arr(vec![mk(16, false), mk(2, false)])),
        ]);
        assert!(speedups(&doc).is_empty(), "different thread counts must not compare");
        let doc = Json::obj(vec![
            ("schema", Json::str(SCHEMA)),
            ("runs", Json::arr(vec![mk(4, false), mk(4, true)])),
        ]);
        assert!(speedups(&doc).is_empty(), "smoke vs full must not compare");
        let doc = Json::obj(vec![
            ("schema", Json::str(SCHEMA)),
            ("runs", Json::arr(vec![mk(4, false), mk(4, false)])),
        ]);
        assert_eq!(speedups(&doc).len(), 1);
    }

    #[test]
    fn smoke_suite_runs_quickly_and_covers_kernels() {
        let entries = run_suite(true, 2);
        assert!(entries.iter().any(|e| e.name.starts_with("fp_siddon")));
        assert!(entries.iter().any(|e| e.name.starts_with("fp_joseph")));
        assert!(entries.iter().any(|e| e.name.starts_with("bp_fdk")));
        assert!(entries.iter().any(|e| e.name.starts_with("fdk_filter")));
        for e in &entries {
            assert!(e.median_s > 0.0 && e.samples >= 1, "{}: empty samples", e.name);
            assert!(e.throughput() > 0.0);
        }
    }
}
