//! Simulated device memory ledger.

use std::collections::BTreeMap;

use crate::util::units::{fmt_bytes, GIB};

/// Static description of a simulated GPU.
#[derive(Clone, Debug)]
pub struct GpuSpec {
    /// Human-readable device name (reports and traces).
    pub name: String,
    /// Device RAM capacity in bytes.
    pub mem_bytes: u64,
}

impl GpuSpec {
    /// The paper's testbed device: NVIDIA GTX 1080 Ti, 11 GiB.
    pub fn gtx1080ti() -> Self {
        Self { name: "GTX 1080 Ti (sim)".into(), mem_bytes: 11 * GIB }
    }

    /// A deliberately tiny device, used to force many image partitions in
    /// tests ("arbitrarily small memories", paper abstract).
    pub fn tiny(mem_bytes: u64) -> Self {
        Self { name: format!("tiny-{}", fmt_bytes(mem_bytes)), mem_bytes }
    }
}

/// Tracks named allocations against the device capacity.
#[derive(Debug)]
pub struct DeviceMem {
    spec: GpuSpec,
    allocs: BTreeMap<String, u64>,
    used: u64,
    peak: u64,
}

impl DeviceMem {
    /// Empty ledger for a device of the given spec.
    pub fn new(spec: GpuSpec) -> Self {
        Self { spec, allocs: BTreeMap::new(), used: 0, peak: 0 }
    }

    /// Device RAM capacity in bytes.
    pub fn capacity(&self) -> u64 {
        self.spec.mem_bytes
    }

    /// Bytes currently allocated.
    pub fn used(&self) -> u64 {
        self.used
    }

    /// High-water mark of usage (the invariant checked by tests: it must
    /// never exceed capacity for any problem size).
    pub fn peak(&self) -> u64 {
        self.peak
    }

    /// Bytes still available (capacity − used).
    pub fn free_bytes(&self) -> u64 {
        self.capacity() - self.used
    }

    /// Allocate; errors if capacity would be exceeded or the label exists.
    pub fn alloc(&mut self, label: &str, bytes: u64) -> Result<(), String> {
        if self.allocs.contains_key(label) {
            return Err(format!("allocation '{label}' already exists"));
        }
        if self.used + bytes > self.capacity() {
            return Err(format!(
                "requested {} but only {} free of {}",
                fmt_bytes(bytes),
                fmt_bytes(self.free_bytes()),
                fmt_bytes(self.capacity())
            ));
        }
        self.allocs.insert(label.to_string(), bytes);
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        Ok(())
    }

    /// Free by label (no-op for unknown labels, mirroring cudaFree(null)).
    pub fn free(&mut self, label: &str) {
        if let Some(bytes) = self.allocs.remove(label) {
            self.used -= bytes;
        }
    }

    /// Size of the named allocation, if it exists.
    pub fn get(&self, label: &str) -> Option<u64> {
        self.allocs.get(label).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_tracks_usage_and_peak() {
        let mut m = DeviceMem::new(GpuSpec::tiny(1000));
        m.alloc("a", 600).unwrap();
        m.alloc("b", 300).unwrap();
        assert_eq!(m.used(), 900);
        m.free("a");
        assert_eq!(m.used(), 300);
        assert_eq!(m.peak(), 900);
        m.alloc("c", 700).unwrap();
        assert_eq!(m.peak(), 1000);
    }

    #[test]
    fn over_capacity_rejected() {
        let mut m = DeviceMem::new(GpuSpec::tiny(100));
        assert!(m.alloc("x", 101).is_err());
        m.alloc("y", 60).unwrap();
        assert!(m.alloc("z", 41).is_err());
        assert_eq!(m.used(), 60);
    }

    #[test]
    fn duplicate_label_rejected() {
        let mut m = DeviceMem::new(GpuSpec::tiny(100));
        m.alloc("x", 10).unwrap();
        assert!(m.alloc("x", 10).is_err());
    }

    #[test]
    fn free_unknown_is_noop() {
        let mut m = DeviceMem::new(GpuSpec::tiny(100));
        m.free("ghost");
        assert_eq!(m.used(), 0);
    }

    #[test]
    fn gtx1080ti_capacity() {
        assert_eq!(GpuSpec::gtx1080ti().mem_bytes, 11 * GIB);
    }
}
