"""L1 correctness: Pallas kernels vs the pure-jnp oracles, plus analytic
properties of the oracles themselves."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import backprojector, projector, ref
from compile.kernels import geometry as geo


def cube_volume(n, half_frac=0.5, density=1.0):
    c = (n - 1) / 2.0
    half = half_frac * n / 2.0
    idx = np.arange(n)
    inside = (
        (np.abs(idx[None, None, :] - c) <= half)
        & (np.abs(idx[None, :, None] - c) <= half)
        & (np.abs(idx[:, None, None] - c) <= half)
    )
    return jnp.asarray(inside.astype(np.float32) * density)


def uniform_angles(a):
    return jnp.arange(a, dtype=jnp.float32) * (2.0 * np.pi / a)


# ---------------------------------------------------------------- pallas vs ref


@pytest.mark.parametrize("n,a", [(8, 2), (12, 4), (16, 3)])
def test_pallas_forward_matches_ref(n, a):
    vol = cube_volume(n)
    params = ref.default_params(n)
    angles = uniform_angles(a)
    got = projector.forward(vol, params, angles, nu=n, nv=n)
    want = ref.forward_ref(vol, params, angles, nu=n, nv=n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,a", [(8, 2), (12, 4), (16, 3)])
def test_pallas_backward_matches_ref(n, a):
    rng = np.random.default_rng(7)
    proj = jnp.asarray(rng.random((a, n, n), dtype=np.float32))
    params = ref.default_params(n)
    angles = uniform_angles(a)
    got = backprojector.backward(proj, params, angles, nx=n, ny=n, nz=n)
    want = ref.backward_ref(proj, params, angles, nx=n, ny=n, nz=n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([6, 8, 10]),
    a=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pallas_forward_matches_ref_random_volumes(n, a, seed):
    rng = np.random.default_rng(seed)
    vol = jnp.asarray(rng.random((n, n, n), dtype=np.float32))
    params = ref.default_params(n)
    angles = uniform_angles(a)
    got = projector.forward(vol, params, angles, nu=n, nv=n)
    want = ref.forward_ref(vol, params, angles, nu=n, nv=n)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([6, 8, 10]),
    a=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pallas_backward_matches_ref_random_projections(n, a, seed):
    rng = np.random.default_rng(seed)
    proj = jnp.asarray(rng.random((a, n, n), dtype=np.float32))
    params = ref.default_params(n)
    angles = uniform_angles(a)
    got = backprojector.backward(proj, params, angles, nx=n, ny=n, nz=n)
    want = ref.backward_ref(proj, params, angles, nx=n, ny=n, nz=n)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    nv=st.sampled_from([6, 9]),
    nu=st.sampled_from([6, 9]),
    off=st.floats(min_value=-2.0, max_value=2.0),
)
def test_pallas_forward_anisotropic_detector_and_offset(nv, nu, off):
    # panel-shifted scans (the paper's coffee-bean dataset) exercise off_u
    n = 8
    vol = cube_volume(n)
    params = np.array(ref.default_params(n, nu=nu, nv=nv))
    params[geo.OFF_U] = off
    params = jnp.asarray(params)
    angles = uniform_angles(2)
    got = projector.forward(vol, params, angles, nu=nu, nv=nv)
    want = ref.forward_ref(vol, params, angles, nu=nu, nv=nv)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- oracle sanity


def test_ref_forward_central_ray_integral():
    # central rays see the cube, corner rays see air
    n = 16
    vol = cube_volume(n, half_frac=0.4)
    params = ref.default_params(n)
    angles = uniform_angles(4)
    p = np.asarray(ref.forward_ref(vol, params, angles, nu=n, nv=n))
    assert p[:, n // 2, n // 2].min() > 3.0
    assert abs(p[:, 0, 0]).max() < 1e-6


def test_ref_forward_linearity():
    n = 10
    rng = np.random.default_rng(3)
    vol = jnp.asarray(rng.random((n, n, n), dtype=np.float32))
    params = ref.default_params(n)
    angles = uniform_angles(3)
    p1 = ref.forward_ref(vol, params, angles, nu=n, nv=n)
    p2 = ref.forward_ref(2.0 * vol, params, angles, nu=n, nv=n)
    np.testing.assert_allclose(2.0 * p1, p2, rtol=1e-5)


def test_ref_backward_slab_recentring():
    # a recentred slab (oz offset) must equal the corresponding slab of
    # the full backprojection — the coordinator's slab_geometry contract
    n = 12
    rng = np.random.default_rng(5)
    proj = jnp.asarray(rng.random((3, n, n), dtype=np.float32))
    params = ref.default_params(n)
    angles = uniform_angles(3)
    full = np.asarray(ref.backward_ref(proj, params, angles, nx=n, ny=n, nz=n))

    # slab z in [4, 8): centre offset = (4 + 2) - 6 = 0 ... compute as rust
    z0, z1 = 4, 9
    sl_params = np.array(params)
    sl_params[geo.OZ] = (z0 + (z1 - z0) / 2.0) - n / 2.0
    slab = np.asarray(
        ref.backward_ref(proj, jnp.asarray(sl_params), angles, nx=n, ny=n, nz=z1 - z0)
    )
    np.testing.assert_allclose(slab, full[z0:z1], rtol=1e-4, atol=1e-5)


def test_ref_forward_rotational_symmetry():
    # a centred ball projects with equal energy at every angle
    n = 16
    c = (n - 1) / 2.0
    idx = np.arange(n)
    d2 = (
        (idx[None, None, :] - c) ** 2
        + (idx[None, :, None] - c) ** 2
        + (idx[:, None, None] - c) ** 2
    )
    vol = jnp.asarray((d2 < 5.0**2).astype(np.float32))
    params = ref.default_params(n)
    angles = uniform_angles(8)
    p = np.asarray(ref.forward_ref(vol, params, angles, nu=n, nv=n))
    energies = np.sqrt((p**2).sum(axis=(1, 2)))
    assert energies.std() / energies.mean() < 0.02


def test_bilinear_boundary_zero():
    img = jnp.ones((4, 4), dtype=jnp.float32)
    out = ref.bilinear(img, jnp.asarray([-1.0, 5.0, 1.5]), jnp.asarray([1.0, 1.0, 1.5]))
    np.testing.assert_allclose(np.asarray(out), [0.0, 0.0, 1.0])
