//! Measurement-physics simulation: turn ideal line integrals into
//! realistic noisy projections (Beer–Lambert transmission + Poisson
//! counting statistics + electronic noise), as the paper's measured
//! datasets exhibit (the fossil scan runs at 3.37 µA — photon-starved).

use crate::util::pcg::Pcg32;
use crate::volume::ProjectionSet;

/// Noise model parameters.
#[derive(Clone, Copy, Debug)]
pub struct NoiseModel {
    /// Incident photon count per detector pixel (I₀).
    pub i0: f64,
    /// Std-dev of additive electronic noise, in counts.
    pub electronic_sigma: f64,
    /// PRNG seed.
    pub seed: u64,
}

impl Default for NoiseModel {
    fn default() -> Self {
        Self { i0: 1.0e4, electronic_sigma: 2.0, seed: 0 }
    }
}

/// Apply the model: p → -ln( Poisson(I₀·e^{−p}) + N(0,σ) ) / I₀.
/// Output is again a line-integral-domain projection set.
pub fn apply(proj: &ProjectionSet, model: &NoiseModel) -> ProjectionSet {
    let mut rng = Pcg32::new(model.seed);
    let mut out = proj.clone();
    for v in &mut out.data {
        let transmitted = model.i0 * (-(*v as f64)).exp();
        let counts = rng.poisson(transmitted) as f64
            + model.electronic_sigma * rng.normal();
        // clamp to one count: a dead pixel would otherwise be +inf
        let counts = counts.max(1.0);
        *v = -((counts / model.i0).ln()) as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{ExecMode, MultiGpu};
    use crate::geometry::Geometry;
    use crate::phantom;

    fn clean_projections() -> ProjectionSet {
        let g = Geometry::cone_beam(16, 8);
        let v = phantom::cube(16, 0.5, 0.05); // thin object: high transmission
        let ctx = MultiGpu::gtx1080ti(1);
        ctx.forward(&g, Some(&v), ExecMode::Full).unwrap().0.unwrap()
    }

    #[test]
    fn high_flux_is_nearly_noiseless() {
        let p = clean_projections();
        let n = apply(&p, &NoiseModel { i0: 1e9, electronic_sigma: 0.0, seed: 1 });
        let rel = {
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for (a, b) in p.data.iter().zip(&n.data) {
                num += ((a - b) as f64).powi(2);
                den += (*a as f64).powi(2) + 1e-12;
            }
            (num / den).sqrt()
        };
        assert!(rel < 0.02, "high-flux relative deviation {rel}");
    }

    #[test]
    fn lower_flux_is_noisier() {
        let p = clean_projections();
        let hi = apply(&p, &NoiseModel { i0: 1e6, electronic_sigma: 0.0, seed: 2 });
        let lo = apply(&p, &NoiseModel { i0: 1e2, electronic_sigma: 0.0, seed: 2 });
        let dev = |q: &ProjectionSet| -> f64 {
            p.data
                .iter()
                .zip(&q.data)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        assert!(dev(&lo) > dev(&hi) * 3.0, "lo {} hi {}", dev(&lo), dev(&hi));
    }

    #[test]
    fn unbiased_in_expectation_at_moderate_flux() {
        let p = clean_projections();
        // average many noisy realizations: mean ≈ clean (small log bias)
        let mut mean = ProjectionSet::zeros(p.nu, p.nv, p.n_angles);
        let reps = 40;
        for s in 0..reps {
            let n = apply(&p, &NoiseModel { i0: 1e5, electronic_sigma: 0.0, seed: s });
            mean.accumulate(&n);
        }
        for v in &mut mean.data {
            *v /= reps as f32;
        }
        let rel = {
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for (a, b) in p.data.iter().zip(&mean.data) {
                num += ((a - b) as f64).powi(2);
                den += (*a as f64).powi(2) + 1e-12;
            }
            (num / den).sqrt()
        };
        assert!(rel < 0.05, "bias {rel}");
    }

    #[test]
    fn dead_pixels_clamped_finite() {
        let mut p = clean_projections();
        for v in &mut p.data {
            *v = 50.0; // opaque: ~zero transmission
        }
        let n = apply(&p, &NoiseModel { i0: 100.0, electronic_sigma: 5.0, seed: 3 });
        assert!(n.data.iter().all(|v| v.is_finite()));
    }
}
