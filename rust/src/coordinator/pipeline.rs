//! The pipelined real executor shared by both operators (PR 3).
//!
//! The simulated timeline has always modeled the paper's overlap story —
//! kernels queued before copies so DMA hides behind compute (Alg. 1/2,
//! Fig. 5) — but the real numeric path used to run every device, slab and
//! angle chunk strictly sequentially on the host thread, staging each
//! piece through `extract_slab`/`extract_chunk` memcpys. This module
//! closes that gap with the CPU analogue of the paper's schedule:
//!
//! 1. **Concurrent device workers.** Each [`DeviceAssignment`] becomes one
//!    scoped job on a [`ThreadPool`] (`Scope::spawn`), so simulated
//!    devices execute concurrently for real. The backend's kernel-thread
//!    budget is divided across workers, keeping total host parallelism
//!    constant.
//! 2. **Zero-copy staging views.** Slab and chunk inputs reach the
//!    kernels as borrowed [`VolumeSlabView`]/[`ProjChunkView`] windows of
//!    the resident arrays (both are contiguous by the layout invariants in
//!    DESIGN.md), and angle-split outputs are written straight into
//!    disjoint windows of the shared output — the executor no longer
//!    copies a single staging buffer on the native backend.
//! 3. **Double-buffered merge lane.** Within a worker, launches follow the
//!    Alg. 1/2 queue order: the kernel for launch `k+1` runs while a
//!    dedicated merge lane folds launch `k`'s partial into the running
//!    accumulator, cycling two staging buffers exactly like the paper's
//!    two on-device projection buffers. Compute hides the (memory-bound)
//!    merge the way the paper hides DMA behind kernels.
//!
//! ## Determinism
//!
//! Outputs are **bit-identical for every worker/thread count**:
//! * per launch, the kernels are thread-count-exact (disjoint output
//!   rows/slices, fixed accumulation order — DESIGN.md §Perf);
//! * within a worker, the merge lane folds partials in launch order
//!   (slab-major, then chunk) through a FIFO channel;
//! * across workers, partial results combine in a fixed order: per-device
//!   partials are reduced by the **canonical pairwise schedule**
//!   ([`merge_schedule`]) — fixed pairings, fixed operand order — for
//!   *both* merge strategies (forward image-split), or land in disjoint
//!   regions (forward angle-split chunks, backward z-slabs) where order
//!   cannot matter.
//!
//! ## Reduction-tree merge (PR 6)
//!
//! [`MergeStrategy`] selects how image-split forward partials fold:
//! `Linear` executes the canonical schedule serially on the host after
//! the workers join; `Tree` executes the same schedule as pairwise
//! worker folds — in each stride-doubling round, worker `i` receives and
//! folds worker `i+stride`'s partial over a channel, overlapped with
//! whatever kernel launches other workers still have in flight. Because
//! the two strategies perform the identical folds in the identical
//! operand order, their outputs are bit-identical; the tree only
//! shortens the merge critical path from `n−1` serial host folds to
//! `⌈log₂ n⌉` rounds. The overlapped in-worker form requires every
//! worker to be resident on the pool at once (a blocked `recv` whose
//! partner is still queued would deadlock — the [`ThreadPool`] rule that
//! jobs must not block on other jobs of the same pool); with fewer
//! workers than active devices the tree falls back to the host-side
//! serial execution of the same schedule, which cannot change a single
//! bit of output. See DESIGN.md §Reduction-tree.
//!
//! The pre-PR3 host-sequential loops are kept below
//! ([`forward_sequential`], [`backward_sequential`]) behind
//! [`ExecutorConfig::pipelined`]` = false` as the benchmark comparison
//! baseline (`bench::coordinator`, `BENCH_coordinator.json`).
//!
//! ## Statelessness contract (PR 4)
//!
//! The cross-iteration residency layer (`coordinator::residency`) sits
//! *above* these executors: it decides which simulated transfers are
//! skipped, but always hands this module the same host-resident arrays.
//! Everything here must therefore stay stateless and deterministic in its
//! inputs — that is what lets `ReconSession` guarantee bit-identical
//! output with the cache on or off, for every worker count.

// Wall-clock reads here feed only the hang watchdog and the degradation
// telemetry (UnitWatch), never the simulated schedule — the DES stays
// deterministic. Waived like a lint-allow entry (see rust/clippy.toml).
#![allow(clippy::disallowed_methods)]

use std::sync::mpsc;

use crate::geometry::Geometry;
use crate::geometry::split::{AngleChunk, ZSlab};
use crate::kernels::scratch;
use crate::simgpu::fault::{FaultScope, LaunchFault, MAX_LAUNCH_RETRIES};
use crate::util::threadpool::{SendPtr, ThreadPool};
use crate::volume::{
    OocProjections, OocVolume, ProjChunkView, ProjInput, ProjectionSet, Volume, VolumeInput,
    VolumeSlabView,
};

use super::degrade::DegradeEvent;
use super::error::{NonFiniteStage, ReconError};
use super::executor::{Backend, MultiGpu};
use super::splitter::{merge_schedule, replan_excluding, DeviceAssignment, MergeStrategy, Plan};

/// Staging buffers cycled through each worker's merge lane — the paper's
/// double buffer (Alg. 1 line 6 / Alg. 2 line 6). The out-of-core
/// loader lanes cycle the same number of disk staging buffers, extending
/// the double-buffer discipline one memory tier up (PR 5): the loader
/// prefetches unit `k+1` from the store while unit `k` computes.
const N_STAGE_BUFFERS: usize = 2;

/// Concurrency for `n_jobs` device jobs under the context's config. Also
/// capped at the backend's total kernel threads so concurrent **kernel**
/// threads never exceed the sequential baseline's budget — the
/// iso-resource premise of `bench::coordinator`'s speedup comparison.
/// (Each worker additionally runs one merge-lane thread, but that thread
/// only performs the `+=` fold the sequential path does inline on its
/// kernel-thread time — moved off the critical path, not added work.)
///
/// The pool itself is created per operator call (`ThreadPool::new` below)
/// rather than held on `MultiGpu`: spawning ≤4 OS threads costs tens of
/// microseconds against millisecond-scale kernel launches, keeps
/// `MultiGpu: Clone` trivial, and bounds concurrency exactly per call.
/// The price — pool-worker scratch arenas are always cold — is paid once
/// here by taking every partial/staging buffer on the host thread, whose
/// arena persists across the calls of an iterative reconstruction.
fn worker_count(ctx: &MultiGpu, n_jobs: usize) -> usize {
    let cap = if ctx.exec.workers == 0 { n_jobs } else { ctx.exec.workers };
    cap.min(n_jobs.max(1)).min(ctx.backend_threads().max(1)).max(1)
}

/// Per-**job** kernel thread budgets (`budgets[i]` for job `i`), keeping
/// the concurrent total within the backend's thread count — the
/// iso-resource premise of the bench comparison. When every job has its
/// own worker (`n_jobs == workers`, the default), the backend total is
/// split exactly, remainder included. With fewer workers than jobs, pool
/// workers pick jobs up FIFO-opportunistically, so *any* `workers`-sized
/// subset of jobs can run concurrently — every job then gets the floor
/// share, trading a little parallelism for never oversubscribing.
fn kernel_thread_budgets(ctx: &MultiGpu, workers: usize, n_jobs: usize) -> Vec<usize> {
    let workers = workers.max(1);
    let total = ctx.backend_threads();
    if n_jobs == workers {
        let base = total / workers;
        let extra = total % workers;
        (0..n_jobs).map(|i| (base + usize::from(i < extra)).max(1)).collect()
    } else {
        vec![(total / workers).max(1); n_jobs]
    }
}

fn join_all<T>(handles: Vec<crate::util::threadpool::ScopedHandle<'_, T>>) -> Vec<T> {
    let mut out = Vec::with_capacity(handles.len());
    for h in handles {
        match h.join() {
            Ok(v) => out.push(v),
            Err(payload) => std::panic::resume_unwind(payload),
        }
    }
    out
}

// ---------------------------------------------------------------------------
// fault injection and unit-level recovery (ISSUE 7)
// ---------------------------------------------------------------------------

/// Real-path backoff before retry `i` of a transiently-failed launch,
/// in microseconds (doubling; the real mirror of the simulated
/// `CostModel::fault_retry_backoff_s`). Tiny so fault tests stay fast —
/// the *policy* (bounded count, doubling) is what production tuning
/// would scale up.
const REAL_RETRY_BACKOFF_US: u64 = 50;

/// Reset the fault plan's real-scope ordinals at an operator entry.
fn begin_real_op(ctx: &MultiGpu) {
    if let Some(plan) = &ctx.fault {
        plan.begin_op(FaultScope::Real);
    }
}

/// Pre-launch fault gate for one unit on `dev`: consumes injected
/// transient failures by sleeping the bounded, doubling backoff and then
/// letting the retried launch proceed (bit-identity is untouched — the
/// unit still executes exactly once). Returns `true` when the device is
/// permanently lost — injected directly, or escalated by a transient
/// burst exceeding [`MAX_LAUNCH_RETRIES`] — in which case the worker
/// stops issuing units and the host replans the remainder.
fn launch_gate(ctx: &MultiGpu, dev: usize) -> bool {
    let Some(plan) = &ctx.fault else { return false };
    match plan.launch_fault(FaultScope::Real, dev) {
        LaunchFault::Ok => false,
        LaunchFault::Transient(k) if k <= MAX_LAUNCH_RETRIES => {
            for i in 0..k {
                std::thread::sleep(std::time::Duration::from_micros(
                    REAL_RETRY_BACKOFF_US << i,
                ));
            }
            false
        }
        // Hung unit (ISSUE 8): the watchdog fires after the unit's
        // deadline, kills the launch, and retries on the same device with
        // the same bounded backoff as a transient — the unit still
        // executes exactly once, so bit-identity is untouched. A hang
        // persisting past the retry budget escalates to device loss
        // through the same machinery as a transient burst.
        LaunchFault::Hung(k) if k <= MAX_LAUNCH_RETRIES => {
            ctx.degrade.record(DegradeEvent::HangRetry { device: dev, times: k });
            for i in 0..k {
                std::thread::sleep(std::time::Duration::from_micros(
                    REAL_RETRY_BACKOFF_US << i,
                ));
            }
            false
        }
        LaunchFault::Hung(_) => {
            ctx.degrade.record(DegradeEvent::WatchdogEscalated { device: dev });
            plan.mark_lost(FaultScope::Real, dev);
            true
        }
        LaunchFault::Transient(_) => {
            plan.mark_lost(FaultScope::Real, dev);
            true
        }
        LaunchFault::Lost => true,
    }
}

/// Numerical-health scan at a merge boundary (ISSUE 8): the first
/// non-finite element fails the operator with a typed [`ReconError`]
/// instead of silently folding NaN/Inf into every downstream voxel.
fn ensure_finite(data: &[f32], stage: NonFiniteStage, what: &str) -> Result<(), ReconError> {
    match data.iter().enumerate().find(|&(_, v)| !v.is_finite()) {
        Some((i, v)) => Err(ReconError::NonFinite {
            stage,
            index: i,
            detail: format!("{what}: value {v}"),
        }),
        None => Ok(()),
    }
}

/// Record-only wall-clock watchdog for real-path units: the deadline is
/// [`CostModel::watchdog_factor`](crate::simgpu::CostModel) times the
/// running mean of this worker's earlier unit times. Overruns are
/// recorded as [`DegradeEvent::SlowUnit`] and never escalated — host
/// wall-clock on a shared CPU is too noisy to kill a device over;
/// injected `Hang` faults drive the escalation machinery
/// deterministically instead (see [`launch_gate`]).
struct UnitWatch {
    device: usize,
    factor: f64,
    mean_s: f64,
    n: u32,
}

impl UnitWatch {
    fn new(ctx: &MultiGpu, device: usize) -> Self {
        Self { device, factor: ctx.cost.watchdog_factor, mean_s: 0.0, n: 0 }
    }

    fn observe(&mut self, ctx: &MultiGpu, elapsed_s: f64) {
        if self.n > 0 {
            let deadline_s = self.factor * self.mean_s;
            if elapsed_s > deadline_s {
                ctx.degrade.record(DegradeEvent::SlowUnit {
                    device: self.device,
                    elapsed_s,
                    deadline_s,
                });
            }
        }
        self.n += 1;
        self.mean_s += (elapsed_s - self.mean_s) / self.n as f64;
    }
}

/// Per-assignment expected launch counts and the loss flags derived from
/// what the workers actually completed. Returns `None` when every
/// assignment ran to completion (the fast path — no recovery needed).
fn loss_flags(
    ctx: &MultiGpu,
    active: &[&DeviceAssignment],
    completed: &[usize],
    needs: &[usize],
) -> Option<Vec<bool>> {
    if completed.iter().zip(needs).all(|(c, n)| c >= n) {
        return None;
    }
    let n = ctx.n_gpus.max(active.iter().map(|d| d.device + 1).max().unwrap_or(0));
    let mut lost = vec![false; n];
    for (i, dev) in active.iter().enumerate() {
        if completed[i] < needs[i] {
            lost[dev.device] = true;
        }
    }
    Some(lost)
}

/// The volume input a lost forward assignment recovers from.
#[derive(Clone, Copy)]
enum FpSource<'a> {
    Ram(&'a Volume),
    Ooc(&'a OocVolume),
}

/// Continue each lost device's image-split forward assignment from its
/// first unexecuted unit, folding every launch into that assignment's
/// own partial **in the original launch order** (slab-major, then
/// chunk) — the same order the worker's merge lane used. The unit
/// partition and per-assignment fold order are unchanged, so the
/// canonical cross-device merge that follows produces bit-identical
/// output to the fault-free run. `replan_excluding` validates survivors
/// exist (and pins the ownership policy); the units themselves execute
/// on the host's kernel threads, which *are* the surviving capacity in
/// this CPU-backed reproduction.
fn recover_fp_losses(
    ctx: &MultiGpu,
    g: &Geometry,
    src: FpSource<'_>,
    plan: &Plan,
    active: &[&DeviceAssignment],
    completed: &[usize],
    folded: &mut [Option<ProjectionSet>],
) -> anyhow::Result<()> {
    let n_chunks = plan.angle_chunks.len();
    let needs: Vec<usize> = active.iter().map(|d| d.slabs.len() * n_chunks).collect();
    let Some(lost) = loss_flags(ctx, active, completed, &needs) else {
        return Ok(());
    };
    let _owners = replan_excluding(lost.len(), &lost).map_err(ReconError::AllDevicesLost)?;
    let per = g.n_det[0] * g.n_det[1];
    let plane = g.n_vox[0] * g.n_vox[1];
    let threads = ctx.backend_threads();
    let mut slab_buf: Vec<f32> = Vec::new();
    let mut chunk_buf = scratch::take_zeroed(
        plan.angle_chunks.iter().map(|c| c.len()).max().unwrap_or(0) * per,
    );
    for (i, dev) in active.iter().enumerate() {
        if completed[i] >= needs[i] {
            continue;
        }
        debug_assert_ne!(_owners[dev.device], dev.device, "lost device needs a new owner");
        let partial = folded[i]
            .as_mut()
            .expect("loss degrades the tree, so every worker returns its partial");
        for unit in completed[i]..needs[i] {
            let slab = dev.slabs[unit / n_chunks];
            let ch = plan.angle_chunks[unit % n_chunks];
            let gs = g.slab_geometry(slab.z0, slab.z1);
            let gc = gs.angle_chunk_geometry(ch.a0, ch.a1);
            let sub: VolumeSlabView<'_> = match src {
                FpSource::Ram(v) => v.slab_view(slab.z0, slab.z1),
                FpSource::Ooc(store) => {
                    slab_buf.resize(slab.len() * plane, 0.0);
                    store.load_slab_into(slab.z0, slab.z1, &mut slab_buf)?;
                    VolumeSlabView {
                        nx: g.n_vox[0],
                        ny: g.n_vox[1],
                        nz: slab.len(),
                        data: &slab_buf,
                    }
                }
            };
            chunk_buf.resize(ch.len() * per, 0.0);
            ctx.kernel_forward_into(&gc, &sub, &mut chunk_buf, threads);
            let dst = &mut partial.data[ch.a0 * per..ch.a0 * per + chunk_buf.len()];
            for (o, v) in dst.iter_mut().zip(&chunk_buf) {
                *o += *v;
            }
        }
    }
    scratch::recycle(chunk_buf);
    Ok(())
}

/// The projection input a lost backprojection assignment recovers from.
#[derive(Clone, Copy)]
enum BpSource<'a> {
    Ram(&'a ProjectionSet),
    Ooc(&'a OocProjections),
}

/// Continue each lost device's backprojection assignment from its first
/// unexecuted unit, accumulating into the shared output exactly as the
/// worker's merge lane would have (zeroed per-launch buffer, `+=` into
/// the slab's z-window, launch order preserved) — device z-ranges are
/// disjoint, so recovered output is bit-identical by the same argument
/// as the fault-free path.
fn recover_bp_losses(
    ctx: &MultiGpu,
    g: &Geometry,
    src: BpSource<'_>,
    plan: &Plan,
    active: &[&DeviceAssignment],
    completed: &[usize],
    out: &mut Volume,
) -> anyhow::Result<()> {
    let n_chunks = plan.angle_chunks.len();
    let needs: Vec<usize> = active.iter().map(|d| d.slabs.len() * n_chunks).collect();
    let Some(lost) = loss_flags(ctx, active, completed, &needs) else {
        return Ok(());
    };
    replan_excluding(lost.len(), &lost).map_err(ReconError::AllDevicesLost)?;
    let per = g.n_det[0] * g.n_det[1];
    let plane = g.n_vox[0] * g.n_vox[1];
    let threads = ctx.backend_threads();
    let mut chunk_buf: Vec<f32> = Vec::new();
    let mut acc = scratch::take_zeroed(
        active
            .iter()
            .flat_map(|d| d.slabs.iter())
            .map(|s| s.len())
            .max()
            .unwrap_or(0)
            * plane,
    );
    for (i, dev) in active.iter().enumerate() {
        for unit in completed[i]..needs[i] {
            let slab = dev.slabs[unit / n_chunks];
            let ch = plan.angle_chunks[unit % n_chunks];
            let gs = g.slab_geometry(slab.z0, slab.z1);
            let gc = gs.angle_chunk_geometry(ch.a0, ch.a1);
            let view: ProjChunkView<'_> = match src {
                BpSource::Ram(p) => p.chunk_view(ch.a0, ch.a1),
                BpSource::Ooc(store) => {
                    chunk_buf.resize(ch.len() * per, 0.0);
                    store.load_chunk_into(ch.a0, ch.a1, &mut chunk_buf)?;
                    ProjChunkView {
                        nu: g.n_det[0],
                        nv: g.n_det[1],
                        n_angles: ch.len(),
                        data: &chunk_buf,
                    }
                }
            };
            let slab_len = slab.len() * plane;
            acc.clear();
            acc.resize(slab_len, 0.0); // backproject_into accumulates
            ctx.kernel_backward_into(&gc, &view, &mut acc, threads);
            let off = slab.z0 * plane;
            for (o, v) in out.data[off..off + slab_len].iter_mut().zip(&acc) {
                *o += *v;
            }
        }
    }
    scratch::recycle(acc);
    Ok(())
}

// ---------------------------------------------------------------------------
// cross-device merge of image-split forward partials
// ---------------------------------------------------------------------------

/// One worker's part in the overlapped reduction tree: the channels
/// wiring it to its [`merge_schedule`] partners. A worker first drains
/// `recvs` in round order (folding each peer partial into its own), then
/// either forwards the folded partial up the tree (`send`) or — for the
/// root, index 0 — returns it as the final sum.
struct TreeRole {
    /// Peer partials to fold, in schedule-round order (ascending stride).
    recvs: Vec<mpsc::Receiver<ProjectionSet>>,
    /// Channel to this worker's consumer; `None` for the root.
    send: Option<mpsc::Sender<ProjectionSet>>,
}

/// Wire the canonical schedule's pairings as channels between the `n`
/// workers.
fn tree_roles(n: usize) -> Vec<TreeRole> {
    let mut roles: Vec<TreeRole> =
        (0..n).map(|_| TreeRole { recvs: Vec::new(), send: None }).collect();
    for round in merge_schedule(n) {
        for (dst, src) in round {
            let (tx, rx) = mpsc::channel();
            roles[dst].recvs.push(rx);
            debug_assert!(roles[src].send.is_none(), "schedule: each index is src once");
            roles[src].send = Some(tx);
        }
    }
    roles
}

/// Roles for the workers of one image-split forward call, or all-`None`
/// when the merge runs host-side: the overlapped in-worker tree needs
/// every worker resident on the pool at once (a blocked `recv` whose
/// partner is still queued behind it would deadlock the pool — see the
/// module docs), so with fewer pool workers than active devices the tree
/// strategy degrades to the host-side serial execution of the *same*
/// canonical schedule in [`fold_partials_into`] — bit-identical output,
/// merge no longer overlapped.
fn tree_roles_for(ctx: &MultiGpu, workers: usize, n_active: usize) -> Vec<Option<TreeRole>> {
    // A fault plan that can lose a device also degrades the tree to the
    // host-side fold: a lost worker can never feed its tree channel, so
    // an in-worker recv on it would deadlock the scope. Same canonical
    // schedule either way ⇒ same bits (ISSUE 7).
    let loss_planned = ctx.fault.as_ref().is_some_and(|f| f.plans_loss());
    if ctx.exec.merge == MergeStrategy::Tree
        && workers >= n_active
        && n_active > 1
        && !loss_planned
    {
        tree_roles(n_active).into_iter().map(Some).collect()
    } else {
        (0..n_active).map(|_| None).collect()
    }
}

/// Run one worker's share of the overlapped tree after its own launches
/// completed: fold each peer partial received in round order, then pass
/// the result up (or keep it, for the root). Returns the folded partial
/// (root or role-less worker) plus the consumed peer partials, which the
/// caller recycles on the host thread — pool-worker arenas are per-call,
/// so recycling there would leak the allocations' reuse (see
/// `worker_count`'s arena note).
fn tree_fold(
    role: Option<TreeRole>,
    mut partial: ProjectionSet,
) -> (Option<ProjectionSet>, Vec<ProjectionSet>) {
    let Some(role) = role else { return (Some(partial), Vec::new()) };
    let mut spent = Vec::with_capacity(role.recvs.len());
    for rx in &role.recvs {
        let peer = rx.recv().expect("tree merge peer terminated");
        partial.accumulate(&peer);
        spent.push(peer);
    }
    match role.send {
        Some(tx) => {
            // a closed channel means the consumer panicked; its partial is
            // dropped here and the pool propagates the consumer's panic
            let _ = tx.send(partial);
            (None, spent)
        }
        None => (Some(partial), spent),
    }
}

/// Fold the workers' surviving partials into `out` by the canonical
/// schedule and recycle them. After an overlapped tree only the root
/// slot is `Some` (every fold already happened in-worker, so the loop
/// no-ops); otherwise — `Linear`, or `Tree` degraded by a small worker
/// pool — this executes the schedule serially, which performs the exact
/// same `n−1` folds in the exact same operand order. Either way the one
/// surviving partial is the root, copied into `out`.
///
/// Merge boundaries are the numerical-health checkpoints (ISSUE 8):
/// every surviving partial is scanned before it folds, and the merged
/// root is scanned before it is published — a NaN/Inf produced by any
/// kernel fails the operator with a typed error naming the stage
/// instead of contaminating the full projection set.
fn fold_partials_into(
    out: &mut ProjectionSet,
    mut partials: Vec<Option<ProjectionSet>>,
) -> anyhow::Result<()> {
    for (i, p) in partials.iter().enumerate() {
        if let Some(p) = p {
            ensure_finite(
                &p.data,
                NonFiniteStage::MergePartial,
                &format!("worker {i} partial"),
            )?;
        }
    }
    for round in merge_schedule(partials.len()) {
        for (dst, src) in round {
            let Some(src_p) = partials[src].take() else { continue };
            let dst_p = partials[dst].as_mut().expect("schedule: dst survives its round");
            dst_p.accumulate(&src_p);
            scratch::recycle_projections(src_p);
        }
    }
    let root = partials.into_iter().flatten().next().expect("merge root partial");
    ensure_finite(&root.data, NonFiniteStage::MergedOutput, "merged projections")?;
    out.data.copy_from_slice(&root.data);
    scratch::recycle_projections(root);
    Ok(())
}

// ---------------------------------------------------------------------------
// forward projection
// ---------------------------------------------------------------------------

/// Pipelined forward projection (Algorithm 1's plan, executed for real).
/// RAM inputs stage through zero-copy slab views; OOC inputs stream
/// slabs from the store on per-worker loader lanes (or materialize once
/// when the plan keeps the full image per device — the planner bounded
/// that by the host budget).
pub fn forward_pipelined(
    ctx: &MultiGpu,
    g: &Geometry,
    vol: VolumeInput<'_>,
    plan: &Plan,
) -> anyhow::Result<ProjectionSet> {
    begin_real_op(ctx);
    match vol {
        VolumeInput::Ram(v) => forward_pipelined_ram(ctx, g, v, plan),
        VolumeInput::Ooc(store) => {
            if let Some(f) = &ctx.fault {
                store.set_fault_plan(f.clone());
            }
            if !plan.image_split {
                // angle-split precondition: the volume fits the host
                // budget, so read_volume serves from the store cache on
                // repeat calls (no flush, no file re-read per iteration)
                let v = store.read_volume()?;
                let out = forward_pipelined_ram(ctx, g, &v, plan);
                scratch::recycle_volume(v);
                out
            } else {
                forward_pipelined_ooc(ctx, g, store, plan)
            }
        }
    }
}

fn forward_pipelined_ram(
    ctx: &MultiGpu,
    g: &Geometry,
    vol: &Volume,
    plan: &Plan,
) -> anyhow::Result<ProjectionSet> {
    let mut out = scratch::take_projections(g.n_det[0], g.n_det[1], g.n_angles());
    if !plan.image_split {
        // Angle split: every device holds the full image and owns a
        // disjoint contiguous run of chunks — workers project straight
        // into their windows of `out` (zero staging, nothing to merge).
        // `jobs` keeps the owning device index so the fault gate knows
        // which simulated device each launch belongs to (ISSUE 7).
        let shares = plan.chunk_shares(ctx.n_gpus);
        let jobs: Vec<(usize, usize, usize)> = shares
            .iter()
            .enumerate()
            .filter(|&(_, &(c0, c1))| c1 > c0)
            .map(|(d, &(c0, c1))| (d, c0, c1))
            .collect();
        let n_jobs = jobs.len();
        let workers = worker_count(ctx, n_jobs);
        let budgets = kernel_thread_budgets(ctx, workers, n_jobs);
        let per = g.n_det[0] * g.n_det[1];
        let out_ptr = SendPtr(out.data.as_mut_ptr());
        let pool = ThreadPool::new(workers);
        let mut completed: Vec<usize> = Vec::new();
        pool.scope(|s| {
            let mut handles = Vec::with_capacity(n_jobs);
            for (i, &(gpu, c0, c1)) in jobs.iter().enumerate() {
                let kt = budgets[i];
                handles.push(s.spawn(move || {
                    let out_ptr = out_ptr;
                    let mut done = 0usize;
                    let mut watch = UnitWatch::new(ctx, gpu);
                    for c in c0..c1 {
                        if launch_gate(ctx, gpu) {
                            break; // device lost: host replans the rest
                        }
                        let t0 = std::time::Instant::now();
                        let ch = plan.angle_chunks[c];
                        let gc = g.angle_chunk_geometry(ch.a0, ch.a1);
                        // SAFETY: chunk runs are disjoint across workers
                        // and chunks are contiguous in `out`'s layout.
                        let dst = unsafe {
                            std::slice::from_raw_parts_mut(
                                out_ptr.0.add(ch.a0 * per),
                                ch.len() * per,
                            )
                        };
                        if let Backend::Pjrt { artifacts_dir, .. } = &ctx.backend {
                            // PJRT artifacts consume owned host buffers —
                            // pass the resident volume directly instead of
                            // letting the view path copy it per chunk
                            let part =
                                crate::runtime::forward_or_native(artifacts_dir, &gc, vol, kt);
                            dst.copy_from_slice(&part.data);
                            scratch::recycle_projections(part);
                        } else {
                            ctx.kernel_forward_into(&gc, &vol.as_view(), dst, kt);
                        }
                        watch.observe(ctx, t0.elapsed().as_secs_f64());
                        done += 1;
                    }
                    done
                }));
            }
            completed = join_all(handles);
        });
        // Unit-level recovery: chunks a lost device never projected are
        // re-run here, overwriting their (still untouched) disjoint
        // windows of `out` with the identical kernel on identical input
        // — each chunk is computed exactly once either way, so the
        // output is bit-identical to the fault-free run.
        if completed.iter().zip(&jobs).any(|(&c, &(_, c0, c1))| c < c1 - c0) {
            let n = ctx.n_gpus.max(jobs.iter().map(|j| j.0 + 1).max().unwrap_or(0));
            let mut lost = vec![false; n];
            for (i, &(gpu, c0, c1)) in jobs.iter().enumerate() {
                if completed[i] < c1 - c0 {
                    lost[gpu] = true;
                }
            }
            replan_excluding(lost.len(), &lost).map_err(ReconError::AllDevicesLost)?;
            let threads = ctx.backend_threads();
            for (i, &(_, c0, c1)) in jobs.iter().enumerate() {
                for c in (c0 + completed[i])..c1 {
                    let ch = plan.angle_chunks[c];
                    let gc = g.angle_chunk_geometry(ch.a0, ch.a1);
                    let dst = &mut out.data[ch.a0 * per..(ch.a0 + ch.len()) * per];
                    if let Backend::Pjrt { artifacts_dir, .. } = &ctx.backend {
                        let part =
                            crate::runtime::forward_or_native(artifacts_dir, &gc, vol, threads);
                        dst.copy_from_slice(&part.data);
                        scratch::recycle_projections(part);
                    } else {
                        ctx.kernel_forward_into(&gc, &vol.as_view(), dst, threads);
                    }
                }
            }
        }
        // angle-split merge boundary: chunks landed directly in `out`
        ensure_finite(&out.data, NonFiniteStage::MergedOutput, "angle-split projections")?;
    } else {
        // Image split: each device projects all chunks of its slabs into a
        // private partial projection set (worker + merge lane); partials
        // then fold by the canonical pairwise schedule — in-worker and
        // overlapped under the tree strategy, serially on this thread
        // otherwise. Same folds, same operand order ⇒ same bits.
        let active: Vec<&DeviceAssignment> =
            plan.per_device.iter().filter(|d| !d.slabs.is_empty()).collect();
        let workers = worker_count(ctx, active.len());
        let budgets = kernel_thread_budgets(ctx, workers, active.len());
        let per = g.n_det[0] * g.n_det[1];
        let max_stage_len =
            plan.angle_chunks.iter().map(|c| c.len()).max().unwrap_or(0) * per;
        let roles = tree_roles_for(ctx, workers, active.len());
        let pool = ThreadPool::new(workers);
        let mut folded = Vec::with_capacity(active.len());
        let mut completed = Vec::with_capacity(active.len());
        pool.scope(|s| {
            let handles: Vec<_> = active
                .iter()
                .zip(roles)
                .enumerate()
                .map(|(i, (dev, role))| {
                    let dev: &DeviceAssignment = dev;
                    let kt = budgets[i];
                    // take the device partial and staging buffers on this
                    // (host) thread: its scratch arena persists across
                    // operator calls, so iterative algorithms reuse these
                    // allocations instead of re-faulting them per call
                    // (pool worker threads are per-call and arena-cold)
                    let partial =
                        scratch::take_projections(g.n_det[0], g.n_det[1], g.n_angles());
                    let stage: Vec<Vec<f32>> =
                        (0..N_STAGE_BUFFERS).map(|_| scratch::take_zeroed(max_stage_len)).collect();
                    s.spawn(move || {
                        forward_device_partial(ctx, g, vol, plan, dev, kt, partial, stage, role)
                    })
                })
                .collect();
            for (root, spent, stage, done) in join_all(handles) {
                folded.push(root);
                completed.push(done);
                for p in spent {
                    scratch::recycle_projections(p);
                }
                for buf in stage {
                    scratch::recycle(buf);
                }
            }
        });
        // finish any lost device's remaining units into its own partial
        // (launch order preserved) before the canonical cross-device fold
        recover_fp_losses(ctx, g, FpSource::Ram(vol), plan, &active, &completed, &mut folded)?;
        fold_partials_into(&mut out, folded)?;
    }
    Ok(out)
}

/// One device's forward worker (image split): for each of its slabs, run
/// every angle-chunk kernel on a zero-copy slab view in the Alg. 1 queue
/// order, handing each launch's chunk partial to the merge lane while the
/// next kernel runs; once all launches merged, play this worker's part of
/// the reduction tree (`role`, a no-op when `None`). `partial` (zeroed)
/// and the `stage` buffers are taken from — and returned to — the
/// caller's scratch arena; this returns the worker's surviving folded
/// partial (`None` when the tree passed it to a peer), the consumed peer
/// partials for host-side recycling, and the drained staging buffers.
#[allow(clippy::too_many_arguments)]
fn forward_device_partial(
    ctx: &MultiGpu,
    g: &Geometry,
    vol: &Volume,
    plan: &Plan,
    dev: &DeviceAssignment,
    kernel_threads: usize,
    mut partial: ProjectionSet,
    stage: Vec<Vec<f32>>,
    role: Option<TreeRole>,
) -> (Option<ProjectionSet>, Vec<ProjectionSet>, Vec<Vec<f32>>, usize) {
    let per = partial.nu * partial.nv;
    let dst_ptr = SendPtr(partial.data.as_mut_ptr());
    let mut completed = 0usize;

    let (req_tx, req_rx) = mpsc::channel::<(Vec<f32>, usize)>();
    let (ret_tx, ret_rx) = mpsc::channel::<Vec<f32>>();
    for buf in stage {
        ret_tx.send(buf).expect("staging channel open");
    }
    std::thread::scope(|sc| {
        // Merge lane: folds launch k's partial into the device partial
        // while the worker runs kernel k+1 (FIFO ⇒ launch order).
        sc.spawn(move || {
            let dst_ptr = dst_ptr;
            for (buf, a0) in req_rx {
                // SAFETY: only the lane writes `partial` during the scope,
                // and requests are processed one at a time.
                let dst =
                    unsafe { std::slice::from_raw_parts_mut(dst_ptr.0.add(a0 * per), buf.len()) };
                for (o, v) in dst.iter_mut().zip(&buf) {
                    *o += *v;
                }
                if ret_tx.send(buf).is_err() {
                    break; // worker is done and dropped its receiver
                }
            }
        });
        let mut lost = false;
        let mut watch = UnitWatch::new(ctx, dev.device);
        for slab in &dev.slabs {
            let gs = g.slab_geometry(slab.z0, slab.z1);
            let sub = vol.slab_view(slab.z0, slab.z1);
            // PJRT artifacts consume owned host buffers: materialize the
            // slab once per slab (as the sequential path does) rather than
            // letting the view path copy it per chunk launch.
            let owned_slab = match &ctx.backend {
                Backend::Pjrt { .. } => Some(sub.to_volume()),
                Backend::Native { .. } | Backend::Sparse { .. } => None,
                #[cfg(test)]
                Backend::PanicInject { .. } | Backend::NanInject { .. } => None,
            };
            for ch in &plan.angle_chunks {
                if launch_gate(ctx, dev.device) {
                    lost = true; // device lost: host replans the rest
                    break;
                }
                let gc = gs.angle_chunk_geometry(ch.a0, ch.a1);
                let mut buf = ret_rx.recv().expect("merge lane terminated");
                // resize only: the kernel overwrites every element, so no
                // zeroing pass is needed between launches (the BP path,
                // whose kernel accumulates, does need it)
                buf.resize(ch.len() * per, 0.0);
                let t0 = std::time::Instant::now();
                match (&ctx.backend, &owned_slab) {
                    (Backend::Pjrt { artifacts_dir, .. }, Some(ov)) => {
                        let part = crate::runtime::forward_or_native(
                            artifacts_dir,
                            &gc,
                            ov,
                            kernel_threads,
                        );
                        buf.copy_from_slice(&part.data);
                        scratch::recycle_projections(part);
                    }
                    _ => ctx.kernel_forward_into(&gc, &sub, &mut buf, kernel_threads),
                }
                watch.observe(ctx, t0.elapsed().as_secs_f64());
                req_tx.send((buf, ch.a0)).expect("merge lane terminated");
                completed += 1;
            }
            if let Some(ov) = owned_slab {
                scratch::recycle_volume(ov);
            }
            if lost {
                break;
            }
        }
        drop(req_tx); // lane drains remaining requests, then exits
    });
    // own merge lane drained ⇒ `partial` is complete (up to `completed`
    // launches under a loss); fold the tree share while peers may still
    // be launching kernels
    let (folded, spent) = tree_fold(role, partial);
    let mut stage = Vec::with_capacity(N_STAGE_BUFFERS);
    while let Ok(buf) = ret_rx.try_recv() {
        stage.push(buf);
    }
    (folded, spent, stage, completed)
}

/// Image-split forward projection streaming slabs from an [`OocVolume`]:
/// the same concurrent device workers and merge lanes as the RAM path,
/// plus a per-worker **loader lane** that prefetches slab `k+1` from the
/// store while slab `k`'s chunks compute — the device pipeline's double-
/// buffer discipline applied to the disk→host tier.
fn forward_pipelined_ooc(
    ctx: &MultiGpu,
    g: &Geometry,
    store: &OocVolume,
    plan: &Plan,
) -> anyhow::Result<ProjectionSet> {
    let mut out = scratch::take_projections(g.n_det[0], g.n_det[1], g.n_angles());
    let active: Vec<&DeviceAssignment> =
        plan.per_device.iter().filter(|d| !d.slabs.is_empty()).collect();
    let workers = worker_count(ctx, active.len());
    let budgets = kernel_thread_budgets(ctx, workers, active.len());
    let per = g.n_det[0] * g.n_det[1];
    let max_stage_len = plan.angle_chunks.iter().map(|c| c.len()).max().unwrap_or(0) * per;
    let plane = g.n_vox[0] * g.n_vox[1];
    let roles = tree_roles_for(ctx, workers, active.len());
    let pool = ThreadPool::new(workers);
    let mut folded = Vec::with_capacity(active.len());
    let mut completed = Vec::with_capacity(active.len());
    pool.scope(|s| {
        let handles: Vec<_> = active
            .iter()
            .zip(roles)
            .enumerate()
            .map(|(i, (dev, role))| {
                let dev: &DeviceAssignment = dev;
                let kt = budgets[i];
                let partial = scratch::take_projections(g.n_det[0], g.n_det[1], g.n_angles());
                let stage: Vec<Vec<f32>> =
                    (0..N_STAGE_BUFFERS).map(|_| scratch::take_zeroed(max_stage_len)).collect();
                let max_slab_len =
                    dev.slabs.iter().map(|sl| sl.len()).max().unwrap_or(0) * plane;
                let slab_bufs: Vec<Vec<f32>> =
                    (0..N_STAGE_BUFFERS).map(|_| scratch::take_zeroed(max_slab_len)).collect();
                s.spawn(move || {
                    forward_device_partial_ooc(
                        ctx, g, store, plan, dev, kt, partial, stage, slab_bufs, role,
                    )
                })
            })
            .collect();
        for (root, spent, stage, slab_bufs, done) in join_all(handles) {
            folded.push(root);
            completed.push(done);
            for p in spent {
                scratch::recycle_projections(p);
            }
            for buf in stage.into_iter().chain(slab_bufs) {
                scratch::recycle(buf);
            }
        }
    });
    // finish any lost device's remaining units (re-reading its slabs
    // from the store) before the canonical cross-device fold
    recover_fp_losses(ctx, g, FpSource::Ooc(store), plan, &active, &completed, &mut folded)?;
    fold_partials_into(&mut out, folded)?;
    Ok(out)
}

/// One device's OOC forward worker: loader lane streams this device's
/// slabs from the store through two staging buffers; the chunk loop and
/// merge lane are identical to [`forward_device_partial`], consuming a
/// [`VolumeSlabView`] over the staged buffer instead of a borrow of a
/// resident volume — so the kernels see identical f32 data and the
/// output is bit-identical to the RAM path on the same plan. `role` is
/// this worker's share of the reduction tree, played after its own merge
/// lane drains (see [`forward_device_partial`]).
#[allow(clippy::too_many_arguments)]
fn forward_device_partial_ooc(
    ctx: &MultiGpu,
    g: &Geometry,
    store: &OocVolume,
    plan: &Plan,
    dev: &DeviceAssignment,
    kernel_threads: usize,
    mut partial: ProjectionSet,
    stage: Vec<Vec<f32>>,
    slab_bufs: Vec<Vec<f32>>,
    role: Option<TreeRole>,
) -> (Option<ProjectionSet>, Vec<ProjectionSet>, Vec<Vec<f32>>, Vec<Vec<f32>>, usize) {
    let per = partial.nu * partial.nv;
    let plane = g.n_vox[0] * g.n_vox[1];
    let dst_ptr = SendPtr(partial.data.as_mut_ptr());
    let mut completed = 0usize;

    let (req_tx, req_rx) = mpsc::channel::<(Vec<f32>, usize)>();
    let (ret_tx, ret_rx) = mpsc::channel::<Vec<f32>>();
    for buf in stage {
        ret_tx.send(buf).expect("staging channel open");
    }
    let (lreq_tx, lreq_rx) = mpsc::channel::<(ZSlab, Vec<f32>)>();
    let (ldone_tx, ldone_rx) = mpsc::channel::<(ZSlab, Vec<f32>)>();
    let mut leftover_slab_bufs: Vec<Vec<f32>> = Vec::new();
    std::thread::scope(|sc| {
        // merge lane (identical to the RAM worker)
        sc.spawn(move || {
            let dst_ptr = dst_ptr;
            for (buf, a0) in req_rx {
                // SAFETY: only the lane writes `partial` during the scope,
                // and requests are processed one at a time.
                let dst =
                    unsafe { std::slice::from_raw_parts_mut(dst_ptr.0.add(a0 * per), buf.len()) };
                for (o, v) in dst.iter_mut().zip(&buf) {
                    *o += *v;
                }
                if ret_tx.send(buf).is_err() {
                    break;
                }
            }
        });
        // loader lane: fills staging buffers from the store in request
        // order (FIFO ⇒ slab order), overlapping the compute below
        sc.spawn(move || {
            for (slab, mut buf) in lreq_rx {
                // resize only (no clear): the store load overwrites every
                // element, so no zeroing pass is needed between slabs
                buf.resize(slab.len() * plane, 0.0);
                store
                    .load_slab_into(slab.z0, slab.z1, &mut buf)
                    .expect("OOC volume store read failed");
                if ldone_tx.send((slab, buf)).is_err() {
                    break;
                }
            }
        });
        let slabs = &dev.slabs;
        let mut free = slab_bufs;
        if let Some(&s0) = slabs.first() {
            lreq_tx.send((s0, free.pop().expect("slab buffer"))).expect("loader lane open");
        }
        let mut lost = false;
        let mut watch = UnitWatch::new(ctx, dev.device);
        for k in 0..slabs.len() {
            // prefetch slab k+1 while slab k computes (double buffer)
            if k + 1 < slabs.len() {
                let buf = free.pop().expect("double-buffered slab staging");
                lreq_tx.send((slabs[k + 1], buf)).expect("loader lane open");
            }
            let (slab, data) = ldone_rx.recv().expect("loader lane terminated");
            debug_assert_eq!(slab, slabs[k], "loader lane must deliver in FIFO order");
            let gs = g.slab_geometry(slab.z0, slab.z1);
            let sub =
                VolumeSlabView { nx: g.n_vox[0], ny: g.n_vox[1], nz: slab.len(), data: &data };
            let owned_slab = match &ctx.backend {
                Backend::Pjrt { .. } => Some(sub.to_volume()),
                Backend::Native { .. } | Backend::Sparse { .. } => None,
                #[cfg(test)]
                Backend::PanicInject { .. } | Backend::NanInject { .. } => None,
            };
            for ch in &plan.angle_chunks {
                if launch_gate(ctx, dev.device) {
                    lost = true; // device lost: host replans the rest
                    break;
                }
                let gc = gs.angle_chunk_geometry(ch.a0, ch.a1);
                let mut buf = ret_rx.recv().expect("merge lane terminated");
                buf.resize(ch.len() * per, 0.0);
                let t0 = std::time::Instant::now();
                match (&ctx.backend, &owned_slab) {
                    (Backend::Pjrt { artifacts_dir, .. }, Some(ov)) => {
                        let part = crate::runtime::forward_or_native(
                            artifacts_dir,
                            &gc,
                            ov,
                            kernel_threads,
                        );
                        buf.copy_from_slice(&part.data);
                        scratch::recycle_projections(part);
                    }
                    _ => ctx.kernel_forward_into(&gc, &sub, &mut buf, kernel_threads),
                }
                watch.observe(ctx, t0.elapsed().as_secs_f64());
                req_tx.send((buf, ch.a0)).expect("merge lane terminated");
                completed += 1;
            }
            if let Some(ov) = owned_slab {
                scratch::recycle_volume(ov);
            }
            free.push(data);
            if lost {
                break;
            }
        }
        drop(lreq_tx); // loader drains and exits
        drop(req_tx); // merge lane drains remaining requests, then exits
        // after a loss break, reclaim any prefetch still in flight so the
        // staging buffers return to the arena (no-op on the clean path)
        for (_, data) in ldone_rx.iter() {
            free.push(data);
        }
        leftover_slab_bufs = free;
    });
    let (folded, spent) = tree_fold(role, partial);
    let mut stage = Vec::with_capacity(N_STAGE_BUFFERS);
    while let Ok(buf) = ret_rx.try_recv() {
        stage.push(buf);
    }
    (folded, spent, stage, leftover_slab_bufs, completed)
}

// ---------------------------------------------------------------------------
// backprojection
// ---------------------------------------------------------------------------

/// Pipelined backprojection (Algorithm 2's plan, executed for real).
/// RAM inputs stage through zero-copy chunk views; OOC inputs stream
/// angle chunks from the store on per-worker loader lanes.
pub fn backward_pipelined(
    ctx: &MultiGpu,
    g: &Geometry,
    proj: ProjInput<'_>,
    plan: &Plan,
) -> anyhow::Result<Volume> {
    begin_real_op(ctx);
    match proj {
        ProjInput::Ram(p) => backward_pipelined_ram(ctx, g, p, plan),
        ProjInput::Ooc(store) => {
            if let Some(f) = &ctx.fault {
                store.set_fault_plan(f.clone());
            }
            backward_pipelined_ooc(ctx, g, store, plan)
        }
    }
}

fn backward_pipelined_ram(
    ctx: &MultiGpu,
    g: &Geometry,
    proj: &ProjectionSet,
    plan: &Plan,
) -> anyhow::Result<Volume> {
    let mut out = scratch::take_volume(g.n_vox[0], g.n_vox[1], g.n_vox[2]);
    let active: Vec<&DeviceAssignment> =
        plan.per_device.iter().filter(|d| !d.slabs.is_empty()).collect();
    let workers = worker_count(ctx, active.len());
    let budgets = kernel_thread_budgets(ctx, workers, active.len());
    let plane = g.n_vox[0] * g.n_vox[1];
    let out_ptr = SendPtr(out.data.as_mut_ptr());
    let pool = ThreadPool::new(workers);
    let mut completed = Vec::with_capacity(active.len());
    pool.scope(|s| {
        let handles: Vec<_> = active
            .iter()
            .enumerate()
            .map(|(i, dev)| {
                let dev: &DeviceAssignment = dev;
                let kt = budgets[i];
                // staging buffers come from the host arena (see the FP
                // branch for the rationale); sized for the largest slab
                let max_stage_len =
                    dev.slabs.iter().map(|sl| sl.len()).max().unwrap_or(0) * plane;
                let stage: Vec<Vec<f32>> =
                    (0..N_STAGE_BUFFERS).map(|_| scratch::take_zeroed(max_stage_len)).collect();
                s.spawn(move || {
                    backward_device_worker(ctx, g, proj, plan, dev, out_ptr, plane, kt, stage)
                })
            })
            .collect();
        for (stage, done) in join_all(handles) {
            completed.push(done);
            for buf in stage {
                scratch::recycle(buf);
            }
        }
    });
    // finish any lost device's remaining units into its (disjoint)
    // z-slabs of the shared output, launch order preserved
    recover_bp_losses(ctx, g, BpSource::Ram(proj), plan, &active, &completed, &mut out)?;
    // BP merge boundary: every slab landed in `out`; scan before publishing
    ensure_finite(&out.data, NonFiniteStage::VolumeSlab, "backprojected volume")?;
    Ok(out)
}

/// One device's backprojection worker: stream every projection chunk (as
/// a zero-copy view) through the double-buffered kernel/merge pipeline,
/// with the merge lane accumulating straight into this device's slabs of
/// the shared output — z-ranges are disjoint across devices (a splitter
/// invariant), so no cross-worker synchronization is needed and the
/// voxel-level accumulation order is the chunk order, as in Alg. 2.
#[allow(clippy::too_many_arguments)]
fn backward_device_worker(
    ctx: &MultiGpu,
    g: &Geometry,
    proj: &ProjectionSet,
    plan: &Plan,
    dev: &DeviceAssignment,
    out_ptr: SendPtr,
    plane: usize,
    kernel_threads: usize,
    stage: Vec<Vec<f32>>,
) -> (Vec<Vec<f32>>, usize) {
    let (req_tx, req_rx) = mpsc::channel::<(Vec<f32>, usize)>();
    let (ret_tx, ret_rx) = mpsc::channel::<Vec<f32>>();
    for buf in stage {
        ret_tx.send(buf).expect("staging channel open");
    }
    let mut completed = 0usize;
    std::thread::scope(|sc| {
        sc.spawn(move || {
            let out_ptr = out_ptr;
            for (buf, offset) in req_rx {
                // SAFETY: `offset` addresses this device's own z-slab of
                // the shared output; device z-ranges are disjoint.
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(out_ptr.0.add(offset), buf.len())
                };
                for (o, v) in dst.iter_mut().zip(&buf) {
                    *o += *v;
                }
                if ret_tx.send(buf).is_err() {
                    break;
                }
            }
        });
        let mut watch = UnitWatch::new(ctx, dev.device);
        'slabs: for slab in &dev.slabs {
            let gs = g.slab_geometry(slab.z0, slab.z1);
            let slab_len = slab.len() * plane;
            for ch in &plan.angle_chunks {
                if launch_gate(ctx, dev.device) {
                    break 'slabs; // device lost: host replans the rest
                }
                let gc = gs.angle_chunk_geometry(ch.a0, ch.a1);
                let view = proj.chunk_view(ch.a0, ch.a1);
                let mut buf = ret_rx.recv().expect("merge lane terminated");
                buf.clear();
                buf.resize(slab_len, 0.0); // backproject_into accumulates
                let t0 = std::time::Instant::now();
                ctx.kernel_backward_into(&gc, &view, &mut buf, kernel_threads);
                watch.observe(ctx, t0.elapsed().as_secs_f64());
                req_tx.send((buf, slab.z0 * plane)).expect("merge lane terminated");
                completed += 1;
            }
        }
        drop(req_tx);
    });
    let mut stage = Vec::with_capacity(N_STAGE_BUFFERS);
    while let Ok(buf) = ret_rx.try_recv() {
        stage.push(buf);
    }
    (stage, completed)
}

/// Backprojection streaming projection chunks from an
/// [`OocProjections`] store: same workers and merge lanes as the RAM
/// path, plus a per-worker loader lane prefetching chunk `c+1` from the
/// store while chunk `c`'s kernel runs.
fn backward_pipelined_ooc(
    ctx: &MultiGpu,
    g: &Geometry,
    store: &OocProjections,
    plan: &Plan,
) -> anyhow::Result<Volume> {
    let mut out = scratch::take_volume(g.n_vox[0], g.n_vox[1], g.n_vox[2]);
    let active: Vec<&DeviceAssignment> =
        plan.per_device.iter().filter(|d| !d.slabs.is_empty()).collect();
    let workers = worker_count(ctx, active.len());
    let budgets = kernel_thread_budgets(ctx, workers, active.len());
    let plane = g.n_vox[0] * g.n_vox[1];
    let per = g.n_det[0] * g.n_det[1];
    let max_chunk_len = plan.angle_chunks.iter().map(|c| c.len()).max().unwrap_or(0) * per;
    let out_ptr = SendPtr(out.data.as_mut_ptr());
    let pool = ThreadPool::new(workers);
    let mut completed = Vec::with_capacity(active.len());
    pool.scope(|s| {
        let handles: Vec<_> = active
            .iter()
            .enumerate()
            .map(|(i, dev)| {
                let dev: &DeviceAssignment = dev;
                let kt = budgets[i];
                let max_stage_len =
                    dev.slabs.iter().map(|sl| sl.len()).max().unwrap_or(0) * plane;
                let stage: Vec<Vec<f32>> =
                    (0..N_STAGE_BUFFERS).map(|_| scratch::take_zeroed(max_stage_len)).collect();
                let chunk_bufs: Vec<Vec<f32>> =
                    (0..N_STAGE_BUFFERS).map(|_| scratch::take_zeroed(max_chunk_len)).collect();
                s.spawn(move || {
                    backward_device_worker_ooc(
                        ctx, g, store, plan, dev, out_ptr, plane, kt, stage, chunk_bufs,
                    )
                })
            })
            .collect();
        for (stage, chunk_bufs, done) in join_all(handles) {
            completed.push(done);
            for buf in stage.into_iter().chain(chunk_bufs) {
                scratch::recycle(buf);
            }
        }
    });
    // finish any lost device's remaining units (re-reading its chunks
    // from the store) into its disjoint z-slabs of the shared output
    recover_bp_losses(ctx, g, BpSource::Ooc(store), plan, &active, &completed, &mut out)?;
    // BP merge boundary: every slab landed in `out`; scan before publishing
    ensure_finite(&out.data, NonFiniteStage::VolumeSlab, "backprojected volume")?;
    Ok(out)
}

/// One device's OOC backprojection worker: the loader lane streams the
/// flattened `(slab, chunk)` launch sequence's chunks from the store
/// through two staging buffers (prefetching the next launch's chunk
/// while the current kernel runs); kernels consume a [`ProjChunkView`]
/// over the staged buffer, so the output is bit-identical to the RAM
/// path on the same plan.
#[allow(clippy::too_many_arguments)]
fn backward_device_worker_ooc(
    ctx: &MultiGpu,
    g: &Geometry,
    store: &OocProjections,
    plan: &Plan,
    dev: &DeviceAssignment,
    out_ptr: SendPtr,
    plane: usize,
    kernel_threads: usize,
    stage: Vec<Vec<f32>>,
    chunk_bufs: Vec<Vec<f32>>,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, usize) {
    let per = g.n_det[0] * g.n_det[1];
    let mut completed = 0usize;
    let (req_tx, req_rx) = mpsc::channel::<(Vec<f32>, usize)>();
    let (ret_tx, ret_rx) = mpsc::channel::<Vec<f32>>();
    for buf in stage {
        ret_tx.send(buf).expect("staging channel open");
    }
    let (lreq_tx, lreq_rx) = mpsc::channel::<(AngleChunk, Vec<f32>)>();
    let (ldone_tx, ldone_rx) = mpsc::channel::<(AngleChunk, Vec<f32>)>();
    // flattened launch order: slab-major, then chunk (Alg. 2's queue)
    let launches: Vec<(ZSlab, AngleChunk)> = dev
        .slabs
        .iter()
        .flat_map(|s| plan.angle_chunks.iter().map(move |c| (*s, *c)))
        .collect();
    let mut leftover_chunk_bufs: Vec<Vec<f32>> = Vec::new();
    std::thread::scope(|sc| {
        // merge lane (identical to the RAM worker)
        sc.spawn(move || {
            let out_ptr = out_ptr;
            for (buf, offset) in req_rx {
                // SAFETY: `offset` addresses this device's own z-slab of
                // the shared output; device z-ranges are disjoint.
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(out_ptr.0.add(offset), buf.len())
                };
                for (o, v) in dst.iter_mut().zip(&buf) {
                    *o += *v;
                }
                if ret_tx.send(buf).is_err() {
                    break;
                }
            }
        });
        // loader lane: chunk prefetch from the store, FIFO order
        sc.spawn(move || {
            for (ch, mut buf) in lreq_rx {
                // resize only: the store load overwrites every element
                buf.resize(ch.len() * per, 0.0);
                store
                    .load_chunk_into(ch.a0, ch.a1, &mut buf)
                    .expect("OOC projection store read failed");
                if ldone_tx.send((ch, buf)).is_err() {
                    break;
                }
            }
        });
        let mut free = chunk_bufs;
        if let Some(&(_, c0)) = launches.first() {
            lreq_tx.send((c0, free.pop().expect("chunk buffer"))).expect("loader lane open");
        }
        let mut watch = UnitWatch::new(ctx, dev.device);
        for (k, &(slab, ch)) in launches.iter().enumerate() {
            if launch_gate(ctx, dev.device) {
                break; // device lost: host replans the rest
            }
            if k + 1 < launches.len() {
                let buf = free.pop().expect("double-buffered chunk staging");
                lreq_tx.send((launches[k + 1].1, buf)).expect("loader lane open");
            }
            let (got, data) = ldone_rx.recv().expect("loader lane terminated");
            debug_assert_eq!(got, ch, "loader lane must deliver in FIFO order");
            let gs = g.slab_geometry(slab.z0, slab.z1);
            let gc = gs.angle_chunk_geometry(ch.a0, ch.a1);
            let view =
                ProjChunkView { nu: g.n_det[0], nv: g.n_det[1], n_angles: ch.len(), data: &data };
            let slab_len = slab.len() * plane;
            let mut buf = ret_rx.recv().expect("merge lane terminated");
            buf.clear();
            buf.resize(slab_len, 0.0); // backproject_into accumulates
            let t0 = std::time::Instant::now();
            ctx.kernel_backward_into(&gc, &view, &mut buf, kernel_threads);
            watch.observe(ctx, t0.elapsed().as_secs_f64());
            req_tx.send((buf, slab.z0 * plane)).expect("merge lane terminated");
            completed += 1;
            free.push(data);
        }
        drop(lreq_tx);
        drop(req_tx);
        // after a loss break, reclaim any prefetch still in flight so the
        // staging buffers return to the arena (no-op on the clean path)
        for (_, data) in ldone_rx.iter() {
            free.push(data);
        }
        leftover_chunk_bufs = free;
    });
    let mut stage = Vec::with_capacity(N_STAGE_BUFFERS);
    while let Ok(buf) = ret_rx.try_recv() {
        stage.push(buf);
    }
    (stage, leftover_chunk_bufs, completed)
}

// ---------------------------------------------------------------------------
// sequential baseline (pre-PR3 loops, behind ExecutorConfig::pipelined=false)
// ---------------------------------------------------------------------------

/// Host-sequential forward execution with owned-copy staging — the
/// comparison baseline for `bench::coordinator`. OOC inputs stage each
/// slab from the store synchronously (no prefetch — the baseline).
pub fn forward_sequential(
    ctx: &MultiGpu,
    g: &Geometry,
    vol: VolumeInput<'_>,
    plan: &Plan,
) -> anyhow::Result<ProjectionSet> {
    match vol {
        VolumeInput::Ram(v) => Ok(forward_sequential_ram(ctx, g, v, plan)),
        VolumeInput::Ooc(store) => {
            if !plan.image_split {
                let v = store.read_volume()?;
                let out = forward_sequential_ram(ctx, g, &v, plan);
                scratch::recycle_volume(v);
                return Ok(out);
            }
            let mut out = ProjectionSet::zeros_like(g);
            let plane = g.n_vox[0] * g.n_vox[1];
            for dev in &plan.per_device {
                for slab in &dev.slabs {
                    let gs = g.slab_geometry(slab.z0, slab.z1);
                    let mut sub = scratch::take_volume(g.n_vox[0], g.n_vox[1], slab.len());
                    store.load_slab_into(slab.z0, slab.z1, &mut sub.data[..slab.len() * plane])?;
                    for ch in &plan.angle_chunks {
                        let gc = gs.angle_chunk_geometry(ch.a0, ch.a1);
                        let part = ctx.kernel_forward(&gc, &sub);
                        let dst = out.chunk_mut(ch.a0, ch.a1);
                        debug_assert_eq!(dst.len(), part.data.len());
                        for (d, v) in dst.iter_mut().zip(&part.data) {
                            *d += v;
                        }
                        scratch::recycle_projections(part);
                    }
                    scratch::recycle_volume(sub);
                }
            }
            Ok(out)
        }
    }
}

fn forward_sequential_ram(
    ctx: &MultiGpu,
    g: &Geometry,
    vol: &Volume,
    plan: &Plan,
) -> ProjectionSet {
    let mut out = ProjectionSet::zeros_like(g);
    if !plan.image_split {
        // angle-split: each device projects the full volume for its chunks
        for &(c0, c1) in &plan.chunk_shares(ctx.n_gpus) {
            for c in c0..c1 {
                let ch = plan.angle_chunks[c];
                let gc = g.angle_chunk_geometry(ch.a0, ch.a1);
                let part = ctx.kernel_forward(&gc, vol);
                out.insert_chunk(ch.a0, &part);
                scratch::recycle_projections(part);
            }
        }
    } else {
        // image-split: partial projections per slab, accumulated
        for dev in &plan.per_device {
            for slab in &dev.slabs {
                let gs = g.slab_geometry(slab.z0, slab.z1);
                let sub = vol.extract_slab(slab.z0, slab.z1);
                for ch in &plan.angle_chunks {
                    let gc = gs.angle_chunk_geometry(ch.a0, ch.a1);
                    let part = ctx.kernel_forward(&gc, &sub);
                    // accumulate into the global running sum
                    let dst = out.chunk_mut(ch.a0, ch.a1);
                    debug_assert_eq!(dst.len(), part.data.len());
                    for (d, v) in dst.iter_mut().zip(&part.data) {
                        *d += v;
                    }
                    scratch::recycle_projections(part);
                }
                scratch::recycle_volume(sub);
            }
        }
    }
    out
}

/// Host-sequential backprojection with owned-copy staging — the
/// comparison baseline for `bench::coordinator`. OOC inputs stage each
/// chunk from the store synchronously (no prefetch — the baseline).
pub fn backward_sequential(
    ctx: &MultiGpu,
    g: &Geometry,
    proj: ProjInput<'_>,
    plan: &Plan,
) -> anyhow::Result<Volume> {
    match proj {
        ProjInput::Ram(p) => Ok(backward_sequential_ram(ctx, g, p, plan)),
        ProjInput::Ooc(store) => {
            let mut out = Volume::zeros_like(g);
            let per = g.n_det[0] * g.n_det[1];
            for dev in &plan.per_device {
                for slab in &dev.slabs {
                    let gs = g.slab_geometry(slab.z0, slab.z1);
                    let mut acc = scratch::take_volume(g.n_vox[0], g.n_vox[1], slab.len());
                    for ch in &plan.angle_chunks {
                        let gc = gs.angle_chunk_geometry(ch.a0, ch.a1);
                        let mut sub =
                            scratch::take_projections(g.n_det[0], g.n_det[1], ch.len());
                        store.load_chunk_into(ch.a0, ch.a1, &mut sub.data[..ch.len() * per])?;
                        let part = ctx.kernel_backward(&gc, &sub);
                        acc.add_scaled(&part, 1.0);
                        scratch::recycle_volume(part);
                        scratch::recycle_projections(sub);
                    }
                    out.insert_slab(slab.z0, &acc);
                    scratch::recycle_volume(acc);
                }
            }
            Ok(out)
        }
    }
}

fn backward_sequential_ram(
    ctx: &MultiGpu,
    g: &Geometry,
    proj: &ProjectionSet,
    plan: &Plan,
) -> Volume {
    let mut out = Volume::zeros_like(g);
    for dev in &plan.per_device {
        for slab in &dev.slabs {
            let gs = g.slab_geometry(slab.z0, slab.z1);
            let mut acc = scratch::take_volume(g.n_vox[0], g.n_vox[1], slab.len());
            for ch in &plan.angle_chunks {
                let gc = gs.angle_chunk_geometry(ch.a0, ch.a1);
                let sub = proj.extract_chunk(ch.a0, ch.a1);
                let part = ctx.kernel_backward(&gc, &sub);
                acc.add_scaled(&part, 1.0);
                scratch::recycle_volume(part);
                scratch::recycle_projections(sub);
            }
            out.insert_slab(slab.z0, &acc);
            scratch::recycle_volume(acc);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use crate::coordinator::executor::{ExecMode, MultiGpu};
    use crate::geometry::Geometry;
    use crate::phantom;

    /// Device memory that forces the image-split regime (the splitter owns
    /// the arithmetic — see `splitter::image_split_mem`).
    fn tiny_mem(g: &Geometry) -> u64 {
        crate::coordinator::splitter::image_split_mem(
            g,
            &crate::coordinator::splitter::SplitConfig::default(),
        )
    }

    #[test]
    fn pipelined_fp_bit_identical_across_worker_counts() {
        let n = 20;
        let n_angles = 12;
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        for n_gpus in [1usize, 2, 3] {
            for image_split in [false, true] {
                let base = MultiGpu::gtx1080ti(n_gpus);
                let base = if image_split {
                    base.with_device_mem(tiny_mem(&g))
                } else {
                    base
                };
                let reference = base
                    .clone()
                    .with_workers(1)
                    .forward(&g, Some(&v), ExecMode::Full)
                    .unwrap()
                    .0
                    .unwrap();
                for workers in [2usize, 4] {
                    let got = base
                        .clone()
                        .with_workers(workers)
                        .forward(&g, Some(&v), ExecMode::Full)
                        .unwrap()
                        .0
                        .unwrap();
                    assert_eq!(
                        reference.data, got.data,
                        "gpus={n_gpus} image_split={image_split} workers={workers}: \
                         pipelined FP must be bit-identical to the single-worker path"
                    );
                }
            }
        }
    }

    #[test]
    fn pipelined_bp_bit_identical_across_worker_counts() {
        let n = 20;
        let n_angles = 12;
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        let p = crate::kernels::forward(&g, &v, crate::kernels::Projector::Siddon, 2);
        for n_gpus in [1usize, 2, 3] {
            for image_split in [false, true] {
                let base = MultiGpu::gtx1080ti(n_gpus);
                let base = if image_split {
                    base.with_device_mem(tiny_mem(&g))
                } else {
                    base
                };
                let reference = base
                    .clone()
                    .with_workers(1)
                    .backward(&g, Some(&p), ExecMode::Full)
                    .unwrap()
                    .0
                    .unwrap();
                for workers in [2usize, 4] {
                    let got = base
                        .clone()
                        .with_workers(workers)
                        .backward(&g, Some(&p), ExecMode::Full)
                        .unwrap()
                        .0
                        .unwrap();
                    assert_eq!(
                        reference.data, got.data,
                        "gpus={n_gpus} image_split={image_split} workers={workers}: \
                         pipelined BP must be bit-identical to the single-worker path"
                    );
                }
            }
        }
    }

    #[test]
    fn angle_split_fp_bit_identical_to_sequential_baseline() {
        // With no image split both executors run the identical kernels on
        // disjoint chunks — the pipelined path merely skips the staging
        // copies — so they agree bit for bit.
        let g = Geometry::cone_beam(16, 10);
        let v = phantom::shepp_logan(16);
        let pipe = MultiGpu::gtx1080ti(2).forward(&g, Some(&v), ExecMode::Full).unwrap().0.unwrap();
        let seq = MultiGpu::gtx1080ti(2)
            .with_sequential_executor()
            .forward(&g, Some(&v), ExecMode::Full)
            .unwrap()
            .0
            .unwrap();
        assert_eq!(pipe.data, seq.data);
    }

    #[test]
    fn bp_bit_identical_to_sequential_baseline() {
        // The pipelined BP merge (slab region += chunk partial, in chunk
        // order, from zero) reassociates nothing vs the sequential
        // accumulator, so the two executors agree bit for bit.
        let n = 20;
        let n_angles = 12;
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        let p = crate::kernels::forward(&g, &v, crate::kernels::Projector::Siddon, 2);
        for image_split in [false, true] {
            let base = MultiGpu::gtx1080ti(2);
            let base = if image_split {
                base.with_device_mem(tiny_mem(&g))
            } else {
                base
            };
            let pipe = base.clone().backward(&g, Some(&p), ExecMode::Full).unwrap().0.unwrap();
            let seq = base
                .with_sequential_executor()
                .backward(&g, Some(&p), ExecMode::Full)
                .unwrap()
                .0
                .unwrap();
            assert_eq!(pipe.data, seq.data, "image_split={image_split}");
        }
    }

    #[test]
    fn ooc_forward_bit_identical_to_ram_on_the_same_plan() {
        // THE OOC correctness claim: streaming slabs from disk through
        // the loader lanes feeds the kernels byte-identical data in the
        // identical order, so outputs match the RAM path bit for bit.
        use crate::coordinator::splitter::plan_forward_ooc;
        use crate::volume::{OocVolume, VolumeInput};
        let n = 20;
        let n_angles = 12;
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        let dir = std::env::temp_dir()
            .join("tigre_pipe_ooc_fp")
            .join(format!("{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let budget = g.volume_bytes() / 2; // forces slab streaming
        for n_gpus in [1usize, 2, 3] {
            let ctx = MultiGpu::gtx1080ti(n_gpus);
            let plan =
                plan_forward_ooc(&g, n_gpus, ctx.spec.mem_bytes, &ctx.split, budget).unwrap();
            assert!(plan.image_split, "gpus={n_gpus}: host budget must force streaming");
            let store = OocVolume::from_volume(
                &dir.join(format!("v{n_gpus}.raw")),
                &v,
                3,
                budget,
            )
            .unwrap();
            let ram =
                super::forward_pipelined(&ctx, &g, VolumeInput::Ram(&v), &plan).unwrap();
            let ooc =
                super::forward_pipelined(&ctx, &g, VolumeInput::Ooc(&store), &plan).unwrap();
            assert_eq!(ram.data, ooc.data, "gpus={n_gpus}: streamed FP must be bit-identical");
            let seq_ram =
                super::forward_sequential(&ctx, &g, VolumeInput::Ram(&v), &plan).unwrap();
            let seq_ooc =
                super::forward_sequential(&ctx, &g, VolumeInput::Ooc(&store), &plan).unwrap();
            assert_eq!(seq_ram.data, seq_ooc.data, "gpus={n_gpus}: sequential OOC parity");
        }
    }

    #[test]
    fn ooc_backward_bit_identical_to_ram_on_the_same_plan() {
        use crate::coordinator::splitter::plan_backward_ooc;
        use crate::volume::{OocProjections, ProjInput};
        let n = 20;
        let n_angles = 12;
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        let p = crate::kernels::forward(&g, &v, crate::kernels::Projector::Siddon, 2);
        let dir = std::env::temp_dir()
            .join("tigre_pipe_ooc_bp")
            .join(format!("{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let budget = g.proj_bytes() / 2; // forces chunk streaming
        for n_gpus in [1usize, 2, 3] {
            let ctx = MultiGpu::gtx1080ti(n_gpus);
            let plan =
                plan_backward_ooc(&g, n_gpus, ctx.spec.mem_bytes, &ctx.split, budget).unwrap();
            let store = OocProjections::from_projections(
                &dir.join(format!("p{n_gpus}.raw")),
                &p,
                2,
                budget,
            )
            .unwrap();
            let ram = super::backward_pipelined(&ctx, &g, ProjInput::Ram(&p), &plan).unwrap();
            let ooc =
                super::backward_pipelined(&ctx, &g, ProjInput::Ooc(&store), &plan).unwrap();
            assert_eq!(ram.data, ooc.data, "gpus={n_gpus}: streamed BP must be bit-identical");
            let seq_ram =
                super::backward_sequential(&ctx, &g, ProjInput::Ram(&p), &plan).unwrap();
            let seq_ooc =
                super::backward_sequential(&ctx, &g, ProjInput::Ooc(&store), &plan).unwrap();
            assert_eq!(seq_ram.data, seq_ooc.data, "gpus={n_gpus}: sequential OOC parity");
        }
    }

    #[test]
    fn image_split_fp_matches_sequential_baseline_within_tolerance() {
        // The image-split FP merge is reassociated (per-device partials,
        // then the canonical pairwise fold) — deterministic, but not
        // bitwise equal to the host-sequential order; it must still
        // agree tightly.
        let n = 20;
        let n_angles = 12;
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        let base = MultiGpu::gtx1080ti(2).with_device_mem(tiny_mem(&g));
        let pipe = base.clone().forward(&g, Some(&v), ExecMode::Full).unwrap().0.unwrap();
        let seq = base
            .with_sequential_executor()
            .forward(&g, Some(&v), ExecMode::Full)
            .unwrap()
            .0
            .unwrap();
        for (i, (a, b)) in seq.data.iter().zip(&pipe.data).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + a.abs()),
                "pixel {i}: sequential {a} vs pipelined {b}"
            );
        }
    }

    /// The PR-6 bit-exactness matrix: tree merge vs. linear merge over
    /// FP image-split for 1–16 simulated devices — including 3 and 5,
    /// the non-power-of-two counts that exercise the bye rounds of the
    /// canonical schedule. Both the host-serial degraded tree
    /// (`workers=1 < n_active`) and the overlapped in-worker tree
    /// (`threads = n_active` so every worker is pool-resident) must
    /// reproduce the linear fold bit for bit.
    #[test]
    fn tree_merge_bit_identical_to_linear_merge_across_device_counts() {
        let n = 20;
        let n_angles = 12;
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        for n_gpus in [1usize, 2, 3, 4, 5, 8, 16] {
            let base = MultiGpu::gtx1080ti(n_gpus).with_device_mem(tiny_mem(&g));
            let linear =
                base.clone().forward(&g, Some(&v), ExecMode::Full).unwrap().0.unwrap();
            let tree_host = base
                .clone()
                .with_tree_merge()
                .with_workers(1)
                .forward(&g, Some(&v), ExecMode::Full)
                .unwrap()
                .0
                .unwrap();
            assert_eq!(
                linear.data, tree_host.data,
                "gpus={n_gpus}: host-serial tree fold must match the linear merge"
            );
            let tree_overlapped = base
                .with_tree_merge()
                .with_threads(n_gpus.max(2))
                .forward(&g, Some(&v), ExecMode::Full)
                .unwrap()
                .0
                .unwrap();
            assert_eq!(
                linear.data, tree_overlapped.data,
                "gpus={n_gpus}: overlapped in-worker tree must match the linear merge"
            );
        }
    }

    /// The merge strategy only exists for image-split FP; every other
    /// operator shape writes disjoint outputs, so tree vs. linear must
    /// be trivially identical there too (guards against the strategy
    /// leaking into paths that have nothing to fold).
    #[test]
    fn merge_strategy_is_a_noop_for_angle_split_and_backprojection() {
        let n = 20;
        let n_angles = 12;
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        let p = crate::kernels::forward(&g, &v, crate::kernels::Projector::Siddon, 2);
        for n_gpus in [2usize, 5] {
            // angle-split FP (full image per device)
            let linear =
                MultiGpu::gtx1080ti(n_gpus).forward(&g, Some(&v), ExecMode::Full).unwrap().0.unwrap();
            let tree = MultiGpu::gtx1080ti(n_gpus)
                .with_tree_merge()
                .forward(&g, Some(&v), ExecMode::Full)
                .unwrap()
                .0
                .unwrap();
            assert_eq!(linear.data, tree.data, "gpus={n_gpus}: angle-split FP");
            // BP, both split regimes
            for image_split in [false, true] {
                let base = MultiGpu::gtx1080ti(n_gpus);
                let base =
                    if image_split { base.with_device_mem(tiny_mem(&g)) } else { base };
                let linear =
                    base.clone().backward(&g, Some(&p), ExecMode::Full).unwrap().0.unwrap();
                let tree = base
                    .with_tree_merge()
                    .backward(&g, Some(&p), ExecMode::Full)
                    .unwrap()
                    .0
                    .unwrap();
                assert_eq!(
                    linear.data, tree.data,
                    "gpus={n_gpus} image_split={image_split}: BP"
                );
            }
        }
    }

    /// OOC streaming must stay bit-identical to the RAM path under the
    /// tree merge too (same plan, same strategy on both sides).
    #[test]
    fn ooc_forward_with_tree_merge_bit_identical_to_ram() {
        use crate::coordinator::splitter::plan_forward_ooc;
        use crate::volume::{OocVolume, VolumeInput};
        let n = 20;
        let n_angles = 12;
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        let dir = std::env::temp_dir()
            .join("tigre_pipe_ooc_tree")
            .join(format!("{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let budget = g.volume_bytes() / 2;
        for n_gpus in [2usize, 5] {
            let ctx = MultiGpu::gtx1080ti(n_gpus).with_tree_merge().with_threads(n_gpus);
            let plan =
                plan_forward_ooc(&g, n_gpus, ctx.spec.mem_bytes, &ctx.split, budget).unwrap();
            let store =
                OocVolume::from_volume(&dir.join(format!("v{n_gpus}.raw")), &v, 3, budget)
                    .unwrap();
            let ram = super::forward_pipelined(&ctx, &g, VolumeInput::Ram(&v), &plan).unwrap();
            let ooc =
                super::forward_pipelined(&ctx, &g, VolumeInput::Ooc(&store), &plan).unwrap();
            assert_eq!(ram.data, ooc.data, "gpus={n_gpus}: OOC tree-merge parity");
        }
    }

    /// Satellite: a panicking kernel inside a worker must propagate out
    /// of the operator call — the merge/loader lanes drain when the
    /// worker's channel senders drop mid-unwind, the scope joins them,
    /// and the pool re-raises the payload — instead of deadlocking. Runs
    /// the FP image-split path (merge lane + tree channels) and the BP
    /// path (merge lane into the shared output) under both strategies.
    #[test]
    fn worker_panic_propagates_without_deadlocking_the_lanes() {
        use crate::coordinator::executor::Backend;
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let n = 20;
        let n_angles = 12;
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        let p = crate::kernels::forward(&g, &v, crate::kernels::Projector::Siddon, 2);
        for tree in [false, true] {
            let ctx = MultiGpu::gtx1080ti(2)
                .with_device_mem(tiny_mem(&g))
                .with_backend(Backend::PanicInject { threads: 2 });
            let ctx = if tree { ctx.with_tree_merge() } else { ctx };
            let fp = catch_unwind(AssertUnwindSafe(|| {
                ctx.forward(&g, Some(&v), ExecMode::Full)
            }));
            assert!(fp.is_err(), "tree={tree}: injected FP panic must propagate");
            let bp = catch_unwind(AssertUnwindSafe(|| {
                ctx.backward(&g, Some(&p), ExecMode::Full)
            }));
            assert!(bp.is_err(), "tree={tree}: injected BP panic must propagate");
        }
    }

    // -----------------------------------------------------------------
    // fault injection & unit-level recovery (ISSUE 7)
    // -----------------------------------------------------------------

    /// Recovery invariant, transient arm: injected transient launch
    /// failures retry on the same device after the bounded backoff, so
    /// every unit still executes exactly once — FP and BP must be
    /// bit-identical to the fault-free run across device counts, split
    /// regimes and merge strategies.
    #[test]
    fn fault_transient_launches_keep_fp_and_bp_bit_identical() {
        use crate::simgpu::FaultPlan;
        let n = 20;
        let n_angles = 12;
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        let p = crate::kernels::forward(&g, &v, crate::kernels::Projector::Siddon, 2);
        for n_gpus in [1usize, 2, 4] {
            for image_split in [false, true] {
                for tree in [false, true] {
                    let base = MultiGpu::gtx1080ti(n_gpus);
                    let base =
                        if image_split { base.with_device_mem(tiny_mem(&g)) } else { base };
                    let base = if tree { base.with_tree_merge() } else { base };
                    let plan = || {
                        FaultPlan::new()
                            .transient_launch(0, 0)
                            .transient_launch(n_gpus - 1, 1)
                    };
                    let tag = format!("gpus={n_gpus} image_split={image_split} tree={tree}");
                    let clean =
                        base.clone().forward(&g, Some(&v), ExecMode::Full).unwrap().0.unwrap();
                    let got = base
                        .clone()
                        .with_fault_plan(plan())
                        .forward(&g, Some(&v), ExecMode::Full)
                        .unwrap()
                        .0
                        .unwrap();
                    assert_eq!(clean.data, got.data, "{tag}: FP under transient faults");
                    let clean =
                        base.clone().backward(&g, Some(&p), ExecMode::Full).unwrap().0.unwrap();
                    let got = base
                        .clone()
                        .with_fault_plan(plan())
                        .backward(&g, Some(&p), ExecMode::Full)
                        .unwrap()
                        .0
                        .unwrap();
                    assert_eq!(clean.data, got.data, "{tag}: BP under transient faults");
                }
            }
        }
    }

    /// Recovery invariant, loss arm: permanently losing one device
    /// mid-run reassigns its remaining units to surviving capacity, but
    /// the unit partition and per-assignment launch/fold order are
    /// unchanged — so FP and BP stay bit-identical to the fault-free
    /// run across device counts, split regimes and merge strategies
    /// (the tree degrades to the host-serial fold of the same canonical
    /// schedule when a loss is planned).
    #[test]
    fn fault_device_loss_replans_and_keeps_output_bit_identical() {
        use crate::simgpu::{FaultPlan, FaultScope};
        let n = 20;
        let n_angles = 12;
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        let p = crate::kernels::forward(&g, &v, crate::kernels::Projector::Siddon, 2);
        for n_gpus in [2usize, 3, 4] {
            for image_split in [false, true] {
                for tree in [false, true] {
                    let base = MultiGpu::gtx1080ti(n_gpus);
                    let base =
                        if image_split { base.with_device_mem(tiny_mem(&g)) } else { base };
                    let base = if tree { base.with_tree_merge() } else { base };
                    let plan = || {
                        // lose device 0 at its first unit (device 0 has
                        // work in every split regime), with a transient
                        // riding along on the last device
                        FaultPlan::new()
                            .device_loss(0, 0)
                            .transient_launch(n_gpus - 1, 0)
                    };
                    let tag = format!("gpus={n_gpus} image_split={image_split} tree={tree}");
                    let clean =
                        base.clone().forward(&g, Some(&v), ExecMode::Full).unwrap().0.unwrap();
                    let faulted = base.clone().with_fault_plan(plan());
                    let got =
                        faulted.forward(&g, Some(&v), ExecMode::Full).unwrap().0.unwrap();
                    assert!(
                        faulted.fault.as_ref().unwrap().is_lost(FaultScope::Real, 0),
                        "{tag}: the loss site must actually fire"
                    );
                    assert_eq!(clean.data, got.data, "{tag}: FP under device loss");
                    let clean =
                        base.clone().backward(&g, Some(&p), ExecMode::Full).unwrap().0.unwrap();
                    let got = base
                        .clone()
                        .with_fault_plan(plan())
                        .backward(&g, Some(&p), ExecMode::Full)
                        .unwrap()
                        .0
                        .unwrap();
                    assert_eq!(clean.data, got.data, "{tag}: BP under device loss");
                }
            }
        }
    }

    /// A transient burst past [`MAX_LAUNCH_RETRIES`] escalates to a
    /// permanent loss at runtime — the plan must advertise it
    /// (`plans_loss`, so the tree degrades instead of deadlocking on
    /// the lost worker's channel) and the output must still match.
    #[test]
    fn fault_escalated_transient_burst_behaves_as_loss() {
        use crate::simgpu::{FaultKind, FaultPlan, FaultSite, MAX_LAUNCH_RETRIES};
        let n = 20;
        let n_angles = 12;
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        for tree in [false, true] {
            let base = MultiGpu::gtx1080ti(2).with_device_mem(tiny_mem(&g));
            let base = if tree { base.with_tree_merge() } else { base };
            let plan = || {
                FaultPlan::new().with_site(FaultSite {
                    kind: FaultKind::TransientLaunch,
                    device: 1,
                    unit: 0,
                    iteration: None,
                    times: MAX_LAUNCH_RETRIES + 1,
                })
            };
            assert!(plan().plans_loss(), "a burst past the retry bound plans a loss");
            let clean = base.clone().forward(&g, Some(&v), ExecMode::Full).unwrap().0.unwrap();
            let got = base
                .with_fault_plan(plan())
                .forward(&g, Some(&v), ExecMode::Full)
                .unwrap()
                .0
                .unwrap();
            assert_eq!(clean.data, got.data, "tree={tree}: FP under escalated burst");
        }
    }

    /// Losing every device leaves nothing to replan onto: the operator
    /// must surface an error instead of hanging or returning a partial
    /// result.
    #[test]
    fn fault_losing_every_device_surfaces_an_error() {
        use crate::simgpu::FaultPlan;
        let g = Geometry::cone_beam(16, 10);
        let v = phantom::shepp_logan(16);
        for image_split in [false, true] {
            let base = MultiGpu::gtx1080ti(2);
            let base = if image_split { base.with_device_mem(tiny_mem(&g)) } else { base };
            let ctx = base
                .with_fault_plan(FaultPlan::new().device_loss(0, 0).device_loss(1, 0));
            assert!(
                ctx.forward(&g, Some(&v), ExecMode::Full).is_err(),
                "image_split={image_split}: all devices lost must be an error"
            );
        }
    }

    /// OOC streaming paths recover through the store: a loss mid-stream
    /// re-reads the lost device's slabs/chunks and the result still
    /// matches the fault-free run bit for bit.
    #[test]
    fn fault_loss_recovery_is_bit_identical_on_the_ooc_paths() {
        use crate::coordinator::splitter::{plan_backward_ooc, plan_forward_ooc};
        use crate::simgpu::FaultPlan;
        use crate::volume::{OocProjections, OocVolume, ProjInput, VolumeInput};
        let n = 20;
        let n_angles = 12;
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        let p = crate::kernels::forward(&g, &v, crate::kernels::Projector::Siddon, 2);
        let dir = std::env::temp_dir()
            .join("tigre_pipe_fault_ooc")
            .join(format!("{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let vbudget = g.volume_bytes() / 2;
        let pbudget = g.proj_bytes() / 2;
        for n_gpus in [2usize, 3] {
            let clean = MultiGpu::gtx1080ti(n_gpus);
            let faulted = || {
                MultiGpu::gtx1080ti(n_gpus).with_fault_plan(
                    FaultPlan::new()
                        .transient_launch(0, 0)
                        .device_loss(n_gpus - 1, 0),
                )
            };
            let fplan =
                plan_forward_ooc(&g, n_gpus, clean.spec.mem_bytes, &clean.split, vbudget)
                    .unwrap();
            let store =
                OocVolume::from_volume(&dir.join(format!("v{n_gpus}.raw")), &v, 3, vbudget)
                    .unwrap();
            let want =
                super::forward_pipelined(&clean, &g, VolumeInput::Ooc(&store), &fplan).unwrap();
            let got = super::forward_pipelined(&faulted(), &g, VolumeInput::Ooc(&store), &fplan)
                .unwrap();
            assert_eq!(want.data, got.data, "gpus={n_gpus}: OOC FP under device loss");
            let bplan =
                plan_backward_ooc(&g, n_gpus, clean.spec.mem_bytes, &clean.split, pbudget)
                    .unwrap();
            let pstore = OocProjections::from_projections(
                &dir.join(format!("p{n_gpus}.raw")),
                &p,
                2,
                pbudget,
            )
            .unwrap();
            let want =
                super::backward_pipelined(&clean, &g, ProjInput::Ooc(&pstore), &bplan).unwrap();
            let got = super::backward_pipelined(&faulted(), &g, ProjInput::Ooc(&pstore), &bplan)
                .unwrap();
            assert_eq!(want.data, got.data, "gpus={n_gpus}: OOC BP under device loss");
        }
    }

    /// Sim path: the DES timeline must charge recovery — a lost device's
    /// kernels redirect to a survivor's compute engine (serializing
    /// them) plus the one-time replan stall, so the simulated makespan
    /// strictly exceeds the fault-free schedule's.
    #[test]
    fn fault_recovery_time_appears_in_the_simulated_makespan() {
        use crate::simgpu::FaultPlan;
        let g = Geometry::cone_beam(20, 12);
        let clean =
            MultiGpu::gtx1080ti(2).forward(&g, None, ExecMode::SimOnly).unwrap().1.makespan_s;
        let lossy = MultiGpu::gtx1080ti(2)
            .with_fault_plan(FaultPlan::new().device_loss(1, 0))
            .forward(&g, None, ExecMode::SimOnly)
            .unwrap()
            .1
            .makespan_s;
        assert!(
            lossy > clean,
            "device loss must stretch the simulated makespan (clean {clean}, lossy {lossy})"
        );
    }

    // -----------------------------------------------------------------
    // graceful degradation (ISSUE 8)
    // -----------------------------------------------------------------

    /// Tentpole acceptance matrix: a hard allocation failure injected at
    /// every (device, unit) coordinate — across 1–4 devices, both split
    /// regimes and both merge strategies — must complete through the
    /// memory-pressure ladder **bit-identically** to the clean run, with
    /// the taken rung recorded in `OpStats::degradation`. Bit-identity
    /// is structural: FP refinement only re-chunks angles (each angle is
    /// independent), BP refinement only re-slabs z (disjoint output),
    /// and neither changes any per-voxel accumulation order.
    #[test]
    fn degrade_alloc_fail_matrix_replans_bit_identically() {
        use crate::simgpu::{FaultPlan, MAX_LAUNCH_RETRIES};
        let n = 20;
        let n_angles = 12;
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        let p = crate::kernels::forward(&g, &v, crate::kernels::Projector::Siddon, 2);
        for n_gpus in [1usize, 2, 4] {
            for image_split in [false, true] {
                for tree in [false, true] {
                    let base = MultiGpu::gtx1080ti(n_gpus);
                    let base =
                        if image_split { base.with_device_mem(tiny_mem(&g)) } else { base };
                    let base = if tree { base.with_tree_merge() } else { base };
                    let clean_fp =
                        base.clone().forward(&g, Some(&v), ExecMode::Full).unwrap().0.unwrap();
                    let clean_bp =
                        base.clone().backward(&g, Some(&p), ExecMode::Full).unwrap().0.unwrap();
                    // units 0 and 1 are the projection double buffers —
                    // allocated on every device in every regime, so the
                    // site always fires
                    for device in 0..n_gpus {
                        for unit in [0usize, 1] {
                            let tag = format!(
                                "gpus={n_gpus} image_split={image_split} tree={tree} \
                                 d{device} u{unit}"
                            );
                            let hard_fail = || {
                                FaultPlan::new().alloc_fail(
                                    device,
                                    unit,
                                    MAX_LAUNCH_RETRIES + 1,
                                )
                            };
                            let (got, stats) = base
                                .clone()
                                .with_fault_plan(hard_fail())
                                .forward(&g, Some(&v), ExecMode::Full)
                                .unwrap();
                            assert_eq!(
                                clean_fp.data,
                                got.unwrap().data,
                                "{tag}: FP must be bit-identical on the refined plan"
                            );
                            let d = &stats.degradation;
                            assert!(
                                d.evictions + d.refinements + d.spills >= 1,
                                "{tag}: FP ladder rung must be recorded: {d:?}"
                            );
                            let (got, stats) = base
                                .clone()
                                .with_fault_plan(hard_fail())
                                .backward(&g, Some(&p), ExecMode::Full)
                                .unwrap();
                            assert_eq!(
                                clean_bp.data,
                                got.unwrap().data,
                                "{tag}: BP must be bit-identical on the refined plan"
                            );
                            let d = &stats.degradation;
                            assert!(
                                d.evictions + d.refinements + d.spills >= 1,
                                "{tag}: BP ladder rung must be recorded: {d:?}"
                            );
                        }
                    }
                }
            }
        }
    }

    /// Watchdog, bounded arm: a unit that hangs and is killed at its
    /// deadline retries on the same device (PR-7 transient machinery
    /// with the `Hang` site) — output bit-identical, retries recorded.
    #[test]
    fn degrade_hang_retries_keep_output_bit_identical() {
        use crate::simgpu::FaultPlan;
        let n = 20;
        let n_angles = 12;
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        let p = crate::kernels::forward(&g, &v, crate::kernels::Projector::Siddon, 2);
        for image_split in [false, true] {
            let base = MultiGpu::gtx1080ti(2);
            let base = if image_split { base.with_device_mem(tiny_mem(&g)) } else { base };
            let tag = format!("image_split={image_split}");
            let clean = base.clone().forward(&g, Some(&v), ExecMode::Full).unwrap().0.unwrap();
            let (got, stats) = base
                .clone()
                .with_fault_plan(FaultPlan::new().hang(0, 0, 2))
                .forward(&g, Some(&v), ExecMode::Full)
                .unwrap();
            assert_eq!(clean.data, got.unwrap().data, "{tag}: FP under hung-unit retries");
            assert!(
                stats.degradation.hang_retries >= 1,
                "{tag}: hang retry must be recorded: {:?}",
                stats.degradation
            );
            let clean = base.clone().backward(&g, Some(&p), ExecMode::Full).unwrap().0.unwrap();
            let (got, stats) = base
                .clone()
                .with_fault_plan(FaultPlan::new().hang(1, 0, 1))
                .backward(&g, Some(&p), ExecMode::Full)
                .unwrap();
            assert_eq!(clean.data, got.unwrap().data, "{tag}: BP under hung-unit retries");
            assert!(
                stats.degradation.hang_retries >= 1,
                "{tag}: hang retry must be recorded: {:?}",
                stats.degradation
            );
        }
    }

    /// Watchdog, escalation arm: a unit that keeps hanging past
    /// [`MAX_LAUNCH_RETRIES`] escalates through the PR-7 device-loss
    /// machinery — the device is marked lost, its units replan onto
    /// survivors, and the output stays bit-identical (the plan
    /// advertises the loss, so the tree merge degrades safely).
    #[test]
    fn degrade_watchdog_escalates_hang_to_device_loss_bit_identically() {
        use crate::simgpu::{FaultPlan, FaultScope, MAX_LAUNCH_RETRIES};
        let n = 20;
        let n_angles = 12;
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        for tree in [false, true] {
            let base = MultiGpu::gtx1080ti(2).with_device_mem(tiny_mem(&g));
            let base = if tree { base.with_tree_merge() } else { base };
            let plan = || FaultPlan::new().hang(1, 0, MAX_LAUNCH_RETRIES + 1);
            assert!(plan().plans_loss(), "an unbounded hang plans a loss");
            let clean = base.clone().forward(&g, Some(&v), ExecMode::Full).unwrap().0.unwrap();
            let faulted = base.clone().with_fault_plan(plan());
            let (got, stats) = faulted.forward(&g, Some(&v), ExecMode::Full).unwrap();
            assert!(
                faulted.fault.as_ref().unwrap().is_lost(FaultScope::Real, 1),
                "tree={tree}: the watchdog must actually escalate to a loss"
            );
            assert_eq!(
                clean.data,
                got.unwrap().data,
                "tree={tree}: FP under watchdog escalation"
            );
            assert!(
                stats.degradation.watchdog_escalations >= 1,
                "tree={tree}: escalation must be recorded: {:?}",
                stats.degradation
            );
        }
    }

    /// Numerical health: a kernel that emits NaN must be caught at the
    /// first merge boundary it crosses and surfaced as a typed
    /// `ReconError::NonFinite` — never folded silently into the output.
    #[test]
    fn degrade_nan_injection_is_caught_at_merge_boundaries() {
        use crate::coordinator::executor::Backend;
        let n = 20;
        let n_angles = 12;
        let g = Geometry::cone_beam(n, n_angles);
        let v = phantom::shepp_logan(n);
        let p = crate::kernels::forward(&g, &v, crate::kernels::Projector::Siddon, 2);
        // image-split FP: the poisoned device partial is caught before
        // the host fold (merge-partial scan)
        let ctx = MultiGpu::gtx1080ti(2)
            .with_device_mem(tiny_mem(&g))
            .with_backend(Backend::NanInject { threads: 2 });
        let err = ctx.forward(&g, Some(&v), ExecMode::Full).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("non-finite"), "{msg}");
        assert!(msg.contains("merge partial") || msg.contains("merged"), "{msg}");
        // angle-split FP: caught on the merged output scan
        let ctx = MultiGpu::gtx1080ti(2).with_backend(Backend::NanInject { threads: 2 });
        let err = ctx.forward(&g, Some(&v), ExecMode::Full).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("non-finite") && msg.contains("merged output"), "{msg}");
        // BP: caught on the volume-slab scan before the slab publishes
        let err = ctx.backward(&g, Some(&p), ExecMode::Full).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("non-finite") && msg.contains("volume slab"), "{msg}");
    }

    /// The clean path pays nothing for the ladder: with no fault plan
    /// attached the first simulation attempt succeeds, no penalty time is
    /// charged, and `OpStats::degradation` reports clean.
    #[test]
    fn degrade_clean_path_records_nothing_and_costs_nothing() {
        let g = Geometry::cone_beam(64, 32);
        let ctx = MultiGpu::gtx1080ti(2);
        let (_, fp) = ctx.forward(&g, None, ExecMode::SimOnly).unwrap();
        let (_, bp) = ctx.backward(&g, None, ExecMode::SimOnly).unwrap();
        assert!(fp.degradation.is_clean(), "{:?}", fp.degradation);
        assert!(bp.degradation.is_clean(), "{:?}", bp.degradation);
        assert!(!fp
            .degradation
            .events
            .iter()
            .any(|e| e.contains("pressure replan")));
    }
}
