//! Precomputed sparse system-matrix projector (CSR SpMV / CSC SpMVᵀ).
//!
//! Marchesini et al. 2020 (*Sparse Matrix-Based HPC Tomography*) and
//! tomoCAM both observe that on repeated-iteration workloads it pays to
//! run the ray tracer **once**, store every (ray, voxel, intersection
//! length) triple as a sparse matrix `A`, and turn every subsequent
//! forward projection into `A·x` and every backprojection into `Aᵀ·y`.
//! The one-time build costs roughly one traversal plus assembly; each
//! later iteration replaces per-ray f64 setup + traversal with a
//! streaming, memory-bound SpMV.
//!
//! The matrix here is **slab-local**: one [`SparseSystemMatrix`] covers
//! exactly one splitter-emitted slab×angle-chunk unit (the `Geometry`
//! handed to the kernel *is* that unit's sub-geometry), so the
//! coordinator, residency cache, OOC store, merge schedules and
//! fault/degradation machinery all apply unchanged — the shard is just a
//! different way to execute the same unit.
//!
//! ## Bit-parity with the Siddon kernel
//!
//! [`SparseSystemMatrix::build`] records, per detector row, the exact
//! `(voxel, (t_end − t) as f32)` sequence the Siddon traversal visits,
//! plus the per-ray scale `len as f32` applied at the end.
//! [`SparseSystemMatrix::project_into`] then replays that sequence:
//! `acc += w·x[col]` in stored order, then `acc * scale` — the same f32
//! operations in the same order as [`crate::kernels::siddon::raytrace`],
//! so sparse forward projection is **bit-identical** to the Siddon
//! kernel for every geometry, split and thread count (pinned by
//! `sparse_fp_bit_identical_to_siddon` below and the coordinator-level
//! parity suite in `tests/sparse_parity.rs`).
//!
//! ## Determinism of the transpose
//!
//! [`SparseSystemMatrix::backproject_into`] is the *matched adjoint*
//! `Aᵀ`: the CSC transpose stores, per voxel, its incident rays in
//! ascending global row order, and each output voxel is accumulated by
//! exactly one task (columns are partitioned across threads, rows of a
//! chunk are folded in ascending order). The accumulation order per
//! voxel is therefore a pure function of the shard — independent of
//! thread count and worker scheduling — which is what makes the SpMVᵀ
//! site blessable for tigre-lint's float-accumulation lint.

use std::sync::Mutex;

use crate::geometry::{DetFrame, Geometry};
use crate::util::threadpool::{parallel_for, SendPtr};
use crate::volume::{ProjChunkView, VolumeSlabView};

/// A slab-local CSR system matrix: rows are detector pixels of one
/// slab×chunk unit (layout `(a·nv + iv)·nu + iu`, identical to
/// [`crate::kernels::siddon::project_into`]), columns are the unit's
/// voxels in linear `(z·ny + y)·nx + x` order.
///
/// Forward projection is a CSR SpMV ([`Self::project_into`]); matched
/// backprojection is a CSC SpMVᵀ over the precomputed transpose
/// ([`Self::backproject_into`]). Build once per `(geometry, plan)` unit
/// via [`Self::build`], then reuse across iterations — the coordinator
/// caches shards in `coordinator::residency::SparseShardCache`.
///
/// # Examples
///
/// ```
/// use tigre::geometry::Geometry;
/// use tigre::kernels::sparse::SparseSystemMatrix;
/// use tigre::kernels::{self, Projector};
/// use tigre::phantom;
///
/// let g = Geometry::cone_beam(16, 4);
/// let v = phantom::shepp_logan(16);
/// let m = SparseSystemMatrix::build(&g, 2);
///
/// // SpMV forward projection is bit-identical to the Siddon kernel.
/// let mut spmv = vec![0.0f32; m.n_rows()];
/// m.project_into(&v.as_view(), &mut spmv, 2);
/// let ray = kernels::forward(&g, &v, Projector::Siddon, 2);
/// assert_eq!(spmv, ray.data);
/// ```
#[derive(Clone)]
pub struct SparseSystemMatrix {
    n_rows: usize,
    n_cols: usize,
    /// CSR row boundaries: row `r`'s entries are `row_ptr[r]..row_ptr[r+1]`.
    row_ptr: Vec<usize>,
    /// Column (voxel) index per entry, in Siddon traversal order.
    col_idx: Vec<u32>,
    /// Per-entry weight `(t_end − t) as f32`, in Siddon traversal order.
    vals: Vec<f32>,
    /// Per-row final scale `len as f32` (the ray length); applied after
    /// the entry fold, exactly as `siddon::raytrace` scales its `acc`.
    row_scale: Vec<f32>,
    /// CSC column boundaries for the transpose.
    col_ptr: Vec<usize>,
    /// Row index per transpose entry, ascending within each column.
    t_row: Vec<u32>,
    /// Pre-scaled transpose weight `w · row_scale[row]`.
    t_val: Vec<f32>,
}

/// One ray's sparse footprint while building: entry list + final scale.
struct RowBuild {
    cols: Vec<u32>,
    vals: Vec<f32>,
    scale: f32,
}

impl SparseSystemMatrix {
    /// Trace every ray of `g` once (the same per-angle [`DetFrame`]
    /// addressing and Amanatides–Woo walk as the Siddon kernel) and
    /// assemble the CSR matrix plus its CSC transpose.
    ///
    /// The build is deterministic for any `threads` value: rows are
    /// traced in fixed-size index blocks whose contents do not depend on
    /// which worker claims them, and the blocks are reassembled in row
    /// order before the matrix is finalized.
    pub fn build(g: &Geometry, threads: usize) -> Self {
        let nu = g.n_det[0];
        let nv = g.n_det[1];
        let n_angles = g.n_angles();
        let n_rows = nu * nv * n_angles;
        let n_cols = g.n_vox[0] * g.n_vox[1] * g.n_vox[2];

        let frames: Vec<DetFrame> = (0..n_angles).map(|a| g.det_frame(a)).collect();
        let (lo, hi) = g.volume_bbox();
        let dv = g.d_vox;
        let n = g.n_vox;

        // Trace detector rows in blocks; each block's rows are fully
        // determined by its index range, so collecting the blocks and
        // sorting by start row reproduces the serial result for any
        // thread count / work-stealing order.
        let det_rows = n_angles * nv;
        let blocks: Mutex<Vec<(usize, Vec<RowBuild>)>> = Mutex::new(Vec::new());
        parallel_for(det_rows, threads, 8, |r0, r1| {
            let mut local: Vec<RowBuild> = Vec::with_capacity((r1 - r0) * nu);
            for row in r0..r1 {
                let a = row / nv;
                let iv = row % nv;
                let frame = &frames[a];
                let row0 = frame.row_origin(iv);
                let us = frame.u_step;
                for iu in 0..nu {
                    let fu = iu as f64;
                    let pix = [
                        row0[0] + fu * us[0],
                        row0[1] + fu * us[1],
                        row0[2] + fu * us[2],
                    ];
                    local.push(trace_row(&frame.src, &pix, &lo, &hi, &dv, &n));
                }
            }
            blocks
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .push((r0, local));
        });
        let mut blocks = blocks.into_inner().unwrap_or_else(|p| p.into_inner());
        blocks.sort_unstable_by_key(|(r0, _)| *r0);

        let mut row_ptr = Vec::with_capacity(n_rows + 1);
        let mut row_scale = Vec::with_capacity(n_rows);
        let nnz: usize = blocks
            .iter()
            .flat_map(|(_, rows)| rows.iter())
            .map(|r| r.cols.len())
            .sum();
        let mut col_idx = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        row_ptr.push(0usize);
        for (_, rows) in &blocks {
            for r in rows {
                col_idx.extend_from_slice(&r.cols);
                vals.extend_from_slice(&r.vals);
                row_ptr.push(col_idx.len());
                row_scale.push(r.scale);
            }
        }
        debug_assert_eq!(row_scale.len(), n_rows);

        // CSC transpose by counting sort: scanning the CSR rows in
        // ascending order fills each column's entry list in ascending
        // row order — the property the adjoint's determinism argument
        // rests on.
        let mut col_count = vec![0usize; n_cols + 1];
        for &c in &col_idx {
            col_count[c as usize + 1] += 1;
        }
        for c in 0..n_cols {
            col_count[c + 1] += col_count[c];
        }
        let col_ptr = col_count.clone();
        let mut cursor = col_count;
        let mut t_row = vec![0u32; nnz];
        let mut t_val = vec![0.0f32; nnz];
        for r in 0..n_rows {
            let scale = row_scale[r];
            for e in row_ptr[r]..row_ptr[r + 1] {
                let c = col_idx[e] as usize;
                let slot = cursor[c];
                cursor[c] += 1;
                t_row[slot] = r as u32;
                t_val[slot] = vals[e] * scale;
            }
        }

        Self {
            n_rows,
            n_cols,
            row_ptr,
            col_idx,
            vals,
            row_scale,
            col_ptr,
            t_row,
            t_val,
        }
    }

    /// Number of matrix rows (detector pixels of the unit).
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Number of matrix columns (voxels of the unit).
    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Approximate heap footprint of the shard (CSR + CSC sides), used
    /// for the shard cache's byte budget.
    pub fn bytes(&self) -> u64 {
        let nnz = self.nnz() as u64;
        // CSR: col_idx(u32) + vals(f32); CSC: t_row(u32) + t_val(f32);
        // pointers: row_ptr + col_ptr (usize) + row_scale (f32).
        nnz * 16
            + (self.row_ptr.len() + self.col_ptr.len()) as u64 * 8
            + self.row_scale.len() as u64 * 4
    }

    /// Forward projection `out = A·x` (every element overwritten), the
    /// SpMV replacement for [`crate::kernels::siddon::project_into`].
    ///
    /// `vol` must match the geometry the matrix was built from; `out`
    /// has the standard `(a·nv + iv)·nu + iu` projection layout. Output
    /// is bit-identical to the Siddon kernel for any `threads`.
    pub fn project_into(&self, vol: &VolumeSlabView<'_>, out: &mut [f32], threads: usize) {
        assert_eq!(vol.data.len(), self.n_cols, "volume does not match matrix");
        assert_eq!(out.len(), self.n_rows, "output length mismatch");
        let x = vol.data;
        let ptr = SendPtr(out.as_mut_ptr());
        parallel_for(self.n_rows, threads, 64, |r0, r1| {
            let ptr = ptr; // copy the Send wrapper into the closure
            for r in r0..r1 {
                let mut acc = 0.0f32;
                for e in self.row_ptr[r]..self.row_ptr[r + 1] {
                    // SAFETY: e < nnz by the row_ptr invariant and every
                    // stored column index is < n_cols == x.len() (written
                    // by trace_row from in-bounds voxel walks).
                    acc += unsafe {
                        *self.vals.get_unchecked(e)
                            * *x.get_unchecked(*self.col_idx.get_unchecked(e) as usize)
                    };
                }
                // SAFETY: parallel_for hands each task a disjoint row
                // range and r < n_rows == out.len().
                unsafe {
                    *ptr.0.add(r) = acc * *self.row_scale.get_unchecked(r);
                }
            }
        });
    }

    /// Matched backprojection `out += Aᵀ·y`, the SpMVᵀ replacement for
    /// the voxel-driven backprojector when the sparse backend is active.
    ///
    /// Accumulates into `out` (the executor's per-device volume buffer),
    /// one voxel per column. Each voxel's incident rays are folded in
    /// ascending global row order regardless of `threads`, so the result
    /// is deterministic for any thread count.
    pub fn backproject_into(&self, proj: &ProjChunkView<'_>, out: &mut [f32], threads: usize) {
        assert_eq!(proj.data.len(), self.n_rows, "projections do not match matrix");
        assert_eq!(out.len(), self.n_cols, "output length mismatch");
        let y = proj.data;
        let ptr = SendPtr(out.as_mut_ptr());
        parallel_for(self.n_cols, threads, 256, |c0, c1| {
            let ptr = ptr; // copy the Send wrapper into the closure
            for c in c0..c1 {
                let mut acc = 0.0f32;
                for e in self.col_ptr[c]..self.col_ptr[c + 1] {
                    // SAFETY: e < nnz by the col_ptr invariant and every
                    // stored row index is < n_rows == y.len().
                    acc += unsafe {
                        *self.t_val.get_unchecked(e)
                            * *y.get_unchecked(*self.t_row.get_unchecked(e) as usize)
                    };
                }
                // SAFETY: parallel_for hands each task a disjoint column
                // range and c < n_cols == out.len(); the read-modify-write
                // races with no other task by that disjointness.
                unsafe {
                    *ptr.0.add(c) += acc;
                }
            }
        });
    }
}

impl std::fmt::Debug for SparseSystemMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SparseSystemMatrix")
            .field("n_rows", &self.n_rows)
            .field("n_cols", &self.n_cols)
            .field("nnz", &self.nnz())
            .finish()
    }
}

/// Trace one ray and record its sparse footprint: the same clip / entry
/// voxel / incremental-`t` walk as [`crate::kernels::siddon::raytrace`],
/// but pushing `(voxel, (t_end − t) as f32)` instead of accumulating.
/// The stored sequence replayed by [`SparseSystemMatrix::project_into`]
/// reproduces `raytrace`'s f32 operations exactly.
#[allow(clippy::too_many_arguments)]
fn trace_row(
    src: &[f64; 3],
    dst: &[f64; 3],
    lo: &[f64; 3],
    hi: &[f64; 3],
    dvox: &[f64; 3],
    n: &[usize; 3],
) -> RowBuild {
    let empty = RowBuild {
        cols: Vec::new(),
        vals: Vec::new(),
        // A missed ray contributes `0.0` in siddon; 0 entries × any
        // scale reproduces that.
        scale: 0.0,
    };
    let dir = [dst[0] - src[0], dst[1] - src[1], dst[2] - src[2]];
    let len = (dir[0] * dir[0] + dir[1] * dir[1] + dir[2] * dir[2]).sqrt();
    if len == 0.0 {
        return empty;
    }

    let mut tmin = 0.0f64;
    let mut tmax = 1.0f64;
    for k in 0..3 {
        if dir[k].abs() < 1e-12 {
            if src[k] < lo[k] || src[k] > hi[k] {
                return empty;
            }
        } else {
            let inv = 1.0 / dir[k];
            let t0 = (lo[k] - src[k]) * inv;
            let t1 = (hi[k] - src[k]) * inv;
            let (t0, t1) = if t0 <= t1 { (t0, t1) } else { (t1, t0) };
            tmin = tmin.max(t0);
            tmax = tmax.min(t1);
        }
    }
    if tmin >= tmax {
        return empty;
    }

    let eps = 1e-9;
    let entry = [
        src[0] + (tmin + eps) * dir[0],
        src[1] + (tmin + eps) * dir[1],
        src[2] + (tmin + eps) * dir[2],
    ];
    let mut ix = [0isize; 3];
    for k in 0..3 {
        let f = ((entry[k] - lo[k]) / dvox[k]).floor();
        ix[k] = (f as isize).clamp(0, n[k] as isize - 1);
    }

    let mut t_next = [f64::INFINITY; 3];
    let mut dt = [f64::INFINITY; 3];
    let mut step = [0isize; 3];
    for k in 0..3 {
        if dir[k] > 1e-12 {
            step[k] = 1;
            let boundary = lo[k] + (ix[k] + 1) as f64 * dvox[k];
            t_next[k] = (boundary - src[k]) / dir[k];
            dt[k] = dvox[k] / dir[k];
        } else if dir[k] < -1e-12 {
            step[k] = -1;
            let boundary = lo[k] + ix[k] as f64 * dvox[k];
            t_next[k] = (boundary - src[k]) / dir[k];
            dt[k] = -dvox[k] / dir[k];
        }
    }

    let nx = n[0] as isize;
    let ny = n[1] as isize;
    let bound = [nx, ny, n[2] as isize];
    let stride = [1isize, nx, nx * ny];
    let istep = [
        step[0] * stride[0],
        step[1] * stride[1],
        step[2] * stride[2],
    ];
    let mut idx = (ix[2] * ny + ix[1]) * nx + ix[0];

    let mut t = tmin;
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    loop {
        let (axis, tn) = {
            let mut axis = 0;
            let mut tn = t_next[0];
            if t_next[1] < tn {
                axis = 1;
                tn = t_next[1];
            }
            if t_next[2] < tn {
                axis = 2;
                tn = t_next[2];
            }
            (axis, tn)
        };
        let t_end = tn.min(tmax);
        if t_end > t {
            cols.push(idx as u32);
            vals.push((t_end - t) as f32);
            t = t_end;
        }
        if tn >= tmax {
            break;
        }
        ix[axis] += step[axis];
        if ix[axis] < 0 || ix[axis] >= bound[axis] {
            break;
        }
        idx += istep[axis];
        t_next[axis] += dt[axis];
    }
    RowBuild {
        cols,
        vals,
        scale: len as f32,
    }
}

/// Stable 64-bit fingerprint of a geometry (FNV-1a over its dimensions
/// and the exact bit patterns of every f64 field, including the angle
/// list). Two geometries fingerprint equal iff the Siddon traversal —
/// and therefore the built shard — is identical, which is what makes
/// this the shard-cache key: each splitter-emitted slab×chunk unit's
/// sub-geometry is fully determined by the `(geometry, plan)` pair.
pub fn geometry_fingerprint(g: &Geometry) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut mix = |x: u64| {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    mix(g.dsd.to_bits());
    mix(g.dso.to_bits());
    for v in g.n_vox {
        mix(v as u64);
    }
    for v in g.d_vox {
        mix(v.to_bits());
    }
    for v in g.offset_origin {
        mix(v.to_bits());
    }
    for v in g.n_det {
        mix(v as u64);
    }
    for v in g.d_det {
        mix(v.to_bits());
    }
    for v in g.offset_det {
        mix(v.to_bits());
    }
    mix(g.angles.len() as u64);
    for a in &g.angles {
        mix(a.to_bits());
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{self, siddon, Projector};
    use crate::phantom;

    #[test]
    fn sparse_fp_bit_identical_to_siddon() {
        // The core parity claim: SpMV replays the Siddon traversal's f32
        // operations exactly, so the projections match bit for bit.
        let n = 20;
        let g = Geometry::cone_beam(n, 6);
        let v = phantom::shepp_logan(n);
        let m = SparseSystemMatrix::build(&g, 2);
        let mut spmv = vec![0.0f32; m.n_rows()];
        m.project_into(&v.as_view(), &mut spmv, 2);
        let ray = kernels::forward(&g, &v, Projector::Siddon, 2);
        assert_eq!(spmv, ray.data);
    }

    #[test]
    fn sparse_fp_bit_identical_on_slab_and_chunk_geometries() {
        // Shards cover splitter-emitted slab×chunk sub-geometries; the
        // parity must hold there too (that is what the executor runs).
        let n = 18;
        let g = Geometry::cone_beam(n, 8);
        let v = phantom::shepp_logan(n);
        let gs = g.slab_geometry(5, 13).angle_chunk_geometry(2, 6);
        let view = v.slab_view(5, 13);
        let m = SparseSystemMatrix::build(&gs, 3);
        let mut spmv = vec![0.0f32; m.n_rows()];
        m.project_into(&view, &mut spmv, 3);
        let mut ray = vec![0.0f32; spmv.len()];
        siddon::project_into(&gs, &view, &mut ray, 3);
        assert_eq!(spmv, ray);
    }

    #[test]
    fn build_is_thread_count_invariant() {
        let g = Geometry::cone_beam(14, 5);
        let a = SparseSystemMatrix::build(&g, 1);
        let b = SparseSystemMatrix::build(&g, 4);
        assert_eq!(a.row_ptr, b.row_ptr);
        assert_eq!(a.col_idx, b.col_idx);
        assert_eq!(a.vals, b.vals);
        assert_eq!(a.row_scale, b.row_scale);
        assert_eq!(a.t_row, b.t_row);
        assert_eq!(a.t_val, b.t_val);
    }

    #[test]
    fn apply_is_thread_count_invariant() {
        let n = 16;
        let g = Geometry::cone_beam(n, 5);
        let v = phantom::shepp_logan(n);
        let m = SparseSystemMatrix::build(&g, 2);
        let mut p1 = vec![0.0f32; m.n_rows()];
        let mut p4 = vec![0.0f32; m.n_rows()];
        m.project_into(&v.as_view(), &mut p1, 1);
        m.project_into(&v.as_view(), &mut p4, 4);
        assert_eq!(p1, p4);

        let proj = ProjChunkView {
            nu: g.n_det[0],
            nv: g.n_det[1],
            n_angles: g.n_angles(),
            data: &p1,
        };
        let mut b1 = vec![0.0f32; m.n_cols()];
        let mut b4 = vec![0.0f32; m.n_cols()];
        m.backproject_into(&proj, &mut b1, 1);
        m.backproject_into(&proj, &mut b4, 4);
        assert_eq!(b1, b4);
    }

    #[test]
    fn transpose_rows_ascend_within_each_column() {
        let g = Geometry::cone_beam(12, 4);
        let m = SparseSystemMatrix::build(&g, 2);
        for c in 0..m.n_cols() {
            let rows = &m.t_row[m.col_ptr[c]..m.col_ptr[c + 1]];
            assert!(rows.windows(2).all(|w| w[0] < w[1]), "column {c} not sorted");
        }
    }

    #[test]
    fn backprojection_is_the_adjoint() {
        // ⟨A·x, y⟩ == ⟨x, Aᵀ·y⟩ up to f32 rounding: the defining property
        // of the matched pair the iterative algorithms need.
        let n = 14;
        let g = Geometry::cone_beam(n, 6);
        let x = phantom::shepp_logan(n);
        let m = SparseSystemMatrix::build(&g, 2);
        let mut ax = vec![0.0f32; m.n_rows()];
        m.project_into(&x.as_view(), &mut ax, 2);
        // A deterministic, non-trivial y.
        let y: Vec<f32> = (0..m.n_rows())
            .map(|i| ((i % 17) as f32 - 8.0) / 17.0)
            .collect();
        let proj = ProjChunkView {
            nu: g.n_det[0],
            nv: g.n_det[1],
            n_angles: g.n_angles(),
            data: &y,
        };
        let mut aty = vec![0.0f32; m.n_cols()];
        m.backproject_into(&proj, &mut aty, 2);
        let lhs: f64 = ax.iter().zip(&y).map(|(a, b)| *a as f64 * *b as f64).sum();
        let rhs: f64 = aty
            .iter()
            .zip(&x.data)
            .map(|(a, b)| *a as f64 * *b as f64)
            .sum();
        let denom = lhs.abs().max(rhs.abs()).max(1e-12);
        assert!(
            ((lhs - rhs) / denom).abs() < 1e-4,
            "adjoint identity violated: {lhs} vs {rhs}"
        );
    }

    #[test]
    fn backprojection_accumulates_into_out() {
        let g = Geometry::cone_beam(10, 3);
        let m = SparseSystemMatrix::build(&g, 1);
        let y = vec![1.0f32; m.n_rows()];
        let proj = ProjChunkView {
            nu: g.n_det[0],
            nv: g.n_det[1],
            n_angles: g.n_angles(),
            data: &y,
        };
        let mut once = vec![0.0f32; m.n_cols()];
        m.backproject_into(&proj, &mut once, 1);
        let mut twice = vec![0.0f32; m.n_cols()];
        m.backproject_into(&proj, &mut twice, 1);
        m.backproject_into(&proj, &mut twice, 1);
        for (o, t) in once.iter().zip(&twice) {
            assert_eq!(*t, o + o, "backproject_into must accumulate");
        }
    }

    #[test]
    fn fingerprint_distinguishes_slabs_and_chunks() {
        let g = Geometry::cone_beam(16, 8);
        let a = geometry_fingerprint(&g.slab_geometry(0, 8));
        let b = geometry_fingerprint(&g.slab_geometry(8, 16));
        let c = geometry_fingerprint(&g.slab_geometry(0, 8).angle_chunk_geometry(0, 4));
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, geometry_fingerprint(&g.slab_geometry(0, 8)));
    }

    #[test]
    fn bytes_reflects_nnz() {
        let g = Geometry::cone_beam(12, 4);
        let m = SparseSystemMatrix::build(&g, 1);
        assert!(m.nnz() > 0);
        assert!(m.bytes() >= m.nnz() as u64 * 16);
    }
}
