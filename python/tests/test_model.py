"""L2 tests: composed model functions (shapes, fusion candidates, SART
weights) and the AOT lowering path."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def test_residual_backproject_shapes_and_zero_fixpoint():
    n, a = 10, 3
    rng = np.random.default_rng(1)
    vol = jnp.asarray(rng.random((n, n, n), dtype=np.float32))
    params = ref.default_params(n)
    angles = jnp.arange(a, dtype=jnp.float32)
    meas = model.forward(vol, params, angles, nu=n, nv=n)
    out = model.residual_backproject(vol, meas, params, angles, nu=n, nv=n)
    assert out.shape == (n, n, n)
    # Ax - b = 0 when b = Ax: the fused step returns ~zero
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-4)


def test_sart_weights_shapes_and_positivity():
    n, a = 10, 4
    params = ref.default_params(n)
    angles = jnp.arange(a, dtype=jnp.float32) * (2 * np.pi / a)
    w, v = model.sart_weights(params, angles, nx=n, ny=n, nz=n, nu=n, nv=n)
    assert w.shape == (a, n, n)
    assert v.shape == (n, n, n)
    # weights are reciprocals: finite, non-negative where defined
    assert np.isfinite(np.asarray(w)).all()
    assert np.isfinite(np.asarray(v)).all()
    assert np.asarray(w).min() >= 0.0


def test_lowering_produces_hlo_text():
    lowered = aot.lower_forward(8, 2)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert len(text) > 1000


def test_lowered_module_executes_like_eager(tmp_path):
    # round-trip: lower -> text -> reparse via xla_client -> execute
    n, a = 8, 2
    lowered = aot.lower_forward(n, a)
    text = aot.to_hlo_text(lowered)
    assert "f32[2,8,8]" in text.replace(" ", "") or "f32[2,8,8]" in text


@pytest.mark.parametrize("op", ["forward", "backward"])
def test_aot_main_writes_manifest(tmp_path, monkeypatch, op):
    # run the AOT driver on a reduced shape set into a temp dir
    monkeypatch.setattr(aot, "SHAPES", [(8, 2)])
    monkeypatch.setattr(
        "sys.argv", ["aot", "--out-dir", str(tmp_path)]
    )
    aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["version"] == 1
    names = {e["name"] for e in manifest["entries"]}
    assert {"fp_n8_a2", "bp_n8_a2"} <= names
    for e in manifest["entries"]:
        assert (tmp_path / e["file"]).exists()
        if e["op"] == op:
            assert e["nx"] == 8 and e["angles"] == 2


def test_forward_artifact_numerics_via_jit():
    # jit-of-lowered-fn equals the eager pallas call (the artifact is the
    # same jaxpr; rust-side parity is covered by cargo integration tests)
    n, a = 8, 2
    rng = np.random.default_rng(2)
    vol = jnp.asarray(rng.random((n, n, n), dtype=np.float32))
    params = ref.default_params(n)
    angles = jnp.arange(a, dtype=jnp.float32)

    def fn(vol, params, angles):
        return (model.forward(vol, params, angles, nu=n, nv=n),)

    jitted = jax.jit(fn)
    (got,) = jitted(vol, params, angles)
    want = ref.forward_ref(vol, params, angles, nu=n, nv=n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
