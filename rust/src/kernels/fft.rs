//! Radix-2 complex FFT (substrate for FDK ramp filtering; no external FFT
//! crate is available offline).
//!
//! Iterative Cooley–Tukey with bit-reversal permutation. Sizes must be
//! powers of two — the filtering module zero-pads detector rows to the
//! next power of two ≥ 2·nu, which also linearizes the circular
//! convolution.

/// Complex number as (re, im).
pub type C64 = (f64, f64);

/// In-place forward FFT. `x.len()` must be a power of two.
pub fn fft(x: &mut [C64]) {
    transform(x, false);
}

/// In-place inverse FFT (including the 1/N scale).
pub fn ifft(x: &mut [C64]) {
    transform(x, true);
    let n = x.len() as f64;
    for v in x.iter_mut() {
        v.0 /= n;
        v.1 /= n;
    }
}

fn transform(x: &mut [C64], inverse: bool) {
    let n = x.len();
    assert!(n.is_power_of_two(), "fft size {n} must be a power of two");
    if n <= 1 {
        return;
    }
    // bit-reversal permutation
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i as u32).reverse_bits() >> (32 - bits);
        let j = j as usize;
        if i < j {
            x.swap(i, j);
        }
    }
    // butterfly passes
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let wlen = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let mut w: C64 = (1.0, 0.0);
            for j in 0..len / 2 {
                let u = x[i + j];
                let t = cmul(x[i + j + len / 2], w);
                x[i + j] = (u.0 + t.0, u.1 + t.1);
                x[i + j + len / 2] = (u.0 - t.0, u.1 - t.1);
                w = cmul(w, wlen);
            }
            i += len;
        }
        len <<= 1;
    }
}

#[inline]
fn cmul(a: C64, b: C64) -> C64 {
    (a.0 * b.0 - a.1 * b.1, a.0 * b.1 + a.1 * b.0)
}

/// Next power of two ≥ n.
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_random() {
        let mut rng = crate::util::pcg::Pcg32::new(1);
        let orig: Vec<C64> = (0..256).map(|_| (rng.next_f64() - 0.5, rng.next_f64() - 0.5)).collect();
        let mut x = orig.clone();
        fft(&mut x);
        ifft(&mut x);
        for (a, b) in orig.iter().zip(&x) {
            assert!((a.0 - b.0).abs() < 1e-10 && (a.1 - b.1).abs() < 1e-10);
        }
    }

    #[test]
    fn delta_transforms_to_flat_spectrum() {
        let mut x = vec![(0.0, 0.0); 8];
        x[0] = (1.0, 0.0);
        fft(&mut x);
        for v in &x {
            assert!((v.0 - 1.0).abs() < 1e-12 && v.1.abs() < 1e-12);
        }
    }

    #[test]
    fn single_tone_peaks_at_bin() {
        let n = 64;
        let k = 5;
        let mut x: Vec<C64> = (0..n)
            .map(|i| {
                let ph = 2.0 * std::f64::consts::PI * k as f64 * i as f64 / n as f64;
                (ph.cos(), 0.0)
            })
            .collect();
        fft(&mut x);
        // energy splits between bins k and n-k
        let mag: Vec<f64> = x.iter().map(|c| (c.0 * c.0 + c.1 * c.1).sqrt()).collect();
        assert!(mag[k] > 31.0 && mag[n - k] > 31.0);
        let others: f64 = mag
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != k && *i != n - k)
            .map(|(_, m)| m)
            .sum();
        assert!(others < 1e-8, "leakage {others}");
    }

    #[test]
    fn parseval_energy_preserved() {
        let mut rng = crate::util::pcg::Pcg32::new(3);
        let orig: Vec<C64> = (0..128).map(|_| (rng.next_f64(), 0.0)).collect();
        let time_energy: f64 = orig.iter().map(|c| c.0 * c.0 + c.1 * c.1).sum();
        let mut x = orig;
        fft(&mut x);
        let freq_energy: f64 =
            x.iter().map(|c| c.0 * c.0 + c.1 * c.1).sum::<f64>() / x.len() as f64;
        assert!((time_energy - freq_energy).abs() < 1e-9 * time_energy);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_pow2_panics() {
        let mut x = vec![(0.0, 0.0); 12];
        fft(&mut x);
    }
}
