//! Coordinator-level end-to-end benchmark runner: pipelined executor vs
//! the sequential baseline (see `tigre::bench::coordinator`), producer of
//! the `BENCH_coordinator.json` perf trajectory.
//!
//! Usage:
//!   cargo bench --bench coordinator                            # print table
//!   cargo bench --bench coordinator -- --smoke                 # CI sanity run
//!   cargo bench --bench coordinator -- \
//!       --json BENCH_coordinator.json --label post-PR3         # append a run
//!
//! Thread count follows `TIGRE_THREADS` when set; the pipelined executor
//! divides the same total across its device workers, so the comparison is
//! iso-parallelism. Reported medians are sim-subtracted (the DES replay
//! cost, identical on both sides, is measured and removed — see
//! `bench::coordinator`).

use tigre::bench::{coordinator as cb, parse_bench_args};
use tigre::kernels;
use tigre::util::stats::fmt_duration;

fn main() {
    let args = parse_bench_args();
    let threads = kernels::kernel_threads();
    println!(
        "=== coordinator executors: pipelined vs sequential ({threads} host threads{}) ===",
        if args.smoke { ", smoke mode" } else { "" }
    );

    let entries = cb::run_suite(args.smoke, threads);
    for e in &entries {
        println!(
            "{:<36} sequential {:>10}  pipelined {:>10}  {:>5.2}x  (sim {:>9}, {} samples)",
            e.name,
            fmt_duration(e.sequential_median_s),
            fmt_duration(e.pipelined_median_s),
            e.speedup(),
            fmt_duration(e.sim_median_s),
            e.samples,
        );
    }

    if let Some(path) = args.json_path {
        if let Err(e) = cb::append_run_to_file(&path, &args.label, threads, args.smoke, &entries) {
            eprintln!("error: writing {}: {e:#}", path.display());
            std::process::exit(1);
        }
        println!("appended run '{}' to {}", args.label, path.display());
    }
}
