//! TIGRE's reconstruction algorithm suite, built on the coordinator's
//! multi-GPU operators. Every `Ax` / `Aᵀb` inside these algorithms goes
//! through [`crate::coordinator::MultiGpu`], so arbitrarily large volumes
//! reconstruct on arbitrarily small (simulated) devices — the whole point
//! of the paper ("by adapting the GPU code …, TIGRE will also
//! automatically handle such images").

pub mod asd_pocs;
pub mod cgls;
pub mod common;
pub mod fdk;
pub mod fista;
pub mod landweber;
pub mod ossart;

pub use asd_pocs::asd_pocs;
pub use cgls::cgls;
pub use common::{ReconOpts, ReconResult};
pub use fdk::fdk;
pub use fista::fista;
pub use landweber::{landweber, mlem};
pub use ossart::{os_sart, sart, sirt};
