// Seeded violation for the `safety-comment` lint: checked under the
// pretend path rust/src/kernels/fixture.rs. Never compiled.

pub fn write_raw(p: *mut f32) {
    unsafe {
        *p = 1.0;
    }
}

pub fn justified(p: *mut f32) {
    // SAFETY: the caller hands a valid, exclusively owned pointer —
    // this block must NOT be reported.
    unsafe {
        *p = 2.0;
    }
}

pub fn justified_split_statement(p: *mut f32, n: usize) -> &'static mut [f32] {
    // SAFETY: comment separated from the unsafe token by a statement
    // continuation line — also must NOT be reported.
    let view =
        unsafe { std::slice::from_raw_parts_mut(p, n) };
    view
}
