"""L1 Pallas kernel: interpolated cone-beam forward projector.

Grid: one step per projection angle. Each step holds the image slab in
VMEM (the BlockSpec is the HBM->VMEM schedule: the analogue of the CUDA
texture residency in the paper's kernels), computes every detector pixel
of that angle with vectorized gather + lerp on the VPU, and writes one
(nv, nu) projection block out.

TPU adaptation notes (DESIGN.md §3): the paper's 9x9x9 thread blocks
tuned for texture-cache hit rate become a per-angle VMEM-resident slab +
a fully vectorized detector sweep; the hardware trilinear fetch of CUDA
textures becomes explicit gather + lerp. `interpret=True` everywhere: the
CPU PJRT plugin cannot execute Mosaic custom-calls, so the kernel lowers
to plain HLO (numerics identical, perf modelled in DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import geometry as geo


def _fp_kernel(vol_ref, params_ref, angle_ref, out_ref, *, nu, nv, n_steps):
    vol = vol_ref[...]
    params = params_ref[...]
    theta = angle_ref[0]
    nz, ny, nx = vol.shape
    lo, hi = geo.volume_bbox(params, nx, ny, nz)

    src = geo.source_pos(params, theta)
    pix = geo.detector_pixels(params, theta, nu, nv)  # (nv, nu, 3)
    tmin, tmax = geo.clip_ray_to_box(src, pix, lo, hi)
    span = jnp.where(tmax > tmin, tmax - tmin, 0.0)
    d = pix - src
    length = jnp.sqrt(jnp.sum(d * d, axis=-1))
    dt = span / n_steps
    seg = (dt * length).astype(vol.dtype)

    def body(i, acc):
        t = tmin + (i + 0.5) * dt  # (nv, nu)
        pts = src + t[..., None] * d  # (nv, nu, 3)
        return acc + geo.trilinear(vol, params, lo, pts)

    acc = jax.lax.fori_loop(0, n_steps, body, jnp.zeros((nv, nu), vol.dtype))
    out_ref[0, :, :] = acc * seg


@functools.partial(jax.jit, static_argnames=("nu", "nv", "step_frac"))
def forward(vol, params, angles, nu, nv, step_frac=0.5):
    """Pallas forward projection: vol (nz,ny,nx) -> proj (A,nv,nu)."""
    nz, ny, nx = vol.shape
    a = angles.shape[0]
    n_steps = geo.fp_n_steps(nx, ny, nz, step_frac)
    kernel = functools.partial(_fp_kernel, nu=nu, nv=nv, n_steps=n_steps)
    return pl.pallas_call(
        kernel,
        grid=(a,),
        in_specs=[
            # whole volume resident per step (slab residency: the
            # coordinator feeds slab-sized volumes for big problems)
            pl.BlockSpec((nz, ny, nx), lambda i: (0, 0, 0)),
            pl.BlockSpec((12,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, nv, nu), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((a, nv, nu), vol.dtype),
        interpret=True,
    )(vol, params, angles)
