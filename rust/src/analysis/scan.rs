//! Minimal Rust lexer/scanner backing `tigre-lint` (see [`crate::analysis`]).
//!
//! Deliberately *not* a parser: just enough token structure to drive the
//! repo's lint catalog without any dependency — the checker must be able
//! to run on a tree that does not compile yet (ROADMAP "toolchain debt").
//! It provides:
//!
//! * comment/string/char-literal stripping with line/column positions,
//! * `#[cfg(test)]` region marking that understands items (`mod tests`),
//!   enum variants (`PanicInject,`) and match arms
//!   (`Backend::PanicInject { .. } | ... => body,`),
//! * an enclosing-`fn`-name per token (nearest *named* `fn`; closures
//!   attribute to the function that contains them), which is what the
//!   allowlist's `fn <name>` matcher keys on.

/// Token class. Comments are stripped during lexing — the `// SAFETY:`
/// lint inspects raw source lines instead.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifiers and keywords.
    Ident,
    /// Operators and delimiters (multi-char runs joined, e.g. `=>`).
    Punct,
    /// String, char and numeric literals.
    Literal,
    /// `'a`-style lifetimes (disambiguated from char literals).
    Lifetime,
}

/// One lexed token with its 1-based source position.
#[derive(Clone, Debug)]
pub struct Tok {
    /// Token text as it appears in the source.
    pub text: String,
    /// Token class.
    pub kind: TokKind,
    /// 1-based source line.
    pub line: u32,
    /// 1-based source column.
    pub col: u32,
}

struct Lexer {
    chars: Vec<char>,
    i: usize,
    line: u32,
    col: u32,
}

impl Lexer {
    fn new(src: &str) -> Self {
        Lexer { chars: src.chars().collect(), i: 0, line: 1, col: 1 }
    }

    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.i + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.i).copied()?;
        self.i += 1;
        if c == '\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }

    /// Consume a `"`-delimited string body (opening quote already eaten).
    fn string_body(&mut self) {
        while let Some(c) = self.bump() {
            match c {
                '\\' => {
                    self.bump();
                }
                '"' => return,
                _ => {}
            }
        }
    }

    /// Consume a raw string `r"…"` / `r#"…"#` (the `r` already eaten,
    /// `self.i` at the first `#` or `"`).
    fn raw_string_body(&mut self) {
        let mut hashes = 0usize;
        while self.peek(0) == Some('#') {
            hashes += 1;
            self.bump();
        }
        if self.peek(0) != Some('"') {
            return; // not actually a raw string; nothing sensible to do
        }
        self.bump();
        loop {
            match self.bump() {
                None => return,
                Some('"') => {
                    let mut k = 0usize;
                    while k < hashes && self.peek(0) == Some('#') {
                        self.bump();
                        k += 1;
                    }
                    if k == hashes {
                        return;
                    }
                }
                Some(_) => {}
            }
        }
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into code tokens. Comments, whitespace and string/char
/// contents are dropped; multi-char operators the lints care about
/// (`=>`, `+=`, `::`, `->`) are joined into single tokens.
pub fn lex(src: &str) -> Vec<Tok> {
    let mut lx = Lexer::new(src);
    let mut toks: Vec<Tok> = Vec::new();
    while let Some(c) = lx.peek(0) {
        let (line, col) = (lx.line, lx.col);
        if c.is_whitespace() {
            lx.bump();
            continue;
        }
        // comments
        if c == '/' && lx.peek(1) == Some('/') {
            while let Some(c) = lx.peek(0) {
                if c == '\n' {
                    break;
                }
                lx.bump();
            }
            continue;
        }
        if c == '/' && lx.peek(1) == Some('*') {
            lx.bump();
            lx.bump();
            let mut depth = 1usize;
            while depth > 0 {
                match (lx.peek(0), lx.peek(1)) {
                    (Some('/'), Some('*')) => {
                        lx.bump();
                        lx.bump();
                        depth += 1;
                    }
                    (Some('*'), Some('/')) => {
                        lx.bump();
                        lx.bump();
                        depth -= 1;
                    }
                    (Some(_), _) => {
                        lx.bump();
                    }
                    (None, _) => break,
                }
            }
            continue;
        }
        // strings (plain, byte, raw)
        if c == '"' {
            lx.bump();
            lx.string_body();
            toks.push(Tok { text: String::new(), kind: TokKind::Literal, line, col });
            continue;
        }
        if c == 'r' && matches!(lx.peek(1), Some('"') | Some('#')) {
            lx.bump();
            lx.raw_string_body();
            toks.push(Tok { text: String::new(), kind: TokKind::Literal, line, col });
            continue;
        }
        if c == 'b' && lx.peek(1) == Some('"') {
            lx.bump();
            lx.bump();
            lx.string_body();
            toks.push(Tok { text: String::new(), kind: TokKind::Literal, line, col });
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            let one = lx.peek(1);
            let two = lx.peek(2);
            let is_lifetime =
                one.is_some_and(|c1| is_ident_start(c1)) && two != Some('\'');
            lx.bump();
            if is_lifetime {
                let mut text = String::from("'");
                while let Some(c1) = lx.peek(0) {
                    if is_ident_continue(c1) {
                        text.push(c1);
                        lx.bump();
                    } else {
                        break;
                    }
                }
                toks.push(Tok { text, kind: TokKind::Lifetime, line, col });
            } else {
                // char literal: consume through the closing quote
                while let Some(c1) = lx.bump() {
                    match c1 {
                        '\\' => {
                            lx.bump();
                        }
                        '\'' => break,
                        _ => {}
                    }
                }
                toks.push(Tok { text: String::new(), kind: TokKind::Literal, line, col });
            }
            continue;
        }
        // identifiers / keywords
        if is_ident_start(c) {
            let mut text = String::new();
            while let Some(c1) = lx.peek(0) {
                if is_ident_continue(c1) {
                    text.push(c1);
                    lx.bump();
                } else {
                    break;
                }
            }
            toks.push(Tok { text, kind: TokKind::Ident, line, col });
            continue;
        }
        // numbers (coarse: exponents lex as trailing tokens, which the
        // lints never look at)
        if c.is_ascii_digit() {
            let mut text = String::new();
            while let Some(c1) = lx.peek(0) {
                if c1.is_alphanumeric() || c1 == '_' || c1 == '.' {
                    // `0..n` range: don't swallow the second dot
                    if c1 == '.' && lx.peek(1) == Some('.') {
                        break;
                    }
                    text.push(c1);
                    lx.bump();
                } else {
                    break;
                }
            }
            toks.push(Tok { text, kind: TokKind::Literal, line, col });
            continue;
        }
        // punctuation, joining the operators the lints match on
        lx.bump();
        let joined = match (c, lx.peek(0)) {
            ('=', Some('>')) => Some("=>"),
            ('+', Some('=')) => Some("+="),
            (':', Some(':')) => Some("::"),
            ('-', Some('>')) => Some("->"),
            _ => None,
        };
        let text = if let Some(j) = joined {
            lx.bump();
            j.to_string()
        } else {
            c.to_string()
        };
        toks.push(Tok { text, kind: TokKind::Punct, line, col });
    }
    toks
}

/// True when `toks[i..]` starts the exact attribute `#[cfg(test)]`.
/// Deliberately strict: `#[cfg(not(test))]`, `#[cfg(any(test, …))]` and
/// `#[cfg_attr(test, …)]` are *not* test regions.
fn is_cfg_test_attr(toks: &[Tok], i: usize) -> bool {
    const PAT: [&str; 7] = ["#", "[", "cfg", "(", "test", ")", "]"];
    toks.len() >= i + PAT.len() && PAT.iter().enumerate().all(|(k, p)| toks[i + k].text == *p)
}

/// Consume one item/variant/arm starting at `start`; returns the
/// exclusive end index. An item ends at `;`/`,` at relative depth zero,
/// or after a balanced `{…}` block — unless the block is a pattern
/// fragment continued by `|` or `=>` (match arms), in which case the
/// scan continues through the arm body.
fn consume_item(toks: &[Tok], start: usize) -> usize {
    let (mut dp, mut db, mut dk) = (0i32, 0i32, 0i32);
    let mut k = start;
    while k < toks.len() {
        match toks[k].text.as_str() {
            "(" => dp += 1,
            ")" => dp -= 1,
            "[" => dk += 1,
            "]" => dk -= 1,
            "{" => db += 1,
            "}" => {
                db -= 1;
                if db < 0 {
                    return k; // closing an enclosing scope: stop before it
                }
                if dp <= 0 && dk <= 0 && db == 0 {
                    let continues = toks
                        .get(k + 1)
                        .is_some_and(|t| t.text == "|" || t.text == "=>");
                    if !continues {
                        return k + 1;
                    }
                }
            }
            ";" | "," if dp == 0 && db == 0 && dk == 0 => return k + 1,
            _ => {}
        }
        k += 1;
    }
    toks.len()
}

/// Per-token `#[cfg(test)]` membership (see module docs for the region
/// shapes understood).
pub fn mark_cfg_test(toks: &[Tok]) -> Vec<bool> {
    let mut test = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        if !is_cfg_test_attr(toks, i) {
            i += 1;
            continue;
        }
        let mut j = i + 7; // past `#[cfg(test)]`
        // skip any further stacked attributes
        while j < toks.len()
            && toks[j].text == "#"
            && toks.get(j + 1).is_some_and(|t| t.text == "[")
        {
            let mut d = 0i32;
            let mut k = j + 1;
            while k < toks.len() {
                match toks[k].text.as_str() {
                    "[" => d += 1,
                    "]" => {
                        d -= 1;
                        if d == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                k += 1;
            }
            j = k + 1;
        }
        let end = consume_item(toks, j);
        for t in test.iter_mut().take(end.min(toks.len())).skip(i) {
            *t = true;
        }
        i = end.max(i + 1);
    }
    test
}

/// Per-token enclosing named-`fn` name (closures attribute to the
/// containing function).
pub fn enclosing_fns(toks: &[Tok]) -> Vec<Option<String>> {
    let mut out: Vec<Option<String>> = vec![None; toks.len()];
    let mut stack: Vec<(String, i32)> = Vec::new();
    let mut depth = 0i32;
    let mut pending: Option<String> = None;
    for (i, t) in toks.iter().enumerate() {
        out[i] = stack.last().map(|(n, _)| n.clone());
        match t.text.as_str() {
            "fn" if t.kind == TokKind::Ident => {
                if let Some(next) = toks.get(i + 1) {
                    if next.kind == TokKind::Ident {
                        pending = Some(next.text.clone());
                    }
                }
            }
            "{" => {
                depth += 1;
                if let Some(name) = pending.take() {
                    stack.push((name, depth));
                }
            }
            "}" => {
                if stack.last().is_some_and(|&(_, d)| d == depth) {
                    stack.pop();
                }
                depth -= 1;
            }
            ";" => pending = None, // trait method declarations without a body
            _ => {}
        }
    }
    out
}

/// Everything the lint passes need about one source file.
pub struct FileModel {
    /// Normalized (forward-slash) path the file was checked under.
    pub path: String,
    /// Raw source lines, for snippets and comment-block scans.
    pub lines: Vec<String>,
    /// Code tokens (comments/whitespace stripped).
    pub toks: Vec<Tok>,
    /// Per-token: inside a `#[cfg(test)]` region.
    pub in_test: Vec<bool>,
    /// Per-token: nearest enclosing named `fn`.
    pub enclosing_fn: Vec<Option<String>>,
}

impl FileModel {
    /// Lex `src` and derive all per-token metadata under `path`.
    pub fn build(path: &str, src: &str) -> FileModel {
        let toks = lex(src);
        let in_test = mark_cfg_test(&toks);
        let enclosing_fn = enclosing_fns(&toks);
        FileModel {
            path: path.replace('\\', "/"),
            lines: src.lines().map(str::to_string).collect(),
            toks,
            in_test,
            enclosing_fn,
        }
    }

    /// 1-based line text (empty for out-of-range).
    pub fn line_text(&self, line: u32) -> &str {
        self.lines
            .get(line as usize - 1)
            .map(String::as_str)
            .unwrap_or("")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lint_scanner_strips_comments_and_strings() {
        let src = r#"
            // unwrap in a comment
            /* panic! in /* a nested */ block */
            let s = "unwrap() inside a string";
            let c = '"';
            let l: &'static str = s;
            x.unwrap();
        "#;
        let toks = lex(src);
        let unwraps: Vec<_> = toks.iter().filter(|t| t.text == "unwrap").collect();
        assert_eq!(unwraps.len(), 1, "only the code token survives");
        assert!(toks.iter().any(|t| t.text == "'static" && t.kind == TokKind::Lifetime));
    }

    #[test]
    fn lint_scanner_joins_compound_operators() {
        let toks = lex("a += 1; m::f(); p -> q; x => y");
        let puncts: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Punct)
            .map(|t| t.text.as_str())
            .collect();
        assert!(puncts.contains(&"+="));
        assert!(puncts.contains(&"::"));
        assert!(puncts.contains(&"->"));
        assert!(puncts.contains(&"=>"));
    }

    #[test]
    fn lint_cfg_test_marks_mod_variant_and_arm() {
        let src = r#"
            enum Backend {
                Native,
                #[cfg(test)]
                PanicInject { threads: usize },
            }
            fn dispatch(b: &Backend) {
                match b {
                    Backend::Native => {}
                    #[cfg(test)]
                    Backend::PanicInject { .. } => panic!("injected"),
                }
            }
            #[cfg(test)]
            mod tests {
                fn helper() { x.unwrap(); }
            }
        "#;
        let toks = lex(src);
        let test = mark_cfg_test(&toks);
        let tok_test = |needle: &str| {
            toks.iter()
                .zip(&test)
                .filter(|(t, _)| t.text == needle)
                .map(|(_, &m)| m)
                .collect::<Vec<bool>>()
        };
        // the arm body's panic! and the variant are test-marked
        assert_eq!(tok_test("panic"), vec![true]);
        assert_eq!(tok_test("unwrap"), vec![true]);
        assert!(tok_test("PanicInject").iter().all(|&m| m));
        // the non-test arm is not
        assert_eq!(tok_test("dispatch"), vec![false]);
        assert!(!tok_test("Native")[1], "match arm Native is not test code");
    }

    #[test]
    fn lint_cfg_not_test_is_not_a_test_region() {
        let toks = lex("#[cfg(not(test))] fn real() { x.unwrap(); }");
        let test = mark_cfg_test(&toks);
        assert!(test.iter().all(|&m| !m));
    }

    #[test]
    fn lint_enclosing_fn_attributes_closures_to_the_named_fn() {
        let src = r#"
            fn outer(xs: &[f32]) {
                let worker = move || {
                    for x in xs { *acc += *x; }
                };
            }
            fn other() {}
        "#;
        let toks = lex(src);
        let fns = enclosing_fns(&toks);
        let idx = toks.iter().position(|t| t.text == "+=").unwrap();
        assert_eq!(fns[idx].as_deref(), Some("outer"));
        let idx = toks.iter().position(|t| t.text == "other").unwrap();
        assert_eq!(fns[idx], None, "the fn name itself belongs to the outer scope");
    }
}
