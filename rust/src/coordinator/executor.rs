//! Execution context shared by the coordinator operators: device fleet
//! description, kernel backend selection and the per-operation report.

use crate::geometry::Geometry;
use crate::kernels::{BackprojWeight, Projector};
use crate::simgpu::timeline::{breakdown, Breakdown};
use crate::simgpu::{CostModel, FaultPlan, FaultScope, GpuSpec, SimNode};

use std::sync::Arc;
use crate::volume::{
    OocProjections, OocVolume, ProjChunkView, ProjInput, ProjectionSet, Volume, VolumeInput,
    VolumeSlabView,
};

use super::degrade::{DegradeLog, DegradeStats};
use super::error::ReconError;
use super::residency::ResidencyStats;
use super::splitter::MergeStrategy;

/// Kernel backend for the real-execution path.
#[derive(Clone, Debug)]
pub enum Backend {
    /// Native rust kernels (arbitrary shapes).
    Native { projector: Projector, weight: BackprojWeight, threads: usize },
    /// AOT-compiled Pallas/JAX artifacts via PJRT (manifest shapes only);
    /// falls back to native for shapes not in the manifest. `weight`
    /// selects the FDK vs pseudo-matched backprojection artifact.
    Pjrt { artifacts_dir: std::path::PathBuf, weight: BackprojWeight, threads: usize },
    /// Precomputed sparse system-matrix backend (ISSUE 10, after
    /// Marchesini et al. 2020): each slab×chunk unit's Siddon traversal
    /// is run **once** and stored as a CSR shard in the shared
    /// [`SparseShardCache`](super::residency::SparseShardCache); forward
    /// projection becomes SpMV (bit-
    /// identical to the Siddon kernel) and backprojection the matched
    /// adjoint SpMVᵀ. Iterations after the first skip the rebuild — the
    /// cache is keyed on each unit's sub-geometry fingerprint, which the
    /// `(geometry, plan)` pair fully determines — so repeated-iteration
    /// workloads amortize the one-time build
    /// ([`CostModel::sparse_crossover_iters`] predicts when).
    Sparse {
        /// Host kernel-thread budget, split across device workers like
        /// the other backends.
        threads: usize,
        /// Shared shard store; cloning the context shares the cache so a
        /// session's forward/backward handles reuse one set of shards.
        cache: Arc<super::residency::SparseShardCache>,
    },
    /// Fault-injection backend for the executor's shutdown tests: every
    /// kernel launch panics. Lets `coordinator::pipeline` prove that a
    /// worker panic drains the merge/loader lanes and propagates instead
    /// of deadlocking the scope.
    #[cfg(test)]
    PanicInject { threads: usize },
    /// Fault-injection backend for the numerical-health guards (ISSUE 8):
    /// computes with the native kernels, then poisons the first element
    /// of every output with `NaN`. Lets the pipeline tests prove a
    /// poisoned partial is caught at the merge boundary before it can
    /// reach the merged output.
    #[cfg(test)]
    NanInject { threads: usize },
}

impl Default for Backend {
    fn default() -> Self {
        Backend::Native {
            projector: Projector::Siddon,
            weight: BackprojWeight::Fdk,
            threads: crate::kernels::kernel_threads(),
        }
    }
}

/// User-facing projector selection (the `--projector` CLI flag and
/// `algorithms::ReconOpts::projector`): which operator family executes
/// `Ax`/`Aᵀy`. `Siddon`/`Joseph` are the ray-driven native kernels;
/// `Sparse` is the precomputed system-matrix backend
/// ([`Backend::Sparse`]), which pays a one-time build per slab×chunk
/// unit and then runs SpMV/SpMVᵀ every iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjectorChoice {
    /// Ray-driven Siddon (exact intersection lengths) — the default.
    Siddon,
    /// Ray-driven Joseph (bilinear interpolation along the main axis).
    Joseph,
    /// Precomputed CSR system matrix: SpMV forward (bit-identical to
    /// Siddon), matched-adjoint SpMVᵀ backward.
    Sparse,
}

impl ProjectorChoice {
    /// Parse a CLI spelling (`siddon`|`joseph`|`sparse`).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "siddon" => Ok(Self::Siddon),
            "joseph" => Ok(Self::Joseph),
            "sparse" => Ok(Self::Sparse),
            other => anyhow::bail!("unknown projector '{other}' (siddon|joseph|sparse)"),
        }
    }
}

/// Whether to run numerics, the timing model, or both.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Real kernels + simulated timeline (tests, examples).
    Full,
    /// Timeline only — no host data is allocated, so arbitrarily large
    /// problems can be *timed* (the Fig. 7–9 sweeps up to N = 3072).
    SimOnly,
}

/// How the executor runs the plan. `pipelined`/`workers` steer the
/// **real** numeric path only; `merge` also steers the simulated
/// timeline, which models whichever merge strategy will execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecutorConfig {
    /// `true` (default): the pipelined executor — device assignments run
    /// concurrently on a thread pool, staging goes through zero-copy
    /// slab/chunk views, and per-launch partials merge on a double-
    /// buffered lane overlapping the next kernel (coordinator::pipeline).
    /// `false`: the pre-PR3 host-sequential loops with owned-copy staging,
    /// kept as the benchmark comparison baseline.
    pub pipelined: bool,
    /// Concurrent device workers for the pipelined executor; `0` (default)
    /// means one per device assignment. Output is bit-identical for every
    /// value — this only throttles concurrency (tests pin it to 1).
    pub workers: usize,
    /// How image-split forward partials fold into the final projection
    /// set (linear host fold vs. log-depth pairwise reduction tree).
    /// Output is bit-identical for both — the tree executes the same
    /// canonical schedule ([`super::splitter::merge_schedule`]); only
    /// the merge critical path changes. No-op for angle-split forward
    /// and for backprojection (disjoint outputs, nothing to fold).
    pub merge: MergeStrategy,
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        Self { pipelined: true, workers: 0, merge: MergeStrategy::Linear }
    }
}

/// Simulated-time report for one operator call.
#[derive(Clone, Debug)]
pub struct OpStats {
    /// Virtual makespan of the schedule, seconds.
    pub makespan_s: f64,
    /// Fig.-9 style exposed-time breakdown.
    pub breakdown: Breakdown,
    /// Image partitions per device (`N_sp`).
    pub splits_per_device: usize,
    /// Whether host image memory was page-locked.
    pub pinned: bool,
    /// Peak device memory over the call, bytes (must be ≤ capacity).
    pub peak_device_bytes: u64,
    /// Residency-cache accounting for this call (all-zero when the call
    /// ran outside a `ReconSession` or with the cache disabled).
    pub residency: ResidencyStats,
    /// Degradation activity during this call: pressure-ladder rungs
    /// taken (evict/refine/spill), watchdog events and step backoffs
    /// (ISSUE 8). Empty (`is_clean()`) on an unpressured run.
    pub degradation: DegradeStats,
}

impl OpStats {
    /// Extract stats from a finished simulated schedule and its plan.
    pub fn from_sim(sim: &SimNode, plan: &super::splitter::Plan) -> Self {
        let peak = (0..sim.n_devices()).map(|d| sim.device_mem(d).peak()).max().unwrap_or(0);
        OpStats {
            makespan_s: sim.makespan(),
            breakdown: breakdown(sim.events()),
            splits_per_device: plan.splits_per_device(),
            pinned: plan.pin_image,
            peak_device_bytes: peak,
            residency: ResidencyStats::default(),
            degradation: DegradeStats::default(),
        }
    }
}

/// A multi-GPU execution context: the paper's "single node with any
/// number of GPUs with arbitrarily small memories".
///
/// # Examples
///
/// ```
/// use tigre::coordinator::{ExecMode, MultiGpu};
/// use tigre::geometry::Geometry;
///
/// // Plan a forward projection on a simulated 2-GPU node: no kernels
/// // run and no projection data is produced, only the schedule and
/// // its predicted stats.
/// let g = Geometry::cone_beam(64, 16);
/// let ctx = MultiGpu::gtx1080ti(2);
/// let (proj, stats) = ctx.forward(&g, None, ExecMode::SimOnly).unwrap();
/// assert!(proj.is_none());
/// assert!(stats.makespan_s > 0.0);
/// ```
#[derive(Clone, Debug)]
pub struct MultiGpu {
    /// Number of devices in the node.
    pub n_gpus: usize,
    /// Per-device hardware description (memory capacity, name).
    pub spec: GpuSpec,
    /// Timing constants the DES planner charges operations against.
    pub cost: CostModel,
    /// Splitting policy knobs (halo depth, pinning threshold, …).
    pub split: super::splitter::SplitConfig,
    /// Kernel backend executing FP/BP chunks (ray-traced or sparse).
    pub backend: Backend,
    /// Real-execution strategy (pipelined vs sequential baseline).
    pub exec: ExecutorConfig,
    /// Optional deterministic fault schedule (ISSUE 7). Drives both the
    /// simulated timeline (`FaultScope::Sim`, attached by `fresh_sim`)
    /// and the real pipelined executor (`FaultScope::Real`: bounded
    /// retry for transient faults, replanning onto survivors for
    /// permanent device loss). `None` (default) = fault-free.
    pub fault: Option<Arc<FaultPlan>>,
    /// Shared degradation recorder (ISSUE 8): the pressure ladder, the
    /// watchdog and the algorithms' step backoffs record here; the
    /// operator entry points drain it into [`OpStats::degradation`]
    /// after each call. Shared across clones of this context, so a
    /// session's forward/backward handles feed one log.
    pub degrade: Arc<DegradeLog>,
}

impl MultiGpu {
    /// The paper's workstation: `n` GTX 1080 Ti class devices.
    pub fn gtx1080ti(n_gpus: usize) -> Self {
        Self {
            n_gpus,
            spec: GpuSpec::gtx1080ti(),
            cost: CostModel::gtx1080ti_pcie3(),
            split: super::splitter::SplitConfig::default(),
            backend: Backend::default(),
            exec: ExecutorConfig::default(),
            fault: None,
            degrade: Arc::new(DegradeLog::new()),
        }
    }

    /// Same node but with devices shrunk to `mem_bytes` — used to force
    /// image splitting at test-sized problems.
    pub fn with_device_mem(mut self, mem_bytes: u64) -> Self {
        self.spec = GpuSpec::tiny(mem_bytes);
        self
    }

    /// Replace the kernel backend (builder-style).
    pub fn with_backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Override the host thread count of the kernel backend (reproducible
    /// benchmarking; see also the `TIGRE_THREADS` env var).
    pub fn with_threads(mut self, n: usize) -> Self {
        match &mut self.backend {
            Backend::Native { threads, .. }
            | Backend::Pjrt { threads, .. }
            | Backend::Sparse { threads, .. } => *threads = n,
            #[cfg(test)]
            Backend::PanicInject { threads } | Backend::NanInject { threads } => *threads = n,
        }
        self
    }

    /// Select the projector family by name (the `ReconOpts::projector` /
    /// `--projector` plumbing): `Siddon`/`Joseph` select the ray-driven
    /// native kernels, `Sparse` swaps in the precomputed system-matrix
    /// backend with a fresh shard cache.
    pub fn with_projector(mut self, choice: ProjectorChoice) -> Self {
        match choice {
            ProjectorChoice::Siddon | ProjectorChoice::Joseph => {
                let p = if choice == ProjectorChoice::Siddon {
                    Projector::Siddon
                } else {
                    Projector::Joseph
                };
                match &mut self.backend {
                    Backend::Native { projector, .. } => *projector = p,
                    // Non-native backends keep their own projector story
                    // (PJRT artifacts bake it in; the injection backends
                    // exist to fail, not to project).
                    Backend::Pjrt { .. } | Backend::Sparse { .. } => {
                        self.backend = Backend::Native {
                            projector: p,
                            weight: BackprojWeight::Fdk,
                            threads: crate::kernels::kernel_threads(),
                        }
                    }
                    #[cfg(test)]
                    Backend::PanicInject { .. } | Backend::NanInject { .. } => {}
                }
                self
            }
            // Idempotent on an already-sparse backend: keep the existing
            // shard cache so nested entry points (e.g. ASD-POCS's inner
            // OS-SART sweep) reuse the shards the outer loop built
            // instead of resetting the cache every sweep.
            ProjectorChoice::Sparse => match &self.backend {
                Backend::Sparse { .. } => self,
                _ => self.with_sparse_backend(),
            },
        }
    }

    /// Swap in the precomputed sparse system-matrix backend (see
    /// [`Backend::Sparse`]) with a fresh shared shard cache.
    pub fn with_sparse_backend(mut self) -> Self {
        self.backend = Backend::Sparse {
            threads: crate::kernels::kernel_threads(),
            cache: Arc::new(super::residency::SparseShardCache::new()),
        };
        self
    }

    /// Shard-cache counters when the sparse backend is active (`None`
    /// otherwise). Tests assert "zero rebuilds on iteration 2+" through
    /// this.
    pub fn sparse_shard_stats(&self) -> Option<super::residency::SparseShardStats> {
        match &self.backend {
            Backend::Sparse { cache, .. } => Some(cache.stats()),
            Backend::Native { .. } | Backend::Pjrt { .. } => None,
            #[cfg(test)]
            Backend::PanicInject { .. } | Backend::NanInject { .. } => None,
        }
    }

    /// Run the real path through the pre-PR3 host-sequential loops —
    /// the benchmark baseline the pipelined executor is compared against.
    pub fn with_sequential_executor(mut self) -> Self {
        self.exec.pipelined = false;
        self
    }

    /// Cap the pipelined executor at `n` concurrent device workers
    /// (`0` = one per device). Output is identical for every value.
    pub fn with_workers(mut self, n: usize) -> Self {
        self.exec.workers = n;
        self
    }

    /// Select how image-split forward partials are merged (see
    /// [`ExecutorConfig::merge`]). Output is bit-identical for every
    /// strategy; only the merge critical path changes.
    pub fn with_merge_strategy(mut self, merge: MergeStrategy) -> Self {
        self.exec.merge = merge;
        self
    }

    /// Shorthand for `with_merge_strategy(MergeStrategy::Tree)`.
    pub fn with_tree_merge(self) -> Self {
        self.with_merge_strategy(MergeStrategy::Tree)
    }

    /// Attach a deterministic fault schedule: subsequent operator calls
    /// inject its faults into the simulated timeline and the real
    /// pipelined executor, which recovers per the ISSUE-7 policy
    /// (bounded retry / replan onto survivors) with bit-identical
    /// output. The plan is stateful — loss is sticky across calls — so
    /// build a fresh one per reconstruction scenario.
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault = Some(Arc::new(plan));
        self
    }

    /// Override the hung-unit watchdog deadline factor (deadline =
    /// predicted unit time × factor; see
    /// [`CostModel::watchdog_factor`]).
    pub fn with_watchdog_factor(mut self, factor: f64) -> Self {
        self.cost.watchdog_factor = factor;
        self
    }

    /// Advance the fault plan's iteration gate (called by the iterative
    /// algorithms at the top of each iteration). No-op without a plan.
    pub fn set_fault_iteration(&self, it: usize) {
        if let Some(f) = &self.fault {
            f.set_iteration(it);
        }
    }

    /// Total kernel host threads the backend was configured with.
    pub(crate) fn backend_threads(&self) -> usize {
        match &self.backend {
            Backend::Native { threads, .. }
            | Backend::Pjrt { threads, .. }
            | Backend::Sparse { threads, .. } => *threads,
            #[cfg(test)]
            Backend::PanicInject { threads } | Backend::NanInject { threads } => *threads,
        }
    }

    /// New simulated node with this context's spec, cost model and (if
    /// configured) fault plan attached.
    pub fn fresh_sim(&self) -> SimNode {
        let mut sim = SimNode::new(self.n_gpus, self.spec.clone(), self.cost.clone());
        if let Some(f) = &self.fault {
            f.begin_op(FaultScope::Sim);
            sim.set_fault_plan(f.clone());
        }
        sim
    }

    /// Forward projection `Ax` (Algorithm 1).
    pub fn forward(
        &self,
        g: &Geometry,
        vol: Option<&Volume>,
        mode: ExecMode,
    ) -> anyhow::Result<(Option<ProjectionSet>, OpStats)> {
        super::forward::run(self, g, vol, mode)
    }

    /// Backprojection `Aᵀb` (Algorithm 2).
    pub fn backward(
        &self,
        g: &Geometry,
        proj: Option<&ProjectionSet>,
        mode: ExecMode,
    ) -> anyhow::Result<(Option<Volume>, OpStats)> {
        super::backward::run(self, g, proj, mode)
    }

    /// Forward projection of a volume streamed from an out-of-core store
    /// (PR 5): plans via `splitter::plan_forward_ooc` with the store's
    /// cache budget as the host-memory budget, streams slabs through the
    /// pipelined executor's loader lanes, and charges the simulated disk
    /// engine — so `SimOnly` predicts when streaming hides behind
    /// kernels. Bit-identical to [`MultiGpu::forward`] on the same plan.
    ///
    /// Budget composition: the store's cache and the plan's staging are
    /// bounded by the same value **independently**, so worst-case host
    /// footprint is up to 2× the store budget (cache + in-flight
    /// staging). Size the store's budget to half the host RAM you are
    /// willing to spend on streaming.
    pub fn forward_ooc(
        &self,
        g: &Geometry,
        vol: &OocVolume,
        mode: ExecMode,
    ) -> anyhow::Result<(Option<ProjectionSet>, OpStats)> {
        let plan = super::splitter::plan_forward_ooc(
            g,
            self.n_gpus,
            self.spec.mem_bytes,
            &self.split,
            vol.budget_bytes(),
        )
        .map_err(|e| ReconError::Plan(format!("forward ooc plan: {e}")))?;
        super::forward::run_with(self, g, Some(VolumeInput::Ooc(vol)), mode, &plan, None)
    }

    /// Backprojection of projections streamed from an out-of-core store
    /// (see [`MultiGpu::forward_ooc`]).
    pub fn backward_ooc(
        &self,
        g: &Geometry,
        proj: &OocProjections,
        mode: ExecMode,
    ) -> anyhow::Result<(Option<Volume>, OpStats)> {
        let plan = super::splitter::plan_backward_ooc(
            g,
            self.n_gpus,
            self.spec.mem_bytes,
            &self.split,
            proj.budget_bytes(),
        )
        .map_err(|e| ReconError::Plan(format!("backward ooc plan: {e}")))?;
        super::backward::run_with(self, g, Some(ProjInput::Ooc(proj)), mode, &plan, None)
    }

    /// Run the real kernels for an angle-chunk of a (slab) geometry.
    ///
    /// Arena contract: the returned buffer is drawn from the calling
    /// thread's `kernels::scratch` arena; callers that consume the result
    /// (forward/backward `execute_real`, the iterative algorithms) hand it
    /// back via `scratch::recycle_projections` / `scratch::recycle_volume`
    /// so the next operator call reuses the allocation.
    pub(crate) fn kernel_forward(&self, g: &Geometry, vol: &Volume) -> ProjectionSet {
        match &self.backend {
            Backend::Native { projector, threads, .. } => {
                crate::kernels::forward(g, vol, *projector, *threads)
            }
            Backend::Pjrt { artifacts_dir, threads, .. } => {
                crate::runtime::forward_or_native(artifacts_dir, g, vol, *threads)
            }
            Backend::Sparse { threads, cache } => {
                let shard = cache.get_or_build(g, *threads);
                let mut p = crate::kernels::scratch::take_projections(
                    g.n_det[0],
                    g.n_det[1],
                    g.n_angles(),
                );
                shard.project_into(&vol.as_view(), &mut p.data, *threads);
                p
            }
            #[cfg(test)]
            Backend::PanicInject { .. } => panic!("injected kernel panic (test)"),
            #[cfg(test)]
            Backend::NanInject { threads } => {
                let mut p = crate::kernels::forward(g, vol, Projector::Siddon, *threads);
                if let Some(v) = p.data.first_mut() {
                    *v = f32::NAN;
                }
                p
            }
        }
    }

    pub(crate) fn kernel_backward(&self, g: &Geometry, proj: &ProjectionSet) -> Volume {
        match &self.backend {
            Backend::Native { weight, threads, .. } => {
                crate::kernels::backward(g, proj, *weight, *threads)
            }
            Backend::Pjrt { artifacts_dir, weight, threads } => {
                crate::runtime::backward_or_native(artifacts_dir, g, proj, *weight, *threads)
            }
            Backend::Sparse { threads, cache } => {
                let shard = cache.get_or_build(g, *threads);
                let mut v = crate::kernels::scratch::take_volume(
                    g.n_vox[0],
                    g.n_vox[1],
                    g.n_vox[2],
                );
                shard.backproject_into(&proj.as_view(), &mut v.data, *threads);
                v
            }
            #[cfg(test)]
            Backend::PanicInject { .. } => panic!("injected kernel panic (test)"),
            #[cfg(test)]
            Backend::NanInject { threads } => {
                let mut v = crate::kernels::backward(g, proj, BackprojWeight::Fdk, *threads);
                if let Some(x) = v.data.first_mut() {
                    *x = f32::NAN;
                }
                v
            }
        }
    }

    /// Zero-copy forward launch for the pipelined executor: project a
    /// borrowed slab view into `out`, overwriting every element. `threads`
    /// is the per-worker kernel thread budget (the pipeline divides the
    /// backend total across concurrent device workers).
    ///
    /// PJRT caveat: artifacts require owned host buffers, so the `Pjrt`
    /// arm below materializes the view **per launch**. The pipeline never
    /// takes that arm — it special-cases PJRT onto the owned
    /// `forward_or_native` path with at most one copy per slab (see
    /// `coordinator::pipeline`); the arm exists only as a correct fallback
    /// for callers without an owned buffer. Prefer the owned path.
    pub(crate) fn kernel_forward_into(
        &self,
        g: &Geometry,
        vol: &VolumeSlabView<'_>,
        out: &mut [f32],
        threads: usize,
    ) {
        match &self.backend {
            Backend::Native { projector, .. } => {
                crate::kernels::forward_into(g, vol, out, *projector, threads)
            }
            Backend::Pjrt { artifacts_dir, .. } => {
                let owned = vol.to_volume();
                let p = crate::runtime::forward_or_native(artifacts_dir, g, &owned, threads);
                out.copy_from_slice(&p.data);
                crate::kernels::scratch::recycle_projections(p);
                crate::kernels::scratch::recycle_volume(owned);
            }
            Backend::Sparse { cache, .. } => {
                cache.get_or_build(g, threads).project_into(vol, out, threads)
            }
            #[cfg(test)]
            Backend::PanicInject { .. } => panic!("injected kernel panic (test)"),
            #[cfg(test)]
            Backend::NanInject { .. } => {
                crate::kernels::forward_into(g, vol, out, Projector::Siddon, threads);
                if let Some(v) = out.first_mut() {
                    *v = f32::NAN;
                }
            }
        }
    }

    /// Zero-copy backprojection launch: accumulate (`+=`) a borrowed
    /// angle-chunk view into `out` (see [`MultiGpu::kernel_forward_into`]
    /// for the threading and PJRT caveats).
    pub(crate) fn kernel_backward_into(
        &self,
        g: &Geometry,
        proj: &ProjChunkView<'_>,
        out: &mut [f32],
        threads: usize,
    ) {
        match &self.backend {
            Backend::Native { weight, .. } => {
                crate::kernels::backward_into(g, proj, out, *weight, threads)
            }
            Backend::Pjrt { artifacts_dir, weight, .. } => {
                let owned = proj.to_projections();
                let v = crate::runtime::backward_or_native(artifacts_dir, g, &owned, *weight, threads);
                for (o, s) in out.iter_mut().zip(&v.data) {
                    *o += *s;
                }
                crate::kernels::scratch::recycle_volume(v);
                crate::kernels::scratch::recycle_projections(owned);
            }
            Backend::Sparse { cache, .. } => {
                cache.get_or_build(g, threads).backproject_into(proj, out, threads)
            }
            #[cfg(test)]
            Backend::PanicInject { .. } => panic!("injected kernel panic (test)"),
            #[cfg(test)]
            Backend::NanInject { .. } => {
                crate::kernels::backward_into(g, proj, out, BackprojWeight::Fdk, threads);
                if let Some(v) = out.first_mut() {
                    *v = f32::NAN;
                }
            }
        }
    }
}
