//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime.
//!
//! `artifacts/manifest.json` format:
//! ```json
//! {
//!   "version": 1,
//!   "entries": [
//!     {"name": "fp_n32_a8", "op": "forward",
//!      "nx": 32, "ny": 32, "nz": 32, "nu": 32, "nv": 32, "angles": 8,
//!      "file": "fp_n32_a8.hlo.txt"}
//!   ]
//! }
//! ```
//! Geometry scalars (DSD, DSO, pitches, offsets) and the angle list are
//! runtime *inputs* of every artifact, so one artifact serves any cone-
//! beam geometry of its shape.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Operator an artifact implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactOp {
    /// Forward projection (volume → projections).
    Forward,
    /// FDK-weighted backprojection.
    Backward,
    /// Pseudo-matched-weight backprojection (for CGLS/FISTA).
    BackwardMatched,
}

/// One AOT-compiled module.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    /// Artifact name (informational, e.g. `fp_n32_a8`).
    pub name: String,
    /// Which operator the module implements.
    pub op: ArtifactOp,
    /// Volume size in x.
    pub nx: usize,
    /// Volume size in y.
    pub ny: usize,
    /// Volume size in z.
    pub nz: usize,
    /// Detector columns.
    pub nu: usize,
    /// Detector rows.
    pub nv: usize,
    /// Number of projection angles.
    pub angles: usize,
    /// Path to the HLO text file, resolved against the manifest dir.
    pub file: PathBuf,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// All artifacts the manifest declares.
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`. A missing manifest is not an error —
    /// it just means "no artifacts", and callers fall back to native.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        if !path.exists() {
            return Ok(Manifest::default());
        }
        let text = std::fs::read_to_string(&path)?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON; `dir` anchors the per-entry file paths.
    pub fn parse(text: &str, dir: &Path) -> anyhow::Result<Manifest> {
        let v = Json::parse(text)?;
        let version = v.get("version").and_then(Json::as_u64).unwrap_or(0);
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let mut entries = Vec::new();
        for e in v
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'entries'"))?
        {
            let get_usize = |k: &str| -> anyhow::Result<usize> {
                e.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("manifest entry missing '{k}'"))
            };
            let op = match e.get("op").and_then(Json::as_str) {
                Some("forward") => ArtifactOp::Forward,
                Some("backward") => ArtifactOp::Backward,
                Some("backward_matched") => ArtifactOp::BackwardMatched,
                other => anyhow::bail!("bad manifest op {other:?}"),
            };
            entries.push(ManifestEntry {
                name: e
                    .get("name")
                    .and_then(Json::as_str)
                    .unwrap_or("unnamed")
                    .to_string(),
                op,
                nx: get_usize("nx")?,
                ny: get_usize("ny")?,
                nz: get_usize("nz")?,
                nu: get_usize("nu")?,
                nv: get_usize("nv")?,
                angles: get_usize("angles")?,
                file: dir.join(
                    e.get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow::anyhow!("manifest entry missing 'file'"))?,
                ),
            });
        }
        Ok(Manifest { entries })
    }

    /// Find an artifact for the exact operator + shape.
    pub fn find(
        &self,
        op: ArtifactOp,
        n_vox: [usize; 3],
        n_det: [usize; 2],
        angles: usize,
    ) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| {
            e.op == op
                && [e.nx, e.ny, e.nz] == n_vox
                && [e.nu, e.nv] == n_det
                && e.angles == angles
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "entries": [
            {"name": "fp_n32_a8", "op": "forward",
             "nx": 32, "ny": 32, "nz": 32, "nu": 32, "nv": 32, "angles": 8,
             "file": "fp_n32_a8.hlo.txt"},
            {"name": "bp_n32_a8", "op": "backward",
             "nx": 32, "ny": 32, "nz": 32, "nu": 32, "nv": 32, "angles": 8,
             "file": "bp_n32_a8.hlo.txt"}
        ]
    }"#;

    #[test]
    fn parses_and_finds() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/artifacts")).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.find(ArtifactOp::Forward, [32, 32, 32], [32, 32], 8).unwrap();
        assert_eq!(e.name, "fp_n32_a8");
        assert!(e.file.ends_with("fp_n32_a8.hlo.txt"));
        assert!(m.find(ArtifactOp::Forward, [32, 32, 32], [32, 32], 9).is_none());
        assert!(m.find(ArtifactOp::Backward, [32, 32, 32], [32, 32], 8).is_some());
    }

    #[test]
    fn missing_manifest_is_empty() {
        let m = Manifest::load(Path::new("/nonexistent-dir-xyz")).unwrap();
        assert!(m.entries.is_empty());
    }

    #[test]
    fn rejects_bad_version() {
        assert!(Manifest::parse(r#"{"version": 2, "entries": []}"#, Path::new(".")).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        let bad = r#"{"version": 1, "entries": [{"op": "forward", "nx": 1}]}"#;
        assert!(Manifest::parse(bad, Path::new(".")).is_err());
    }
}
