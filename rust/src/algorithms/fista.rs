//! FISTA with TV proximal operator: accelerated proximal gradient on
//! `min ‖Ax − b‖² + λ·TV(x)` (Beck & Teboulle 2009, as shipped in TIGRE).
//! The TV prox is solved by the multi-GPU ROF denoiser (§2.3).

use crate::coordinator::checkpoint::{self, CheckpointState};
use crate::coordinator::regularizer::rof_denoise_split;
use crate::coordinator::{MultiGpu, ReconSession};
use crate::geometry::Geometry;
use crate::kernels::scratch;
use crate::volume::{ProjectionSet, TrackedProjections, TrackedVolume, Volume};

use super::common::{projector_ctx, DivergenceGuard, ReconOpts, ReconResult};
use super::landweber::power_iteration_norm;
use super::ossart::matched_ctx;
use crate::coordinator::DegradeEvent;

/// FISTA options beyond the common ones.
#[derive(Clone, Debug)]
pub struct FistaOpts {
    /// Options shared by every iterative algorithm.
    pub common: ReconOpts,
    /// TV weight λ.
    pub tv_lambda: f32,
    /// Inner ROF iterations per prox evaluation.
    pub tv_iters: usize,
    /// Step size 1/L; if `None`, estimated by power iteration on AᵀA.
    pub step: Option<f32>,
}

impl Default for FistaOpts {
    fn default() -> Self {
        Self {
            common: ReconOpts::default(),
            tv_lambda: 0.05,
            tv_iters: 10,
            step: None,
        }
    }
}

/// FISTA-TV reconstruction.
pub fn fista(
    ctx: &MultiGpu,
    g: &Geometry,
    proj: &ProjectionSet,
    opts: &FistaOpts,
) -> anyhow::Result<ReconResult> {
    let ctx = matched_ctx(&projector_ctx(ctx, &opts.common));
    let mut sess = ReconSession::new(&ctx, g)?;

    // Estimate the Lipschitz constant L = ‖AᵀA‖ by power iteration.
    let mut step = match opts.step {
        Some(s) => s,
        None => (1.0 / power_iteration_norm(&mut sess, g, 42)?.max(1e-30)) as f32,
    };

    // constant measurement, device-resident across iterations
    let b = TrackedProjections::new(proj.clone());
    let mut x = Volume::zeros_like(g);
    let mut y = TrackedVolume::new(x.clone());
    let mut t = 1.0f32;
    let mut residuals = Vec::with_capacity(opts.common.iterations);
    // simulated time of the TV prox calls (outside the session)
    let mut prox_sim_s = 0.0f64;

    let (mut ck, resumed) = checkpoint::setup(&opts.common.checkpoint, "fista")?;
    let mut start = 0;
    if let Some(mut st) = resumed {
        // restore the momentum recurrence: both iterates and t (an f32
        // stored as f64 — the widening is exact, so the cast back is too)
        start = st.iteration.min(opts.common.iterations);
        residuals = st.residuals.clone();
        scratch::recycle_volume(std::mem::replace(&mut x, st.volume("x")?));
        scratch::recycle_volume(y.replace(st.volume("y")?));
        t = st.scalar("t")? as f32;
    }
    let mut guard = DivergenceGuard::new("fista", &opts.common);
    guard.seed(&residuals);
    for it in start..opts.common.iterations {
        ctx.set_fault_iteration(it);
        // gradient step on y: y − step·Aᵀ(Ay − b). The session forms the
        // residual against the resident b, returning Aᵀ(b − Ay) — the
        // negated gradient — so the update adds `+step` (IEEE negation is
        // exact: numerics are bit-identical to the old Aᵀ(Ay − b) form).
        let ay = sess.forward(&y)?;
        let (neg_grad, res_norm) = sess.backward_residual(&b, &ay)?;
        sess.recycle_projections(ay);
        residuals.push(res_norm); // ‖b − Ay‖₂ = ‖Ay − b‖₂
        // residual growth → shrink the step and restart the momentum
        // (adaptive restart) before applying this gradient step
        if let Some(f) = guard.check(it, res_norm)? {
            step *= f;
            t = 1.0;
            ctx.degrade
                .record(DegradeEvent::StepBackoff { algorithm: "fista", iteration: it });
        }
        let mut z = y.get().clone();
        z.add_scaled(&neg_grad, step);
        scratch::recycle_volume(neg_grad);
        // prox: multi-GPU ROF TV denoise
        let (x_new, stats) =
            rof_denoise_split(&ctx, &z, opts.tv_lambda * step, opts.tv_iters, opts.tv_iters)?;
        scratch::recycle_volume(z);
        prox_sim_s += stats.makespan_s;
        let mut x_new = x_new;
        if opts.common.nonneg {
            x_new.clamp_min(0.0);
        }
        // momentum
        let t_new = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
        let beta = (t - 1.0) / t_new;
        let mut y_new = x_new.clone();
        for (yv, (xn, xo)) in y_new.data.iter_mut().zip(x_new.data.iter().zip(&x.data)) {
            *yv = xn + beta * (xn - xo);
        }
        scratch::recycle_volume(std::mem::replace(&mut x, x_new));
        scratch::recycle_volume(y.replace(y_new));
        t = t_new;
        if opts.common.verbose {
            crate::log_info!("fista iter {it}: residual {:.4e}", residuals.last().unwrap());
        }
        if let Some(ck) = ck.as_mut() {
            if ck.due(it + 1) {
                ck.save(&CheckpointState {
                    iteration: it + 1,
                    residuals: residuals.clone(),
                    scalars: vec![("t".into(), t as f64)],
                    volumes: vec![("x".into(), x.clone()), ("y".into(), y.get().clone())],
                    ..Default::default()
                })?;
            }
        }
    }
    sess.recycle_projections(b);
    scratch::recycle_volume(y.into_inner());

    Ok(ReconResult {
        volume: x,
        residuals,
        sim_time_s: sess.sim_time_s + prox_sim_s,
        peak_device_bytes: sess.peak_device_bytes,
        backoffs: guard.backoffs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ExecMode;
    use crate::metrics;
    use crate::phantom;

    #[test]
    fn fista_converges_on_clean_data() {
        let n = 16;
        let g = Geometry::cone_beam(n, 20);
        let truth = phantom::cube(n, 0.5, 1.0);
        let ctx = MultiGpu::gtx1080ti(1);
        let (p, _) = ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap();
        let opts = FistaOpts {
            common: ReconOpts { iterations: 12, ..Default::default() },
            tv_lambda: 0.01,
            tv_iters: 5,
            step: None,
        };
        let r = fista(&ctx, &g, &p.unwrap(), &opts).unwrap();
        let corr = metrics::correlation(&truth, &r.volume);
        assert!(corr > 0.8, "correlation {corr}");
        let first = r.residuals[0];
        let last = *r.residuals.last().unwrap();
        assert!(last < first * 0.5, "residuals {first} → {last}");
    }

    #[test]
    fn fault_fista_resumes_from_checkpoint_bit_identically() {
        // momentum recurrence (x, y, t) must survive the round trip
        use crate::coordinator::CheckpointConfig;
        let n = 14;
        let g = Geometry::cone_beam(n, 12);
        let truth = phantom::cube(n, 0.5, 1.0);
        let ctx = MultiGpu::gtx1080ti(2);
        let (p, _) = ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap();
        let p = p.unwrap();
        let dir = std::env::temp_dir()
            .join("tigre_algo_ckpt")
            .join(format!("fista_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mk = |iterations, checkpoint| FistaOpts {
            common: ReconOpts { iterations, checkpoint, ..Default::default() },
            tv_lambda: 0.01,
            tv_iters: 4,
            step: None,
        };
        let clean = fista(&ctx, &g, &p, &mk(3, None)).unwrap();
        let ck = Some(CheckpointConfig::new(&dir, 1));
        let _partial = fista(&ctx, &g, &p, &mk(2, ck.clone())).unwrap();
        let resumed = fista(&ctx, &g, &p, &mk(3, ck)).unwrap();
        assert_eq!(resumed.volume.data, clean.volume.data);
        assert_eq!(resumed.residuals, clean.residuals);
    }

    #[test]
    fn fista_tv_denoises_noisy_projections() {
        // TV-regularized recon beats plain SIRT under projection noise.
        let n = 16;
        let g = Geometry::cone_beam(n, 20);
        let truth = phantom::cube(n, 0.5, 1.0);
        let ctx = MultiGpu::gtx1080ti(1);
        let (p, _) = ctx.forward(&g, Some(&truth), ExecMode::Full).unwrap();
        let mut noisy = p.unwrap();
        let mut rng = crate::util::pcg::Pcg32::new(6);
        let scale = 0.08 * noisy.data.iter().cloned().fold(f32::MIN, f32::max);
        for v in &mut noisy.data {
            *v += scale * rng.normal() as f32;
        }
        let r_fista = fista(
            &ctx,
            &g,
            &noisy,
            &FistaOpts {
                common: ReconOpts { iterations: 10, ..Default::default() },
                tv_lambda: 0.1,
                tv_iters: 8,
                step: None,
            },
        )
        .unwrap();
        let r_sirt = super::super::ossart::sirt(
            &ctx,
            &g,
            &noisy,
            &ReconOpts { iterations: 10, ..Default::default() },
        )
        .unwrap();
        let e_fista = metrics::rmse(&truth, &r_fista.volume);
        let e_sirt = metrics::rmse(&truth, &r_sirt.volume);
        assert!(e_fista < e_sirt, "fista {e_fista} vs sirt {e_sirt}");
    }
}
