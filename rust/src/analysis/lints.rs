//! The repo-specific lint catalog enforced by `tigre-lint`.
//!
//! Each lint is a machine-checked invariant the paper's splitting
//! strategy rests on (DESIGN.md §Static-analysis has the full catalog
//! and the waiver policy):
//!
//! 1.  `no-panic-paths` — no `unwrap`/`expect`/`panic!`/`todo!` in
//!     non-test coordinator/pipeline/out-of-core code. Failures must
//!     travel the typed `ReconError` path; the only waivable exception
//!     is the pipeline's lane protocol, where a closed channel proves a
//!     peer already panicked and unwinding into the scope join *is* the
//!     designed abort path.
//! 2.  `safety-comment` — every `unsafe` token is preceded by a
//!     `// SAFETY:` comment block stating the actual argument.
//! 3.  `typed-errors` — no `anyhow!`/`bail!`/`ensure!`/`.context()`
//!     stringly errors inside `coordinator/`; construct `ReconError`.
//!     The allowlist section for this lint must stay empty.
//! 4.  `no-wallclock` — no `Instant`/`SystemTime` in `simgpu/` or
//!     `coordinator/splitter.rs`: the DES and the planner must be
//!     deterministic functions of their inputs.
//! 5.  `deterministic-maps` — no `HashMap`/`HashSet` in schedule- or
//!     plan-producing modules (iteration order would leak
//!     nondeterminism into fold order); use `BTreeMap`/vectors.
//! 6.  `blessed-accumulation` — element-wise float accumulation
//!     (`+=` through a deref or index) in `coordinator/` only inside
//!     allowlisted merge sites, so every fold provably runs the one
//!     canonical `merge_schedule`.
//! 7.  `backend-match` — every `match` directly on a `Backend` value is
//!     exhaustive without a `_` arm and carries the `cfg(test)`
//!     injection arms (`PanicInject`/`NanInject`). Tuple matches that
//!     pair the backend with other state dispatch through the
//!     executor's own `Backend` match and are out of scope.
//! 8.  `no-bare-print` — no `println!`/`eprintln!` outside
//!     `main.rs`/`bench/`/`bin/`; library code reports through
//!     `util::log` or return values.

use super::scan::{FileModel, TokKind};
use super::Diagnostic;

/// Static description of one lint.
pub struct LintInfo {
    /// Stable lint id (doubles as the allowlist section name).
    pub id: &'static str,
    /// Whether a violation fails the run without `--deny-all`.
    pub deny_by_default: bool,
    /// One-line description shown by `tigre-lint --list`.
    pub summary: &'static str,
}

/// The catalog, in check order.
pub const LINTS: &[LintInfo] = &[
    LintInfo {
        id: "no-panic-paths",
        deny_by_default: true,
        summary: "no unwrap/expect/panic!/todo! in non-test coordinator/outofcore code",
    },
    LintInfo {
        id: "safety-comment",
        deny_by_default: true,
        summary: "every `unsafe` is preceded by a // SAFETY: comment",
    },
    LintInfo {
        id: "typed-errors",
        deny_by_default: true,
        summary: "coordinator failures construct ReconError, not anyhow!/bail!/ensure!/context",
    },
    LintInfo {
        id: "no-wallclock",
        deny_by_default: true,
        summary: "no Instant/SystemTime in simgpu/ or the splitter (DES determinism)",
    },
    LintInfo {
        id: "deterministic-maps",
        deny_by_default: true,
        summary: "no HashMap/HashSet in schedule/plan-producing modules",
    },
    LintInfo {
        id: "blessed-accumulation",
        deny_by_default: true,
        summary: "buffer `+=` accumulation in coordinator/ only inside blessed merge sites",
    },
    LintInfo {
        id: "backend-match",
        deny_by_default: true,
        summary: "matches on Backend are exhaustive and carry the cfg(test) arms",
    },
    LintInfo {
        id: "no-bare-print",
        deny_by_default: false,
        summary: "no bare println!/eprintln! outside main.rs/bench/bin",
    },
];

/// Look up a lint's catalog entry by id.
pub fn lint_info(id: &str) -> Option<&'static LintInfo> {
    LINTS.iter().find(|l| l.id == id)
}

// ---------------------------------------------------------------------------
// path scoping
// ---------------------------------------------------------------------------

fn in_coordinator(path: &str) -> bool {
    path.contains("coordinator/")
}

fn is_outofcore(path: &str) -> bool {
    path.ends_with("volume/outofcore.rs")
}

fn is_splitter(path: &str) -> bool {
    path.ends_with("coordinator/splitter.rs")
}

fn in_simgpu(path: &str) -> bool {
    path.contains("simgpu/")
}

/// Modules whose data structures feed schedules or plans (lint 5).
fn in_deterministic_scope(path: &str) -> bool {
    is_splitter(path)
        || in_simgpu(path)
        || path.ends_with("geometry/split.rs")
        || path.ends_with("coordinator/forward.rs")
        || path.ends_with("coordinator/backward.rs")
}

/// Entry points that own stdout/stderr (lint 8 exemptions).
fn print_exempt(path: &str) -> bool {
    path.ends_with("src/main.rs") || path.contains("/bench/") || path.contains("/bin/")
}

// ---------------------------------------------------------------------------
// the passes
// ---------------------------------------------------------------------------

/// Run every lint over one scanned file, appending raw (pre-allowlist)
/// diagnostics.
pub fn run_all(m: &FileModel, out: &mut Vec<Diagnostic>) {
    no_panic_paths(m, out);
    safety_comment(m, out);
    typed_errors(m, out);
    no_wallclock(m, out);
    deterministic_maps(m, out);
    blessed_accumulation(m, out);
    backend_match(m, out);
    no_bare_print(m, out);
}

fn push(m: &FileModel, out: &mut Vec<Diagnostic>, lint: &'static str, i: usize, msg: String) {
    let t = &m.toks[i];
    out.push(Diagnostic {
        lint,
        deny: lint_info(lint).map_or(true, |l| l.deny_by_default),
        path: m.path.clone(),
        line: t.line,
        col: t.col,
        message: msg,
        snippet: m.line_text(t.line).trim().to_string(),
        enclosing_fn: m.enclosing_fn[i].clone(),
    });
}

/// Is token `i` a method call named `name` (`.name(`)?
fn is_method_call(m: &FileModel, i: usize, name: &str) -> bool {
    m.toks[i].kind == TokKind::Ident
        && m.toks[i].text == name
        && i > 0
        && m.toks[i - 1].text == "."
        && m.toks.get(i + 1).is_some_and(|t| t.text == "(")
}

/// Is token `i` a macro invocation named `name` (`name!`)?
fn is_macro_call(m: &FileModel, i: usize, name: &str) -> bool {
    m.toks[i].kind == TokKind::Ident
        && m.toks[i].text == name
        && m.toks.get(i + 1).is_some_and(|t| t.text == "!")
}

fn no_panic_paths(m: &FileModel, out: &mut Vec<Diagnostic>) {
    if !in_coordinator(&m.path) && !is_outofcore(&m.path) {
        return;
    }
    for i in 0..m.toks.len() {
        if m.in_test[i] {
            continue;
        }
        for name in ["unwrap", "expect"] {
            if is_method_call(m, i, name) {
                push(
                    m,
                    out,
                    "no-panic-paths",
                    i,
                    format!(".{name}() on a recoverable path — return a typed error instead"),
                );
            }
        }
        for name in ["panic", "todo"] {
            if is_macro_call(m, i, name) {
                push(
                    m,
                    out,
                    "no-panic-paths",
                    i,
                    format!("{name}! on a recoverable path — return a typed error instead"),
                );
            }
        }
    }
}

fn safety_comment(m: &FileModel, out: &mut Vec<Diagnostic>) {
    for i in 0..m.toks.len() {
        let t = &m.toks[i];
        if t.kind != TokKind::Ident || t.text != "unsafe" {
            continue;
        }
        // Walk upward from the line above the `unsafe`: skip statement
        // continuation lines (a multi-line `let dst = \n unsafe {` split),
        // then require the contiguous comment block to say SAFETY:.
        let mut line = t.line.saturating_sub(1);
        let mut continuations = 0usize;
        let mut justified = false;
        while line >= 1 {
            let text = m.line_text(line).trim().to_string();
            if text.starts_with("//") {
                // scan the whole contiguous comment block
                let mut l = line;
                while l >= 1 {
                    let c = m.line_text(l).trim();
                    if !c.starts_with("//") {
                        break;
                    }
                    if c.contains("SAFETY:") {
                        justified = true;
                    }
                    l -= 1;
                }
                break;
            }
            // allow a few continuation lines of the same statement
            let ends_stmt = text.ends_with(';')
                || text.ends_with('{')
                || text.ends_with('}')
                || text.is_empty();
            if ends_stmt || continuations >= 3 {
                break;
            }
            continuations += 1;
            line -= 1;
        }
        if !justified {
            push(
                m,
                out,
                "safety-comment",
                i,
                "`unsafe` without a preceding // SAFETY: comment".to_string(),
            );
        }
    }
}

fn typed_errors(m: &FileModel, out: &mut Vec<Diagnostic>) {
    if !in_coordinator(&m.path) {
        return;
    }
    for i in 0..m.toks.len() {
        if m.in_test[i] {
            continue;
        }
        for name in ["anyhow", "bail", "ensure"] {
            if is_macro_call(m, i, name) {
                push(
                    m,
                    out,
                    "typed-errors",
                    i,
                    format!("{name}! builds a stringly error — construct a ReconError variant"),
                );
            }
        }
        for name in ["context", "with_context"] {
            if is_method_call(m, i, name) {
                push(
                    m,
                    out,
                    "typed-errors",
                    i,
                    format!(".{name}() wraps a stringly error — construct a ReconError variant"),
                );
            }
        }
    }
}

fn no_wallclock(m: &FileModel, out: &mut Vec<Diagnostic>) {
    if !in_simgpu(&m.path) && !is_splitter(&m.path) {
        return;
    }
    for i in 0..m.toks.len() {
        if m.in_test[i] {
            continue;
        }
        let t = &m.toks[i];
        if t.kind == TokKind::Ident && (t.text == "Instant" || t.text == "SystemTime") {
            push(
                m,
                out,
                "no-wallclock",
                i,
                format!("{} read in deterministic code — the DES/planner must not see wall-clock", t.text),
            );
        }
    }
}

fn deterministic_maps(m: &FileModel, out: &mut Vec<Diagnostic>) {
    if !in_deterministic_scope(&m.path) {
        return;
    }
    for i in 0..m.toks.len() {
        if m.in_test[i] {
            continue;
        }
        let t = &m.toks[i];
        if t.kind == TokKind::Ident && (t.text == "HashMap" || t.text == "HashSet") {
            push(
                m,
                out,
                "deterministic-maps",
                i,
                format!("{} in a schedule/plan-producing module — use BTreeMap or a vector", t.text),
            );
        }
    }
}

fn blessed_accumulation(m: &FileModel, out: &mut Vec<Diagnostic>) {
    if !in_coordinator(&m.path) {
        return;
    }
    for i in 0..m.toks.len() {
        if m.in_test[i] || m.toks[i].text != "+=" {
            continue;
        }
        // Scan the place expression back to the statement boundary: a
        // deref (`*dst += …`) or index (`buf[i] += …`) marks element-wise
        // accumulation into a shared buffer; scalar counters are fine.
        let mut is_buffer = false;
        let mut k = i;
        while k > 0 {
            k -= 1;
            match m.toks[k].text.as_str() {
                ";" | "{" | "}" | "=>" => break,
                "*" | "[" => {
                    is_buffer = true;
                }
                _ => {}
            }
        }
        if is_buffer {
            push(
                m,
                out,
                "blessed-accumulation",
                i,
                "buffer accumulation outside a blessed merge site — every fold must run \
                 the canonical merge_schedule"
                    .to_string(),
            );
        }
    }
}

fn backend_match(m: &FileModel, out: &mut Vec<Diagnostic>) {
    for i in 0..m.toks.len() {
        let t = &m.toks[i];
        if m.in_test[i] || t.kind != TokKind::Ident || t.text != "match" {
            continue;
        }
        // scrutinee: tokens up to the body `{` at bracket/paren depth 0
        let (mut dp, mut dk) = (0i32, 0i32);
        let mut body_open = None;
        let mut mentions_backend = false;
        for (j, s) in m.toks.iter().enumerate().skip(i + 1) {
            match s.text.as_str() {
                "(" => dp += 1,
                ")" => dp -= 1,
                "[" => dk += 1,
                "]" => dk -= 1,
                "{" if dp == 0 && dk == 0 => {
                    body_open = Some(j);
                    break;
                }
                _ => {}
            }
            if s.kind == TokKind::Ident && (s.text == "backend" || s.text == "Backend") {
                mentions_backend = true;
            }
        }
        let Some(open) = body_open else { continue };
        // tuple scrutinees pair the backend with other state and dispatch
        // through the executor's own Backend match — out of scope
        if !mentions_backend || m.toks.get(i + 1).is_some_and(|t| t.text == "(") {
            continue;
        }
        // walk the body: find the matching close, bare `_ =>` arms, and
        // the injection-variant idents
        let mut db = 0i32;
        let mut has_wildcard = false;
        let mut has_panic_inject = false;
        let mut has_nan_inject = false;
        let mut close = m.toks.len();
        for j in open..m.toks.len() {
            let s = &m.toks[j];
            match s.text.as_str() {
                "{" => db += 1,
                "}" => {
                    db -= 1;
                    if db == 0 {
                        close = j;
                        break;
                    }
                }
                "_" if db == 1
                    && m.toks.get(j + 1).is_some_and(|t| t.text == "=>")
                    && matches!(m.toks[j - 1].text.as_str(), "{" | "," | "}") =>
                {
                    has_wildcard = true;
                }
                "PanicInject" => has_panic_inject = true,
                "NanInject" => has_nan_inject = true,
                _ => {}
            }
        }
        let _ = close;
        if has_wildcard {
            push(
                m,
                out,
                "backend-match",
                i,
                "`_` arm in a match on Backend — a new backend variant would silently \
                 fall through; name every variant"
                    .to_string(),
            );
        } else if !has_panic_inject || !has_nan_inject {
            push(
                m,
                out,
                "backend-match",
                i,
                "match on Backend is missing the cfg(test) injection arms \
                 (PanicInject/NanInject)"
                    .to_string(),
            );
        }
    }
}

fn no_bare_print(m: &FileModel, out: &mut Vec<Diagnostic>) {
    if print_exempt(&m.path) {
        return;
    }
    for i in 0..m.toks.len() {
        if m.in_test[i] {
            continue;
        }
        for name in ["println", "eprintln"] {
            if is_macro_call(m, i, name) {
                push(
                    m,
                    out,
                    "no-bare-print",
                    i,
                    format!("bare {name}! in library code — report through util::log or a return value"),
                );
            }
        }
    }
}
