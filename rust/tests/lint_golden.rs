//! Golden-fixture tests for `tigre-lint` (ISSUE 9).
//!
//! Each fixture under `tests/lint_fixtures/` seeds violations of exactly
//! one lint; the test asserts every diagnostic the checker emits for it
//! carries that lint id (cross-firing into another lint is a bug in the
//! fixture or the pass). The fixtures are never compiled — they are
//! checked as text under a *pretend* path, because paths select lint
//! scopes. `lint_repo_tree_is_clean` is the acceptance criterion the CI
//! lane enforces: the real tree, under the checked-in allowlist, has
//! zero diagnostics.

use std::path::Path;

use tigre::analysis::{self, Allowlist, Diagnostic};

fn check(pretend_path: &str, src: &str) -> Vec<Diagnostic> {
    analysis::check_source(pretend_path, src, &Allowlist::empty())
}

/// The fixture must trip at least once, and ONLY its intended lint.
fn assert_only(lint: &str, pretend_path: &str, src: &str) {
    let diags = check(pretend_path, src);
    assert!(!diags.is_empty(), "{lint}: fixture tripped nothing");
    for d in &diags {
        assert_eq!(
            d.lint, lint,
            "fixture for {lint} also tripped {} at {}:{} ({})",
            d.lint, d.path, d.line, d.snippet
        );
    }
}

#[test]
fn lint_fixture_no_panic_paths() {
    let src = include_str!("lint_fixtures/no_panic_paths.rs");
    let diags = check("rust/src/coordinator/fixture.rs", src);
    assert_only("no-panic-paths", "rust/src/coordinator/fixture.rs", src);
    // one each for unwrap / expect / panic! / todo!; the cfg(test) unwrap
    // is exempt
    assert_eq!(diags.len(), 4, "{}", analysis::render_text(&diags, false));
}

#[test]
fn lint_fixture_no_panic_paths_is_scoped_to_coordinator_and_ooc() {
    let src = include_str!("lint_fixtures/no_panic_paths.rs");
    assert!(
        check("rust/src/metrics/fixture.rs", src).is_empty(),
        "unwraps outside coordinator/outofcore scope must not be reported"
    );
    assert!(!check("rust/src/volume/outofcore.rs", src).is_empty());
}

#[test]
fn lint_fixture_safety_comment() {
    let src = include_str!("lint_fixtures/safety_comment.rs");
    let diags = check("rust/src/kernels/fixture.rs", src);
    assert_only("safety-comment", "rust/src/kernels/fixture.rs", src);
    // only the uncommented block: the justified and split-statement
    // blocks pass
    assert_eq!(diags.len(), 1, "{}", analysis::render_text(&diags, false));
    assert!(diags[0].snippet.contains("unsafe"));
}

#[test]
fn lint_fixture_typed_errors() {
    let src = include_str!("lint_fixtures/typed_errors.rs");
    assert_only("typed-errors", "rust/src/coordinator/fixture.rs", src);
    // anyhow! + ensure! + bail! + .context()
    assert_eq!(check("rust/src/coordinator/fixture.rs", src).len(), 4);
    assert!(
        check("rust/src/algorithms/fixture.rs", src).is_empty(),
        "typed-errors is scoped to coordinator/"
    );
}

#[test]
fn lint_fixture_no_wallclock() {
    let src = include_str!("lint_fixtures/no_wallclock.rs");
    assert_only("no-wallclock", "rust/src/simgpu/fixture.rs", src);
    assert_only("no-wallclock", "rust/src/coordinator/splitter.rs", src);
    assert!(
        check("rust/src/bench/fixture.rs", src).is_empty(),
        "wall-clock reads outside the DES/planner are fine"
    );
}

#[test]
fn lint_fixture_deterministic_maps() {
    let src = include_str!("lint_fixtures/deterministic_maps.rs");
    assert_only("deterministic-maps", "rust/src/geometry/split.rs", src);
    assert!(
        check("rust/src/volume/mod.rs", src).is_empty(),
        "hash maps outside schedule-producing modules are fine"
    );
}

#[test]
fn lint_fixture_blessed_accumulation() {
    let src = include_str!("lint_fixtures/blessed_accumulation.rs");
    let path = "rust/src/coordinator/fixture.rs";
    let diags = check(path, src);
    assert_only("blessed-accumulation", path, src);
    // the deref fold and the indexed fold; scalar counters pass
    assert_eq!(diags.len(), 2, "{}", analysis::render_text(&diags, false));

    // blessing the function by name waives it
    let allow = Allowlist::parse(
        "[blessed-accumulation]\nallow = \"coordinator/fixture.rs | fn rogue_fold\"\n",
    )
    .unwrap();
    let left = analysis::check_source(path, src, &allow);
    assert_eq!(left.len(), 1);
    assert_eq!(left[0].enclosing_fn.as_deref(), Some("rogue_indexed"));
}

#[test]
fn lint_fixture_backend_match() {
    let src = include_str!("lint_fixtures/backend_match.rs");
    let diags = check("rust/src/algorithms/fixture.rs", src);
    assert_only("backend-match", "rust/src/algorithms/fixture.rs", src);
    // the wildcard arm + the missing injection arms; the tuple match is
    // exempt
    assert_eq!(diags.len(), 2, "{}", analysis::render_text(&diags, false));
}

#[test]
fn lint_fixture_no_bare_print() {
    let src = include_str!("lint_fixtures/no_bare_print.rs");
    let diags = check("rust/src/metrics/fixture.rs", src);
    assert_only("no-bare-print", "rust/src/metrics/fixture.rs", src);
    assert_eq!(diags.len(), 2);
    assert!(diags.iter().all(|d| !d.deny), "no-bare-print warns by default");
    assert!(
        check("rust/src/main.rs", src).is_empty(),
        "main.rs owns stdout/stderr"
    );
    assert!(check("rust/src/bench/report.rs", src).is_empty());
}

#[test]
fn lint_fixture_clean_file_trips_nothing() {
    let src = include_str!("lint_fixtures/clean.rs");
    let diags = check("rust/src/coordinator/fixture.rs", src);
    assert!(diags.is_empty(), "{}", analysis::render_text(&diags, true));
}

/// The acceptance criterion: `tigre-lint --deny-all` exits 0 on the repo
/// tree. Runs the same walk + the checked-in allowlist the binary uses.
#[test]
fn lint_repo_tree_is_clean() {
    let src_root = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/src"));
    let allow_path = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/../lint-allow.toml"));
    let allow = Allowlist::load(allow_path).expect("checked-in allowlist parses");
    assert!(
        !allow.entries().is_empty(),
        "the checked-in allowlist should have loaded waiver entries"
    );
    assert!(
        !allow.entries().iter().any(|e| e.lint == "typed-errors"),
        "the typed-errors allowlist section must stay empty (ISSUE 9)"
    );
    let diags = analysis::check_tree(src_root, &allow).expect("tree walk");
    assert!(
        diags.is_empty(),
        "tigre-lint --deny-all would fail:\n{}",
        analysis::render_text(&diags, true)
    );
}
